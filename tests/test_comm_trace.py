"""repro.comm.trace unit tests: HLO text parsing, device-pair expansion,
and dependency-level overlap analysis on synthetic inputs (single device;
the end-to-end trace-vs-compiled-HLO assertions live in
tests/multidevice/test_comm_stream.py)."""
import numpy as np
import pytest

from repro.comm import (
    ScheduleTrace,
    TransferEvent,
    shift_perm,
    validate,
)
from repro.comm.trace import (
    collective_permutes,
    expected_pairs,
    independent_compute,
    parse_computations,
)

SYNTH_OVERLAPPABLE = """
HloModule m

%fused_computation (p0: f32[4], p1: f32[4]) -> f32[4] {
  %p0 = f32[4]{0} parameter(0)
  %p1 = f32[4]{0} parameter(1)
  ROOT %add = f32[4]{0} add(f32[4]{0} %p0, f32[4]{0} %p1)
}

ENTRY %main (a: f32[4,4], b: f32[4,4]) -> f32[4] {
  %a = f32[4,4]{1,0} parameter(0)
  %b = f32[4,4]{1,0} parameter(1)
  %collective-permute.1 = f32[4]{0} collective-permute(f32[4,4]{1,0} %a), channel_id=1, source_target_pairs={{0,1},{1,0},{2,3},{3,2},{4,5},{5,4},{6,7},{7,6}}
  %dot.1 = f32[4]{0} dot(f32[4,4]{1,0} %b, f32[4,4]{1,0} %b), lhs_contracting_dims={0}, rhs_contracting_dims={0}
  ROOT %fusion.1 = f32[4]{0} fusion(f32[4]{0} %collective-permute.1, f32[4]{0} %dot.1), kind=kLoop, calls=%fused_computation
}
"""

SYNTH_SERIAL = """
HloModule m

%fused_computation (p0: f32[4]) -> f32[4] {
  %p0 = f32[4]{0} parameter(0)
  ROOT %add = f32[4]{0} add(f32[4]{0} %p0, f32[4]{0} %p0)
}

ENTRY %main (a: f32[4,4]) -> f32[4] {
  %a = f32[4,4]{1,0} parameter(0)
  %collective-permute.1 = f32[4]{0} collective-permute(f32[4,4]{1,0} %a), channel_id=1, source_target_pairs={{0,1},{1,0},{2,3},{3,2},{4,5},{5,4},{6,7},{7,6}}
  ROOT %fusion.1 = f32[4]{0} fusion(f32[4]{0} %collective-permute.1), kind=kLoop, calls=%fused_computation
}
"""


SYNTH_WHILE_BODY = """
HloModule m

%body (arg_tuple.1: (f32[4,4], f32[4,4], s32[])) -> (f32[4,4], f32[4,4], s32[]) {
  %arg_tuple.1 = (f32[4,4]{1,0}, f32[4,4]{1,0}, s32[]) parameter(0)
  %get-tuple-element.1 = f32[4,4]{1,0} get-tuple-element((f32[4,4]{1,0}, f32[4,4]{1,0}, s32[]) %arg_tuple.1), index=0
  %get-tuple-element.2 = f32[4,4]{1,0} get-tuple-element((f32[4,4]{1,0}, f32[4,4]{1,0}, s32[]) %arg_tuple.1), index=1
  %get-tuple-element.3 = s32[] get-tuple-element((f32[4,4]{1,0}, f32[4,4]{1,0}, s32[]) %arg_tuple.1), index=2
  %collective-permute.2 = f32[4,4]{1,0} collective-permute(f32[4,4]{1,0} %get-tuple-element.1), channel_id=2, source_target_pairs={{0,1},{1,0},{2,3},{3,2},{4,5},{5,4},{6,7},{7,6}}
  %dot.2 = f32[4,4]{1,0} dot(f32[4,4]{1,0} %get-tuple-element.2, f32[4,4]{1,0} %get-tuple-element.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %tuple.1 = (f32[4,4]{1,0}, f32[4,4]{1,0}, s32[]) tuple(f32[4,4]{1,0} %collective-permute.2, f32[4,4]{1,0} %dot.2, s32[] %get-tuple-element.3)
}

ENTRY %main (a: f32[4,4]) -> f32[4,4] {
  %a = f32[4,4]{1,0} parameter(0)
  %tuple.0 = (f32[4,4]{1,0}, f32[4,4]{1,0}, s32[]) tuple(f32[4,4]{1,0} %a, f32[4,4]{1,0} %a)
  %while.1 = (f32[4,4]{1,0}, f32[4,4]{1,0}, s32[]) while((f32[4,4]{1,0}, f32[4,4]{1,0}, s32[]) %tuple.0), body=%body
  ROOT %gte = f32[4,4]{1,0} get-tuple-element((f32[4,4]{1,0}, f32[4,4]{1,0}, s32[]) %while.1), index=0
}
"""


class _FakeDev:
    def __init__(self, i):
        self.id = i


class _FakeMesh:
    """Duck-typed mesh: expected_pairs only touches devices/axis_names/shape."""

    def __init__(self, **shape):
        self.axis_names = tuple(shape)
        self.shape = shape
        n = int(np.prod(list(shape.values())))
        self.devices = np.array([_FakeDev(i) for i in range(n)]).reshape(
            tuple(shape.values()))


def test_parse_computations_splits_and_orders():
    comps = parse_computations(SYNTH_OVERLAPPABLE)
    assert set(comps) == {"%fused_computation", "ENTRY"} or len(comps) == 2
    entry = [c for name, c in comps.items() if any(
        i.op == "collective-permute" for i in c)][0]
    ops = [i.op for i in entry]
    assert "dot" in ops and "fusion" in ops and "parameter" in ops
    fusion = [i for i in entry if i.op == "fusion"][0]
    assert "%collective-permute.1" in fusion.operands
    assert "%dot.1" in fusion.operands


def test_collective_permutes_found():
    (p,) = collective_permutes(SYNTH_OVERLAPPABLE)
    assert p.op == "collective-permute"
    assert "%a" in p.operands


def test_independent_compute_detects_overlap_freedom():
    comps = parse_computations(SYNTH_OVERLAPPABLE)
    entry = [c for c in comps.values() if any(
        i.op == "collective-permute" for i in c)][0]
    perm = [i for i in entry if i.op == "collective-permute"][0]
    free = independent_compute(entry, perm)
    assert [i.name for i in free] == ["%dot.1"]  # fusion depends on permute

    comps = parse_computations(SYNTH_SERIAL)
    entry = [c for c in comps.values() if any(
        i.op == "collective-permute" for i in c)][0]
    perm = [i for i in entry if i.op == "collective-permute"][0]
    assert independent_compute(entry, perm) == []


def test_expected_pairs_matches_xla_expansion():
    """Pinned against observed XLA source_target_pairs on the (2,2,2)
    pod/data/model mesh."""
    mesh = _FakeMesh(pod=2, data=2, model=2)
    got = expected_pairs(mesh, ("model",), ((0, 1), (1, 0)))
    assert got == frozenset(
        [(0, 1), (1, 0), (2, 3), (3, 2), (4, 5), (5, 4), (6, 7), (7, 6)])
    got2 = expected_pairs(mesh, ("pod", "model"), shift_perm(4, 1))
    assert got2 == frozenset(
        [(0, 1), (1, 4), (4, 5), (5, 0), (2, 3), (3, 6), (6, 7), (7, 2)])


def _event(overlaps=""):
    return TransferEvent(stream="s", channel="s.hop", stage=0,
                         axes=("model",), perm=((0, 1), (1, 0)),
                         shape=(4,), n_tensors=1, overlaps=overlaps)


def test_validate_end_to_end_on_synthetic_hlo():
    mesh = _FakeMesh(pod=2, data=2, model=2)
    tr = ScheduleTrace("t", events=[_event(overlaps="attend")])
    rep = validate(tr, SYNTH_OVERLAPPABLE, mesh)
    assert rep.ok, rep.summary()
    assert rep.hlo_permutes == 1 and rep.overlapped == ["s.hop"]

    rep2 = validate(tr, SYNTH_SERIAL, mesh)
    assert not rep2.ok
    assert any("cannot overlap" in f for f in rep2.failures)

    # a put whose route never made it into the HLO is a failure
    tr3 = ScheduleTrace("t", events=[TransferEvent(
        stream="s", channel="s.other", stage=0, axes=("pod",),
        perm=((0, 1), (1, 0)), shape=(4,), n_tensors=1, overlaps="")])
    rep3 = validate(tr3, SYNTH_OVERLAPPABLE, mesh)
    assert not rep3.ok
    assert any("no collective-permute" in f for f in rep3.failures)


def test_tuple_param_computations_are_parsed():
    """Regression: while/fori-loop body computations have tuple-typed
    parameters (nested parens in the header); permutes inside them must be
    visible to the validator or non-unrolled ring schedules falsely fail."""
    comps = parse_computations(SYNTH_WHILE_BODY)
    assert any("%body" in name for name in comps)
    (p,) = collective_permutes(SYNTH_WHILE_BODY)
    assert p.computation.startswith("%body")
    body = [c for c in comps.values()
            if any(i.op == "collective-permute" for i in c)][0]
    assert [i.name for i in independent_compute(body, p)] == ["%dot.2"]
    mesh = _FakeMesh(pod=2, data=2, model=2)
    tr = ScheduleTrace("t", events=[_event(overlaps="attend")])
    rep = validate(tr, SYNTH_WHILE_BODY, mesh)
    assert rep.ok, rep.summary()


def test_validate_without_overlap_intent_passes_serial_hlo():
    mesh = _FakeMesh(pod=2, data=2, model=2)
    tr = ScheduleTrace("t", events=[_event()])
    assert validate(tr, SYNTH_SERIAL, mesh).ok
