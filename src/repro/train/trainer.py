"""Training loop: jit-compiled train_step with GSPMD sharding + the SP
attention strategy threaded through the model.

``make_train_step`` builds the jitted update function with explicit
in/out shardings derived from the logical-axis rules; ``Trainer`` drives
steps, metrics, and checkpointing for the example runs.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..configs.shapes import InputShape
from ..core import SPConfig
from ..models import ParallelContext, get_model, param_shardings
from . import checkpoint as ckpt_lib
from .data import SyntheticStream
from .optimizer import AdamWConfig, AdamWState, adamw_update, init_adamw


def batch_shardings(batch_spec, mesh: Mesh, sp: SPConfig):
    """Shard token/seq dims of the input batch: batch -> data axes,
    sequence -> SP axes."""
    ba, sa = sp.batch_axes, sp.sp_axes

    import math
    ba_k = math.prod(mesh.shape[a] for a in (ba or ()))
    sa_k = math.prod(mesh.shape[a] for a in (sa or ()))

    def spec(s):
        # shard a dim only if the axis product divides it (decode's [B, 1]
        # tokens, DiT's short cond sequence, etc. stay replicated)
        b_ = lambda i: ba if ba and s.shape[i] % ba_k == 0 and s.shape[i] > 1 else None
        s_ = lambda i: sa if sa and s.shape[i] % sa_k == 0 and s.shape[i] > 1 else None
        if len(s.shape) == 1:
            return NamedSharding(mesh, P(None))
        if len(s.shape) == 2:  # [B, L]
            return NamedSharding(mesh, P(b_(0), s_(1)))
        if len(s.shape) == 3 and s.shape[0] == 3:  # mrope positions [3, B, L]
            return NamedSharding(mesh, P(None, b_(1), s_(2)))
        if len(s.shape) == 3:  # [B, L, d]
            return NamedSharding(mesh, P(b_(0), s_(1), None))
        return NamedSharding(mesh, P(b_(0)))

    return jax.tree.map(spec, batch_spec)


def make_train_step(cfg: ModelConfig, mesh: Mesh, sp: SPConfig,
                    opt_cfg: AdamWConfig, remat: str = "full"):
    bundle = get_model(cfg)
    ctx = ParallelContext(mesh, sp, "train", remat=remat)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, aux = bundle.loss(p, batch, cfg, ctx)
            return loss, aux

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, metrics = adamw_update(opt_cfg, grads, opt_state, params)
        metrics.update({"loss": loss, "aux_loss": aux})
        return params, opt_state, metrics

    return train_step


@dataclasses.dataclass
class Trainer:
    cfg: ModelConfig
    mesh: Mesh
    sp: SPConfig
    shape: InputShape
    opt_cfg: AdamWConfig = AdamWConfig()
    seed: int = 0
    ckpt_path: str | None = None

    def setup(self):
        bundle = get_model(self.cfg)
        key = jax.random.PRNGKey(self.seed)
        ep = self.mesh.shape.get("model", 1)
        with jax.default_device(jax.devices("cpu")[0]):
            params, axes = bundle.init(self.cfg, key, ep)
        self.param_sh = param_shardings(axes, self.cfg, self.mesh, "train")
        params = jax.device_put(params, self.param_sh)
        opt_state = init_adamw(params)
        self.stream = SyntheticStream(self.cfg, self.shape, self.seed)
        step_fn = make_train_step(self.cfg, self.mesh, self.sp, self.opt_cfg)
        self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        return params, opt_state

    def run(self, steps: int, log_every: int = 10):
        params, opt_state = self.setup()
        history = []
        t0 = time.time()
        for step in range(steps):
            batch = self.stream.batch(step)
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            if step % log_every == 0 or step == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["wall"] = time.time() - t0
                history.append(m)
                print(f"step {step:5d} loss {m['loss']:.4f} "
                      f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e}")
        if self.ckpt_path:
            ckpt_lib.save(self.ckpt_path, {"params": params, "step": steps})
        return params, history
