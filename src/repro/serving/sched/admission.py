"""SLA-aware cross-bucket admission (DESIGN.md §9).

Each scheduling round scores candidate (bucket, batch-size) pairs and
admits the best one — across buckets, not head-of-line.  The score is in
seconds, combining:

  * **deadline slack** — min over the candidate's requests of
    ``deadline - now - predicted_batch_latency``, with the batch latency
    taken from the comm model via the plan cache.  Tight slack ⇒ urgent.
  * **padding cost** — the device time the dp-divisibility pad would
    waste, ``pad_rows / batch_rows * batch_latency``.
  * **aging credit** — ``oldest_age * aging_rate`` subtracted from the
    score, so waiting buckets monotonically gain urgency.

Two hard rules sit above the scoring:

  * **starvation bound** — a bucket whose oldest request has waited
    ``starvation_age`` or longer MUST be served next (most overdue first);
    scoring only breaks ties among non-overdue buckets.
  * **deferral** — a candidate that needs padding rows may wait for more
    arrivals while its slack exceeds ``defer_slack`` (unless ``flush`` is
    set, i.e. no more arrivals are coming); this is what converts greedy
    fragment batches into dp-aligned ones.  With an ``ArrivalForecaster``
    attached (DESIGN.md §10) the wait is no longer open-ended: the
    candidate defers only while the forecast time for the missing rows to
    arrive — EWMA interarrival gap plus a variance safety margin — fits
    inside its slack.  A bucket whose arrivals have dried up is served
    padded immediately instead of stalling until ``flush``.
"""
from __future__ import annotations

import dataclasses

from ..metrics import Tracker
from .bucketer import Bucket, aged_priority, padded_rows
from .forecast import ArrivalForecaster
from .plan_cache import PlanCache, PlanChoice


@dataclasses.dataclass(frozen=True)
class SchedConfig:
    max_batch: int = 4
    dp: int = 1  # data-parallel degree the batch must divide into
    starvation_age: float = 30.0  # s: hard admission bound
    aging_rate: float = 1.0  # s of score credit per s of queue age
    default_slack: float = 60.0  # assumed slack for requests without SLA
    defer_slack: float = 1.0  # padded candidates wait while slack > this
    # std-dev multiplier on the forecast fill time: higher inflates the
    # predicted wait under jittery arrivals, so padded candidates give up
    # deferring (and serve padded) sooner; 0 trusts the mean gap alone
    forecast_safety: float = 1.0


@dataclasses.dataclass(frozen=True)
class Candidate:
    bucket: Bucket
    k: int  # real requests admitted
    batch_rows: int  # k + dp padding rows
    pad_rows: int
    plan: PlanChoice
    min_slack: float
    age: float
    score: float


class AdmissionPolicy:
    def __init__(self, cfg: SchedConfig, plan_cache: PlanCache,
                 forecaster: ArrivalForecaster | None = None,
                 tracker: Tracker | None = None):
        self.cfg = cfg
        self.plans = plan_cache
        self.forecaster = forecaster
        self.tracker = tracker if tracker is not None else plan_cache.tracker

    def _worth_deferring(self, c: Candidate, now: float) -> bool:
        """Whether a padded candidate should wait for more arrivals.

        Without a forecaster: the PR-3 rule (wait while slack allows).
        With one: wait only while the predicted time for the missing rows
        to arrive also fits inside the slack — the explicit deferral
        horizon (DESIGN.md §10)."""
        if c.min_slack <= self.cfg.defer_slack:
            return False  # too urgent to wait, forecast or not
        if self.forecaster is None:
            return True
        fill = self.forecaster.expected_fill_time(
            c.bucket.seq_len, c.pad_rows, now,
            safety=self.cfg.forecast_safety)
        if fill is None:
            return True  # no rate estimate yet: keep the PR-3 behavior
        return fill <= c.min_slack - self.cfg.defer_slack

    def _candidate(self, b: Bucket, k: int, now: float) -> Candidate:
        c = self.cfg
        pad = padded_rows(k, c.dp)
        rows = k + pad
        plan = self.plans.select(rows, b.seq_len)
        slack = b.min_slack(now, plan.t_batch, k, c.default_slack)
        age = b.oldest_age(now)
        pad_cost = pad / rows * plan.t_batch
        score = slack + pad_cost - aged_priority(0.0, age, c.aging_rate)
        return Candidate(b, k, rows, pad, plan, slack, age, score)

    def candidates(self, buckets: list[Bucket], now: float) -> list[Candidate]:
        c = self.cfg
        out = []
        for b in buckets:
            ks = {min(len(b), c.max_batch)}
            aligned = (min(len(b), c.max_batch) // c.dp) * c.dp
            if aligned > 0:
                ks.add(aligned)  # pad-free alternative when enough queued
            for k in sorted(ks):
                out.append(self._candidate(b, k, now))
        return out

    def pick(self, buckets: list[Bucket], now: float,
             flush: bool = False) -> Candidate | None:
        cands = self.candidates(buckets, now)
        if not cands:
            return None
        c = self.cfg
        overdue = [x for x in cands if x.age >= c.starvation_age]
        if overdue:
            # starvation bound: most overdue first; bigger batch breaks ties
            best = max(overdue, key=lambda x: (x.age, x.k))
            self.tracker.count("sched.overdue_admissions",
                               tags={"seq": best.bucket.seq_len})
            return best
        if not flush:
            eligible = [x for x in cands
                        if x.pad_rows == 0
                        or not self._worth_deferring(x, now)]
            if not eligible:
                # every padded option is worth waiting on
                self.tracker.count("sched.deferrals")
                return None
            cands = eligible
        # lowest score = most urgent; ties to the older, then longer bucket
        return min(cands, key=lambda x: (x.score, -x.age, -x.bucket.seq_len))
