"""Hybrid-parallel sweep (beyond-paper; DESIGN.md §7): predicted per-step
serving latency of swift_torus SP alone vs + cfg parallelism vs + patch
pipelining, at EQUAL device count, from the analytical model.

Guided sampling (CFG) is on for every row — that is the serving scenario
the hybrid axes exist for.  All plans spend the same total FLOPs per step;
the hybrid plans win by (a) halving the sequential-guidance factor with
one velocity-sized recombine and (b) replacing per-layer inter-machine SP
collectives with one activation hand-off per stage boundary per step.

The win is regime-dependent and the sweep shows both sides honestly: at
the paper's longest sequences attention compute dominates and Torus hides
the inter-machine traffic anyway (hybrid ≈ SP-only, minus the pipeline
bubble); at medium resolutions — the latency-critical serving bucket —
per-layer comm exposure dominates SP-only and the hybrid plan, whose SP
sub-mesh never leaves the machine, wins by multiples.

Rows: ``hybrid_sweep/<wl>/N<n>/<plan>`` with us = predicted step latency
and derived = speedup over the SP-only plan (see EXPERIMENTS.md).

``python -m benchmarks.hybrid_sweep --calibration fit.json`` prints the
same rows under a calibrated ``NetworkModel`` (the JSON written by
``scripts/calibrate_comm.py`` from recorded BENCH_*.json measurements)
instead of the nominal testbed constants.
"""
from __future__ import annotations

import argparse

from repro.core import plan, plan_hybrid
from repro.core.comm_model import (
    LayerWorkload,
    NetworkModel,
    hybrid_step_latency,
    load_network_model,
    sp_step_latency,
)

from .common import row

# (workload, DiT depth): the paper's two geometries at several latent
# resolutions — seq scales ~ pixels, so 1024px ≈ 4k tokens for Flux.
WORKLOADS = {
    "flux_1024": (LayerWorkload(batch=1, seq=4_096, heads=24, head_dim=128), 96),
    "flux_2048": (LayerWorkload(batch=1, seq=16_384, heads=24, head_dim=128), 96),
    "flux_3072": (LayerWorkload(batch=1, seq=36_864, heads=24, head_dim=128), 96),
    "cogvideox_5s": (LayerWorkload(batch=1, seq=12_288, heads=24, head_dim=64), 42),
    "cogvideox_20s": (LayerWorkload(batch=1, seq=49_152, heads=24, head_dim=64), 42),
}
M_PER_MACHINE = 8  # paper testbed: 8 GPUs per machine


def _sweep(net: NetworkModel | None = None):
    """Yield (name, workload-name, n, plan-dict, prediction-dict) points."""
    net = net or NetworkModel()
    for wname, (wl, n_layers) in WORKLOADS.items():
        for n in (2, 4):
            sp_only = plan(n, M_PER_MACHINE, wl.heads)
            base = sp_step_latency(sp_only, wl, net, n_layers=n_layers,
                                   guided=True)
            yield (wname, n, wl, n_layers, "sp_only",
                   {"cfg": 1, "pp": 1, "p_ulysses": sp_only.p_ulysses,
                    "p_ring": sp_only.p_ring}, base, base)
            plans = {
                "cfg": dict(cfg_parallel=True, pp=1),
                "cfg_pp2": dict(cfg_parallel=True, pp=2),
            }
            for pname, kw in plans.items():
                h = plan_hybrid(n, M_PER_MACHINE, wl.heads,
                                n_layers=n_layers, **kw)
                pred = hybrid_step_latency(h, wl, net, n_layers=n_layers,
                                           guided=True)
                yield (wname, n, wl, n_layers, pname,
                       {"cfg": h.cfg, "pp": h.pp, "p_ulysses": h.sp.p_ulysses,
                        "p_ring": h.sp.p_ring}, pred, base)


def run(net: NetworkModel | None = None) -> list[str]:
    rows = []
    for wname, n, wl, n_layers, pname, pl, pred, base in _sweep(net):
        if pname == "sp_only":
            rows.append(row(f"hybrid_sweep/{wname}/N{n}/sp_only",
                            pred["t_step"] * 1e6,
                            f"Pu={pl['p_ulysses']},Pr={pl['p_ring']}"))
        else:
            rows.append(row(
                f"hybrid_sweep/{wname}/N{n}/{pname}", pred["t_step"] * 1e6,
                f"cfg={pl['cfg']},pp={pl['pp']},Pu={pl['p_ulysses']},"
                f"Pr={pl['p_ring']},speedup={base['t_step'] / pred['t_step']:.2f}x"))
    return rows


def records(net: NetworkModel | None = None) -> list[dict]:
    """Structured trajectory records for BENCH_hybrid_sweep.json: one entry
    per swept configuration, pairing the config with the comm-model
    prediction breakdown.  ``measured_step_us`` is null on this CPU
    container — the field exists so multi-machine runs can fill it in and
    ``scripts/calibrate_comm.py`` has a fit target."""
    out = []
    for wname, n, wl, n_layers, pname, pl, pred, _ in _sweep(net):
        out.append({
            "name": f"hybrid_sweep/{wname}/N{n}/{pname}",
            "workload": {"batch": wl.batch, "seq": wl.seq, "heads": wl.heads,
                         "head_dim": wl.head_dim, "n_layers": n_layers},
            "n_machines": n,
            "m_per_machine": M_PER_MACHINE,
            "plan": pl,
            "predicted_step_us": pred["t_step"] * 1e6,
            "predicted_breakdown": {k: v for k, v in pred.items()
                                    if k != "t_step"},
            # first-class column (DESIGN.md §12): fraction of hideable
            # comm the intended schedule actually hides for this plan
            "overlap_efficiency": pred.get("overlap_efficiency"),
            "measured_step_us": None,
        })
    return out


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--calibration", default=None, metavar="JSON",
                    help="NetworkModel JSON from scripts/calibrate_comm.py; "
                         "prints calibrated instead of nominal predictions")
    args = ap.parse_args(argv)
    net = load_network_model(args.calibration) if args.calibration else None
    print("name,us_per_call,derived")
    for line in run(net):
        print(line)


if __name__ == "__main__":
    main()
