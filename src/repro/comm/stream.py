"""Staged transfer programs over one-sided channels (DESIGN.md §8).

A ``Stream`` is the comm-side analogue of a CUDA/NVSHMEM stream: an
ordered sequence of channel stages making up one logical transfer program.
Each stage opens a channel (a fixed route), puts its tensors, and the
stage index is recorded so trace validation can reason about the program
shape.  The staged programs the SP schedules need are provided here:

  ring_shift          — one intra-ring rotation (Ring Attention's KV hop)
  torus_hop           — distance-k hop inside the Ulysses group (§4.3
                        stage k of the decomposed all-to-all)
  staged_all_to_all   — the full P_u-stage decomposition with the
                        stationary diagonal chunk (grouped_all_to_all)
  staged_ungroup      — its inverse (the Push-O / fourth all-to-all)
  intra_hop/inter_hop — the two legs of the hierarchical a2a: distance-j
                        rotation inside a machine sub-group / distance-k
                        rotation across machine sub-groups (§8.2)
  hier_all_to_all     — the two-level (intra-machine a2a, then staged
                        inter-machine hops) decomposition of the Ulysses
                        all-to-all; bit-identical output to the flat
                        path, optionally fp8 on the inter-machine wire
  hier_ungroup        — its inverse (the hierarchical Push-O)
  pipe_handoff        — the pipe-axis stage boundary transfer of the
                        displaced patch pipeline (models/dit.py)

Everything here is layout-agnostic: ``layout`` ducks as any object with
``axes``, ``p_ulysses``, ``my_coords()``, ``ring_perm(k)`` and
``ulysses_stage_perm(k)`` (core/collectives.GroupLayout in practice; the
duck-typing keeps this package import-free of core so core can build on
it without cycles).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from . import compress as _compress
from .channel import Channel, InFlight, shift_perm

__all__ = ["Stream", "ring_shift", "torus_hop", "intra_hop", "inter_hop",
           "staged_all_to_all", "staged_ungroup", "hier_all_to_all",
           "hier_ungroup", "pipe_handoff"]


@dataclasses.dataclass
class Stream:
    """An ordered program of channel transfers.

    ``channel`` mints a Channel bound to this stream at the current stage;
    ``next_stage`` advances the program counter.  Streams are trace-time
    bookkeeping only — they add no ops of their own.  ``backend`` selects
    the channel lowering for every stage of the program ("xla" | "pallas",
    see channel.py); ``interpret`` runs Pallas channels in interpreter
    mode (the CPU CI path).
    """

    name: str
    stage: int = 0
    backend: str = "xla"
    interpret: bool = True

    def channel(self, axes, perm, label: str = "") -> Channel:
        return Channel(axes=tuple(axes), perm=tuple(perm),
                       name=f"{self.name}.{label}" if label else self.name,
                       stream=self.name, stage=self.stage,
                       backend=self.backend, interpret=self.interpret)

    def next_stage(self) -> int:
        self.stage += 1
        return self.stage

    # -- staged programs as stream methods (each advances the stage) ------
    def put(self, axes, perm, *tensors, label: str = "",
            overlaps: str = "") -> InFlight:
        fut = self.channel(axes, perm, label).put(*tensors, overlaps=overlaps)
        self.next_stage()
        return fut


def ring_shift(layout: Any, *tensors: jax.Array, shift: int = 1,
               stream: Stream | None = None,
               overlaps: str = "", backend: str = "xla",
               interpret: bool = True) -> InFlight:
    """One rotation inside each Ring group (same u): the KV hop of Ring
    Attention.  Returns the in-flight handle — the caller owns the wait."""
    stream = stream or Stream("ring", backend=backend, interpret=interpret)
    return stream.put(layout.axes, layout.ring_perm(shift), *tensors,
                      label=f"shift{shift}", overlaps=overlaps)


def torus_hop(layout: Any, k: int, *tensors: jax.Array,
              stream: Stream | None = None,
              overlaps: str = "", backend: str = "xla",
              interpret: bool = True) -> InFlight:
    """Distance-k hop inside each Ulysses group (same r): stage k of the
    §4.3 decomposed all-to-all."""
    stream = stream or Stream("torus", backend=backend, interpret=interpret)
    return stream.put(layout.axes, layout.ulysses_stage_perm(k), *tensors,
                      label=f"hop{k}", overlaps=overlaps)


def intra_hop(layout: Any, j: int, *tensors: jax.Array,
              stream: Stream | None = None,
              overlaps: str = "", backend: str = "xla",
              interpret: bool = True) -> InFlight:
    """Distance-j hop inside the machine-local Ulysses sub-group (same
    u_hi, same r): stage j of the hierarchical a2a's fast leg (§8.2).
    Never crosses the slow boundary."""
    stream = stream or Stream("hier", backend=backend, interpret=interpret)
    return stream.put(layout.axes, layout.ulysses_intra_stage_perm(j),
                      *tensors, label=f"intra{j}", overlaps=overlaps)


def inter_hop(layout: Any, k: int, *tensors: jax.Array,
              stream: Stream | None = None,
              overlaps: str = "", backend: str = "xla",
              interpret: bool = True) -> InFlight:
    """Distance-k hop across machine sub-groups (same u_lo, same r):
    stage k of the hierarchical a2a's slow leg — the only leg of the
    two-level program that touches the inter-machine wire."""
    stream = stream or Stream("hier", backend=backend, interpret=interpret)
    return stream.put(layout.axes, layout.ulysses_inter_stage_perm(k),
                      *tensors, label=f"inter{k}", overlaps=overlaps)


def _dyn_set(buf: jax.Array, idx, val: jax.Array) -> jax.Array:
    return lax.dynamic_update_slice_in_dim(buf, val[None], idx, axis=0)


def staged_all_to_all(
    x: jax.Array,
    layout: Any,
    *,
    split_axis: int,
    stream: Stream | None = None,
    backend: str = "xla",
    interpret: bool = True,
) -> jax.Array:
    """All-to-all restricted to Ulysses groups, as P_u - 1 channel stages.

    Splits ``x`` into P_u chunks along ``split_axis``; chunk j is put to
    ulysses-peer j.  The diagonal chunk (j == my u) is stationary (§4.3)
    and never touches the wire.  Returns chunks stacked on a new leading
    axis in *source*-u order: ``out[j]`` = the chunk peer j produced for
    me.  Every stage's put is independent of every other stage's — the
    whole program can be in flight at once, which is what lets Torus
    interleave these stages with attention compute.
    """
    stream = stream or Stream("a2a", backend=backend, interpret=interpret)
    p_u = layout.p_ulysses
    chunks = jnp.stack(jnp.split(x, p_u, axis=split_axis), axis=0)
    if p_u == 1:
        return chunks
    u, _ = layout.my_coords()
    out = jnp.zeros_like(chunks)
    out = _dyn_set(out, u, jnp.take(chunks, u, axis=0))
    for k in range(1, p_u):
        # I put my chunk destined for peer (u + k); peer (u - k) puts mine.
        send = jnp.take(chunks, (u + k) % p_u, axis=0)
        recv = torus_hop(layout, k, send, stream=stream).wait()
        out = _dyn_set(out, (u - k) % p_u, recv)
    return out


def staged_ungroup(
    stacked: jax.Array,
    layout: Any,
    *,
    concat_axis: int,
    stream: Stream | None = None,
    backend: str = "xla",
    interpret: bool = True,
) -> jax.Array:
    """Inverse program: put ``stacked[j]`` back to ulysses-peer j and
    concatenate the received chunks along ``concat_axis`` (the fourth
    all-to-all of Ulysses attention / Torus Push-O; diagonal stays put)."""
    stream = stream or Stream("a2a.inv", backend=backend, interpret=interpret)
    p_u = layout.p_ulysses
    if p_u == 1:
        return jnp.squeeze(stacked, axis=0)
    u, _ = layout.my_coords()
    out = jnp.zeros_like(stacked)
    out = _dyn_set(out, u, jnp.take(stacked, u, axis=0))
    for k in range(1, p_u):
        send = jnp.take(stacked, (u + k) % p_u, axis=0)
        recv = torus_hop(layout, k, send, stream=stream,
                         overlaps="next-layer compute").wait()
        out = _dyn_set(out, (u - k) % p_u, recv)
    return jnp.concatenate(list(out), axis=concat_axis)


def _hier_exchange(
    chunks: jax.Array,
    layout: Any,
    *,
    stream: Stream,
    wire_dtype: str | None = None,
    err: tuple | None = None,
    overlaps_inter: str = "peer inter hops + update fusions",
) -> jax.Array | tuple[jax.Array, tuple]:
    """Two-level routing core shared by hier_all_to_all / hier_ungroup.

    ``chunks`` is [P_u, ...] in destination-u order (chunk j is what I owe
    peer u = j); returns [P_u, ...] in source-u order (out[j] = what peer
    u = j produced for me) — the exact contract of the flat staged path.

    Factor u = u_hi * m_u + u_lo over (machine sub-group, local slot),
    g = layout.u_groups, m_u = P_u / g.  Two legs:

      fast leg (m_u - 1 intra stages): within each machine, local slot b
        sends the whole [g]-bundle of chunks destined for local slot
        (b + j) — after it, W[b'] holds the g chunks source (a, b')
        produced for the b-slots of every machine sub-group.
      slow leg (g - 1 inter stages): across machines, sub-group a sends
        the [m_u]-bundle W[:, (a + k) % g] — m_u chunks aggregated into
        one message, so the inter-machine wire sees g - 1 latency-paced
        stages instead of the flat path's P_u - 1.

    Both diagonals are stationary (the §4.3 observation, applied per
    level).  The program is pure routing — no arithmetic touches the
    payload — so the output is bit-identical to the flat path.  With
    ``wire_dtype`` the slow leg quantises each bundle (compress.py)
    before the put and dequantises on arrival; ``err`` (a tuple of g - 1
    fp32 buffers) enables error feedback, in which case the new residuals
    are returned alongside the output.
    """
    g = layout.u_groups
    p_u = layout.p_ulysses
    m_u = p_u // g
    rest = chunks.shape[1:]
    u, _ = layout.my_coords()
    a, b = u // m_u, u % m_u
    shaped = chunks.reshape((g, m_u) + rest)

    # fast leg: intra-machine exchange of dest-local-slot bundles
    w = jnp.zeros((m_u, g) + rest, chunks.dtype)
    w = _dyn_set(w, b, jnp.take(shaped, b, axis=1))
    for j in range(1, m_u):
        send = jnp.take(shaped, (b + j) % m_u, axis=1)
        recv = intra_hop(layout, j, send, stream=stream).wait()
        w = _dyn_set(w, (b - j) % m_u, recv)

    # slow leg: inter-machine exchange of per-sub-group bundles; every
    # stage is independent of every other, so the whole leg can be in
    # flight at once — the overlap declaration trace.validate checks
    out = jnp.zeros((g, m_u) + rest, chunks.dtype)
    out = _dyn_set(out, a, jnp.take(w, a, axis=1))
    new_err = []
    for k in range(1, g):
        send = jnp.take(w, (a + k) % g, axis=1)
        if wire_dtype is not None:
            if err is not None:
                wire, scale, e = _compress.ef_encode(
                    send, err[k - 1], wire_dtype)
                new_err.append(e)
            else:
                wire, scale = _compress.quantize(send, wire_dtype)
            rw, rs = inter_hop(layout, k, wire, scale, stream=stream,
                               overlaps=overlaps_inter).wait()
            recv = _compress.dequantize(rw, rs, chunks.dtype)
        else:
            recv = inter_hop(layout, k, send, stream=stream,
                             overlaps=overlaps_inter).wait()
        out = _dyn_set(out, (a - k) % g, recv)
    result = out.reshape((p_u,) + rest)
    if err is not None:
        return result, tuple(new_err)
    return result


def hier_all_to_all(
    x: jax.Array,
    layout: Any,
    *,
    split_axis: int,
    stream: Stream | None = None,
    backend: str = "xla",
    interpret: bool = True,
    wire_dtype: str | None = None,
    err: tuple | None = None,
) -> jax.Array | tuple[jax.Array, tuple]:
    """Hierarchical two-level grouped all-to-all (§8.2): same contract as
    :func:`staged_all_to_all` — split into P_u chunks along ``split_axis``,
    deliver chunk j to ulysses-peer j, return received chunks stacked on a
    new leading axis in source-u order — but routed as an intra-machine
    a2a followed by g - 1 aggregated inter-machine hops."""
    stream = stream or Stream("hier.a2a", backend=backend,
                              interpret=interpret)
    p_u = layout.p_ulysses
    chunks = jnp.stack(jnp.split(x, p_u, axis=split_axis), axis=0)
    if p_u == 1:
        return chunks if err is None else (chunks, ())
    return _hier_exchange(chunks, layout, stream=stream,
                          wire_dtype=wire_dtype, err=err)


def hier_ungroup(
    stacked: jax.Array,
    layout: Any,
    *,
    concat_axis: int,
    stream: Stream | None = None,
    backend: str = "xla",
    interpret: bool = True,
    wire_dtype: str | None = None,
    err: tuple | None = None,
) -> jax.Array | tuple[jax.Array, tuple]:
    """Hierarchical inverse (§8.2): same contract as
    :func:`staged_ungroup` — ``stacked[j]`` goes back to ulysses-peer j,
    received chunks concatenate along ``concat_axis``.  The exchange core
    is self-inverse (it is a transpose of the u coordinate), so this is
    the same two-leg program with a concat epilogue."""
    stream = stream or Stream("hier.a2a.inv", backend=backend,
                              interpret=interpret)
    p_u = layout.p_ulysses
    if p_u == 1:
        out = jnp.squeeze(stacked, axis=0)
        return out if err is None else (out, ())
    res = _hier_exchange(stacked, layout, stream=stream,
                         wire_dtype=wire_dtype, err=err,
                         overlaps_inter="next-layer compute")
    if err is not None:
        moved, new_err = res
        return jnp.concatenate(list(moved), axis=concat_axis), new_err
    return jnp.concatenate(list(res), axis=concat_axis)


def pipe_handoff(
    x: jax.Array,
    mesh: jax.sharding.Mesh,
    axis: str,
    *,
    shift: int = 1,
    batch_axes: tuple[str, ...] | None = None,
    stream: Stream | None = None,
    backend: str = "xla",
    interpret: bool = True,
) -> jax.Array:
    """Stage-boundary hand-off of the displaced patch pipeline: rotate the
    activation one stage forward along the pipe ``axis``.

    This is the transfer that replaces the GSPMD-implicit stage hand-off
    (ROADMAP item): an explicit collective-permute over the pipe axis
    carrying exactly the bytes the real pipeline moves per boundary, so
    (a) the HLO names the transfer and trace.py can validate that patch
    (p+1)'s hand-off overlaps patch p's stage compute, and (b) the
    emulation pays the wire cost it claims.  In the single-program
    emulation the activation is replicated over the pipe axis, so the
    rotation is value-preserving — the multi-device schedule it stands in
    for is documented in DESIGN.md §8.

    Must be called OUTSIDE any shard_map (it opens its own over ``axis``).
    """
    stream = stream or Stream("pipe", backend=backend, interpret=interpret)
    pp = mesh.shape[axis]
    if pp == 1:
        return x
    ch = stream.channel((axis,), shift_perm(pp, shift), f"handoff{stream.stage}")
    stream.next_stage()
    spec = P(batch_axes) if batch_axes else P()

    def body(xs):
        return ch.put(xs, overlaps="stage compute").wait()

    return shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=spec,
                     check_vma=False)(x)
