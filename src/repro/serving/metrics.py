"""Serving observability: a unified metrics tracker (DESIGN.md §11).

The control loop built across PRs 3–5 generates rich internal signals —
plan-cache hit/miss/invalidation counters, per-step wall clocks,
preemption and resync tallies, calibration drift ratios — but until this
subsystem each lived in its own ad-hoc attribute, observable only by
reaching into objects.  This module turns them into one time-series
surface in the spirit of levanter's ``tracker.py``: components publish
named metrics to a ``Tracker`` sink; what happens to the stream is the
sink's business (dropped, held in memory, streamed to disk).  The fleet
router on the ROADMAP consumes exactly this surface cross-replica.

Sink taxonomy:

  * ``Tracker``       — the default threaded through every engine when no
    sink is given: aggregates counters and per-series gauge statistics
    (so the legacy attributes like ``PlanCache.hits`` keep working as
    thin reads, and ``summary()`` can print an end-of-run table) but
    retains **no per-record stream** — a long-running server never
    accumulates unbounded history by default.
  * ``NullTracker``   — a TRUE no-op: no counters, no stats, no records.
    Legacy counter reads through it are always 0; use it only when the
    attribute surface is not consumed.
  * ``RecordingTracker`` — ``Tracker`` plus the full in-memory record
    stream (``records``).  The test sink.
  * ``JsonlTracker``  — ``Tracker`` plus one schema-versioned JSON line
    per record streamed to disk (``launch/serve.py --metrics out.jsonl``,
    ``benchmarks/run.py --metrics``).  ``read_jsonl`` round-trips the
    file back into ``Record`` objects bit-exactly.

Every record carries ``schema`` (``SCHEMA_VERSION``) so mixed streams —
bench trajectories and serving telemetry share this schema — stay
self-describing; ``validate_record`` is the single checker CI's
``scripts/check_metrics_schema.py`` gate and the tests both call.

Metric kinds:

  * ``count(name, value)`` — monotone counter; the emitted record's
    ``value`` is the NEW cumulative total (so a JSONL stream replays to
    the same final counts without summing) and ``Tracker.counter(name)``
    reads the current total.
  * ``log(name, value)``   — gauge / time-series sample (per-step wall
    clocks, drift trajectories, event markers).  ``step`` orders samples
    within a series; ``tags`` split series (bucket shape, admission id).
  * ``span(name)`` / ``span_event(name, t_start, dur)`` — timed interval
    (DESIGN.md §12): ``value`` is the duration in seconds, ``t_start``
    the offset from the tracker's ``epoch``.  ``span`` is a context
    manager that times a host region (nesting recorded via a ``parent``
    tag); ``span_event`` publishes an interval measured elsewhere (the
    comm profiler's drained device-side legs).  ``scripts/trace_report.py``
    turns a span stream into a Perfetto timeline plus overlap/residual
    reports.

Everything is host-side pure Python — no jax — so the discrete-event
simulation in ``benchmarks/sched_sweep.py`` publishes through the exact
sink type the real engine uses.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import pathlib
import time
from typing import IO, Any, Iterable, Iterator, Mapping

SCHEMA_VERSION = "metrics.v1"

# record kinds a conforming stream may contain.  "span" is the PR 7
# extension (DESIGN.md §12): a timed interval — ``value`` is the duration
# in seconds and ``t_start`` its offset from the tracker's epoch — and is
# backward compatible: span-free streams are unchanged, and readers that
# predate spans see a gauge-shaped record with one extra field.
KINDS = ("counter", "gauge", "span")

# a tag value must survive a JSON round-trip unchanged
TagValue = str | int | float | bool

_REQUIRED_FIELDS = ("schema", "seq", "name", "kind", "value")


@dataclasses.dataclass(frozen=True)
class Record:
    """One metric sample.  ``seq`` is the tracker-assigned monotone
    record index (total order of the stream, even across interleaved
    series); ``step`` is the caller's position within ITS series (sampler
    step, refit ordinal) and may repeat across series."""

    name: str
    value: float
    kind: str = "gauge"
    step: int | None = None
    tags: dict[str, TagValue] = dataclasses.field(default_factory=dict)
    seq: int = 0
    schema: str = SCHEMA_VERSION
    # spans only: start offset (seconds) from the tracker's epoch; the
    # duration is ``value``.  None for counters/gauges.
    t_start: float | None = None

    def to_dict(self) -> dict[str, Any]:
        d = {"schema": self.schema, "seq": self.seq, "name": self.name,
             "kind": self.kind, "value": self.value}
        if self.step is not None:
            d["step"] = self.step
        if self.tags:
            d["tags"] = dict(self.tags)
        if self.t_start is not None:
            d["t_start"] = self.t_start
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Record":
        return cls(name=d["name"], value=d["value"], kind=d["kind"],
                   step=d.get("step"), tags=dict(d.get("tags") or {}),
                   seq=d["seq"], schema=d["schema"],
                   t_start=d.get("t_start"))


def validate_record(d: Mapping[str, Any]) -> list[str]:
    """Schema check for one record dict; returns the list of violations
    (empty = conforming).  The single source of truth shared by the unit
    tests and ``scripts/check_metrics_schema.py``."""
    errs = []
    for f in _REQUIRED_FIELDS:
        if f not in d:
            errs.append(f"missing field {f!r}")
    if errs:
        return errs
    if d["schema"] != SCHEMA_VERSION:
        errs.append(f"schema {d['schema']!r} != {SCHEMA_VERSION!r}")
    if d["kind"] not in KINDS:
        errs.append(f"kind {d['kind']!r} not in {KINDS}")
    if not isinstance(d["name"], str) or not d["name"]:
        errs.append("name must be a non-empty string")
    if not isinstance(d["value"], (int, float)) or isinstance(d["value"], bool):
        errs.append(f"value {d['value']!r} is not a number")
    if not isinstance(d["seq"], int) or d["seq"] < 0:
        errs.append(f"seq {d['seq']!r} is not a non-negative int")
    step = d.get("step")
    if step is not None and not isinstance(step, int):
        errs.append(f"step {step!r} is not an int")
    tags = d.get("tags", {})
    if not isinstance(tags, Mapping):
        errs.append("tags is not a mapping")
    else:
        for k, v in tags.items():
            if not isinstance(k, str):
                errs.append(f"tag key {k!r} is not a string")
            if not isinstance(v, (str, int, float, bool)):
                errs.append(f"tag {k}={v!r} is not str/int/float/bool")
    t_start = d.get("t_start")
    if d["kind"] == "span":
        if t_start is None:
            errs.append("span record is missing t_start")
        elif (not isinstance(t_start, (int, float))
              or isinstance(t_start, bool) or t_start < 0):
            errs.append(f"t_start {t_start!r} is not a non-negative number")
        if isinstance(d["value"], (int, float)) and d["value"] < 0:
            errs.append(f"span duration {d['value']!r} is negative")
    elif t_start is not None:
        errs.append(f"t_start is only valid on span records, not {d['kind']}")
    unknown = set(d) - {*_REQUIRED_FIELDS, "step", "tags", "t_start"}
    if unknown:
        errs.append(f"unknown fields {sorted(unknown)}")
    return errs


def _tag_key(tags: Mapping[str, TagValue] | None) -> tuple:
    """Canonical hashable identity of a tag set (order-insensitive)."""
    if not tags:
        return ()
    return tuple(sorted(tags.items()))


@dataclasses.dataclass
class SeriesStats:
    """Constant-space aggregate of one gauge series (per (name, tags))."""

    n: int = 0
    total: float = 0.0
    vmin: float = float("inf")
    vmax: float = float("-inf")
    last: float = 0.0

    def add(self, v: float) -> None:
        self.n += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        self.last = v

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0


class Tracker:
    """Aggregating sink: counters + per-series gauge statistics, no
    record retention.  Subclasses persist the stream by overriding
    ``_emit`` (called once per record, AFTER aggregation)."""

    def __init__(self):
        self._counters: dict[tuple[str, tuple], float] = {}
        self._stats: dict[tuple[str, tuple], SeriesStats] = {}
        self._seq = 0
        # span timebase: every t_start in this tracker's stream is an
        # offset from this perf_counter reading, so spans from different
        # components (host code, drained comm-profiler events) share one
        # clock and the trace report never has to reconcile epochs.
        self.epoch = time.perf_counter()
        self._span_stack: list[str] = []

    # -- publishing -------------------------------------------------------
    def count(self, name: str, value: float = 1.0, *, step: int | None = None,
              tags: Mapping[str, TagValue] | None = None) -> float:
        """Increment a monotone counter; returns (and emits) the new
        cumulative total.  ``value`` must be non-negative — counters
        never decrease (test_metrics.py pins the monotonicity)."""
        assert value >= 0, f"counter increment must be >= 0, got {value}"
        key = (name, _tag_key(tags))
        total = self._counters.get(key, 0.0) + value
        self._counters[key] = total
        self._record(name, total, "counter", step, tags)
        return total

    def log(self, name: str, value: float, *, step: int | None = None,
            tags: Mapping[str, TagValue] | None = None) -> None:
        """Publish one gauge sample of the series (name, tags)."""
        key = (name, _tag_key(tags))
        st = self._stats.get(key)
        if st is None:
            st = self._stats[key] = SeriesStats()
        st.add(float(value))
        self._record(name, float(value), "gauge", step, tags)

    def now(self) -> float:
        """Seconds since this tracker's epoch — the span timebase."""
        return time.perf_counter() - self.epoch

    def span_event(self, name: str, t_start: float, dur: float, *,
                   step: int | None = None,
                   tags: Mapping[str, TagValue] | None = None) -> None:
        """Publish one already-measured span: ``t_start`` is seconds since
        ``self.epoch`` (use ``now()``), ``dur`` the duration in seconds.
        Durations aggregate into the same per-series stats as gauges, so
        ``summary()`` shows span timing tables for free."""
        key = (name, _tag_key(tags))
        st = self._stats.get(key)
        if st is None:
            st = self._stats[key] = SeriesStats()
        st.add(float(dur))
        self._record(name, float(dur), "span", step, tags,
                     t_start=float(t_start))

    @contextlib.contextmanager
    def span(self, name: str, *, step: int | None = None,
             tags: Mapping[str, TagValue] | None = None) -> Iterator[None]:
        """Time a host-side region as a span record.  Nested spans get a
        ``parent`` tag automatically (unless the caller sets one), which
        is how ``scripts/trace_report.py`` rebuilds the step→stage tree.
        The record is emitted even if the body raises, so a crashed
        step's partial timing still lands in the stream."""
        t0 = self.now()
        tags = dict(tags) if tags else {}
        if self._span_stack and "parent" not in tags:
            tags["parent"] = self._span_stack[-1]
        self._span_stack.append(name)
        try:
            yield
        finally:
            self._span_stack.pop()
            self.span_event(name, t0, self.now() - t0, step=step,
                            tags=tags or None)

    def _record(self, name: str, value: float, kind: str,
                step: int | None, tags: Mapping[str, TagValue] | None, *,
                t_start: float | None = None) -> None:
        rec = Record(name=name, value=value, kind=kind, step=step,
                     tags=dict(tags) if tags else {}, seq=self._seq,
                     t_start=t_start)
        self._seq += 1
        self._emit(rec)

    def _emit(self, rec: Record) -> None:  # aggregate-only: drop the record
        pass

    # -- reading ----------------------------------------------------------
    # Sinks that retain the full record stream set this True; the engine
    # reads it to decide whether per-step wall clocks are worth their
    # device sync even without the control loop engaged (DESIGN.md §11).
    persistent = False

    def counter(self, name: str,
                tags: Mapping[str, TagValue] | None = None) -> float:
        """Current cumulative value of a counter (0.0 if never bumped) —
        what the legacy attributes (``PlanCache.hits`` & co.) read."""
        return self._counters.get((name, _tag_key(tags)), 0.0)

    def counter_total(self, name: str) -> float:
        """Sum of a counter over ALL tag sets sharing ``name``."""
        return sum(v for (n, _), v in self._counters.items() if n == name)

    def counter_items(self, name: str) -> list[tuple[dict, float]]:
        """Every tag set of a counter with its total — how a fleet router
        enumerates a folded multi-replica view (e.g. which ``replica``
        tags have compiled which ``seq`` shapes) without knowing the tag
        sets in advance."""
        return [(dict(k), v) for (n, k), v in self._counters.items()
                if n == name]

    def series_items(self, name: str) -> list[tuple[dict, "SeriesStats"]]:
        """Every tag set of a gauge/span series with its aggregate stats
        (the gauge counterpart of ``counter_items``)."""
        return [(dict(k), st) for (n, k), st in self._stats.items()
                if n == name]

    def series(self, name: str,
               tags: Mapping[str, TagValue] | None = None) -> SeriesStats:
        """Aggregate stats of one gauge series (empty stats if unseen)."""
        return self._stats.get((name, _tag_key(tags)), SeriesStats())

    def summary(self) -> list[dict[str, Any]]:
        """End-of-run aggregate table: one row per counter and per gauge
        series, sorted by name then tags — what ``launch/serve.py``
        prints after a ``--metrics`` run."""
        rows: list[dict[str, Any]] = []
        for (name, tags), v in self._counters.items():
            rows.append({"name": name, "kind": "counter",
                         "tags": dict(tags), "value": v})
        for (name, tags), st in self._stats.items():
            rows.append({"name": name, "kind": "gauge", "tags": dict(tags),
                         "n": st.n, "mean": st.mean, "min": st.vmin,
                         "max": st.vmax, "last": st.last})
        rows.sort(key=lambda r: (r["name"], sorted(r["tags"].items())))
        return rows

    def format_summary(self) -> str:
        """The summary as an aligned text table."""
        lines = ["metric                                   kind     value"]
        for r in self.summary():
            tag_s = ("{" + ",".join(f"{k}={v}" for k, v in
                                    sorted(r["tags"].items())) + "}"
                     if r["tags"] else "")
            name = f"{r['name']}{tag_s}"
            if r["kind"] == "counter":
                val = f"{r['value']:g}"
            else:
                val = (f"n={r['n']} mean={r['mean']:.6g} "
                       f"min={r['min']:.6g} max={r['max']:.6g}")
            lines.append(f"{name:<40} {r['kind']:<8} {val}")
        return "\n".join(lines)

    def close(self) -> None:
        pass

    def __enter__(self) -> "Tracker":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class NullTracker(Tracker):
    """A true no-op sink: publishing does nothing at all (no counters,
    no stats, no seq advance), reads are always empty/zero."""

    def count(self, name: str, value: float = 1.0, *, step=None,
              tags=None) -> float:
        return 0.0

    def log(self, name: str, value: float, *, step=None, tags=None) -> None:
        pass

    def span_event(self, name, t_start, dur, *, step=None, tags=None) -> None:
        pass

    @contextlib.contextmanager
    def span(self, name, *, step=None, tags=None):
        yield


class RecordingTracker(Tracker):
    """In-memory sink for tests: full record stream + the aggregates."""

    def __init__(self):
        super().__init__()
        self.records: list[Record] = []

    persistent = True

    def _emit(self, rec: Record) -> None:
        self.records.append(rec)


class JsonlTracker(Tracker):
    """Streams every record to ``path`` as one JSON line (sorted keys, so
    byte output is deterministic given the record stream).

    Crash safety: by default every record is flushed to the OS as soon as
    it is written (``flush_every=1``), so a run killed mid-serve leaves a
    trace whose completed lines are all readable and schema-valid — at
    worst the final line is truncated (``read_jsonl(partial_tail="drop")``
    recovers everything before it).  Raise ``flush_every`` to amortize
    the flush for high-rate span streams; the tracker still flushes on
    ``close()``, and the context-manager protocol closes on exception."""

    def __init__(self, path: str | pathlib.Path, *, flush_every: int = 1):
        super().__init__()
        assert flush_every >= 1, f"flush_every must be >= 1, got {flush_every}"
        self.path = pathlib.Path(path)
        self.flush_every = flush_every
        self._since_flush = 0
        self._fh: IO[str] | None = self.path.open("w")

    persistent = True

    def _emit(self, rec: Record) -> None:
        assert self._fh is not None, "JsonlTracker is closed"
        self._fh.write(json.dumps(rec.to_dict(), sort_keys=True) + "\n")
        self._since_flush += 1
        if self._since_flush >= self.flush_every:
            self._fh.flush()
            self._since_flush = 0

    def flush(self) -> None:
        if self._fh is not None:
            self._fh.flush()
            self._since_flush = 0

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


def read_jsonl(path: str | pathlib.Path, validate: bool = True,
               partial_tail: str = "error") -> list[Record]:
    """Load a JSONL trace back into ``Record`` objects (the round-trip
    inverse of ``JsonlTracker``); ``validate`` schema-checks every line.
    ``partial_tail="drop"`` tolerates a truncated FINAL line (a crashed
    writer) — corruption anywhere else still raises."""
    assert partial_tail in ("error", "drop"), partial_tail
    records = []
    lines = pathlib.Path(path).read_text().splitlines()
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            d = json.loads(line)
        except json.JSONDecodeError:
            if partial_tail == "drop" and i == len(lines) - 1:
                break
            raise
        if validate:
            errs = validate_record(d)
            if errs:
                raise ValueError(f"{path}:{i + 1}: {'; '.join(errs)}")
        records.append(Record.from_dict(d))
    return records


class TraceFold:
    """Incremental fold of one shipped record stream into another tracker
    — the consumer side of the fleet tier's trace-shipping protocol
    (DESIGN.md §13; ``serving/fleet.py``).

    Counter records carry cumulative totals, so writing them into the
    destination verbatim would (a) bypass ``_emit`` — persistent sinks
    like ``JsonlTracker`` would silently drop every replayed counter —
    and (b) make a second stream folded into the same tracker CLOBBER
    the first (last record wins) instead of summing.  The fold instead
    differences consecutive totals per SOURCE series and re-publishes the
    increments through the tracker API (``count``/``log``/``span_event``),
    so:

      * every replayed record reaches ``_emit`` (persistent sinks see it),
      * multiple replicas' streams folded into one tracker SUM,
      * re-folding a growing trace from the start is idempotent on the
        already-folded prefix (records are deduplicated by ``seq``).

    ``tags`` namespaces every re-published record (the router passes
    ``{"replica": rid}``), so per-replica series stay distinguishable in
    the folded view while ``counter_total`` still sums across them."""

    def __init__(self, tags: Mapping[str, TagValue] | None = None):
        self.tags: dict[str, TagValue] = dict(tags) if tags else {}
        self._totals: dict[tuple[str, tuple], float] = {}
        self._cursor = -1  # highest source seq already folded

    def fold(self, records: Iterable[Record], into: Tracker) -> int:
        """Re-publish every not-yet-folded record into ``into``; returns
        the number of records folded."""
        n = 0
        for r in records:
            if r.seq <= self._cursor:
                continue  # already folded in an earlier ship
            self._cursor = r.seq
            tags = {**r.tags, **self.tags} or None
            if r.kind == "counter":
                key = (r.name, _tag_key(r.tags))
                prev = self._totals.get(key, 0.0)
                assert r.value >= prev, (
                    f"counter {r.name} decreased in source stream "
                    f"({prev} -> {r.value}); not a valid metrics.v1 trace")
                self._totals[key] = r.value
                into.count(r.name, r.value - prev, step=r.step, tags=tags)
            elif r.kind == "span":
                into.span_event(r.name, r.t_start, r.value, step=r.step,
                                tags=tags)
            else:
                into.log(r.name, r.value, step=r.step, tags=tags)
            n += 1
        return n


def replay(records: Iterable[Record], into: Tracker | None = None,
           tags: Mapping[str, TagValue] | None = None) -> Tracker:
    """Re-publish a record stream into a tracker — counters land on their
    recorded cumulative totals via per-series increments routed through
    the tracker API (so persistent sinks receive the replayed records and
    folding a SECOND stream into the same tracker sums instead of
    clobbering), gauges rebuild their series stats, spans keep their
    windows.  ``tags`` namespaces the folded records (a fleet router
    passes ``{"replica": rid}`` per shipped trace); use ``TraceFold``
    directly for incremental shipping of a growing trace."""
    t = into if into is not None else Tracker()
    TraceFold(tags=tags).fold(records, t)
    return t
