"""Planner (§4.2) invariants + paper examples."""
import math

from hypothesis import given, settings, strategies as st

from repro.core import plan, usp_plan


@given(
    st.sampled_from([1, 2, 4, 8]),
    st.sampled_from([1, 2, 4, 8, 16]),
    st.integers(1, 128),
)
@settings(max_examples=200, deadline=None)
def test_plan_invariants(n, m, heads):
    p = plan(n, m, heads)
    assert p.p_ulysses * p.p_ring == n * m
    assert heads % p.p_ulysses == 0  # Ulysses degree divides heads
    assert p.p_ulysses == math.gcd(n * m, heads)  # maximal (paper's choice)


@given(
    st.sampled_from([2, 4]), st.sampled_from([2, 4, 8]),
    st.integers(1, 64), st.integers(1, 8),
)
@settings(max_examples=200, deadline=None)
def test_gqa_constrains_ulysses(n, m, hq_mult, hkv):
    hq = hkv * hq_mult
    p = plan(n, m, hq, hkv)
    assert hkv % p.p_ulysses == 0  # never forces KV-head replication
    p2 = plan(n, m, hq, hkv, replicate_kv=True)
    assert p2.p_ulysses >= p.p_ulysses


def test_paper_simple_case():
    """H = N: Ulysses spans exactly the machines (paper §4.2)."""
    p = plan(4, 8, 4 * 8)
    assert p.p_ulysses == 32  # gcd(32, 32)
    p = plan(4, 8, 4)
    assert p.p_ulysses == 4 and p.p_ring == 8
    assert p.ulysses_inter


def test_usp_same_factorisation_different_boundary():
    a = plan(4, 8, 24)
    b = usp_plan(4, 8, 24)
    assert (a.p_ulysses, a.p_ring) == (b.p_ulysses, b.p_ring)
    assert a.ulysses_inter and not b.ulysses_inter


def test_assigned_arch_head_counts():
    """The planner handles every assigned arch's head geometry on the
    production SP group (N=2 pods × M=16)."""
    cases = {  # (Hq, Hkv)
        "qwen2-1.5b": (12, 2), "qwen2-vl-2b": (12, 2), "stablelm-3b": (32, 32),
        "whisper-tiny": (6, 6), "hymba-1.5b": (25, 5), "arctic-480b": (56, 8),
        "chatglm3-6b": (32, 2), "starcoder2-7b": (36, 4),
        "qwen2-moe-a2.7b": (16, 16),
    }
    for arch, (hq, hkv) in cases.items():
        p = plan(2, 16, hq, hkv)
        assert p.p_ulysses * p.p_ring == 32, arch
        assert math.gcd(hq, hkv) % p.p_ulysses == 0, arch
