"""Paper Fig. 12: the custom multi-Q/KV kernel vs a plain reference kernel.

On this CPU container the Pallas kernel executes in interpret mode, so
absolute times are not TPU times; the benchmark reports (a) measured
parity between the XLA reference attention and the chunked multi-segment
formulation (the paper's claim: the fused kernel adds negligible overhead
vs FlashAttention-2 while handling multiple segments), and (b) the
interpret-mode kernel as a correctness-exercised call.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import MaskSpec, reference_attention
from repro.core.softmax import attend_chunked, finalize
from repro.kernels import flash_attention

from .common import row, time_call


def run() -> list[str]:
    rows = []
    key = jax.random.PRNGKey(0)
    for (b, l, h, d) in ((1, 1024, 8, 64), (1, 2048, 8, 64)):
        kq, kk, kv = jax.random.split(key, 3)
        q = jax.random.normal(kq, (b, l, h, d))
        k = jax.random.normal(kk, (b, l, h, d))
        v = jax.random.normal(kv, (b, l, h, d))

        ref = jax.jit(lambda q, k, v: reference_attention(
            q, k, v, mask=MaskSpec(causal=True)))
        t_ref = time_call(ref, q, k, v)
        rows.append(row(f"kernel/ref_xla/L{l}", t_ref, "oracle"))

        def chunked(q, k, v):
            cs = l // 4
            chunks = [(k[:, i:i + cs], v[:, i:i + cs], i)
                      for i in range(0, l, cs)]
            return finalize(attend_chunked(q, chunks, causal=True))

        t_chunk = time_call(jax.jit(chunked), q, k, v)
        rows.append(row(f"kernel/multi_chunk_merge/L{l}", t_chunk,
                        f"overhead_vs_ref={t_chunk / t_ref:.3f}x"))

        fa = jax.jit(lambda q, k, v: flash_attention(
            q, k, v, causal=True, interpret=True))
        t_pl = time_call(fa, q, k, v, iters=3, warmup=1)
        rows.append(row(f"kernel/pallas_interpret/L{l}", t_pl,
                        "interpret-mode (not TPU time)"))
    return rows
