"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,  # routed-expert hidden size (assignment spec)
    vocab=151936,
    qkv_bias=True,
    rope="rope",
    rope_theta=1e6,
    act="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(
        n_experts=60,
        top_k=4,
        n_shared_experts=4,
        moe_d_ff=1408,
        capacity_factor=1.25,
    ),
    sharding_overrides=(("vocab", ("data",)),),
    citation="hf:Qwen/Qwen1.5-MoE-A2.7B",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=64,
        vocab=512,
        moe=MoEConfig(n_experts=4, top_k=2, n_shared_experts=1, moe_d_ff=64),
    )
