"""whisper-tiny [audio] — encoder-decoder, conv frontend stubbed
[arXiv:2212.04356].

``input_specs()`` supplies precomputed frame embeddings (the output of the
mel-spectrogram + 2-conv frontend) of shape [B, encoder_seq, d_model].
Decode shapes exercise the decoder backbone mechanically; 32k/500k KV far
exceeds Whisper's real 448-token decoder context and is shape-stress only
(DESIGN.md §5).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-tiny",
    family="audio",
    n_layers=4,  # decoder layers
    encoder_layers=4,
    encoder_seq=1536,  # 1500 real frames padded to 1536 for SP divisibility
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    rope="sinusoidal",
    qkv_bias=True,
    act="gelu",
    norm="layernorm",
    citation="arXiv:2212.04356",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        encoder_layers=2,
        encoder_seq=64,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab=512,
    )
