"""RoPE variants + GroupLayout permutation invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.collectives import GroupLayout
from repro.models.blocks import apply_rope, sinusoidal_embedding


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def _qk(key, b=2, l=16, h=4, d=32):
    kq, kk = jax.random.split(key)
    return (jax.random.normal(kq, (b, l, h, d)),
            jax.random.normal(kk, (b, l, h, d)))


@pytest.mark.parametrize("variant,pct", [("rope", 1.0), ("rope", 0.25),
                                         ("rope2d", 1.0)])
def test_rope_preserves_norm(variant, pct, rng):
    q, k = _qk(rng)
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    q2, k2 = apply_rope(q, k, pos, variant=variant, theta=1e4, rope_pct=pct)
    np.testing.assert_allclose(jnp.linalg.norm(q2, axis=-1),
                               jnp.linalg.norm(q, axis=-1), rtol=1e-5)


def test_rope_relative_position_property(rng):
    """<rope(q,i), rope(k,j)> depends only on i - j (full rotary)."""
    q, k = _qk(rng, b=1, l=1)
    def dot_at(i, j):
        pi = jnp.full((1, 1), i)
        pj = jnp.full((1, 1), j)
        qi, _ = apply_rope(q, q, pi, variant="rope", theta=1e4)
        kj, _ = apply_rope(k, k, pj, variant="rope", theta=1e4)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-5  # actually position-dep.


def test_mrope_components_differ(rng):
    """Different (t, h, w) position triples rotate differently."""
    q, k = _qk(rng, b=1, l=4)
    p1 = jnp.stack([jnp.zeros((1, 4), jnp.int32),
                    jnp.arange(4)[None], jnp.arange(4)[None]])
    p2 = jnp.stack([jnp.arange(4)[None], jnp.zeros((1, 4), jnp.int32),
                    jnp.arange(4)[None]])
    q1, _ = apply_rope(q, k, p1, variant="mrope", theta=1e4)
    q2, _ = apply_rope(q, k, p2, variant="mrope", theta=1e4)
    assert float(jnp.max(jnp.abs(q1 - q2))) > 1e-4


def test_sinusoidal_table():
    t = sinusoidal_embedding(32, 64)
    assert t.shape == (32, 64)
    assert float(jnp.max(jnp.abs(t))) <= 1.0 + 1e-6


# ---------------------------------------------------------------------------
# GroupLayout (pure python invariants)
# ---------------------------------------------------------------------------

@given(st.sampled_from([1, 2, 4, 8]), st.sampled_from([1, 2, 4, 8]),
       st.booleans())
@settings(max_examples=100, deadline=None)
def test_layout_coords_roundtrip(pu, pr, outer):
    lay = GroupLayout(("x",), pu, pr, ulysses_outer=outer)
    seen = set()
    for p in range(lay.size):
        u, r = lay.coords(p)
        assert 0 <= u < pu and 0 <= r < pr
        assert lay.rank(u, r) == p
        seen.add((u, r))
    assert len(seen) == lay.size


@given(st.sampled_from([2, 4, 8]), st.sampled_from([2, 4]), st.booleans(),
       st.integers(1, 7))
@settings(max_examples=100, deadline=None)
def test_ulysses_stage_perm_is_permutation(pu, pr, outer, k):
    lay = GroupLayout(("x",), pu, pr, ulysses_outer=outer)
    perm = lay.ulysses_stage_perm(k % pu if k % pu else 1)
    srcs = [a for a, _ in perm]
    dsts = [b for _, b in perm]
    assert sorted(srcs) == list(range(lay.size))
    assert sorted(dsts) == list(range(lay.size))
    # stage permutes stay within the ulysses group (same ring coord)
    for a, b in perm:
        assert lay.coords(a)[1] == lay.coords(b)[1]


@given(st.sampled_from([2, 4, 8]), st.sampled_from([2, 4]), st.booleans())
@settings(max_examples=60, deadline=None)
def test_ring_perm_cycles_within_group(pu, pr, outer):
    lay = GroupLayout(("x",), pu, pr, ulysses_outer=outer)
    perm = dict(lay.ring_perm(1))
    for start in range(lay.size):
        u0 = lay.coords(start)[0]
        cur, steps = start, 0
        while True:
            cur = perm[cur]
            steps += 1
            assert lay.coords(cur)[0] == u0  # never leaves the ring group
            if cur == start:
                break
        assert steps == pr  # full cycle covers the ring group exactly
