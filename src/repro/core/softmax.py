"""Online-softmax partial attention and merge algebra (paper Appendix C).

SwiftFusion's Ring and Torus attention both compute attention of one query
chunk against *multiple* KV chunks that arrive at different times.  Each
partial computation produces a triplet ``A_i = (O'_i, l_i, m_i)`` where

    m_i = rowmax(Q K_i^T * scale)
    l_i = rowsum(exp(Q K_i^T * scale - m_i))
    O'_i = exp(Q K_i^T * scale - m_i) @ V_i        (FlashAttention-2 style:
                                                    *unnormalised* by l_i)

and two triplets merge associatively (Appendix C, eq. 2-3):

    m = max(m_i, m_j)
    l = l_i e^{m_i - m} + l_j e^{m_j - m}
    O' = O'_i e^{m_i - m} + O'_j e^{m_j - m}

with one division ``O = O'/l`` at the very end (``finalize``).

All functions are pure jnp and GQA-aware; they are the oracle against which
the Pallas kernel (kernels/flash_mqkv.py) and every distributed schedule is
validated.

Shapes (B = batch, Lq/Lk = seq, Hq/Hkv = heads, D = head dim):
    q: [B, Lq, Hq, D]    k, v: [B, Lk, Hkv, D]
    o: [B, Lq, Hq, D]    l, m: [B, Hq, Lq]
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..compat import optimization_barrier

NEG_INF = float("-inf")


class Partial(NamedTuple):
    """FA2-style intermediate result A' = (O' = O*l, l, m)."""

    o: jax.Array  # [B, Lq, Hq, D], unnormalised
    l: jax.Array  # [B, Hq, Lq]
    m: jax.Array  # [B, Hq, Lq]


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    """Attention masking for one (q-chunk, kv-chunk) pair.

    Positions are *global* sequence positions, so chunked/distributed
    schedules apply exactly the same mask the single-device computation
    would, even when a gathered chunk is discontinuous in the global
    sequence (paper §4.3: received chunks "can be discontinuous").

    Either give scalar offsets (``q_offset``/``k_offset``, chunk is then
    contiguous from there) or explicit per-element position arrays
    (``q_pos``/``k_pos``), which take precedence.

    ``causal``: standard autoregressive mask (q attends to k ≤ q).
    ``window``: sliding-window size; q attends to k in
                (q_pos - window, q_pos].  ``None`` = unlimited.
    ``valid_k``: optional [Lk] bool — False masks a key out entirely
                 (used by the decode path for unwritten cache slots).
    """

    causal: bool = False
    window: int | None = None
    q_offset: int | jax.Array = 0
    k_offset: int | jax.Array = 0
    q_pos: jax.Array | None = None
    k_pos: jax.Array | None = None
    valid_k: jax.Array | None = None

    def bias(self, lq: int, lk: int, dtype=jnp.float32) -> jax.Array | None:
        if not self.causal and self.window is None and self.valid_k is None:
            return None
        q_pos = self.q_pos if self.q_pos is not None else jnp.arange(lq) + self.q_offset
        k_pos = self.k_pos if self.k_pos is not None else jnp.arange(lk) + self.k_offset
        ok = jnp.ones((lq, lk), dtype=bool)
        if self.causal:
            ok &= q_pos[:, None] >= k_pos[None, :]
        if self.window is not None:
            ok &= k_pos[None, :] > (q_pos[:, None] - self.window)
        if self.valid_k is not None:
            ok &= self.valid_k[None, :]
        return jnp.where(ok, 0.0, NEG_INF).astype(dtype)


def empty_partial(batch: int, lq: int, hq: int, d: int, dtype=jnp.float32) -> Partial:
    """Identity element of the merge monoid."""
    return Partial(
        o=jnp.zeros((batch, lq, hq, d), dtype),
        l=jnp.zeros((batch, hq, lq), dtype),
        m=jnp.full((batch, hq, lq), NEG_INF, dtype),
    )


def _repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    """[B, L, Hkv, D] -> [B, L, Hkv * n_rep, D] (GQA broadcast)."""
    if n_rep == 1:
        return x
    b, l, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, l, h, n_rep, d)).reshape(
        b, l, h * n_rep, d
    )


def attend_partial(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: float | None = None,
    mask: MaskSpec | None = None,
    precision=jax.lax.Precision.HIGHEST,
) -> Partial:
    """Unnormalised attention of q against one KV chunk (Appendix C eq. 1)."""
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    assert hq % hkv == 0, f"GQA requires Hkv | Hq, got {hq=} {hkv=}"
    k = _repeat_kv(k, hq // hkv)
    v = _repeat_kv(v, hq // hkv)
    if scale is None:
        scale = d ** -0.5
    s = jnp.einsum("blhd,bkhd->bhlk", q, k, precision=precision) * scale
    s = s.astype(jnp.float32)
    if mask is not None:
        bias = mask.bias(lq, lk)
        if bias is not None:
            s = s + bias[None, None]
    m = jnp.max(s, axis=-1)  # [B, Hq, Lq]
    # Fully-masked rows have m == -inf; exp(-inf - -inf) would be NaN.
    safe_m = jnp.where(jnp.isneginf(m), 0.0, m)
    p = jnp.exp(s - safe_m[..., None])  # [B, Hq, Lq, Lk]
    p = jnp.where(jnp.isneginf(s), 0.0, p)
    l = jnp.sum(p, axis=-1)  # [B, Hq, Lq]
    o = jnp.einsum("bhlk,bkhd->blhd", p.astype(v.dtype), v, precision=precision)
    return Partial(o=o.astype(jnp.float32), l=l, m=m)


def attend_partial_blockwise(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: float | None = None,
    mask: MaskSpec | None = None,
    kv_block: int = 1024,
) -> Partial:
    """attend_partial with the KV dim processed in blocks + online merge —
    caps the materialized score matrix at [B, H, Lq, kv_block] (the
    XLA-level analogue of the Pallas kernel's VMEM tiling; beyond-paper
    §Perf fix for long-gathered-KV memory blowups)."""
    b, lq, hq, d = q.shape
    lk = k.shape[1]
    if lk <= kv_block:
        return attend_partial(q, k, v, scale=scale, mask=mask)
    acc = empty_partial(b, lq, hq, d)
    for i in range(0, lk, kv_block):
        j = min(i + kv_block, lk)
        if mask is not None:
            kp = (mask.k_pos[i:j] if mask.k_pos is not None
                  else jnp.arange(i, j) + mask.k_offset)
            vk = mask.valid_k[i:j] if mask.valid_k is not None else None
            m = dataclasses.replace(mask, k_pos=kp, k_offset=0, valid_k=vk)
        else:
            m = None
        acc = merge(acc, attend_partial(q, k[:, i:j], v[:, i:j],
                                        scale=scale, mask=m))
        # pin the schedule: without this XLA is free to materialize every
        # block's score matrix before any merge, defeating the blocking
        acc = Partial(*optimization_barrier(tuple(acc)))
    return acc


def merge(a: Partial, b: Partial) -> Partial:
    """Associative, commutative merge of two partials (Appendix C eq. 2-3)."""
    m = jnp.maximum(a.m, b.m)
    safe = lambda mi: jnp.where(jnp.isneginf(mi) & jnp.isneginf(m), 0.0, mi - m)
    ea = jnp.exp(safe(a.m))
    eb = jnp.exp(safe(b.m))
    l = a.l * ea + b.l * eb
    # broadcast [B,Hq,Lq] -> [B,Lq,Hq,1] for the output tensor layout
    t = lambda e: jnp.swapaxes(e, 1, 2)[..., None]
    o = a.o * t(ea) + b.o * t(eb)
    return Partial(o=o, l=l, m=m)


def finalize(p: Partial, dtype=None) -> jax.Array:
    """O = O' / l with one division at the end (Appendix C 'optimizing FP ops')."""
    l = jnp.swapaxes(p.l, 1, 2)[..., None]  # [B, Lq, Hq, 1]
    o = p.o / jnp.where(l == 0.0, 1.0, l)
    return o.astype(dtype or p.o.dtype)


def reference_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: float | None = None,
    mask: MaskSpec | None = None,
) -> jax.Array:
    """Plain single-device softmax attention — the ground-truth oracle."""
    return finalize(attend_partial(q, k, v, scale=scale, mask=mask),
                    dtype=q.dtype)


def attend_chunked(
    q: jax.Array,
    kv_chunks: list[tuple[jax.Array, jax.Array, int]],
    *,
    scale: float | None = None,
    causal: bool = False,
    window: int | None = None,
    q_offset: int = 0,
) -> Partial:
    """Attention of q against a list of (k, v, k_offset) chunks, merged.

    Mirrors what Ring/Torus attention computes step-by-step; used by tests
    to check chunk-order invariance.
    """
    b, lq, hq, d = q.shape
    acc = empty_partial(b, lq, hq, d)
    for k, v, k_off in kv_chunks:
        mask = MaskSpec(causal=causal, window=window, q_offset=q_offset, k_offset=k_off)
        acc = merge(acc, attend_partial(q, k, v, scale=scale, mask=mask))
    return acc
