"""Planner (§4.2) invariants + paper examples."""
import math

from hypothesis import given, settings, strategies as st

from repro.core import plan, usp_plan


@given(
    st.sampled_from([1, 2, 4, 8]),
    st.sampled_from([1, 2, 4, 8, 16]),
    st.integers(1, 128),
)
@settings(max_examples=200, deadline=None)
def test_plan_invariants(n, m, heads):
    p = plan(n, m, heads)
    assert p.p_ulysses * p.p_ring == n * m
    assert heads % p.p_ulysses == 0  # Ulysses degree divides heads
    assert p.p_ulysses == math.gcd(n * m, heads)  # maximal (paper's choice)


@given(
    st.sampled_from([2, 4]), st.sampled_from([2, 4, 8]),
    st.integers(1, 64), st.integers(1, 8),
)
@settings(max_examples=200, deadline=None)
def test_gqa_constrains_ulysses(n, m, hq_mult, hkv):
    hq = hkv * hq_mult
    p = plan(n, m, hq, hkv)
    assert hkv % p.p_ulysses == 0  # never forces KV-head replication
    p2 = plan(n, m, hq, hkv, replicate_kv=True)
    assert p2.p_ulysses >= p.p_ulysses


def test_paper_simple_case():
    """H = N: Ulysses spans exactly the machines (paper §4.2)."""
    p = plan(4, 8, 4 * 8)
    assert p.p_ulysses == 32  # gcd(32, 32)
    p = plan(4, 8, 4)
    assert p.p_ulysses == 4 and p.p_ring == 8
    assert p.ulysses_inter


def test_usp_same_factorisation_different_boundary():
    a = plan(4, 8, 24)
    b = usp_plan(4, 8, 24)
    assert (a.p_ulysses, a.p_ring) == (b.p_ulysses, b.p_ring)
    assert a.ulysses_inter and not b.ulysses_inter


def test_assigned_arch_head_counts():
    """The planner handles every assigned arch's head geometry on the
    production SP group (N=2 pods × M=16)."""
    cases = {  # (Hq, Hkv)
        "qwen2-1.5b": (12, 2), "qwen2-vl-2b": (12, 2), "stablelm-3b": (32, 32),
        "whisper-tiny": (6, 6), "hymba-1.5b": (25, 5), "arctic-480b": (56, 8),
        "chatglm3-6b": (32, 2), "starcoder2-7b": (36, 4),
        "qwen2-moe-a2.7b": (16, 16),
    }
    for arch, (hq, hkv) in cases.items():
        p = plan(2, 16, hq, hkv)
        assert p.p_ulysses * p.p_ring == 32, arch
        assert math.gcd(hq, hkv) % p.p_ulysses == 0, arch


# ---------------------------------------------------------------------------
# hierarchical a2a candidates (DESIGN.md §8.2)
# ---------------------------------------------------------------------------

def test_candidates_include_hier_variants_when_applicable():
    from repro.core.comm_model import hierarchical_applicable
    from repro.core.planner import candidate_hybrid_plans

    cands = candidate_hybrid_plans(2, 8, 32, n_layers=24)
    flat = [h for h in cands if not h.hier_a2a]
    hier = [h for h in cands if h.hier_a2a]
    assert hier, "no hierarchical candidate on a 2-machine mesh"
    for h in hier:
        assert hierarchical_applicable(h.sp), h
        # a flat twin of the same factorisation is always also offered
        assert any((f.cfg, f.pp, f.sp) == (h.cfg, h.pp, h.sp)
                   for f in flat), h
    # single machine: hierarchy never applies, no variant emitted
    assert not any(h.hier_a2a for h in candidate_hybrid_plans(1, 8, 32))


def test_candidates_fp8_variant_requires_opt_in():
    from repro.core.planner import candidate_hybrid_plans

    plain = candidate_hybrid_plans(2, 8, 32)
    assert not any(h.a2a_wire_dtype for h in plain)
    fp8 = candidate_hybrid_plans(2, 8, 32, a2a_wire_dtype="float8_e4m3fn")
    wired = [h for h in fp8 if h.a2a_wire_dtype]
    assert wired and all(h.hier_a2a for h in wired)


def test_plan_hybrid_drops_hier_when_topology_disqualifies():
    from repro.core.planner import plan_hybrid

    # cfg=2 consumes the second machine: the SP sub-mesh is single-machine
    h = plan_hybrid(2, 8, 32, cfg_parallel=True, hier_a2a=True,
                    a2a_wire_dtype="float8_e4m3fn")
    assert h.cfg == 2 and h.sp.n_machines == 1
    assert not h.hier_a2a and h.a2a_wire_dtype is None
    # without cfg the 2-machine sub-mesh qualifies (P_u=16 > N=2)
    h2 = plan_hybrid(2, 8, 32, hier_a2a=True)
    assert h2.hier_a2a


def test_plan_for_shape_scores_hier_vs_flat():
    """Long sequences on a multi-machine mesh: the message-count savings
    make a hierarchical candidate win at least one bucket."""
    from repro.core.planner import plan_for_shape

    best, pred = plan_for_shape(
        2, 8, 32, seq=48_000, head_dim=64, n_layers=24)
    assert pred["t_step"] > 0
    # the hier variant of the winning factorisation never scores WORSE
    # than its flat twin (identical volumes, fewer paced inter messages)
    from repro.core.comm_model import (LayerWorkload, plan_step_latency)
    import dataclasses as _dc
    from repro.core.planner import candidate_hybrid_plans

    wl = LayerWorkload(batch=1, seq=48_000, heads=32, head_dim=64)
    for h in candidate_hybrid_plans(2, 8, 32, n_layers=24):
        if not h.hier_a2a:
            continue
        flat = _dc.replace(h, hier_a2a=False)
        s_h = plan_step_latency(h, wl, n_layers=24)["t_step"]
        s_f = plan_step_latency(flat, wl, n_layers=24)["t_step"]
        assert s_h <= s_f * (1 + 1e-9), (h, s_h, s_f)
