"""Request scheduler facade (DESIGN.md §9): bucketer → admission → plan
cache, behind the two calls an engine needs (``submit`` / ``next_batch``).

The scheduler is pure host-side bookkeeping — no jax, no device state —
so the same object drives the real ``DiTServer`` and the analytical
discrete-event simulation in ``benchmarks/sched_sweep.py``.
"""
from __future__ import annotations

import dataclasses

from .admission import AdmissionPolicy, SchedConfig
from .bucketer import Bucketer, BucketStats
from .plan_cache import PlanCache, PlanChoice


@dataclasses.dataclass(frozen=True)
class Admission:
    """One scheduling decision: which requests run next and under what
    plan."""

    seq_len: int
    requests: list
    batch_rows: int  # len(requests) + dp padding rows
    pad_rows: int
    plan: PlanChoice
    min_slack: float
    age: float  # oldest queue age at admission


class RequestScheduler:
    def __init__(self, plan_cache: PlanCache,
                 cfg: SchedConfig = SchedConfig()):
        self.cfg = cfg
        self.plan_cache = plan_cache
        self.bucketer = Bucketer()
        self.policy = AdmissionPolicy(cfg, plan_cache)
        self.admissions: int = 0

    def submit(self, req, now: float) -> None:
        """Enqueue a request, stamping its submission time (the basis for
        SLA deadlines and starvation ages)."""
        req.submitted = now
        self.bucketer.add(req)

    @property
    def pending(self) -> int:
        return self.bucketer.pending

    def next_batch(self, now: float, flush: bool = False) -> Admission | None:
        """Pick and dequeue the next batch; None = nothing admissible
        (queue empty, or every candidate is worth deferring and ``flush``
        is False)."""
        cand = self.policy.pick(self.bucketer.nonempty(), now, flush=flush)
        if cand is None:
            return None
        reqs = cand.bucket.pop(cand.k, now, self.cfg.dp)
        self.admissions += 1
        return Admission(cand.bucket.seq_len, reqs, cand.batch_rows,
                         cand.pad_rows, cand.plan, cand.min_slack, cand.age)

    def totals(self) -> BucketStats:
        """Aggregated padding-waste / starvation-age accounting."""
        return self.bucketer.totals()
