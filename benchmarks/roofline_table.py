"""Assignment §Roofline: per (arch × shape × mesh) three-term table,
read from the dry-run artifacts in experiments/dryrun/.

derived column: bottleneck term + useful-compute ratio.  Times are the
roofline TERM values in microseconds (not wall measurements).
"""
from __future__ import annotations

import glob
import json
import os

from .common import row

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def run() -> list[str]:
    rows = []
    for path in sorted(glob.glob(f"{DRYRUN_DIR}/*.json")):
        with open(path) as f:
            d = json.load(f)
        r = d["roofline"]
        tag = f"{d['arch']}/{d['shape']}/{d['mesh']}/{d['strategy']}"
        dominant = max(r["t_compute"], r["t_memory"], r["t_collective"])
        rows.append(row(
            f"roofline/{tag}", dominant * 1e6,
            f"bottleneck={r['bottleneck']};useful={r['useful_ratio']:.2f};"
            f"mem_GiB={d['memory']['total_bytes'] / 2**30:.2f}"))
    if not rows:
        rows.append(row("roofline/none", 0.0,
                        "run repro.launch.dryrun first"))
    return rows
