"""Paper Fig. 9: single attention-layer latency sweeps over sequence length,
head dimension, and batch size (SFU normalized to USP).

Sweeps mirror the paper's §5.3 grid: D ∈ {32, 64, 128}, L ∈ {96k, 128k,
160k, 192k}, B ∈ {1, 2, 4}; N=4 machines × 8 GPUs.
"""
from __future__ import annotations

from repro.core import plan, usp_plan
from repro.core.comm_model import LayerWorkload, attention_layer_latency

from .common import row

N, M_PER, HEADS = 4, 8, 24


def _norm_latency(wl: LayerWorkload) -> tuple[float, float]:
    usp = attention_layer_latency(usp_plan(N, M_PER, HEADS), wl, swift=False,
                                  overlap_inter=False)["t_total"]
    sfu = attention_layer_latency(plan(N, M_PER, HEADS), wl, swift=True,
                                  overlap_inter=True)["t_total"]
    return usp, sfu


def run() -> list[str]:
    rows = []
    for d in (32, 64, 128):
        for seq in (96_000, 128_000, 160_000, 192_000):
            wl = LayerWorkload(batch=1, seq=seq, heads=HEADS, head_dim=d)
            usp, sfu = _norm_latency(wl)
            rows.append(row(f"layerwise/seq/D{d}/L{seq // 1000}k",
                            sfu * 1e6, f"norm_vs_usp={sfu / usp:.3f}"))
    for d in (32, 64, 128):
        for b in (1, 2, 4):
            wl = LayerWorkload(batch=b, seq=96_000, heads=HEADS, head_dim=d)
            usp, sfu = _norm_latency(wl)
            rows.append(row(f"layerwise/batch/D{d}/B{b}",
                            sfu * 1e6, f"norm_vs_usp={sfu / usp:.3f}"))
    return rows
