"""Per-architecture smoke tests (assignment deliverable (f)).

Each assigned arch instantiates a REDUCED variant of the same family
(≤2 layers, d_model ≤ 512, ≤4 experts) and runs one forward + one train
step on CPU, asserting output shapes and finiteness.  Full configs are
exercised only via the dry-run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_config, get_reduced
from repro.configs.shapes import InputShape
from repro.core import SPConfig
from repro.models import ParallelContext, get_model
from repro.train import AdamWConfig, adamw_update, init_adamw

SP_FULL = SPConfig(strategy="full", sp_axes=("model",), batch_axes=("data",))
SHAPE = InputShape("smoke", 32, 2, "training")


def _reduced_cfg(arch):
    cfg = get_reduced(arch)
    return dataclasses.replace(cfg, dtype="float32", sharding_overrides=())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_constraints(arch):
    cfg = get_reduced(arch)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    assert cfg.family == get_config(arch).family


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch, mesh1, rng):
    cfg = _reduced_cfg(arch)
    bundle = get_model(cfg)
    params, axes = bundle.init(cfg, rng, 1)
    batch = bundle.input_specs(cfg, SHAPE, abstract=False, key=rng,
                               dtype=jnp.float32)
    ctx = ParallelContext(mesh1, SP_FULL, "prefill")
    out = jax.jit(lambda p, b: bundle.apply(p, b, cfg, ctx))(params, batch)
    if cfg.family == "dit":
        assert out.shape == (SHAPE.global_batch, SHAPE.seq_len, 64)
    else:
        assert out.shape == (SHAPE.global_batch, SHAPE.seq_len, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch, mesh1, rng):
    cfg = _reduced_cfg(arch)
    bundle = get_model(cfg)
    params, axes = bundle.init(cfg, rng, 1)
    batch = bundle.input_specs(cfg, SHAPE, abstract=False, key=rng,
                               dtype=jnp.float32)
    ctx = ParallelContext(mesh1, SP_FULL, "train")
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = init_adamw(params, opt_cfg)

    @jax.jit
    def step(params, opt, batch):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: bundle.loss(p, batch, cfg, ctx), has_aux=True)(params)
        params, opt, metrics = adamw_update(opt_cfg, grads, opt, params)
        return params, opt, loss, metrics

    params2, opt2, loss, metrics = step(params, opt, batch)
    assert np.isfinite(float(loss))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x[0] - x[1]))),
        jax.tree.map(lambda a, b: (a, b), params, params2), 0.0)
    assert delta > 0.0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_assigned_config_matches_assignment(arch):
    """Full configs carry exactly the assigned numbers."""
    spec = {
        "qwen2-vl-2b": dict(n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
                            d_ff=8960, vocab=151936),
        "qwen2-moe-a2.7b": dict(n_layers=24, d_model=2048, n_heads=16,
                                n_kv_heads=16, vocab=151936),
        "stablelm-3b": dict(n_layers=32, d_model=2560, n_heads=32,
                            n_kv_heads=32, d_ff=6912, vocab=50304),
        "whisper-tiny": dict(n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
                             d_ff=1536, vocab=51865),
        "qwen2-1.5b": dict(n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
                           d_ff=8960, vocab=151936),
        "hymba-1.5b": dict(n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
                           d_ff=5504, vocab=32001),
        "arctic-480b": dict(n_layers=35, d_model=7168, n_heads=56,
                            n_kv_heads=8, d_ff=4864, vocab=32000),
        "rwkv6-1.6b": dict(n_layers=24, d_model=2048, n_heads=0, d_ff=7168,
                           vocab=65536),
        "chatglm3-6b": dict(n_layers=28, d_model=4096, n_heads=32,
                            n_kv_heads=2, d_ff=13696, vocab=65024),
        "starcoder2-7b": dict(n_layers=32, d_model=4608, n_heads=36,
                              n_kv_heads=4, d_ff=18432, vocab=49152),
    }[arch]
    cfg = get_config(arch)
    for k, v in spec.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    moe_spec = {"qwen2-moe-a2.7b": (60, 4), "arctic-480b": (128, 2)}
    if arch in moe_spec:
        assert (cfg.moe.n_experts, cfg.moe.top_k) == moe_spec[arch]
    if arch == "rwkv6-1.6b":
        assert cfg.attention_free
    if arch == "hymba-1.5b":
        assert cfg.ssm is not None and cfg.ssm.state_size == 16
