"""Pallas TPU kernels for the paper's compute hot-spot: FlashAttention over
multiple discontiguous Q/KV chunks with fused online-softmax merge
(Algorithm 2, Appendix B/C)."""
from .ops import flash_attention, flash_attention_segments
from .ref import flash_attention_ref
from .rwkv6_wkv import rwkv6_wkv

__all__ = ["flash_attention", "flash_attention_segments",
           "flash_attention_ref", "rwkv6_wkv"]
