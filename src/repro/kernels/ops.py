"""Jitted wrappers around the flash_mqkv Pallas kernel.

``flash_attention``     — [B, L, H, D]-layout entry point with GQA,
                          padding to block multiples, position arrays.
``flash_attention_segments`` — the Algorithm-2 use case: one Q against a
                          *list* of discontiguous KV chunks, carrying the
                          online-softmax state across kernel calls and
                          finalizing once (Appendix C).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash_mqkv import DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q, flash_mqkv


def _pad_to(x: jax.Array, axis: int, mult: int, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _flatten_heads(x: jax.Array) -> jax.Array:
    b, l, h, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, l, d)


def _unflatten_heads(x: jax.Array, b: int, h: int) -> jax.Array:
    bh, l, d = x.shape
    return x.reshape(b, h, l, d).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,  # [B, Lq, Hq, D]
    k: jax.Array,  # [B, Lk, Hkv, D]
    v: jax.Array,
    q_pos: jax.Array | None = None,  # [Lq]
    k_pos: jax.Array | None = None,  # [Lk]
    *,
    causal: bool = False,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """Drop-in flash attention; returns [B, Lq, Hq, D]."""
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    group = hq // hkv
    if q_pos is None:
        q_pos = jnp.arange(lq, dtype=jnp.int32)
    if k_pos is None:
        k_pos = jnp.arange(lk, dtype=jnp.int32)

    bq = min(block_q, max(8, lq))
    bk = min(block_k, max(8, lk))
    qf = _pad_to(_flatten_heads(q), 1, bq)
    kf = _pad_to(_flatten_heads(k), 1, bk)
    vf = _pad_to(_flatten_heads(v), 1, bk)
    qpp = _pad_to(q_pos.astype(jnp.int32), 0, bq, value=0)
    kpp = _pad_to(k_pos.astype(jnp.int32), 0, bk, value=-1)

    o, _, _ = flash_mqkv(
        qf, kf, vf, qpp, kpp, group=group, scale=scale, causal=causal,
        window=window, finalize=True, block_q=bq, block_k=bk,
        interpret=interpret,
    )
    return _unflatten_heads(o[:, :lq], b, hq)


def flash_attention_segments(
    q: jax.Array,  # [B, Lq, Hq, D]
    segments: list[tuple[jax.Array, jax.Array, jax.Array]],  # (k, v, k_pos)
    q_pos: jax.Array | None = None,
    *,
    causal: bool = False,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """Attention of one Q against multiple discontiguous KV chunks — the
    RINGATTN inner loop of Algorithm 1 with the Algorithm-2 fused merge:
    the (O', l, m) state is carried across kernel calls, one division at
    the very end."""
    b, lq, hq, d = q.shape
    if q_pos is None:
        q_pos = jnp.arange(lq, dtype=jnp.int32)
    bq = min(block_q, max(8, lq))
    qf = _pad_to(_flatten_heads(q), 1, bq)
    qpp = _pad_to(q_pos.astype(jnp.int32), 0, bq, value=0)

    state = None
    for i, (k, v, k_pos) in enumerate(segments):
        _, lk, hkv, _ = k.shape
        group = hq // hkv
        bk = min(block_k, max(8, lk))
        kf = _pad_to(_flatten_heads(k), 1, bk)
        vf = _pad_to(_flatten_heads(v), 1, bk)
        kpp = _pad_to(k_pos.astype(jnp.int32), 0, bk, value=-1)
        last = i == len(segments) - 1
        out = flash_mqkv(
            qf, kf, vf, qpp, kpp, group=group, scale=scale, causal=causal,
            window=window, state=state, finalize=last,
            block_q=bq, block_k=bk, interpret=interpret,
        )
        if last:
            o = out[0]
        else:
            state = out
    return _unflatten_heads(o[:, :lq].astype(q.dtype), b, hq)
