"""End-to-end driver: train a ~100M-param qwen2-family model for a few
hundred steps on synthetic data with the SwiftFusion SP attention in the
loss path (deliverable (b) end-to-end driver).

Runs on whatever devices exist; on this container that is 1 CPU device
(strategy degrades to the single-device oracle path, which is exactly what
the paper's methods do at SP=1).  Pass --steps to shorten.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config
from repro.configs.shapes import InputShape
from repro.core import SPConfig
from repro.train import AdamWConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="experiments/ckpt/train_lm")
    args = ap.parse_args()

    # ~100M-parameter qwen2-family variant (95M: 12L d=768 ff=2304 v=16k)
    cfg = dataclasses.replace(
        get_config("qwen2-1.5b"),
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2304,
        vocab=16384, dtype="float32", sharding_overrides=(),
    )
    n_params = cfg.params_dense_estimate()
    print(f"model: {n_params / 1e6:.1f}M params, "
          f"{cfg.n_layers}L d={cfg.d_model}")

    mesh = jax.make_mesh((1, len(jax.devices())), ("data", "model"))
    sp = SPConfig(strategy="swift_torus" if len(jax.devices()) > 1 else "full",
                  sp_axes=("model",), batch_axes=("data",))
    shape = InputShape("train_demo", args.seq, args.batch, "training")
    tr = Trainer(cfg, mesh, sp, shape,
                 opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=20,
                                     total_steps=args.steps),
                 ckpt_path=args.ckpt)
    params, history = tr.run(args.steps, log_every=20)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({'OK: decreased' if last < first else 'WARN: did not decrease'})")


if __name__ == "__main__":
    main()
