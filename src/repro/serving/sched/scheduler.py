"""Request scheduler facade (DESIGN.md §9/§10): bucketer → forecaster →
admission → plan cache, behind the calls an engine needs (``submit`` /
``next_batch`` / ``requeue``).

The scheduler is pure host-side bookkeeping — no jax, no device state —
so the same object drives the real ``DiTServer`` and the analytical
discrete-event simulation in ``benchmarks/sched_sweep.py``.
"""
from __future__ import annotations

import dataclasses

from ..metrics import Tracker
from .admission import AdmissionPolicy, Candidate, SchedConfig
from .bucketer import Bucketer, BucketStats
from .forecast import ArrivalForecaster
from .plan_cache import PlanCache, PlanChoice


@dataclasses.dataclass(frozen=True)
class Admission:
    """One scheduling decision: which requests run next and under what
    plan."""

    seq_len: int
    requests: list
    batch_rows: int  # len(requests) + dp padding rows
    pad_rows: int
    plan: PlanChoice
    min_slack: float
    age: float  # oldest queue age at admission


class RequestScheduler:
    def __init__(self, plan_cache: PlanCache,
                 cfg: SchedConfig = SchedConfig(),
                 forecaster: ArrivalForecaster | None = None,
                 tracker: Tracker | None = None):
        self.cfg = cfg
        self.plan_cache = plan_cache
        self.bucketer = Bucketer()
        self.forecaster = forecaster
        # share the engine's sink by default (an engine passes its own;
        # standalone schedulers fall back to the plan cache's)
        self.tracker = tracker if tracker is not None else plan_cache.tracker
        self.policy = AdmissionPolicy(cfg, plan_cache, forecaster,
                                      tracker=self.tracker)

    # -- tracker-backed counters (legacy attribute surface, DESIGN.md §11)
    @property
    def admissions(self) -> int:
        return int(self.tracker.counter_total("sched.admissions"))

    @property
    def preempted(self) -> int:
        """Requests returned via ``requeue()``."""
        return int(self.tracker.counter("sched.requeued_requests"))

    def submit(self, req, now: float, *, resubmit: bool = False) -> None:
        """Enqueue a request, stamping its submission time (the basis for
        SLA deadlines and starvation ages) and feeding the bucket's
        arrival-rate estimate.

        ``resubmit=True`` is the fleet-failover path (serving/fleet.py):
        the request was evacuated from another replica, so ``submitted``
        is kept (accrued age and the original SLA deadline survive the
        re-dispatch, same invariant as ``requeue``) and the arrival is
        NOT fed to the forecaster — a failover is not new traffic."""
        if resubmit:
            self.tracker.count("sched.resubmitted", tags={"seq": req.seq_len})
        else:
            req.submitted = now
            if self.forecaster is not None:
                self.forecaster.observe(req.seq_len, now)
            self.tracker.count("sched.submitted", tags={"seq": req.seq_len})
        self.bucketer.add(req)

    def requeue(self, reqs: list, pad_rows: int = 0) -> None:
        """Park a preempted batch: its requests return to the HEAD of
        their bucket in original order with ``submitted`` untouched, so
        accrued starvation age survives the preemption (DESIGN.md §10),
        and the admission's bucket accounting is reversed (``pad_rows``
        from the Admission) so ``totals()`` counts only completed
        batches.  No arrival is recorded — a parked request is not new
        traffic.  ``admissions`` is NOT decremented: it counts
        ``next_batch`` decisions, parked or not."""
        self.bucketer.requeue(reqs, pad_rows)
        self.tracker.count("sched.requeued_requests", len(reqs))

    def drain(self) -> list:
        """Evacuate every queued request (global FIFO by submission,
        ``submitted`` untouched) — a failed/draining fleet replica hands
        these back to the router for re-dispatch (serving/fleet.py)."""
        reqs = self.bucketer.drain()
        if reqs:
            self.tracker.count("sched.drained", len(reqs))
        return reqs

    @property
    def pending(self) -> int:
        return self.bucketer.pending

    def next_batch(self, now: float, flush: bool = False) -> Admission | None:
        """Pick and dequeue the next batch; None = nothing admissible
        (queue empty, or every candidate is worth deferring and ``flush``
        is False)."""
        cand = self.policy.pick(self.bucketer.nonempty(), now, flush=flush)
        if cand is None:
            return None
        reqs = cand.bucket.pop(cand.k, now, self.cfg.dp)
        t = self.tracker
        tags = {"seq": cand.bucket.seq_len}
        t.count("sched.admissions", tags=tags)
        t.count("sched.pad_rows", cand.pad_rows, tags=tags)
        t.log("sched.batch_wait_s", cand.age, tags=tags)
        t.log("sched.min_slack_s", cand.min_slack, tags=tags)
        return Admission(cand.bucket.seq_len, reqs, cand.batch_rows,
                         cand.pad_rows, cand.plan, cand.min_slack, cand.age)

    def waiting_candidates(self, now: float) -> list[Candidate]:
        """Scored candidates over the currently queued buckets WITHOUT
        dequeuing — what the preemption policy inspects between sampler
        steps (sched/control.py)."""
        return self.policy.candidates(self.bucketer.nonempty(), now)

    def totals(self) -> BucketStats:
        """Aggregated padding-waste / starvation-age accounting."""
        return self.bucketer.totals()
