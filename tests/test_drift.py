"""Direct ``DriftPolicy`` unit coverage (serving/sched/drift.py): the
threshold decision's edges — zero drift, exactly-at-threshold (the bound
is STRICT: ``d > bound``, so a trajectory sitting on its bound never
resyncs), per-request overrides in both directions, warmup dominance —
plus the ``drift.trigger`` telemetry (DESIGN.md §11): the crossing that
forces a warm step is published with the offending row and bound, and a
decision that doesn't trigger publishes nothing."""
import dataclasses

import pytest

from repro.core.pipefusion import PipelineConfig
from repro.serving.metrics import NullTracker, RecordingTracker
from repro.serving.sched import DriftPolicy

PIPE = PipelineConfig(pp=2, warmup_steps=1)


# ---------------------------------------------------------------------------
# threshold edges
# ---------------------------------------------------------------------------

def test_zero_drift_never_triggers():
    """d == 0 stays displaced even under the tightest possible bound
    (0.0): the rule is strictly 'staleness EXCEEDS the bound'."""
    pol = DriftPolicy(threshold=0.0)
    assert not pol.warm(PIPE, 3, [0.0], [None])
    assert not pol.warm(PIPE, 3, [0.0, 0.0, 0.0], [None, None, None])


def test_exactly_at_threshold_does_not_trigger():
    pol = DriftPolicy(threshold=0.25)
    assert not pol.warm(PIPE, 2, [0.25], [None])  # d == bound: no resync
    assert pol.warm(PIPE, 2, [0.25 + 1e-9], [None])  # just past: resync


def test_exactly_at_per_request_threshold_does_not_trigger():
    pol = DriftPolicy(threshold=None)
    assert not pol.warm(PIPE, 2, [0.1], [0.1])
    assert pol.warm(PIPE, 2, [0.1 + 1e-9], [0.1])


def test_warmup_steps_always_warm():
    """Warmup wins over everything — even a crossed bound is moot (the
    step was already synchronous), and no trigger event is published."""
    t = RecordingTracker()
    pol = DriftPolicy(threshold=0.0)
    pipe = PipelineConfig(pp=2, warmup_steps=3)
    for step in range(3):
        assert pol.warm(pipe, step, [99.0], [None], tracker=t)
    assert t.records == []


def test_first_post_warmup_step_has_no_history():
    # last_drift None = the previous step was warm (or none ran): fresh
    # KV cannot have drifted, so never resync on it
    pol = DriftPolicy(threshold=0.0)
    assert not pol.warm(PIPE, PIPE.warmup_steps, None, [None])


def test_no_bound_anywhere_never_triggers():
    pol = DriftPolicy()  # threshold=None
    assert not pol.warm(PIPE, 5, [1e9], [None, None])
    assert not pol.engaged([None, None])
    assert not pol.engaged([])


# ---------------------------------------------------------------------------
# per-request override (both directions)
# ---------------------------------------------------------------------------

def test_tighter_request_bound_overrides_loose_default():
    pol = DriftPolicy(threshold=0.5)
    assert pol.warm(PIPE, 2, [0.1], [0.05])  # request bound crossed
    assert not pol.warm(PIPE, 2, [0.1], [None])  # default bound isn't


def test_looser_request_bound_overrides_tight_default():
    """A request carrying its own bound is judged ONLY by it — the
    policy default applies to bound-less requests, not on top."""
    pol = DriftPolicy(threshold=0.05)
    assert not pol.warm(PIPE, 2, [0.1], [0.5])
    # a second bound-less request at the same drift falls back to the
    # tight default and triggers
    assert pol.warm(PIPE, 2, [0.1, 0.1], [0.5, None])


def test_any_row_crossing_triggers_for_the_whole_batch():
    pol = DriftPolicy(threshold=None)
    # resync is batch-granular: one crossing row warms everyone
    assert pol.warm(PIPE, 2, [0.0, 0.0, 0.3], [None, None, 0.2])


def test_engaged_per_request_only():
    assert DriftPolicy().engaged([None, 0.3])
    assert DriftPolicy(threshold=0.1).engaged([None, None])


# ---------------------------------------------------------------------------
# drift.trigger telemetry
# ---------------------------------------------------------------------------

def test_trigger_published_with_row_and_bound():
    t = RecordingTracker()
    pol = DriftPolicy(threshold=0.5)
    assert pol.warm(PIPE, 4, [0.1, 0.7, 0.9], [None, None, None], tracker=t)
    assert len(t.records) == 1  # first crossing row decides; no double log
    r = t.records[0]
    assert r.name == "drift.trigger" and r.kind == "gauge"
    assert r.value == pytest.approx(0.7)  # the offending drift value
    assert r.step == 4
    assert r.tags == {"row": 1, "bound": 0.5}


def test_trigger_reports_per_request_bound():
    t = RecordingTracker()
    pol = DriftPolicy(threshold=0.5)
    assert pol.warm(PIPE, 2, [0.1], [0.05], tracker=t)
    assert t.records[0].tags == {"row": 0, "bound": 0.05}


def test_no_trigger_publishes_nothing():
    t = RecordingTracker()
    pol = DriftPolicy(threshold=0.5)
    assert not pol.warm(PIPE, 2, [0.1, 0.2], [None, None], tracker=t)
    assert t.records == []


def test_tracker_optional_and_null_safe():
    pol = DriftPolicy(threshold=0.1)
    assert pol.warm(PIPE, 2, [0.2], [None])  # tracker=None: same decision
    assert pol.warm(PIPE, 2, [0.2], [None], tracker=NullTracker())


def test_policy_is_frozen():
    with pytest.raises(dataclasses.FrozenInstanceError):
        DriftPolicy(threshold=0.1).threshold = 0.2
