"""Fused ring-step kernel: flash_mqkv + the next KV-chunk put issued
in-kernel (the paper's Algorithm-2 overlap, DESIGN.md §8.1).

``flash_mqkv`` computes one ring step's attention; the transfer of the KV
chunk to the next ring rank is then a separate op whose overlap with the
attention compute is left to XLA's latency-hiding scheduler.  This kernel
closes that gap the way the paper's NVSHMEM kernels do: the *same* kernel
that consumes the current KV chunk also issues its forwarding copy —

  * at the **first grid step**, before any compute, the DMA of the whole
    (K, V) chunk into the forward buffers is started
    (``pltpu.make_async_copy`` — a *local* copy into the RDMA staging
    buffer; the inter-device hop itself is ``Channel.put_fused``'s
    ppermute on every branch, with true in-kernel
    ``make_async_remote_copy`` forwarding left as the ROADMAP hardware
    item);
  * every (q-block, kv-block) grid step runs the unchanged flash_mqkv
    online-softmax body while the copy rides the DMA engines;
  * only at the **last grid step**, after the final output write, does the
    kernel wait the DMA semaphores — the no-blocking-wait schedule
    ``comm.trace.validate_semaphores`` checks.

The attention math is byte-for-byte flash_mqkv's (its kernel body is
invoked on the same refs), so (o, l, m) parity with ``flash_mqkv`` is
structural; the property tests in tests/test_ring_flash.py lock it in.
The forwarded buffers are returned to the caller; ``core/ring.py`` hands
them to ``Channel.put_fused`` for the wire move (emulated with ppermute
on CPU CI — see DESIGN.md §8.1 interpret caveats).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import tpu_compiler_params
from .flash_mqkv import DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q, _kernel as _flash_body


def _ring_kernel(
    q_ref, k_ref, v_ref, qp_ref, kp_ref, oin_ref, lin_ref, min_ref,
    kfull_ref, vfull_ref,
    o_ref, l_ref, m_ref, kfwd_ref, vfwd_ref,
    acc_s, m_s, l_s, sem,
    *, scale: float, causal: bool, window: int | None, finalize: bool,
    n_k: int, has_state: bool,
):
    h, qi, ki = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    k_dma = pltpu.make_async_copy(kfull_ref, kfwd_ref, sem.at[0])
    v_dma = pltpu.make_async_copy(vfull_ref, vfwd_ref, sem.at[1])

    # issue the forwarding put before any compute (Algorithm 1: pull next,
    # compute current — expressed in push form)
    @pl.when((h == 0) & (qi == 0) & (ki == 0))
    def _issue():
        k_dma.start()
        v_dma.start()

    _flash_body(
        q_ref, k_ref, v_ref, qp_ref, kp_ref, oin_ref, lin_ref, min_ref,
        o_ref, l_ref, m_ref, acc_s, m_s, l_s,
        scale=scale, causal=causal, window=window, finalize=finalize,
        n_k=n_k, has_state=has_state,
    )

    # wait only after the LAST compute block of the whole grid
    last_h = pl.num_programs(0) - 1
    last_q = pl.num_programs(1) - 1

    @pl.when((h == last_h) & (qi == last_q) & (ki == n_k - 1))
    def _drain():
        k_dma.wait()
        v_dma.wait()


def ring_flash_step(
    q: jax.Array,  # [BH, Lq, D]
    k: jax.Array,  # [BHkv, Lk, D]
    v: jax.Array,
    q_pos: jax.Array,  # [Lq] int32
    k_pos: jax.Array,  # [Lk] int32, -1 = padding
    *,
    group: int = 1,
    scale: float | None = None,
    causal: bool = False,
    window: int | None = None,
    state: tuple[jax.Array, jax.Array, jax.Array] | None = None,
    finalize: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
):
    """One fused ring step.  Same contract as ``flash_mqkv`` plus the
    forwarded chunk: returns ``(o, l, m), (k_fwd, v_fwd)`` where the
    forward buffers hold the consumed KV chunk, copied by the in-kernel
    DMA that overlapped the attention compute."""
    bh, lq, d = q.shape
    bhkv, lk, _ = k.shape
    assert bh == bhkv * group, (bh, bhkv, group)
    assert lq % block_q == 0 and lk % block_k == 0, (lq, lk, block_q, block_k)
    if scale is None:
        scale = d ** -0.5
    n_q, n_k = lq // block_q, lk // block_k
    has_state = state is not None

    qp2 = q_pos.reshape(1, lq)
    kp2 = k_pos.reshape(1, lk)
    if state is None:
        o_in = jnp.zeros((bh, block_q, d), jnp.float32)
        l_in = jnp.zeros((bh, block_q), jnp.float32)
        m_in = jnp.zeros((bh, block_q), jnp.float32)
        oin_spec = pl.BlockSpec((None, block_q, d), lambda h, qi, ki: (h, 0, 0))
        lin_spec = pl.BlockSpec((None, block_q), lambda h, qi, ki: (h, 0))
    else:
        o_in, l_in, m_in = state
        oin_spec = pl.BlockSpec((None, block_q, d), lambda h, qi, ki: (h, qi, 0))
        lin_spec = pl.BlockSpec((None, block_q), lambda h, qi, ki: (h, qi))

    kernel = functools.partial(
        _ring_kernel, scale=scale, causal=causal, window=window,
        finalize=finalize, n_k=n_k, has_state=has_state,
    )
    out_shape = (
        jax.ShapeDtypeStruct((bh, lq, d), q.dtype if finalize else jnp.float32),
        jax.ShapeDtypeStruct((bh, lq), jnp.float32),
        jax.ShapeDtypeStruct((bh, lq), jnp.float32),
        jax.ShapeDtypeStruct(k.shape, k.dtype),
        jax.ShapeDtypeStruct(v.shape, v.dtype),
    )
    o, l, m, k_fwd, v_fwd = pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((None, block_k, d),
                         lambda h, qi, ki, g=group: (h // g, ki, 0)),
            pl.BlockSpec((None, block_k, d),
                         lambda h, qi, ki, g=group: (h // g, ki, 0)),
            pl.BlockSpec((1, block_q), lambda h, qi, ki: (0, qi)),
            pl.BlockSpec((1, block_k), lambda h, qi, ki: (0, ki)),
            oin_spec,
            lin_spec,
            lin_spec,
            pl.BlockSpec(memory_space=pltpu.ANY),  # DMA source: full K
            pl.BlockSpec(memory_space=pltpu.ANY),  # DMA source: full V
        ],
        out_specs=[
            pl.BlockSpec((None, block_q, d), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((None, block_q), lambda h, qi, ki: (h, qi)),
            pl.BlockSpec((None, block_q), lambda h, qi, ki: (h, qi)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # forward buffer: K
            pl.BlockSpec(memory_space=pltpu.ANY),  # forward buffer: V
        ],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        compiler_params=tpu_compiler_params(pltpu,
            # DMA issue/drain at fixed grid steps imposes an execution
            # order; no parallel dimension semantics for the fused kernel
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v, qp2, kp2, o_in, l_in, m_in, k, v)
    return (o, l, m), (k_fwd, v_fwd)
