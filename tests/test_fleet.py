"""Fleet tier (serving/fleet.py, DESIGN.md §13) and the cross-replica
metrics-replay fixes (ISSUE 9):

  (a) folding two replicas' traces into one router tracker SUMS counters
      (the old ``replay`` wrote ``_counters[key] = value`` directly —
      last trace won) and routes through the tracker API, so persistent
      router sinks re-emit every folded record,
  (b) gauge series keep their per-replica tag namespace across the fold,
  (c) a truncated-tail trace (replica killed mid-write) still folds via
      ``read_jsonl(partial_tail="drop")``,
  (d) router policies decide ONLY from the folded view + the unshipped
      dispatch ledger — never by reaching into a replica's scheduler,
  (e) failover re-dispatch preserves accrued submission age.

All host-side on simulated time; the replica stacks use the small plan
cache flavour from tests/test_sched.py."""
import dataclasses

import pytest

from repro.serving.fleet import (
    ACTIVE,
    DRAINING,
    FAILED,
    FailureEvent,
    FleetConfig,
    FleetRequest,
    FleetRouter,
    Replica,
    run_fleet,
)
from repro.serving.metrics import (
    JsonlTracker,
    RecordingTracker,
    TraceFold,
    Tracker,
    read_jsonl,
    replay,
)


def sim_replica(rid: str, trace_path=None, **kw) -> Replica:
    args = dict(n_machines=2, m_per_machine=4, heads=8, head_dim=64,
                n_layers=8, num_steps=4, dp=2, max_batch=4)
    args.update(kw)
    return Replica.sim(rid, trace_path, **args)


def req(rid: int, seq: int, arrival: float = 0.0,
        sla: float | None = None) -> FleetRequest:
    return FleetRequest(rid=rid, seq_len=seq, arrival=arrival, sla=sla)


# ---------------------------------------------------------------------------
# (a) two-replica fold: sums, not clobbers; persistent sinks see records
# ---------------------------------------------------------------------------

def _replica_trace(counts: list[float], gauge: float) -> list:
    """A recorded stream with one counter series and one gauge series —
    the same (name, tags) on every replica, the clobber scenario."""
    t = RecordingTracker()
    for v in counts:
        t.count("sched.submitted", v, tags={"seq": 256})
    t.log("replica.queue_depth", gauge)
    return t.records


def test_two_replica_fold_sums_counters():
    router = Tracker()
    TraceFold(tags={"replica": "r0"}).fold(_replica_trace([1, 1], 3.0),
                                           router)
    TraceFold(tags={"replica": "r1"}).fold(_replica_trace([1, 1, 1], 5.0),
                                           router)
    # the old replay assigned the second trace's cumulative total over
    # the first: counter_total would read 3, not 5
    assert router.counter_total("sched.submitted") == 5
    assert router.counter("sched.submitted",
                          {"seq": 256, "replica": "r0"}) == 2
    assert router.counter("sched.submitted",
                          {"seq": 256, "replica": "r1"}) == 3


def test_fold_routes_through_emit_for_persistent_sinks(tmp_path):
    """The old replay bypassed ``_emit`` — a JsonlTracker fold target
    would write NOTHING for replayed counters."""
    sink = JsonlTracker(tmp_path / "router.jsonl")
    TraceFold(tags={"replica": "r0"}).fold(_replica_trace([1, 2], 3.0), sink)
    sink.close()
    recs = read_jsonl(tmp_path / "router.jsonl")
    counters = [r for r in recs if r.kind == "counter"]
    assert len(counters) == 2
    # re-emitted as increments under the router's own dense seq: the
    # folded file is itself a valid, replayable metrics.v1 stream
    assert [r.seq for r in recs] == list(range(len(recs)))
    assert replay(recs).counter_total("sched.submitted") == 3


def test_fold_keeps_per_replica_gauge_namespace():
    router = Tracker()
    TraceFold(tags={"replica": "r0"}).fold(_replica_trace([1], 3.0), router)
    TraceFold(tags={"replica": "r1"}).fold(_replica_trace([1], 5.0), router)
    assert router.series("replica.queue_depth", {"replica": "r0"}).last == 3.0
    assert router.series("replica.queue_depth", {"replica": "r1"}).last == 5.0
    # and the namespaces are separate series, not one merged gauge
    assert router.series("replica.queue_depth").n == 0


def test_fold_is_incremental_not_double_counting():
    src = RecordingTracker()
    router = Tracker()
    fold = TraceFold(tags={"replica": "r0"})
    src.count("c", 2)
    assert fold.fold(src.records, router) == 1
    src.count("c", 3)
    # second ship re-reads the whole stream; only the new record folds
    assert fold.fold(src.records, router) == 1
    assert router.counter_total("c") == 5


def test_fold_rejects_counter_regression():
    """A cumulative counter running backwards means trace corruption —
    fold refuses rather than publishing a negative increment."""
    good = Tracker()
    recs = _replica_trace([1, 1], 0.0)
    corrupted = [recs[1], dataclasses.replace(recs[0], seq=5)]
    with pytest.raises(AssertionError):
        TraceFold().fold(corrupted, good)


def test_truncated_tail_trace_still_folds(tmp_path):
    p = tmp_path / "r0.jsonl"
    with JsonlTracker(p) as t:
        t.count("sched.submitted", 1, tags={"seq": 256})
        t.count("sched.submitted", 1, tags={"seq": 256})
        t.log("replica.queue_depth", 7.0)
    # replica killed mid-write: the final line is half a record
    raw = p.read_text()
    p.write_text(raw[:len(raw) - len(raw.splitlines()[-1]) // 2 - 1])
    recs = read_jsonl(p, partial_tail="drop")
    assert len(recs) == 2
    router = Tracker()
    TraceFold(tags={"replica": "r0"}).fold(recs, router)
    assert router.counter_total("sched.submitted") == 2


def test_replay_into_persistent_sink_reemits():
    """replay() is a fold into a fresh (or caller-supplied) tracker —
    a RecordingTracker target must capture every replayed record."""
    back = replay(_replica_trace([1, 1], 3.0), into=RecordingTracker())
    assert isinstance(back, RecordingTracker)
    assert len(back.records) == 3
    assert back.counter_total("sched.submitted") == 2


# ---------------------------------------------------------------------------
# (d) router decides from the folded view only
# ---------------------------------------------------------------------------

def make_fleet(policy: str, n: int = 2, **cfg_kw):
    reps = [sim_replica(f"r{k}") for k in range(n)]
    return reps, FleetRouter(reps, policy=policy,
                             cfg=FleetConfig(**cfg_kw))


def test_round_robin_cycles_active_replicas():
    reps, router = make_fleet("round_robin", n=3)
    picks = [router.dispatch(req(i, 256, 0.0), 0.0) for i in range(6)]
    assert picks == ["r0", "r1", "r2", "r0", "r1", "r2"]
    reps[1].drain(0.0)
    router.ship(0.0)  # the router learns state ONLY from the fold
    picks = [router.dispatch(req(i, 256, 0.0), 0.0) for i in range(4)]
    assert "r1" not in picks


def test_least_loaded_uses_ledger_before_ship_and_fold_after():
    reps, router = make_fleet("least_loaded")
    # before any ship the folded depth is 0 for both; the unshipped
    # dispatch ledger alone must balance the load
    picks = [router.dispatch(req(i, 256, 0.0), 0.0) for i in range(4)]
    assert sorted(picks) == ["r0", "r0", "r1", "r1"]
    router.ship(0.0)
    # folded queue_depth now carries what the ledger carried
    v0, v1 = router.view("r0"), router.view("r1")
    assert (v0.queue_depth, v0.in_flight) == (2, 0)
    assert (v1.queue_depth, v1.in_flight) == (2, 0)
    assert v0.queue_depth == reps[0].pending  # fold mirrors the truth


def test_warmth_affinity_is_sticky_per_band():
    _, router = make_fleet("warmth")
    homes = {router.dispatch(req(i, 256, 0.0), 0.0) for i in range(5)}
    assert len(homes) == 1  # one home replica for the band
    other = {router.dispatch(req(10 + i, 1024, 0.0), 0.0) for i in range(5)}
    assert len(other) == 1
    assert homes != other  # second band homes on the other replica


def test_warmth_spills_under_pressure():
    _, router = make_fleet("warmth", spill_depth=3)
    for i in range(3):
        router.dispatch(req(i, 256, 0.0), 0.0)
    assert router.spills == 0
    spilled = router.dispatch(req(3, 256, 0.0), 0.0)
    assert router.spills == 1
    assert spilled != router._pools[256][0]


def test_warmth_first_sighting_prefers_warm_replica():
    reps, router = make_fleet("warmth")
    # r1's folded trace shows a compiled step for seq=512 (a step_miss
    # counter with that tag); the band's first dispatch must go there
    reps[1].tracker.count("plan_cache.step_miss", tags={"rows": 4,
                                                        "seq": 512})
    router.ship(0.0)
    assert router.view("r1").warm == frozenset({512})
    assert router.dispatch(req(0, 512, 0.0), 0.0) == "r1"


def test_failover_redispatch_preserves_age():
    reps, router = make_fleet("round_robin")
    r = req(0, 256, arrival=0.0, sla=1.0)
    router.dispatch(r, 0.0)
    assert r.submitted == 0.0
    rid = "r0" if reps[0].pending else "r1"
    evacuated = router.by_rid[rid].fail(0.5)
    assert [x.rid for x in evacuated] == [0]
    assert router.by_rid[rid].state == FAILED
    router.ship(0.5)
    new_rid = router.redispatch(evacuated, 0.5)[0]
    assert new_rid != rid
    # accrued age survives the failover: submitted is NOT restamped
    assert r.submitted == 0.0
    assert router.requeued == 1
    srv = router.by_rid[new_rid].scheduler
    assert srv.tracker.counter_total("sched.resubmitted") == 1
    assert srv.tracker.counter_total("sched.submitted") == 0


def test_dispatch_to_failed_replica_refused():
    reps, router = make_fleet("round_robin")
    for rep in reps:
        rep.fail(0.0)
    router.ship(0.0)
    with pytest.raises(RuntimeError):
        router.dispatch(req(0, 256, 0.0), 0.0)
    reps[0].resume(0.1)
    router.ship(0.1)
    assert router.dispatch(req(0, 256, 0.1), 0.1) == "r0"


def test_replica_state_machine_roundtrip():
    rep = sim_replica("r0")
    assert rep.state == ACTIVE
    rep.drain(0.0)
    assert rep.state == DRAINING
    rep.resume(0.1)
    rep.submit(req(0, 256), 0.1)
    assert rep.fail(0.2)[0].rid == 0
    assert rep.state == FAILED and rep.pending == 0
    # the transitions were all published as gauge samples
    codes = [r.value for r in rep.tracker.records
             if r.name == "replica.state"]
    assert codes == [0.0, 1.0, 0.0, 2.0]


# ---------------------------------------------------------------------------
# end-to-end fleet run (small, deterministic)
# ---------------------------------------------------------------------------

def _stream(n: int = 24) -> list[FleetRequest]:
    reqs, t = [], 0.0
    for i in range(n):
        t += 0.003 + 0.002 * (i % 3)
        seq = (256, 512, 1024)[(i * 7) % 3]
        reqs.append(FleetRequest(rid=i, seq_len=seq, arrival=round(t, 5),
                                 sla=2.0))
    return reqs


@pytest.mark.parametrize("policy", ["round_robin", "warmth", "sla"])
def test_run_fleet_serves_everything(policy):
    reps = [sim_replica(f"r{k}") for k in range(2)]
    router = FleetRouter(reps, policy=policy)
    stats = run_fleet(_stream(), router)
    assert stats["served"] == 24
    assert stats["sla_total"] == 24
    assert stats["preemptions"] == 0
    # fleet totals mirror the folded per-replica counters exactly
    folded = router.tracker.counter_total("replica.served")
    assert folded == 24


def test_run_fleet_failover_serves_everything():
    reps = [sim_replica(f"r{k}") for k in range(2)]
    router = FleetRouter(reps, policy="warmth")
    stats = run_fleet(
        _stream(), router,
        failure=FailureEvent(at=0.03, rid="r0", kind="fail",
                             revive_after=0.05))
    assert stats["served"] == 24
    # the folded router stream shows the failure transition it acted on
    st = router.tracker.series("replica.state", {"replica": "r0"})
    assert st.vmax == 2.0  # FAILED was visible through the fold


def test_run_fleet_is_deterministic():
    def once():
        reps = [sim_replica(f"r{k}") for k in range(2)]
        return run_fleet(_stream(), FleetRouter(reps, policy="sla"))
    assert once() == once()
