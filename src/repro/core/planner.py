"""Topology-aware SP planner (paper §4.2).

Given a cluster of N machines × M devices (TPU: N pods × M intra-pod chips
in the SP group) and an attention layer with H heads, SwiftFusion organises
the N·M devices into a 2-D logical mesh P_u × P_r with

    P_u = gcd(N·M, H)          (maximise Ulysses usage)
    P_r = N·M / P_u

and assigns the *Ulysses* group to span the slow (inter-machine) boundary
and the *Ring* group to stay inside the fast (intra-machine) network —
the inverse of USP's assignment.

For GQA models the Ulysses head-scatter must divide the number of *KV*
heads (otherwise KV heads would have to be replicated); the planner
therefore takes ``heads = gcd(H_q, H_kv)`` unless ``replicate_kv`` is set.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class SPPlan:
    """A concrete SP decomposition of ``n_machines * m_per_machine`` devices."""

    n_machines: int  # N: pods (slow boundary)
    m_per_machine: int  # M: chips per pod in the SP group (fast network)
    p_ulysses: int  # P_u
    p_ring: int  # P_r
    ulysses_inter: bool  # True = SwiftFusion/TAS, False = USP baseline

    @property
    def sp_degree(self) -> int:
        return self.n_machines * self.m_per_machine

    @property
    def torus_degree(self) -> int:
        """N for Torus Attention (inter-machine Ulysses stages), §4.3.

        Torus applies when Ulysses spans machines; its stage count is the
        number of machines covered by the Ulysses group.
        """
        if not self.ulysses_inter:
            return 1
        return min(self.p_ulysses, self.n_machines)

    def validate(self) -> None:
        assert self.p_ulysses * self.p_ring == self.sp_degree, self
        assert self.p_ulysses >= 1 and self.p_ring >= 1, self


def plan(
    n_machines: int,
    m_per_machine: int,
    num_q_heads: int,
    num_kv_heads: int | None = None,
    *,
    swift: bool = True,
    replicate_kv: bool = False,
) -> SPPlan:
    """Compute (P_u, P_r) per §4.2: P_u = gcd(N*M, H), P_r = N*M / P_u."""
    sp = n_machines * m_per_machine
    if num_kv_heads is None:
        num_kv_heads = num_q_heads
    heads = num_q_heads if replicate_kv else math.gcd(num_q_heads, num_kv_heads)
    p_u = math.gcd(sp, heads)
    p = SPPlan(
        n_machines=n_machines,
        m_per_machine=m_per_machine,
        p_ulysses=p_u,
        p_ring=sp // p_u,
        ulysses_inter=swift,
    )
    p.validate()
    return p


def usp_plan(
    n_machines: int,
    m_per_machine: int,
    num_q_heads: int,
    num_kv_heads: int | None = None,
) -> SPPlan:
    """The USP baseline: same (P_u, P_r) factorisation but Ring spans the
    inter-machine boundary and Ulysses stays intra-machine (§2.2)."""
    p = plan(n_machines, m_per_machine, num_q_heads, num_kv_heads, swift=False)
    return p


# ---------------------------------------------------------------------------
# hybrid planning: (cfg, pp, P_u, P_r) over N machines × M chips
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HybridPlan:
    """A (cfg, pp, P_u, P_r) decomposition of N·M devices (DESIGN.md §7).

    The hybrid axes are ordered by how rarely they synchronise:

      cfg — classifier-free-guidance parallelism (xDiT, arXiv:2411.01738),
            generalised to guidance degree k (negative prompts /
            multi-conditioning stacks): the k branches are independent
            full forwards that recombine ONCE per sampler step (one
            psum-sized weighted sum of the velocity).  Cheapest axis;
            placed across the slow (inter-machine) boundary first.
      pp  — patch-level pipeline parallelism (PipeFusion): stages exchange
            one patch's activations per micro-step, once per layer-group
            rather than per layer.  Second-cheapest; also prefers the slow
            boundary.
      sp  — the remaining devices run the paper's topology-aware SP plan
            (Torus/TAS placement rules unchanged) on the residual
            (machines × chips) sub-mesh.
    """

    cfg: int  # 1 (sequential CFG) or k >= 2 (parallel guidance branches)
    pp: int  # pipeline stages
    sp: SPPlan  # SP factorisation of the remaining devices
    n_machines: int  # N of the full cluster
    m_per_machine: int  # M of the full cluster
    cfg_machines: int = 1  # machine-level factor consumed by cfg
    pp_machines: int = 1  # machine-level factor consumed by pp
    # Comm lowering the plan will execute with (DESIGN.md §8.1): "pallas"
    # scores the kernel-fused schedule (no per-step issue overhead) in
    # comm_model.plan_step_latency and selects the fused ring kernel via
    # SPConfig.comm_backend at execution time.
    comm_backend: str = "xla"
    # Hierarchical two-level a2a (DESIGN.md §8.2): decompose the Ulysses
    # all-to-alls into intra-machine exchange + staged inter-machine hops.
    # Only meaningful when comm_model.hierarchical_applicable(sp) holds —
    # the executor and the latency model both fall back to the flat path
    # otherwise.  a2a_wire_dtype optionally compresses the inter-machine
    # leg ("float8_e4m3fn"/"float8_e5m2"); None keeps the wire exact.
    hier_a2a: bool = False
    a2a_wire_dtype: str | None = None

    @property
    def total_devices(self) -> int:
        return self.cfg * self.pp * self.sp.sp_degree

    @property
    def cfg_inter(self) -> bool:
        """True when the CFG pair spans the inter-machine boundary."""
        return self.cfg_machines > 1

    @property
    def pp_inter(self) -> bool:
        """True when pipeline-stage hand-offs cross machines."""
        return self.pp_machines > 1

    def validate(self) -> None:
        assert self.cfg >= 1, self
        assert self.pp >= 1, self
        assert self.comm_backend in ("xla", "pallas"), self
        if self.a2a_wire_dtype is not None:
            from ..comm.compress import WIRE_DTYPES
            assert self.a2a_wire_dtype in WIRE_DTYPES, self
            assert self.hier_a2a, "wire compression rides the hier path only"
        self.sp.validate()
        assert self.total_devices == self.n_machines * self.m_per_machine, self


def _consume(n: int, m: int, degree: int) -> tuple[int, int, int]:
    """Factor ``degree`` devices out of (n machines × m chips), machines
    first (independent/cheap axes belong on the slow boundary).  Returns
    (n', m', machine_factor)."""
    from_n = math.gcd(n, degree)
    from_m = degree // from_n
    if m % from_m != 0:
        raise ValueError(
            f"cannot factor degree {degree} out of {n} machines x {m} chips")
    return n // from_n, m // from_m, from_n


def plan_hybrid(
    n_machines: int,
    m_per_machine: int,
    num_q_heads: int,
    num_kv_heads: int | None = None,
    *,
    cfg_parallel: bool = False,
    cfg_degree: int = 2,
    pp: int = 1,
    n_layers: int | None = None,
    swift: bool = True,
    replicate_kv: bool = False,
    comm_backend: str = "xla",
    hier_a2a: bool = False,
    a2a_wire_dtype: str | None = None,
) -> HybridPlan:
    """Plan (cfg, pp, P_u, P_r) for N machines × M chips.

    cfg and pp consume machine-level factors first (they synchronise the
    least, see HybridPlan); whatever remains is planned by the paper's §4.2
    rule, so the SP sub-mesh keeps the TAS placement (Ulysses/Torus across
    the surviving machine boundary, Ring inside the machine).
    ``cfg_degree`` is the guidance degree k consumed by the cfg axis when
    ``cfg_parallel`` (k = 2 is the classic cond/uncond pair).

    ``hier_a2a`` requests the hierarchical two-level a2a on the SP
    sub-plan; it is silently dropped (flat plan returned) when the
    residual sub-mesh's topology does not qualify, so callers can pass it
    unconditionally.
    """
    if cfg_parallel:
        assert cfg_degree >= 2, cfg_degree
    cfg = cfg_degree if cfg_parallel else 1
    total = n_machines * m_per_machine
    if total % (cfg * pp) != 0:
        raise ValueError(
            f"cfg*pp = {cfg * pp} does not divide {total} devices")
    if n_layers is not None and pp > 1 and n_layers % pp != 0:
        raise ValueError(f"pp = {pp} does not divide n_layers = {n_layers}")
    n, m = n_machines, m_per_machine
    n, m, cfg_mach = _consume(n, m, cfg)
    n, m, pp_mach = _consume(n, m, pp)
    sp = plan(n, m, num_q_heads, num_kv_heads, swift=swift,
              replicate_kv=replicate_kv)
    if hier_a2a:
        from .comm_model import hierarchical_applicable
        if not hierarchical_applicable(sp):
            hier_a2a, a2a_wire_dtype = False, None
    h = HybridPlan(
        cfg=cfg, pp=pp, sp=sp,
        n_machines=n_machines, m_per_machine=m_per_machine,
        cfg_machines=cfg_mach, pp_machines=pp_mach,
        comm_backend=comm_backend,
        hier_a2a=hier_a2a, a2a_wire_dtype=a2a_wire_dtype,
    )
    h.validate()
    return h


# ---------------------------------------------------------------------------
# per-shape plan selection (DESIGN.md §9): the scheduler's entry point
# ---------------------------------------------------------------------------

def candidate_hybrid_plans(
    n_machines: int,
    m_per_machine: int,
    num_q_heads: int,
    num_kv_heads: int | None = None,
    *,
    n_layers: int | None = None,
    cfg_degree: int = 2,
    max_pp: int = 4,
    swift: bool = True,
    replicate_kv: bool = False,
    comm_backend: str = "xla",
    a2a_wire_dtype: str | None = None,
) -> list[HybridPlan]:
    """Every feasible (cfg, pp) split of the cluster, deduplicated by the
    resulting (cfg, pp, P_u, P_r, hier) — the candidate set
    ``plan_for_shape`` and the scheduler's plan cache score per bucket.
    Each candidate's SP sub-plan keeps the §4.2 TAS/Torus placement; when
    the residual sub-mesh qualifies, a hierarchical-a2a variant of the
    same factorisation is emitted alongside the flat one (with
    ``a2a_wire_dtype`` compression when requested), so flat-vs-hier is a
    scored decision per topology, not a config toggle."""
    from .comm_model import hierarchical_applicable

    pps = [1]
    while pps[-1] * 2 <= max_pp:
        pps.append(pps[-1] * 2)
    seen, out = set(), []

    def add(h: HybridPlan) -> None:
        key = (h.cfg, h.pp, h.sp.p_ulysses, h.sp.p_ring,
               h.hier_a2a, h.a2a_wire_dtype)
        if key not in seen:
            seen.add(key)
            out.append(h)

    for cfg_parallel in (False, True):
        for pp in pps:
            try:
                h = plan_hybrid(
                    n_machines, m_per_machine, num_q_heads, num_kv_heads,
                    cfg_parallel=cfg_parallel, cfg_degree=cfg_degree, pp=pp,
                    n_layers=n_layers, swift=swift, replicate_kv=replicate_kv,
                    comm_backend=comm_backend)
            except ValueError:
                continue
            add(h)
            if hierarchical_applicable(h.sp):
                add(dataclasses.replace(h, hier_a2a=True))
                if a2a_wire_dtype is not None:
                    add(dataclasses.replace(
                        h, hier_a2a=True, a2a_wire_dtype=a2a_wire_dtype))
    return out


def plan_for_shape(
    n_machines: int,
    m_per_machine: int,
    num_q_heads: int,
    num_kv_heads: int | None = None,
    *,
    seq: int,
    batch: int = 1,
    head_dim: int,
    n_layers: int,
    net=None,
    guided: bool = True,
    guidance_branches: int = 2,
    num_steps: int = 20,
    candidates: list[HybridPlan] | None = None,
    cfg_degree: int = 2,
    max_pp: int = 4,
    swift: bool = True,
    comm_backend: str = "xla",
    a2a_wire_dtype: str | None = None,
) -> tuple[HybridPlan, dict]:
    """Select the (cfg, pp, P_u, P_r) plan with the lowest predicted step
    latency FOR A SPECIFIC WORKLOAD SHAPE (batch, seq) — the per-bucket
    planning entry the request scheduler uses: plan_hybrid is shape-blind
    (it factors devices), but which factorisation wins depends on the
    sequence length through the comm model.  Returns (plan, prediction).
    """
    from .comm_model import LayerWorkload, NetworkModel, plan_step_latency

    net = net or NetworkModel()
    cands = candidates if candidates is not None else candidate_hybrid_plans(
        n_machines, m_per_machine, num_q_heads, num_kv_heads,
        n_layers=n_layers, cfg_degree=cfg_degree, max_pp=max_pp, swift=swift,
        comm_backend=comm_backend, a2a_wire_dtype=a2a_wire_dtype)
    assert cands, "no feasible hybrid plan"
    wl = LayerWorkload(batch=batch, seq=seq, heads=num_q_heads,
                       head_dim=head_dim)
    best: tuple[HybridPlan, dict] | None = None
    for h in cands:
        pred = plan_step_latency(
            h, wl, net, n_layers=n_layers, guided=guided,
            guidance_branches=guidance_branches, num_steps=num_steps)
        if best is None or pred["t_step"] < best[1]["t_step"]:
            best = (h, pred)
    return best
