"""Fleet-router sweep (DESIGN.md §13): multi-replica SLA-aware dispatch
policies over the seeded sched_sweep arrival streams, with replica
failure/drain injected.

Extends ``benchmarks/sched_sweep.py``'s discrete-event simulation one
tier up: each replica is a full PR-3/5 scheduler stack (bucketer,
admission, plan cache, forecaster) on the paper testbed flavour (N=2
machines x M=4 devices, dp=2), and a ``FleetRouter`` dispatches the
global stream across R of them.  Router state is fed EXCLUSIVELY by
folded per-replica ``metrics.v1`` tracker traces (``TraceFold`` over
``read_jsonl`` / recorded streams, period ``FleetConfig.ship_every``) —
never by reaching into a replica's scheduler — so every policy decides
on exactly the information a cross-host router would have.

Policies swept (serving/fleet.py): ``round_robin`` (baseline),
``least_loaded`` (folded queue-depth gauge + unshipped ledger),
``warmth`` (resolution-band affinity to warm plan caches, least-queue
spill under pressure), ``sla`` (warmth + elastic repartition from the
folded per-bucket ``ArrivalForecaster`` rates).  Scenarios: the seeded
``bursty`` mixed-resolution stream, the same stream with a replica
FAILURE injected mid-burst (queue evacuated, router re-dispatch with
age intact), and the ``diurnal`` stream with a replica DRAIN (serves
out, no dispatch) — all deterministic, no wall clock anywhere.

The headline claim mirrors the plan-cache economics: batches stall
``TRACE_COST_S`` the first time a replica runs a bucket shape, so
round_robin interleaves both resolution bands onto both replicas (tight
256-burst SLAs queue behind ~30 ms 1024 batches and every replica
compiles every shape) while warm-cache affinity pins each band to its
home replica — higher SLA-met fraction on fewer jit traces.  ``--smoke``
asserts that uplift (with the failure injected), that every request is
served under failover, and the fold-sum invariant (router counter totals
equal the per-replica sums).  ``--trace-dir`` retains the per-replica
JSONL traces and the router's folded trace for
``scripts/check_metrics_schema.py``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import pathlib
import sys
import tempfile

from repro.serving.fleet import (
    POLICIES,
    FailureEvent,
    FleetRouter,
    Replica,
    run_fleet,
)
from repro.serving.metrics import JsonlTracker, Tracker, read_jsonl

from .common import row
from .sched_sweep import (
    DP,
    M_PER_MACHINE,
    N_MACHINES,
    bursty_stream,
    diurnal_stream,
)

N_REPLICAS = 2
# first-run jit stall per bucket shape per replica: the warmth signal.
# Deliberately larger than the 12 ms burst SLA and comparable to one
# ~30 ms 1024 batch — a cold replica visibly costs the latency tier.
TRACE_COST_S = 0.04

# scenario -> (stream factory, injected failure/drain or None)
SCENARIOS = {
    "bursty": (bursty_stream, None),
    "bursty+fail": (bursty_stream,
                    FailureEvent(at=0.35, rid="r0", kind="fail",
                                 revive_after=0.12)),
    "diurnal+drain": (diurnal_stream,
                      FailureEvent(at=0.2, rid="r0", kind="drain",
                                   revive_after=0.2)),
}


def run_one(scenario: str, policy: str, n_replicas: int = N_REPLICAS,
            trace_dir: pathlib.Path | None = None) -> dict:
    """One (scenario, policy) fleet run.  With ``trace_dir`` set, every
    replica streams its trace to ``<dir>/<scenario>-<policy>-<rid>.jsonl``
    and the router folds into ``...-router.jsonl`` — the files CI's
    schema gate validates; otherwise the streams stay in memory
    (``RecordingTracker``), byte-identical fold semantics."""
    gen, failure = SCENARIOS[scenario]
    reqs = [dataclasses.replace(r) for r in gen()]
    tag = scenario.replace("+", "_")
    with contextlib.ExitStack() as stack:
        if trace_dir is not None:
            trace_dir.mkdir(parents=True, exist_ok=True)
            paths = [trace_dir / f"{tag}-{policy}-r{k}.jsonl"
                     for k in range(n_replicas)]
            router_trk = stack.enter_context(
                JsonlTracker(trace_dir / f"{tag}-{policy}-router.jsonl"))
        else:
            paths = [None] * n_replicas
            router_trk = None
        replicas = [Replica.sim(f"r{k}", paths[k]) for k in range(n_replicas)]
        for rep in replicas:
            if isinstance(rep.tracker, JsonlTracker):
                stack.enter_context(rep.tracker)
        router = FleetRouter(replicas, policy=policy, tracker=router_trk)
        stats = run_fleet(reqs, router, trace_cost_s=TRACE_COST_S,
                          failure=failure)
        stats["_router"] = router  # smoke asserts inspect the folded view
        stats["_replicas"] = replicas
    return stats


@functools.lru_cache(maxsize=1)
def _sweep() -> dict:
    """Every (scenario, policy) cell — deterministic, so memoized (run(),
    records() and the smoke asserts all consume it)."""
    return {(sc, pol): run_one(sc, pol)
            for sc in SCENARIOS for pol in POLICIES}


_METRIC_KEYS = ("pad_tokens", "real_tokens", "batches", "max_wait",
                "sla_miss", "sla_met", "sla_total", "served", "preemptions",
                "makespan_s", "sla_met_frac", "spills", "repartitions",
                "requeued", "traces")


def _metrics(s: dict) -> dict:
    return {k: s[k] for k in _METRIC_KEYS}


def _cell_row(scenario: str, policy: str, s: dict) -> str:
    return row(
        f"fleet_sweep/R{N_REPLICAS}/{scenario}/{policy}",
        s["makespan_s"] * 1e6,
        f"sla_met_frac={s['sla_met_frac']:.3f},served={s['served']},"
        f"batches={s['batches']},traces={s['traces']},"
        f"spills={s['spills']},requeued={s['requeued']},"
        f"max_wait_s={s['max_wait']:.2f}")


def run() -> list[str]:
    sweep = _sweep()
    rows = [_cell_row(sc, pol, sweep[(sc, pol)])
            for sc in SCENARIOS for pol in POLICIES]
    rr, warm = sweep[("bursty", "round_robin")], sweep[("bursty", "warmth")]
    rows.append(row(
        f"fleet_sweep/R{N_REPLICAS}/bursty/uplift",
        (warm["sla_met_frac"] - rr["sla_met_frac"]) * 1e6,
        f"sla_met_frac={rr['sla_met_frac']:.3f}->{warm['sla_met_frac']:.3f},"
        f"traces={rr['traces']}->{warm['traces']}"))
    return rows


def records() -> list[dict]:
    """Structured BENCH_fleet_sweep.json records: one per (scenario,
    policy) cell, same per-replica cluster fields as sched_sweep plus
    the fleet width."""
    sweep = _sweep()
    return [{
        "name": f"fleet_sweep/R{N_REPLICAS}/{sc}/{pol}",
        "policy": pol,
        "scenario": sc,
        "n_replicas": N_REPLICAS,
        "n_machines": N_MACHINES,
        "m_per_machine": M_PER_MACHINE,
        "dp": DP,
        "metrics": _metrics(sweep[(sc, pol)]),
        "measured_step_us": None,
    } for sc in SCENARIOS for pol in POLICIES]


# ---------------------------------------------------------------------------
# --smoke: acceptance asserts + schema-valid shipped traces
# ---------------------------------------------------------------------------

def _assert_uplift() -> list[str]:
    """ISSUE-9 acceptance: warm-cache affinity beats round_robin on
    SLA-met fraction for the bursty mixed-resolution scenario — and
    STRICTLY with the replica failure injected — while serving every
    request on every policy, failover included."""
    sweep = _sweep()
    for (sc, pol), s in sweep.items():
        assert s["served"] == sweep[(sc, "round_robin")]["served"] > 0, (
            sc, pol, s["served"])
    rr, warm = sweep[("bursty", "round_robin")], sweep[("bursty", "warmth")]
    assert warm["sla_met_frac"] >= rr["sla_met_frac"], (
        warm["sla_met_frac"], rr["sla_met_frac"])
    assert warm["traces"] < rr["traces"], (warm["traces"], rr["traces"])
    frr = sweep[("bursty+fail", "round_robin")]
    fwarm = sweep[("bursty+fail", "warmth")]
    assert fwarm["sla_met_frac"] > frr["sla_met_frac"], (
        fwarm["sla_met_frac"], frr["sla_met_frac"])
    assert fwarm["requeued"] > 0, "failure never evacuated a queue"
    return [f"uplift: bursty sla_met {rr['sla_met_frac']:.3f} -> "
            f"{warm['sla_met_frac']:.3f} "
            f"(traces {rr['traces']} -> {warm['traces']}); +fail "
            f"{frr['sla_met_frac']:.3f} -> {fwarm['sla_met_frac']:.3f} "
            f"({fwarm['requeued']} requeued)"]


def _assert_fold_sums() -> list[str]:
    """The router's folded view must SUM per-replica counters (the
    metrics.replay clobber bug this PR fixes) and keep per-replica tag
    namespaces: router totals == sum over replicas of each replica's own
    aggregate, per counter."""
    sweep = _sweep()
    s = sweep[("bursty+fail", "warmth")]
    router, replicas = s["_router"], s["_replicas"]
    for name in ("sched.submitted", "sched.admissions",
                 "plan_cache.step_miss", "replica.served"):
        per_replica = sum(rep.tracker.counter_total(name)
                          for rep in replicas)
        folded = router.tracker.counter_total(name)
        assert folded == per_replica, (name, folded, per_replica)
        for rep in replicas:
            mine = sum(v for tags, v in
                       router.tracker.counter_items(name)
                       if tags.get("replica") == rep.rid)
            assert mine == rep.tracker.counter_total(name), (
                name, rep.rid, mine)
    return [f"fold: router counter totals == per-replica sums "
            f"(submitted={int(router.tracker.counter_total('sched.submitted'))}"
            f" across {len(replicas)} replicas)"]


def _assert_shipped_traces(trace_dir: pathlib.Path) -> list[str]:
    """Re-run one cell with JSONL sinks: every per-replica trace and the
    router's folded trace must be schema-valid (read back with
    ``validate=True``), and the folded totals must match a direct fold
    of the files."""
    from repro.serving.metrics import replay

    run_one("bursty+fail", "warmth", trace_dir=trace_dir)
    files = sorted(trace_dir.glob("bursty_fail-warmth-*.jsonl"))
    assert len(files) == N_REPLICAS + 1, files
    total = 0
    submitted = 0.0
    for f in files:
        recs = read_jsonl(f, validate=True)
        assert recs, f
        total += len(recs)
        if "router" not in f.name:
            submitted += replay(recs).counter_total("sched.submitted")
    folded = replay(read_jsonl(
        trace_dir / "bursty_fail-warmth-router.jsonl", validate=True))
    assert folded.counter_total("sched.submitted") == submitted, (
        folded.counter_total("sched.submitted"), submitted)
    return [f"traces: {len(files)} schema-valid JSONL streams "
            f"({total} records), replayed folded submitted == "
            f"per-replica sum ({int(submitted)})"]


def main(argv: list[str] | None = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="assert the acceptance claims")
    ap.add_argument("--trace-dir", type=pathlib.Path, default=None,
                    help="retain per-replica + router-folded JSONL traces "
                         "here (for scripts/check_metrics_schema.py)")
    args = ap.parse_args(sys.argv[1:] if argv is None else argv)

    for line in run():
        print(line)
    if args.smoke or args.trace_dir is not None:
        with contextlib.ExitStack() as stack:
            td = args.trace_dir
            if td is None:
                td = pathlib.Path(stack.enter_context(
                    tempfile.TemporaryDirectory()))
            msgs = []
            if args.smoke:
                msgs += _assert_uplift()
                msgs += _assert_fold_sums()
            msgs += _assert_shipped_traces(td)
            for m in msgs:
                print(f"# {m}", file=sys.stderr)
        if args.smoke:
            print("# fleet_sweep smoke OK", file=sys.stderr)


if __name__ == "__main__":
    main()
