"""Minimal dependency-free checkpointing: pytrees <-> an .npz + JSON treedef.

Handles params, optimizer state, and step counters.  Arrays are pulled to
host (fully replicated read-back) — fine for the ~100M example runs this
repo trains; a production deployment would swap in tensorstore behind the
same interface.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(x) for x in leaves], treedef


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = _flatten(tree)
    # bf16 isn't natively storable in npz; view as uint16 with a dtype tag
    arrays, dtypes = {}, []
    for i, a in enumerate(leaves):
        if a.dtype == jnp.bfloat16:
            arrays[f"a{i}"] = a.view(np.uint16)
            dtypes.append("bfloat16")
        else:
            arrays[f"a{i}"] = a
            dtypes.append(str(a.dtype))
    np.savez(path + ".npz", **arrays)
    with open(path + ".tree.json", "w") as f:
        json.dump({"treedef": str(treedef), "n": len(leaves), "dtypes": dtypes}, f)


def load(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    data = np.load(path + ".npz")
    with open(path + ".tree.json") as f:
        meta = json.load(f)
    leaves_like, treedef = jax.tree.flatten(like)
    assert meta["n"] == len(leaves_like), "checkpoint/model structure mismatch"
    out = []
    for i, ref in enumerate(leaves_like):
        a = data[f"a{i}"]
        if meta["dtypes"][i] == "bfloat16":
            a = a.view(jnp.bfloat16)
        assert a.shape == ref.shape, (i, a.shape, ref.shape)
        out.append(jnp.asarray(a))
    return jax.tree.unflatten(treedef, out)


def exists(path: str) -> bool:
    return os.path.exists(path + ".npz") and os.path.exists(path + ".tree.json")
