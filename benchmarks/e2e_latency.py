"""Paper Fig. 7: end-to-end one-sampling-step latency, USP vs TAS vs SFU at
each method's optimal distributed configuration, M = 1..4 machines.

Latency from the calibrated two-level network model; derived column shows
speedup over USP (the paper reports TAS 1.27x, SFU 1.35x mean on >2
machines — asserted directionally in tests/test_comm_model.py).

``python -m benchmarks.e2e_latency --calibration fit.json`` swaps the
nominal testbed constants for parameters fitted from recorded
BENCH_*.json measurements by ``scripts/calibrate_comm.py``.
"""
from __future__ import annotations

import argparse

from repro.configs import get_config
from repro.core import plan, usp_plan
from repro.core.comm_model import (
    LayerWorkload,
    NetworkModel,
    attention_layer_latency,
    load_network_model,
)

from .common import row

M_PER = 8
WORKLOADS = {
    "flux_3072": ("flux-12b", 36_864, 1),
    "flux_4096": ("flux-12b", 65_536, 1),
    "cogvideox_20s": ("cogvideox-5b", 49_152, 1),
    "cogvideox_40s": ("cogvideox-5b", 98_304, 1),
}


def _layer_latency(arch, seq, batch, n, method, net: NetworkModel):
    cfg = get_config(arch)
    wl = LayerWorkload(batch=batch, seq=seq, heads=cfg.n_heads,
                       head_dim=cfg.resolved_head_dim)
    if method == "usp":
        p = usp_plan(n, M_PER, cfg.n_heads)
        r = attention_layer_latency(p, wl, net, swift=False,
                                    overlap_inter=False)
    elif method == "tas":
        p = plan(n, M_PER, cfg.n_heads)
        r = attention_layer_latency(p, wl, net, swift=True,
                                    overlap_inter=False)
    else:  # sfu = tas + torus overlap + one-sided
        p = plan(n, M_PER, cfg.n_heads)
        r = attention_layer_latency(p, wl, net, swift=True,
                                    overlap_inter=True)
    return r["t_total"]


def run(net: NetworkModel | None = None) -> list[str]:
    net = net or NetworkModel()
    rows = []
    for wname, (arch, seq, batch) in WORKLOADS.items():
        cfg = get_config(arch)
        for n in (1, 2, 3, 4):
            base = _layer_latency(arch, seq, batch, n, "usp", net) * cfg.n_layers
            for method in ("usp", "tas", "sfu"):
                t = _layer_latency(arch, seq, batch, n, method, net) * cfg.n_layers
                sp = base / t if t else 0.0
                rows.append(row(f"e2e/{wname}/M{n}/{method}", t * 1e6,
                                f"speedup_vs_usp={sp:.2f}x"))
    return rows


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--calibration", default=None, metavar="JSON",
                    help="NetworkModel JSON from scripts/calibrate_comm.py; "
                         "prints calibrated instead of nominal predictions")
    args = ap.parse_args(argv)
    net = load_network_model(args.calibration) if args.calibration else None
    print("name,us_per_call,derived")
    for line in run(net):
        print(line)


if __name__ == "__main__":
    main()
