"""Flow-matching Euler sampler for DiT serving (paper Figure 1 pipeline).

One sampling step = one full DiT forward (velocity prediction) — this is
the unit the paper benchmarks ("latency of one sampling step").  The
sampler integrates x_t from t=1 (noise) to t=0 (data) with uniform Euler
steps; the toy linear VAE decode is the stubbed frontend inverse
(DESIGN.md §6).

Beyond the paper, the sampler composes two extra parallel axes with SP
(DESIGN.md §7):

  * **CFG parallelism** (``SamplerConfig.cfg_parallel``): with guidance
    enabled, the conditional and unconditional branches are stacked on the
    batch dim and — when the mesh carries ``SPConfig.cfg_axis`` — sharded
    across a 2-way mesh axis, so each half of the mesh runs one branch.
    The branches recombine with a single psum-style weighted sum of the
    velocities (``v = g·v_cond + (1-g)·v_uncond``), the only cross-branch
    communication of the whole step.
  * **Displaced patch pipelining** (``SamplerConfig.pipeline``): after
    ``warmup_steps`` synchronous steps, each step runs the PipeFusion
    forward (models/dit.py: ``dit_forward_displaced``) reusing
    one-step-stale KV for non-resident patches; the sampler threads the
    per-layer KVState across steps.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.pipefusion import KVState, PipelineConfig, init_kv_state
from ..models import ParallelContext
from ..models.dit import (
    COND_TOKENS,
    LATENT_CHANNELS,
    dit_forward,
    dit_forward_displaced,
)


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    num_steps: int = 20
    guidance_scale: float = 1.0  # >1 enables classifier-free guidance
    # hybrid parallelism (DESIGN.md §7); both compose with any SP strategy
    cfg_parallel: bool = False  # evaluate the CFG pair on the cfg mesh axis
    pipeline: PipelineConfig | None = None  # patch-level pipelining

    @property
    def guided(self) -> bool:
        return self.guidance_scale != 1.0

    @property
    def pipelined(self) -> bool:
        return self.pipeline is not None and self.pipeline.enabled


def _cfg_recombine(v_pair: jax.Array, batch: int, g: float) -> jax.Array:
    """The single cross-branch exchange: v = g·v_cond + (1-g)·v_uncond.

    Written as a weighted sum (not ``v_u + g (v_c - v_u)``) so with the
    pair sharded over the cfg axis it lowers to exactly one psum-sized
    collective of the velocity tensor.
    """
    v_c, v_u = v_pair[:batch], v_pair[batch:]
    return g * v_c + (1.0 - g) * v_u


def _stack_cfg_pair(x_t, cond):
    """[B,...] -> [2B,...]: conditional branch first, unconditional second."""
    return (jnp.concatenate([x_t, x_t], axis=0),
            jnp.concatenate([cond, jnp.zeros_like(cond)], axis=0))


def _ctx_for(ctx: ParallelContext, sc: SamplerConfig) -> ParallelContext:
    """Drop the cfg mesh axis from the sharding specs unless this sampler
    config actually stacks the CFG pair — otherwise the un-doubled batch
    cannot be sharded over the 2-way cfg axis (shard_map divisibility)."""
    if ctx.sp.cfg_axis and not (sc.guided and sc.cfg_parallel):
        return dataclasses.replace(
            ctx, sp=dataclasses.replace(ctx.sp, cfg_axis=None))
    return ctx


def sample_step(params, cfg: ModelConfig, ctx: ParallelContext,
                x_t: jax.Array, cond: jax.Array, t: jax.Array,
                dt: jax.Array, sc: SamplerConfig) -> jax.Array:
    """One Euler step x_{t-dt} = x_t - dt * v(x_t, t)."""
    ctx = _ctx_for(ctx, sc)
    b = x_t.shape[0]
    tt = jnp.full((b,), t, jnp.float32)
    if sc.guided and sc.cfg_parallel:
        lat2, cond2 = _stack_cfg_pair(x_t, cond)
        v2 = dit_forward(params, cfg, ctx, latents=lat2, cond=cond2,
                         timesteps=jnp.concatenate([tt, tt]))
        v = _cfg_recombine(v2, b, sc.guidance_scale)
        return x_t - dt * v.astype(x_t.dtype)
    v = dit_forward(params, cfg, ctx, latents=x_t, cond=cond, timesteps=tt)
    if sc.guided:
        v_un = dit_forward(params, cfg, ctx, latents=x_t,
                           cond=jnp.zeros_like(cond), timesteps=tt)
        v = v_un + sc.guidance_scale * (v - v_un)
    return x_t - dt * v.astype(x_t.dtype)


# ---------------------------------------------------------------------------
# hybrid (cfg-parallel × patch-pipelined) stepping with threaded KV state
# ---------------------------------------------------------------------------

def hybrid_state_shape(cfg: ModelConfig, batch: int, seq_len: int,
                       sc: SamplerConfig) -> KVState:
    """Zero KVState matching what the hybrid steps thread (cfg pair incl.)."""
    b = 2 * batch if (sc.guided and sc.cfg_parallel) else batch
    return init_kv_state(cfg.n_layers, b, COND_TOKENS + seq_len,
                         cfg.n_kv_heads, cfg.resolved_head_dim,
                         jnp.dtype(cfg.dtype))


def hybrid_sample_step(params, cfg: ModelConfig, ctx: ParallelContext,
                       x_t: jax.Array, cond: jax.Array, t: jax.Array,
                       dt: jax.Array, sc: SamplerConfig, state: KVState,
                       *, warm: bool) -> tuple[jax.Array, KVState]:
    """One Euler step that also threads the displaced-pipeline KV state.

    ``warm`` (static): True runs the fully-synchronous forward — identical
    computation to ``sample_step``'s x-path — while capturing per-layer KV;
    False runs the PipeFusion displaced forward against ``state``.
    """
    assert sc.pipelined
    ctx = _ctx_for(ctx, sc)
    pipe = sc.pipeline
    b = x_t.shape[0]
    tt = jnp.full((b,), t, jnp.float32)
    if sc.guided and sc.cfg_parallel:
        lat_in, cond_in = _stack_cfg_pair(x_t, cond)
        tt_in = jnp.concatenate([tt, tt])
    elif sc.guided:
        raise NotImplementedError(
            "pipelined sampling with sequential CFG would need two KV "
            "states; enable cfg_parallel (works on any mesh) instead")
    else:
        lat_in, cond_in, tt_in = x_t, cond, tt

    if warm:
        v_out, state = dit_forward(params, cfg, ctx, latents=lat_in,
                                   cond=cond_in, timesteps=tt_in,
                                   return_layer_kv=True)
    else:
        v_out, state = dit_forward_displaced(
            params, cfg, ctx, latents=lat_in, cond=cond_in, timesteps=tt_in,
            kv_state=state, num_patches=pipe.patches, pp=pipe.pp)
    if sc.guided and sc.cfg_parallel:
        v = _cfg_recombine(v_out, b, sc.guidance_scale)
    else:
        v = v_out
    return x_t - dt * v.astype(x_t.dtype), state


def sample(params, cfg: ModelConfig, ctx: ParallelContext, *,
           key: jax.Array, batch: int, seq_len: int, cond: jax.Array,
           sc: SamplerConfig = SamplerConfig(),
           step_fn=None) -> jax.Array:
    """Full sampling loop; returns final latents [B, T, LATENT_CHANNELS].

    With ``sc.pipeline`` set, the loop threads the displaced-pipeline KV
    state: the first ``warmup_steps`` steps run synchronously, the rest
    displaced (PipeFusion).  A custom ``step_fn`` bypasses all of that.
    """
    x = jax.random.normal(key, (batch, seq_len, LATENT_CHANNELS), cfg.dtype)
    dt = 1.0 / sc.num_steps
    if step_fn is not None:
        for i in range(sc.num_steps):
            x = step_fn(x, cond, 1.0 - i * dt)
        return x
    if not sc.pipelined:
        for i in range(sc.num_steps):
            x = sample_step(params, cfg, ctx, x, cond, 1.0 - i * dt, dt, sc)
        return x
    state = hybrid_state_shape(cfg, batch, seq_len, sc)
    for i in range(sc.num_steps):
        warm = i < sc.pipeline.warmup_steps
        x, state = hybrid_sample_step(params, cfg, ctx, x, cond,
                                      1.0 - i * dt, dt, sc, state, warm=warm)
    return x


def toy_vae_decode(latents: jax.Array, out_channels: int = 3,
                   patch: int = 2) -> jax.Array:
    """Stub VAE decoder: fixed linear map latent tokens -> pixel patches.
    [B, T, C] -> [B, T * patch**2, out_channels]."""
    b, t, c = latents.shape
    key = jax.random.PRNGKey(42)  # fixed decoder
    w = jax.random.normal(key, (c, patch * patch * out_channels), latents.dtype)
    px = jnp.einsum("btc,cp->btp", latents, w) / (c ** 0.5)
    return px.reshape(b, t * patch * patch, out_channels)
