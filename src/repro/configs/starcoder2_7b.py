"""starcoder2-7b [dense] — GQA kv=4, RoPE, sliding-window 4096
[arXiv:2402.19173]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab=49152,
    rope="rope",
    rope_theta=1e5,
    qkv_bias=True,
    act="gelu",
    norm="layernorm",
    window=4096,  # SWA makes long_500k natively sub-quadratic
    sharding_overrides=(("mlp", ("data",)), ("vocab", ("data",))),
    citation="arXiv:2402.19173",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab=512,
        window=16,
        sharding_overrides=(),
    )
