"""Hierarchical-vs-flat a2a sweep (DESIGN.md §8.2): predicted per-step
serving latency of the flat staged Ulysses all-to-all against the
two-level (intra-machine exchange + staged inter-machine hops)
decomposition, and against the two-level path with fp8 wire compression
on the inter-machine leg, at 2–4 machines.

Both paths move the SAME inter-machine volume — the hierarchical win is
the message-count term (N - 1 paced inter hops instead of P_u - 1, each
paying ``NetworkModel.inter_hop_lat``) plus, with ``a2a_wire_dtype``,
halved wire bytes at the price of a codec term.  The sweep therefore
separates regimes honestly: hierarchy pays ~N× more NVLink traffic on
the fast leg, so single-machine or P_u = N topologies (where it cannot
engage) and bandwidth-dominated regimes show parity, while deep-Ulysses
multi-machine topologies show the win.

Rows: ``hier_a2a_sweep/<wl>/N<n>/<variant>`` with us = predicted step
latency and derived = the per-leg split plus speedup over flat.  The
final row per bucket, ``.../planner``, reports which variant
``plan_for_shape`` actually selects for that (workload, N) — the
regression surface for "the planner picks hierarchical where it should".
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.core.comm_model import (
    LayerWorkload,
    NetworkModel,
    hierarchical_applicable,
    load_network_model,
    plan_step_latency,
)
from repro.core.planner import plan_for_shape, plan_hybrid

from .common import row

# Same paper geometries as hybrid_sweep, trimmed to the buckets where the
# Ulysses degree is deep enough for hierarchy to be non-trivial.
WORKLOADS = {
    "flux_2048": (LayerWorkload(batch=1, seq=16_384, heads=24, head_dim=128), 96),
    "flux_3072": (LayerWorkload(batch=1, seq=36_864, heads=24, head_dim=128), 96),
    "cogvideox_20s": (LayerWorkload(batch=1, seq=49_152, heads=24, head_dim=64), 42),
}
M_PER_MACHINE = 8
MACHINES = (2, 3, 4)
WIRE = "float8_e4m3fn"


def _variants(n: int, wl: LayerWorkload, n_layers: int):
    flat = plan_hybrid(n, M_PER_MACHINE, wl.heads, n_layers=n_layers)
    out = [("flat", flat)]
    if hierarchical_applicable(flat.sp):
        out.append(("hier", dataclasses.replace(flat, hier_a2a=True)))
        out.append(("hier_fp8", dataclasses.replace(
            flat, hier_a2a=True, a2a_wire_dtype=WIRE)))
    return out


def _sweep(net: NetworkModel | None = None):
    net = net or NetworkModel()
    for wname, (wl, n_layers) in WORKLOADS.items():
        for n in MACHINES:
            preds = []
            for vname, h in _variants(n, wl, n_layers):
                pred = plan_step_latency(h, wl, net, n_layers=n_layers,
                                         guided=True)
                preds.append((vname, h, pred))
            best, best_pred = plan_for_shape(
                n, M_PER_MACHINE, wl.heads, seq=wl.seq, batch=wl.batch,
                head_dim=wl.head_dim, n_layers=n_layers, net=net,
                a2a_wire_dtype=WIRE)
            yield wname, n, wl, n_layers, preds, (best, best_pred)


def run(net: NetworkModel | None = None) -> list[str]:
    rows = []
    for wname, n, wl, n_layers, preds, (best, best_pred) in _sweep(net):
        base = preds[0][2]["t_step"]  # flat
        for vname, h, pred in preds:
            rows.append(row(
                f"hier_a2a_sweep/{wname}/N{n}/{vname}",
                pred["t_step"] * 1e6,
                f"Pu={h.sp.p_ulysses},Pr={h.sp.p_ring},"
                f"speedup={base / pred['t_step']:.3f}x"))
        chosen = ("hier_fp8" if best.a2a_wire_dtype else
                  "hier" if best.hier_a2a else "flat")
        rows.append(row(
            f"hier_a2a_sweep/{wname}/N{n}/planner",
            best_pred["t_step"] * 1e6,
            f"picks={chosen},cfg={best.cfg},pp={best.pp},"
            f"Pu={best.sp.p_ulysses},Pr={best.sp.p_ring}"))
    return rows


def records(net: NetworkModel | None = None) -> list[dict]:
    """BENCH_hier_a2a_sweep.json: one record per (bucket, variant) with
    the per-leg latency breakdown (t_a2a_inter / t_a2a_intra /
    t_ring_inter / t_ring_intra / t_codec — no single-blob a2a term) plus
    one ``planner`` record per bucket naming the selected variant."""
    out = []
    for wname, n, wl, n_layers, preds, (best, best_pred) in _sweep(net):
        for vname, h, pred in preds:
            out.append({
                "name": f"hier_a2a_sweep/{wname}/N{n}/{vname}",
                "workload": {"batch": wl.batch, "seq": wl.seq,
                             "heads": wl.heads, "head_dim": wl.head_dim,
                             "n_layers": n_layers},
                "n_machines": n,
                "m_per_machine": M_PER_MACHINE,
                "plan": {"cfg": h.cfg, "pp": h.pp,
                         "p_ulysses": h.sp.p_ulysses,
                         "p_ring": h.sp.p_ring,
                         "hier_a2a": h.hier_a2a,
                         "a2a_wire_dtype": h.a2a_wire_dtype},
                "predicted_step_us": pred["t_step"] * 1e6,
                "predicted_breakdown": {k: v for k, v in pred.items()
                                        if k != "t_step"},
                "overlap_efficiency": pred.get("overlap_efficiency"),
                "measured_step_us": None,
            })
        out.append({
            "name": f"hier_a2a_sweep/{wname}/N{n}/planner",
            "n_machines": n,
            "m_per_machine": M_PER_MACHINE,
            "picked": {"cfg": best.cfg, "pp": best.pp,
                       "p_ulysses": best.sp.p_ulysses,
                       "p_ring": best.sp.p_ring,
                       "hier_a2a": best.hier_a2a,
                       "a2a_wire_dtype": best.a2a_wire_dtype},
            "predicted_step_us": best_pred["t_step"] * 1e6,
            "measured_step_us": None,
        })
    return out


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--calibration", default=None, metavar="JSON",
                    help="NetworkModel JSON from scripts/calibrate_comm.py")
    args = ap.parse_args(argv)
    net = load_network_model(args.calibration) if args.calibration else None
    print("name,us_per_call,derived")
    for line in run(net):
        print(line)


if __name__ == "__main__":
    main()
