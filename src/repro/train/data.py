"""Deterministic synthetic data pipeline.

Produces batches matching the registry's input_specs: a seeded, stateless
stream (step -> batch), so multi-host dataloading is trivially consistent
(every host computes the same global batch and jit's in_shardings slice
it).  Token streams are a mixed Zipf/ngram synthetic language so that the
LM loss actually decreases during the example training runs (pure uniform
noise would pin loss at log V).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..configs.shapes import InputShape
from ..models.registry import get_model


@dataclasses.dataclass(frozen=True)
class SyntheticStream:
    cfg: ModelConfig
    shape: InputShape
    seed: int = 0

    def _tokens(self, rng: np.random.Generator, b: int, l: int) -> np.ndarray:
        v = max(self.cfg.vocab, 4)
        # order-1 markov chain with shared transition structure: next token
        # depends on current via a fixed random permutation + noise.
        perm = np.random.default_rng(self.seed).permutation(v)
        x = np.empty((b, l + 1), np.int32)
        x[:, 0] = rng.integers(0, v, size=b)
        noise = rng.random((b, l))
        jump = rng.integers(0, v, size=(b, l))
        for t in range(l):
            nxt = perm[x[:, t]]
            x[:, t + 1] = np.where(noise[:, t] < 0.8, nxt, jump[:, t])
        return x

    def batch(self, step: int):
        rng = np.random.default_rng(self.seed * 100003 + step)
        bundle = get_model(self.cfg)
        spec = bundle.input_specs(self.cfg, self.shape, abstract=True)
        out = {}
        if "tokens" in spec and "labels" in spec:
            b, l = spec["tokens"].shape
            seq = self._tokens(rng, b, l)
            out["tokens"] = jnp.asarray(seq[:, :-1])
            out["labels"] = jnp.asarray(seq[:, 1:])
        for name, s in spec.items():
            if name in out:
                continue
            if np.issubdtype(np.dtype(s.dtype), np.integer):
                if name == "positions":
                    base = np.broadcast_to(np.arange(s.shape[-1], dtype=np.int32),
                                           s.shape)
                    out[name] = jnp.asarray(base)
                else:
                    out[name] = jnp.asarray(
                        rng.integers(0, max(self.cfg.vocab, 2), size=s.shape,
                                     dtype=np.int32))
            else:
                out[name] = jnp.asarray(
                    rng.standard_normal(s.shape).astype(np.float32) * 0.02
                ).astype(s.dtype)
        return out
