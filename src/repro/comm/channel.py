"""One-sided channel primitive (DESIGN.md §8): NVSHMEM put/signal/wait
semantics expressed in XLA terms.

The paper's runtime moves every tensor with one-sided NVSHMEM puts: the
sender writes straight into the receiver's buffer (no rendezvous), sets a
signal flag, and the receiver spin-waits on the flag only when it actually
needs the data — so the transfer rides a communication stream while SMs
keep computing.  On TPU-style backends the same three verbs map onto XLA
primitives:

    put     -> ``lax.ppermute``: lowered to collective-permute-start/done
               executed by the DMA engines; the latency-hiding scheduler
               hoists the start above independent compute, which is the
               moral equivalent of issuing the put on a comm stream.
               (The Pallas lowering is ``pltpu.make_async_remote_copy`` +
               ``rdma.start()``; this layer stays at the XLA level.)
    signal  -> the data dependency on the permute's result: XLA's done op
               plays the role of the flag write, so no separate flag
               tensor is materialised.
    wait    -> ``optimization_barrier``: pins *when* the received buffer
               may be consumed relative to other live values, without
               making the transfer itself depend on them — the receiver-
               side spin-wait, minus the spinning.

A ``Channel`` is a fixed (mesh axes, permutation) route — the double
buffer: every ``put`` returns an ``InFlight`` handle whose payload is the
receive buffer, and the caller decides when to ``wait`` on it.  Streams
(stream.py) compose channels into staged transfer programs; trace.py
records every put and validates the intended overlap against compiled HLO.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
from jax import lax

from ..compat import optimization_barrier
from . import profiler as _profiler
from . import trace as _trace

__all__ = ["Channel", "InFlight", "fence", "pin", "ring_perm_of",
           "shift_perm"]


def shift_perm(size: int, shift: int = 1) -> tuple[tuple[int, int], ...]:
    """Rotation permutation: rank r -> (r + shift) % size."""
    return tuple((r, (r + shift) % size) for r in range(size))


def ring_perm_of(layout: Any, shift: int = 1) -> tuple[tuple[int, int], ...]:
    """The layout's intra-ring rotation as a hashable perm table."""
    return tuple(layout.ring_perm(shift))


@dataclasses.dataclass(frozen=True)
class Channel:
    """A fixed one-sided route: ``put`` moves tensors one hop along
    ``perm`` over the named mesh ``axes``.

    Channels are cheap value objects — construct them per schedule stage;
    the name only matters for trace/debug output.  ``backend`` selects the
    lowering: ``"xla"`` (ppermute + optimization_barrier, overlap left to
    XLA's scheduler) or ``"pallas"`` (in-kernel DMA + explicit semaphores,
    DESIGN.md §8.1); ``interpret`` runs the Pallas branch in interpreter
    mode (the CPU CI path).
    """

    axes: tuple[str, ...]
    perm: tuple[tuple[int, int], ...]
    name: str = "chan"
    stream: str = ""  # owning Stream name (trace bookkeeping)
    stage: int = 0  # stage index within the stream program
    backend: str = "xla"  # "xla" | "pallas"
    interpret: bool = True  # Pallas branch: interpreter mode (CPU CI)

    def __post_init__(self):
        assert self.backend in ("xla", "pallas"), self.backend

    def put(self, *tensors: jax.Array, overlaps: str = "") -> "InFlight":
        """Issue the one-sided transfer of ``tensors`` (start the DMA).

        Multiple tensors ride the same route in one put (K and V travel
        together).  ``overlaps`` names the compute this transfer is meant
        to hide behind; trace validation asserts the compiled HLO admits
        it.  The returned handle's payload is the *received* buffer — in
        SPMD every rank is simultaneously the sender and the receiver of
        its neighbour's put.
        """
        if self.backend == "pallas":
            return self._put_pallas(tensors, overlaps)
        meta = self._leg_meta(tensors, overlaps, "xla")
        if meta is not None:
            _profiler.mark(_profiler.active(), meta, "issue", tensors)
        perm = list(self.perm)
        out = tuple(lax.ppermute(t, self.axes, perm=perm) for t in tensors)
        _trace.emit(_trace.TransferEvent(
            stream=self.stream, channel=self.name, stage=self.stage,
            axes=tuple(self.axes), perm=tuple(self.perm),
            shape=tuple(tensors[0].shape), n_tensors=len(tensors),
            overlaps=overlaps, backend="xla"))
        if meta is not None:
            _profiler.mark(_profiler.active(), meta, "signal", out)
        return InFlight(channel=self, payload=out, meta=meta)

    def _leg_meta(self, tensors: tuple[jax.Array, ...], overlaps: str,
                  backend: str) -> Any:
        """Mint the runtime-profiler leg identity for one put, or None
        when no profiler is active at trace time (zero-cost default)."""
        prof = _profiler.active()
        if prof is None:
            return None
        return prof.new_leg(
            kind="comm", stream=self.stream, channel=self.name,
            stage=self.stage, axes=tuple(self.axes),
            nbytes=_profiler.nbytes_of(tensors), n_tensors=len(tensors),
            backend=backend, intent=overlaps)

    def _put_pallas(self, tensors: tuple[jax.Array, ...],
                    overlaps: str) -> "InFlight":
        """Pallas lowering: semaphore-tracked delivery (DESIGN.md §8.1)."""
        from . import pallas_backend as _pb

        sem = _pb.new_sem(self.name, self.stage)
        meta = self._leg_meta(tensors, overlaps, "pallas")
        if meta is not None:
            _profiler.mark(_profiler.active(), meta, "issue", tensors)
        _trace.emit(_trace.TransferEvent(
            stream=self.stream, channel=self.name, stage=self.stage,
            axes=tuple(self.axes), perm=tuple(self.perm),
            shape=tuple(tensors[0].shape), n_tensors=len(tensors),
            overlaps=overlaps, backend="pallas"))
        _trace.emit_sem(_trace.SemEvent(
            kind="put", sem=sem, stream=self.stream, channel=self.name,
            stage=self.stage))
        out = _pb.deliver(tensors, tuple(self.axes), tuple(self.perm),
                          interpret=self.interpret, profile_src=self)
        _trace.emit_sem(_trace.SemEvent(
            kind="signal", sem=sem, stream=self.stream, channel=self.name,
            stage=self.stage))
        if meta is not None:
            # the DMA-semaphore signal: fires once landing_copy delivered
            _profiler.mark(_profiler.active(), meta, "signal", out)
        return InFlight(channel=self, payload=out, sem=sem, meta=meta)

    def put_fused(self, *tensors: jax.Array, overlaps: str = "") -> "InFlight":
        """Deliver a put that was ISSUED inside a fused kernel
        (kernels/ring_flash.py): the kernel already started the copy at
        its first grid step and waited it only after its last compute
        block; ``tensors`` are the forwarded buffers it produced.  This
        records the schedule (put flagged ``overlap=True`` — the
        semaphore validator then requires compute between issue and
        wait) and performs the wire move: the kernel's DMA stages the
        chunk into the forward buffer on the *local* device, so the
        inter-device hop is a ppermute on every branch (DESIGN.md §8.1
        interpret caveats; true in-kernel remote-copy forwarding is the
        ROADMAP hardware item).
        """
        assert self.backend == "pallas", "put_fused is a Pallas-path verb"
        from . import pallas_backend as _pb

        meta = self._leg_meta(tensors, overlaps, "pallas")
        if meta is not None:
            _profiler.mark(_profiler.active(), meta, "issue", tensors)
        sem = _pb.fused_transfer_events(
            self, tuple(tensors[0].shape), len(tensors), overlaps=overlaps)
        # The fused kernel's DMA is a LOCAL make_async_copy into the
        # forward buffer (the RDMA staging step) on every branch, so the
        # wire move is always this ppermute — including on real TPUs.
        # Replacing it with true in-kernel make_async_remote_copy
        # forwarding is the ROADMAP hardware item.
        out = tuple(lax.ppermute(t, self.axes, perm=list(self.perm))
                    for t in tensors)
        _trace.emit_sem(_trace.SemEvent(
            kind="signal", sem=sem, stream=self.stream, channel=self.name,
            stage=self.stage))
        if meta is not None:
            _profiler.mark(_profiler.active(), meta, "signal", out)
        return InFlight(channel=self, payload=out, sem=sem, meta=meta)


@dataclasses.dataclass(frozen=True)
class InFlight:
    """Handle to a put in flight; ``payload`` is the receive buffer."""

    channel: Channel
    payload: tuple[jax.Array, ...]
    sem: str = ""  # semaphore id (Pallas backend only)
    meta: Any = None  # runtime-profiler leg identity (profiling only)

    def wait(self, *deps: jax.Array) -> Any:
        """Signal-wait: deliver the buffer, ordered after ``deps``.

        With no deps this is a plain delivery (the data dependency is the
        signal).  With deps, the received tensors and the deps are fenced
        together so the consumer cannot be scheduled before the deps
        finish — while the transfer start stays independent and hoistable.
        Returns the payload (unpacked when it is a single tensor); with
        deps, returns ``(payload..., deps...)`` all fenced.
        """
        if self.sem:
            _trace.emit_sem(_trace.SemEvent(
                kind="wait", sem=self.sem, stream=self.channel.stream,
                channel=self.channel.name, stage=self.channel.stage))
        if self.meta is not None and _profiler.active() is not None:
            # fires when the receiver's independent compute (the deps) is
            # done and it truly needs the buffer; with no deps the wait
            # is observed at delivery (exposure reads as zero)
            _profiler.mark(_profiler.active(), self.meta, "wait",
                           deps if deps else self.payload)
        if not deps:
            return self.payload[0] if len(self.payload) == 1 else self.payload
        vals, deps_out = fence(self.payload, deps)
        if len(vals) == 1:
            return (vals[0], *deps_out)
        return (*vals, *deps_out)


def fence(tensors: Sequence[jax.Array],
          deps: Sequence[jax.Array]) -> tuple[tuple, tuple]:
    """Joint ordering point: gate ``tensors`` (received or resident
    buffers) on ``deps`` so compute consuming them cannot start before the
    deps complete — the consumer-side wait of the signal protocol.  Values
    that do not pass through the fence (e.g. the next put) stay
    independent and keep overlapping.  Returns (tensors, deps) pinned.
    """
    out = optimization_barrier(tuple(tensors) + tuple(deps))
    n = len(tuple(tensors))
    return out[:n], out[n:]


def pin(xs: Sequence[jax.Array]) -> tuple:
    """Serialise a value chain (e.g. an accumulator) across schedule steps
    so only O(1) intermediates are live — the quiet counterpart of fence.
    """
    return optimization_barrier(tuple(xs))
