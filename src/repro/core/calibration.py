"""NetworkModel least-squares calibration (DESIGN.md §10).

The damped Gauss-Newton fitter that used to live inside
``scripts/calibrate_comm.py`` (PR 3), refactored into an importable
module so the offline CLI and the serving engine's ``OnlineCalibrator``
(serving/sched/control.py) share one implementation: the script fits
recorded ``BENCH_*.json`` records in one shot, the engine refits a
sliding window of its own measured step times in-flight.

Method: Gauss-Newton with Levenberg damping on **log-parameters** with
log-ratio residuals ``log(pred/measured)`` (numpy only — no scipy in the
container).  Log space keeps every parameter positive and makes the fit
scale-free across the many orders of magnitude between bandwidths and
hop latencies; the damping keeps parameters the observations cannot
identify (e.g. intra_bw when every record models intra traffic as
overlapped, or hop latencies in bandwidth-bound configs) pinned near
their starting value instead of wandering.

The fitter is generic over the observation type: ``fit`` takes any
sequence of observations plus a ``predict(obs, net) -> µs`` callable, so
the script's dict records and the engine's (plan, workload, measurement)
tuples go through the same solver.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

from .comm_model import FIT_PARAMS, NetworkModel, fit_param_ratios

__all__ = ["FIT_PARAMS", "FitReport", "fit", "net_from_log_params"]


def net_from_log_params(theta: np.ndarray,
                        base: NetworkModel | None = None) -> NetworkModel:
    """NetworkModel with FIT_PARAMS set from log-space ``theta`` (other
    fields keep ``base``'s values)."""
    return dataclasses.replace(
        base if base is not None else NetworkModel(),
        **{k: float(math.exp(v)) for k, v in zip(FIT_PARAMS, theta)})


@dataclasses.dataclass(frozen=True)
class FitReport:
    n_obs: int
    rms_rel_error: float
    ratio_vs_nominal: dict[str, float]

    def as_dict(self) -> dict:
        # legacy key names kept for calibration JSON / test compatibility
        return {"n_records": self.n_obs,
                "rms_rel_error": self.rms_rel_error,
                "ratio_vs_nominal": dict(self.ratio_vs_nominal)}


def fit(obs: Sequence, predict_us: Callable[[object, NetworkModel], float],
        *, start: NetworkModel | None = None, iters: int = 40,
        damping: float = 1e-3, fd_eps: float = 1e-5
        ) -> tuple[NetworkModel, FitReport]:
    """Least-squares fit of FIT_PARAMS to measured observations.

    Every observation must expose its measurement via
    ``obs.measured_step_us`` or ``obs["measured_step_us"]``; the model's
    prediction for it comes from ``predict_us(obs, net)``.  ``start`` is
    the damping anchor and initial iterate (nominal by default) — the
    online calibrator passes its current fitted model so successive
    refits walk from the last estimate rather than re-fitting from
    nominal every time.
    """
    assert obs, "no observations with a fit target — nothing to fit"

    def measured(o) -> float:
        if isinstance(o, dict):
            return o["measured_step_us"]
        return o.measured_step_us

    base = start if start is not None else NetworkModel()
    theta = np.array([math.log(getattr(base, k)) for k in FIT_PARAMS])

    def residuals(th: np.ndarray) -> np.ndarray:
        net = net_from_log_params(th, base)
        return np.array([
            math.log(predict_us(o, net) / measured(o)) for o in obs])

    r = residuals(theta)
    for _ in range(iters):
        jac = np.empty((len(obs), len(theta)))
        for j in range(len(theta)):
            t2 = theta.copy()
            t2[j] += fd_eps
            jac[:, j] = (residuals(t2) - r) / fd_eps
        a = np.vstack([jac, math.sqrt(damping) * np.eye(len(theta))])
        b = np.concatenate([-r, np.zeros(len(theta))])
        step, *_ = np.linalg.lstsq(a, b, rcond=None)
        if not np.all(np.isfinite(step)):
            break
        theta = theta + step
        r = residuals(theta)
        if np.linalg.norm(step) < 1e-10:
            break
    net = net_from_log_params(theta, base)
    report = FitReport(
        n_obs=len(obs),
        rms_rel_error=float(math.sqrt(float(np.mean(r ** 2)))),
        ratio_vs_nominal=fit_param_ratios(net))
    return net, report
