"""One-sided channel primitive (DESIGN.md §8): NVSHMEM put/signal/wait
semantics expressed in XLA terms.

The paper's runtime moves every tensor with one-sided NVSHMEM puts: the
sender writes straight into the receiver's buffer (no rendezvous), sets a
signal flag, and the receiver spin-waits on the flag only when it actually
needs the data — so the transfer rides a communication stream while SMs
keep computing.  On TPU-style backends the same three verbs map onto XLA
primitives:

    put     -> ``lax.ppermute``: lowered to collective-permute-start/done
               executed by the DMA engines; the latency-hiding scheduler
               hoists the start above independent compute, which is the
               moral equivalent of issuing the put on a comm stream.
               (The Pallas lowering is ``pltpu.make_async_remote_copy`` +
               ``rdma.start()``; this layer stays at the XLA level.)
    signal  -> the data dependency on the permute's result: XLA's done op
               plays the role of the flag write, so no separate flag
               tensor is materialised.
    wait    -> ``optimization_barrier``: pins *when* the received buffer
               may be consumed relative to other live values, without
               making the transfer itself depend on them — the receiver-
               side spin-wait, minus the spinning.

A ``Channel`` is a fixed (mesh axes, permutation) route — the double
buffer: every ``put`` returns an ``InFlight`` handle whose payload is the
receive buffer, and the caller decides when to ``wait`` on it.  Streams
(stream.py) compose channels into staged transfer programs; trace.py
records every put and validates the intended overlap against compiled HLO.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
from jax import lax

from ..compat import optimization_barrier
from . import trace as _trace

__all__ = ["Channel", "InFlight", "fence", "pin", "ring_perm_of",
           "shift_perm"]


def shift_perm(size: int, shift: int = 1) -> tuple[tuple[int, int], ...]:
    """Rotation permutation: rank r -> (r + shift) % size."""
    return tuple((r, (r + shift) % size) for r in range(size))


def ring_perm_of(layout: Any, shift: int = 1) -> tuple[tuple[int, int], ...]:
    """The layout's intra-ring rotation as a hashable perm table."""
    return tuple(layout.ring_perm(shift))


@dataclasses.dataclass(frozen=True)
class Channel:
    """A fixed one-sided route: ``put`` moves tensors one hop along
    ``perm`` over the named mesh ``axes``.

    Channels are cheap value objects — construct them per schedule stage;
    the name only matters for trace/debug output.
    """

    axes: tuple[str, ...]
    perm: tuple[tuple[int, int], ...]
    name: str = "chan"
    stream: str = ""  # owning Stream name (trace bookkeeping)
    stage: int = 0  # stage index within the stream program

    def put(self, *tensors: jax.Array, overlaps: str = "") -> "InFlight":
        """Issue the one-sided transfer of ``tensors`` (start the DMA).

        Multiple tensors ride the same route in one put (K and V travel
        together).  ``overlaps`` names the compute this transfer is meant
        to hide behind; trace validation asserts the compiled HLO admits
        it.  The returned handle's payload is the *received* buffer — in
        SPMD every rank is simultaneously the sender and the receiver of
        its neighbour's put.
        """
        perm = list(self.perm)
        out = tuple(lax.ppermute(t, self.axes, perm=perm) for t in tensors)
        _trace.emit(_trace.TransferEvent(
            stream=self.stream, channel=self.name, stage=self.stage,
            axes=tuple(self.axes), perm=tuple(self.perm),
            shape=tuple(tensors[0].shape), n_tensors=len(tensors),
            overlaps=overlaps))
        return InFlight(channel=self, payload=out)


@dataclasses.dataclass(frozen=True)
class InFlight:
    """Handle to a put in flight; ``payload`` is the receive buffer."""

    channel: Channel
    payload: tuple[jax.Array, ...]

    def wait(self, *deps: jax.Array) -> Any:
        """Signal-wait: deliver the buffer, ordered after ``deps``.

        With no deps this is a plain delivery (the data dependency is the
        signal).  With deps, the received tensors and the deps are fenced
        together so the consumer cannot be scheduled before the deps
        finish — while the transfer start stays independent and hoistable.
        Returns the payload (unpacked when it is a single tensor); with
        deps, returns ``(payload..., deps...)`` all fenced.
        """
        if not deps:
            return self.payload[0] if len(self.payload) == 1 else self.payload
        vals, deps_out = fence(self.payload, deps)
        if len(vals) == 1:
            return (vals[0], *deps_out)
        return (*vals, *deps_out)


def fence(tensors: Sequence[jax.Array],
          deps: Sequence[jax.Array]) -> tuple[tuple, tuple]:
    """Joint ordering point: gate ``tensors`` (received or resident
    buffers) on ``deps`` so compute consuming them cannot start before the
    deps complete — the consumer-side wait of the signal protocol.  Values
    that do not pass through the fence (e.g. the next put) stay
    independent and keep overlapping.  Returns (tensors, deps) pinned.
    """
    out = optimization_barrier(tuple(tensors) + tuple(deps))
    n = len(tuple(tensors))
    return out[:n], out[n:]


def pin(xs: Sequence[jax.Array]) -> tuple:
    """Serialise a value chain (e.g. an accumulator) across schedule steps
    so only O(1) intermediates are live — the quiet counterpart of fence.
    """
    return optimization_barrier(tuple(xs))
