"""Staged transfer programs over one-sided channels (DESIGN.md §8).

A ``Stream`` is the comm-side analogue of a CUDA/NVSHMEM stream: an
ordered sequence of channel stages making up one logical transfer program.
Each stage opens a channel (a fixed route), puts its tensors, and the
stage index is recorded so trace validation can reason about the program
shape.  The staged programs the SP schedules need are provided here:

  ring_shift          — one intra-ring rotation (Ring Attention's KV hop)
  torus_hop           — distance-k hop inside the Ulysses group (§4.3
                        stage k of the decomposed all-to-all)
  staged_all_to_all   — the full P_u-stage decomposition with the
                        stationary diagonal chunk (grouped_all_to_all)
  staged_ungroup      — its inverse (the Push-O / fourth all-to-all)
  pipe_handoff        — the pipe-axis stage boundary transfer of the
                        displaced patch pipeline (models/dit.py)

Everything here is layout-agnostic: ``layout`` ducks as any object with
``axes``, ``p_ulysses``, ``my_coords()``, ``ring_perm(k)`` and
``ulysses_stage_perm(k)`` (core/collectives.GroupLayout in practice; the
duck-typing keeps this package import-free of core so core can build on
it without cycles).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from .channel import Channel, InFlight, shift_perm

__all__ = ["Stream", "ring_shift", "torus_hop", "staged_all_to_all",
           "staged_ungroup", "pipe_handoff"]


@dataclasses.dataclass
class Stream:
    """An ordered program of channel transfers.

    ``channel`` mints a Channel bound to this stream at the current stage;
    ``next_stage`` advances the program counter.  Streams are trace-time
    bookkeeping only — they add no ops of their own.  ``backend`` selects
    the channel lowering for every stage of the program ("xla" | "pallas",
    see channel.py); ``interpret`` runs Pallas channels in interpreter
    mode (the CPU CI path).
    """

    name: str
    stage: int = 0
    backend: str = "xla"
    interpret: bool = True

    def channel(self, axes, perm, label: str = "") -> Channel:
        return Channel(axes=tuple(axes), perm=tuple(perm),
                       name=f"{self.name}.{label}" if label else self.name,
                       stream=self.name, stage=self.stage,
                       backend=self.backend, interpret=self.interpret)

    def next_stage(self) -> int:
        self.stage += 1
        return self.stage

    # -- staged programs as stream methods (each advances the stage) ------
    def put(self, axes, perm, *tensors, label: str = "",
            overlaps: str = "") -> InFlight:
        fut = self.channel(axes, perm, label).put(*tensors, overlaps=overlaps)
        self.next_stage()
        return fut


def ring_shift(layout: Any, *tensors: jax.Array, shift: int = 1,
               stream: Stream | None = None,
               overlaps: str = "", backend: str = "xla",
               interpret: bool = True) -> InFlight:
    """One rotation inside each Ring group (same u): the KV hop of Ring
    Attention.  Returns the in-flight handle — the caller owns the wait."""
    stream = stream or Stream("ring", backend=backend, interpret=interpret)
    return stream.put(layout.axes, layout.ring_perm(shift), *tensors,
                      label=f"shift{shift}", overlaps=overlaps)


def torus_hop(layout: Any, k: int, *tensors: jax.Array,
              stream: Stream | None = None,
              overlaps: str = "", backend: str = "xla",
              interpret: bool = True) -> InFlight:
    """Distance-k hop inside each Ulysses group (same r): stage k of the
    §4.3 decomposed all-to-all."""
    stream = stream or Stream("torus", backend=backend, interpret=interpret)
    return stream.put(layout.axes, layout.ulysses_stage_perm(k), *tensors,
                      label=f"hop{k}", overlaps=overlaps)


def _dyn_set(buf: jax.Array, idx, val: jax.Array) -> jax.Array:
    return lax.dynamic_update_slice_in_dim(buf, val[None], idx, axis=0)


def staged_all_to_all(
    x: jax.Array,
    layout: Any,
    *,
    split_axis: int,
    stream: Stream | None = None,
    backend: str = "xla",
    interpret: bool = True,
) -> jax.Array:
    """All-to-all restricted to Ulysses groups, as P_u - 1 channel stages.

    Splits ``x`` into P_u chunks along ``split_axis``; chunk j is put to
    ulysses-peer j.  The diagonal chunk (j == my u) is stationary (§4.3)
    and never touches the wire.  Returns chunks stacked on a new leading
    axis in *source*-u order: ``out[j]`` = the chunk peer j produced for
    me.  Every stage's put is independent of every other stage's — the
    whole program can be in flight at once, which is what lets Torus
    interleave these stages with attention compute.
    """
    stream = stream or Stream("a2a", backend=backend, interpret=interpret)
    p_u = layout.p_ulysses
    chunks = jnp.stack(jnp.split(x, p_u, axis=split_axis), axis=0)
    if p_u == 1:
        return chunks
    u, _ = layout.my_coords()
    out = jnp.zeros_like(chunks)
    out = _dyn_set(out, u, jnp.take(chunks, u, axis=0))
    for k in range(1, p_u):
        # I put my chunk destined for peer (u + k); peer (u - k) puts mine.
        send = jnp.take(chunks, (u + k) % p_u, axis=0)
        recv = torus_hop(layout, k, send, stream=stream).wait()
        out = _dyn_set(out, (u - k) % p_u, recv)
    return out


def staged_ungroup(
    stacked: jax.Array,
    layout: Any,
    *,
    concat_axis: int,
    stream: Stream | None = None,
    backend: str = "xla",
    interpret: bool = True,
) -> jax.Array:
    """Inverse program: put ``stacked[j]`` back to ulysses-peer j and
    concatenate the received chunks along ``concat_axis`` (the fourth
    all-to-all of Ulysses attention / Torus Push-O; diagonal stays put)."""
    stream = stream or Stream("a2a.inv", backend=backend, interpret=interpret)
    p_u = layout.p_ulysses
    if p_u == 1:
        return jnp.squeeze(stacked, axis=0)
    u, _ = layout.my_coords()
    out = jnp.zeros_like(stacked)
    out = _dyn_set(out, u, jnp.take(stacked, u, axis=0))
    for k in range(1, p_u):
        send = jnp.take(stacked, (u + k) % p_u, axis=0)
        recv = torus_hop(layout, k, send, stream=stream,
                         overlaps="next-layer compute").wait()
        out = _dyn_set(out, (u - k) % p_u, recv)
    return jnp.concatenate(list(out), axis=concat_axis)


def pipe_handoff(
    x: jax.Array,
    mesh: jax.sharding.Mesh,
    axis: str,
    *,
    shift: int = 1,
    batch_axes: tuple[str, ...] | None = None,
    stream: Stream | None = None,
    backend: str = "xla",
    interpret: bool = True,
) -> jax.Array:
    """Stage-boundary hand-off of the displaced patch pipeline: rotate the
    activation one stage forward along the pipe ``axis``.

    This is the transfer that replaces the GSPMD-implicit stage hand-off
    (ROADMAP item): an explicit collective-permute over the pipe axis
    carrying exactly the bytes the real pipeline moves per boundary, so
    (a) the HLO names the transfer and trace.py can validate that patch
    (p+1)'s hand-off overlaps patch p's stage compute, and (b) the
    emulation pays the wire cost it claims.  In the single-program
    emulation the activation is replicated over the pipe axis, so the
    rotation is value-preserving — the multi-device schedule it stands in
    for is documented in DESIGN.md §8.

    Must be called OUTSIDE any shard_map (it opens its own over ``axis``).
    """
    stream = stream or Stream("pipe", backend=backend, interpret=interpret)
    pp = mesh.shape[axis]
    if pp == 1:
        return x
    ch = stream.channel((axis,), shift_perm(pp, shift), f"handoff{stream.stage}")
    stream.next_stage()
    spec = P(batch_axes) if batch_axes else P()

    def body(xs):
        return ch.put(xs, overlaps="stage compute").wait()

    return shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=spec,
                     check_vma=False)(x)
