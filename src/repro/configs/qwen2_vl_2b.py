"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

Transformer backbone only; the ViT vision tower + projector is the stubbed
modality frontend — ``input_specs()`` supplies precomputed patch embeddings
interleaved with text embeddings, plus the 3-component (temporal, h, w)
M-RoPE position ids.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    rope="mrope",
    rope_theta=1e6,
    act="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    sharding_overrides=(("vocab", ("data",)),),
    citation="arXiv:2409.12191",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512
    )
