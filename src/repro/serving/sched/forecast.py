"""Per-bucket arrival forecasting (DESIGN.md §10; ROADMAP "Scheduler
preemption / arrival forecasting").

The PR-3 admission policy defers a padded batch while its deadline slack
exceeds ``defer_slack`` — an *open-ended* wait justified only by the hope
that more same-bucket arrivals show up before ``flush``.  The
``ArrivalForecaster`` turns that hope into an estimate: it tracks an EWMA
of each bucket's interarrival gap and the gap's variance, and answers
"how long until this bucket's next ``k`` arrivals?".  The admission
policy then defers a padded candidate **only** while the forecast fill
time (plus a variance safety term) fits inside the candidate's slack —
an explicit, slack-aware deferral horizon instead of wait-until-flush.

All state is host-side floats keyed by latent length; ``observe`` is
called once per ``RequestScheduler.submit``.  No wall-clock reads happen
here — every method takes ``now`` from the caller, so the deterministic
replay harness (benchmarks/sched_sweep.py) drives it on simulated time.
"""
from __future__ import annotations

import dataclasses
import math

from ..metrics import Tracker


@dataclasses.dataclass
class BucketRate:
    """EWMA interarrival statistics of one bucket."""

    last_arrival: float
    mean_gap: float = 0.0
    var_gap: float = 0.0
    n: int = 1  # arrivals observed (gaps observed = n - 1)

    @property
    def rate(self) -> float:
        """Smoothed arrivals per second (0 until two arrivals seen)."""
        if self.n < 2 or self.mean_gap <= 0.0:
            return 0.0
        return 1.0 / self.mean_gap

    @property
    def std_gap(self) -> float:
        return math.sqrt(max(self.var_gap, 0.0))


class ArrivalForecaster:
    """EWMA per-bucket arrival-rate estimator.

    ``alpha`` is the EWMA weight of the newest gap; the variance uses the
    standard EW recursion ``var ← (1-α)·(var + α·(gap-mean)²)`` so bursty
    buckets carry a wide predictive interval and steady ones a tight one.
    """

    def __init__(self, alpha: float = 0.25,
                 tracker: Tracker | None = None,
                 idle_age: float | None = None):
        assert 0.0 < alpha <= 1.0, alpha
        assert idle_age is None or idle_age > 0.0, idle_age
        self.alpha = alpha
        # None = keep every bucket forever (the PR-5 behavior, fine for
        # bounded benchmark runs); a long-running server sets an idle age
        # so the per-latent-length map cannot grow without bound — one
        # ``BucketRate`` per distinct seq_len is a leak under adversarial
        # or long-tailed resolution mixes (ISSUE 9).  Eviction uses the
        # caller-supplied ``now`` only — no wall-clock reads here.
        self.idle_age = idle_age
        self.buckets: dict[int, BucketRate] = {}
        # metrics sink (DESIGN.md §11): the per-bucket rate estimate is
        # published on every update so a trace shows the forecast the
        # deferral horizon actually consulted
        self.tracker = tracker if tracker is not None else Tracker()

    def observe(self, seq_len: int, now: float) -> None:
        """Record one arrival (called on every submit)."""
        self.evict_idle(now)
        b = self.buckets.get(seq_len)
        if b is None:
            self.buckets[seq_len] = BucketRate(last_arrival=now)
            return
        gap = max(now - b.last_arrival, 0.0)
        if b.n == 1:
            b.mean_gap = gap
        else:
            delta = gap - b.mean_gap
            b.mean_gap += self.alpha * delta
            b.var_gap = (1.0 - self.alpha) * (
                b.var_gap + self.alpha * delta * delta)
        b.last_arrival = now
        b.n += 1
        self.tracker.log("forecast.mean_gap_s", b.mean_gap,
                         tags={"seq": seq_len})

    def evict_idle(self, now: float) -> int:
        """Drop every bucket whose last arrival is more than ``idle_age``
        old; returns how many were evicted.  Called from every
        ``observe``, and directly by long-idle owners (the fleet tier's
        per-replica forecasters).  A dried-up bucket re-seeds from
        scratch on its next arrival — correct, since its old rate
        estimate carried no predictive value anyway (see
        ``expected_fill_time``'s dried-up-bucket note)."""
        if self.idle_age is None:
            return 0
        dead = [s for s, b in self.buckets.items()
                if now - b.last_arrival > self.idle_age]
        for s in dead:
            del self.buckets[s]
            self.tracker.count("forecast.evictions", tags={"seq": s})
        return len(dead)

    def rate(self, seq_len: int) -> float:
        b = self.buckets.get(seq_len)
        return b.rate if b is not None else 0.0

    def expected_fill_time(self, seq_len: int, k: int, now: float,
                           safety: float = 1.0) -> float | None:
        """Predicted seconds until ``k`` more requests of this bucket
        arrive, with a ``safety``-weighted standard-deviation margin.

        None = no estimate (fewer than two arrivals seen) — the caller
        falls back to the PR-3 wait-until-flush rule.  The first of the
        ``k`` arrivals is credited with the time already elapsed since
        the bucket's last arrival (a gap is partially "used up" while
        the candidate waits) — but once the current gap has OUTLIVED the
        estimate, the excess is evidence the rate has collapsed, and the
        projected wait grows with it: ``|mean_gap - elapsed|`` rises
        without bound for a dried-up bucket, so its padded candidates
        stop deferring as soon as the projection leaves the slack
        (admission.py ``_worth_deferring``) instead of stalling on an
        ever-"imminent" arrival.
        """
        if k <= 0:
            return 0.0
        b = self.buckets.get(seq_len)
        if b is None or b.n < 2 or b.mean_gap <= 0.0:
            return None
        elapsed = max(now - b.last_arrival, 0.0)
        first = abs(b.mean_gap - elapsed)
        t = first + (k - 1) * b.mean_gap
        return t + safety * b.std_gap * math.sqrt(k)
