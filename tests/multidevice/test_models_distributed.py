"""Distributed model forward/train ≡ single-replica reference (8 devices)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.shapes import InputShape
from repro.core import SPConfig
from repro.models import ParallelContext, get_model, param_shardings
from repro.train import batch_shardings

SHAPE = InputShape("md", 32, 4, "training")


def _cfg(arch):
    return dataclasses.replace(get_reduced(arch), dtype="float32",
                               sharding_overrides=())


def _single_device_loss(cfg, params, batch):
    mesh1 = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sp1 = SPConfig(strategy="full", sp_axes=("model",), batch_axes=("data",))
    ctx = ParallelContext(mesh1, sp1, "train")
    bundle = get_model(cfg)
    loss, aux = bundle.loss(params, batch, cfg, ctx)
    return float(loss)


@pytest.mark.parametrize("arch,strategy", [
    ("qwen2-1.5b", "swift_torus"),
    ("qwen2-1.5b", "usp"),
    ("chatglm3-6b", "swift"),
    ("hymba-1.5b", "swift_torus"),
    ("rwkv6-1.6b", "swift_torus"),  # attention-free: distributed prefix scan
    ("qwen2-vl-2b", "swift_torus"),
    ("whisper-tiny", "swift_torus"),
])
def test_distributed_loss_matches_single(arch, strategy, mesh8, rng):
    cfg = _cfg(arch)
    bundle = get_model(cfg)
    params, axes = bundle.init(cfg, rng, mesh8.shape["model"])
    batch = bundle.input_specs(cfg, SHAPE, abstract=False, key=rng,
                               dtype=jnp.float32)
    sp = SPConfig(strategy=strategy, sp_axes=("model",),
                  batch_axes=("pod", "data"))
    ctx = ParallelContext(mesh8, sp, "train")
    p_sh = param_shardings(axes, cfg, mesh8, "train")
    b_sh = batch_shardings(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch), mesh8, sp)
    params_d = jax.device_put(params, p_sh)
    batch_d = jax.device_put(batch, b_sh)
    loss_d, _ = jax.jit(lambda p, b: bundle.loss(p, b, cfg, ctx))(params_d, batch_d)
    loss_1 = _single_device_loss(cfg, params, batch)
    # MoE dispatch order may differ marginally; everything else tight
    tol = 2e-3 if cfg.family == "moe" else 5e-4
    assert abs(float(loss_d) - loss_1) < tol, (arch, float(loss_d), loss_1)


@pytest.mark.slow  # ~13s: MoE dispatch jit dominates (CI 'slow' job)
def test_moe_a2a_matches_single(mesh8, rng):
    """Expert-parallel all-to-all dispatch on 2 EP ranks == 1-device path
    (generous capacity so no drops)."""
    cfg = _cfg("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    bundle = get_model(cfg)
    params, axes = bundle.init(cfg, rng, mesh8.shape["model"])
    batch = bundle.input_specs(cfg, SHAPE, abstract=False, key=rng,
                               dtype=jnp.float32)
    sp = SPConfig(strategy="swift_torus", sp_axes=("model",),
                  batch_axes=("pod", "data"))
    ctx = ParallelContext(mesh8, sp, "train")
    p_sh = param_shardings(axes, cfg, mesh8, "train")
    params_d = jax.device_put(params, p_sh)
    loss_d, _ = jax.jit(lambda p, b: bundle.loss(p, b, cfg, ctx))(params_d, batch)
    loss_1 = _single_device_loss(cfg, params, batch)
    # dispatch/psum summation order differs across EP ranks -> f32 noise
    assert abs(float(loss_d) - loss_1) < 2e-3, (float(loss_d), loss_1)


@pytest.mark.slow  # ~15s: two grad jits (CI 'slow' job)
def test_gradients_match_single_device(mesh8, rng):
    """Train-step gradient parity: distributed == single replica."""
    cfg = _cfg("qwen2-1.5b")
    bundle = get_model(cfg)
    params, axes = bundle.init(cfg, rng, 1)
    batch = bundle.input_specs(cfg, SHAPE, abstract=False, key=rng,
                               dtype=jnp.float32)
    sp = SPConfig(strategy="swift_torus", sp_axes=("model",),
                  batch_axes=("pod", "data"))
    ctx8 = ParallelContext(mesh8, sp, "train")
    g8 = jax.jit(jax.grad(lambda p: bundle.loss(p, batch, cfg, ctx8)[0]))(params)

    mesh1 = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sp1 = SPConfig(strategy="full", sp_axes=("model",), batch_axes=("data",))
    ctx1 = ParallelContext(mesh1, sp1, "train")
    g1 = jax.jit(jax.grad(lambda p: bundle.loss(p, batch, cfg, ctx1)[0]))(params)

    for (ka, a), (kb, b) in zip(
            sorted(jax.tree_util.tree_leaves_with_path(g8),
                   key=lambda t: str(t[0])),
            sorted(jax.tree_util.tree_leaves_with_path(g1),
                   key=lambda t: str(t[0]))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-4,
                                   err_msg=str(ka))


def test_decode_step_distributed_cache(mesh8, rng):
    """serve_step over a sequence-sharded KV cache on the 3-axis mesh."""
    cfg = _cfg("qwen2-1.5b")
    bundle = get_model(cfg)
    params, _ = bundle.init(cfg, rng, 1)
    B, L = 4, 16
    tokens = jax.random.randint(rng, (B, L), 0, cfg.vocab, jnp.int32)

    sp1 = SPConfig(strategy="full", sp_axes=("model",), batch_axes=("data",))
    mesh1 = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    full = bundle.apply(params, {"tokens": tokens}, cfg,
                        ParallelContext(mesh1, sp1, "prefill"))

    sp = SPConfig(strategy="swift", sp_axes=("pod", "model"),
                  batch_axes=("data",))
    ctx = ParallelContext(mesh8, sp, "decode")
    caches = bundle.init_caches(cfg, B, L, jnp.float32)
    step = jax.jit(lambda p, b, c, i: bundle.step(p, b, c, i, cfg, ctx))
    outs = []
    for t in range(L):
        logit, caches = step(params, {"tokens": tokens[:, t:t + 1]},
                             caches, jnp.int32(t))
        outs.append(logit)
    np.testing.assert_allclose(jnp.stack(outs, 1), full, rtol=1e-4, atol=1e-4)
