"""Paper Fig. 3b / Appendix D: inter-machine communication volume per GPU,
USP vs SwiftFusion, as machine count scales.

Workloads: the paper's Flux (H=24, D=128) and CogVideoX (H=24, D=64)
geometries.  Volumes in MiB (bf16), derived column = V_USP / V_SFU.
"""
from __future__ import annotations

from repro.core import plan, plan_hybrid, usp_plan
from repro.core.comm_model import (
    LayerWorkload,
    cfg_recombine_volume,
    pipefusion_boundary_volume,
    swift_inter_volume,
    usp_inter_volume,
)

from .common import row

WORKLOADS = {
    "flux_3072": LayerWorkload(batch=1, seq=36_864, heads=24, head_dim=128),
    "cogvideox_20s": LayerWorkload(batch=1, seq=49_152, heads=24, head_dim=64),
}
M_PER_MACHINE = 8  # paper testbed: 8 GPUs per machine
N_LAYERS = {"flux_3072": 96, "cogvideox_20s": 42}


def run() -> list[str]:
    rows = []
    for wname, wl in WORKLOADS.items():
        for n in (2, 3, 4):
            sp = plan(n, M_PER_MACHINE, wl.heads)
            up = usp_plan(n, M_PER_MACHINE, wl.heads)
            v_s = swift_inter_volume(sp, wl.blhd) * 2 / 2**20  # bf16 MiB
            v_u = usp_inter_volume(up, wl.blhd) * 2 / 2**20
            ratio = v_u / v_s if v_s else float("inf")
            rows.append(row(f"comm_volume/{wname}/N{n}/usp_MiB", v_u,
                            f"Pu={up.p_ulysses},Pr={up.p_ring}"))
            rows.append(row(f"comm_volume/{wname}/N{n}/sfu_MiB", v_s,
                            f"usp_over_sfu={ratio:.2f}x"))
        # hybrid (DESIGN.md §7): per-STEP inter-machine volume.  SP pays its
        # per-layer volume n_layers times (×2 for sequential guidance);
        # pipelining pays one boundary hand-off and CFG one recombine.
        n, nl = 4, N_LAYERS[wname]
        sp = plan(n, M_PER_MACHINE, wl.heads)
        v_sp_step = swift_inter_volume(sp, wl.blhd) * 2 * nl * 2 / 2**20
        h = plan_hybrid(n, M_PER_MACHINE, wl.heads, cfg_parallel=True, pp=2,
                        n_layers=nl)
        v_h_step = (swift_inter_volume(h.sp, wl.blhd) * (nl / h.pp)
                    + pipefusion_boundary_volume(wl, h.pp)
                    + cfg_recombine_volume(wl)) * 2 / 2**20
        rows.append(row(f"comm_volume/{wname}/N{n}/sfu_step_MiB", v_sp_step,
                        f"per-step, guided, layers={nl}"))
        rows.append(row(f"comm_volume/{wname}/N{n}/hybrid_step_MiB", v_h_step,
                        f"cfg={h.cfg},pp={h.pp},"
                        f"sfu_over_hybrid={v_sp_step / v_h_step:.1f}x"))
    return rows
