#!/usr/bin/env python3
"""Fit NetworkModel parameters to measured BENCH_*.json step latencies
(ROADMAP comm-model calibration item; DESIGN.md §9 uses the result to
score scheduler admissions with calibrated rather than nominal numbers).

``benchmarks/run.py`` emits per-config BENCH_<module>.json trajectory
records whose ``measured_step_us`` field multi-machine runs fill in.
This script least-squares-fits (intra_bw, inter_bw, intra_lat, inter_lat,
mfu) so the analytical model reproduces those measurements:

    python scripts/calibrate_comm.py BENCH_hybrid_sweep.json \
        --out calibration.json
    python -m benchmarks.hybrid_sweep --calibration calibration.json
    python -m benchmarks.e2e_latency  --calibration calibration.json

Method: damped Gauss-Newton on log-parameters with log-ratio residuals
``log(pred/measured)`` (numpy only — no scipy in the container).  Log
space keeps every parameter positive and makes the fit scale-free across
the many orders of magnitude between bandwidths and hop latencies; the
damping keeps parameters the records cannot identify (e.g. intra_bw when
every record models intra traffic as overlapped, or hop latencies in
bandwidth-bound configs) pinned near their nominal start instead of
wandering.

The regression test (tests/test_calibration.py) pins the fitted/nominal
ratios on a checked-in fixture generated from a known ground-truth model.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.core.comm_model import (  # noqa: E402
    LayerWorkload,
    NetworkModel,
    plan_step_latency,
)
from repro.core.planner import plan_hybrid  # noqa: E402

FIT_PARAMS = ("intra_bw", "inter_bw", "intra_lat", "inter_lat", "mfu")


def load_records(paths: list[pathlib.Path]) -> list[dict]:
    """Records with a fit target, from any mix of BENCH_*.json files."""
    out = []
    for p in paths:
        payload = json.loads(p.read_text())
        for rec in payload.get("records", []):
            if rec.get("measured_step_us") is None:
                continue
            if "workload" not in rec or "plan" not in rec:
                continue
            out.append(rec)
    return out


def predict_us(rec: dict, net: NetworkModel) -> float:
    """Re-run the comm model on one record's configuration.

    The (cfg, pp) split is re-planned with ``plan_hybrid`` — deterministic
    given the recorded cluster shape — so the prediction path is exactly
    the one the sweeps used when the record was written."""
    wl = rec["workload"]
    w = LayerWorkload(batch=wl["batch"], seq=wl["seq"], heads=wl["heads"],
                      head_dim=wl["head_dim"])
    pl = rec["plan"]
    h = plan_hybrid(rec["n_machines"], rec["m_per_machine"], wl["heads"],
                    cfg_parallel=pl["cfg"] > 1, cfg_degree=max(pl["cfg"], 2),
                    pp=pl["pp"], n_layers=wl["n_layers"])
    assert (h.sp.p_ulysses, h.sp.p_ring) == (pl["p_ulysses"], pl["p_ring"]), (
        f"{rec['name']}: re-planned SP split {h.sp.p_ulysses}x{h.sp.p_ring} "
        f"!= recorded {pl['p_ulysses']}x{pl['p_ring']}")
    pred = plan_step_latency(h, w, net, n_layers=wl["n_layers"], guided=True,
                             num_patches=pl.get("num_patches"))
    return pred["t_step"] * 1e6


def _net_from_theta(theta: np.ndarray) -> NetworkModel:
    return dataclasses.replace(
        NetworkModel(), **{k: float(math.exp(v))
                           for k, v in zip(FIT_PARAMS, theta)})


def _residuals(theta: np.ndarray, recs: list[dict]) -> np.ndarray:
    net = _net_from_theta(theta)
    return np.array([
        math.log(predict_us(r, net) / r["measured_step_us"]) for r in recs])


def fit(recs: list[dict], *, iters: int = 40, damping: float = 1e-3,
        fd_eps: float = 1e-5) -> tuple[NetworkModel, dict]:
    """Least-squares fit; returns (model, report).

    Gauss-Newton with Levenberg damping; the Jacobian is finite-differenced
    in log-parameter space (5 params x len(recs) residuals).
    """
    assert recs, "no records with measured_step_us — nothing to fit"
    nominal = NetworkModel()
    theta = np.array([math.log(getattr(nominal, k)) for k in FIT_PARAMS])
    r = _residuals(theta, recs)
    for _ in range(iters):
        jac = np.empty((len(recs), len(theta)))
        for j in range(len(theta)):
            t2 = theta.copy()
            t2[j] += fd_eps
            jac[:, j] = (_residuals(t2, recs) - r) / fd_eps
        a = np.vstack([jac, math.sqrt(damping) * np.eye(len(theta))])
        b = np.concatenate([-r, np.zeros(len(theta))])
        step, *_ = np.linalg.lstsq(a, b, rcond=None)
        if not np.all(np.isfinite(step)):
            break
        theta = theta + step
        r = _residuals(theta, recs)
        if np.linalg.norm(step) < 1e-10:
            break
    net = _net_from_theta(theta)
    report = {
        "n_records": len(recs),
        "rms_rel_error": float(math.sqrt(float(np.mean(r ** 2)))),
        "ratio_vs_nominal": {
            k: getattr(net, k) / getattr(nominal, k) for k in FIT_PARAMS},
    }
    return net, report


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench", nargs="+", type=pathlib.Path,
                    help="BENCH_*.json files with measured_step_us filled in")
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="write the fitted NetworkModel JSON here "
                         "(stdout otherwise)")
    args = ap.parse_args(argv)
    recs = load_records(args.bench)
    if not recs:
        print("no records with measured_step_us in "
              f"{[str(p) for p in args.bench]}", file=sys.stderr)
        return 1
    net, report = fit(recs)
    payload = {k: getattr(net, k) for k in FIT_PARAMS}
    payload["fit"] = report
    text = json.dumps(payload, indent=1, sort_keys=True)
    if args.out:
        args.out.write_text(text)
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(text)
    print(f"fit: {report['n_records']} records, rms rel error "
          f"{report['rms_rel_error']:.4f}", file=sys.stderr)
    for k, v in report["ratio_vs_nominal"].items():
        print(f"  {k}: x{v:.3f} vs nominal", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
