"""Training loop + checkpoint round-trip."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.shapes import InputShape
from repro.core import SPConfig
from repro.train import AdamWConfig, Trainer, checkpoint
from repro.train.optimizer import schedule


def test_loss_decreases_on_synthetic_lm(mesh1, tmp_path):
    cfg = dataclasses.replace(get_reduced("qwen2-1.5b"), dtype="float32",
                              sharding_overrides=())
    sp = SPConfig(strategy="full", sp_axes=("model",), batch_axes=("data",))
    shape = InputShape("tiny_train", 64, 4, "training")
    tr = Trainer(cfg, mesh1, sp, shape,
                 opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60),
                 ckpt_path=str(tmp_path / "ck"))
    params, history = tr.run(steps=40, log_every=10)
    first, last = history[0]["loss"], history[-1]["loss"]
    assert np.isfinite(last)
    assert last < first - 0.2, (first, last)  # synthetic markov is learnable


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 50, 99)]
    assert lrs[0] < lrs[1] < lrs[2]  # warmup
    assert lrs[2] >= lrs[3] >= lrs[4]  # decay
    assert lrs[4] >= 0.1 * 0.99


def test_checkpoint_roundtrip(tmp_path, rng):
    tree = {
        "a": jax.random.normal(rng, (4, 8)),
        "b": {"c": jnp.arange(5, dtype=jnp.int32),
              "d": jax.random.normal(rng, (3,), jnp.bfloat16)},
    }
    path = str(tmp_path / "ckpt")
    checkpoint.save(path, tree)
    assert checkpoint.exists(path)
    like = jax.tree.map(jnp.zeros_like, tree)
    out = checkpoint.load(path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_structure_mismatch_raises(tmp_path, rng):
    path = str(tmp_path / "ckpt2")
    checkpoint.save(path, {"a": jnp.zeros((2,))})
    with pytest.raises(AssertionError):
        checkpoint.load(path, {"a": jnp.zeros((2,)), "b": jnp.zeros((3,))})
