"""Ulysses Attention transforms (paper §2.2) over a logical Ulysses group.

The forward transform runs the three all-to-alls on Q, K, V: scatter the
head dimension (H -> H/P_u) and gather the sequence dimension
(L/P -> P_u * L/P) within each Ulysses group.  The inverse transform is the
fourth all-to-all restoring O to [B, L/P, H, D].

Gathered chunks are ordered by source ulysses coordinate; because group
members are not adjacent in the global sequence when the group spans the
slow axis, the transforms also return global *position arrays* used for
exact causal/window masking downstream.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .collectives import GroupLayout, monolithic_all_to_all, ungroup_all_to_all

HEAD_AXIS = 2  # [B, L, H, D]
SEQ_AXIS = 1


class Gathered(NamedTuple):
    q: jax.Array  # [B, P_u * Ls, Hq / P_u, D]
    k: jax.Array  # [B, P_u * Ls, Hkv / P_u, D]
    v: jax.Array
    q_pos: jax.Array  # [P_u * Ls] global positions of the gathered sequence


def group_positions(layout: GroupLayout, shard_len: int, ring_r) -> jax.Array:
    """Global positions of the sequence gathered by the Ulysses group whose
    ring coordinate is ``ring_r`` (traced ok), ordered by source u."""
    us = jnp.arange(layout.p_ulysses)
    if layout.ulysses_outer:
        ranks = us * layout.p_ring + ring_r
    else:
        ranks = ring_r * layout.p_ulysses + us
    return (ranks[:, None] * shard_len + jnp.arange(shard_len)[None, :]).reshape(-1)


def gather_qkv(
    q: jax.Array, k: jax.Array, v: jax.Array, layout: GroupLayout,
    *, backend: str = "xla", interpret: bool = True,
    wire_dtype: str | None = None,
) -> Gathered:
    """The first three all-to-alls of Ulysses Attention.  ``wire_dtype``
    compresses the inter-machine leg when the layout is hierarchical
    (``layout.u_groups > 1``, DESIGN.md §8.2); ignored otherwise."""
    shard_len = q.shape[SEQ_AXIS]

    def fwd(x):
        stacked = monolithic_all_to_all(x, layout, split_axis=HEAD_AXIS,
                                        backend=backend, interpret=interpret,
                                        wire_dtype=wire_dtype)
        # [P_u, B, Ls, h, D] -> [B, P_u * Ls, h, D], source-u order
        p_u, b, ls, h, d = stacked.shape
        return jnp.moveaxis(stacked, 0, 1).reshape(b, p_u * ls, h, d)

    _, my_r = layout.my_coords()
    return Gathered(
        q=fwd(q), k=fwd(k), v=fwd(v), q_pos=group_positions(layout, shard_len, my_r)
    )


def scatter_o(o: jax.Array, layout: GroupLayout, *, backend: str = "xla",
              interpret: bool = True,
              wire_dtype: str | None = None) -> jax.Array:
    """The fourth all-to-all: restore O from [B, P_u*Ls, H/P_u, D] to the
    original [B, Ls, H, D] sequence sharding."""
    p_u = layout.p_ulysses
    b, lg, h, d = o.shape
    stacked = o.reshape(b, p_u, lg // p_u, h, d).transpose(1, 0, 2, 3, 4)
    return ungroup_all_to_all(stacked, layout, concat_axis=HEAD_AXIS,
                              backend=backend, interpret=interpret,
                              wire_dtype=wire_dtype)
