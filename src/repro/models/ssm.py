"""Linear-recurrence (SSM) substrate: chunked scans + distributed sequence
sharding for attention-free architectures (rwkv6) and hybrid SSM branches
(hymba).

The paper's SP technique assumes softmax attention; for linear recurrences
``S_t = a_t ⊙ S_{t-1} + b_t`` the sequence dimension is sharded instead
with a **two-pass distributed prefix scan** (DESIGN.md §5):

  pass 1 (local)   : chunked scan with S_in = 0 → outputs₀, device totals
                     (A_dev = ∏ decays, B_dev = final state)
  exchange         : exclusive prefix scan of (A_dev, B_dev) across SP ranks
                     — log₂P Hillis-Steele rounds of `ppermute` (the same
                     one-sided primitive the attention path uses)
  pass 2 (local)   : outputs = outputs₀ + influence(S_in)

The linear-recurrence composition ((a₂,b₂)∘(a₁,b₁) = (a₂a₁, a₂b₁+b₂)) is
associative, so the cross-device pass is exact, not an approximation.

Two chunk kernels:
  * rwkv6 (Finch): per-channel data-dependent decay w_t, bonus u, state
    [N_k, N_v] per head (GLA-style chunk trick with cumulative-decay
    normalisation).
  * ssd (mamba2-style scalar-per-head decay), used by the hymba branch.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

EPS = 1e-6


class ScanResult(NamedTuple):
    out: jax.Array  # outputs with S_in = 0
    a_dev: jax.Array  # total decay across the local sequence
    s_out: jax.Array  # final state with S_in = 0
    infl: jax.Array  # per-token influence of S_in on the output


# ---------------------------------------------------------------------------
# RWKV6 chunk scan (per-channel decay, state [Nk, Nv] per head)
# ---------------------------------------------------------------------------

def rwkv6_chunk_scan(
    r: jax.Array,  # [B, L, H, N]
    k: jax.Array,  # [B, L, H, N]
    v: jax.Array,  # [B, L, H, N]
    w: jax.Array,  # [B, L, H, N] decay in (0, 1]
    u: jax.Array,  # [H, N] bonus for the current token
    chunk: int = 64,
) -> ScanResult:
    b, l, h, n = r.shape
    c = min(chunk, l)
    assert l % c == 0, (l, c)
    nc = l // c
    rs = lambda x: x.reshape(b, nc, c, h, n)
    r_, k_, v_, w_ = rs(r), rs(k), rs(v), rs(w)
    w_ = jnp.clip(w_.astype(jnp.float32), EPS, 1.0)
    logw = jnp.log(w_)
    # D[t] = prod_{s<=t} w_s within chunk (inclusive), in log space
    logD = jnp.cumsum(logw, axis=2)
    D = jnp.exp(logD)  # [b, nc, c, h, n]
    Dm1 = jnp.exp(logD - logw)  # D[t-1] (exclusive)
    a_chunk = D[:, :, -1]  # [b, nc, h, n] total chunk decay

    rf = r_.astype(jnp.float32)
    kf = k_.astype(jnp.float32)
    vf = v_.astype(jnp.float32)
    # pairwise intra-chunk term: A[t,s] = (r_t ⊙ D_{t-1}) · (k_s / D_s), s < t
    r_sc = rf * Dm1
    k_sc = kf / D
    att = jnp.einsum("bgthn,bgshn->bghts", r_sc, k_sc)
    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
    att = jnp.where(tri[None, None, None], att, 0.0)
    # bonus diagonal: r_t · (u ⊙ k_t)
    diag = jnp.einsum("bgthn,hn,bgthn->bgth", rf, u.astype(jnp.float32), kf)
    out = jnp.einsum("bghts,bgshn->bgthn", att, vf)
    out = out + diag[..., None] * vf

    # cross-chunk: sequential scan over chunks carrying S [b, h, n, n]
    # state contribution of chunk g: sum_s (a_chunk/D_s ⊙ k_s) ⊗ v_s
    k_tail = jnp.einsum("bghn,bgshn->bgshn", a_chunk, k_sc)  # k_s * (a_c / D_s)
    b_chunk = jnp.einsum("bgshn,bgshm->bghnm", k_tail, vf)

    def step(S, xs):
        a_g, b_g, rsc_g = xs  # [b,h,n], [b,h,n,m], [b,c,h,n]
        o_corr = jnp.einsum("bthn,bhnm->bthm", rsc_g, S)
        S = a_g[..., None] * S + b_g
        return S, o_corr

    S0 = jnp.zeros((b, h, n, n), jnp.float32)
    xs = (
        jnp.moveaxis(a_chunk, 1, 0),
        jnp.moveaxis(b_chunk, 1, 0),
        jnp.moveaxis(r_sc, 1, 0),
    )
    s_out, o_corr = lax.scan(step, S0, xs, unroll=True)
    out = out + jnp.moveaxis(o_corr, 0, 1)

    a_dev = jnp.exp(jnp.sum(logw, axis=(1, 2)))  # [b, h, n]
    # influence of S_in on token t: r_t ⊙ (prefix decay up to t-1)
    prefix = jnp.exp(jnp.cumsum(logw.reshape(b, l, h, n), axis=1)
                     - logw.reshape(b, l, h, n))
    infl = r.astype(jnp.float32) * prefix  # [b, l, h, n]
    return ScanResult(
        out=out.reshape(b, l, h, n), a_dev=a_dev, s_out=s_out, infl=infl
    )


def rwkv6_apply_influence(out: jax.Array, infl: jax.Array, s_in: jax.Array) -> jax.Array:
    return out + jnp.einsum("blhn,bhnm->blhm", infl, s_in)


def rwkv6_decode_step(r, k, v, w, u, s):  # all [B, H, N]; s [B, H, N, N]
    w = jnp.clip(w.astype(jnp.float32), EPS, 1.0)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    kv = kf[..., :, None] * vf[..., None, :]  # [B, H, N, N]
    o = jnp.einsum("bhn,bhnm->bhm", rf, s + u.astype(jnp.float32)[..., None] * kv)
    s = w[..., None] * s + kv
    return o, s


# ---------------------------------------------------------------------------
# SSD chunk scan (mamba2-style scalar-per-head decay) for hymba
# ---------------------------------------------------------------------------

def ssd_chunk_scan(
    x: jax.Array,  # [B, L, H, P] (P = channels per head)
    dt: jax.Array,  # [B, L, H] positive step sizes
    Bm: jax.Array,  # [B, L, H, N] input projection
    Cm: jax.Array,  # [B, L, H, N] output projection
    a: jax.Array,  # [H] negative per-head decay rate
    chunk: int = 64,
) -> ScanResult:
    b, l, h, p_ = x.shape
    n = Bm.shape[-1]
    c = min(chunk, l)
    assert l % c == 0
    nc = l // c
    dtl = dt.astype(jnp.float32).reshape(b, nc, c, h)
    loggam = dtl * a.astype(jnp.float32)  # log decay per token, ≤ 0
    T = jnp.cumsum(loggam, axis=2)  # within-chunk cumulative
    xs_ = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]).reshape(
        b, nc, c, h, p_)
    Bc = Bm.astype(jnp.float32).reshape(b, nc, c, h, n)
    Cc = Cm.astype(jnp.float32).reshape(b, nc, c, h, n)

    # intra-chunk: L[t,s] = exp(T_t - T_s), s <= t
    Lmat = jnp.exp(T[:, :, :, None] - T[:, :, None, :]).transpose(0, 1, 4, 2, 3)
    tri = jnp.tril(jnp.ones((c, c), bool))
    Lmat = jnp.where(tri[None, None, None], Lmat, 0.0)
    cb = jnp.einsum("bgthn,bgshn->bghts", Cc, Bc)
    out = jnp.einsum("bghts,bgshp->bgthp", cb * Lmat, xs_)

    # cross-chunk state carry: S [b, h, p, n]
    gam_c = jnp.exp(T[:, :, -1])  # [b, nc, h]
    # chunk state contribution: sum_s exp(T_c - T_s) ⊙ (xs_s ⊗ B_s)
    b_chunk = jnp.einsum("bgsh,bgshp,bgshn->bghpn",
                         jnp.exp(T[:, :, -1][:, :, None] - T), xs_, Bc)
    c_infl = jnp.exp(T)  # decay from chunk start to t (inclusive)

    def step(S, xsit):
        g, bg, Cg, inf = xsit
        o_corr = jnp.einsum("bth,bthn,bhpn->bthp", inf, Cg, S)
        S = g[..., None, None] * S + bg
        return S, o_corr

    S0 = jnp.zeros((b, h, p_, n), jnp.float32)
    xs_scan = (
        jnp.moveaxis(gam_c, 1, 0),
        jnp.moveaxis(b_chunk, 1, 0),
        jnp.moveaxis(Cc, 1, 0),
        jnp.moveaxis(c_infl, 1, 0),
    )
    s_out, o_corr = lax.scan(step, S0, xs_scan, unroll=True)
    out = out + jnp.moveaxis(o_corr, 0, 1)

    a_dev = jnp.exp(jnp.sum(loggam, axis=(1, 2)))  # [b, h]
    # influence: Γ_t (from device start) ⊙ C_t · S_in
    full_T = jnp.cumsum((dt.astype(jnp.float32) * a.astype(jnp.float32)), axis=1)
    infl = jnp.exp(full_T)[..., None] * Cm.astype(jnp.float32)  # [b, l, h, n]
    return ScanResult(
        out=out.reshape(b, l, h, p_), a_dev=a_dev, s_out=s_out, infl=infl
    )


def ssd_apply_influence(out, infl, s_in):
    return out + jnp.einsum("blhn,bhpn->blhp", infl, s_in)


def ssd_decode_step(x, dt, Bm, Cm, a, s):
    # x [B,H,P], dt [B,H], Bm/Cm [B,H,N], s [B,H,P,N]
    g = jnp.exp(dt.astype(jnp.float32) * a.astype(jnp.float32))  # [B,H]
    upd = jnp.einsum("bhp,bhn->bhpn", x.astype(jnp.float32) * dt[..., None], Bm)
    s = g[..., None, None] * s + upd
    o = jnp.einsum("bhpn,bhn->bhp", s, Cm.astype(jnp.float32))
    return o, s


# ---------------------------------------------------------------------------
# distributed exclusive prefix scan over SP ranks (log-depth ppermute)
# ---------------------------------------------------------------------------

def _exclusive_scan(a_dev, b_dev, axes, size):
    """Exclusive prefix 'composition' scan of per-device (A, B) recurrence
    summaries across the flattened SP axes.  Identity = (1, 0).

    Hillis-Steele inclusive scan (log₂ size ppermute rounds — wait-free
    one-sided hops, no ring serialisation), then shift right by one rank."""
    rank = lax.axis_index(axes)

    def bc(a, like):
        return a.reshape(a.shape + (1,) * (like.ndim - a.ndim))

    a, b = a_dev.astype(jnp.float32), b_dev.astype(jnp.float32)
    d = 1
    while d < size:
        perm = [(i, i + d) for i in range(size - d)]
        a_r = lax.ppermute(a, axes, perm)
        b_r = lax.ppermute(b, axes, perm)
        use = rank >= d
        new_a = a * a_r
        new_b = bc(a, b) * b_r + b
        a = jnp.where(use, new_a, a)
        b = jnp.where(bc(use, b), new_b, b)
        d *= 2
    # shift inclusive -> exclusive: take (a, b) of rank - 1; rank 0 = identity
    perm1 = [(i, i + 1) for i in range(size - 1)]
    b_prev = lax.ppermute(b, axes, perm1)
    s_in = jnp.where(rank >= 1, b_prev, jnp.zeros_like(b_prev))
    return s_in


def distributed_state_in(a_dev, s_out, axes, size):
    """S_in for each SP rank given per-rank (total decay, zero-init state)."""
    if size == 1:
        return jnp.zeros_like(s_out)
    return _exclusive_scan(a_dev, s_out, axes, size)
