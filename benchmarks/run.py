"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  See each module's docstring for
the figure it regenerates and the derivation caveats (this container is
CPU-only; multi-pod numbers come from the calibrated analytical model and
the dry-run roofline, not wall clocks).
"""
from __future__ import annotations

import sys


def main() -> None:
    from . import (
        ablation,
        comm_volume,
        config_sweep,
        e2e_latency,
        hybrid_sweep,
        kernel_bench,
        layerwise,
        roofline_table,
    )

    modules = {
        "comm_volume (Fig 3b / App D)": comm_volume,
        "e2e_latency (Fig 7)": e2e_latency,
        "config_sweep (Fig 8)": config_sweep,
        "layerwise (Fig 9)": layerwise,
        "ablation (Fig 10)": ablation,
        "kernel_bench (Fig 12)": kernel_bench,
        "roofline_table (assignment)": roofline_table,
        "hybrid_sweep (beyond-paper, DESIGN.md §7)": hybrid_sweep,
    }
    print("name,us_per_call,derived")
    ok = True
    for title, mod in modules.items():
        print(f"# --- {title} ---", file=sys.stderr)
        try:
            for line in mod.run():
                print(line)
        except Exception as e:  # keep the harness running, flag failure
            print(f"{title},NaN,ERROR:{type(e).__name__}:{e}")
            ok = False
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
