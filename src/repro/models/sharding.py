"""Logical-axis → mesh-axis sharding rules (MaxText-style).

Params carry logical axis names (built by ParamBuilder); these rules map
them to mesh axes per deployment mode.  The paper's serving setup keeps
model weights replicated across the SP group (DiTs are small, activations
are huge) — that is the default.  Big assigned archs override via
``ModelConfig.sharding_overrides`` (e.g. arctic shards experts over
'model' and expert hidden dims over 'data'); training additionally shards
optimizer-heavy dims over 'data' (ZeRO-style).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig

# logical axis -> tuple of mesh axes ((), = replicated)
BASE_RULES: dict[str, tuple[str, ...]] = {
    "vocab": (),
    "embed": (),
    "embed_out": (),
    "embed_norm": (),
    "mlp": (),
    "heads_flat": (),
    "kv_heads_flat": (),
    "experts": ("model",),
    "expert_mlp": ("data",),
    "ssm_heads": (),
    "layers": (),
}

TRAIN_EXTRAS: dict[str, tuple[str, ...]] = {
    # shard the optimizer-dominant dims over data (ZeRO / weight FSDP).
    # "vocab" stays per-config (whisper/hymba vocabs aren't divisible by 16).
    "mlp": ("data",),
    "heads_flat": ("data",),
    "kv_heads_flat": ("data",),
}


def rules_for(cfg: ModelConfig, mode: str,
              extra_rules: dict[str, tuple[str, ...]] | None = None
              ) -> dict[str, tuple[str, ...]]:
    rules = dict(BASE_RULES)
    if mode == "train":
        rules.update(TRAIN_EXTRAS)
    rules.update({k: tuple(v) for k, v in cfg.sharding_overrides})
    if extra_rules:  # e.g. {"layers": ("pipe",)} for patch pipelining
        rules.update(extra_rules)
    return rules


def _spec_of(logical: tuple[str | None, ...], rules, mesh: Mesh) -> P:
    entries = []
    for name in logical:
        axes = rules.get(name, ()) if name is not None else ()
        axes = tuple(a for a in axes if a in mesh.axis_names)
        entries.append(axes if axes else None)
    return P(*entries)


def param_shardings(axes_tree, cfg: ModelConfig, mesh: Mesh, mode: str,
                    extra_rules: dict[str, tuple[str, ...]] | None = None):
    """Pytree of NamedSharding mirroring the params pytree."""
    rules = rules_for(cfg, mode, extra_rules)
    is_leaf = lambda x: isinstance(x, tuple)
    return jax.tree.map(
        lambda lg: NamedSharding(mesh, _spec_of(lg, rules, mesh)),
        axes_tree,
        is_leaf=is_leaf,
    )


def param_pspecs(axes_tree, cfg: ModelConfig, mesh: Mesh, mode: str):
    """Same but raw PartitionSpecs (for in_shardings on lowered fns)."""
    rules = rules_for(cfg, mode)
    is_leaf = lambda x: isinstance(x, tuple)
    return jax.tree.map(
        lambda lg: _spec_of(lg, rules, mesh), axes_tree, is_leaf=is_leaf
    )
