"""Architecture configs: 10 assigned + the paper's 2 DiT workloads."""
from __future__ import annotations

import importlib

from .base import ModelConfig, MoEConfig, SSMConfig
from .shapes import DIT_SHAPES, SHAPES, InputShape

_MODULES = {
    "qwen2-vl-2b": "qwen2_vl_2b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "stablelm-3b": "stablelm_3b",
    "whisper-tiny": "whisper_tiny",
    "qwen2-1.5b": "qwen2_1_5b",
    "hymba-1.5b": "hymba_1_5b",
    "arctic-480b": "arctic_480b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "chatglm3-6b": "chatglm3_6b",
    "starcoder2-7b": "starcoder2_7b",
    "flux-12b": "flux_12b",
    "cogvideox-5b": "cogvideox_5b",
}

ASSIGNED_ARCHS = tuple(a for a in _MODULES if a not in ("flux-12b", "cogvideox-5b"))
DIT_ARCHS = ("flux-12b", "cogvideox-5b")
ALL_ARCHS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.CONFIG


def get_reduced(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.reduced()


__all__ = [
    "ALL_ARCHS",
    "ASSIGNED_ARCHS",
    "DIT_ARCHS",
    "DIT_SHAPES",
    "InputShape",
    "ModelConfig",
    "MoEConfig",
    "SHAPES",
    "SSMConfig",
    "get_config",
    "get_reduced",
]
