"""scripts/trace_report.py (DESIGN.md §12): Chrome trace rendering,
overlap-efficiency accounting, NetworkModel residual attribution, and the
--check gate — against synthetic span streams plus the checked-in fixture
(tests/data/span_trace_fixture.jsonl, a real ``commcheck --profile``
capture on the 8-fake-device mesh)."""
import importlib.util
import json
import pathlib
import sys

import pytest

from repro.core.comm_model import NetworkModel
from repro.serving.metrics import JsonlTracker, RecordingTracker

ROOT = pathlib.Path(__file__).resolve().parent.parent
FIXTURE = ROOT / "tests" / "data" / "span_trace_fixture.jsonl"

_spec = importlib.util.spec_from_file_location(
    "trace_report", ROOT / "scripts" / "trace_report.py")
tr = importlib.util.module_from_spec(_spec)
sys.modules["trace_report"] = tr
_spec.loader.exec_module(tr)


def _span(t, name, t0, dur, **tags):
    t.span_event(name, t0, dur, tags=tags or None)


def _synthetic():
    """One device track with a hidden leg, an exposed leg, and a compute
    block; one host engine.step span carrying model predictions."""
    t = RecordingTracker()
    t.epoch = 0.0
    dev = "pod=0,model=1"
    _span(t, "comm.compute", 1.00, 0.10, label="ring attend",
          stream="ring", track=dev, leg=5, occ=0)
    # fully hidden: runs inside the compute block, no stall
    _span(t, "comm.leg", 1.02, 0.04, stream="ring", channel="ring.shift1",
          stage=0, axes="pod,model", track=dev, leg=0, occ=0, nbytes=1 << 20,
          tensors=2, backend="xla", intent="ring attend", exposed_s=0.0)
    # half exposed: 20ms of its 40ms stalled the consumer
    _span(t, "comm.leg", 2.00, 0.04, stream="torus", channel="torus.hop1",
          stage=0, axes="pod", track=dev, leg=1, occ=0, nbytes=1 << 20,
          tensors=1, backend="xla", intent="gathered-Q attend",
          exposed_s=0.02)
    _span(t, "comm.exposed_wait", 2.02, 0.02, stream="torus",
          channel="torus.hop1", track=dev, leg=1, occ=0)
    with t.span("engine.step", step=0,
                tags={"pred_t_step_s": 0.5, "pred_compute_s": 0.25}):
        pass
    recs = list(t.records)
    # give the host step a real window for nesting/overlap math
    step = recs[-1]
    recs[-1] = type(step)(name=step.name, value=1.0, kind="span",
                          step=step.step, tags=step.tags, seq=step.seq,
                          t_start=0.5)
    return recs


# ---------------------------------------------------------------------------
# chrome trace
# ---------------------------------------------------------------------------

def test_chrome_trace_structure():
    spans = _synthetic()
    c = tr.chrome_trace(spans)
    xs = [e for e in c["traceEvents"] if e["ph"] == "X"]
    metas = [e for e in c["traceEvents"] if e["ph"] == "M"]
    assert len(xs) == len(spans)
    # host track exists and is tid 0; the device track has its own tid
    names = {e["args"]["name"] for e in metas if e["name"] == "thread_name"}
    assert names == {"host", "pod=0,model=1"}
    host_tid = next(e["tid"] for e in metas
                    if e["name"] == "thread_name"
                    and e["args"]["name"] == "host")
    assert host_tid == 0
    # µs timebase; display names come from channel/label tags
    leg = next(e for e in xs if e["name"] == "ring.shift1")
    assert leg["ts"] == pytest.approx(1.02e6)
    assert leg["dur"] == pytest.approx(0.04e6)
    assert leg["cat"] == "comm"
    assert {e["name"] for e in xs} >= {"ring attend", "torus.hop1",
                                       "engine.step"}
    json.dumps(c)  # serializable


# ---------------------------------------------------------------------------
# overlap table
# ---------------------------------------------------------------------------

def test_overlap_table_measured_vs_intended():
    rows = {(r["stream"], r["channel"]): r
            for r in tr.overlap_table(_synthetic())}
    ring = rows[("ring", "ring.shift1")]
    assert ring["hidden_frac"] == pytest.approx(1.0)
    assert ring["intended_hidden"] is True
    # the whole leg ran under the marked compute block
    assert ring["compute_overlap_frac"] == pytest.approx(1.0)
    torus = rows[("torus", "torus.hop1")]
    assert torus["hidden_frac"] == pytest.approx(0.5)  # 20ms of 40ms stalled
    assert torus["intended_hidden"] is True
    assert torus["compute_overlap_frac"] == pytest.approx(0.0)
    text = tr.format_overlap(list(rows.values()))
    assert "ring/ring.shift1/s0" in text


def test_sem_intent_not_counted_as_intended():
    t = RecordingTracker()
    t.epoch = 0.0
    _span(t, "comm.leg", 1.0, 0.01, stream="torus",
          channel="torus.hop1.semwait", stage=0, axes="pod", track="d",
          leg=0, occ=0, nbytes=8, tensors=1, backend="pallas", intent="sem")
    (row,) = tr.overlap_table(t.records)
    assert row["intended_hidden"] is False


# ---------------------------------------------------------------------------
# residuals
# ---------------------------------------------------------------------------

def test_leg_residuals_classify_and_attribute():
    net = NetworkModel()
    res = {(r["stream"], r["channel"]): r
           for r in tr.leg_residuals(_synthetic(), net,
                                     inter_axes=frozenset({"pod"}))}
    # axes "pod,model" touches pod => inter; pure-"pod" leg too
    ring = res[("ring", "ring.shift1")]
    assert ring["cls"] == "inter" and ring["bw_term"] == "inter_bw"
    meas = ring["measured_us"] / 1e6
    pred = (1 << 20) / net.inter_bw + net.inter_lat + net.step_issue_overhead
    assert ring["predicted_us"] == pytest.approx(pred * 1e6)
    assert ring["ratio"] == pytest.approx(meas / pred)
    # implied bw: the bytes over whatever time is left after model overhead
    wire = meas - net.inter_lat - net.step_issue_overhead
    assert ring["implied_bw"] == pytest.approx((1 << 20) / wire)
    text = tr.format_residuals(list(res.values()),
                               tr.step_residuals(_synthetic(), net), net)
    assert "inter_bw" in text


def test_step_residuals_from_engine_tags():
    net = NetworkModel()
    step = tr.step_residuals(_synthetic(), net)
    assert step["n_steps"] == 1
    assert step["measured_step_s"] == pytest.approx(1.0)
    assert step["pred_step_s"] == pytest.approx(0.5)
    assert step["step_ratio"] == pytest.approx(2.0)
    # one compute span of 0.1s on one track, one step
    assert step["measured_compute_s"] == pytest.approx(0.10)
    assert step["implied_mfu"] == pytest.approx(net.mfu * 0.25 / 0.10)
    assert tr.step_residuals([], net) is None


# ---------------------------------------------------------------------------
# --check gate
# ---------------------------------------------------------------------------

def test_check_passes_on_good_trace():
    spans = _synthetic()
    assert tr.check_trace(spans, tr.chrome_trace(spans)) == []


def test_check_flags_missing_overlap_and_bad_nesting():
    t = RecordingTracker()
    t.epoch = 0.0
    _span(t, "comm.leg", 1.0, 0.01, stream="r", channel="c", stage=0,
          axes="pod", track="d", leg=0, occ=0, nbytes=8, tensors=1,
          backend="xla", intent="")
    _span(t, "comm.compute", 5.0, 0.01, label="x", stream="r", track="d",
          leg=1, occ=0)  # disjoint from the leg
    _span(t, "plan_cache.trace", 9.0, 0.01, parent="engine.step")  # orphan
    errs = tr.check_trace(t.records, tr.chrome_trace(t.records))
    assert any("overlap" in e for e in errs)
    assert any("nested" in e for e in errs)
    assert tr.check_trace([], {}) == ["trace contains no span records"]


# ---------------------------------------------------------------------------
# the checked-in fixture end to end
# ---------------------------------------------------------------------------

def test_fixture_trace_renders_and_checks(tmp_path):
    spans = tr.load_spans(FIXTURE)
    assert spans, "fixture is empty"
    chrome = tr.chrome_trace(spans)
    assert tr.check_trace(spans, chrome) == []
    rows = tr.overlap_table(spans)
    assert rows and any(r["intended_hidden"] for r in rows)
    # the pallas landing-protocol spans ride along in the fixture
    assert any(r["backend"] == "pallas" for r in rows)
    res = tr.leg_residuals(spans, NetworkModel(), frozenset({"pod"}))
    assert res and all(r["measured_us"] > 0 for r in res)
    # main() end to end (writes chrome, prints tables, --check passes)
    out = tmp_path / "chrome.json"
    tr.main([str(FIXTURE), "--chrome", str(out), "--check"])
    assert json.loads(out.read_text())["traceEvents"]


def test_load_spans_tolerates_truncated_tail(tmp_path):
    p = tmp_path / "t.jsonl"
    t = JsonlTracker(p)
    t.span_event("comm.leg", 0.0, 1.0, tags={"stream": "r", "channel": "c"})
    t.flush()
    with p.open("a") as fh:
        fh.write('{"kind": "span", "name": "cut')  # crashed writer
    t.close()
    (r,) = tr.load_spans(p)
    assert r.name == "comm.leg"
