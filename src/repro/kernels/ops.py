"""Jitted wrappers around the flash_mqkv / ring_flash Pallas kernels.

``flash_attention``     — [B, L, H, D]-layout entry point with GQA,
                          padding to block multiples, position arrays.
``flash_attention_segments`` — the Algorithm-2 use case: one Q against a
                          *list* of discontiguous KV chunks, carrying the
                          online-softmax state across kernel calls and
                          finalizing once (Appendix C).

Dispatch discipline: every variant knob that selects a different lowering
— ``backend`` ("pallas" kernel vs "xla" jnp fallback), ``fused`` (the
ring_flash kernel that issues its own DMA vs plain flash_mqkv), and
``interpret`` — lives in ONE variant tuple (``STATIC_ARGNAMES``), the
``lru_cache`` key of ``_dispatch``, which builds one jitted closure per
key.  A partial key (the historical bug: keying on ``interpret`` but not
``backend``) would hand the xla variant a cached pallas trace and
vice-versa; ``tests/test_ring_flash.py`` counts traces per key to pin
this down.
"""
from __future__ import annotations

import functools
import inspect

import jax
import jax.numpy as jnp

from .flash_mqkv import DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q, flash_mqkv
from .ref import flash_attention_ref
from .ring_flash import ring_flash_step

# the ONE variant key: lowering variants must never share a jit cache
# entry; asserted below to match _dispatch's signature exactly
STATIC_ARGNAMES = ("causal", "window", "scale", "block_q", "block_k",
                   "interpret", "backend", "fused")

# traces per static key (trace-time side effect; the regression counter)
_trace_counts: dict[tuple, int] = {}


def trace_counts() -> dict[tuple, int]:
    """Snapshot of jit traces per static dispatch key."""
    return dict(_trace_counts)


def reset_trace_counts() -> None:
    _trace_counts.clear()


def _pad_to(x: jax.Array, axis: int, mult: int, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _flatten_heads(x: jax.Array) -> jax.Array:
    b, l, h, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, l, d)


def _unflatten_heads(x: jax.Array, b: int, h: int) -> jax.Array:
    bh, l, d = x.shape
    return x.reshape(b, h, l, d).transpose(0, 2, 1, 3)


def _step(qf, kf, vf, qpp, kpp, *, group, scale, causal, window, state,
          finalize, block_q, block_k, interpret, backend, fused):
    """One kernel step on flattened [BH, L, D] operands, by variant."""
    if backend == "xla":
        kr = jnp.repeat(kf, group, axis=0) if group > 1 else kf
        vr = jnp.repeat(vf, group, axis=0) if group > 1 else vf
        out = flash_attention_ref(
            qf, kr, vr, qpp, kpp, scale=scale, causal=causal, window=window,
            state=state, finalize=finalize)
        return out if not finalize else (out, None, None)
    if fused:
        (o, l, m), _ = ring_flash_step(
            qf, kf, vf, qpp, kpp, group=group, scale=scale, causal=causal,
            window=window, state=state, finalize=finalize,
            block_q=block_q, block_k=block_k, interpret=interpret)
        return o, l, m
    return flash_mqkv(
        qf, kf, vf, qpp, kpp, group=group, scale=scale, causal=causal,
        window=window, state=state, finalize=finalize,
        block_q=block_q, block_k=block_k, interpret=interpret)


@functools.lru_cache(maxsize=None)
def _dispatch(causal, window, scale, block_q, block_k, interpret, backend,
              fused):
    """Build (and cache) the jitted impl for one static-variant key.

    The lru_cache key IS the full variant tuple (one jitted closure per
    key — the knobs are closure constants, not jit static args), so no
    two variants can collide on a cache entry.
    """
    key = (causal, window, scale, block_q, block_k, interpret, backend,
           fused)

    @jax.jit
    def impl(q, k, v, q_pos, k_pos):
        _trace_counts[key] = _trace_counts.get(key, 0) + 1
        b, lq, hq, d = q.shape
        _, lk, hkv, _ = k.shape
        group = hq // hkv
        bq = min(block_q, max(8, lq))
        bk = min(block_k, max(8, lk))
        qf = _pad_to(_flatten_heads(q), 1, bq)
        kf = _pad_to(_flatten_heads(k), 1, bk)
        vf = _pad_to(_flatten_heads(v), 1, bk)
        qpp = _pad_to(q_pos.astype(jnp.int32), 0, bq, value=0)
        kpp = _pad_to(k_pos.astype(jnp.int32), 0, bk, value=-1)
        o, _, _ = _step(
            qf, kf, vf, qpp, kpp, group=group, scale=scale, causal=causal,
            window=window, state=None, finalize=True, block_q=bq, block_k=bk,
            interpret=interpret, backend=backend, fused=fused)
        return _unflatten_heads(o[:, :lq], b, hq)

    return impl


# the canonical key ordering and the dispatch signature must not drift
assert tuple(
    inspect.signature(_dispatch.__wrapped__).parameters) == STATIC_ARGNAMES


def flash_attention(
    q: jax.Array,  # [B, Lq, Hq, D]
    k: jax.Array,  # [B, Lk, Hkv, D]
    v: jax.Array,
    q_pos: jax.Array | None = None,  # [Lq]
    k_pos: jax.Array | None = None,  # [Lk]
    *,
    causal: bool = False,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
    backend: str = "pallas",
    fused: bool = False,
) -> jax.Array:
    """Drop-in flash attention; returns [B, Lq, Hq, D].

    ``backend="pallas"`` runs the Pallas kernel (``fused=True`` selects
    the ring_flash variant that also issues its forwarding DMA);
    ``backend="xla"`` runs the pure-jnp lowering (platforms without
    Pallas).  All three produce the same values.  Note ``fused=True``
    here discards the forward buffers (and pays their copy) — its
    consumer is core/ring.py's pallas path; on this entry point it
    exists for parity and dispatch testing, not as a perf knob.
    """
    lq, lk = q.shape[1], k.shape[1]
    if q_pos is None:
        q_pos = jnp.arange(lq, dtype=jnp.int32)
    if k_pos is None:
        k_pos = jnp.arange(lk, dtype=jnp.int32)
    impl = _dispatch(causal, window, scale, block_q, block_k, interpret,
                     backend, fused)
    return impl(q, k, v, q_pos, k_pos)


def flash_attention_segments(
    q: jax.Array,  # [B, Lq, Hq, D]
    segments: list[tuple[jax.Array, jax.Array, jax.Array]],  # (k, v, k_pos)
    q_pos: jax.Array | None = None,
    *,
    causal: bool = False,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
    backend: str = "pallas",
    fused: bool = False,
) -> jax.Array:
    """Attention of one Q against multiple discontiguous KV chunks — the
    RINGATTN inner loop of Algorithm 1 with the Algorithm-2 fused merge:
    the (O', l, m) state is carried across kernel calls, one division at
    the very end."""
    b, lq, hq, d = q.shape
    if q_pos is None:
        q_pos = jnp.arange(lq, dtype=jnp.int32)
    bq = min(block_q, max(8, lq))
    qf = _pad_to(_flatten_heads(q), 1, bq)
    qpp = _pad_to(q_pos.astype(jnp.int32), 0, bq, value=0)

    state = None
    for i, (k, v, k_pos) in enumerate(segments):
        _, lk, hkv, _ = k.shape
        group = hq // hkv
        bk = min(block_k, max(8, lk))
        kf = _pad_to(_flatten_heads(k), 1, bk)
        vf = _pad_to(_flatten_heads(v), 1, bk)
        kpp = _pad_to(k_pos.astype(jnp.int32), 0, bk, value=-1)
        last = i == len(segments) - 1
        out = _step(
            qf, kf, vf, qpp, kpp, group=group, scale=scale, causal=causal,
            window=window, state=state, finalize=last, block_q=bq, block_k=bk,
            interpret=interpret, backend=backend, fused=fused)
        if last:
            o = out[0]
        else:
            state = out
    return _unflatten_heads(o[:, :lq].astype(q.dtype), b, hq)
