"""Paper Fig. 10: ablation — USP → +TAS → +Torus → +one-sided.

Model mapping of the ablation steps (DESIGN.md §2): TAS flips the
boundary; Torus enables inter-machine overlap; the one-sided step removes
the per-step rendezvous latency (modelled as the per-hop latency term,
which ppermute/NVSHMEM avoid paying per transfer).
"""
from __future__ import annotations

import dataclasses

from repro.core import plan, usp_plan
from repro.core.comm_model import (
    LayerWorkload,
    NetworkModel,
    attention_layer_latency,
)

from .common import row

N, M_PER = 4, 8
WORKLOADS = {
    "flux_3072": LayerWorkload(batch=1, seq=36_864, heads=24, head_dim=128),
    "cogvideox_20s": LayerWorkload(batch=1, seq=49_152, heads=24, head_dim=64),
    "cogvideox_40s": LayerWorkload(batch=1, seq=98_304, heads=24, head_dim=64),
}


def run() -> list[str]:
    rows = []
    net = NetworkModel(inter_lat=5e-5)  # EFA-class per-rendezvous latency
    for wname, wl in WORKLOADS.items():
        steps = {
            "usp": attention_layer_latency(
                usp_plan(N, M_PER, wl.heads), wl, swift=False,
                overlap_inter=False, net=net),
            "tas": attention_layer_latency(
                plan(N, M_PER, wl.heads), wl, swift=True,
                overlap_inter=False, net=net),
            "tas+torus": attention_layer_latency(
                plan(N, M_PER, wl.heads), wl, swift=True,
                overlap_inter=True, net=net),
            "tas+torus+onesided": attention_layer_latency(
                plan(N, M_PER, wl.heads), wl, swift=True,
                overlap_inter=True, one_sided=True, net=net),
        }
        base = steps["usp"]["t_total"]
        for name, r in steps.items():
            rows.append(row(f"ablation/{wname}/{name}", r["t_total"] * 1e6,
                            f"norm={r['t_total'] / base:.3f}"))
    return rows
