"""Production mesh construction (assignment MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (fake or real) devices exist — used by
    smoke tests, examples, and the multidevice test suite."""
    return make_mesh((data, model), ("data", "model"))


def make_hybrid_mesh(cfg: int = 1, pipe: int = 1, data: int = 1,
                     model: int = 1) -> jax.sharding.Mesh:
    """(cfg, pipe, data, model) mesh for hybrid-parallel DiT serving
    (DESIGN.md §7).

    Axis order mirrors the planner's boundary preference: cfg (syncs once
    per step) outermost, then pipe (stage hand-offs), then the batch and
    SP axes — on real hardware the outer axes land on the slow network.
    Size-1 axes are kept so one SPConfig works across degrees.
    """
    return make_mesh((cfg, pipe, data, model), ("cfg", "pipe", "data", "model"))
