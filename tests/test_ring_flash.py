"""Fused ring-step kernel vs flash_mqkv, and the ops dispatch regression.

The ring_flash kernel reuses flash_mqkv's body on the same refs, so the
attention outputs must agree *bitwise* on every configuration — random
chunk counts, k_pos = -1 padding layouts, causal/window masks, GQA, and
carried (O', l, m) state (mini-hypothesis sweeps).  The forwarded KV
buffers must equal the inputs (the in-kernel DMA is a copy).

The dispatch regression pins kernels/ops.py's static-arg discipline: all
variant knobs (backend, fused, interpret) share ONE static tuple, so no
two lowering variants can collide on a cached trace.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import (
    flash_attention,
    flash_attention_segments,
    reset_trace_counts,
    ring_flash_step,
    trace_counts,
)
from repro.kernels.flash_mqkv import flash_mqkv


def _mk_flat(seed, bh, l, d, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (bh, l, d), dtype),
            jax.random.normal(ks[1], (bh, l, d), dtype),
            jax.random.normal(ks[2], (bh, l, d), dtype))


# ---------------------------------------------------------------------------
# property sweeps: ring_flash single step == flash_mqkv, bitwise
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4), st.integers(0, 15), st.booleans(),
       st.sampled_from([None, 24]))
def test_ring_flash_matches_flash_mqkv(n_chunks, pad, causal, window):
    """Random chunk counts / padding layouts / masks: identical (o, l, m)
    and exact forwarded buffers, with the state carried across chunks."""
    bh, d, bq, bk = 2, 16, 16, 16
    lq = 32
    lk = n_chunks * bk
    q, _, _ = _mk_flat(n_chunks * 31 + pad, bh, lq, d)
    _, k, v = _mk_flat(pad * 17 + 3, bh, lk, d)
    qp = jnp.arange(lq, dtype=jnp.int32) + lk  # q after all k (causal-safe)
    # padding layout: last `pad` k slots invalid, garbage in the data
    kp = jnp.where(jnp.arange(lk) < lk - min(pad, lk - 1),
                   jnp.arange(lk), -1).astype(jnp.int32)
    k = jnp.where((kp < 0)[None, :, None], 999.0, k)
    v = jnp.where((kp < 0)[None, :, None], 999.0, v)

    state = None
    for c in range(n_chunks):
        sl = slice(c * bk, (c + 1) * bk)
        args = (q, k[:, sl], v[:, sl], qp, kp[sl])
        kw = dict(causal=causal, window=window, state=state,
                  finalize=c == n_chunks - 1, block_q=bq, block_k=bk,
                  interpret=True)
        ref = flash_mqkv(*args, **kw)
        (o, l, m), (kf, vf) = ring_flash_step(*args, **kw)
        np.testing.assert_array_equal(np.asarray(o), np.asarray(ref[0]))
        np.testing.assert_array_equal(np.asarray(l), np.asarray(ref[1]))
        np.testing.assert_array_equal(np.asarray(m), np.asarray(ref[2]))
        np.testing.assert_array_equal(np.asarray(kf), np.asarray(k[:, sl]))
        np.testing.assert_array_equal(np.asarray(vf), np.asarray(v[:, sl]))
        state = ref if c < n_chunks - 1 else None


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([1, 2, 4]), st.booleans())
def test_ring_flash_gqa_groups(group, causal):
    bh_kv, d = 2, 16
    q, _, _ = _mk_flat(11, bh_kv * group, 32, d)
    _, k, v = _mk_flat(12, bh_kv, 32, d)
    pos = jnp.arange(32, dtype=jnp.int32)
    ref = flash_mqkv(q, k, v, pos, pos, group=group, causal=causal,
                     block_q=16, block_k=16, interpret=True)
    (o, l, m), _ = ring_flash_step(q, k, v, pos, pos, group=group,
                                   causal=causal, block_q=16, block_k=16,
                                   interpret=True)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(l), np.asarray(ref[1]))
    np.testing.assert_array_equal(np.asarray(m), np.asarray(ref[2]))


def test_segments_fused_matches_unfused():
    """flash_attention_segments through the fused kernel == plain kernel."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (1, 32, 4, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))
    kp = jnp.arange(64, dtype=jnp.int32)
    segs = [(k[:, :32], v[:, :32], kp[:32]), (k[:, 32:], v[:, 32:], kp[32:])]
    qp = jnp.arange(32) + 32
    a = flash_attention_segments(q, segs, q_pos=qp, causal=True,
                                 block_q=16, block_k=16, interpret=True,
                                 fused=False)
    b = flash_attention_segments(q, segs, q_pos=qp, causal=True,
                                 block_q=16, block_k=16, interpret=True,
                                 fused=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("backend,fused", [("pallas", False),
                                           ("pallas", True),
                                           ("xla", False)])
def test_flash_attention_backends_agree(backend, fused):
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (2, 48, 4, 32))
    k = jax.random.normal(ks[1], (2, 48, 2, 32))
    v = jax.random.normal(ks[2], (2, 48, 2, 32))
    ref = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                          interpret=True)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                          interpret=True, backend=backend, fused=fused)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# dispatch regression: variants never collide on a cached trace
# ---------------------------------------------------------------------------

def test_dispatch_traces_once_per_variant():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 16))
    k = jax.random.normal(ks[1], (1, 32, 2, 16))
    v = jax.random.normal(ks[2], (1, 32, 2, 16))
    reset_trace_counts()

    variants = [
        dict(backend="pallas", fused=False),
        dict(backend="pallas", fused=True),
        dict(backend="xla", fused=False),
    ]
    for kw in variants:
        for _ in range(3):  # repeats must hit the cache, not re-trace
            flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                            interpret=True, **kw)
    counts = trace_counts()
    # one distinct static key per variant — a collision would show up as
    # fewer keys (variants sharing a trace) or counts > 1 (re-tracing)
    assert len(counts) == len(variants), counts
    assert all(n == 1 for n in counts.values()), counts
    keys = set(counts)
    assert {(kk[-2], kk[-1]) for kk in keys} == {
        ("pallas", False), ("pallas", True), ("xla", False)}
