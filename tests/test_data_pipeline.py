"""Synthetic data pipeline: determinism + learnable structure."""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.configs.shapes import InputShape
from repro.train.data import SyntheticStream

SHAPE = InputShape("t", 64, 4, "training")


def _stream(arch="qwen2-1.5b", seed=0):
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    return SyntheticStream(cfg, SHAPE, seed)


def test_deterministic_per_step():
    a = _stream().batch(3)
    b = _stream().batch(3)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_different_steps_differ():
    s = _stream()
    a, b = s.batch(0), s.batch(1)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_labels_are_next_tokens():
    b = _stream().batch(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_markov_structure_learnable():
    """~80% of transitions follow the fixed permutation (the structure the
    train example's loss-decrease test relies on)."""
    s = _stream()
    hits = tot = 0
    perm = np.random.default_rng(0).permutation(512)
    for step in range(5):
        b = s.batch(step)
        tok = np.asarray(b["tokens"])
        lab = np.asarray(b["labels"])
        hits += int(np.sum(lab == perm[tok]))
        tot += lab.size
    assert 0.7 < hits / tot < 0.9


def test_vlm_batch_has_embeddings_and_mrope():
    b = _stream("qwen2-vl-2b").batch(0)
    assert "inputs_embeds" in b and b["inputs_embeds"].ndim == 3
    assert b["positions"].shape[0] == 3


def test_whisper_batch_has_frames():
    b = _stream("whisper-tiny").batch(0)
    assert "frames" in b
    assert b["frames"].shape[1] == _stream("whisper-tiny").cfg.encoder_seq
