"""Shared transformer building blocks (pure-functional, pytree params).

Conventions:
  * params are nested dicts of jnp arrays; a parallel pytree of *logical
    axis names* is built at init time (see ParamBuilder) and mapped to mesh
    axes by models/sharding.py.
  * layer stacks are ``lax.scan`` over stacked weights (leading "layers"
    dim) — keeps HLO size O(1) in depth for the 40-pair dry-run.
  * attention dispatches to core.sp_attention (train/prefill) or
    core.decode_attention (decode) based on the ParallelContext.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from ..core import SPConfig, decode_attention, sp_attention
from ..core.pipefusion import displaced_attention

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------

class ParamBuilder:
    """Builds a params pytree and a mirrored logical-axes pytree in lockstep,
    so sharding specs can never drift from the actual structure."""

    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self.key = key
        self.dtype = dtype
        self.params: Params = {}
        self.axes: Params = {}

    def _next(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub

    def add(self, name: str, shape: tuple[int, ...], logical: tuple[str | None, ...],
            init: str = "normal", scale: float | None = None) -> None:
        assert len(shape) == len(logical), (name, shape, logical)
        if init == "normal":
            if scale is None:
                scale = shape[0] ** -0.5  # fan-in
            arr = jax.random.normal(self._next(), shape, self.dtype) * scale
        elif init == "zeros":
            arr = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            arr = jnp.ones(shape, self.dtype)
        else:
            raise ValueError(init)
        _nested_set(self.params, name, arr)
        _nested_set(self.axes, name, logical)


def _nested_set(d: dict, path: str, val) -> None:
    keys = path.split("/")
    for k in keys[:-1]:
        d = d.setdefault(k, {})
    d[keys[-1]] = val


def stack_layers(init_fn: Callable[[jax.Array], tuple[Params, Params]],
                 n_layers: int, key: jax.Array) -> tuple[Params, Params]:
    """vmap a per-layer init over layer keys -> stacked params with a
    leading 'layers' logical axis."""
    keys = jax.random.split(key, n_layers)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, axes = init_fn(key)  # structure only
    axes = jax.tree.map(
        lambda a: ("layers",) + tuple(a), axes,
        is_leaf=lambda x: isinstance(x, tuple),
    )
    return params, axes


# ---------------------------------------------------------------------------
# context
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParallelContext:
    """Everything a model needs to know about how it is distributed."""

    mesh: jax.sharding.Mesh
    sp: SPConfig
    mode: str = "train"  # train | prefill | decode
    # activation-checkpoint policy for the layer scan (train mode):
    #   full — recompute everything (min HBM);  dots — save matmul outputs
    #   (jax dots_with_no_batch_dims_saveable);  none — save all residuals
    remat: str = "full"
    # decode-mode MoE: gather tokens over 'data' instead of all-gathering
    # FSDP'd expert weights every step (beyond-paper, §Perf)
    ep_token_gather: bool = False

    @property
    def decode(self) -> bool:
        return self.mode == "decode"

    def remat_wrap(self, body):
        if self.mode != "train" or self.remat == "none":
            return body
        if self.remat == "dots":
            return jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        return jax.checkpoint(body)


# ---------------------------------------------------------------------------
# basic ops
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(dt)


def norm(x: jax.Array, p: Params, kind: str) -> jax.Array:
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def init_norm(b: ParamBuilder, name: str, d: int, kind: str) -> None:
    b.add(f"{name}/scale", (d,), ("embed_norm",), init="ones")
    if kind == "layernorm":
        b.add(f"{name}/bias", (d,), ("embed_norm",), init="zeros")


def linear(x: jax.Array, p: Params) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, p["w"].astype(x.dtype))
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_linear(b: ParamBuilder, name: str, d_in: int, d_out: int,
                logical: tuple[str | None, str | None], bias: bool = False,
                init: str = "normal", scale: float | None = None) -> None:
    b.add(f"{name}/w", (d_in, d_out), logical, init=init, scale=scale)
    if bias:
        b.add(f"{name}/b", (d_out,), (logical[1],), init="zeros")


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


# ---------------------------------------------------------------------------
# rotary position embeddings (all assigned variants)
# ---------------------------------------------------------------------------

def _rope_angles(positions: jax.Array, rot_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions [...] -> (sin, cos) of shape [..., rot_dim // 2]."""
    freqs = theta ** (-jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.sin(ang), jnp.cos(ang)


def _rotate(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """Rotate pairs (x[..., :r/2], x[..., r/2:]) — GPT-NeoX convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(
    q: jax.Array,  # [B, L, H, D]
    k: jax.Array,
    positions: jax.Array,  # [B, L] or [3, B, L] for mrope
    *,
    variant: str,
    theta: float,
    rope_pct: float = 1.0,
) -> tuple[jax.Array, jax.Array]:
    if variant in ("none", "sinusoidal"):
        return q, k
    d = q.shape[-1]
    if variant == "rope2d":
        rot = d // 2  # chatglm: rotary on half the head dim
    else:
        rot = int(d * rope_pct) // 2 * 2

    def rot_fn(x):
        xr, xp = x[..., :rot], x[..., rot:]
        if variant == "mrope":
            # 3 position components (t, h, w) over 3 sections of the rotary
            # half-dims (qwen2-vl §2.1); section sizes ~ equal thirds.
            half = rot // 2
            s1, s2 = half // 3, 2 * (half // 3)
            sin, cos = [], []
            for c, (lo, hi) in enumerate(((0, s1), (s1, s2), (s2, half))):
                freqs = theta ** (-jnp.arange(0, rot, 2, dtype=jnp.float32) / rot)
                ang = positions[c][..., None].astype(jnp.float32) * freqs[lo:hi]
                sin.append(jnp.sin(ang))
                cos.append(jnp.cos(ang))
            sin = jnp.concatenate(sin, axis=-1)[:, :, None, :]
            cos = jnp.concatenate(cos, axis=-1)[:, :, None, :]
        else:
            sin, cos = _rope_angles(positions, rot, theta)
            sin, cos = sin[:, :, None, :], cos[:, :, None, :]
        return jnp.concatenate([_rotate(xr, sin, cos).astype(x.dtype), xp], axis=-1)

    return rot_fn(q), rot_fn(k)


def sinusoidal_embedding(length: int, d: int) -> jax.Array:
    """Whisper-style sinusoidal positional table [length, d]."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = jnp.arange(length)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# attention block
# ---------------------------------------------------------------------------

def init_attention(b: ParamBuilder, cfg, prefix: str = "attn",
                   cross: bool = False) -> None:
    d, hq, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    init_linear(b, f"{prefix}/wq", d, hq * hd, ("embed", "heads_flat"), bias=cfg.qkv_bias)
    init_linear(b, f"{prefix}/wk", d, hkv * hd, ("embed", "kv_heads_flat"), bias=cfg.qkv_bias)
    init_linear(b, f"{prefix}/wv", d, hkv * hd, ("embed", "kv_heads_flat"), bias=cfg.qkv_bias)
    init_linear(b, f"{prefix}/wo", hq * hd, d, ("heads_flat", "embed"),
                scale=(hq * hd) ** -0.5 / (2 * cfg.n_layers) ** 0.5)


def attention(
    x: jax.Array,  # [B, L, d]
    p: Params,
    cfg,
    ctx: ParallelContext,
    positions: jax.Array,
    *,
    window: int | jax.Array | None = None,
    kv_cache: tuple[jax.Array, jax.Array] | None = None,
    cur_index: jax.Array | None = None,
    xkv: jax.Array | None = None,  # cross-attention source (whisper decoder)
    causal: bool | None = None,
    extra_kv: tuple[jax.Array, jax.Array] | None = None,
    return_kv: bool = False,
):
    """Returns (output [B, L, d], updated kv_cache or None).

    ``extra_kv`` — one-step-stale full-sequence KV of the *non-resident*
    rows for displaced patch pipelining (PipeFusion; DESIGN.md §7): K is
    already post-RoPE, and the patch's fresh KV is merged with it via the
    Appendix-C partial algebra instead of the SP schedule (the resident
    patch and the stale rows have different sequence lengths, so the
    equal-shard SP collectives don't apply).  Only valid for
    non-causal, unwindowed attention (DiT).

    ``return_kv`` — additionally return this call's (post-RoPE K, V) as a
    third element, so the sampler can populate the stale-KV state.
    """
    b_, l_, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    causal = cfg.causal if causal is None else causal
    src = x if xkv is None else xkv

    q = linear(x, p["wq"]).reshape(b_, l_, hq, hd)
    k = linear(src, p["wk"]).reshape(b_, src.shape[1], hkv, hd)
    v = linear(src, p["wv"]).reshape(b_, src.shape[1], hkv, hd)
    if xkv is None:  # no rope on cross-attention
        q, k = apply_rope(q, k, positions, variant=cfg.rope, theta=cfg.rope_theta,
                          rope_pct=cfg.rope_pct)

    if extra_kv is not None:
        assert not ctx.decode and xkv is None
        assert not causal and window is None, (
            "displaced attention is DiT-only (bidirectional, unwindowed)")
        o = displaced_attention(q, k, v, extra_kv[0], extra_kv[1])
        new_cache = None
    elif ctx.decode and xkv is None:
        assert kv_cache is not None and cur_index is not None
        kc, vc = kv_cache
        o, kc, vc = decode_attention(
            q, kc, vc, k, v, cur_index,
            mesh=ctx.mesh, cfg=ctx.sp, window=window,
        )
        new_cache = (kc, vc)
    elif ctx.decode:  # cross-attention during decode: q len 1 vs full memory
        o = sp_attention(q, k, v, mesh=ctx.mesh, cfg=_xattn_cfg(ctx.sp),
                         causal=False, window=None)
        new_cache = kv_cache
    else:
        o = sp_attention(q, k, v, mesh=ctx.mesh, cfg=ctx.sp, causal=causal,
                         window=_static_window(window))
        new_cache = None
    o = o.reshape(b_, l_, hq * hd)
    out = linear(o, p["wo"])
    if return_kv:
        return out, new_cache, (k, v)
    return out, new_cache


def _static_window(window):
    """sp_attention's mask plumbing accepts traced windows; None stays None."""
    return window


def _xattn_cfg(sp: SPConfig) -> SPConfig:
    """Cross-attention with a decode-mode 1-token q: run unsharded (the
    encoder memory is small relative to self-attention caches)."""
    return dataclasses.replace(sp, strategy="full")


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(b: ParamBuilder, cfg, prefix: str = "mlp", d_ff: int | None = None,
             logical_ff: str = "mlp") -> None:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    if cfg.act in ("swiglu", "geglu"):
        init_linear(b, f"{prefix}/wi_gate", d, ff, ("embed", logical_ff))
        init_linear(b, f"{prefix}/wi_up", d, ff, ("embed", logical_ff))
    else:
        init_linear(b, f"{prefix}/wi_up", d, ff, ("embed", logical_ff))
    init_linear(b, f"{prefix}/wo", ff, d, (logical_ff, "embed"),
                scale=ff ** -0.5 / (2 * cfg.n_layers) ** 0.5)


def mlp(x: jax.Array, p: Params, cfg) -> jax.Array:
    if cfg.act == "swiglu":
        h = jax.nn.silu(linear(x, p["wi_gate"])) * linear(x, p["wi_up"])
    elif cfg.act == "geglu":
        h = gelu(linear(x, p["wi_gate"])) * linear(x, p["wi_up"])
    else:
        h = gelu(linear(x, p["wi_up"]))
    return linear(h, p["wo"])
