"""Beyond-paper optimizations preserve numerics (8 fake devices)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import SPConfig
from repro.models import ParallelContext, get_model
from repro.models import lm as lm_mod
from repro.models.moe import moe_block


def test_token_gather_ep_decode_matches_baseline(mesh8, rng):
    """Gathering tokens instead of FSDP'd expert weights is exact."""
    cfg = dataclasses.replace(get_reduced("arctic-480b"), dtype="float32")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params, _ = lm_mod.init_lm(cfg, rng, 2)
    lp = jax.tree.map(lambda x: x[0], params["layers"])
    x = jax.random.normal(rng, (4, 1, cfg.d_model))
    sp = SPConfig(strategy="swift", sp_axes=("pod", "model"),
                  batch_axes=("data",))
    base = ParallelContext(mesh8, sp, "decode", ep_token_gather=False)
    tg = ParallelContext(mesh8, sp, "decode", ep_token_gather=True)
    y0, _ = jax.jit(lambda x: moe_block(x, lp["moe"], cfg, base))(x)
    y1, _ = jax.jit(lambda x: moe_block(x, lp["moe"], cfg, tg))(x)
    np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-5)


def test_last_only_prefill_matches_full(mesh8, rng):
    cfg = dataclasses.replace(get_reduced("qwen2-1.5b"), dtype="float32",
                              sharding_overrides=())
    bundle = get_model(cfg)
    params, _ = bundle.init(cfg, rng, 1)
    tokens = jax.random.randint(rng, (4, 16), 0, cfg.vocab, jnp.int32)
    sp = SPConfig(strategy="swift_torus", sp_axes=("pod", "model"),
                  batch_axes=("data",))
    ctx = ParallelContext(mesh8, sp, "prefill")
    full = bundle.apply(params, {"tokens": tokens}, cfg, ctx)
    last = bundle.apply(params, {"tokens": tokens}, cfg, ctx, last_only=True)
    assert last.shape == (4, 1, cfg.vocab)
    np.testing.assert_allclose(last[:, 0], full[:, -1], rtol=1e-5, atol=1e-5)
