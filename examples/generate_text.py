"""Serve an assigned LM arch with batched requests through the AR engine:
continuous batching over a sequence-sharded KV cache.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/generate_text.py
"""
import dataclasses
import os
import sys

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core import SPConfig
from repro.models import get_model
from repro.serving import ARRequest, ARServer


def main():
    cfg = dataclasses.replace(get_reduced("qwen2-1.5b"), dtype="float32")
    bundle = get_model(cfg)
    params, _ = bundle.init(cfg, jax.random.PRNGKey(0), 1)

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    # decode shards the KV cache over (pod, model); 4 batch slots over data
    sp = SPConfig(strategy="swift", sp_axes=("pod", "model"),
                  batch_axes=("data",))
    srv = ARServer(params, cfg, mesh, sp, batch_slots=4, max_len=64)

    prompts = {
        1: [3, 1, 4, 1, 5],
        2: [2, 7, 1, 8],
        3: [9, 9, 9],
        4: [11],
        5: [5, 4, 3, 2, 1],
        6: [42, 42],
    }
    for rid, p in prompts.items():
        srv.submit(ARRequest(rid=rid, prompt=jnp.asarray(p, jnp.int32),
                             max_new_tokens=8))
    results = srv.serve()
    for rid in sorted(results):
        print(f"request {rid}: prompt={prompts[rid]} -> {results[rid]}")
    print(f"\nserved {len(results)} requests; KV cache sequence-sharded over "
          f"(pod × model) = {mesh.shape['pod'] * mesh.shape['model']} ways")


if __name__ == "__main__":
    main()
