from .engine import ARRequest, ARServer, DiTRequest, DiTResult, DiTServer
from .sampler import SamplerConfig, sample, sample_step, toy_vae_decode

__all__ = [
    "ARRequest",
    "ARServer",
    "DiTRequest",
    "DiTResult",
    "DiTServer",
    "SamplerConfig",
    "sample",
    "sample_step",
    "toy_vae_decode",
]
