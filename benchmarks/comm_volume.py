"""Paper Fig. 3b / Appendix D: inter-machine communication volume per GPU,
USP vs SwiftFusion, as machine count scales.

Workloads: the paper's Flux (H=24, D=128) and CogVideoX (H=24, D=64)
geometries.  Volumes in MiB (bf16), derived column = V_USP / V_SFU.
"""
from __future__ import annotations

from repro.core import plan, usp_plan
from repro.core.comm_model import LayerWorkload, swift_inter_volume, usp_inter_volume

from .common import row

WORKLOADS = {
    "flux_3072": LayerWorkload(batch=1, seq=36_864, heads=24, head_dim=128),
    "cogvideox_20s": LayerWorkload(batch=1, seq=49_152, heads=24, head_dim=64),
}
M_PER_MACHINE = 8  # paper testbed: 8 GPUs per machine


def run() -> list[str]:
    rows = []
    for wname, wl in WORKLOADS.items():
        for n in (2, 3, 4):
            sp = plan(n, M_PER_MACHINE, wl.heads)
            up = usp_plan(n, M_PER_MACHINE, wl.heads)
            v_s = swift_inter_volume(sp, wl.blhd) * 2 / 2**20  # bf16 MiB
            v_u = usp_inter_volume(up, wl.blhd) * 2 / 2**20
            ratio = v_u / v_s if v_s else float("inf")
            rows.append(row(f"comm_volume/{wname}/N{n}/usp_MiB", v_u,
                            f"Pu={up.p_ulysses},Pr={up.p_ring}"))
            rows.append(row(f"comm_volume/{wname}/N{n}/sfu_MiB", v_s,
                            f"usp_over_sfu={ratio:.2f}x"))
    return rows
