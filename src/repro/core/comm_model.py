"""Analytical communication-volume and latency model (paper Appendix D).

Reproduces the paper's inter-machine communication volume formulas for USP
and SwiftFusion, plus a simple two-level (intra/inter) alpha-beta latency
model used by the benchmark harness to regenerate the shape of the paper's
Figures 7/8/10 without multi-machine hardware.

All volumes are **elements per GPU** (multiply by bytes/elem for bytes), in
terms of B (batch), L (global sequence), H (heads), D (head dim), N
(machines), M (devices per machine), P_u, P_r (Ulysses/Ring degrees).
"""
from __future__ import annotations

import dataclasses

from .planner import SPPlan


def usp_inter_volume(plan: SPPlan, blhd: float) -> float:
    """Appendix D eq. (4)-(5): USP inter-machine elements per GPU."""
    n, p_r, p_u = plan.n_machines, plan.p_ring, plan.p_ulysses
    if n == 1:
        return 0.0
    if p_r >= n:
        # Ring spans machines; each of the N-1 inter-machine hops moves KV.
        return 2.0 * (n - 1) * blhd / n
    # Ring smaller than machine count: Ulysses also crosses machines with
    # degree N / P_r.
    g = n / p_r
    return (2.0 * (p_r - 1) * (n / p_r) + 4.0 * (g - 1) / g) * blhd / n


def swift_inter_volume(plan: SPPlan, blhd: float) -> float:
    """Appendix D eq. (6)-(7): SwiftFusion inter-machine elements per GPU."""
    n, p_u = plan.n_machines, plan.p_ulysses
    if n == 1:
        return 0.0
    if p_u >= n:
        return 4.0 * (n - 1) / n * blhd / n
    # Ulysses smaller than machine count: Ring also crosses machines with
    # degree N / P_u.
    g = n / p_u
    return (2.0 * (g - 1) + 4.0 * (p_u - 1) / p_u * g) * blhd / n


def intra_volume(plan: SPPlan, blhd: float, *, swift: bool) -> float:
    """Intra-machine elements per GPU (not in the paper's appendix; derived
    the same way).  Swift runs Ring intra-machine (volume 2·(Pr-1)/Pr·BLHD
    restricted to the machine's L/N slice per step ... aggregated), USP runs
    Ulysses intra-machine."""
    n, m = plan.n_machines, plan.m_per_machine
    p_u, p_r = plan.p_ulysses, plan.p_ring
    if m == 1:
        return 0.0
    if swift:
        r_intra = min(p_r, m)
        return 2.0 * (r_intra - 1) * blhd / n / max(r_intra, 1) * r_intra
    u_intra = min(p_u, m)
    return 4.0 * (u_intra - 1) / u_intra * blhd / n


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    """Two-level network + compute model for latency estimates.

    Defaults approximate the paper's testbed-equivalent on TPU terms:
    intra = ICI, inter = DCN/inter-pod.
    """

    intra_bw: float = 4.9e11  # B/s aggregated intra-machine per device
    inter_bw: float = 5.0e10  # B/s inter-machine per device
    intra_lat: float = 1e-6  # s per hop
    inter_lat: float = 1e-5  # s per hop
    flops: float = 197e12  # peak bf16 FLOP/s per device
    mfu: float = 0.5  # assumed attention kernel efficiency
    bytes_per_elem: int = 2


@dataclasses.dataclass(frozen=True)
class LayerWorkload:
    batch: int
    seq: int  # global sequence length
    heads: int
    head_dim: int

    @property
    def blhd(self) -> float:
        return float(self.batch * self.seq * self.heads * self.head_dim)

    def attention_flops(self) -> float:
        # 2 matmuls (QK^T and PV), 2*L*L*D each per head, bidirectional DiT.
        return 4.0 * self.batch * self.heads * self.seq * self.seq * self.head_dim


def attention_layer_latency(
    plan: SPPlan,
    wl: LayerWorkload,
    net: NetworkModel = NetworkModel(),
    *,
    swift: bool,
    overlap_inter: bool = False,
    overlap_intra: bool = True,
    one_sided: bool = False,
) -> dict[str, float]:
    """Estimate one distributed attention layer's latency components.

    ``overlap_inter`` models Torus Attention: the inter-machine all-to-all
    is hidden behind compute up to the compute time.  Ring's intra-machine
    transfers are overlappable by construction (``overlap_intra``).

    ``one_sided`` models §4.4: two-sided libraries pay a sender/receiver
    rendezvous *per transfer step* (P_r - 1 ring steps + the a2a stages,
    Fig. 4); the one-sided design pays exactly two barriers per layer
    (Algorithm 1 lines 16/36), independent of step count.
    """
    inter_v = (swift_inter_volume if swift else usp_inter_volume)(plan, wl.blhd)
    intra_v = intra_volume(plan, wl.blhd, swift=swift)
    b = net.bytes_per_elem
    t_inter = inter_v * b / net.inter_bw + (plan.n_machines > 1) * net.inter_lat
    t_intra = intra_v * b / net.intra_bw + (plan.m_per_machine > 1) * net.intra_lat
    t_comp = wl.attention_flops() / plan.sp_degree / (net.flops * net.mfu)
    ring_steps = max(plan.p_ring - 1, 0)
    a2a_stages = max(plan.p_ulysses - 1, 0)
    if one_sided:
        t_sync = 2 * (net.inter_lat if plan.n_machines > 1 else net.intra_lat)
    else:
        inter_steps = a2a_stages if plan.ulysses_inter else ring_steps
        intra_steps = ring_steps if plan.ulysses_inter else a2a_stages
        t_sync = (inter_steps * net.inter_lat * (plan.n_machines > 1)
                  + intra_steps * net.intra_lat * (plan.m_per_machine > 1))
    exposed_intra = 0.0 if overlap_intra else t_intra
    exposed_inter = max(0.0, t_inter - t_comp) if overlap_inter else t_inter
    total = t_comp + exposed_inter + exposed_intra + t_sync
    return {
        "t_compute": t_comp,
        "t_inter": t_inter,
        "t_intra": t_intra,
        "t_sync": t_sync,
        "t_total": total,
        "inter_elems": inter_v,
        "intra_elems": intra_v,
    }
