"""Serving observability subsystem (serving/metrics.py, DESIGN.md §11):
the tracker sink contract the whole control loop now publishes through —

  (a) counters are monotone and every counter record carries the NEW
      cumulative total (a trace replays without summing),
  (b) a ``JsonlTracker`` trace round-trips bit-exactly (bytes and
      ``Record`` objects) through ``read_jsonl``,
  (c) stream order (``seq``), ``step`` and ``tags`` survive the disk
      round-trip unchanged,
  (d) ``NullTracker`` is a TRUE no-op,
  (e) every record is schema-versioned and ``validate_record`` rejects
      each class of malformed record,

plus the counter-migration regression: the legacy attribute surface
(``PlanCache.hits`` & co.) must read exactly what the record stream says
on a mixed-resolution serve — pinned here so future sinks can't drift
from the attributes tests and launchers consume.

All host-side (no jax, no mesh); property tests use seeded
mini-hypothesis (see tests/_mini_hypothesis.py)."""
import dataclasses
import json
import pathlib
import random
import tempfile

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving.metrics import (
    KINDS,
    SCHEMA_VERSION,
    JsonlTracker,
    NullTracker,
    Record,
    RecordingTracker,
    SeriesStats,
    Tracker,
    read_jsonl,
    replay,
    validate_record,
)

NAMES = ("engine.t_step_s", "plan_cache.step_hit", "sched.admissions",
         "calibration.drift_ratio", "sim.batches")
TAGSETS = (None, {"seq": 256}, {"seq": 512, "rows": 4},
           {"adm": 3, "warm": True}, {"param": "alpha_us"})


def _drive(tracker: Tracker, seed: int, n_ops: int = 40) -> None:
    """Deterministic mixed counter/gauge stream (the shared generator the
    property tests replay into multiple sinks)."""
    rnd = random.Random(seed)
    for i in range(n_ops):
        name = rnd.choice(NAMES)
        tags = rnd.choice(TAGSETS)
        step = rnd.randrange(100) if rnd.random() < 0.5 else None
        if rnd.random() < 0.5:
            tracker.count(name, rnd.randrange(0, 5), step=step, tags=tags)
        else:
            tracker.log(name, rnd.uniform(-10, 10), step=step, tags=tags)


# ---------------------------------------------------------------------------
# (a) counter semantics
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_counters_monotone_and_records_carry_totals(seed):
    rnd = random.Random(seed)
    t = RecordingTracker()
    expect: dict[tuple, float] = {}
    for _ in range(rnd.randint(1, 60)):
        name = rnd.choice(NAMES)
        tags = rnd.choice(TAGSETS)
        inc = rnd.randrange(0, 7)
        key = (name, tuple(sorted((tags or {}).items())))
        expect[key] = expect.get(key, 0.0) + inc
        total = t.count(name, inc, tags=tags)
        # count() returns (and the record carries) the NEW cumulative total
        assert total == expect[key]
        assert t.records[-1].kind == "counter"
        assert t.records[-1].value == expect[key]
        assert t.counter(name, tags) == expect[key]
    # per-series record values never decrease (monotone counters)
    per_series: dict[tuple, list[float]] = {}
    for r in t.records:
        per_series.setdefault(
            (r.name, tuple(sorted(r.tags.items()))), []).append(r.value)
    for vals in per_series.values():
        assert vals == sorted(vals)
    # counter_total sums across every tag set of the name
    for name in NAMES:
        assert t.counter_total(name) == pytest.approx(
            sum(v for (n, _), v in expect.items() if n == name))


def test_negative_counter_increment_rejected():
    with pytest.raises(AssertionError):
        Tracker().count("x", -1.0)


def test_gauge_series_stats():
    t = Tracker()
    for v in (3.0, -1.0, 5.0):
        t.log("g", v, tags={"seq": 256})
    st_ = t.series("g", {"seq": 256})
    assert (st_.n, st_.vmin, st_.vmax, st_.last) == (3, -1.0, 5.0, 5.0)
    assert st_.mean == pytest.approx(7.0 / 3.0)
    # an unseen series reads as empty stats, not KeyError
    empty = t.series("g", {"seq": 1024})
    assert isinstance(empty, SeriesStats) and empty.n == 0


# ---------------------------------------------------------------------------
# (b) JSONL bit-exact round-trip
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_jsonl_round_trip_bit_exact(seed):
    with tempfile.TemporaryDirectory() as td:
        p1 = pathlib.Path(td) / "a.jsonl"
        p2 = pathlib.Path(td) / "b.jsonl"
        rec = RecordingTracker()
        with JsonlTracker(p1) as j1:
            _drive(rec, seed)
            _drive(j1, seed)
        # Record-level equality: disk stream == in-memory stream
        assert read_jsonl(p1) == rec.records
        # byte-level determinism: the same stream writes identical bytes
        with JsonlTracker(p2) as j2:
            _drive(j2, seed)
        assert p1.read_bytes() == p2.read_bytes()
        # aggregate parity: both sinks saw the same totals
        for name in NAMES:
            assert j1.counter_total(name) == rec.counter_total(name)


def test_jsonl_valid_at_every_prefix(tmp_path):
    """Every line is complete JSON the moment it's written — a crashed
    run's trace is readable up to the last record."""
    p = tmp_path / "t.jsonl"
    t = JsonlTracker(p)
    t.count("a", 1)
    t.log("b", 2.5, step=3, tags={"seq": 256})
    t.flush()
    lines = p.read_text().splitlines()
    assert len(lines) == 2
    for line in lines:
        assert validate_record(json.loads(line)) == []
    t.close()
    t.close()  # idempotent


def test_replay_rebuilds_aggregates(tmp_path):
    p = tmp_path / "t.jsonl"
    with JsonlTracker(p) as t:
        _drive(t, seed=7)
    back = replay(read_jsonl(p))
    for name in NAMES:
        assert back.counter_total(name) == t.counter_total(name)
    for tags in TAGSETS:
        for name in NAMES:
            assert back.counter(name, tags) == t.counter(name, tags)
            assert back.series(name, tags).n == t.series(name, tags).n


# ---------------------------------------------------------------------------
# (c) ordering, step and tags survive the round-trip
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_seq_total_order_and_step_tags_preserved(seed):
    with tempfile.TemporaryDirectory() as td:
        p = pathlib.Path(td) / "t.jsonl"
        with JsonlTracker(p) as t:
            _drive(t, seed)
        recs = read_jsonl(p)
        # seq is the dense 0..n-1 total order of the stream, in file order
        assert [r.seq for r in recs] == list(range(len(recs)))
        # regenerate the identical stream and compare field-by-field
        mirror = RecordingTracker()
        _drive(mirror, seed)
        for a, b in zip(recs, mirror.records):
            assert (a.name, a.kind, a.value, a.step, a.tags) == \
                   (b.name, b.kind, b.value, b.step, b.tags)


def test_tag_order_is_canonical():
    """The same tag set in any insertion order is one series."""
    t = Tracker()
    t.count("c", 1, tags={"a": 1, "b": 2})
    t.count("c", 1, tags={"b": 2, "a": 1})
    assert t.counter("c", {"a": 1, "b": 2}) == 2
    assert t.counter_total("c") == 2


# ---------------------------------------------------------------------------
# (d) NullTracker is a TRUE no-op
# ---------------------------------------------------------------------------

def test_null_tracker_noop():
    t = NullTracker()
    assert t.count("a", 5, tags={"seq": 256}) == 0.0
    t.log("b", 1.0, step=3)
    assert t.counter("a", {"seq": 256}) == 0.0
    assert t.counter_total("a") == 0.0
    assert t.series("b").n == 0
    assert t.summary() == []
    assert not t.persistent


# ---------------------------------------------------------------------------
# (e) schema versioning + validate_record
# ---------------------------------------------------------------------------

def test_every_record_is_schema_versioned():
    t = RecordingTracker()
    _drive(t, seed=3)
    assert t.records, "generator produced no records"
    for r in t.records:
        assert r.schema == SCHEMA_VERSION
        assert r.kind in KINDS
        assert validate_record(r.to_dict()) == []


def test_record_dict_round_trip():
    r = Record(name="n", value=1.5, kind="gauge", step=4,
               tags={"seq": 256, "warm": True}, seq=9)
    assert Record.from_dict(r.to_dict()) == r
    # omitted optionals stay omitted on disk but default on the way back
    bare = Record(name="n", value=2.0, kind="counter", seq=0)
    d = bare.to_dict()
    assert "step" not in d and "tags" not in d
    assert Record.from_dict(d) == bare


@pytest.mark.parametrize("mutate,needle", [
    (lambda d: d.pop("schema"), "missing field"),
    (lambda d: d.pop("name"), "missing field"),
    (lambda d: d.pop("seq"), "missing field"),
    (lambda d: d.update(schema="metrics.v0"), "schema"),
    (lambda d: d.update(kind="histogram"), "kind"),
    (lambda d: d.update(value=True), "not a number"),
    (lambda d: d.update(value="fast"), "not a number"),
    (lambda d: d.update(seq=-1), "seq"),
    (lambda d: d.update(step=1.5), "step"),
    (lambda d: d.update(tags={"k": [1, 2]}), "tag"),
    (lambda d: d.update(surprise=1), "unknown fields"),
])
def test_validate_record_rejects_malformed(mutate, needle):
    d = Record(name="n", value=1.0, kind="gauge", seq=0).to_dict()
    mutate(d)
    errs = validate_record(d)
    assert errs and any(needle in e for e in errs), errs


def test_read_jsonl_raises_on_malformed_line(tmp_path):
    p = tmp_path / "bad.jsonl"
    good = Record(name="n", value=1.0, kind="gauge", seq=0).to_dict()
    bad = dict(good, schema="metrics.v0")
    p.write_text(json.dumps(good) + "\n" + json.dumps(bad) + "\n")
    with pytest.raises(ValueError, match="bad.jsonl:2"):
        read_jsonl(p)
    assert len(read_jsonl(p, validate=False)) == 2


# ---------------------------------------------------------------------------
# summary table
# ---------------------------------------------------------------------------

def test_summary_rows_and_format():
    t = Tracker()
    t.count("c", 2, tags={"seq": 256})
    t.log("g", 1.5)
    t.log("g", 2.5)
    rows = {(r["name"], r["kind"]): r for r in t.summary()}
    assert rows[("c", "counter")]["value"] == 2
    g = rows[("g", "gauge")]
    assert (g["n"], g["mean"], g["min"], g["max"]) == (2, 2.0, 1.5, 2.5)
    text = t.format_summary()
    assert "c{seq=256}" in text and "counter" in text and "gauge" in text


# ---------------------------------------------------------------------------
# counter-migration regression: legacy attributes == the record stream
# ---------------------------------------------------------------------------

def _mixed_drain(tracker: Tracker):
    """A mixed-resolution stream through the real scheduler + plan cache
    (the objects the engine wires to one tracker), drained to empty."""
    from repro.serving.sched import RequestScheduler, SchedConfig
    from tests.test_sched import Req, make_cache

    cache = make_cache(dp=2, tracker=tracker)
    sched = RequestScheduler(
        cache, SchedConfig(max_batch=4, dp=2, starvation_age=10.0,
                           aging_rate=1.0, default_slack=100.0,
                           defer_slack=1.0), tracker=tracker)
    lens = [256, 512, 256, 1024, 512, 256, 1024, 256, 256, 512]
    for i, n in enumerate(lens):
        sched.submit(Req(i, n), now=0.01 * i)
    admissions = []
    now = 1.0
    while sched.pending:
        adm = sched.next_batch(now, flush=True)
        cache.step_fn(adm.batch_rows, adm.seq_len, lambda: (lambda: None))
        admissions.append(adm)
        now += 0.1
    return cache, sched, admissions


def test_legacy_attributes_match_record_stream():
    t = RecordingTracker()
    cache, sched, admissions = _mixed_drain(t)

    def final_totals(name: str) -> float:
        # counter records carry cumulative totals: the last record per
        # tag set is that series' final count
        last: dict[tuple, float] = {}
        for r in t.records:
            if r.kind == "counter" and r.name == name:
                last[tuple(sorted(r.tags.items()))] = r.value
        return sum(last.values())

    # the legacy attribute surface reads exactly what the stream says
    assert sched.admissions == final_totals("sched.admissions") == \
        len(admissions)
    assert cache.plan_misses == final_totals("plan_cache.plan_miss")
    assert cache.plan_hits == final_totals("plan_cache.plan_hit")
    assert cache.hits == final_totals("plan_cache.step_hit")
    assert cache.misses == final_totals("plan_cache.step_miss")
    # structural cross-checks: one compiled trace per ADMITTED shape (the
    # plan cache also scores candidate shapes that are never admitted, so
    # plans >= compiled shapes)
    shapes = {(a.batch_rows, a.seq_len) for a in admissions}
    assert cache.misses == cache.traces == len(shapes) > 0
    assert cache.hits == len(admissions) - len(shapes)
    assert cache.plan_misses == len(cache.plans) >= len(shapes)
    assert cache.plan_hits > 0  # repeated scoring of known shapes
    assert final_totals("sched.submitted") == 10


def test_default_and_recording_trackers_agree():
    """The aggregate-only default sink and the recording sink see the
    same totals on the same drain — persistence must not change
    accounting."""
    t_rec, t_plain = RecordingTracker(), Tracker()
    cache_r, sched_r, _ = _mixed_drain(t_rec)
    cache_p, sched_p, _ = _mixed_drain(t_plain)
    assert (cache_r.hits, cache_r.misses, cache_r.plan_hits,
            cache_r.plan_misses, sched_r.admissions) == \
           (cache_p.hits, cache_p.misses, cache_p.plan_hits,
            cache_p.plan_misses, sched_p.admissions)


def test_calibrator_counters_through_tracker():
    """OnlineCalibrator's refit/recalibration tallies live in the
    tracker now; the attributes are reads of it."""
    from repro.serving.sched import CalibrationConfig, OnlineCalibrator
    from tests.test_sched import make_cache

    t = RecordingTracker()
    cache = make_cache(dp=2, tracker=t)
    choice = cache.select(4, 256)
    cal = OnlineCalibrator(
        cache, CalibrationConfig(min_samples=1, refit_every=1), tracker=t)
    assert cal.refits == 0 and cal.recalibrations == 0
    # wildly slower than predicted -> refit and (damped) drift
    for _ in range(3):
        cal.observe(choice, 4, 256, [choice.t_step * 50.0] * 4)
    assert cal.refits == 3
    assert cal.refits == t.counter("calibration.refits")
    assert t.series("calibration.measured_step_us",
                    {"rows": 4, "seq": 256}).n == 3
    drift_records = [r for r in t.records
                     if r.name == "calibration.drift_ratio"]
    assert drift_records and all(r.kind == "gauge" for r in drift_records)
    assert cal.recalibrations == t.counter("calibration.recalibrations")


def test_forecaster_publishes_gap_series():
    from repro.serving.sched import ArrivalForecaster

    t = RecordingTracker()
    f = ArrivalForecaster(tracker=t)
    f.observe(256, 0.0)  # first arrival: no gap yet
    assert t.series("forecast.mean_gap_s", {"seq": 256}).n == 0
    f.observe(256, 1.0)
    f.observe(256, 2.0)
    assert t.series("forecast.mean_gap_s", {"seq": 256}).n == 2
    assert t.series("forecast.mean_gap_s", {"seq": 256}).last == \
        pytest.approx(1.0)


# ---------------------------------------------------------------------------
# (f) span records (DESIGN.md §12): schema, nesting, crash safety
# ---------------------------------------------------------------------------

def test_span_event_record_shape():
    t = RecordingTracker()
    t.span_event("comm.leg", 0.25, 0.005, step=3, tags={"stream": "ring"})
    (r,) = t.records
    assert (r.kind, r.name, r.step) == ("span", "comm.leg", 3)
    assert r.t_start == pytest.approx(0.25)
    assert r.value == pytest.approx(0.005)
    assert validate_record(r.to_dict()) == []
    # durations aggregate like gauges, so summary() covers spans for free
    assert t.series("comm.leg", {"stream": "ring"}).n == 1
    # round-trips with t_start intact
    assert Record.from_dict(r.to_dict()) == r


def test_span_context_manager_times_and_nests():
    t = RecordingTracker()
    with t.span("engine.step", step=0):
        with t.span("plan_cache.trace", tags={"rows": 2}):
            pass
    inner, outer = t.records
    assert inner.name == "plan_cache.trace"
    assert inner.tags["parent"] == "engine.step"  # nesting is recorded
    assert outer.name == "engine.step" and "parent" not in outer.tags
    # the inner window is contained in the outer one
    assert outer.t_start <= inner.t_start
    assert inner.t_start + inner.value <= outer.t_start + outer.value + 1e-9
    for r in t.records:
        assert validate_record(r.to_dict()) == []


def test_span_emitted_even_on_exception():
    t = RecordingTracker()
    with pytest.raises(RuntimeError):
        with t.span("engine.step"):
            raise RuntimeError("boom")
    assert [r.name for r in t.records] == ["engine.step"]
    assert t._span_stack == []  # stack unwound


@pytest.mark.parametrize("mutate,needle", [
    (lambda d: d.pop("t_start"), "t_start"),
    (lambda d: d.update(t_start=-0.5), "t_start"),
    (lambda d: d.update(t_start=True), "t_start"),
    (lambda d: d.update(value=-1.0), "negative"),
])
def test_validate_record_rejects_malformed_spans(mutate, needle):
    d = Record(name="s", value=1.0, kind="span", seq=0, t_start=0.0).to_dict()
    mutate(d)
    errs = validate_record(d)
    assert errs and any(needle in e for e in errs), errs


def test_t_start_forbidden_on_non_span_kinds():
    d = Record(name="g", value=1.0, kind="gauge", seq=0).to_dict()
    d["t_start"] = 0.5
    assert any("span" in e for e in validate_record(d))


def test_null_tracker_span_noop():
    t = NullTracker()
    with t.span("x"):
        t.span_event("y", 0.0, 1.0)
    assert t.series("y").n == 0


def test_jsonl_crash_tail_recoverable(tmp_path):
    """A writer killed mid-record leaves a trace whose completed lines are
    all schema-valid; read_jsonl(partial_tail='drop') recovers them."""
    p = tmp_path / "t.jsonl"
    t = JsonlTracker(p)  # flush_every=1: every record hits the OS at once
    t.count("a", 1)
    with t.span("s"):
        pass
    t.log("g", 2.0)
    # crash simulation: truncate the final record mid-line, no close()
    t.flush()
    raw = p.read_bytes()
    p.write_bytes(raw[:-9])  # cut into the last JSON line
    for line in p.read_text().splitlines()[:-1]:
        assert validate_record(json.loads(line)) == []
    with pytest.raises(json.JSONDecodeError):
        read_jsonl(p)  # default: corruption is an error
    recs = read_jsonl(p, partial_tail="drop")
    assert [r.name for r in recs] == ["a", "s"]
    assert recs[1].kind == "span"
    t.close()


def test_jsonl_flush_every_batches_but_close_flushes(tmp_path):
    p = tmp_path / "t.jsonl"
    t = JsonlTracker(p, flush_every=100)
    t.count("a", 1)
    t.count("a", 1)
    # unflushed: the OS may have nothing yet (can't assert emptiness
    # portably, but flush() must make both lines visible)
    t.flush()
    assert len(p.read_text().splitlines()) == 2
    t.count("a", 1)
    t.close()
    assert len(read_jsonl(p)) == 3


def test_jsonl_closes_on_exception(tmp_path):
    p = tmp_path / "t.jsonl"
    with pytest.raises(RuntimeError):
        with JsonlTracker(p, flush_every=1000) as t:
            t.count("a", 1)
            raise RuntimeError("serve crashed")
    assert t._fh is None  # context manager closed (and thus flushed) it
    assert [r.name for r in read_jsonl(p)] == ["a"]


def test_partial_tail_drop_does_not_mask_mid_file_corruption(tmp_path):
    p = tmp_path / "bad.jsonl"
    good = json.dumps(Record(name="n", value=1.0, kind="gauge",
                             seq=0).to_dict(), sort_keys=True)
    p.write_text('{"truncated' + "\n" + good + "\n")
    with pytest.raises(json.JSONDecodeError):
        read_jsonl(p, partial_tail="drop")
