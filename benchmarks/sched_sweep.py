"""Request-scheduler sweep (DESIGN.md §9): resolution-bucketed SLA-aware
continuous batching vs the greedy same-length batcher, on a simulated
mixed-resolution queue.

The analytical part runs both policies through a discrete-event
simulation of one serving pipeline (per-replica cluster N=2 machines x
M=4 devices, dp=2 data-parallel replicas of the batch) over the SAME
deterministic arrival stream of 256/512/1024-latent requests with SLAs:

  * **greedy** — the pre-scheduler ``DiTServer`` behavior: head-of-line
    same-length batching, immediate admission (fragment batches pay dp
    padding rows), one static plan (the sp-only swift_torus default) for
    every bucket.
  * **bucketed** — the ``serving.sched`` subsystem: per-bucket queues,
    deadline/aging-scored cross-bucket admission with padded batches
    deferred while slack allows, and a per-bucket ``plan_hybrid``
    selection (cfg/pp split + patch count) from the plan cache.

Rows report predicted makespan, padded-token work, worst queue wait and
SLA misses per policy, plus the per-bucket plan the cache selected.  The
acceptance claims (ISSUE 3) — strictly less padded-token work, strictly
lower makespan, starvation bound honored, one plan per bucket shape —
are asserted by ``--smoke``, which additionally drives a real tiny
``DiTServer`` end-to-end on 8 simulated CPU devices and checks the step
cache traced exactly once per bucket shape.
"""
from __future__ import annotations

import dataclasses
import functools
import sys
from collections import deque
from typing import NamedTuple

from repro.core import plan_hybrid
from repro.core.comm_model import NetworkModel
from repro.serving.sched import (
    RequestScheduler,
    SchedConfig,
    PlanCache,
    padded_rows,
)

from .common import row

# per-replica cluster the plans are scored on (paper testbed flavour)
N_MACHINES = 2
M_PER_MACHINE = 4
DP = 2  # data-parallel replicas the global batch must divide into
HEADS = 24
HEAD_DIM = 64
N_LAYERS = 42
NUM_STEPS = 20
MAX_BATCH = 4
STARVATION_AGE = 1.0
SEQS = (256, 512, 1024)
# SLA seconds per bucket: short sequences are the latency-critical tier
SLAS = {256: 0.15, 512: 0.4, 1024: 2.0}


@dataclasses.dataclass
class SimRequest:
    """Duck-typed stand-in for DiTRequest (no jax import needed)."""

    rid: int
    seq_len: int
    arrival: float
    sla: float | None = None
    submitted: float = 0.0
    drift_threshold: float | None = None


def request_stream(n: int = 30) -> list[SimRequest]:
    """Deterministic mixed-resolution arrival stream (no RNG: modular
    pattern), staggered so head-of-line batching fragments."""
    reqs, t = [], 0.0
    for i in range(n):
        seq = SEQS[(i * 7 + i // 3) % 3]
        t += 0.002 + 0.0013 * ((i * 5) % 3)
        reqs.append(SimRequest(rid=i, seq_len=seq, arrival=round(t, 5),
                               sla=SLAS[seq]))
    return reqs


def _plan_cache(static: bool) -> PlanCache:
    """Bucketed mode enumerates every feasible (cfg, pp) split and patch
    count; greedy mode pins the single sp-only plan with default patches
    — exactly what the pre-scheduler server ran."""
    kw = dict(heads=HEADS, head_dim=HEAD_DIM, n_layers=N_LAYERS,
              num_steps=NUM_STEPS, guided=True, dp=DP, net=NetworkModel())
    if static:
        sp_only = plan_hybrid(N_MACHINES, M_PER_MACHINE, HEADS,
                              n_layers=N_LAYERS)
        return PlanCache(candidates=[sp_only], patch_multipliers=(1,), **kw)
    return PlanCache(n_machines=N_MACHINES, m_per_machine=M_PER_MACHINE, **kw)


class _GreedyAdmission(NamedTuple):
    seq_len: int
    requests: list
    batch_rows: int
    pad_rows: int
    plan: object  # PlanChoice


class GreedyPolicy:
    """The old ``DiTServer._next_batch``: head-of-line same-length
    batching, admitted immediately — no deferral, no cross-bucket choice,
    one static plan."""

    def __init__(self):
        self.q: deque = deque()
        self.plan_cache = _plan_cache(static=True)

    def submit(self, req, now: float) -> None:
        req.submitted = now
        self.q.append(req)

    @property
    def pending(self) -> int:
        return len(self.q)

    def next(self, now: float, flush: bool) -> _GreedyAdmission | None:
        if not self.q:
            return None
        head = self.q[0]
        batch, rest = [], deque()
        while self.q and len(batch) < MAX_BATCH:
            r = self.q.popleft()
            (batch if r.seq_len == head.seq_len else rest).append(r)
        while rest:
            self.q.appendleft(rest.pop())
        pad = padded_rows(len(batch), DP)
        rows = len(batch) + pad
        return _GreedyAdmission(head.seq_len, batch, rows, pad,
                                self.plan_cache.select(rows, head.seq_len))


class BucketedPolicy:
    """The sched subsystem behind the same simulation interface."""

    def __init__(self):
        self.plan_cache = _plan_cache(static=False)
        self.sched = RequestScheduler(
            self.plan_cache,
            SchedConfig(max_batch=MAX_BATCH, dp=DP,
                        starvation_age=STARVATION_AGE, default_slack=10.0,
                        defer_slack=0.02))

    def submit(self, req, now: float) -> None:
        self.sched.submit(req, now)

    @property
    def pending(self) -> int:
        return self.sched.pending

    def next(self, now: float, flush: bool):
        return self.sched.next_batch(now, flush=flush)


def simulate(policy, reqs: list[SimRequest]) -> dict:
    """Discrete-event run of one serving pipeline: batches execute
    sequentially for their comm-model-predicted duration; arrivals land
    while earlier batches run."""
    i, t = 0, 0.0
    stats = {"pad_tokens": 0, "real_tokens": 0, "batches": 0,
             "max_wait": 0.0, "sla_miss": 0, "served": 0,
             "max_batch_s": 0.0}
    while True:
        while i < len(reqs) and reqs[i].arrival <= t + 1e-9:
            policy.submit(reqs[i], reqs[i].arrival)
            i += 1
        if not policy.pending:
            if i >= len(reqs):
                break
            t = reqs[i].arrival
            continue
        adm = policy.next(t, flush=i >= len(reqs))
        if adm is None:  # deferred for better packing; wait for arrivals
            t = reqs[i].arrival
            continue
        dur = adm.plan.t_batch
        finish = t + dur
        for r in adm.requests:
            stats["max_wait"] = max(stats["max_wait"], t - r.submitted)
            if r.sla is not None and finish - r.submitted > r.sla:
                stats["sla_miss"] += 1
        stats["pad_tokens"] += adm.pad_rows * adm.seq_len
        stats["real_tokens"] += len(adm.requests) * adm.seq_len
        stats["served"] += len(adm.requests)
        stats["batches"] += 1
        stats["max_batch_s"] = max(stats["max_batch_s"], dur)
        t = finish
    stats["makespan_s"] = t
    return stats


@functools.lru_cache(maxsize=1)
def _compare() -> tuple[dict, dict, BucketedPolicy]:
    """Both policies over the same stream — deterministic, so memoized
    (run(), records() and the smoke asserts all consume it)."""
    reqs = request_stream()
    greedy = simulate(GreedyPolicy(), [dataclasses.replace(r) for r in reqs])
    bucketed_policy = BucketedPolicy()
    bucketed = simulate(bucketed_policy,
                        [dataclasses.replace(r) for r in reqs])
    return greedy, bucketed, bucketed_policy


def run() -> list[str]:
    greedy, bucketed, policy = _compare()
    rows = []
    for name, s in (("greedy", greedy), ("bucketed", bucketed)):
        rows.append(row(
            f"sched_sweep/N{N_MACHINES}M{M_PER_MACHINE}/{name}/makespan",
            s["makespan_s"] * 1e6,
            f"padded_tokens={s['pad_tokens']},batches={s['batches']},"
            f"max_wait_s={s['max_wait']:.2f},sla_miss={s['sla_miss']}"))
    rows.append(row(
        f"sched_sweep/N{N_MACHINES}M{M_PER_MACHINE}/reduction",
        (greedy["makespan_s"] - bucketed["makespan_s"]) * 1e6,
        f"makespan_speedup={greedy['makespan_s'] / bucketed['makespan_s']:.2f}x,"
        f"pad_tokens={greedy['pad_tokens']}->{bucketed['pad_tokens']}"))
    for (rows_, seq), choice in sorted(policy.plan_cache.plans.items()):
        h = choice.hplan
        rows.append(row(
            f"sched_sweep/N{N_MACHINES}M{M_PER_MACHINE}/plan/seq{seq}/b{rows_}",
            choice.t_step * 1e6,
            f"cfg={h.cfg},pp={h.pp},Pu={h.sp.p_ulysses},Pr={h.sp.p_ring},"
            f"patches={choice.num_patches}"))
    return rows


def records() -> list[dict]:
    """Structured BENCH_sched_sweep.json records: both policies' queue
    metrics plus every per-bucket plan selection (fit-target field kept
    for symmetry with the other sweeps)."""
    greedy, bucketed, policy = _compare()
    out = [{
        "name": f"sched_sweep/N{N_MACHINES}M{M_PER_MACHINE}/{name}",
        "policy": name,
        "n_machines": N_MACHINES,
        "m_per_machine": M_PER_MACHINE,
        "dp": DP,
        "metrics": s,
        "measured_step_us": None,
    } for name, s in (("greedy", greedy), ("bucketed", bucketed))]
    for (rows_, seq), choice in sorted(policy.plan_cache.plans.items()):
        h = choice.hplan
        out.append({
            "name": (f"sched_sweep/N{N_MACHINES}M{M_PER_MACHINE}"
                     f"/plan/seq{seq}/b{rows_}"),
            # workload.batch is the per-replica slice the prediction was
            # scored on (rows // dp) — the contract calibrate_comm.py's
            # predict_us() relies on; batch_rows keeps the global size
            "workload": {"batch": max(rows_ // DP, 1), "seq": seq,
                         "heads": HEADS, "head_dim": HEAD_DIM,
                         "n_layers": N_LAYERS},
            "batch_rows": rows_,
            "dp": DP,
            "n_machines": N_MACHINES,
            "m_per_machine": M_PER_MACHINE,
            "plan": {"cfg": h.cfg, "pp": h.pp, "p_ulysses": h.sp.p_ulysses,
                     "p_ring": h.sp.p_ring,
                     "num_patches": choice.num_patches},
            "predicted_step_us": choice.t_step * 1e6,
            "predicted_breakdown": {k: v for k, v in choice.pred.items()
                                    if k != "t_step"},
            "measured_step_us": None,
        })
    return out


# ---------------------------------------------------------------------------
# --smoke: assert the acceptance claims + drive a real DiTServer
# ---------------------------------------------------------------------------

def _assert_analytic() -> list[str]:
    greedy, bucketed, policy = _compare()
    msgs = []
    assert bucketed["served"] == greedy["served"] > 0
    assert bucketed["pad_tokens"] < greedy["pad_tokens"], (
        bucketed["pad_tokens"], greedy["pad_tokens"])
    assert bucketed["makespan_s"] < greedy["makespan_s"], (
        bucketed["makespan_s"], greedy["makespan_s"])
    # starvation bound: an overdue bucket is served next, so no wait can
    # exceed the bound by more than the batches that were already ahead
    bound = STARVATION_AGE + len(SEQS) * bucketed["max_batch_s"]
    assert bucketed["max_wait"] <= bound, (bucketed["max_wait"], bound)
    # one plan per bucket shape, selected via plan_hybrid
    assert len(policy.plan_cache.plans) >= len(SEQS)
    msgs.append(f"analytic: pad {greedy['pad_tokens']} -> "
                f"{bucketed['pad_tokens']} tokens, makespan "
                f"{greedy['makespan_s']:.1f}s -> {bucketed['makespan_s']:.1f}s, "
                f"max_wait {bucketed['max_wait']:.1f}s <= bound {bound:.1f}s")
    return msgs


def _smoke_engine() -> list[str]:
    """Mixed 256/512/1024 queue through a real (tiny) DiTServer on 8
    simulated CPU devices: scheduler path end-to-end, one jit trace per
    bucket shape."""
    import os

    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

    import dataclasses as dc

    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.core import PipelineConfig, SPConfig
    from repro.launch.mesh import make_hybrid_mesh
    from repro.models import get_model
    from repro.serving import DiTRequest, DiTServer, DriftPolicy, SamplerConfig

    assert len(jax.devices()) == 8, (
        "smoke needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
        f"before jax initializes (got {len(jax.devices())} devices)")
    cfg = dc.replace(get_reduced("flux-12b"), dtype="float32")
    bundle = get_model(cfg)
    params, axes = bundle.init(cfg, jax.random.PRNGKey(0), 1)
    mesh = make_hybrid_mesh(cfg=1, pipe=2, data=2, model=2)
    sp = SPConfig(strategy="swift_torus", sp_axes=("model",),
                  batch_axes=("data",), pp_axis="pipe")
    srv = DiTServer(params, cfg, mesh, sp,
                    sampler=SamplerConfig(
                        num_steps=3,
                        pipeline=PipelineConfig(pp=2, warmup_steps=1)),
                    max_batch=2, param_axes=axes,
                    drift=DriftPolicy(threshold=0.05))
    lens = [256, 512, 1024, 256, 512, 256]
    for i, n in enumerate(lens):
        srv.submit(DiTRequest(rid=i, seq_len=n, sla=SLAS[n],
                              drift_threshold=0.05 if i % 2 else None))
    results = srv.serve()
    assert sorted(r.rid for r in results) == list(range(len(lens)))
    by_rid = {r.rid: r for r in results}
    for i, n in enumerate(lens):
        r = by_rid[i]
        assert r.latents.shape == (n, 64), r.latents.shape
        assert bool(jnp.all(jnp.isfinite(r.latents)))
        assert len(r.kv_drift) == 3
    shapes = set(srv.plan_cache.plans)
    # one compiled trace per bucket shape, hits for every repeat
    assert srv.plan_cache.traces == len(shapes), (
        srv.plan_cache.traces, shapes)
    assert srv.plan_cache.hits == srv.scheduler.admissions - len(shapes)
    tot = srv.scheduler.totals()
    assert tot.admitted == len(lens)
    return [f"engine: served {len(results)} mixed requests over "
            f"{len(shapes)} bucket shapes, {srv.plan_cache.traces} traces, "
            f"{srv.plan_cache.hits} step-cache hits, "
            f"{tot.padded_rows} padded rows"]


def main(argv: list[str] | None = None) -> None:
    args = list(sys.argv[1:] if argv is None else argv)
    for line in run():
        print(line)
    if "--smoke" in args:
        for m in _assert_analytic():
            print(f"# {m}", file=sys.stderr)
        for m in _smoke_engine():
            print(f"# {m}", file=sys.stderr)
        print("# sched_sweep smoke OK", file=sys.stderr)


if __name__ == "__main__":
    main()
