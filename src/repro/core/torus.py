"""Torus Attention (paper §4.3, Algorithm 1): chunked, overlappable
all-to-all fused with attention compute.

The monolithic Ulysses all-to-all is decomposed into P_u - 1 point-to-point
stages.  The diagonal chunk (head-slice u of device u's own shard) is
*stationary* — §4.3's key observation — so compute starts immediately, and
each stage-k transfer (a distance-k hop on the torus) is interleaved with
attention on already-resident chunks:

    stage 0        : RingAttn(Q_{t,t}, K_{t,t}, V_{t,t})          (no comm)
    Pull-Q  k=1..N-1: recv Q chunk from u-k; RingAttn(vs local diag KV)
                      while Q chunk for u+k is in flight
    Pull-KV k=1..N-1: recv KV chunk from u-k; RingAttn(all Q vs recv'd KV)
                      while KV chunk for u+k is in flight
    Push-O         : inverse staged all-to-all of O (diagonal stays put)

Q is scheduled before KV exactly as in the paper ("KV doubles the volume
and is harder to hide").  Every per-stage compute is a full RINGATTN over
the intra-machine Ring group, as in Algorithm 1.

Deviations from Algorithm 1 (documented in DESIGN.md §2): the paper defers
the diagonal Q's non-local-KV compute into the Push-O stage so NVSHMEM
pushes overlap it at runtime.  XLA schedules statically, so we fold that
compute into the Pull-KV stages and rely on the latency-hiding scheduler to
overlap the staged Push-O permutes with *subsequent layer* compute — the
same bytes move, on the same hops, in the same stage order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..comm import Stream, fence, pin, torus_hop
from .collectives import GroupLayout
from .ring import ring_attention
from .softmax import Partial, empty_partial, finalize, merge


def _pin(acc: Partial) -> Partial:
    """Serialise the accumulator chain across schedule steps."""
    return Partial(*pin(tuple(acc)))


def _gate(tensors: tuple, acc: Partial):
    """Fence stage inputs on the running accumulator: stage k's attention
    cannot start before stage k-1 merged, so only O(1) score matrices are
    ever live (the channel puts don't pass through the fence and still get
    hoisted/overlapped by the scheduler)."""
    vals, accs = fence(tensors, tuple(acc))
    return vals, Partial(*accs)
from .ulysses import group_positions, scatter_o

HEAD_AXIS = 2


def _split_heads(x: jax.Array, p_u: int) -> jax.Array:
    """[B, Ls, H, D] -> [P_u, B, Ls, H/P_u, D]; chunk j is destined to peer j."""
    return jnp.stack(jnp.split(x, p_u, axis=HEAD_AXIS), axis=0)


def _rank_of(layout: GroupLayout, u, r):
    if layout.ulysses_outer:
        return u * layout.p_ring + r
    return r * layout.p_ulysses + u


def _merge_slice(acc: Partial, upd: Partial, start: jax.Array, ls: int) -> Partial:
    """Merge ``upd`` (covering q slice [start, start+ls)) into ``acc``."""
    sl = lambda a, ax: lax.dynamic_slice_in_dim(a, start, ls, axis=ax)
    cur = Partial(o=sl(acc.o, 1), l=sl(acc.l, 2), m=sl(acc.m, 2))
    new = merge(cur, upd)
    ins = lambda a, u, ax: lax.dynamic_update_slice_in_dim(a, u, start, axis=ax)
    return Partial(
        o=ins(acc.o, new.o, 1), l=ins(acc.l, new.l, 2), m=ins(acc.m, new.m, 2)
    )


def torus_attention(
    q: jax.Array,  # [B, Ls, Hq, D] natural (seq-sharded) layout
    k: jax.Array,  # [B, Ls, Hkv, D]
    v: jax.Array,
    layout: GroupLayout,
    *,
    scale: float | None = None,
    causal: bool = False,
    window: int | None = None,
    unroll: bool = True,
    fused_pull_q: bool = False,
    kv_block: int | None = None,
    backend: str = "xla",
    interpret: bool = True,
    wire_dtype: str | None = None,
) -> jax.Array:
    """Full SwiftFusion attention with the Torus schedule; returns O in the
    original [B, Ls, Hq, D] sharding.

    ``wire_dtype`` compresses the inter-machine leg of the Push-O when the
    layout is hierarchical (``layout.u_groups > 1``, DESIGN.md §8.2); the
    Pull legs stay exact (Q/KV feed compute directly).

    ``backend="pallas"`` lowers every transfer through the Pallas channel
    backend (semaphore-tracked puts, DESIGN.md §8.1) and runs each
    per-stage RINGATTN through the fused ring_flash kernel;
    ``interpret`` selects interpreter mode (the CPU CI path).

    ``fused_pull_q`` is a beyond-paper optimization (EXPERIMENTS.md §Perf):
    Algorithm 1 invokes RINGATTN once per Pull-Q stage, re-circulating the
    *same* diagonal KV chunk through the Ring group P_u times.  The fused
    variant keeps the staged (distance-k) Q permutes — identical inter-pod
    wire schedule — but runs ONE ring circulation over the assembled
    gathered Q, cutting Pull-Q intra-pod ring traffic by P_u×.  Trade-off:
    diagonal-KV compute can no longer start before Q chunks arrive (the
    permuted Q tensors are 2× smaller than KV and arrive early, so the
    exposed latency is small)."""
    p_u, p_r = layout.p_ulysses, layout.p_ring
    b, ls, hq, d = q.shape
    h = hq // p_u
    u, r = layout.my_coords()

    qc = _split_heads(q, p_u)  # [P_u, B, Ls, h, D]
    kc = _split_heads(k, p_u)
    vc = _split_heads(v, p_u)
    k_diag, v_diag = jnp.take(kc, u, axis=0), jnp.take(vc, u, axis=0)

    my_pos = lambda: _rank_of(layout, u, r) * ls + jnp.arange(ls)
    chunk_pos = lambda src_u: _rank_of(layout, src_u, r) * ls + jnp.arange(ls)
    # position of the diagonal KV chunk as it circulates the Ring group
    diag_kpos_fn = lambda owner_r: _rank_of(layout, u, owner_r) * ls + jnp.arange(ls)

    acc = empty_partial(b, p_u * ls, h, d)  # gathered-q accumulator, source-u order

    if not fused_pull_q:
        # ---- stage 0: stationary diagonal chunks, compute starts, no comm
        part = ring_attention(
            jnp.take(qc, u, axis=0), k_diag, v_diag, layout,
            q_pos=my_pos(), k_pos_fn=diag_kpos_fn,
            scale=scale, causal=causal, window=window, unroll=unroll,
            kv_block=kv_block, backend=backend, interpret=interpret,
        )
        acc = _merge_slice(acc, part, u * ls, ls)

    stream = Stream("torus", backend=backend, interpret=interpret)

    # ---- Pull-Q stages: Q chunks arrive one hop-distance k at a time
    q_recv = [None] * p_u  # q_recv[j] = Q chunk from ulysses peer j
    for kstage in range(1, p_u):
        send = jnp.take(qc, (u + kstage) % p_u, axis=0)
        recv = torus_hop(layout, kstage, send, stream=stream,
                         overlaps="diag-KV attend").wait()
        src = (u - kstage) % p_u
        if not fused_pull_q:
            part = ring_attention(
                recv, k_diag, v_diag, layout,
                q_pos=chunk_pos(src), k_pos_fn=diag_kpos_fn,
                scale=scale, causal=causal, window=window, unroll=unroll,
                kv_block=kv_block, backend=backend, interpret=interpret,
            )
            acc = _pin(_merge_slice(acc, part, src * ls, ls))
        q_recv[kstage] = (src, recv)

    # assemble the gathered Q (source-u order) for the Pull-KV stages
    q_gather = jnp.zeros((p_u, b, ls, h, d), q.dtype)
    q_gather = lax.dynamic_update_slice_in_dim(
        q_gather, jnp.take(qc, u, axis=0)[None], u, axis=0
    )
    for src, recv in filter(None, q_recv):
        q_gather = lax.dynamic_update_slice_in_dim(q_gather, recv[None], src, axis=0)
    q_gather = jnp.moveaxis(q_gather, 0, 1).reshape(b, p_u * ls, h, d)
    q_pos_all = group_positions(layout, ls, r)

    if fused_pull_q:
        # single ring circulation of the diagonal KV over ALL gathered Q
        part = ring_attention(
            q_gather, k_diag, v_diag, layout,
            q_pos=q_pos_all, k_pos_fn=diag_kpos_fn,
            scale=scale, causal=causal, window=window, unroll=unroll,
            kv_block=kv_block, backend=backend, interpret=interpret,
        )
        acc = merge(acc, part)

    # ---- Pull-KV stages: KV chunks arrive; all Q attends each new chunk
    for kstage in range(1, p_u):
        src = (u - kstage) % p_u
        k_recv, v_recv = torus_hop(
            layout, kstage,
            jnp.take(kc, (u + kstage) % p_u, axis=0),
            jnp.take(vc, (u + kstage) % p_u, axis=0),
            stream=stream, overlaps="gathered-Q attend").wait()
        (k_recv, v_recv), acc = _gate((k_recv, v_recv), acc)
        kpos_fn = lambda owner_r, s=src: _rank_of(layout, s, owner_r) * ls + jnp.arange(ls)
        part = ring_attention(
            q_gather, k_recv, v_recv, layout,
            q_pos=q_pos_all, k_pos_fn=kpos_fn,
            scale=scale, causal=causal, window=window, unroll=unroll,
            kv_block=kv_block, backend=backend, interpret=interpret,
        )
        acc = merge(acc, part)

    # ---- Push-O: staged inverse all-to-all; diagonal O never moves
    o = finalize(acc, dtype=q.dtype)  # [B, P_u * Ls, h, D]
    return scatter_o(o, layout, backend=backend, interpret=interpret,
                     wire_dtype=wire_dtype)
