#!/usr/bin/env python3
"""Fit NetworkModel parameters to measured BENCH_*.json step latencies
(ROADMAP comm-model calibration item; DESIGN.md §9 uses the result to
score scheduler admissions with calibrated rather than nominal numbers).

``benchmarks/run.py`` emits per-config BENCH_<module>.json trajectory
records whose ``measured_step_us`` field multi-machine runs fill in.
This script least-squares-fits (intra_bw, inter_bw, intra_lat, inter_lat,
mfu) so the analytical model reproduces those measurements:

    python scripts/calibrate_comm.py BENCH_hybrid_sweep.json \
        --out calibration.json
    python -m benchmarks.hybrid_sweep --calibration calibration.json
    python -m benchmarks.e2e_latency  --calibration calibration.json

The solver itself lives in ``repro.core.calibration`` (damped
Gauss-Newton on log-parameters with log-ratio residuals, numpy only) —
shared with the serving engine's in-flight ``OnlineCalibrator``
(DESIGN.md §10); this script is the offline record-file frontend.

The regression test (tests/test_calibration.py) pins the fitted/nominal
ratios on a checked-in fixture generated from a known ground-truth model.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.core import calibration  # noqa: E402
from repro.core.calibration import FIT_PARAMS  # noqa: E402,F401  (re-export)
from repro.core.comm_model import (  # noqa: E402
    LayerWorkload,
    NetworkModel,
    plan_step_latency,
)
from repro.core.planner import plan_hybrid  # noqa: E402


def load_records(paths: list[pathlib.Path]) -> list[dict]:
    """Records with a fit target, from any mix of BENCH_*.json files."""
    out = []
    for p in paths:
        payload = json.loads(p.read_text())
        for rec in payload.get("records", []):
            if rec.get("measured_step_us") is None:
                continue
            if "workload" not in rec or "plan" not in rec:
                continue
            out.append(rec)
    return out


def predict_us(rec: dict, net: NetworkModel) -> float:
    """Re-run the comm model on one record's configuration.

    The (cfg, pp) split is re-planned with ``plan_hybrid`` — deterministic
    given the recorded cluster shape — so the prediction path is exactly
    the one the sweeps used when the record was written."""
    wl = rec["workload"]
    w = LayerWorkload(batch=wl["batch"], seq=wl["seq"], heads=wl["heads"],
                      head_dim=wl["head_dim"])
    pl = rec["plan"]
    h = plan_hybrid(rec["n_machines"], rec["m_per_machine"], wl["heads"],
                    cfg_parallel=pl["cfg"] > 1, cfg_degree=max(pl["cfg"], 2),
                    pp=pl["pp"], n_layers=wl["n_layers"])
    assert (h.sp.p_ulysses, h.sp.p_ring) == (pl["p_ulysses"], pl["p_ring"]), (
        f"{rec['name']}: re-planned SP split {h.sp.p_ulysses}x{h.sp.p_ring} "
        f"!= recorded {pl['p_ulysses']}x{pl['p_ring']}")
    pred = plan_step_latency(h, w, net, n_layers=wl["n_layers"], guided=True,
                             num_patches=pl.get("num_patches"))
    return pred["t_step"] * 1e6


def fit(recs: list[dict], *, iters: int = 40, damping: float = 1e-3,
        fd_eps: float = 1e-5) -> tuple[NetworkModel, dict]:
    """Fit the shared solver to record dicts; returns (model, report
    dict) — the report-as-dict form older callers and the calibration
    JSON payload expect."""
    assert recs, "no records with measured_step_us — nothing to fit"
    net, report = calibration.fit(recs, predict_us, iters=iters,
                                  damping=damping, fd_eps=fd_eps)
    return net, report.as_dict()


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench", nargs="+", type=pathlib.Path,
                    help="BENCH_*.json files with measured_step_us filled in")
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="write the fitted NetworkModel JSON here "
                         "(stdout otherwise)")
    args = ap.parse_args(argv)
    recs = load_records(args.bench)
    if not recs:
        print("no records with measured_step_us in "
              f"{[str(p) for p in args.bench]}", file=sys.stderr)
        return 1
    net, report = fit(recs)
    payload = {k: getattr(net, k) for k in FIT_PARAMS}
    payload["fit"] = report
    text = json.dumps(payload, indent=1, sort_keys=True)
    if args.out:
        args.out.write_text(text)
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(text)
    print(f"fit: {report['n_records']} records, rms rel error "
          f"{report['rms_rel_error']:.4f}", file=sys.stderr)
    for k, v in report["ratio_vs_nominal"].items():
        print(f"  {k}: x{v:.3f} vs nominal", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
