"""SLA-aware request scheduling for DiT serving (DESIGN.md §9/§10).

Resolution-bucketed continuous batching: a bucketer groups requests by
latent length, an admission policy scores (bucket, batch-size) candidates
with the analytical comm model against per-request SLAs, a plan cache
selects and memoizes one ``plan_hybrid`` execution plan (and compiled
step) per bucket shape, and a drift policy turns the displaced pipeline's
``kv_drift`` signal into threshold-triggered resyncs.

The adaptive control loop (DESIGN.md §10) closes three feedback paths on
top: an ``ArrivalForecaster`` bounds padded-batch deferral with an
explicit per-bucket horizon, a ``PreemptionPolicy`` can park a running
batch between sampler steps for an SLA-critical bucket, and an
``OnlineCalibrator`` refits the comm model from measured step times,
invalidating plan-cache scores when the fit drifts.
"""
from .admission import AdmissionPolicy, Candidate, SchedConfig
from .bucketer import (
    Bucket,
    Bucketer,
    BucketStats,
    aged_priority,
    deadline_of,
    padded_rows,
)
from .control import (
    CalibrationConfig,
    ControlConfig,
    OnlineCalibrator,
    PreemptionPolicy,
    StepObservation,
    steady_t_step,
)
from .drift import DriftPolicy
from .forecast import ArrivalForecaster, BucketRate
from .plan_cache import PlanCache, PlanChoice
from .scheduler import Admission, RequestScheduler
from ..metrics import (
    SCHEMA_VERSION,
    JsonlTracker,
    NullTracker,
    Record,
    RecordingTracker,
    Tracker,
)

__all__ = [
    "Admission",
    "AdmissionPolicy",
    "ArrivalForecaster",
    "Bucket",
    "Bucketer",
    "BucketRate",
    "BucketStats",
    "CalibrationConfig",
    "Candidate",
    "ControlConfig",
    "DriftPolicy",
    "JsonlTracker",
    "NullTracker",
    "OnlineCalibrator",
    "PlanCache",
    "PlanChoice",
    "PreemptionPolicy",
    "Record",
    "RecordingTracker",
    "RequestScheduler",
    "SCHEMA_VERSION",
    "SchedConfig",
    "StepObservation",
    "Tracker",
    "aged_priority",
    "deadline_of",
    "padded_rows",
    "steady_t_step",
]
