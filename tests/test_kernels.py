"""Pallas flash_mqkv kernel vs pure-jnp oracle (interpret mode on CPU).

Sweeps shapes / dtypes / masks / GQA groups / multi-segment merges per the
assignment's per-kernel requirement.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MaskSpec, reference_attention
from repro.kernels import flash_attention, flash_attention_segments
from repro.kernels.flash_mqkv import flash_mqkv
from repro.kernels.ref import flash_attention_ref


def _mk(key, b, lq, lk, hq, hkv, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, lq, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, lk, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, lk, hkv, d), dtype)
    return q, k, v


@pytest.mark.parametrize("shape", [
    (1, 16, 16, 1, 1, 16),
    (2, 64, 64, 4, 2, 32),
    (1, 128, 256, 8, 8, 64),
    (2, 48, 80, 6, 3, 128),   # non-multiple of block -> padding path
])
@pytest.mark.parametrize("causal,window", [(False, None), (True, None), (True, 20)])
def test_kernel_shape_sweep(shape, causal, window):
    b, lq, lk, hq, hkv, d = shape
    if causal and lq != lk:
        lk = lq  # causal comparison needs aligned positions
    q, k, v = _mk(jax.random.PRNGKey(0), b, lq, lk, hq, hkv, d, jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_k=32, interpret=True)
    ref = reference_attention(q, k, v, mask=MaskSpec(causal=causal, window=window))
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
def test_kernel_dtype_sweep(dtype, tol):
    q, k, v = _mk(jax.random.PRNGKey(1), 2, 64, 64, 4, 2, 64, dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = reference_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), mask=MaskSpec(causal=True))
    np.testing.assert_allclose(np.asarray(out, np.float32), ref, rtol=tol, atol=tol)


@pytest.mark.parametrize("group", [1, 2, 4])
def test_kernel_gqa_groups(group):
    hkv = 2
    q, k, v = _mk(jax.random.PRNGKey(2), 2, 32, 32, hkv * group, hkv, 32,
                  jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                          interpret=True)
    ref = reference_attention(q, k, v, mask=MaskSpec(causal=True))
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_kernel_positions_discontiguous():
    """Chunks anywhere in memory: exact masks from global position arrays."""
    key = jax.random.PRNGKey(3)
    q, k, v = _mk(key, 1, 32, 32, 2, 2, 32, jnp.float32)
    # global positions: q at [100, 132), k split across two far-apart ranges
    q_pos = jnp.arange(32) + 100
    k_pos = jnp.concatenate([jnp.arange(16), jnp.arange(16) + 110])
    out = flash_attention(q, k, v, q_pos, k_pos, causal=True,
                          block_q=16, block_k=16, interpret=True)
    ref = flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(2, 32, 32),
        k.transpose(0, 2, 1, 3).reshape(2, 32, 32),
        v.transpose(0, 2, 1, 3).reshape(2, 32, 32),
        q_pos, k_pos, causal=True)
    np.testing.assert_allclose(
        out, ref.reshape(1, 2, 32, 32).transpose(0, 2, 1, 3), rtol=2e-5, atol=2e-5)


def test_kernel_state_carry_matches_single_call():
    """Algorithm 2's fused merge: two calls with carried (O', l, m) ==
    one call over the concatenated KV."""
    key = jax.random.PRNGKey(4)
    q, k, v = _mk(key, 1, 32, 64, 2, 2, 32, jnp.float32)
    kp = jnp.arange(64, dtype=jnp.int32)
    segs = [(k[:, :32], v[:, :32], kp[:32]), (k[:, 32:], v[:, 32:], kp[32:])]
    out = flash_attention_segments(q, segs, q_pos=jnp.arange(32) + 32,
                                   causal=True, block_q=16, block_k=16,
                                   interpret=True)
    full = flash_attention(q, k, v, jnp.arange(32) + 32, kp, causal=True,
                           block_q=16, block_k=16, interpret=True)
    np.testing.assert_allclose(out, full, rtol=2e-5, atol=2e-5)


def test_kernel_segment_order_invariance():
    key = jax.random.PRNGKey(5)
    q, k, v = _mk(key, 1, 32, 96, 2, 1, 32, jnp.float32)
    kp = jnp.arange(96, dtype=jnp.int32)
    segs = [(k[:, i:i + 32], v[:, i:i + 32], kp[i:i + 32]) for i in (0, 32, 64)]
    a = flash_attention_segments(q, segs, q_pos=jnp.arange(32) + 64,
                                 causal=True, interpret=True)
    b = flash_attention_segments(q, segs[::-1], q_pos=jnp.arange(32) + 64,
                                 causal=True, interpret=True)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_kernel_padding_masked():
    """k_pos = -1 marks padding: result identical to the unpadded call."""
    key = jax.random.PRNGKey(6)
    q, k, v = _mk(key, 1, 16, 48, 2, 2, 32, jnp.float32)
    out_full = flash_attention(q, k[:, :40], v[:, :40],
                               jnp.arange(16), jnp.arange(40),
                               block_q=16, block_k=16, interpret=True)
    kp = jnp.where(jnp.arange(48) < 40, jnp.arange(48), -1)
    kk = k.at[:, 40:].set(999.0)  # garbage in padded slots must not leak
    vv = v.at[:, 40:].set(999.0)
    out_pad = flash_attention(q, kk, vv, jnp.arange(16), kp,
                              block_q=16, block_k=16, interpret=True)
    np.testing.assert_allclose(out_pad, out_full, rtol=2e-5, atol=2e-5)


def test_kernel_unnormalized_state_output():
    """finalize=False returns FA2-style (O', l, m) mergeable state."""
    key = jax.random.PRNGKey(7)
    b, l, h, d = 1, 32, 2, 32
    q, k, v = _mk(key, b, l, l, h, h, d, jnp.float32)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, l, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, l, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, l, d)
    pos = jnp.arange(l, dtype=jnp.int32)
    o, lsum, m = flash_mqkv(qf, kf, vf, pos, pos, finalize=False,
                            block_q=16, block_k=16, interpret=True)
    o_ref, l_ref, m_ref = flash_attention_ref(qf, kf, vf, pos, pos,
                                              finalize=False)
    np.testing.assert_allclose(o, o_ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(lsum, l_ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(m, m_ref, rtol=2e-5, atol=2e-5)
