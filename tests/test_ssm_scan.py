"""Chunked linear-recurrence scans vs naive sequential recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import ssm

B, H, N, P_ = 2, 3, 8, 5


def naive_rwkv(r, k, v, w, u, S0=None):
    L = r.shape[1]
    S = np.zeros((B, H, N, N)) if S0 is None else np.asarray(S0, np.float64).copy()
    out = np.zeros((B, L, H, N))
    r, k, v, w, u = (np.asarray(t, np.float64) for t in (r, k, v, w, u))
    for t in range(L):
        kv = k[:, t][..., :, None] * v[:, t][..., None, :]
        out[:, t] = np.einsum("bhn,bhnm->bhm", r[:, t],
                              S + u[None, :, :, None] * kv)
        S = w[:, t][..., None] * S + kv
    return out, S


def naive_ssd(x, dt, Bm, Cm, a, S0=None):
    L = x.shape[1]
    S = np.zeros((B, H, P_, N)) if S0 is None else np.asarray(S0, np.float64).copy()
    out = np.zeros((B, L, H, P_))
    x, dt, Bm, Cm, a = (np.asarray(t, np.float64) for t in (x, dt, Bm, Cm, a))
    for t in range(L):
        g = np.exp(dt[:, t] * a[None])
        S = g[..., None, None] * S + np.einsum(
            "bhp,bhn->bhpn", x[:, t] * dt[:, t][..., None], Bm[:, t])
        out[:, t] = np.einsum("bhpn,bhn->bhp", S, Cm[:, t])
    return out, S


def _rwkv_inputs(seed, L):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], (B, L, H, N))
    k = jax.random.normal(ks[1], (B, L, H, N))
    v = jax.random.normal(ks[2], (B, L, H, N))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, L, H, N))) * 0.5 + 0.5
    u = jax.random.normal(ks[4], (H, N)) * 0.1
    return r, k, v, w, u


@given(st.integers(0, 1000), st.sampled_from([16, 32, 64]),
       st.sampled_from([8, 16, 64]))
@settings(max_examples=12, deadline=None)
def test_rwkv_chunk_scan_matches_naive(seed, L, chunk):
    r, k, v, w, u = _rwkv_inputs(seed, L)
    res = ssm.rwkv6_chunk_scan(r, k, v, w, u, chunk=chunk)
    out, S = naive_rwkv(r, k, v, w, u)
    np.testing.assert_allclose(res.out, out, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(res.s_out, S, rtol=1e-4, atol=1e-4)


def test_rwkv_influence_matches_nonzero_state():
    r, k, v, w, u = _rwkv_inputs(7, 32)
    S0 = jax.random.normal(jax.random.PRNGKey(9), (B, H, N, N)) * 0.3
    res = ssm.rwkv6_chunk_scan(r, k, v, w, u, chunk=16)
    got = ssm.rwkv6_apply_influence(res.out, res.infl, S0)
    want, _ = naive_rwkv(r, k, v, w, u, S0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(st.integers(0, 1000), st.sampled_from([16, 64]))
@settings(max_examples=10, deadline=None)
def test_ssd_chunk_scan_matches_naive(seed, L):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, L, H, P_))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    Bm = jax.random.normal(ks[2], (B, L, H, N)) * 0.5
    Cm = jax.random.normal(ks[3], (B, L, H, N)) * 0.5
    a = -jnp.exp(jax.random.normal(ks[4], (H,)) * 0.3)
    res = ssm.ssd_chunk_scan(x, dt, Bm, Cm, a, chunk=16)
    out, S = naive_ssd(x, dt, Bm, Cm, a)
    np.testing.assert_allclose(res.out, out, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(res.s_out, S, rtol=1e-4, atol=1e-4)
    # influence with nonzero initial state
    S0 = jax.random.normal(ks[0], (B, H, P_, N)) * 0.3
    got = ssm.ssd_apply_influence(res.out, res.infl, S0)
    want, _ = naive_ssd(x, dt, Bm, Cm, a, S0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_rwkv_decode_step_matches_naive():
    r, k, v, w, u = _rwkv_inputs(11, 8)
    S = np.zeros((B, H, N, N))
    out_ref, _ = naive_rwkv(r, k, v, w, u)
    s = jnp.zeros((B, H, N, N))
    for t in range(8):
        o, s = ssm.rwkv6_decode_step(r[:, t], k[:, t], v[:, t], w[:, t], u, s)
        np.testing.assert_allclose(o, out_ref[:, t], rtol=1e-4, atol=1e-4)


def test_ssd_decode_step_matches_naive():
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    L = 8
    x = jax.random.normal(ks[0], (B, L, H, P_))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    Bm = jax.random.normal(ks[2], (B, L, H, N)) * 0.5
    Cm = jax.random.normal(ks[3], (B, L, H, N)) * 0.5
    a = -jnp.exp(jax.random.normal(ks[4], (H,)) * 0.3)
    ref, _ = naive_ssd(x, dt, Bm, Cm, a)
    s = jnp.zeros((B, H, P_, N))
    for t in range(L):
        o, s = ssm.ssd_decode_step(x[:, t], dt[:, t], Bm[:, t], Cm[:, t], a, s)
        np.testing.assert_allclose(o, ref[:, t], rtol=1e-4, atol=1e-4)
