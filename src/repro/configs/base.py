"""Model/architecture configuration.

One ``ModelConfig`` covers every assigned family (dense / moe / ssm /
hybrid / vlm / audio / dit); family-specific fields are simply unused by
the others.  Every config file in this package cites its source in the
module docstring, and provides

    CONFIG          — the full assigned architecture
    reduced()       — the smoke-test variant (≤2 layers, d_model ≤ 512,
                      ≤4 experts) of the same family
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio", "dit"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 2
    n_shared_experts: int = 0  # qwen2-moe: shared experts always active
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    moe_d_ff: int = 0  # routed-expert hidden size
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001  # load-balance loss weight


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_size: int = 16
    expand: int = 2  # d_inner = expand * d_model (mamba-style)
    n_ssm_heads: int = 0  # rwkv: heads for WKV; hymba: mamba heads
    dt_rank: int = 0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int  # 0 for attention-free archs (rwkv6)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # --- positional / attention flavour ---
    rope: Literal["rope", "mrope", "rope2d", "sinusoidal", "none"] = "rope"
    rope_theta: float = 10000.0
    rope_pct: float = 1.0  # stablelm: partial rotary
    qkv_bias: bool = False
    causal: bool = True
    window: int | None = None  # sliding-window attention (tokens)
    global_attn_every: int = 0  # hymba: every k-th layer is global
    # --- families ---
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    encoder_layers: int = 0  # whisper: encoder depth (decoder = n_layers)
    encoder_seq: int = 1536  # whisper: frames after conv frontend (padded for SP)
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    act: Literal["swiglu", "gelu", "geglu"] = "swiglu"
    tie_embeddings: bool = False
    # --- numerics / sharding ---
    dtype: str = "bfloat16"
    # logical-axis -> mesh-axes rules; see models/sharding.py
    sharding_overrides: tuple[tuple[str, tuple[str, ...]], ...] = ()
    citation: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.n_heads == 0

    def params_dense_estimate(self) -> float:
        """Rough total parameter count (for roofline MODEL_FLOPS = 6·N·D)."""
        d, ff, l = self.d_model, self.d_ff, self.n_layers
        hd = self.resolved_head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.attention_free:
            attn = 2 * d * d  # rwkv time-mix approx
        gate = 3 if self.act in ("swiglu", "geglu") else 2
        mlp = gate * d * ff
        if self.moe:
            mlp = gate * d * self.moe.moe_d_ff * self.moe.n_experts
            mlp += gate * d * self.moe.moe_d_ff * self.moe.n_shared_experts
            if self.moe.dense_residual:
                mlp += gate * d * self.d_ff
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return float(l * (attn + mlp) + emb)

    def params_active_estimate(self) -> float:
        """Active parameters per token (MoE: top-k + shared + dense)."""
        if not self.moe:
            return self.params_dense_estimate()
        d, l = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        gate = 3 if self.act in ("swiglu", "geglu") else 2
        mlp = gate * d * self.moe.moe_d_ff * (self.moe.top_k + self.moe.n_shared_experts)
        if self.moe.dense_residual:
            mlp += gate * d * self.d_ff
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return float(l * (attn + mlp) + emb)
