"""SP strategies ≡ single-device oracle on a (2,2,2) mesh (8 fake devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    MaskSpec,
    SPConfig,
    decode_attention,
    reference_attention,
    sp_attention,
)

B, L, HQ, HKV, D = 2, 32, 8, 4, 16


@pytest.fixture(scope="module")
def qkv(rng):
    kq, kk, kv = jax.random.split(rng, 3)
    return (jax.random.normal(kq, (B, L, HQ, D)),
            jax.random.normal(kk, (B, L, HKV, D)),
            jax.random.normal(kv, (B, L, HKV, D)))


@pytest.mark.parametrize("strategy", ["ring", "ulysses", "usp", "swift",
                                      "swift_torus"])
@pytest.mark.parametrize("causal,window", [(False, None), (True, None),
                                           (True, 12), (False, 9)])
def test_strategy_matches_oracle(strategy, causal, window, qkv, mesh8):
    q, k, v = qkv
    cfg = SPConfig(strategy=strategy, sp_axes=("pod", "model"),
                   batch_axes=("data",))
    ref = reference_attention(q, k, v,
                              mask=MaskSpec(causal=causal, window=window))
    out = jax.jit(lambda q, k, v: sp_attention(
        q, k, v, mesh=mesh8, cfg=cfg, causal=causal, window=window))(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("strategy", ["usp", "swift", "swift_torus"])
def test_sp_over_all_three_axes(strategy, qkv, mesh8):
    """long_500k-style: sequence sharded over the whole mesh."""
    q, k, v = qkv
    cfg = SPConfig(strategy=strategy, sp_axes=("pod", "data", "model"),
                   batch_axes=None)
    ref = reference_attention(q, k, v, mask=MaskSpec(causal=True))
    out = jax.jit(lambda q, k, v: sp_attention(
        q, k, v, mesh=mesh8, cfg=cfg, causal=True))(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("causal,window", [(False, None), (True, None),
                                           (True, 12)])
def test_torus_fused_pull_q_matches_oracle(causal, window, qkv, mesh8):
    """Beyond-paper fused Pull-Q schedule is numerically identical."""
    q, k, v = qkv
    cfg = SPConfig(strategy="swift_torus", sp_axes=("pod", "model"),
                   batch_axes=("data",), torus_fused_pull_q=True)
    ref = reference_attention(q, k, v,
                              mask=MaskSpec(causal=causal, window=window))
    out = jax.jit(lambda q, k, v: sp_attention(
        q, k, v, mesh=mesh8, cfg=cfg, causal=causal, window=window))(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_gqa_limits_ulysses_degree(qkv, mesh8):
    """kv=1 head ⇒ planner must fall back to pure ring; still correct."""
    q, k, v = qkv
    k1, v1 = k[:, :, :1], v[:, :, :1]
    cfg = SPConfig(strategy="swift_torus", sp_axes=("pod", "model"),
                   batch_axes=("data",))
    ref = reference_attention(q, k1, v1, mask=MaskSpec(causal=True))
    out = jax.jit(lambda q, k, v: sp_attention(
        q, k, v, mesh=mesh8, cfg=cfg, causal=True))(q, k1, v1)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_decode_attention_distributed(qkv, mesh8):
    q, k, v = qkv
    cur = 21
    kc = jnp.zeros((B, L, HKV, D)).at[:, :cur].set(k[:, :cur])
    vc = jnp.zeros((B, L, HKV, D)).at[:, :cur].set(v[:, :cur])
    cfg = SPConfig(strategy="swift", sp_axes=("pod", "model"),
                   batch_axes=("data",))
    o, kc2, vc2 = jax.jit(lambda *a: decode_attention(
        *a, mesh=mesh8, cfg=cfg))(q[:, cur:cur + 1], kc, vc,
                                  k[:, cur:cur + 1], v[:, cur:cur + 1],
                                  jnp.int32(cur))
    ref = reference_attention(q[:, cur:cur + 1], k[:, :cur + 1], v[:, :cur + 1])
    np.testing.assert_allclose(o, ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(kc2[:, cur], k[:, cur], rtol=1e-6)


def test_decode_attention_windowed(qkv, mesh8):
    q, k, v = qkv
    cur, win = 25, 8
    kc = jnp.zeros((B, L, HKV, D)).at[:, :cur].set(k[:, :cur])
    vc = jnp.zeros((B, L, HKV, D)).at[:, :cur].set(v[:, :cur])
    cfg = SPConfig(strategy="swift", sp_axes=("pod", "model"),
                   batch_axes=("data",))
    o, _, _ = jax.jit(lambda *a: decode_attention(
        *a, mesh=mesh8, cfg=cfg, window=win))(q[:, cur:cur + 1], kc, vc,
                                              k[:, cur:cur + 1],
                                              v[:, cur:cur + 1], jnp.int32(cur))
    lo = cur + 1 - win
    ref = reference_attention(q[:, cur:cur + 1], k[:, lo:cur + 1],
                              v[:, lo:cur + 1])
    np.testing.assert_allclose(o, ref, rtol=1e-4, atol=1e-4)
