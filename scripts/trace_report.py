#!/usr/bin/env python3
"""Render a span trace (``--profile`` JSONL, DESIGN.md §12) into human
and Perfetto form.

Three outputs from one ``metrics.v1`` span stream:

  * ``--chrome OUT.json`` — Chrome trace-event JSON loadable in Perfetto
    (https://ui.perfetto.dev): one track per device coordinate (the
    ``track`` tag the comm profiler stamps, e.g. ``pod=0,model=3``) plus
    a ``host`` track for engine/sampler/plan-cache/calibrator spans,
    with nesting rebuilt from the ``parent`` tags.
  * overlap-efficiency table (default stdout) — per comm leg class
    (stream/channel/stage): measured hidden fraction
    ``1 - Σexposed / Σdur`` (exposed = how long the receiver's wait
    stalled before the signal landed) next to the *intended* schedule
    from ``comm.trace`` (the ``intent`` tag carries the put's
    ``overlaps`` label: non-empty means trace validation admitted the
    overlap, so the intended hidden fraction is 1.0), plus the fraction
    of each leg's duration spent under a marked compute span on the same
    device track.
  * per-leg NetworkModel residuals — each leg class's measured mean
    duration against the model's ``bytes/bw + lat + issue`` prediction,
    with the drift attributed to a specific term: the implied bandwidth
    (intra_bw or inter_bw by the leg's axes), the implied per-leg
    overhead (lat + issue), and — from the ``engine.step`` spans' model
    tags — the implied mfu.  This is what turns "the calibrator moved"
    into "inter_bw is 3x off, everything else is fine".

``--check`` runs the CI assertions (profile-smoke job): the Chrome JSON
parses, every span with a ``parent`` tag nests inside a same-track span
of that name, and at least one comm leg overlaps a compute span.

Usage:
  python scripts/trace_report.py TRACE.JSONL [--chrome OUT.json]
         [--check] [--inter-axes pod] [--net calibration.json]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from collections import defaultdict

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.core.comm_model import NetworkModel, load_network_model  # noqa: E402
from repro.serving.metrics import Record, read_jsonl  # noqa: E402

HOST_TRACK = "host"


def load_spans(path: str | pathlib.Path) -> list[Record]:
    """Span records of a trace, tolerating a crashed writer's tail."""
    return [r for r in read_jsonl(path, partial_tail="drop")
            if r.kind == "span"]


def track_of(r: Record) -> str:
    return str(r.tags.get("track", HOST_TRACK))


def span_name(r: Record) -> str:
    """Display name: comm legs read as their channel, compute as label."""
    if r.name == "comm.leg":
        return str(r.tags.get("channel", r.name))
    if r.name == "comm.compute":
        return str(r.tags.get("label", r.name))
    return r.name


# ---------------------------------------------------------------------------
# (a) Chrome trace-event JSON
# ---------------------------------------------------------------------------

def chrome_trace(spans: list[Record]) -> dict:
    """Trace-event JSON: ``ph:"X"`` complete events, µs timebase, one tid
    per track (host first, then device coords sorted)."""
    tracks = sorted({track_of(r) for r in spans},
                    key=lambda t: (t != HOST_TRACK, t))
    tid = {t: i for i, t in enumerate(tracks)}
    events: list[dict] = [
        {"ph": "M", "pid": 0, "tid": 0, "name": "process_name",
         "args": {"name": "repro --profile"}},
    ]
    for t in tracks:
        events.append({"ph": "M", "pid": 0, "tid": tid[t],
                       "name": "thread_name", "args": {"name": t}})
    for r in spans:
        args = {k: v for k, v in r.tags.items() if k != "track"}
        if r.step is not None:
            args["step"] = r.step
        events.append({
            "ph": "X", "pid": 0, "tid": tid[track_of(r)],
            "ts": r.t_start * 1e6, "dur": r.value * 1e6,
            "name": span_name(r), "cat": r.name.split(".")[0],
            "args": args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


# ---------------------------------------------------------------------------
# (b) overlap-efficiency table
# ---------------------------------------------------------------------------

def _intervals_by_track(spans: list[Record],
                        name: str) -> dict[str, list[tuple[float, float]]]:
    out: dict[str, list[tuple[float, float]]] = defaultdict(list)
    for r in spans:
        if r.name == name:
            out[track_of(r)].append((r.t_start, r.t_start + r.value))
    for v in out.values():
        v.sort()
    return out


def _overlap_with(iv: tuple[float, float],
                  others: list[tuple[float, float]]) -> float:
    """Total time of ``iv`` covered by the (sorted, possibly overlapping)
    ``others`` — union of the pairwise intersections."""
    lo, hi = iv
    covered = 0.0
    cur = lo
    for a, b in others:
        if b <= cur or a >= hi:
            continue
        a = max(a, cur)
        b = min(b, hi)
        if b > a:
            covered += b - a
            cur = b
    return covered


def leg_key(r: Record) -> tuple:
    return (str(r.tags.get("stream", "")), str(r.tags.get("channel", "")),
            int(r.tags.get("stage", 0)))


def overlap_table(spans: list[Record]) -> list[dict]:
    """One row per comm leg class: measured vs intended hiding."""
    compute = _intervals_by_track(spans, "comm.compute")
    rows: dict[tuple, dict] = {}
    for r in spans:
        if r.name != "comm.leg":
            continue
        k = leg_key(r)
        row = rows.setdefault(k, {
            "stream": k[0], "channel": k[1], "stage": k[2],
            "intent": str(r.tags.get("intent", "")),
            "backend": str(r.tags.get("backend", "")),
            "n": 0, "dur_s": 0.0, "exposed_s": 0.0, "n_waited": 0,
            "compute_overlap_s": 0.0,
        })
        row["n"] += 1
        row["dur_s"] += r.value
        if "exposed_s" in r.tags:
            row["exposed_s"] += float(r.tags["exposed_s"])
            row["n_waited"] += 1
        iv = (r.t_start, r.t_start + r.value)
        row["compute_overlap_s"] += _overlap_with(
            iv, compute.get(track_of(r), []))
    out = []
    for k in sorted(rows):
        row = rows[k]
        dur = row["dur_s"]
        row["mean_us"] = dur / row["n"] * 1e6
        # measured: the stall-based hidden fraction (1.0 when no wait was
        # observed or every wait came after the signal)
        row["hidden_frac"] = 1.0 - row["exposed_s"] / dur if dur > 0 else 1.0
        row["compute_overlap_frac"] = (row["compute_overlap_s"] / dur
                                       if dur > 0 else 0.0)
        # intended: comm.trace admitted the overlap iff the put named the
        # compute it hides behind ("sem" marks the landing-protocol span)
        row["intended_hidden"] = row["intent"] not in ("", "sem")
        out.append(row)
    return out


def format_overlap(rows: list[dict]) -> str:
    lines = ["overlap efficiency (measured vs intended, DESIGN.md §12)",
             f"{'leg (stream/channel/stage)':<34} {'n':>4} {'mean_us':>9} "
             f"{'hidden':>7} {'intended':>9} {'compute_ov':>10}"]
    for r in rows:
        leg = f"{r['stream']}/{r['channel']}/s{r['stage']}"
        lines.append(
            f"{leg:<34} {r['n']:>4} {r['mean_us']:>9.1f} "
            f"{r['hidden_frac']:>7.2f} "
            f"{'1.00' if r['intended_hidden'] else '-':>9} "
            f"{r['compute_overlap_frac']:>10.2f}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# (c) per-leg NetworkModel residuals
# ---------------------------------------------------------------------------

def leg_residuals(spans: list[Record], net: NetworkModel,
                  inter_axes: frozenset[str]) -> list[dict]:
    """Measured mean duration per leg class vs the model's
    ``bytes/bw + lat + issue`` — and the term-level attribution: the
    implied bandwidth given the model's fixed overheads, and the implied
    per-leg overhead given the model's bandwidth."""
    agg: dict[tuple, dict] = {}
    for r in spans:
        if r.name != "comm.leg":
            continue
        k = leg_key(r)
        a = agg.setdefault(k, {
            "stream": k[0], "channel": k[1], "stage": k[2], "n": 0,
            "dur_s": 0.0, "nbytes": int(r.tags.get("nbytes", 0)),
            "axes": str(r.tags.get("axes", "")),
        })
        a["n"] += 1
        a["dur_s"] += r.value
    out = []
    for k in sorted(agg):
        a = agg[k]
        axes = set(a["axes"].split(",")) if a["axes"] else set()
        inter = bool(axes & inter_axes)
        bw = net.inter_bw if inter else net.intra_bw
        lat = net.inter_lat if inter else net.intra_lat
        overhead = lat + net.step_issue_overhead
        pred = a["nbytes"] / bw + overhead
        meas = a["dur_s"] / a["n"]
        wire = meas - overhead  # time left for the bytes under model overhead
        a.update({
            "cls": "inter" if inter else "intra",
            "measured_us": meas * 1e6,
            "predicted_us": pred * 1e6,
            "ratio": meas / pred if pred > 0 else float("inf"),
            # attribution: what each single term would have to be for the
            # model to match this leg, holding the others at their
            # current values
            "implied_bw": a["nbytes"] / wire if wire > 0 else 0.0,
            "implied_overhead_us": max(meas - a["nbytes"] / bw, 0.0) * 1e6,
            "bw_term": "inter_bw" if inter else "intra_bw",
        })
        out.append(a)
    return out


def step_residuals(spans: list[Record], net: NetworkModel) -> dict | None:
    """Whole-step and compute-term residuals from the ``engine.step``
    spans' model tags (``pred_t_step_s`` / ``pred_compute_s``).  The
    measured compute occupancy comes from the ``comm.compute`` spans
    (upper bounds — their start fires when inputs are ready), so the
    implied mfu is a lower bound on the true value."""
    steps = [r for r in spans if r.name == "engine.step"]
    if not steps:
        return None
    n = len(steps)
    meas_step = sum(r.value for r in steps) / n
    preds = [float(r.tags["pred_t_step_s"]) for r in steps
             if "pred_t_step_s" in r.tags]
    pred_step = sum(preds) / len(preds) if preds else None
    comp_preds = [float(r.tags["pred_compute_s"]) for r in steps
                  if "pred_compute_s" in r.tags]
    pred_comp = sum(comp_preds) / len(comp_preds) if comp_preds else None
    comp = [r for r in spans if r.name == "comm.compute"]
    tracks = {track_of(r) for r in comp} or {HOST_TRACK}
    meas_comp = (sum(r.value for r in comp) / (n * len(tracks))
                 if comp else None)
    out = {"n_steps": n, "measured_step_s": meas_step,
           "pred_step_s": pred_step,
           "step_ratio": (meas_step / pred_step
                          if pred_step else None),
           "measured_compute_s": meas_comp, "pred_compute_s": pred_comp}
    if meas_comp and pred_comp and meas_comp > 0:
        # measured slower than modelled compute => effective mfu lower
        out["implied_mfu"] = net.mfu * pred_comp / meas_comp
    return out


def format_residuals(rows: list[dict], step: dict | None,
                     net: NetworkModel) -> str:
    lines = ["per-leg NetworkModel residuals (term attribution)",
             f"{'leg':<34} {'cls':>5} {'bytes':>9} {'meas_us':>9} "
             f"{'pred_us':>9} {'ratio':>7}  attribution"]
    for r in rows:
        leg = f"{r['stream']}/{r['channel']}/s{r['stage']}"
        model_bw = net.inter_bw if r["cls"] == "inter" else net.intra_bw
        attr = (f"{r['bw_term']}~{r['implied_bw']:.3g}B/s "
                f"(model {model_bw:.3g}), "
                f"lat+issue~{r['implied_overhead_us']:.1f}us")
        lines.append(f"{leg:<34} {r['cls']:>5} {r['nbytes']:>9} "
                     f"{r['measured_us']:>9.1f} {r['predicted_us']:>9.1f} "
                     f"{r['ratio']:>7.2f}  {attr}")
    if step is not None:
        lines.append("")
        lines.append(
            f"steps: n={step['n_steps']} "
            f"measured={step['measured_step_s'] * 1e3:.2f}ms"
            + (f" pred={step['pred_step_s'] * 1e3:.2f}ms "
               f"ratio={step['step_ratio']:.2f}"
               if step["pred_step_s"] else ""))
        if step.get("implied_mfu") is not None:
            lines.append(
                f"compute: measured/dev/step="
                f"{step['measured_compute_s'] * 1e3:.2f}ms "
                f"model={step['pred_compute_s'] * 1e3:.2f}ms "
                f"=> implied mfu~{step['implied_mfu']:.3g} "
                f"(model {net.mfu}; lower bound, compute spans are "
                f"input-ready..output windows)")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# --check: the CI assertions (profile-smoke job)
# ---------------------------------------------------------------------------

def check_trace(spans: list[Record], chrome: dict) -> list[str]:
    errs: list[str] = []
    if not spans:
        return ["trace contains no span records"]
    # 1. Chrome JSON well-formed: every X event has the required fields
    #    and survives a JSON round-trip
    try:
        parsed = json.loads(json.dumps(chrome))
    except (TypeError, ValueError) as e:
        return [f"chrome trace not JSON-serializable: {e}"]
    xs = [e for e in parsed["traceEvents"] if e.get("ph") == "X"]
    if len(xs) != len(spans):
        errs.append(f"{len(spans)} spans but {len(xs)} X events")
    for e in xs:
        for f in ("ts", "dur", "pid", "tid", "name"):
            if f not in e:
                errs.append(f"X event missing {f!r}: {e}")
                break
    # 2. nesting: every span with a parent tag lies inside a same-track
    #    span of that name (small epsilon for clock granularity)
    eps = 1e-6
    by_track: dict[str, list[Record]] = defaultdict(list)
    for r in spans:
        by_track[track_of(r)].append(r)
    for r in spans:
        parent = r.tags.get("parent")
        if parent is None:
            continue
        lo, hi = r.t_start, r.t_start + r.value
        ok = any(p.name == parent
                 and p.t_start - eps <= lo and hi <= p.t_start + p.value + eps
                 for p in by_track[track_of(r)] if p is not r)
        if not ok:
            errs.append(f"span {r.name!r} (seq {r.seq}) not nested inside "
                        f"its parent {parent!r}")
    # 3. at least one comm leg overlaps a compute span — the measured
    #    counterpart of the schedule trace.validate admits
    legs = [(r.t_start, r.t_start + r.value)
            for r in spans if r.name == "comm.leg"]
    comps = [(r.t_start, r.t_start + r.value)
             for r in spans if r.name == "comm.compute"]
    if legs and comps:
        if not any(max(a0, c0) < min(a1, c1)
                   for a0, a1 in legs for c0, c1 in comps):
            errs.append("no comm.leg span overlaps any comm.compute span")
    elif legs or comps:
        errs.append("trace has comm legs xor compute spans — "
                    "instrumentation incomplete")
    return errs


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", type=pathlib.Path, help="span JSONL "
                    "(launch/serve.py --profile / commcheck --profile)")
    ap.add_argument("--chrome", type=pathlib.Path, default=None,
                    metavar="OUT.json",
                    help="write Chrome trace-event JSON (Perfetto)")
    ap.add_argument("--check", action="store_true",
                    help="CI assertions: chrome parses, spans nest, "
                         "comm overlaps compute")
    ap.add_argument("--inter-axes", default="pod", metavar="AX[,AX]",
                    help="mesh axes counted as machine-crossing for "
                         "residual classification (default: pod)")
    ap.add_argument("--net", type=pathlib.Path, default=None,
                    help="calibration JSON (scripts/calibrate_comm.py); "
                         "default: nominal NetworkModel")
    args = ap.parse_args(argv)

    spans = load_spans(args.trace)
    net = load_network_model(args.net) if args.net else NetworkModel()
    chrome = chrome_trace(spans)
    if args.chrome is not None:
        args.chrome.write_text(json.dumps(chrome))
        print(f"# wrote {args.chrome} ({len(spans)} spans, "
              f"{len({track_of(r) for r in spans})} tracks)", file=sys.stderr)

    rows = overlap_table(spans)
    if rows:
        print(format_overlap(rows))
        print()
    inter = frozenset(a for a in args.inter_axes.split(",") if a)
    res = leg_residuals(spans, net, inter)
    if res:
        print(format_residuals(res, step_residuals(spans, net), net))
    if not rows and not res:
        print(f"# {args.trace}: no comm spans "
              f"({len(spans)} host spans only)")

    if args.check:
        errs = check_trace(spans, chrome)
        if errs:
            for e in errs:
                print(f"CHECK FAIL: {e}", file=sys.stderr)
            raise SystemExit(1)
        print(f"# check OK: {len(spans)} spans", file=sys.stderr)


if __name__ == "__main__":
    main()
