"""HLO collective parser used by the roofline analysis."""
from repro.launch import roofline as rl


HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[128,256]{1,0} parameter(0)
  %cp = bf16[128,256]{1,0} collective-permute(%p0), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
  %ag = f32[512,256]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[128,256]{1,0} all-reduce(%ag), replica_groups={{0,1},{2,3}}, to_apply=add
  %a2a = bf16[4,32,256]{2,1,0} all-to-all(%p0), replica_groups={{0,1,2,3}}
  %cps = (bf16[128,256]{1,0}, bf16[128,256]{1,0}) collective-permute-start(%p0), source_target_pairs={{0,256},{256,0}}
  %cpd = bf16[128,256]{1,0} collective-permute-done(%cps)
}
"""


def test_parse_collective_bytes():
    stats = rl.parse_collectives(HLO, pod_size=256)
    cp = 128 * 256 * 2
    ag = 512 * 256 * 4
    ar = 128 * 256 * 4 * 2  # all-reduce counted twice (RS + AG)
    a2a = 4 * 32 * 256 * 2
    cps = 128 * 256 * 2
    assert stats.bytes_total == cp + ag + ar + a2a + cps


def test_inter_pod_classification():
    stats = rl.parse_collectives(HLO, pod_size=256)
    # only the -start op has a pair crossing rank 256
    assert stats.bytes_inter_pod == 128 * 256 * 2
    stats2 = rl.parse_collectives(HLO, pod_size=2)
    assert stats2.bytes_inter_pod > stats.bytes_inter_pod


def test_analyze_terms_and_bottleneck():
    r = rl.analyze_from_terms(flops=1e12, byts=1e9, coll_bytes=1e9,
                              coll_inter=0, chips=256, model_flops=2e14)
    assert r.bottleneck == "collective"  # 1e9/50e9 > 1e12/197e12 > 1e9/819e9
    assert abs(r.t_compute - 1e12 / rl.PEAK_FLOPS) < 1e-12
    assert 0 < r.useful_ratio < 1


def test_done_ops_not_double_counted():
    stats = rl.parse_collectives(HLO, pod_size=1 << 30)
    # collective-permute-done must not add bytes (its -start already did)
    assert stats.counts.get("collective-permute/intra", 0) == 2
