"""Mixture-of-Experts layer with expert-parallel all-to-all dispatch.

Experts are sharded over the 'model' mesh axis (expert parallelism).  For
sequence-sharded activations (train/prefill) tokens are routed with a
sort-based, capacity-dropped dispatch and exchanged with their expert
owners via ``lax.all_to_all`` over 'model' — the same all-to-all family the
paper's Ulysses path optimises, so the MoE dispatch shows up in the
roofline collective term alongside attention.

For decode (activations replicated over 'model') no all-to-all is needed:
each shard computes its local experts' contribution and a ``psum``
combines — the standard inference EP schedule.

Routing: softmax top-k, optional shared experts (qwen2-moe) and a dense
residual branch (arctic) are handled by the caller (models/registry).  A
GShard-style load-balance auxiliary loss is returned.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import shard_map

from .blocks import ParallelContext, ParamBuilder, Params


def init_moe(b: ParamBuilder, cfg, prefix: str = "moe", n_pad_experts: int = 0) -> None:
    m = cfg.moe
    d, ff = cfg.d_model, m.moe_d_ff
    e = m.n_experts + n_pad_experts
    b.add(f"{prefix}/router/w", (d, m.n_experts), ("embed", None))
    b.add(f"{prefix}/wi_gate", (e, d, ff), ("experts", "embed", "expert_mlp"))
    b.add(f"{prefix}/wi_up", (e, d, ff), ("experts", "embed", "expert_mlp"))
    b.add(f"{prefix}/wo", (e, ff, d), ("experts", "expert_mlp", "embed"),
          scale=ff ** -0.5 / (2 * cfg.n_layers) ** 0.5)


def padded_n_experts(cfg, ep_degree: int) -> int:
    """Experts padded up so the expert dim divides the EP axis (e.g. qwen2's
    60 experts on a 16-way axis -> 64, last 4 never routed to)."""
    e = cfg.moe.n_experts
    return int(math.ceil(e / ep_degree) * ep_degree)


def _positions_within_group(ids: jax.Array, n_groups: int) -> jax.Array:
    """Stable rank of each element within its id-group (sort-based; the
    XLA-friendly alternative to a [T, E, C] one-hot dispatch tensor)."""
    t = ids.shape[0]
    perm = jnp.argsort(ids, stable=True)
    sorted_ids = ids[perm]
    starts = jnp.searchsorted(sorted_ids, jnp.arange(n_groups), side="left")
    pos_sorted = jnp.arange(t) - starts[sorted_ids]
    return jnp.zeros(t, jnp.int32).at[perm].set(pos_sorted)


def _expert_ffn(x: jax.Array, wg: jax.Array, wu: jax.Array, wo: jax.Array,
                act: str) -> jax.Array:
    """Batched expert FFN: x [E, C, d] with per-expert weights [E, d, ff]."""
    if act in ("swiglu", "geglu"):
        gate = jnp.einsum("ecd,edf->ecf", x, wg.astype(x.dtype))
        gate = jax.nn.silu(gate) if act == "swiglu" else jax.nn.gelu(gate)
        h = gate * jnp.einsum("ecd,edf->ecf", x, wu.astype(x.dtype))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, wu.astype(x.dtype)))
    return jnp.einsum("ecf,efd->ecd", h, wo.astype(x.dtype))


def _route(x2d: jax.Array, router_w: jax.Array, top_k: int, n_real: int):
    """Returns (topk ids [T,k], weights [T,k], aux load-balance loss)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = lax.top_k(probs, top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    # GShard aux: E * sum_e f_e * p_e
    f = jnp.mean(jnp.sum(jax.nn.one_hot(ids, n_real), axis=1), axis=0)
    p = jnp.mean(probs, axis=0)
    aux = n_real * jnp.sum(f * p)
    return ids, w.astype(x2d.dtype), aux


def _moe_local(x, router_w, wg, wu, wo, *, cfg, ep_axes, ep_degree, replicated):
    """Per-device MoE body inside shard_map.

    x: [T_local, d].  wg/wu/wo: [E_local, ...] (this device's experts).
    """
    m = cfg.moe
    t_l, d = x.shape
    e_local = wg.shape[0]
    ids, w, aux = _route(x, router_w, m.top_k, m.n_experts)

    if replicated:
        # decode: everyone has all tokens; compute my experts, psum outputs.
        my_rank = lax.axis_index(ep_axes)
        lo = my_rank * e_local
        flat_ids = ids.reshape(-1)
        local = flat_ids - lo
        keep = (local >= 0) & (local < e_local)
        cap = t_l * m.top_k  # worst case, tiny in decode
        pos = _positions_within_group(jnp.where(keep, local, e_local), e_local + 1)
        src = jnp.repeat(jnp.arange(t_l), m.top_k)
        buf = jnp.zeros((e_local, cap, d), x.dtype)
        buf = buf.at[jnp.where(keep, local, e_local), pos].set(x[src], mode="drop")
        out_buf = _expert_ffn(buf, wg, wu, wo, cfg.act)
        gathered = out_buf.at[jnp.where(keep, local, e_local), pos].get(
            mode="fill", fill_value=0.0)
        y = jnp.zeros((t_l, d), x.dtype)
        y = y.at[src].add(gathered * w.reshape(-1)[:, None])
        y = lax.psum(y, ep_axes)
        return y, aux

    # --- expert-parallel all-to-all dispatch (train / prefill) -----------
    flat_ids = ids.reshape(-1)  # [T*k]
    src = jnp.repeat(jnp.arange(t_l), m.top_k)
    peer = flat_ids // e_local  # owner of each slot's expert
    cap_send = int(math.ceil(t_l * m.top_k / ep_degree * m.capacity_factor))
    pos = _positions_within_group(peer, ep_degree)  # slot within peer buffer
    in_cap = pos < cap_send

    send_x = jnp.zeros((ep_degree, cap_send, d), x.dtype)
    send_x = send_x.at[peer, pos].set(
        jnp.where(in_cap[:, None], x[src], 0.0), mode="drop")
    send_eid = jnp.full((ep_degree, cap_send), -1, jnp.int32)
    send_eid = send_eid.at[peer, pos].set(
        jnp.where(in_cap, flat_ids % e_local, -1), mode="drop")

    recv_x = lax.all_to_all(send_x, ep_axes, 0, 0, tiled=True)
    recv_eid = lax.all_to_all(send_eid, ep_axes, 0, 0, tiled=True)

    rx = recv_x.reshape(ep_degree * cap_send, d)
    reid = recv_eid.reshape(-1)
    valid = reid >= 0
    cap_e = int(math.ceil(ep_degree * cap_send / e_local * m.capacity_factor))
    eid_or_pad = jnp.where(valid, reid, e_local)
    epos = _positions_within_group(eid_or_pad, e_local + 1)
    buf = jnp.zeros((e_local, cap_e, d), x.dtype)
    buf = buf.at[eid_or_pad, epos].set(jnp.where(valid[:, None], rx, 0.0),
                                       mode="drop")
    out_buf = _expert_ffn(buf, wg, wu, wo, cfg.act)
    out_tok = out_buf.at[eid_or_pad, epos].get(mode="fill", fill_value=0.0)
    out_tok = jnp.where(valid[:, None], out_tok, 0.0)

    back = lax.all_to_all(out_tok.reshape(ep_degree, cap_send, d),
                          ep_axes, 0, 0, tiled=True)
    gathered = back.at[peer, pos].get(mode="fill", fill_value=0.0)
    gathered = jnp.where(in_cap[:, None], gathered, 0.0)
    y = jnp.zeros((t_l, d), x.dtype)
    y = y.at[src].add(gathered * w.reshape(-1)[:, None])
    return y, aux


def _moe_token_gather_decode(x2d, rw, wg, wu, wo, *, cfg, ep_axes, e_local,
                             ff_axes, batch_axes):
    """Decode-mode EP with FSDP'd expert weights — beyond-paper (§Perf).

    The naive decode path all-gathers the expert hidden dims (sharded over
    'data' for arctic-class models) every step: ~GBs of weights per token.
    Instead gather the TOKENS over 'data' (KBs), compute each rank's ff
    slice, psum the partial outputs, and slice the local batch back —
    weights never move.
    """
    t_l, d = x2d.shape
    m = cfg.moe
    x_all = lax.all_gather(x2d, batch_axes, axis=0, tiled=True)  # [T_all, d]
    t_all = x_all.shape[0]
    ids, w, aux = _route(x_all, rw, m.top_k, m.n_experts)
    my_rank = lax.axis_index(ep_axes)
    lo = my_rank * e_local
    flat_ids = ids.reshape(-1)
    local = flat_ids - lo
    keep = (local >= 0) & (local < e_local)
    cap = t_all * m.top_k
    pos = _positions_within_group(jnp.where(keep, local, e_local), e_local + 1)
    src = jnp.repeat(jnp.arange(t_all), m.top_k)
    buf = jnp.zeros((e_local, cap, d), x2d.dtype)
    buf = buf.at[jnp.where(keep, local, e_local), pos].set(x_all[src], mode="drop")
    out_buf = _expert_ffn(buf, wg, wu, wo, cfg.act)  # ff dim is a slice
    gathered = out_buf.at[jnp.where(keep, local, e_local), pos].get(
        mode="fill", fill_value=0.0)
    y = jnp.zeros((t_all, d), x2d.dtype)
    y = y.at[src].add(gathered * w.reshape(-1)[:, None])
    # partial over both expert shards (model) and ff slices (data)
    y = lax.psum(y, ep_axes + ff_axes)
    my_b = lax.axis_index(batch_axes)
    y = lax.dynamic_slice_in_dim(y, my_b * t_l, t_l, axis=0)
    return y, aux


def moe_block(
    x: jax.Array,  # [B, L, d]
    p: Params,  # {'router': {'w'}, 'wi_gate', 'wi_up', 'wo'} (padded E)
    cfg,
    ctx: ParallelContext,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [B, L, d], aux loss scalar)."""
    ep_axes = ("model",)
    mesh = ctx.mesh
    ep_degree = math.prod(mesh.shape[a] for a in ep_axes)
    ba = ctx.sp.batch_axes
    sp_axes = ctx.sp.sp_axes
    b_, l_, d = x.shape
    replicated = ctx.decode
    # token-gather decode applies when expert hidden dims are FSDP-sharded
    # and there is a data axis to gather tokens over
    from .sharding import rules_for
    ff_axes = tuple(a for a in rules_for(cfg, "serve").get("expert_mlp", ())
                    if a in mesh.axis_names and mesh.shape[a] > 1)
    token_gather = (ctx.decode and ctx.ep_token_gather and bool(ff_axes)
                    and ba is not None)

    if replicated:
        xspec = P(ba, None, None)
    else:
        xspec = P(ba, sp_axes, None)

    if token_gather:
        e_local = p["wi_gate"].shape[0] // ep_degree
        in_specs = (xspec, P(None, None),
                    P(("model",), None, ff_axes),
                    P(("model",), None, ff_axes),
                    P(("model",), ff_axes, None))

        def body(x, rw, wg, wu, wo):
            t = x.reshape(-1, d)
            y, aux = _moe_token_gather_decode(
                t, rw, wg, wu, wo, cfg=cfg, ep_axes=ep_axes,
                e_local=e_local, ff_axes=ff_axes, batch_axes=ba)
            all_axes = tuple(mesh.axis_names)
            aux = lax.pmean(aux, all_axes)
            return y.reshape(x.shape), aux

        fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                           out_specs=(xspec, P()), check_vma=False)
        return fn(x, p["router"]["w"], p["wi_gate"], p["wi_up"], p["wo"])

    espec = lambda *rest: P(("model",), *rest)

    def body(x, rw, wg, wu, wo):
        t = x.reshape(-1, d)
        y, aux = _moe_local(
            t, rw, wg, wu, wo,
            cfg=cfg, ep_axes=ep_axes, ep_degree=ep_degree, replicated=replicated,
        )
        # aux is per-device; average over the whole mesh for a global scalar
        all_axes = tuple(mesh.axis_names)
        aux = lax.pmean(lax.pmean(aux, ep_axes), tuple(a for a in all_axes if a not in ep_axes))
        return y.reshape(x.shape), aux

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(xspec, P(None, None), espec(None, None), espec(None, None),
                  espec(None, None)),
        out_specs=(xspec, P()),
        check_vma=False,
    )
    y, aux = fn(x, p["router"]["w"], p["wi_gate"], p["wi_up"], p["wo"])
    return y, aux
