from .engine import ARRequest, ARServer, DiTRequest, DiTResult, DiTServer
from .sampler import (
    SamplerConfig,
    hybrid_sample_step,
    hybrid_state_shape,
    sample,
    sample_step,
    toy_vae_decode,
)

__all__ = [
    "ARRequest",
    "ARServer",
    "DiTRequest",
    "DiTResult",
    "DiTServer",
    "SamplerConfig",
    "hybrid_sample_step",
    "hybrid_state_shape",
    "sample",
    "sample_step",
    "toy_vae_decode",
]
