#!/usr/bin/env python3
"""Validate metrics/bench artifacts against their schemas (CI gate).

Two artifact families share the serving observability surface
(DESIGN.md §11):

  * ``*.jsonl`` — metrics traces (``metrics.v1``): one record per line,
    checked with ``repro.serving.metrics.validate_record`` (the same
    checker the unit tests pin), plus the stream-level invariants the
    sinks guarantee — ``seq`` is the dense 0..n-1 total order, every
    counter series is monotone (records carry cumulative totals), and
    span records (the §12 profiler extension) carry finite
    ``t_start``/duration windows.
  * ``BENCH_*.json`` — benchmark trajectory records (``bench.v1``,
    benchmarks/run.py): the envelope and row/record structure
    ``scripts/calibrate_comm.py`` consumes.

Usage:  python scripts/check_metrics_schema.py [--partial-tail-ok] FILE...
Exit 0 = every file conforms; violations are printed per file:line.
``--partial-tail-ok`` tolerates a truncated FINAL line in a .jsonl trace
(a crash mid-record; JsonlTracker flushes per record, so at most the
last line can be cut short).
"""
from __future__ import annotations

import json
import math
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.serving.metrics import SCHEMA_VERSION, validate_record  # noqa: E402

BENCH_SCHEMA = "bench.v1"
# step-level per-leg keys (core/comm_model.py PER_LEG_KEYS + "_step")
PER_LEG_STEP_KEYS = ("t_a2a_inter_step", "t_a2a_intra_step",
                     "t_ring_inter_step", "t_ring_intra_step",
                     "t_codec_step")


def check_metrics_jsonl(path: pathlib.Path,
                        partial_tail_ok: bool = False) -> list[str]:
    errs: list[str] = []
    counters: dict[tuple, float] = {}
    n = 0
    lines = path.read_text().splitlines()
    for i, line in enumerate(lines, start=1):
        if not line.strip():
            continue
        try:
            d = json.loads(line)
        except json.JSONDecodeError as e:
            if partial_tail_ok and i == len(lines):
                print(f"  {path}:{i}: truncated final record dropped "
                      "(crash tail)")
                break
            errs.append(f"{path}:{i}: not JSON ({e})")
            continue
        msgs = validate_record(d)
        if msgs:
            errs.extend(f"{path}:{i}: {m}" for m in msgs)
            continue
        if d.get("seq") != n:
            errs.append(f"{path}:{i}: seq {d.get('seq')} != {n} "
                        f"(stream must be the dense record order)")
        n += 1
        if d.get("kind") == "counter":
            key = (d["name"], tuple(sorted((d.get("tags") or {}).items())))
            prev = counters.get(key)
            if prev is not None and d["value"] < prev:
                errs.append(f"{path}:{i}: counter {d['name']} decreased "
                            f"({prev} -> {d['value']})")
            counters[key] = d["value"]
        elif d.get("kind") == "span":
            # validate_record pins type/sign; the stream gate adds the
            # window sanity a renderer relies on
            if not (math.isfinite(d["t_start"]) and math.isfinite(d["value"])):
                errs.append(f"{path}:{i}: span {d['name']} has a non-finite "
                            f"window ({d['t_start']}, {d['value']})")
            if d.get("name") == "comm.leg":
                # per-leg profiler spans (DESIGN.md §8.2/§12): each leg
                # must identify its channel/stream (flat torus hop vs
                # hier intra/inter leg) and its wire payload, or the
                # trace report cannot fold legs into NetworkModel terms
                tags = d.get("tags") or {}
                for req in ("channel", "stream", "track", "nbytes"):
                    if req not in tags:
                        errs.append(f"{path}:{i}: comm.leg span missing "
                                    f"tag {req!r}")
    if n == 0:
        errs.append(f"{path}: empty trace (no records)")
    return errs


def check_bench_json(path: pathlib.Path) -> list[str]:
    errs: list[str] = []
    try:
        data = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        return [f"{path}: not JSON ({e})"]
    if data.get("schema") != BENCH_SCHEMA:
        errs.append(f"{path}: schema {data.get('schema')!r} != "
                    f"{BENCH_SCHEMA!r}")
    for field in ("module", "generated_at", "rows", "records"):
        if field not in data:
            errs.append(f"{path}: missing field {field!r}")
    for j, row in enumerate(data.get("rows", [])):
        if set(row) != {"name", "us", "derived"}:
            errs.append(f"{path}: rows[{j}] fields {sorted(row)} != "
                        f"['derived', 'name', 'us']")
        elif row["us"] is not None and not isinstance(row["us"], (int, float)):
            errs.append(f"{path}: rows[{j}].us {row['us']!r} not a number")
    for j, rec in enumerate(data.get("records", [])):
        if "name" not in rec:
            errs.append(f"{path}: records[{j}] has no name")
            continue
        # per-leg comm terms (DESIGN.md §8.2): any record carrying a
        # prediction breakdown must use the leg-split keys, never a
        # single-blob a2a term; the hier sweep's variant records must
        # carry the full split so flat-vs-hier is auditable per leg
        bd = rec.get("predicted_breakdown")
        if bd is None:
            continue
        if "t_a2a" in bd:
            errs.append(f"{path}: records[{j}] has single-blob 't_a2a' "
                        "(per-leg keys required)")
        if data.get("module") == "hier_a2a_sweep":
            missing = [k for k in PER_LEG_STEP_KEYS if k not in bd]
            if missing:
                errs.append(f"{path}: records[{j}] breakdown missing "
                            f"per-leg fields {missing}")
    return errs


def check(path: pathlib.Path, partial_tail_ok: bool = False) -> list[str]:
    if not path.exists():
        return [f"{path}: no such file"]
    if path.suffix == ".jsonl":
        return check_metrics_jsonl(path, partial_tail_ok)
    if path.suffix == ".json":
        return check_bench_json(path)
    return [f"{path}: unknown artifact type (want .jsonl or BENCH_*.json)"]


def main(argv: list[str]) -> int:
    partial_tail_ok = "--partial-tail-ok" in argv
    argv = [a for a in argv if a != "--partial-tail-ok"]
    if not argv:
        print(__doc__)
        return 2
    errors: list[str] = []
    for arg in argv:
        p = pathlib.Path(arg)
        errs = check(p, partial_tail_ok)
        errors += errs
        kind = "metrics" if p.suffix == ".jsonl" else "bench"
        print(f"{'FAIL' if errs else 'ok':>4}  {p} ({kind})")
    if errors:
        print("\n".join(errors))
        print(f"\n{len(errors)} schema violation(s) "
              f"(metrics schema: {SCHEMA_VERSION})")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
