"""Shared fixtures.  NOTE: no XLA_FLAGS here — the main test run sees ONE
device (the assignment requires it); multi-device SP tests run in a
subprocess (tests/test_multidevice.py) with their own flags."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # the container image has no hypothesis; fall back to the mini shim
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - depends on environment
    sys.path.insert(0, os.path.dirname(__file__))
    import _mini_hypothesis

    sys.modules["hypothesis"] = _mini_hypothesis
    sys.modules["hypothesis.strategies"] = _mini_hypothesis.strategies

import jax
import pytest


@pytest.fixture(scope="session")
def mesh1():
    """1-device (data=1, model=1) mesh for smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
