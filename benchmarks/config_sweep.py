"""Paper Fig. 8: UxRy configuration sweep at fixed machine count.

For N=4 and N=3 machines (×8 GPUs), sweep every valid (P_u, P_r)
factorisation and report the latency-model estimate for USP vs TAS vs SFU
at that configuration (UxRy = Ulysses degree x, Ring degree y).
"""
from __future__ import annotations

import dataclasses

from repro.core.comm_model import LayerWorkload, attention_layer_latency
from repro.core.planner import SPPlan

from .common import row

M_PER = 8
WL = LayerWorkload(batch=1, seq=49_152, heads=24, head_dim=64)  # cogvideox


def _valid_factorisations(n, m, heads):
    total = n * m
    out = []
    for pu in range(1, total + 1):
        if total % pu or heads % pu:
            continue
        out.append((pu, total // pu))
    return out


def run() -> list[str]:
    rows = []
    for n in (3, 4):
        for pu, pr in _valid_factorisations(n, M_PER, WL.heads):
            for method, swift, overlap in (("usp", False, False),
                                           ("tas", True, False),
                                           ("sfu", True, True)):
                p = SPPlan(n_machines=n, m_per_machine=M_PER, p_ulysses=pu,
                           p_ring=pr, ulysses_inter=swift)
                r = attention_layer_latency(p, WL, swift=swift,
                                            overlap_inter=overlap)
                rows.append(row(
                    f"config_sweep/N{n}/U{pu}R{pr}/{method}",
                    r["t_total"] * 1e6,
                    f"inter_MiB={r['inter_elems'] * 2 / 2**20:.1f}"))
    return rows
