"""Topology-aware SP planner (paper §4.2).

Given a cluster of N machines × M devices (TPU: N pods × M intra-pod chips
in the SP group) and an attention layer with H heads, SwiftFusion organises
the N·M devices into a 2-D logical mesh P_u × P_r with

    P_u = gcd(N·M, H)          (maximise Ulysses usage)
    P_r = N·M / P_u

and assigns the *Ulysses* group to span the slow (inter-machine) boundary
and the *Ring* group to stay inside the fast (intra-machine) network —
the inverse of USP's assignment.

For GQA models the Ulysses head-scatter must divide the number of *KV*
heads (otherwise KV heads would have to be replicated); the planner
therefore takes ``heads = gcd(H_q, H_kv)`` unless ``replicate_kv`` is set.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class SPPlan:
    """A concrete SP decomposition of ``n_machines * m_per_machine`` devices."""

    n_machines: int  # N: pods (slow boundary)
    m_per_machine: int  # M: chips per pod in the SP group (fast network)
    p_ulysses: int  # P_u
    p_ring: int  # P_r
    ulysses_inter: bool  # True = SwiftFusion/TAS, False = USP baseline

    @property
    def sp_degree(self) -> int:
        return self.n_machines * self.m_per_machine

    @property
    def torus_degree(self) -> int:
        """N for Torus Attention (inter-machine Ulysses stages), §4.3.

        Torus applies when Ulysses spans machines; its stage count is the
        number of machines covered by the Ulysses group.
        """
        if not self.ulysses_inter:
            return 1
        return min(self.p_ulysses, self.n_machines)

    def validate(self) -> None:
        assert self.p_ulysses * self.p_ring == self.sp_degree, self
        assert self.p_ulysses >= 1 and self.p_ring >= 1, self


def plan(
    n_machines: int,
    m_per_machine: int,
    num_q_heads: int,
    num_kv_heads: int | None = None,
    *,
    swift: bool = True,
    replicate_kv: bool = False,
) -> SPPlan:
    """Compute (P_u, P_r) per §4.2: P_u = gcd(N*M, H), P_r = N*M / P_u."""
    sp = n_machines * m_per_machine
    if num_kv_heads is None:
        num_kv_heads = num_q_heads
    heads = num_q_heads if replicate_kv else math.gcd(num_q_heads, num_kv_heads)
    p_u = math.gcd(sp, heads)
    p = SPPlan(
        n_machines=n_machines,
        m_per_machine=m_per_machine,
        p_ulysses=p_u,
        p_ring=sp // p_u,
        ulysses_inter=swift,
    )
    p.validate()
    return p


def usp_plan(
    n_machines: int,
    m_per_machine: int,
    num_q_heads: int,
    num_kv_heads: int | None = None,
) -> SPPlan:
    """The USP baseline: same (P_u, P_r) factorisation but Ring spans the
    inter-machine boundary and Ulysses stays intra-machine (§2.2)."""
    p = plan(n_machines, m_per_machine, num_q_heads, num_kv_heads, swift=False)
    return p
