"""Inner test suite run in a subprocess with 8 fake CPU devices.

Never collected by the outer run (see tests/test_multidevice.py and
pyproject's norecursedirs) so the main suite keeps 1 device.
"""
import os
import sys

# must run before jax initializes — this conftest is imported first in the
# subprocess pytest invocation
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def mesh8():
    """(pod=2, data=2, model=2) production-mesh miniature."""
    assert len(jax.devices()) == 8, "inner suite needs 8 fake devices"
    return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
