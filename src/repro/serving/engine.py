"""Serving engines.

DiTServer — the paper's scenario: requests ask for an image/video at a
given latent sequence length; the SLA-aware request scheduler
(serving/sched, DESIGN.md §9) buckets them by latent length, admits
across buckets against per-request deadlines, and memoizes one compiled
step per bucket shape; the flow-matching sampler runs with the configured
SP strategy and results stream back.

ARServer — autoregressive decode for the LM-family assigned archs:
slot-based continuous batching (fixed B decode slots; prefill on admit;
every engine tick advances all active slots one token through the
sequence-sharded KV cache).  Slot admission is priority-ordered with
aging (shared with the DiT scheduler's starvation accounting), so no
request can be bypassed indefinitely.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import time
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp

from ..comm import CommProfiler, emit_leg_spans
from ..comm import profile as comm_profile
from ..configs.base import ModelConfig
from ..core import SPConfig, plan_hybrid
from ..core.comm_model import NetworkModel
from ..models import ParallelContext, get_model, param_shardings
from ..models.dit import COND_TOKENS, LATENT_CHANNELS
from .metrics import Tracker
from .sampler import (
    SamplerConfig,
    hybrid_sample_step,
    hybrid_state_shape,
    sample_step,
)
from .sched import (
    ArrivalForecaster,
    ControlConfig,
    DriftPolicy,
    OnlineCalibrator,
    PlanCache,
    PlanChoice,
    RequestScheduler,
    SchedConfig,
    aged_priority,
    steady_t_step,
)


# ---------------------------------------------------------------------------
# DiT serving (paper §5 workloads)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DiTRequest:
    rid: int
    seq_len: int  # latent tokens (resolution / duration proxy)
    cond: jax.Array | None = None  # [COND_TOKENS, d] text embedding (stub)
    submitted: float = 0.0
    # SLA: seconds from submission to deadline; None = best-effort.  The
    # admission policy scores deadline slack with the comm model's
    # predicted batch latency (DESIGN.md §9).
    sla: float | None = None
    # per-request KV-staleness bound for the displaced pipeline; crossing
    # it triggers a resync step (None = the server DriftPolicy's default)
    drift_threshold: float | None = None
    # times this request's batch was parked by the preemption policy
    # (maintained by the engine; requeued requests keep their submitted
    # stamp, so accrued starvation age survives a park)
    preemptions: int = 0


@dataclasses.dataclass
class DiTResult:
    rid: int
    latents: jax.Array
    latency: float
    sampling_steps: int
    # per-step KV staleness trajectory of the displaced pipeline (empty for
    # non-pipelined sampling); see core/pipefusion.kv_drift
    kv_drift: list[float] = dataclasses.field(default_factory=list)
    # warm steps the drift policy injected after warmup (0 under the
    # static resync_every schedule)
    resyncs: int = 0
    # whether the request's deadline (submitted + sla) was met
    sla_met: bool = True
    # per-step wall clocks of the FINAL (completing) run of this
    # request's batch (empty unless the control loop measures steps) —
    # step-granular latencies, not one aggregate over resyncs
    step_times: list[float] = dataclasses.field(default_factory=list)
    # times the request's batch was parked before completing
    preemptions: int = 0


class DiTServer:
    """Batched DiT sampling over the hybrid-parallel mesh (DESIGN.md §7).

    Request intake and batching are delegated to the scheduler subsystem
    (DESIGN.md §9): ``submit`` feeds the bucketer, ``run_once`` asks the
    admission policy for the next (bucket, batch) under SLA/starvation
    rules, and compiled steps come from the plan cache (one trace per
    bucket shape).  Beyond plain SP the server drives two optional extra
    axes:

      * ``sampler.cfg_parallel`` — the CFG branches are evaluated on the
        ``sp.cfg_axis`` slices of the mesh (one psum-style recombine per
        step).
      * ``sampler.pipeline`` — displaced patch pipelining: the server jits
        warm/displaced step variants per (batch, seq) bucket and threads
        the per-layer stale-KV state across the sampling loop.  When the
        mesh carries ``sp.pp_axis`` and ``param_axes`` is given, the
        stacked DiT block weights are sharded over the pipe axis, so each
        stage holds n_layers / pp blocks.  The per-bucket plan choice
        co-selects the patch count for that bucket's latent length.
    """

    def __init__(self, params, cfg: ModelConfig, mesh, sp: SPConfig,
                 sampler: SamplerConfig = SamplerConfig(),
                 max_batch: int = 4, param_axes=None,
                 sched: SchedConfig | None = None,
                 drift: DriftPolicy | None = None,
                 net: NetworkModel | None = None,
                 control: ControlConfig | None = None,
                 tracker: Tracker | None = None,
                 profile: bool = False):
        self.params = params
        self.cfg = cfg
        self.ctx = ParallelContext(mesh, sp, "prefill")
        self.sampler = sampler
        # span-level runtime profiling (DESIGN.md §12): with ``profile``
        # set, step compilation happens under a comm-profiler context (so
        # every channel put/wait and marked compute block carries runtime
        # observation callbacks), the step loop emits ``engine.step``
        # spans, and each admission's device-side leg events are drained
        # into the tracker as ``comm.*`` spans
        self.profiler = CommProfiler() if profile else None
        # one metrics sink for the whole engine (DESIGN.md §11): the plan
        # cache, scheduler, calibrator and step loop all publish here.
        # The default aggregate-only Tracker keeps the legacy counter
        # attributes readable at zero retention cost; pass a JsonlTracker
        # or RecordingTracker to capture the full stream (which also
        # opts the step loop into per-step wall clocks, see run_once).
        self.tracker = tracker if tracker is not None else Tracker()
        # noise is drawn per REQUEST (fold_in of the rid, see _noise), so
        # a request's trajectory is independent of batch composition and
        # admission order — a parked batch's restart and an unpreempted
        # rerun of the same requests produce bitwise-identical latents
        self._noise_key = jax.random.PRNGKey(0)
        self.drift = drift if drift is not None else DriftPolicy()
        self.control = control if control is not None else ControlConfig()
        # instrumentation hook: called as on_step(server, step_index)
        # after every completed sampler step, before the preemption check
        # (tests inject mid-batch arrivals through it)
        self.on_step: Callable[[DiTServer, int], None] | None = None
        if (sampler.pipelined and sp.pp_axis
                and sp.pp_axis in mesh.axis_names and param_axes is not None):
            # stage partitioning: each pipe rank holds its n_layers/pp blocks
            sh = param_shardings(param_axes, cfg, mesh, "serve",
                                 extra_rules={"layers": (sp.pp_axis,)})
            self.params = jax.device_put(params, sh)

        # -- scheduler wiring (DESIGN.md §9) -----------------------------
        dp = self._dp_degree()
        sched = sched if sched is not None else SchedConfig(max_batch=max_batch)
        self.sched_cfg = dataclasses.replace(sched, dp=dp)
        pipe = sampler.pipeline if sampler.pipelined else None
        cfg_deg = (sampler.cfg_degree
                   if (sampler.guided and sampler.cfg_parallel) else 1)
        pp = pipe.pp if pipe else 1
        sp_deg = math.prod(mesh.shape[a] for a in sp.sp_axes)
        # the one plan this mesh/sampler can execute; planned as 1 machine
        # x (cfg*pp*sp) devices — the per-bucket degree of freedom left to
        # the plan cache is the patch count (and the predicted latency the
        # admission policy scores)
        fixed = plan_hybrid(1, cfg_deg * pp * sp_deg, cfg.n_heads,
                            cfg.n_kv_heads, cfg_parallel=cfg_deg > 1,
                            cfg_degree=max(cfg_deg, 2), pp=pp,
                            n_layers=cfg.n_layers)
        self.plan_cache = PlanCache(
            heads=cfg.n_heads, head_dim=cfg.resolved_head_dim,
            kv_heads=cfg.n_kv_heads, n_layers=cfg.n_layers,
            num_steps=sampler.num_steps, guided=sampler.guided,
            guidance_branches=sampler.cfg_degree, dp=dp, net=net,
            candidates=[fixed], base_patches=pipe.patches if pipe else 0,
            tracker=self.tracker)
        forecaster = (ArrivalForecaster(self.control.forecast_alpha,
                                        tracker=self.tracker)
                      if self.control.forecast else None)
        self.scheduler = RequestScheduler(self.plan_cache, self.sched_cfg,
                                          forecaster=forecaster,
                                          tracker=self.tracker)
        self.preempt = self.control.preemption
        self.calibrator = (OnlineCalibrator(self.plan_cache,
                                            self.control.calibration,
                                            tracker=self.tracker)
                           if self.control.calibration is not None else None)

    # -- tracker-backed counters (legacy attribute surface) ---------------
    @property
    def preemptions(self) -> int:
        """Batches parked (not requests)."""
        return int(self.tracker.counter("engine.preemptions"))

    def submit(self, req: DiTRequest) -> None:
        self.scheduler.submit(req, time.time())

    @property
    def pending(self) -> int:
        return self.scheduler.pending

    def _bucket_sampler(self, choice: PlanChoice) -> SamplerConfig:
        """The sampler config for one bucket: the server config with the
        plan cache's per-bucket patch count applied."""
        if not (self.sampler.pipelined and choice.num_patches):
            return self.sampler
        return dataclasses.replace(
            self.sampler, pipeline=dataclasses.replace(
                self.sampler.pipeline, num_patches=choice.num_patches))

    def _step_fn(self, batch: int, seq: int, choice: PlanChoice) -> Callable:
        sc = self._bucket_sampler(choice)

        def build():
            dt = 1.0 / sc.num_steps
            if sc.pipelined:
                def warm(params, x, cond, t, state):
                    return hybrid_sample_step(params, self.cfg, self.ctx, x,
                                              cond, t, dt, sc, state,
                                              warm=True)

                def displaced(params, x, cond, t, state):
                    return hybrid_sample_step(params, self.cfg, self.ctx, x,
                                              cond, t, dt, sc, state,
                                              warm=False)

                # donate the threaded KV state (arg 4): the caller discards
                # the old state each step, so XLA may update it in place
                # instead of allocating a second full-size KV buffer
                return (jax.jit(warm, donate_argnums=(4,)),
                        jax.jit(displaced, donate_argnums=(4,)))

            def f(params, x, cond, t):
                return sample_step(params, self.cfg, self.ctx, x, cond, t,
                                   dt, sc)

            return jax.jit(f)

        # the patch count is part of the compiled step's identity: after
        # an online recalibration changes a bucket's plan choice, the new
        # variant compiles lazily instead of reusing the stale trace
        return self.plan_cache.step_fn(batch, seq, build,
                                       variant=choice.num_patches)

    def _dp_degree(self) -> int:
        ba = self.ctx.sp.batch_axes or ()
        return math.prod(self.ctx.mesh.shape[a] for a in ba)

    # salt folded into the noise key for dp padding rows (disjoint from
    # request ids, so pad noise is deterministic but never collides)
    _PAD_NOISE_SALT = 1 << 30

    def _noise(self, batch: list[DiTRequest], b: int, t: int) -> jax.Array:
        """Initial latent noise, drawn per ROW from a key that depends
        only on the request's rid (pad rows: the row index) — batch
        composition and admission order cannot change any request's
        trajectory, which is what makes a preempted batch's restart
        bitwise-equal to an unpreempted rerun (DESIGN.md §10)."""
        keys = [jax.random.fold_in(self._noise_key,
                                   batch[i].rid if i < len(batch)
                                   else self._PAD_NOISE_SALT + i)
                for i in range(b)]
        return jnp.stack([
            jax.random.normal(k, (t, LATENT_CHANNELS), self.cfg.dtype)
            for k in keys])

    def _park(self, adm, adm_id: int, step: int) -> None:
        """Preempt the running batch: requests return to the head of
        their bucket with accrued age intact (admission accounting
        reversed); the threaded KV state and partial latents are simply
        dropped (sampler steps leave no other per-batch state — the
        PipeFusion preemption-point argument)."""
        for r in adm.requests:
            r.preemptions += 1
        self.scheduler.requeue(adm.requests, adm.pad_rows)
        self.tracker.count("engine.preemptions")
        # park event: which admission, at which step, whose requests —
        # the restart shows up later as those rids completing under a new
        # admission id with preemptions > 0
        self.tracker.log("engine.park", float(step), step=step,
                         tags={"adm": adm_id, "seq": adm.seq_len,
                               "rids": ",".join(str(r.rid)
                                                for r in adm.requests)})

    def _should_park(self, adm, step: int, num_steps: int,
                     step_times: list[float]) -> bool:
        """The between-steps preemption check (sched/control.py): the
        running batch's remaining time is estimated from its OWN measured
        steps (``sched.control.steady_t_step`` — trace-robust median,
        shared with the calibrator), so the decision self-corrects on
        hardware the analytical model mispredicts.  At the very first
        check the single (possibly trace-paying) sample is used
        deliberately: over-estimating the unknown remaining time errs
        toward the SLA-critical waiting side."""
        if self.preempt is None or step >= num_steps - 1:
            return False
        now = time.time()
        measured = steady_t_step(step_times)
        t_est = measured if measured is not None else adm.plan.t_step
        oldest = min(r.submitted for r in adm.requests)
        victim = self.preempt.should_preempt(
            self.scheduler.waiting_candidates(now),
            remaining_steps=num_steps - 1 - step, t_step=t_est,
            running_age=now - oldest,
            starvation_age=self.sched_cfg.starvation_age,
            running_seq=adm.seq_len, running_k=len(adm.requests),
            max_batch=self.sched_cfg.max_batch)
        return victim is not None

    def run_once(self, flush: bool = True) -> list[DiTResult]:
        """Serve one scheduler admission.  ``flush=False`` lets the
        admission policy defer partial (padded) batches in the hope of
        more arrivals; the default serves whatever scores best now.

        With the control loop engaged (``ControlConfig.preemption`` or
        ``.calibration``) the step loop is measured: each sampler step is
        blocked on and wall-clocked individually, the preemption policy
        runs between steps (a parked batch returns [] and its requests
        re-enter the queue), and completed batches feed the online
        calibrator.  Without it, the loop is the PR-3 sync-free one."""
        adm = self.scheduler.next_batch(time.time(), flush=flush)
        if adm is None:
            return []
        # admission ordinal: the tag that stitches one batch's step
        # series, park events and request completions together in the
        # metrics stream (DESIGN.md §11)
        adm_id = self.scheduler.admissions
        batch = adm.requests
        n_real = len(batch)
        b = adm.batch_rows  # n_real + dp padding rows (dropped at the end)
        t = adm.seq_len
        d = self.cfg.d_model
        sc = self._bucket_sampler(adm.plan)
        cond = jnp.stack([
            (batch[i].cond if i < n_real and batch[i].cond is not None
             else jnp.zeros((COND_TOKENS, d), self.cfg.dtype))
            for i in range(b)
        ])
        x = self._noise(batch, b, t)
        fn = self._step_fn(b, t, adm.plan)
        dt = 1.0 / sc.num_steps
        # a persistent sink (JSONL / recording) opts into the per-step
        # series even without the control loop: the wall-clock sync is
        # the price of a trace worth shipping.  Profiling implies
        # measurement — the step spans need the per-step clocks.
        measure = (self.control.engaged or self.tracker.persistent
                   or self.profiler is not None)
        step_tags = {"adm": adm_id, "seq": t, "rows": b}
        step_times: list[float] = []
        drift_vals = []
        resyncs = 0

        def tick(i: int, outputs, t0: float, warm=None) -> bool:
            """Post-step control point: stamp the step's wall clock, run
            the instrumentation hook, then the preemption check.  The
            clock stops at output-ready; span/metric emission happens
            after it (the sampler satellite's contract, applied here
            too)."""
            if measure:
                jax.block_until_ready(outputs)
                t_step = time.perf_counter() - t0
                step_times.append(t_step)
                self.tracker.log("engine.t_step_s", t_step, step=i,
                                 tags=step_tags)
                if self.profiler is not None:
                    tags = dict(step_tags)
                    tags["pred_t_step_s"] = adm.plan.t_step
                    if "t_compute_step" in adm.plan.pred:
                        # lets trace_report attribute step drift to mfu
                        tags["pred_compute_s"] = adm.plan.pred[
                            "t_compute_step"]
                    if warm is not None:
                        tags["warm"] = bool(warm)
                    self.tracker.span_event(
                        "engine.step", t0 - self.tracker.epoch, t_step,
                        step=i, tags=tags)
            if self.on_step is not None:
                self.on_step(self, i)
            if self._should_park(adm, i, sc.num_steps, step_times):
                self._park(adm, adm_id, i)
                return True
            return False

        parked = False
        prof_ctx = (comm_profile(self.profiler)
                    if self.profiler is not None else contextlib.nullcontext())
        with prof_ctx:
            if sc.pipelined:
                warm_fn, displaced_fn = fn
                pipe = sc.pipeline
                thresholds = [r.drift_threshold for r in batch]
                use_drift = self.drift.engaged(thresholds)
                state = hybrid_state_shape(self.cfg, b, t, sc)
                last_drift: list[float] | None = None
                for i in range(sc.num_steps):
                    if use_drift:
                        warm = self.drift.warm(pipe, i, last_drift,
                                               thresholds,
                                               tracker=self.tracker)
                        if warm and i >= pipe.warmup_steps:
                            resyncs += 1
                            self.tracker.count("engine.resyncs",
                                               tags={"seq": t})
                    else:
                        warm = pipe.warm_step(i)
                    f = warm_fn if warm else displaced_fn
                    t0 = time.perf_counter()
                    x, state, m = f(self.params, x, cond,
                                    jnp.float32(1.0 - i * dt), state)
                    per = m["kv_drift_per_request"]
                    drift_vals.append(per)
                    if use_drift:
                        # threshold-triggered resync needs the drift on the
                        # host: one device sync per step, only when a bound
                        # is actually configured (DESIGN.md §9)
                        last_drift = [float(per[j]) for j in range(n_real)]
                    if tick(i, (x, state), t0, warm=warm):
                        parked = True
                        break
            else:
                for i in range(sc.num_steps):
                    t0 = time.perf_counter()
                    x = fn(self.params, x, cond, jnp.float32(1.0 - i * dt))
                    if tick(i, x, t0):
                        parked = True
                        break
            if not parked:
                x.block_until_ready()
        if self.profiler is not None:
            # pair and publish this admission's device-side leg events
            # (comm.leg / comm.compute / comm.exposed_wait spans)
            emit_leg_spans(self.profiler, self.tracker)
        if parked:
            return []
        now = time.time()
        if self.calibrator is not None and step_times:
            self.calibrator.observe(adm.plan, b, t, step_times)
        # materialise after the timed region; row i is request i's own
        # trajectory (padded rows are never handed to a request)
        drifts = [[float(v[i]) for v in drift_vals] for i in range(n_real)]
        results = [
            DiTResult(r.rid, x[i], now - r.submitted, sc.num_steps,
                      kv_drift=drifts[i] if drift_vals else [],
                      resyncs=resyncs,
                      sla_met=(r.sla is None
                               or now <= r.submitted + r.sla),
                      step_times=list(step_times),
                      preemptions=r.preemptions)
            for i, r in enumerate(batch)
        ]
        # completion telemetry — emitted outside the timed region.  The
        # kv_drift series is logged here (not mid-loop) so the stream
        # carries it without adding any per-step host sync.
        tr = self.tracker
        tr.log("engine.batch_done", float(n_real),
               tags={"adm": adm_id, "seq": t, "rows": b})
        if drift_vals and n_real:
            for s in range(len(drift_vals)):
                mean = sum(drifts[i][s] for i in range(n_real)) / n_real
                tr.log("engine.kv_drift", mean, step=s,
                       tags={"adm": adm_id, "seq": t})
        for r, req in zip(results, batch):
            tr.count("engine.completed", tags={"seq": t})
            if r.preemptions:
                tr.count("engine.restarted_requests")
            tr.log("engine.request_done", r.latency,
                   tags={"adm": adm_id, "rid": r.rid, "seq": t,
                         "preemptions": r.preemptions,
                         "sla_met": r.sla_met})
            if req.sla is not None:
                tr.count("engine.sla_met" if r.sla_met
                         else "engine.sla_miss", tags={"seq": t})
        return results

    def serve(self) -> list[DiTResult]:
        """Drain the queue.  With the arrival forecaster engaged
        (``ControlConfig.forecast``), each round first offers the
        admission policy a non-flush pick so the §10 deferral horizon is
        consulted — a padded candidate whose missing rows are forecast
        to arrive within its slack keeps waiting for them (only
        meaningful with dp > 1: the deferral applies to dp-padded
        batches).  A round that admits nothing and parks nothing falls
        back to a flush pick, so the drain always terminates."""
        out = []
        while self.scheduler.pending:
            if self.scheduler.forecaster is not None:
                pre = self.preemptions
                got = self.run_once(flush=False)
                if got or self.preemptions != pre:
                    out.extend(got)
                    continue
            out.extend(self.run_once(flush=True))
        return out


# ---------------------------------------------------------------------------
# AR decode serving (assigned LM archs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ARRequest:
    rid: int
    prompt: jax.Array  # [L_prompt] int32
    max_new_tokens: int = 16
    priority: float = 0.0  # higher admits sooner; aging bounds starvation
    submitted: int = 0  # engine tick at submission (stamped by submit())


@dataclasses.dataclass
class Slot:
    req: ARRequest | None = None
    pos: int = 0  # next cache index to write
    generated: list[int] = dataclasses.field(default_factory=list)


class ARServer:
    """Fixed-slot continuous batching over a sequence-sharded KV cache.

    Prefill is implemented as teacher-forced decode of the prompt (one
    engine, one cache layout — adequate for the assigned decode shapes;
    a chunked-prefill path is a straightforward extension).

    Freed slots are filled by effective priority ``priority + age *
    aging_rate`` (serving/sched ``aged_priority``) rather than raw FIFO:
    a high-priority stream can jump the queue, but every waiting request's
    effective priority grows with its queue age, so a request of base
    priority p is admitted within ``(p_max - p) / aging_rate`` ticks of
    any fresher competitor — the same starvation bound the DiT scheduler
    enforces on buckets.  Ties (equal effective priority, e.g. all base 0)
    reduce to FIFO.
    """

    def __init__(self, params, cfg: ModelConfig, mesh, sp: SPConfig,
                 batch_slots: int = 4, max_len: int = 256,
                 cache_dtype=jnp.float32, aging_rate: float = 0.1,
                 tracker: Tracker | None = None):
        self.params = params
        self.cfg = cfg
        self.ctx = ParallelContext(mesh, sp, "decode")
        self.bundle = get_model(cfg)
        self.slots = [Slot() for _ in range(batch_slots)]
        self.max_len = max_len
        self.aging_rate = aging_rate
        self.caches = self.bundle.init_caches(cfg, batch_slots, max_len, cache_dtype)
        self.queue: deque[ARRequest] = deque()
        self.results: dict[int, list[int]] = {}
        self._ticks = 0
        # metrics sink (DESIGN.md §11): slot admission / completion
        # counters plus the queue-wait series, same schema as DiTServer
        self.tracker = tracker if tracker is not None else Tracker()

        def step(params, caches, tokens, cur_index):
            batch = {"tokens": tokens}
            logits, caches = self.bundle.step(params, batch, caches,
                                              cur_index, cfg, self.ctx)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

        self._step = jax.jit(step)

    def submit(self, req: ARRequest) -> None:
        req.submitted = self._ticks
        self.queue.append(req)
        self.tracker.count("ar.submitted")

    def _take_next(self) -> ARRequest:
        """Pop the waiting request with the highest aged priority (stable:
        FIFO among equals — max() keeps the first of tied keys)."""
        best = max(self.queue,
                   key=lambda r: aged_priority(r.priority,
                                               self._ticks - r.submitted,
                                               self.aging_rate))
        self.queue.remove(best)
        return best

    def _admit(self) -> None:
        for s in self.slots:
            if s.req is None and self.queue:
                s.req = self._take_next()
                s.pos = 0
                s.generated = []
                self.tracker.count("ar.admitted")
                self.tracker.log("ar.queue_wait_ticks",
                                 float(self._ticks - s.req.submitted),
                                 tags={"rid": s.req.rid})

    def tick(self) -> None:
        """Advance every active slot one position.

        All slots share one cur_index per tick in this reference engine;
        requests are aligned at admission (pos 0).  Slots therefore run in
        lockstep — the standard static-batching baseline."""
        self._admit()
        self._ticks += 1
        active = [s for s in self.slots if s.req is not None]
        if not active:
            return
        self.tracker.count("ar.ticks")
        pos = active[0].pos
        tokens = []
        for s in self.slots:
            if s.req is None:
                tokens.append(0)
            elif s.pos < len(s.req.prompt):
                tokens.append(int(s.req.prompt[s.pos]))
            else:
                tokens.append(s.generated[-1] if s.generated else 0)
        tok = jnp.asarray(tokens, jnp.int32)[:, None]
        nxt, self.caches = self._step(self.params, self.caches, tok,
                                      jnp.int32(pos))
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            s.pos += 1
            if s.pos >= len(s.req.prompt):
                s.generated.append(int(nxt[i]))
            if (len(s.generated) >= s.req.max_new_tokens
                    or s.pos >= self.max_len - 1):
                self.results[s.req.rid] = list(s.generated)
                self.tracker.count("ar.completed")
                self.tracker.log("ar.request_done", float(len(s.generated)),
                                 tags={"rid": s.req.rid})
                s.req = None

    def serve(self, max_ticks: int = 10_000) -> dict[int, list[int]]:
        t = 0
        while (self.queue or any(s.req for s in self.slots)) and t < max_ticks:
            self.tick()
            t += 1
        return self.results
