"""MoE routing + expert-parallel dispatch (1-device path; a2a on 8 fake
devices is covered in tests/multidevice/)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import MoEConfig
from repro.core import SPConfig
from repro.models import ParallelContext
from repro.models.moe import (
    _positions_within_group,
    _route,
    moe_block,
    padded_n_experts,
)
from repro.models import lm as lm_mod

SP = SPConfig(strategy="full", sp_axes=("model",), batch_axes=("data",))


def test_positions_within_group():
    ids = jnp.array([2, 0, 2, 1, 0, 2, 2])
    pos = _positions_within_group(ids, 3)
    # stable ranks within each group
    want = [0, 0, 1, 0, 1, 2, 3]
    np.testing.assert_array_equal(np.asarray(pos), want)


def test_route_topk_normalised():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 6))
    ids, wts, aux = _route(x, w, 2, 6)
    assert ids.shape == (16, 2) and wts.shape == (16, 2)
    np.testing.assert_allclose(jnp.sum(wts, -1), 1.0, rtol=1e-5)
    assert float(aux) >= 1.0 - 1e-5  # aux >= 1 (perfectly balanced == 1)


def test_padded_experts():
    cfg = get_reduced("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=60))
    assert padded_n_experts(cfg, 16) == 64
    assert padded_n_experts(cfg, 1) == 60


def _dense_moe_reference(x2d, p, cfg):
    """All-experts-on-all-tokens reference (no capacity drops)."""
    m = cfg.moe
    logits = x2d.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    wts, ids = jax.lax.top_k(probs, m.top_k)
    wts = wts / wts.sum(-1, keepdims=True)
    outs = []
    for e in range(m.n_experts):
        h = jax.nn.silu(x2d @ p["wi_gate"][e]) * (x2d @ p["wi_up"][e])
        outs.append(h @ p["wo"][e])
    outs = jnp.stack(outs, 1)  # [T, E, d]
    sel = jnp.take_along_axis(outs, ids[..., None], axis=1)
    return jnp.sum(sel * wts[..., None], axis=1)


def test_moe_block_matches_dense_reference(mesh1, rng):
    """With generous capacity, sort-based dispatch == dense computation."""
    cfg = get_reduced("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                n_shared_experts=0))
    key = rng
    params, _ = lm_mod.init_lm(cfg, key, 1)
    lp = jax.tree.map(lambda x: x[0], params["layers"])  # layer 0 slice
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    ctx = ParallelContext(mesh1, SP, "prefill")
    y, aux = moe_block(x, lp["moe"], cfg, ctx)
    ref = _dense_moe_reference(x.reshape(-1, cfg.d_model), lp["moe"], cfg)
    np.testing.assert_allclose(y.reshape(-1, cfg.d_model), ref,
                               rtol=2e-4, atol=2e-4)


def test_moe_decode_replicated_path_matches(mesh1, rng):
    cfg = get_reduced("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                n_shared_experts=0))
    params, _ = lm_mod.init_lm(cfg, rng, 1)
    lp = jax.tree.map(lambda x: x[0], params["layers"])
    x = jax.random.normal(rng, (4, 1, cfg.d_model))
    ctx = ParallelContext(mesh1, SP, "decode")
    y, _ = moe_block(x, lp["moe"], cfg, ctx)
    ref = _dense_moe_reference(x.reshape(-1, cfg.d_model), lp["moe"], cfg)
    np.testing.assert_allclose(y.reshape(-1, cfg.d_model), ref,
                               rtol=2e-4, atol=2e-4)


def test_capacity_drops_are_bounded(mesh1, rng):
    """With cf=1.0 and adversarial routing, output stays finite and close
    to reference on non-dropped tokens (never NaN/garbage)."""
    cfg = get_reduced("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=1.0,
                                n_shared_experts=0))
    params, _ = lm_mod.init_lm(cfg, rng, 1)
    lp = jax.tree.map(lambda x: x[0], params["layers"])
    x = jnp.broadcast_to(jax.random.normal(rng, (1, 1, cfg.d_model)),
                         (2, 16, cfg.d_model))  # all tokens identical
    ctx = ParallelContext(mesh1, SP, "prefill")
    y, _ = moe_block(x, lp["moe"], cfg, ctx)
    assert bool(jnp.all(jnp.isfinite(y)))
