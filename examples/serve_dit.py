"""Serve a small DiT with batched requests through the SwiftFusion engine —
the paper's own scenario (Figure 1): requests -> batched flow-matching
sampling -> latents -> toy VAE decode.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_dit.py
"""
import dataclasses
import os
import sys

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core import SPConfig
from repro.models import get_model
from repro.serving import DiTRequest, DiTServer, SamplerConfig, toy_vae_decode


def main():
    cfg = dataclasses.replace(get_reduced("flux-12b"), n_layers=2,
                              d_model=256, n_heads=8, n_kv_heads=8,
                              head_dim=32, d_ff=512, dtype="float32")
    bundle = get_model(cfg)
    params, _ = bundle.init(cfg, jax.random.PRNGKey(0), 1)

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    sp = SPConfig(strategy="swift_torus", sp_axes=("pod", "model"),
                  batch_axes=("data",))
    srv = DiTServer(params, cfg, mesh, sp,
                    sampler=SamplerConfig(num_steps=4), max_batch=2)

    # a mixed queue: two "image" sizes (latent sequence lengths)
    for i in range(5):
        srv.submit(DiTRequest(rid=i, seq_len=64 if i % 2 else 128))
    results = srv.serve()
    for r in sorted(results, key=lambda r: r.rid):
        px = toy_vae_decode(r.latents[None])
        print(f"request {r.rid}: latents {tuple(r.latents.shape)} -> "
              f"pixels {tuple(px.shape)}  "
              f"latency {r.latency * 1e3:.1f} ms  finite="
              f"{bool(jnp.all(jnp.isfinite(r.latents)))}")
    print(f"\nserved {len(results)} requests with swift_torus SP over "
          f"{mesh.devices.size} devices")


if __name__ == "__main__":
    main()
