"""Model registry: one uniform API over every assigned architecture.

    bundle = get_model(cfg)
    params, axes = bundle.init(cfg, key, ep_degree)
    loss, aux    = bundle.loss(params, batch, cfg, ctx)          # train
    logits       = bundle.apply(params, batch, cfg, ctx)         # prefill
    out, caches  = bundle.step(params, batch, caches, idx, cfg, ctx)  # decode
    batch        = bundle.input_specs(cfg, shape, abstract=...)  # SDS or data

Batches are plain dicts; modality frontends (vision patches, audio frames)
appear as precomputed embeddings per the stub carve-out.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..configs.shapes import InputShape
from . import dit as dit_mod
from . import lm as lm_mod
from . import whisper as whisper_mod
from .blocks import ParallelContext, Params

Batch = dict[str, Any]


def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    init: Callable
    loss: Callable  # (params, batch, cfg, ctx) -> (loss, aux)
    apply: Callable  # (params, batch, cfg, ctx) -> outputs (prefill/forward)
    step: Callable | None  # decode: (params, batch, caches, idx, cfg, ctx)
    init_caches: Callable | None
    input_specs: Callable  # (cfg, shape, abstract=True, key=None) -> Batch


# ---------------------------------------------------------------------------
# LM families (dense / moe / hybrid / ssm / vlm)
# ---------------------------------------------------------------------------

def _lm_inputs(cfg: ModelConfig, shape: InputShape, abstract=True, key=None,
               dtype=None) -> Batch:
    dtype = dtype or cfg.dtype
    b, l = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        batch: Batch = {"tokens": _sds((b, 1), jnp.int32)}
        if cfg.family == "vlm":
            batch["positions"] = _sds((3, b, 1), jnp.int32)
    else:
        batch = {
            "tokens": _sds((b, l), jnp.int32),
            "labels": _sds((b, l), jnp.int32),
        }
        if cfg.family == "vlm":
            # stub frontend: patch+text embeddings and 3D M-RoPE positions
            batch["inputs_embeds"] = _sds((b, l, cfg.d_model), dtype)
            batch["positions"] = _sds((3, b, l), jnp.int32)
    if abstract:
        return batch
    assert key is not None
    return _concretize(batch, key, cfg)


def _concretize(batch: Batch, key: jax.Array, cfg: ModelConfig) -> Batch:
    out = {}
    for name, s in batch.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            hi = max(cfg.vocab, 2)
            out[name] = jax.random.randint(sub, s.shape, 0, hi, s.dtype)
        else:
            out[name] = jax.random.normal(sub, s.shape, s.dtype) * 0.02
    return out


def _lm_loss(params, batch, cfg, ctx):
    logits, aux, _ = lm_mod.lm_forward(
        params, cfg, ctx,
        tokens=batch.get("tokens"),
        inputs_embeds=batch.get("inputs_embeds"),
        positions=batch.get("positions"),
    )
    return _xent(logits, batch["labels"]) + aux, aux


def _lm_apply(params, batch, cfg, ctx, last_only=False):
    logits, _, _ = lm_mod.lm_forward(
        params, cfg, ctx,
        tokens=batch.get("tokens"),
        inputs_embeds=batch.get("inputs_embeds"),
        positions=batch.get("positions"),
        last_only=last_only,
    )
    return logits


def _lm_step(params, batch, caches, cur_index, cfg, ctx):
    logits, _, new_caches = lm_mod.lm_forward(
        params, cfg, ctx,
        tokens=batch.get("tokens"),
        positions=batch.get("positions"),
        caches=caches, cur_index=cur_index,
    )
    return logits[:, -1], new_caches


LM_BUNDLE = ModelBundle(
    init=lm_mod.init_lm,
    loss=_lm_loss,
    apply=_lm_apply,
    step=_lm_step,
    init_caches=lm_mod.init_lm_caches,
    input_specs=_lm_inputs,
)


# ---------------------------------------------------------------------------
# whisper (audio)
# ---------------------------------------------------------------------------

def _whisper_inputs(cfg, shape, abstract=True, key=None, dtype=None):
    dtype = dtype or cfg.dtype
    b, l = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        batch = {
            "tokens": _sds((b, 1), jnp.int32),
            "encoder_out": _sds((b, cfg.encoder_seq, cfg.d_model), dtype),
        }
    else:
        batch = {
            "frames": _sds((b, cfg.encoder_seq, cfg.d_model), dtype),
            "tokens": _sds((b, l), jnp.int32),
            "labels": _sds((b, l), jnp.int32),
        }
    if abstract:
        return batch
    return _concretize(batch, key, cfg)


def _whisper_loss(params, batch, cfg, ctx):
    memory = whisper_mod.encode(params, batch["frames"], cfg, ctx)
    logits, _ = whisper_mod.decode_forward(
        params, cfg, ctx, tokens=batch["tokens"], memory=memory)
    return _xent(logits, batch["labels"]), jnp.zeros((), jnp.float32)


def _whisper_apply(params, batch, cfg, ctx):
    memory = whisper_mod.encode(params, batch["frames"], cfg, ctx)
    logits, _ = whisper_mod.decode_forward(
        params, cfg, ctx, tokens=batch["tokens"], memory=memory)
    return logits


def _whisper_step(params, batch, caches, cur_index, cfg, ctx):
    logits, new_caches = whisper_mod.decode_forward(
        params, cfg, ctx, tokens=batch["tokens"], memory=batch["encoder_out"],
        caches=caches, cur_index=cur_index)
    return logits[:, -1], new_caches


WHISPER_BUNDLE = ModelBundle(
    init=whisper_mod.init_whisper,
    loss=_whisper_loss,
    apply=_whisper_apply,
    step=_whisper_step,
    init_caches=whisper_mod.init_whisper_caches,
    input_specs=_whisper_inputs,
)


# ---------------------------------------------------------------------------
# DiT
# ---------------------------------------------------------------------------

def _dit_inputs(cfg, shape, abstract=True, key=None, dtype=None):
    dtype = dtype or cfg.dtype
    b, t = shape.global_batch, shape.seq_len
    batch = {
        "latents": _sds((b, t, dit_mod.LATENT_CHANNELS), dtype),
        "cond": _sds((b, dit_mod.COND_TOKENS, cfg.d_model), dtype),
        "timesteps": _sds((b,), jnp.float32),
        "targets": _sds((b, t, dit_mod.LATENT_CHANNELS), dtype),
    }
    if abstract:
        return batch
    return _concretize(batch, key, cfg)


def _dit_loss(params, batch, cfg, ctx):
    v = dit_mod.dit_forward(params, cfg, ctx, latents=batch["latents"],
                            cond=batch["cond"], timesteps=batch["timesteps"])
    loss = jnp.mean((v.astype(jnp.float32)
                     - batch["targets"].astype(jnp.float32)) ** 2)
    return loss, jnp.zeros((), jnp.float32)


def _dit_apply(params, batch, cfg, ctx):
    return dit_mod.dit_forward(params, cfg, ctx, latents=batch["latents"],
                               cond=batch["cond"], timesteps=batch["timesteps"])


DIT_BUNDLE = ModelBundle(
    init=dit_mod.init_dit,
    loss=_dit_loss,
    apply=_dit_apply,
    step=None,  # diffusion has no AR decode; sampling loops over apply
    init_caches=None,
    input_specs=_dit_inputs,
)


def get_model(cfg: ModelConfig) -> ModelBundle:
    if cfg.family == "audio":
        return WHISPER_BUNDLE
    if cfg.family == "dit":
        return DIT_BUNDLE
    return LM_BUNDLE
