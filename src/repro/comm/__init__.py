"""One-sided communication subsystem (DESIGN.md §8).

NVSHMEM-style put/signal/wait semantics over XLA collectives:

  channel — ``Channel`` / ``InFlight`` / ``fence`` / ``pin``: the put is a
            ``lax.ppermute`` (collective-permute DMA), the wait an
            ``optimization_barrier`` ordering point.
  stream  — staged transfer programs composed from channels: ring shifts,
            distance-k torus hops, the decomposed all-to-all, and the
            displaced pipeline's pipe-axis stage hand-off.
  pallas_backend — the ``backend="pallas"`` lowering (DESIGN.md §8.1):
            in-kernel DMA issue + explicit semaphores instead of ppermute
            + barrier; interpret mode makes it runnable on CPU CI.
  trace   — records the intended overlap schedule at trace time and
            validates it against compiled HLO (collective-permute
            placement + dependency-level overlap admission) and, for the
            Pallas path, validates the semaphore schedule's pairing.

core/{ring,torus,collectives}.py and models/dit.py route all their
transfers through this package; this package imports nothing from core,
so the dependency points one way.
"""
from .channel import Channel, InFlight, fence, pin, ring_perm_of, shift_perm
from .compress import (
    dequantize,
    ef_encode,
    has_wire_dtype,
    quantize,
    zero_feedback,
)
from .pallas_backend import BACKENDS
from .profiler import CommProfiler, emit_leg_spans, profile
from .stream import (
    Stream,
    hier_all_to_all,
    hier_ungroup,
    inter_hop,
    intra_hop,
    pipe_handoff,
    ring_shift,
    staged_all_to_all,
    staged_ungroup,
    torus_hop,
)
from .trace import (
    ScheduleTrace,
    SemEvent,
    SemReport,
    TransferEvent,
    ValidationReport,
    mark_compute,
    record,
    validate,
    validate_semaphores,
)

__all__ = [
    "BACKENDS",
    "Channel",
    "CommProfiler",
    "InFlight",
    "ScheduleTrace",
    "SemEvent",
    "SemReport",
    "Stream",
    "TransferEvent",
    "ValidationReport",
    "dequantize",
    "ef_encode",
    "emit_leg_spans",
    "fence",
    "has_wire_dtype",
    "hier_all_to_all",
    "hier_ungroup",
    "inter_hop",
    "intra_hop",
    "mark_compute",
    "pin",
    "pipe_handoff",
    "profile",
    "quantize",
    "record",
    "ring_perm_of",
    "ring_shift",
    "shift_perm",
    "staged_all_to_all",
    "staged_ungroup",
    "torus_hop",
    "validate",
    "validate_semaphores",
    "zero_feedback",
]
