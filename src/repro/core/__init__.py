"""SwiftFusion core: topology-aware sequence parallelism for attention.

Public API:
  sp_attention / SPConfig     — distributed attention entry point
  decode_attention            — distributed decode over sharded KV cache
  reference_attention         — single-device oracle
  plan / SPPlan               — the paper's §4.2 topology planner
  plan_hybrid / HybridPlan    — (cfg, pp, P_u, P_r) hybrid planner (DESIGN.md §7)
  PipelineConfig / KVState    — displaced patch pipelining (PipeFusion)
  comm_model                  — Appendix-D analytical volumes + hybrid latency
"""
from .decode import decode_attention
from .pipefusion import KVState, PipelineConfig
from .planner import (
    HybridPlan,
    SPPlan,
    candidate_hybrid_plans,
    plan,
    plan_for_shape,
    plan_hybrid,
    usp_plan,
)
from .softmax import (
    MaskSpec,
    Partial,
    attend_partial,
    empty_partial,
    finalize,
    merge,
    reference_attention,
)
from .strategy import STRATEGIES, SPConfig, resolve_layout, sp_attention

__all__ = [
    "HybridPlan",
    "KVState",
    "MaskSpec",
    "Partial",
    "PipelineConfig",
    "SPConfig",
    "SPPlan",
    "STRATEGIES",
    "candidate_hybrid_plans",
    "plan_for_shape",
    "plan_hybrid",
    "attend_partial",
    "decode_attention",
    "empty_partial",
    "finalize",
    "merge",
    "plan",
    "reference_attention",
    "resolve_layout",
    "sp_attention",
    "usp_plan",
]
