"""qwen2-1.5b [dense] — GQA, QKV bias [arXiv:2407.10671]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    rope="rope",
    rope_theta=1e6,
    act="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    sharding_overrides=(("vocab", ("data",)),),
    citation="arXiv:2407.10671",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512
    )
