"""swift_torus SP composed with CFG parallelism and patch pipelining on the
hybrid (cfg=2, pipe=2, data=1, model=2) mesh — 8 fake devices.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import PipelineConfig, SPConfig
from repro.launch.mesh import make_hybrid_mesh
from repro.models import ParallelContext, get_model
from repro.models.dit import COND_TOKENS
from repro.serving import DiTRequest, DiTServer, SamplerConfig, sample

SEQ = 64

# heavy e2e: every test in here pays a 5-16s distributed sampling run on
# the hybrid mesh — runs in the dedicated CI 'slow' job, not the default
# tier-1 pass (RUN_SLOW_TESTS=1 to run locally)
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_reduced("flux-12b"), dtype="float32",
                              n_heads=8, n_kv_heads=8)
    bundle = get_model(cfg)
    params, axes = bundle.init(cfg, jax.random.PRNGKey(0), 1)
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(99), len(leaves))
    leaves = [l + 0.05 * jax.random.normal(k, l.shape, l.dtype)
              for l, k in zip(leaves, keys)]
    params = jax.tree.unflatten(treedef, leaves)
    cond = jax.random.normal(jax.random.PRNGKey(1),
                             (1, COND_TOKENS, cfg.d_model), jnp.float32)
    return cfg, params, axes, cond


def _sample(cfg, params, cond, mesh, sp, sc, key=None):
    ctx = ParallelContext(mesh, sp, "prefill")
    return sample(params, cfg, ctx, key=key or jax.random.PRNGKey(7),
                  batch=1, seq_len=SEQ, cond=cond, sc=sc)


def test_hybrid_matches_single_device_reference(setup):
    """cfg-parallel + swift_torus on the hybrid mesh == plain sequential
    CFG on one device (warm pipeline => no staleness)."""
    cfg, params, _, cond = setup
    ref = _sample(cfg, params, cond, jax.make_mesh((1, 1), ("data", "model")),
                  SPConfig(strategy="full", sp_axes=("model",),
                           batch_axes=("data",)),
                  SamplerConfig(num_steps=3, guidance_scale=4.0))
    mesh = make_hybrid_mesh(cfg=2, pipe=2, data=1, model=2)
    sp = SPConfig(strategy="swift_torus", sp_axes=("model",),
                  batch_axes=("data",), cfg_axis="cfg", pp_axis="pipe")
    hyb = _sample(cfg, params, cond, mesh, sp,
                  SamplerConfig(num_steps=3, guidance_scale=4.0,
                                cfg_parallel=True,
                                pipeline=PipelineConfig(pp=2, warmup_steps=3)))
    np.testing.assert_allclose(np.asarray(hyb), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


def test_hybrid_displaced_close_to_reference(setup):
    cfg, params, _, cond = setup
    ref = _sample(cfg, params, cond, jax.make_mesh((1, 1), ("data", "model")),
                  SPConfig(strategy="full", sp_axes=("model",),
                           batch_axes=("data",)),
                  SamplerConfig(num_steps=4, guidance_scale=4.0))
    mesh = make_hybrid_mesh(cfg=2, pipe=2, data=1, model=2)
    sp = SPConfig(strategy="swift_torus", sp_axes=("model",),
                  batch_axes=("data",), cfg_axis="cfg", pp_axis="pipe")
    hyb = _sample(cfg, params, cond, mesh, sp,
                  SamplerConfig(num_steps=4, guidance_scale=4.0,
                                cfg_parallel=True,
                                pipeline=PipelineConfig(pp=2, warmup_steps=1)))
    assert bool(jnp.all(jnp.isfinite(hyb)))
    diff = float(jnp.max(jnp.abs(hyb - ref)))
    assert diff < 0.05 * float(jnp.max(jnp.abs(ref))), diff


def test_unguided_sampling_on_hybrid_mesh(setup):
    """Regression: with cfg_axis configured but guidance off, the un-doubled
    batch must not be sharded over the 2-way cfg axis."""
    cfg, params, _, cond = setup
    mesh = make_hybrid_mesh(cfg=2, pipe=1, data=1, model=2)
    sp = SPConfig(strategy="swift_torus", sp_axes=("model",),
                  batch_axes=("data",), cfg_axis="cfg", pp_axis="pipe")
    out = _sample(cfg, params, cond, mesh, sp, SamplerConfig(num_steps=2))
    assert bool(jnp.all(jnp.isfinite(out)))
    ref = _sample(cfg, params, cond, jax.make_mesh((1, 1), ("data", "model")),
                  SPConfig(strategy="full", sp_axes=("model",),
                           batch_axes=("data",)),
                  SamplerConfig(num_steps=2))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


def test_cfg_degree_4_on_4way_cfg_axis(setup):
    """ROADMAP k>2 guidance: 4 branches (3 conditionings + uncond) sharded
    over a 4-way cfg axis == the same weighted sum computed sequentially
    on one device."""
    cfg, params, _, cond = setup
    weights = (2.0, 1.0, 0.5, -2.5)
    conds = jnp.concatenate(
        [cond, 2.0 * cond, -1.0 * cond, jnp.zeros_like(cond)], axis=0)
    conds = conds.reshape(4, 1, COND_TOKENS, cfg.d_model)
    ref = _sample(cfg, params, conds,
                  jax.make_mesh((1, 1), ("data", "model")),
                  SPConfig(strategy="full", sp_axes=("model",),
                           batch_axes=("data",)),
                  SamplerConfig(num_steps=2, cfg_weights=weights))
    mesh = make_hybrid_mesh(cfg=4, pipe=1, data=1, model=2)
    sp = SPConfig(strategy="swift_torus", sp_axes=("model",),
                  batch_axes=("data",), cfg_axis="cfg", pp_axis="pipe")
    par = _sample(cfg, params, conds, mesh, sp,
                  SamplerConfig(num_steps=2, cfg_weights=weights,
                                cfg_parallel=True))
    np.testing.assert_allclose(np.asarray(par), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


def test_dit_server_hybrid_end_to_end(setup):
    """DiTServer drives the full composition, with the block weights
    sharded over the pipe axis."""
    cfg, params, axes, _ = setup
    mesh = make_hybrid_mesh(cfg=2, pipe=2, data=1, model=2)
    sp = SPConfig(strategy="swift_torus", sp_axes=("model",),
                  batch_axes=("data",), cfg_axis="cfg", pp_axis="pipe")
    srv = DiTServer(params, cfg, mesh, sp,
                    sampler=SamplerConfig(num_steps=3, guidance_scale=3.0,
                                          cfg_parallel=True,
                                          pipeline=PipelineConfig(
                                              pp=2, warmup_steps=1)),
                    max_batch=2, param_axes=axes)
    # weights really are stage-partitioned over the pipe axis
    lw = srv.params["layers"]["attn"]["wq"]["w"]
    spec = lw.sharding.spec
    assert spec[0] in ("pipe", ("pipe",)), spec
    for i in range(2):
        srv.submit(DiTRequest(rid=i, seq_len=SEQ))
    results = srv.serve()
    assert sorted(r.rid for r in results) == [0, 1]
    for r in results:
        assert bool(jnp.all(jnp.isfinite(r.latents)))
        # the per-step staleness trajectory is surfaced: warm step 0 has
        # zero drift, the displaced steps a positive, finite drift
        assert len(r.kv_drift) == 3
        assert r.kv_drift[0] == 0.0
        assert all(d > 0.0 and jnp.isfinite(d) for d in r.kv_drift[1:])
