from .blocks import ParallelContext, Params
from .registry import ModelBundle, get_model
from .sharding import param_pspecs, param_shardings, rules_for

__all__ = [
    "ModelBundle",
    "ParallelContext",
    "Params",
    "get_model",
    "param_pspecs",
    "param_shardings",
    "rules_for",
]
