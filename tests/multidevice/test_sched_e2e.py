"""Mixed-resolution DiT serving end-to-end through the request scheduler
(DESIGN.md §9) on the 8-fake-device hybrid mesh: 256/512/1024-latent
requests with SLAs and drift thresholds, per-bucket plan selection, one
jit trace per bucket shape."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.core import PipelineConfig, SPConfig
from repro.launch.mesh import make_hybrid_mesh
from repro.serving import (
    DiTRequest,
    DiTServer,
    DriftPolicy,
    SamplerConfig,
    SchedConfig,
)

# heavy e2e: the module-scoped server fixture pays multi-second jit
# traces per bucket shape — runs in the dedicated CI 'slow' job, not the
# default tier-1 pass (RUN_SLOW_TESTS=1 to run locally)
pytestmark = pytest.mark.slow

LENS = [256, 512, 1024, 256, 512, 256, 256]  # 4x256 + 2x512 + 1x1024
SLAS = {256: 30.0, 512: 60.0, 1024: 120.0}


@pytest.fixture(scope="module")
def server():
    cfg = dataclasses.replace(get_reduced("flux-12b"), dtype="float32")
    from repro.models import get_model

    bundle = get_model(cfg)
    params, axes = bundle.init(cfg, jax.random.PRNGKey(0), 1)
    mesh = make_hybrid_mesh(cfg=1, pipe=2, data=2, model=2)
    sp = SPConfig(strategy="swift_torus", sp_axes=("model",),
                  batch_axes=("data",), pp_axis="pipe")
    srv = DiTServer(params, cfg, mesh, sp,
                    sampler=SamplerConfig(
                        num_steps=3,
                        pipeline=PipelineConfig(pp=2, warmup_steps=1)),
                    max_batch=2, param_axes=axes,
                    sched=SchedConfig(max_batch=2, starvation_age=30.0),
                    drift=DriftPolicy(threshold=0.05))
    for i, n in enumerate(LENS):
        srv.submit(DiTRequest(rid=i, seq_len=n, sla=SLAS[n],
                              drift_threshold=0.05 if i % 2 else None))
    return srv, srv.serve()


def test_all_requests_served_with_correct_shapes(server):
    srv, results = server
    assert sorted(r.rid for r in results) == list(range(len(LENS)))
    for r in results:
        assert r.latents.shape == (LENS[r.rid], 64)
        assert bool(jnp.all(jnp.isfinite(r.latents)))
        assert r.sampling_steps == 3
        assert len(r.kv_drift) == 3
        assert r.kv_drift[0] == 0.0  # warmup step is synchronous


def test_one_trace_per_bucket_shape(server):
    srv, _ = server
    # dp=2 pads every batch to 2 rows: bucket shapes are (2, seq)
    shapes = set(srv.plan_cache.plans)
    assert {s for _, s in shapes} == {256, 512, 1024}
    assert srv.plan_cache.traces == len(shapes)
    # 4x256 and 2x512 revisit their bucket shapes => step-cache hits
    assert srv.plan_cache.hits == srv.scheduler.admissions - len(shapes)
    assert srv.plan_cache.hits >= 1


def test_per_bucket_plans_selected_and_uniform_batches(server):
    srv, _ = server
    tot = srv.scheduler.totals()
    assert tot.admitted == len(LENS)
    # batches never mix buckets: padded work is only dp-divisibility rows
    # (the odd 1024-bucket count with max_batch=2, dp=2; 4x256 and 2x512
    # pack exactly)
    assert tot.padded_token_work == 1024
    for (rows, seq), choice in srv.plan_cache.plans.items():
        choice.hplan.validate()
        assert choice.hplan.pp == 2  # the engine's fixed pipeline depth
        # per-bucket patch count must divide the bucket's latent length
        assert choice.num_patches % 2 == 0 and seq % choice.num_patches == 0
    assert tot.max_wait <= 30.0 + 60.0  # starvation bound + service time


def test_drift_policy_metrics_surfaced(server):
    srv, results = server
    # every displaced step reports per-request drift; threshold-triggered
    # resyncs are counted on the result
    for r in results:
        assert all(d >= 0.0 for d in r.kv_drift)
        assert 0 <= r.resyncs <= 2
        assert r.sla_met
