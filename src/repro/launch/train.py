"""Production training launcher.

On real hardware this runs under the TPU runtime with the production mesh;
on this container it can be exercised with fake devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.train --arch qwen2-1.5b --steps 10 \
        --mesh host --data 2 --model 4 --reduced
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from ..configs import get_config, get_reduced
from ..configs.shapes import SHAPES, InputShape
from ..core import SPConfig
from ..train import AdamWConfig, Trainer
from .mesh import make_host_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None, help="assigned shape name")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--strategy", default="swift_torus")
    ap.add_argument("--mesh", choices=["pod", "multipod", "host"], default="host")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    if args.mesh == "host":
        mesh = make_host_mesh(model=args.model, data=args.data)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg, dtype="float32", sharding_overrides=())
    shape = (SHAPES[args.shape] if args.shape
             else InputShape("cli", args.seq, args.batch, "training"))
    sp_degree = mesh.shape["model"]
    sp = SPConfig(strategy=args.strategy if sp_degree > 1 else "full",
                  sp_axes=("model",), batch_axes=("data",))
    tr = Trainer(cfg, mesh, sp, shape,
                 opt_cfg=AdamWConfig(total_steps=args.steps),
                 ckpt_path=args.ckpt)
    tr.run(args.steps)


if __name__ == "__main__":
    main()
