"""Quickstart: SwiftFusion SP attention in 40 lines.

Runs every SP strategy on a small attention problem over however many
devices are available (fake 8 CPU devices here) and checks them against
the single-device oracle — then shows the paper's planner picking
(P_u, P_r) for a real architecture.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import MaskSpec, SPConfig, plan, reference_attention, sp_attention


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (2, 64, 8, 32))   # [B, L, Hq, D]
    k = jax.random.normal(kk, (2, 64, 4, 32))   # GQA: 4 KV heads
    v = jax.random.normal(kv, (2, 64, 4, 32))

    ref = reference_attention(q, k, v, mask=MaskSpec(causal=True))
    for strategy in ("ring", "ulysses", "usp", "swift", "swift_torus"):
        cfg = SPConfig(strategy=strategy, sp_axes=("pod", "model"),
                       batch_axes=("data",))
        out = jax.jit(lambda q, k, v: sp_attention(
            q, k, v, mesh=mesh, cfg=cfg, causal=True))(q, k, v)
        err = float(jnp.max(jnp.abs(out - ref)))
        print(f"{strategy:12s} max|Δ| vs oracle = {err:.2e}")

    print("\nplanner on the production SP group (2 pods × 16 chips):")
    for arch, hq, hkv in (("qwen2-1.5b", 12, 2), ("arctic-480b", 56, 8),
                          ("flux-12b", 24, 24)):
        p = plan(2, 16, hq, hkv)
        print(f"  {arch:14s} Hq={hq:3d} Hkv={hkv:3d} -> "
              f"P_u={p.p_ulysses:2d} (inter-pod Ulysses/Torus), "
              f"P_r={p.p_ring:2d} (intra-pod Ring)")


if __name__ == "__main__":
    main()
