"""Decode path ≡ full teacher-forced forward, per family (1-device mesh)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import SPConfig
from repro.models import ParallelContext, get_model
from repro.models import whisper as wmod

SP = SPConfig(strategy="full", sp_axes=("model",), batch_axes=("data",))


@pytest.mark.parametrize("arch", [
    "qwen2-1.5b", "stablelm-3b", "chatglm3-6b", "starcoder2-7b",
    "rwkv6-1.6b", "hymba-1.5b", "qwen2-moe-a2.7b",
])
def test_decode_matches_forward(arch, mesh1, rng):
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32",
                              sharding_overrides=())
    bundle = get_model(cfg)
    params, _ = bundle.init(cfg, rng, 1)
    B, L = 2, 16
    tokens = jax.random.randint(rng, (B, L), 0, cfg.vocab, jnp.int32)
    ctx_pre = ParallelContext(mesh1, SP, "prefill")
    ctx_dec = ParallelContext(mesh1, SP, "decode")

    full = bundle.apply(params, {"tokens": tokens}, cfg, ctx_pre)
    caches = bundle.init_caches(cfg, B, L, jnp.float32)
    step = jax.jit(lambda p, b, c, i: bundle.step(p, b, c, i, cfg, ctx_dec))
    outs = []
    for t in range(L):
        logit, caches = step(params, {"tokens": tokens[:, t:t + 1]}, caches,
                             jnp.int32(t))
        outs.append(logit)
    dec = jnp.stack(outs, axis=1)
    tol = 5e-4 if cfg.family in ("ssm", "hybrid") else 5e-5
    np.testing.assert_allclose(dec, full, rtol=tol, atol=tol)


def test_whisper_decode_matches_forward(mesh1, rng):
    cfg = dataclasses.replace(get_reduced("whisper-tiny"), dtype="float32")
    bundle = get_model(cfg)
    params, _ = bundle.init(cfg, rng, 1)
    B, L = 2, 12
    tokens = jax.random.randint(rng, (B, L), 0, cfg.vocab, jnp.int32)
    frames = jax.random.normal(rng, (B, cfg.encoder_seq, cfg.d_model)) * 0.02
    ctx_pre = ParallelContext(mesh1, SP, "prefill")
    ctx_dec = ParallelContext(mesh1, SP, "decode")

    full = bundle.apply(params, {"frames": frames, "tokens": tokens}, cfg, ctx_pre)
    memory = wmod.encode(params, frames, cfg, ctx_pre)
    caches = bundle.init_caches(cfg, B, L, jnp.float32)
    outs = []
    for t in range(L):
        logit, caches = bundle.step(
            params, {"tokens": tokens[:, t:t + 1], "encoder_out": memory},
            caches, jnp.int32(t), cfg, ctx_dec)
        outs.append(logit)
    np.testing.assert_allclose(jnp.stack(outs, 1), full, rtol=5e-5, atol=5e-5)


def test_greedy_generation_deterministic(mesh1, rng):
    """Same prompt, two runs -> identical continuation (engine invariant)."""
    cfg = dataclasses.replace(get_reduced("qwen2-1.5b"), dtype="float32")
    bundle = get_model(cfg)
    params, _ = bundle.init(cfg, rng, 1)

    def gen():
        caches = bundle.init_caches(cfg, 1, 32, jnp.float32)
        tok = jnp.array([[5]], jnp.int32)
        out = []
        for t in range(12):
            logit, caches = bundle.step(
                params, {"tokens": tok}, caches, jnp.int32(t), cfg,
                ParallelContext(mesh1, SP, "decode"))
            tok = jnp.argmax(logit, -1)[:, None].astype(jnp.int32)
            out.append(int(tok[0, 0]))
        return out

    assert gen() == gen()
