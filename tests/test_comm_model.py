"""Appendix-D communication volume model: Lemma D.1 + paper claims."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import plan, usp_plan
from repro.core.comm_model import (
    FIT_PARAMS,
    LayerWorkload,
    NetworkModel,
    a2a_leg_volumes,
    attention_layer_latency,
    hierarchical_applicable,
    intra_volume,
    ring_leg_volumes,
    swift_inter_volume,
    usp_inter_volume,
)

BLHD = 1.0e6


@given(st.sampled_from([2, 3, 4, 6, 8]), st.sampled_from([2, 4, 8]),
       st.integers(1, 96))
@settings(max_examples=60, deadline=None)
def test_lemma_d1_swift_never_more_inter_volume(n, m, heads):
    """V_USP >= V_SFU for the planner's own (P_u, P_r) when 2<=M<=P_u<=N —
    and empirically for every planner output with P_u != 2 (the paper's
    stated exception)."""
    sp = plan(n, m, heads)
    up = usp_plan(n, m, heads)
    v_s = swift_inter_volume(sp, BLHD)
    v_u = usp_inter_volume(up, BLHD)
    if sp.p_ulysses == 2:
        return  # paper: the single case where Ulysses may exceed Ring
    assert v_s <= v_u * (1 + 1e-9), (n, m, heads, sp, v_s, v_u)


def test_volume_formulas_match_paper_simple_cases():
    # P_u >= N: V_SFU = 4 (N-1)/N * BLHD / N          (eq. 6)
    p = plan(4, 2, 8)  # sp=8, heads=8 -> P_u=8 >= N=4
    assert math.isclose(swift_inter_volume(p, BLHD), 4 * 3 / 4 * BLHD / 4)
    # P_r >= N: V_USP = 2 (N-1) BLHD / N              (eq. 4)
    u = usp_plan(4, 2, 1)  # P_u=1, P_r=8 >= N
    assert math.isclose(usp_inter_volume(u, BLHD), 2 * 3 * BLHD / 4)


def test_single_machine_no_inter_volume():
    p = plan(1, 8, 24)
    assert swift_inter_volume(p, BLHD) == 0.0
    assert usp_inter_volume(usp_plan(1, 8, 24), BLHD) == 0.0


def test_ulysses_volume_decreases_with_machines():
    """SwiftFusion claim: inter-machine volume per GPU shrinks ~1/N."""
    vols = []
    for n in (2, 4, 8):
        p = plan(n, 8, 64)
        vols.append(swift_inter_volume(p, BLHD))
    assert vols[0] > vols[1] > vols[2]


def test_ring_volume_flat_with_machines():
    """Ring's volume does not shrink with more machines (paper Challenge 1)."""
    v = [usp_inter_volume(usp_plan(n, 8, 1), BLHD) for n in (2, 4, 8)]
    assert v[2] > v[1] > v[0] * 0.99  # grows toward 2*BLHD asymptote


@pytest.mark.parametrize("heads", [24, 48])
def test_latency_model_swift_beats_usp_multi_machine(heads):
    """End-to-end latency model reproduces the paper's Fig. 7 direction for
    the CogVideoX-like workload on >= 3 machines."""
    wl = LayerWorkload(batch=2, seq=48_000, heads=heads, head_dim=64)
    for n in (3, 4):
        sw = attention_layer_latency(plan(n, 8, heads), wl, swift=True,
                                     overlap_inter=True)
        us = attention_layer_latency(usp_plan(n, 8, heads), wl, swift=False,
                                     overlap_inter=False)
        assert sw["t_total"] < us["t_total"], (n, sw, us)


def test_torus_overlap_reduces_total():
    wl = LayerWorkload(batch=2, seq=96_000, heads=24, head_dim=64)
    p = plan(4, 8, 24)
    tas = attention_layer_latency(p, wl, swift=True, overlap_inter=False)
    sfu = attention_layer_latency(p, wl, swift=True, overlap_inter=True)
    assert sfu["t_total"] <= tas["t_total"]
    assert sfu["t_total"] < tas["t_total"] or tas["t_inter"] <= tas["t_compute"]


# ---------------------------------------------------------------------------
# per-leg decomposition (DESIGN.md §8.2) + the intra_volume derivation fix
# ---------------------------------------------------------------------------

def test_leg_sums_match_paper_inter_formulas():
    """Invariant: a2a_inter + ring_inter == the paper's eq. 4-7 totals for
    every planner output (the per-leg split is a refinement, not a new
    model)."""
    for n in (1, 2, 3, 4, 8):
        for m in (1, 2, 4, 8):
            for heads in (1, 2, 6, 8, 24, 64):
                for swift, mk in ((True, plan), (False, usp_plan)):
                    p = mk(n, m, heads)
                    a2a = a2a_leg_volumes(p, BLHD, swift=swift)
                    ring = ring_leg_volumes(p, BLHD, swift=swift)
                    ref = (swift_inter_volume if swift
                           else usp_inter_volume)(p, BLHD)
                    got = a2a["a2a_inter"] + ring["ring_inter"]
                    assert math.isclose(got, ref, abs_tol=1e-9), (
                        n, m, heads, swift, p, got, ref)


def test_intra_volume_derivation_limits():
    """Pin the satellite-1 fix.  The old swift branch computed
    2*(min(P_r, M) - 1)*BLHD/N via a self-cancelling
    ``/ max(r_intra, 1) * r_intra`` factor; that is the correct ring-intra
    share only when the ring fits inside the machine (P_r <= M), and it
    dropped the flat a2a's intra share entirely.

    Limit 1 (P_r = M, ring fully intra): ring share = 2*(M-1)*BLHD/N, and
    the a2a contributes its NVLink share 4*(m_u-1)/P_u*BLHD/N on top.
    """
    p = plan(4, 2, 8)  # N=4, M=2 -> P_u=8, P_r=1 ... need P_r=M case:
    p = plan(4, 4, 4)  # sp=16, heads=4 -> P_u=4, P_r=4 = M: ring intra
    assert (p.p_ring, p.m_per_machine) == (4, 4)
    ring = ring_leg_volumes(p, BLHD, swift=True)
    assert math.isclose(ring["ring_intra"], 2 * 3 * BLHD / 4)
    assert ring["ring_inter"] == 0.0
    # m_u = P_u/N = 1: the flat a2a has no intra share here
    a2a = a2a_leg_volumes(p, BLHD, swift=True)
    assert a2a["a2a_intra"] == 0.0
    assert math.isclose(intra_volume(p, BLHD, swift=True), 2 * 3 * BLHD / 4)


def test_intra_volume_n1_limit_counts_everything():
    """Limit 2 (N = 1): ALL traffic is intra-machine — the a2a moves
    4*(P_u-1)/P_u*BLHD and the ring 2*(P_r-1)*BLHD; nothing crosses
    machines.  The old formula agreed on the ring term but dropped the
    a2a term."""
    p = plan(1, 8, 4)  # P_u=4, P_r=2, N=1
    assert (p.p_ulysses, p.p_ring) == (4, 2)
    want = 4 * 3 / 4 * BLHD + 2 * 1 * BLHD
    assert math.isclose(intra_volume(p, BLHD, swift=True), want)
    assert swift_inter_volume(p, BLHD) == 0.0


def test_intra_volume_ring_spanning_machines_regression():
    """The regime the old formula undercounted: USP with P_r > M.  The
    ring re-enters each machine N/P_u... concretely N=2, M=4, P_r=8: the
    single-pass total is 2*7*BLHD/2 of which eq. 4 says 2*(N-1)*BLHD/N
    crosses machines — intra must be the complement 2*6*BLHD/2, NOT the
    old 2*(min(P_r,M)-1)*BLHD/N = 2*3*BLHD/2."""
    u = usp_plan(2, 4, 1)  # P_u=1, P_r=8 spans both machines
    assert (u.p_ulysses, u.p_ring) == (1, 8)
    ring = ring_leg_volumes(u, BLHD, swift=False)
    assert math.isclose(ring["ring_inter"], 2 * 1 * BLHD / 2)
    assert math.isclose(ring["ring_intra"], 2 * 6 * BLHD / 2)
    assert math.isclose(intra_volume(u, BLHD, swift=False), 2 * 6 * BLHD / 2)


def test_hierarchical_applicability_and_volumes():
    p = plan(2, 4, 8)  # P_u=8 > N=2, N | P_u -> applicable
    assert hierarchical_applicable(p)
    assert not hierarchical_applicable(usp_plan(2, 4, 8))  # ulysses intra
    assert not hierarchical_applicable(plan(1, 8, 8))  # single machine
    assert not hierarchical_applicable(plan(4, 1, 4))  # P_u = N
    flat = a2a_leg_volumes(p, BLHD, swift=True)
    hier = a2a_leg_volumes(p, BLHD, swift=True, hierarchical=True)
    # inter volume identical: the same remote chunks cross the NIC
    assert math.isclose(flat["a2a_inter"], hier["a2a_inter"])
    assert math.isclose(flat["a2a_inter"], swift_inter_volume(p, BLHD))
    # hier pays N x more NVLink (4*(m_u-1)/m_u vs 4*(m_u-1)/P_u of
    # BLHD/N): every chunk traverses the fast leg, not just the 1/N that
    # stays local
    assert math.isclose(hier["a2a_intra"],
                        flat["a2a_intra"] * p.n_machines)


def test_hierarchical_latency_fewer_inter_messages_wins():
    """The hierarchical path's win is the message-count term: same inter
    volume, but N-1 paced inter hops instead of P_u-1.  With a
    non-trivial per-message cost the hier score must be lower, and the
    per-leg keys must carry the split (no single-blob a2a term)."""
    wl = LayerWorkload(batch=1, seq=48_000, heads=32, head_dim=64)
    p = plan(2, 8, 32)  # P_u=16, N=2 -> 15 flat vs 1 hier inter message
    assert hierarchical_applicable(p)
    net = NetworkModel()
    flat = attention_layer_latency(p, wl, net, swift=True)
    hier = attention_layer_latency(p, wl, net, swift=True, hierarchical=True)
    assert flat["hierarchical"] == 0.0 and hier["hierarchical"] == 1.0
    for key in ("t_a2a_inter", "t_a2a_intra", "t_ring_inter", "t_ring_intra",
                "t_codec"):
        assert key in flat and key in hier
    assert hier["t_a2a_inter"] < flat["t_a2a_inter"]
    # exact per-message accounting
    delta = (p.p_ulysses - p.n_machines) * net.inter_hop_lat
    assert math.isclose(flat["t_a2a_inter"] - hier["t_a2a_inter"], delta)
    # the NVLink price of the hier intra leg is visible, not hidden
    assert hier["t_a2a_intra"] > flat["t_a2a_intra"]


def test_hierarchical_noop_when_not_applicable():
    wl = LayerWorkload(batch=1, seq=8_000, heads=8, head_dim=64)
    p = plan(4, 1, 4)  # P_u = N: nothing to factor
    flat = attention_layer_latency(p, wl, swift=True)
    hier = attention_layer_latency(p, wl, swift=True, hierarchical=True)
    assert flat == hier


def test_fp8_wire_halves_inter_bytes_and_prices_codec():
    wl = LayerWorkload(batch=1, seq=48_000, heads=32, head_dim=64)
    p = plan(2, 8, 32)
    net = NetworkModel()
    exact = attention_layer_latency(p, wl, net, swift=True, hierarchical=True)
    fp8 = attention_layer_latency(p, wl, net, swift=True, hierarchical=True,
                                  wire_dtype="float8_e4m3fn")
    assert fp8["t_codec"] > 0.0 and exact["t_codec"] == 0.0
    # wire bytes 2 -> 1 on the a2a inter leg only; message count unchanged
    vol = a2a_leg_volumes(p, wl.blhd, swift=True,
                          hierarchical=True)["a2a_inter"]
    assert math.isclose(exact["t_a2a_inter"] - fp8["t_a2a_inter"],
                        vol * 1 / net.inter_bw)
    assert math.isclose(fp8["t_ring_intra"], exact["t_ring_intra"])


def test_fit_params_cover_per_leg_terms():
    assert {"a2a_intra_bw", "inter_hop_lat", "codec_bw"} <= set(FIT_PARAMS)
    net = NetworkModel()
    for name in FIT_PARAMS:
        assert isinstance(getattr(net, name), float)
