"""Diffusion Transformer (the paper's own workload family).

Latent patches arrive pre-patchified (VAE + patchifier stubbed per
DESIGN.md §6) together with a text-conditioning token sequence; the model
concatenates [cond ; latents], runs adaLN-zero DiT blocks with the
configured SP attention strategy (bidirectional — DiTs are non-causal),
and projects the latent positions back to the latent channel dim,
predicting the flow-matching velocity.

This is the model the serving engine (serving/engine.py) samples with.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from .blocks import (
    ParallelContext,
    ParamBuilder,
    Params,
    attention,
    init_attention,
    init_linear,
    init_mlp,
    init_norm,
    linear,
    mlp,
    norm,
    sinusoidal_embedding,
    stack_layers,
)

LATENT_CHANNELS = 64
COND_TOKENS = 256
TIME_EMB = 256


def _init_block(key, cfg: ModelConfig):
    b = ParamBuilder(key, dtype=jnp.dtype(cfg.dtype))
    init_norm(b, "ln_attn", cfg.d_model, cfg.norm)
    init_attention(b, cfg)
    init_norm(b, "ln_mlp", cfg.d_model, cfg.norm)
    init_mlp(b, cfg)
    # adaLN-zero: 6 modulation vectors from the time embedding; zero-init so
    # blocks start as identity (DiT paper).
    init_linear(b, "ada", cfg.d_model, 6 * cfg.d_model, ("embed", None),
                init="zeros")
    return b.params, b.axes


def init_dit(cfg: ModelConfig, key: jax.Array, ep_degree: int = 1):
    k1, k2 = jax.random.split(key)
    b = ParamBuilder(k1, dtype=jnp.dtype(cfg.dtype))
    init_linear(b, "proj_in", LATENT_CHANNELS, cfg.d_model, (None, "embed"))
    init_linear(b, "cond_proj", cfg.d_model, cfg.d_model, ("embed", "embed_out"))
    init_linear(b, "time_mlp1", TIME_EMB, cfg.d_model, (None, "embed"))
    init_linear(b, "time_mlp2", cfg.d_model, cfg.d_model, ("embed", "embed_out"))
    init_norm(b, "ln_f", cfg.d_model, cfg.norm)
    init_linear(b, "ada_f", cfg.d_model, 2 * cfg.d_model, ("embed", None),
                init="zeros")
    init_linear(b, "proj_out", cfg.d_model, LATENT_CHANNELS, ("embed", None),
                init="zeros")
    params, axes = b.params, b.axes
    lp, la = stack_layers(partial(_init_block, cfg=cfg), cfg.n_layers, k2)
    params["layers"], axes["layers"] = lp, la
    return params, axes


def _modulate(x, shift, scale):
    return x * (1.0 + scale[:, None]) + shift[:, None]


def dit_forward(
    params: Params,
    cfg: ModelConfig,
    ctx: ParallelContext,
    *,
    latents: jax.Array,  # [B, T, LATENT_CHANNELS]
    cond: jax.Array,  # [B, COND_TOKENS, d] (stub text encoder output)
    timesteps: jax.Array,  # [B] in [0, 1]
) -> jax.Array:
    """Returns predicted velocity [B, T, LATENT_CHANNELS]."""
    b_, t_, _ = latents.shape
    x_lat = linear(latents, params["proj_in"])
    x_cond = linear(cond, params["cond_proj"])
    x = jnp.concatenate([x_cond, x_lat], axis=1)
    l_ = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(l_)[None], (b_, l_))

    temb = sinusoidal_embedding(TIME_EMB, TIME_EMB)  # reuse table as freqs
    t_feat = jnp.concatenate(
        [jnp.sin(timesteps[:, None] * 1000.0 * temb[0, : TIME_EMB // 2]),
         jnp.cos(timesteps[:, None] * 1000.0 * temb[0, : TIME_EMB // 2])],
        axis=-1,
    ).astype(x.dtype)
    t_emb = linear(jax.nn.silu(linear(t_feat, params["time_mlp1"])),
                   params["time_mlp2"])  # [B, d]

    def body(x, lp):
        mod = linear(t_emb, lp["ada"])  # [B, 6d]
        sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
        h = _modulate(norm(x, lp["ln_attn"], cfg.norm), sh1, sc1)
        o, _ = attention(h, lp["attn"], cfg, ctx, positions, causal=False)
        x = x + g1[:, None] * o
        h = _modulate(norm(x, lp["ln_mlp"], cfg.norm), sh2, sc2)
        x = x + g2[:, None] * mlp(h, lp["mlp"], cfg)
        return x, None

    body = ctx.remat_wrap(body)
    x, _ = lax.scan(body, x, params["layers"], unroll=cfg.n_layers <= 2)
    sh, sc = jnp.split(linear(t_emb, params["ada_f"]), 2, axis=-1)
    x = _modulate(norm(x, params["ln_f"], cfg.norm), sh, sc)
    v = linear(x, params["proj_out"])
    return v[:, COND_TOKENS:]  # velocity for latent positions only
