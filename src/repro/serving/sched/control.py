"""Adaptive serving control loop (DESIGN.md §10): step-level preemption
and online comm-model recalibration.

Two feedback paths close the loop between the engine's measured behavior
and the planning stack built in PRs 1-4:

  * **PreemptionPolicy** — DiT sampler steps are natural preemption
    points (PipeFusion: the KV state is per-batch and disposable).
    Between steps the engine compares the running batch's predicted
    remaining time (``remaining_steps × t_step``, with ``t_step`` taken
    from the batch's own measured steps) against the tightest waiting
    candidate's deadline slack.  When a waiting bucket would miss its SLA
    if the running batch ran to completion — but can still make it if
    served now — the running batch is *parked*: its requests return to
    the head of their bucket with accrued age intact and its KV state is
    dropped (the batch restarts from scratch on re-admission).

  * **OnlineCalibrator** — the engine's measured per-step wall clocks are
    fed back through the shared damped Gauss-Newton fitter
    (core/calibration.py, the same solver ``scripts/calibrate_comm.py``
    runs offline) over a sliding window, so the ``NetworkModel`` the
    admission policy and plan cache score with tracks the deployed
    hardware.  When the refit drifts past a threshold ratio on any fitted
    parameter, the plan cache's SCORES are invalidated
    (``PlanCache.recalibrate``) — compiled steps are never retraced.

Both are pure host-side decision logic: no jax imports, every method
takes ``now`` from the caller, so the deterministic replay harness
(benchmarks/sched_sweep.py ``--replay``) exercises the exact code the
engine runs.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Sequence

from ...core import calibration
from ...core.comm_model import (
    LayerWorkload,
    NetworkModel,
    fit_param_ratios,
    plan_step_latency,
)
from ..metrics import Tracker
from .admission import Candidate
from .plan_cache import PlanCache, PlanChoice


def steady_t_step(step_times_s: Sequence[float]) -> float | None:
    """Trace-robust per-step estimate from one batch's measured wall
    clocks: the median of the steps AFTER the first (a fresh bucket
    shape's first step pays its jit trace, which later steps never
    re-pay), the lone sample when only one exists, None when empty.
    Shared by the preemption check and the online calibrator so both
    consume the same estimate."""
    if not step_times_s:
        return None
    if len(step_times_s) > 1:
        return statistics.median(step_times_s[1:])
    return step_times_s[0]


# ---------------------------------------------------------------------------
# step-level preemption
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PreemptionPolicy:
    """Decision rule for parking a running batch between sampler steps.

    A waiting candidate triggers preemption iff it is *salvageable but
    doomed by waiting*:

        0 ≤ min_slack  and  min_slack < remaining_steps·t_step − margin

    i.e. served right now it still meets its deadline, but after the
    running batch finishes it will not.  Two guards bound the disruption:

      * ``min_remaining_steps`` — a batch about to finish is never parked
        (a restart costs the full step count; saving one step's latency
        cannot justify it).
      * a running batch that is itself overdue (its admission age crossed
        ``starvation_age``) is immune — so a parked batch that has aged
        past the bound runs to completion, which is what carries the PR-3
        hard starvation bound through preemption (invariant (b),
        tests/test_sched_control.py).
    """

    min_remaining_steps: int = 2
    margin: float = 0.0  # extra slack (s) the waiting side must lack

    def should_preempt(self, candidates: Sequence[Candidate], *,
                       remaining_steps: int, t_step: float,
                       running_age: float, starvation_age: float,
                       running_seq: int | None = None,
                       running_k: int = 0,
                       max_batch: int | None = None) -> Candidate | None:
        """The candidate worth parking the running batch for (the
        tightest-slack one), or None.

        A candidate from the running batch's OWN bucket (``running_seq``)
        is considered only when the parked requests and the candidate's
        fit into one batch (``running_k + k ≤ max_batch``): the parked
        batch re-enters at the bucket head, so otherwise the re-admission
        just re-serves the parked requests and the trigger re-fires —
        futile park/restart thrash with zero SLA benefit."""
        if remaining_steps < self.min_remaining_steps:
            return None
        if running_age >= starvation_age:
            return None  # overdue batches are immune (starvation bound)
        t_rem = remaining_steps * t_step

        def useful(c: Candidate) -> bool:
            if not 0.0 <= c.min_slack < t_rem - self.margin:
                return False
            if (running_seq is not None and c.bucket is not None
                    and c.bucket.seq_len == running_seq):
                return max_batch is not None and running_k + c.k <= max_batch
            return True

        crit = [c for c in candidates if useful(c)]
        if not crit:
            return None
        return min(crit, key=lambda c: c.min_slack)


# ---------------------------------------------------------------------------
# online comm-model recalibration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CalibrationConfig:
    min_samples: int = 8  # observations before the first refit
    window: int = 64  # sliding window of recent observations fitted
    refit_every: int = 8  # new observations between refit attempts
    # any fitted parameter moving past this ratio (either direction) vs
    # the model the plan cache currently scores with invalidates scores
    drift_ratio: float = 1.15
    iters: int = 25  # Gauss-Newton iterations per refit (online budget)
    damping: float = 1e-3


@dataclasses.dataclass(frozen=True)
class StepObservation:
    """One served batch's measured step latency plus everything needed to
    re-predict it under a trial NetworkModel."""

    choice: PlanChoice
    wl: LayerWorkload
    measured_step_us: float


class OnlineCalibrator:
    """Sliding-window refit of the plan cache's NetworkModel from the
    engine's own measured per-step wall clocks (DESIGN.md §10)."""

    def __init__(self, plan_cache: PlanCache,
                 cfg: CalibrationConfig = CalibrationConfig(),
                 tracker: Tracker | None = None):
        self.cfg = cfg
        self.plans = plan_cache
        self.net = plan_cache.net  # latest fit (pushed to plans on drift)
        self.obs: list[StepObservation] = []
        self._since_refit = 0
        self.last_ratios: dict[str, float] = {}
        # metrics sink (DESIGN.md §11): refit/recalibration counters plus
        # the per-parameter drift-ratio trajectory; shares the plan
        # cache's sink unless given its own
        self.tracker = tracker if tracker is not None else plan_cache.tracker

    # -- tracker-backed counters (legacy attribute surface) ---------------
    @property
    def refits(self) -> int:
        return int(self.tracker.counter("calibration.refits"))

    @property
    def recalibrations(self) -> int:
        """Refits that crossed the drift threshold."""
        return int(self.tracker.counter("calibration.recalibrations"))

    def _predict_us(self, o: StepObservation, net: NetworkModel) -> float:
        pc = self.plans
        pred = plan_step_latency(
            o.choice.hplan, o.wl, net, n_layers=pc.n_layers,
            guided=pc.guided, guidance_branches=pc.guidance_branches,
            num_patches=o.choice.num_patches or None,
            num_steps=pc.num_steps, comm_backend=pc.comm_backend)
        return pred["t_step"] * 1e6

    def observe(self, choice: PlanChoice, batch_rows: int, seq: int,
                step_times_s: Sequence[float]) -> bool:
        """Feed one batch's measured per-step wall clocks (seconds).

        The fit target is the median of the steps AFTER the first: a
        fresh bucket shape's first step pays its jit trace, and with few
        sampler steps the plain median would still be polluted by it
        (for already-compiled batches, dropping one typical sample is
        harmless).  Returns True when this observation triggered a refit
        that crossed the drift threshold (plan-cache scores were
        invalidated)."""
        t = steady_t_step(step_times_s)
        if t is None:
            return False
        wl = LayerWorkload(batch=max(batch_rows // self.plans.dp, 1),
                           seq=seq, heads=self.plans.heads,
                           head_dim=self.plans.head_dim)
        self.obs.append(StepObservation(choice, wl, t * 1e6))
        if len(self.obs) > self.cfg.window:
            del self.obs[:len(self.obs) - self.cfg.window]
        self._since_refit += 1
        self.tracker.log("calibration.measured_step_us", t * 1e6,
                         tags={"rows": batch_rows, "seq": seq})
        return self._maybe_refit()

    def _maybe_refit(self) -> bool:
        c = self.cfg
        if len(self.obs) < c.min_samples or self._since_refit < c.refit_every:
            return False
        self._since_refit = 0
        # the refit is a host-timeline span: Gauss-Newton iterations are
        # real milliseconds between steps, and the profiler report should
        # attribute them to the control loop, not the sampler (§12)
        with self.tracker.span("calibration.refit",
                               tags={"samples": len(self.obs)}):
            self.net, _report = calibration.fit(
                self.obs, self._predict_us, start=self.net, iters=c.iters,
                damping=c.damping)
        refit_no = int(self.tracker.count("calibration.refits"))
        self.last_ratios = fit_param_ratios(self.net, self.plans.net)
        for param, r in self.last_ratios.items():
            self.tracker.log("calibration.drift_ratio", r, step=refit_no,
                             tags={"param": param})
        drifted = any(r > c.drift_ratio or r < 1.0 / c.drift_ratio
                      for r in self.last_ratios.values())
        if drifted:
            self.plans.recalibrate(self.net)
            self.tracker.count("calibration.recalibrations")
        return drifted


# ---------------------------------------------------------------------------
# engine-facing bundle
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ControlConfig:
    """What the adaptive control loop of a ``DiTServer`` runs with.

    The default (all None/False) is the PR-3 open-loop scheduler; each
    member can be enabled independently.  ``forecast`` also changes the
    admission policy's padded-batch deferral from wait-until-flush to the
    forecaster's explicit horizon (sched/forecast.py)."""

    preemption: PreemptionPolicy | None = None
    calibration: CalibrationConfig | None = None
    forecast: bool = False
    forecast_alpha: float = 0.25  # EWMA weight of the newest gap

    @property
    def engaged(self) -> bool:
        """Whether the engine must measure per-step wall clocks (either
        feedback path consumes them)."""
        return self.preemption is not None or self.calibration is not None
