"""Collective toolkit for SwiftFusion's SP schedules on TPU meshes.

The paper implements its communication with one-sided NVSHMEM put/get so
that (a) no per-transfer sender/receiver rendezvous happens and (b) no SM
cycles are burnt on communication kernels.  The TPU-idiomatic equivalent
lives in ``repro.comm`` (DESIGN.md §8): channels whose ``put`` is a
``lax.ppermute`` — lowered to ``collective-permute-start/done`` pairs
executed by the ICI DMA engines (no core cycles), with XLA's latency-hiding
scheduler hoisting the ``start`` above independent compute — precisely the
overlap NVSHMEM gives the paper.  Every schedule is therefore built from
channel puts over a *flattened* SP axis, with the paper's logical
(P_u × P_r) factorisation expressed as plain rank arithmetic.  This module
owns the layout bookkeeping (GroupLayout) and the all-to-all entry points;
the staged transfer programs themselves are ``repro.comm.stream``'s.

Logical layout (see planner.py):
  flat rank p in [0, P_u * P_r) over the mesh SP axes (major axis first).
  SwiftFusion (ulysses_outer=True):  u = p // P_r,  r = p %  P_r
      → Ulysses groups span the slow outer (pod) boundary, Ring groups are
        contiguous inside a pod.
  USP       (ulysses_outer=False):   u = p %  P_u,  r = p // P_u
      → Ring groups span pods, Ulysses groups stay inside a pod.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from ..comm import (hier_all_to_all, hier_ungroup, staged_all_to_all,
                    staged_ungroup)

AxisNames = tuple[str, ...]


def flat_axis_size(mesh: jax.sharding.Mesh | None, axes: AxisNames) -> int:
    if mesh is None:  # inside shard_map: use psum-of-ones trick? callers pass mesh
        raise ValueError("mesh required")
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


def flat_rank(axes: AxisNames) -> jax.Array:
    """Flattened rank over (possibly multiple) named mesh axes, major-first."""
    return lax.axis_index(axes)


@dataclasses.dataclass(frozen=True)
class GroupLayout:
    """(P_u × P_r) logical factorisation of a flattened SP axis."""

    axes: AxisNames
    p_ulysses: int
    p_ring: int
    ulysses_outer: bool  # True = SwiftFusion/TAS; False = USP
    # Hierarchical a2a factorisation (DESIGN.md §8.2): number of machine
    # sub-groups each Ulysses group is split into.  u_groups == 1 is the
    # flat (monolithic or staged) a2a; u_groups == N decomposes every
    # Ulysses transform into an intra-machine exchange followed by
    # staged inter-machine hops.  Only meaningful with ulysses_outer
    # (the u-blocks must be machine-contiguous); resolve_layout enforces
    # the divisibility conditions.
    u_groups: int = 1

    @property
    def size(self) -> int:
        return self.p_ulysses * self.p_ring

    @property
    def u_group_size(self) -> int:
        """m_u: Ulysses-group members per machine sub-group."""
        return self.p_ulysses // self.u_groups

    # -- static (python int) coordinates, used to build perm tables --------
    def coords(self, p: int) -> tuple[int, int]:
        if self.ulysses_outer:
            return p // self.p_ring, p % self.p_ring
        return p % self.p_ulysses, p // self.p_ulysses

    def rank(self, u: int, r: int) -> int:
        if self.ulysses_outer:
            return u * self.p_ring + r
        return r * self.p_ulysses + u

    # -- traced coordinates, used inside shard_map bodies -------------------
    def my_coords(self) -> tuple[jax.Array, jax.Array]:
        p = flat_rank(self.axes)
        if self.ulysses_outer:
            return p // self.p_ring, p % self.p_ring
        return p % self.p_ulysses, p // self.p_ulysses

    # -- permutation tables --------------------------------------------------
    def ring_perm(self, shift: int = 1) -> list[tuple[int, int]]:
        """Rotate by ``shift`` inside each Ring group (same u)."""
        out = []
        for u in range(self.p_ulysses):
            for r in range(self.p_ring):
                out.append((self.rank(u, r), self.rank(u, (r + shift) % self.p_ring)))
        return out

    def ulysses_stage_perm(self, k: int) -> list[tuple[int, int]]:
        """Stage ``k`` of the decomposed all-to-all: u sends to (u + k) % P_u
        inside each Ulysses group (same r).  §4.3 'Breakdown of All-to-All'."""
        out = []
        for u in range(self.p_ulysses):
            for r in range(self.p_ring):
                out.append(
                    (self.rank(u, r), self.rank((u + k) % self.p_ulysses, r))
                )
        return out

    def ulysses_intra_stage_perm(self, j: int) -> list[tuple[int, int]]:
        """Stage ``j`` of the hierarchical a2a's *fast leg*: distance-j
        rotation of the local coordinate u_lo = u % m_u inside each machine
        sub-group (same u_hi, same r).  With u_groups == N and
        ulysses_outer, every (u_hi, r) block is exactly one machine, so
        this perm never crosses the slow boundary."""
        g, m_u = self.u_groups, self.u_group_size
        out = []
        for hi in range(g):
            for lo in range(m_u):
                for r in range(self.p_ring):
                    out.append((
                        self.rank(hi * m_u + lo, r),
                        self.rank(hi * m_u + (lo + j) % m_u, r),
                    ))
        return out

    def ulysses_inter_stage_perm(self, k: int) -> list[tuple[int, int]]:
        """Stage ``k`` of the hierarchical a2a's *slow leg*: distance-k
        rotation of the machine coordinate u_hi = u // m_u (same u_lo,
        same r) — the only leg that touches the inter-machine wire."""
        g, m_u = self.u_groups, self.u_group_size
        out = []
        for hi in range(g):
            for lo in range(m_u):
                for r in range(self.p_ring):
                    out.append((
                        self.rank(hi * m_u + lo, r),
                        self.rank(((hi + k) % g) * m_u + lo, r),
                    ))
        return out

    def seq_offset_of_rank(self, shard_len: int) -> jax.Array:
        """Global sequence offset of *this* device's original shard."""
        return flat_rank(self.axes) * shard_len

    def ulysses_group_offsets(self, shard_len: int) -> jax.Array:
        """Global seq offsets of the shards gathered from my Ulysses group,
        ordered by source ulysses-coordinate u' = 0..P_u-1.  Traced."""
        _, r = self.my_coords()
        us = jnp.arange(self.p_ulysses)
        if self.ulysses_outer:
            ranks = us * self.p_ring + r
        else:
            ranks = r * self.p_ulysses + us
        return ranks * shard_len


# ---------------------------------------------------------------------------
# Grouped all-to-all via staged channel puts (the one-sided decomposition);
# the transfer programs live in repro.comm.stream, this is the core-facing
# entry point.
# ---------------------------------------------------------------------------

def grouped_all_to_all(
    x: jax.Array,
    layout: GroupLayout,
    *,
    split_axis: int,
    stack_axis: int = 0,
    backend: str = "xla",
    interpret: bool = True,
    wire_dtype: str | None = None,
) -> jax.Array:
    """All-to-all restricted to Ulysses groups of ``layout``.

    Splits ``x`` into P_u equal chunks along ``split_axis``; chunk j is
    delivered to ulysses-peer j.  Returns the received chunks stacked on a
    new leading axis ordered by *source* ulysses coordinate:
    ``out[j] = chunk (destined for me) from peer with u = j``.

    Implemented as P_u - 1 one-sided channel stages (comm.stream).  The
    diagonal chunk (j == my u) is **stationary** — the paper's §4.3
    observation — and never moves.  With ``layout.u_groups > 1`` the
    exchange runs the hierarchical two-level program instead (DESIGN.md
    §8.2): an intra-machine a2a followed by staged inter-machine hops,
    bit-identical output (pure routing, no arithmetic), optionally with
    fp8 on the inter-machine wire.
    """
    if layout.u_groups > 1:
        return hier_all_to_all(x, layout, split_axis=split_axis,
                               backend=backend, interpret=interpret,
                               wire_dtype=wire_dtype)
    return staged_all_to_all(x, layout, split_axis=split_axis,
                             backend=backend, interpret=interpret)


def monolithic_all_to_all(
    x: jax.Array, layout: GroupLayout, *, split_axis: int,
    backend: str = "xla", interpret: bool = True,
    wire_dtype: str | None = None,
) -> jax.Array:
    """Baseline atomic all-to-all (what Ulysses does before Torus).

    Same contract as :func:`grouped_all_to_all`.  Uses ``lax.all_to_all``
    when the ulysses group covers the whole flattened SP axis; otherwise
    falls back to the staged implementation (XLA's all_to_all has no
    subgroup support over a partial logical factor of a named axis).  A
    hierarchical layout (``u_groups > 1``) always takes the two-level
    staged program — that is the point of the decomposition.
    """
    if layout.u_groups > 1:
        return hier_all_to_all(x, layout, split_axis=split_axis,
                               backend=backend, interpret=interpret,
                               wire_dtype=wire_dtype)
    if (layout.p_ring == 1 and layout.p_ulysses == layout.size
            and backend == "xla"):
        chunks = jnp.stack(jnp.split(x, layout.p_ulysses, axis=split_axis), axis=0)
        # tiled all-to-all over the leading [P_u] axis: slice j -> peer j,
        # received slices re-stacked in source order — one atomic XLA op.
        return lax.all_to_all(
            chunks, layout.axes, split_axis=0, concat_axis=0, tiled=True
        )
    return grouped_all_to_all(x, layout, split_axis=split_axis,
                              backend=backend, interpret=interpret)


def ungroup_all_to_all(
    stacked: jax.Array, layout: GroupLayout, *, concat_axis: int,
    backend: str = "xla", interpret: bool = True,
    wire_dtype: str | None = None,
) -> jax.Array:
    """Inverse transform: send ``stacked[j]`` back to ulysses-peer j and
    concatenate the received chunks along ``concat_axis`` (the fourth
    all-to-all of Ulysses attention, applied to O)."""
    p_u = layout.p_ulysses
    if p_u == 1:
        return jnp.squeeze(stacked, axis=0)
    if layout.u_groups > 1:
        return hier_ungroup(stacked, layout, concat_axis=concat_axis,
                            backend=backend, interpret=interpret,
                            wire_dtype=wire_dtype)
    if (layout.p_ring == 1 and layout.p_ulysses == layout.size
            and backend == "xla"):
        moved = lax.all_to_all(
            stacked, layout.axes, split_axis=0, concat_axis=0, tiled=True
        )
        return jnp.concatenate(list(moved), axis=concat_axis)
    return staged_ungroup(stacked, layout, concat_axis=concat_axis,
                          backend=backend, interpret=interpret)
