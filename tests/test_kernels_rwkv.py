"""Pallas RWKV6 WKV kernel vs the jnp chunk-scan oracle and the naive
sequential recurrence (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rwkv6_wkv import rwkv6_wkv
from repro.models import ssm


def _inputs(seed, bh, l, n):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], (bh, l, n))
    k = jax.random.normal(ks[1], (bh, l, n))
    v = jax.random.normal(ks[2], (bh, l, n))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (bh, l, n))) * 0.5 + 0.5
    u = jax.random.normal(ks[4], (bh, n)) * 0.1
    return r, k, v, w, u


def _naive(r, k, v, w, u):
    bh, l, n = r.shape
    S = np.zeros((bh, n, n))
    out = np.zeros((bh, l, n))
    r, k, v, w, u = (np.asarray(t, np.float64) for t in (r, k, v, w, u))
    for t in range(l):
        kv = k[:, t][:, :, None] * v[:, t][:, None, :]
        out[:, t] = np.einsum("bn,bnm->bm", r[:, t], S + u[:, :, None] * kv)
        S = w[:, t][:, :, None] * S + kv
    return out


@pytest.mark.parametrize("l,n,chunk", [(32, 8, 8), (64, 16, 16), (128, 64, 64),
                                       (64, 32, 64)])
def test_wkv_kernel_matches_naive(l, n, chunk):
    r, k, v, w, u = _inputs(0, 2, l, n)
    out = rwkv6_wkv(r, k, v, w, u, chunk=chunk, interpret=True)
    ref = _naive(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


def test_wkv_kernel_matches_jnp_chunk_scan():
    """Cross-check against the model-path oracle (models/ssm.py) with the
    [B, L, H, N] layout mapped to the kernel's [BH, L, N]."""
    b, l, h, n = 2, 64, 3, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r = jax.random.normal(ks[0], (b, l, h, n))
    k = jax.random.normal(ks[1], (b, l, h, n))
    v = jax.random.normal(ks[2], (b, l, h, n))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, l, h, n))) * 0.5 + 0.5
    u = jax.random.normal(ks[4], (h, n)) * 0.1
    res = ssm.rwkv6_chunk_scan(r, k, v, w, u, chunk=16)

    fl = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, l, n)
    u_bh = jnp.broadcast_to(u[None], (b, h, n)).reshape(b * h, n)
    out = rwkv6_wkv(fl(r), fl(k), fl(v), fl(w), u_bh, chunk=16, interpret=True)
    out = out.reshape(b, h, l, n).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(res.out),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4), (jnp.bfloat16, 5e-2)])
def test_wkv_kernel_dtypes(dtype, tol):
    r, k, v, w, u = _inputs(2, 2, 64, 16)
    out = rwkv6_wkv(r.astype(dtype), k.astype(dtype), v.astype(dtype),
                    w.astype(dtype), u.astype(dtype), chunk=32, interpret=True)
    ref = _naive(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out, np.float32), ref,
                               rtol=tol, atol=tol)
