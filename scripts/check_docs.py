#!/usr/bin/env python3
"""Fail if src/ cites a DESIGN.md / EXPERIMENTS.md section that does not
exist (run by CI; see ISSUE acceptance: zero dangling doc references).

Checked reference forms:
    DESIGN.md §<N>        -> DESIGN.md must contain a "## §<N>" heading
    EXPERIMENTS.md §<Tag> -> EXPERIMENTS.md must contain "## §<Tag>"
    bare "DESIGN.md" / "EXPERIMENTS.md" mentions -> the file must exist
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
REF_RE = re.compile(r"(DESIGN|EXPERIMENTS)\.md(?:\s*§(\w+))?")
HEAD_RE = re.compile(r"^##\s*§(\w+)", re.MULTILINE)


def sections(doc: pathlib.Path) -> set[str]:
    if not doc.exists():
        return set()
    return set(HEAD_RE.findall(doc.read_text()))


def main() -> int:
    have = {name: sections(ROOT / f"{name}.md")
            for name in ("DESIGN", "EXPERIMENTS")}
    errors = []
    scanned = []
    for d in ("src", "benchmarks", "scripts", "examples"):
        scanned += sorted((ROOT / d).rglob("*.py"))
    for py in scanned:
        text = py.read_text()
        for m in REF_RE.finditer(text):
            name, sec = m.group(1), m.group(2)
            line = text[: m.start()].count("\n") + 1
            if not (ROOT / f"{name}.md").exists():
                errors.append(f"{py.relative_to(ROOT)}:{line}: "
                              f"cites missing file {name}.md")
            elif sec is not None and sec not in have[name]:
                errors.append(
                    f"{py.relative_to(ROOT)}:{line}: cites {name}.md §{sec} "
                    f"but {name}.md has no '## §{sec}' heading "
                    f"(has: {sorted(have[name])})")
    if errors:
        print("\n".join(errors))
        print(f"\n{len(errors)} dangling doc reference(s)")
        return 1
    print("doc references OK "
          f"(DESIGN: §{sorted(have['DESIGN'])}, "
          f"EXPERIMENTS: §{sorted(have['EXPERIMENTS'])})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
