"""Flow-matching Euler sampler for DiT serving (paper Figure 1 pipeline).

One sampling step = one full DiT forward (velocity prediction) — this is
the unit the paper benchmarks ("latency of one sampling step").  The
sampler integrates x_t from t=1 (noise) to t=0 (data) with uniform Euler
steps; the toy linear VAE decode is the stubbed frontend inverse
(DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import ParallelContext
from ..models.dit import LATENT_CHANNELS, dit_forward


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    num_steps: int = 20
    guidance_scale: float = 1.0  # >1 enables classifier-free guidance


def sample_step(params, cfg: ModelConfig, ctx: ParallelContext,
                x_t: jax.Array, cond: jax.Array, t: jax.Array,
                dt: jax.Array, sc: SamplerConfig) -> jax.Array:
    """One Euler step x_{t-dt} = x_t - dt * v(x_t, t)."""
    b = x_t.shape[0]
    tt = jnp.full((b,), t, jnp.float32)
    v = dit_forward(params, cfg, ctx, latents=x_t, cond=cond, timesteps=tt)
    if sc.guidance_scale != 1.0:
        v_un = dit_forward(params, cfg, ctx, latents=x_t,
                           cond=jnp.zeros_like(cond), timesteps=tt)
        v = v_un + sc.guidance_scale * (v - v_un)
    return x_t - dt * v.astype(x_t.dtype)


def sample(params, cfg: ModelConfig, ctx: ParallelContext, *,
           key: jax.Array, batch: int, seq_len: int, cond: jax.Array,
           sc: SamplerConfig = SamplerConfig(),
           step_fn=None) -> jax.Array:
    """Full sampling loop; returns final latents [B, T, LATENT_CHANNELS]."""
    x = jax.random.normal(key, (batch, seq_len, LATENT_CHANNELS), cfg.dtype)
    dt = 1.0 / sc.num_steps
    fn = step_fn or (lambda x, c, t: sample_step(params, cfg, ctx, x, c, t, dt, sc))
    for i in range(sc.num_steps):
        t = 1.0 - i * dt
        x = fn(x, cond, t)
    return x


def toy_vae_decode(latents: jax.Array, out_channels: int = 3,
                   patch: int = 2) -> jax.Array:
    """Stub VAE decoder: fixed linear map latent tokens -> pixel patches.
    [B, T, C] -> [B, T * patch**2, out_channels]."""
    b, t, c = latents.shape
    key = jax.random.PRNGKey(42)  # fixed decoder
    w = jax.random.normal(key, (c, patch * patch * out_channels), latents.dtype)
    px = jnp.einsum("btc,cp->btp", latents, w) / (c ** 0.5)
    return px.reshape(b, t * patch * patch, out_channels)
