"""Metrics stream end-to-end (DESIGN.md §11) on the 8-fake-device hybrid
mesh: a ``DiTServer`` with the full control loop (preemption +
recalibration) and a ``JsonlTracker`` serves a bursty queue — two 256
requests parked mid-batch for an injected SLA-critical 1024 request —
and the JSONL trace must tell the whole story:

  * every line schema-validates and the stream is totally ordered,
  * the park shows up as an ``engine.park`` event naming the parked
    admission and rids, and those rids later complete with
    ``preemptions > 0`` under a NEW admission id (the restart),
  * each completed request's ``engine.t_step_s`` series (matched by its
    admission tag) has exactly ``len(DiTResult.step_times)`` samples,
    with per-step wall clocks agreeing sample-for-sample,
  * the calibrator's refit counters and measured-step gauges stream
    alongside,
  * the tracker-backed legacy attributes equal the trace's final
    cumulative counter values (the migration contract, on-mesh).
"""
import dataclasses

import jax
import pytest

from repro.configs import get_reduced
from repro.core import PipelineConfig, SPConfig
from repro.launch.mesh import make_hybrid_mesh
from repro.serving import (
    CalibrationConfig,
    ControlConfig,
    DiTRequest,
    DiTServer,
    JsonlTracker,
    PreemptionPolicy,
    SamplerConfig,
    SchedConfig,
    read_jsonl,
)

URGENT_SLA = 1.0  # see tests/multidevice/test_preempt_e2e.py

# heavy e2e: the module-scoped served fixture runs a full preempting
# serve behind multi-second jit traces — runs in the dedicated CI 'slow'
# job, not the default tier-1 pass (RUN_SLOW_TESTS=1 to run locally)
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def served(tmp_path_factory):
    """One bursty serve with the full control loop streaming to JSONL:
    two 256 requests admitted, an urgent 1024 request injected after the
    batch's first step, the 256 batch parked and later restarted."""
    cfg = dataclasses.replace(get_reduced("flux-12b"), dtype="float32")
    from repro.models import get_model

    bundle = get_model(cfg)
    params, axes = bundle.init(cfg, jax.random.PRNGKey(0), 1)
    mesh = make_hybrid_mesh(cfg=1, pipe=2, data=2, model=2)
    sp = SPConfig(strategy="swift_torus", sp_axes=("model",),
                  batch_axes=("data",), pp_axis="pipe")
    path = tmp_path_factory.mktemp("metrics") / "trace.jsonl"
    tracker = JsonlTracker(path)
    srv = DiTServer(
        params, cfg, mesh, sp,
        sampler=SamplerConfig(num_steps=3,
                              pipeline=PipelineConfig(pp=2, warmup_steps=1)),
        max_batch=2, param_axes=axes,
        sched=SchedConfig(max_batch=2, starvation_age=3600.0,
                          default_slack=1e9),
        control=ControlConfig(
            preemption=PreemptionPolicy(min_remaining_steps=1),
            calibration=CalibrationConfig(min_samples=1, refit_every=1)),
        tracker=tracker)
    srv.submit(DiTRequest(rid=0, seq_len=256))
    srv.submit(DiTRequest(rid=1, seq_len=256))
    injected = []

    def inject(server, step):
        if not injected:
            injected.append(step)
            server.submit(DiTRequest(rid=2, seq_len=1024, sla=URGENT_SLA))

    srv.on_step = inject
    results = srv.serve()
    srv.on_step = None
    tracker.flush()
    return srv, results, read_jsonl(path)  # read_jsonl validates each line


def _by_name(records, name):
    return [r for r in records if r.name == name]


def test_stream_is_validated_and_totally_ordered(served):
    _, _, records = served
    assert records, "serve produced no metrics"
    assert [r.seq for r in records] == list(range(len(records)))
    # counters are monotone per (name, tags) series across the trace
    per_series = {}
    for r in records:
        if r.kind == "counter":
            per_series.setdefault(
                (r.name, tuple(sorted(r.tags.items()))), []).append(r.value)
    assert per_series, "no counter records in the trace"
    for vals in per_series.values():
        assert vals == sorted(vals)


def test_park_and_restart_events(served):
    srv, results, records = served
    parks = _by_name(records, "engine.park")
    assert len(parks) == srv.preemptions >= 1
    # the park names the parked requests; rids 0 and 1 were in the batch
    parked_rids = set()
    for p in parks:
        parked_rids |= {int(x) for x in str(p.tags["rids"]).split(",")}
        assert p.tags["seq"] == 256
    assert parked_rids == {0, 1}
    done = _by_name(records, "engine.request_done")
    assert sorted(r.tags["rid"] for r in done) == [0, 1, 2]
    by_rid = {r.tags["rid"]: r for r in done}
    # the restart: parked rids complete with preemptions > 0 under a new
    # admission id; the urgent request ran clean
    parked_adm = {p.tags["adm"] for p in parks}
    for rid in (0, 1):
        assert by_rid[rid].tags["preemptions"] >= 1
        assert by_rid[rid].tags["adm"] not in parked_adm
    assert by_rid[2].tags["preemptions"] == 0
    # request_done mirrors the result object (sla_met is NOT asserted
    # true: on this CPU mesh the urgent bucket's jit trace can eat the
    # whole deadline — the trace must report whatever actually happened)
    for r in results:
        assert by_rid[r.rid].value == pytest.approx(r.latency)
        assert by_rid[r.rid].tags["sla_met"] is r.sla_met


def test_per_step_series_matches_result_step_times(served):
    _, results, records = served
    steps = _by_name(records, "engine.t_step_s")
    done = {r.tags["rid"]: r for r in _by_name(records, "engine.request_done")}
    for res in results:
        adm = done[res.rid].tags["adm"]
        series = sorted((r for r in steps if r.tags["adm"] == adm),
                        key=lambda r: r.step)
        # the completing run's step series, sample-for-sample
        assert len(series) == len(res.step_times) == 3
        assert [r.step for r in series] == [0, 1, 2]
        for rec, t in zip(series, res.step_times):
            assert rec.value == pytest.approx(t)
    # the parked admission also measured steps (before its park), so the
    # trace holds MORE step samples than the completing runs alone
    completed_adms = {done[r.rid].tags["adm"] for r in results}
    assert any(r.tags["adm"] not in completed_adms for r in steps)


def test_calibration_events_stream(served):
    srv, results, records = served
    refits = _by_name(records, "calibration.refits")
    # refit_every=1/min_samples=1: every completed batch triggers one
    completed_batches = len(_by_name(records, "engine.batch_done"))
    assert len(refits) == completed_batches >= 2
    assert refits[-1].value == srv.calibrator.refits
    measured = _by_name(records, "calibration.measured_step_us")
    assert len(measured) == completed_batches
    assert all(m.value > 0 for m in measured)
    # each refit publishes the per-parameter drift-ratio trajectory
    ratios = _by_name(records, "calibration.drift_ratio")
    assert ratios and {r.tags["param"] for r in ratios} == \
        set(srv.calibrator.last_ratios)
    assert all(r.value > 0 for r in ratios)


def test_legacy_attributes_equal_final_counter_values(served):
    srv, results, records = served

    def final_total(name):
        last = {}
        for r in records:
            if r.kind == "counter" and r.name == name:
                last[tuple(sorted(r.tags.items()))] = r.value
        return sum(last.values())

    assert srv.preemptions == final_total("engine.preemptions")
    assert srv.scheduler.admissions == final_total("sched.admissions")
    assert srv.scheduler.preempted == final_total("sched.requeued_requests")
    assert srv.plan_cache.hits == final_total("plan_cache.step_hit")
    assert srv.plan_cache.traces == final_total("plan_cache.step_miss")
    assert srv.calibrator.refits == final_total("calibration.refits")
    assert final_total("engine.completed") == len(results) == 3
    assert final_total("engine.restarted_requests") == 2
