"""Extra serving-engine coverage: batch padding, mixed lengths, DiT batch
divisibility, sampler step math."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import SPConfig
from repro.models import ParallelContext, get_model
from repro.models.dit import COND_TOKENS, dit_forward
from repro.serving import DiTRequest, DiTServer, SamplerConfig
from repro.serving.sampler import sample_step

SP = SPConfig(strategy="full", sp_axes=("model",), batch_axes=("data",))


@pytest.fixture(scope="module")
def dit():
    cfg = dataclasses.replace(get_reduced("cogvideox-5b"), dtype="float32")
    bundle = get_model(cfg)
    params, _ = bundle.init(cfg, jax.random.PRNGKey(0), 1)
    return cfg, params


def test_single_request_batch_pads(dit, mesh1):
    cfg, params = dit
    srv = DiTServer(params, cfg, mesh1, SP,
                    sampler=SamplerConfig(num_steps=1), max_batch=4)
    srv.submit(DiTRequest(rid=0, seq_len=32))
    out = srv.serve()
    assert len(out) == 1 and out[0].latents.shape == (32, 64)


def test_euler_step_direction(dit, mesh1):
    """x_{t-dt} = x_t - dt*v: a zero-velocity model leaves x unchanged."""
    cfg, params = dit
    # zero the output projection -> v == 0 (proj_out is zero-init already,
    # but adaLN gates are zero-init too; assert the identity holds)
    ctx = ParallelContext(mesh1, SP, "prefill")
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 64))
    cond = jnp.zeros((1, COND_TOKENS, cfg.d_model))
    v = dit_forward(params, cfg, ctx, latents=x, cond=cond,
                    timesteps=jnp.ones((1,)))
    x2 = sample_step(params, cfg, ctx, x, cond, jnp.float32(1.0),
                     jnp.float32(0.5), SamplerConfig(num_steps=2))
    np.testing.assert_allclose(np.asarray(x2), np.asarray(x - 0.5 * v),
                               rtol=1e-5, atol=1e-6)


def test_guidance_scale_path(dit, mesh1):
    cfg, params = dit
    ctx = ParallelContext(mesh1, SP, "prefill")
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 64))
    cond = jax.random.normal(jax.random.PRNGKey(3),
                             (1, COND_TOKENS, cfg.d_model)) * 0.02
    out = sample_step(params, cfg, ctx, x, cond, jnp.float32(1.0),
                      jnp.float32(0.25), SamplerConfig(num_steps=4,
                                                       guidance_scale=3.0))
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
