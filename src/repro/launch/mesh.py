"""Production mesh construction (assignment MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1, data: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (fake or real) devices exist — used by
    smoke tests, examples, and the multidevice test suite."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(AxisType.Auto, AxisType.Auto),
    )
