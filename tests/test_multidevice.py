"""Runs the multi-device SP suite in ONE subprocess with 8 fake devices.

The outer pytest run keeps 1 device (assignment requirement); the inner
run sets XLA_FLAGS before jax initializes.  pyproject excludes
tests/multidevice from outer collection.
"""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)


@pytest.mark.timeout(1800)
def test_multidevice_suite():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    proc = subprocess.run(
        [sys.executable, "-m", "pytest",
         os.path.join(HERE, "multidevice"), "-q", "-p", "no:cacheprovider"],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    if proc.returncode != 0:
        tail = "\n".join((proc.stdout + proc.stderr).splitlines()[-60:])
        pytest.fail(f"inner multidevice suite failed:\n{tail}")
