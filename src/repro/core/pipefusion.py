"""Displaced patch pipeline parallelism for DiT inference (PipeFusion,
arXiv:2405.14430), composed with the SwiftFusion SP strategies.

Diffusion sampling runs the same network num_steps times on slowly-varying
inputs ("inter-step latent similarity").  PipeFusion exploits this with two
moves:

  1. **Patch pipelining** — split the latent sequence into ``num_patches``
     contiguous patches and the DiT block stack into ``pp`` contiguous
     stages, one stage per device along a ``pipe`` mesh axis.  Patches
     stream through the stages GPipe-style, so each device holds only
     ``n_layers / pp`` blocks and the activation working set of one patch.

  2. **Displaced (one-step-stale) activations** — attention needs KV for
     the *full* sequence, but only the resident patch is fresh on a stage.
     PipeFusion's async variant reuses the previous diffusion step's
     per-layer KV for every non-resident token instead of waiting, turning
     the per-layer SP collectives into a single P2P activation hand-off
     per stage boundary per step.  The approximation error vanishes as
     sampling converges (x_t changes less and less per step); the first
     ``warmup_steps`` steps run fully synchronous to populate the caches.

This module owns the schedule/bookkeeping; the DiT-specific forward lives
in models/dit.py (``dit_forward_displaced``) and the mesh/axis planning in
core/planner.py (``plan_hybrid``).  See DESIGN.md §7 for how the
single-program emulation below maps onto the paper-style multi-device
schedule, and which parts of PipeFusion are deliberately deviated from.

Freshness rule implemented here (async PipeFusion): when patch p is
processed at diffusion step t, layer l's attention sees

    K, V rows of patch p        : fresh (computed this step, this layer)
    K, V rows of every other row: stale (step t-1, same layer)

so every patch depends only on the previous step's state, never on another
patch's current-step values — exactly the dependency structure that lets
the real system run all stages concurrently without a sync point.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax
from typing import NamedTuple

from .softmax import attend_partial, finalize, merge


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Patch-level pipeline parallelism knobs (PipeFusion).

    ``pp``           — pipeline stages; DiT blocks are split into ``pp``
                       contiguous groups along a ``pp_axis`` mesh axis
                       (weights: the stacked 'layers' dim is sharded).
    ``num_patches``  — latent patches streamed through the stages; 0 means
                       "same as pp" (the paper's default M = N choice).
    ``warmup_steps`` — leading sampler steps run fully synchronous (no
                       staleness) to populate the per-layer KV state; must
                       be >= 1.
    ``resync_every`` — staleness control (ROADMAP): after warmup, run one
                       fully-synchronous re-sync step every this many
                       sampler steps, bounding how far the displaced KV
                       can drift from the fresh activations.  0 = never
                       (PipeFusion's warmup-only refresh); 1 = every step
                       synchronous (no staleness at all).
    ``pp_axis``      — mesh axis name holding the stages.
    """

    pp: int = 1
    num_patches: int = 0
    warmup_steps: int = 1
    resync_every: int = 0
    pp_axis: str = "pipe"

    def __post_init__(self):
        assert self.pp >= 1, self
        assert self.num_patches >= 0, self
        assert self.warmup_steps >= 1, "first step must populate the KV state"
        assert self.resync_every >= 0, self

    @property
    def patches(self) -> int:
        return self.num_patches or self.pp

    @property
    def enabled(self) -> bool:
        return self.pp > 1 or self.patches > 1

    def warm_step(self, i: int) -> bool:
        """Whether sampler step ``i`` runs fully synchronous: the warmup
        prefix, plus every ``resync_every``-th step after it."""
        if i < self.warmup_steps:
            return True
        if self.resync_every <= 0:
            return False
        return (i - self.warmup_steps + 1) % self.resync_every == 0


class KVState(NamedTuple):
    """Per-layer full-sequence attention KV from the previous sampler step.

    ``k`` is stored post-RoPE so stale rows can be attended directly.
    Shapes: [n_layers, B, T_total, Hkv, D] each, where T_total counts the
    conditioning tokens + latent tokens (models/dit.py concatenates them).
    """

    k: jax.Array
    v: jax.Array


def init_kv_state(n_layers: int, batch: int, seq_total: int, n_kv_heads: int,
                  head_dim: int, dtype) -> KVState:
    """Zero state with the right signature for the jitted displaced step.

    Never *read* before warmup writes it (warmup_steps >= 1); zeros exist
    only so the step function has a fixed input signature.
    """
    shape = (n_layers, batch, seq_total, n_kv_heads, head_dim)
    return KVState(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


# ---------------------------------------------------------------------------
# static partitioning helpers (all python ints — resolved at trace time)
# ---------------------------------------------------------------------------

def patch_slices(cond_tokens: int, latent_len: int,
                 num_patches: int) -> list[tuple[int, int]]:
    """(start, length) patches over the concatenated [cond ; latents] seq.

    Patch 0 additionally owns the conditioning tokens, so their activations
    are refreshed every step by whichever stage holds patch 0 — PipeFusion
    treats the text tokens as resident state of the first micro-batch.
    """
    assert num_patches >= 1
    assert latent_len % num_patches == 0, (
        f"latent length {latent_len} must divide into {num_patches} patches")
    chunk = latent_len // num_patches
    out = [(0, cond_tokens + chunk)]
    for p in range(1, num_patches):
        out.append((cond_tokens + p * chunk, chunk))
    return out


def stage_layers(n_layers: int, pp: int) -> list[tuple[int, int]]:
    """(first_layer, count) per pipeline stage — contiguous block split."""
    assert n_layers % pp == 0, (
        f"n_layers {n_layers} must divide into {pp} pipeline stages")
    per = n_layers // pp
    return [(s * per, per) for s in range(pp)]


def drop_rows(x: jax.Array, start: int, length: int, axis: int) -> jax.Array:
    """Remove rows [start, start+length) along ``axis`` (static indices)."""
    lo = lax.slice_in_dim(x, 0, start, axis=axis)
    hi = lax.slice_in_dim(x, start + length, x.shape[axis], axis=axis)
    return jnp.concatenate([lo, hi], axis=axis)


# ---------------------------------------------------------------------------
# displaced attention
# ---------------------------------------------------------------------------

def displaced_attention(
    q: jax.Array,        # [B, Lp, Hq, D] fresh queries of the resident patch
    k_fresh: jax.Array,  # [B, Lp, Hkv, D] fresh (post-RoPE) resident KV
    v_fresh: jax.Array,
    k_stale: jax.Array,  # [B, Lr, Hkv, D] one-step-stale KV, non-resident rows
    v_stale: jax.Array,
    *,
    scale: float | None = None,
) -> jax.Array:
    """Attention of a patch's fresh Q against mixed-freshness full-seq KV.

    Uses the Appendix-C partial/merge algebra (the same machinery Ring and
    Torus attention use) rather than a concat: the fresh and stale
    contributions are computed as two unnormalised partials and merged with
    one log-sum-exp rescale — so the stale tensors are consumed in place.
    DiT attention is bidirectional and unwindowed, so no mask is needed.
    """
    fresh = attend_partial(q, k_fresh, v_fresh, scale=scale)
    if k_stale.shape[1] == 0:
        return finalize(fresh, dtype=q.dtype)
    stale = attend_partial(q, k_stale.astype(q.dtype),
                           v_stale.astype(q.dtype), scale=scale)
    return finalize(merge(fresh, stale), dtype=q.dtype)


def kv_drift(old: KVState, new: KVState, *, per_item: bool = False) -> jax.Array:
    """Per-step KV staleness metric: RMS change of the per-layer KV state
    across one sampler step, in units of the state's own RMS magnitude.

    This is the quantity ``resync_every`` bounds — as sampling converges
    ("inter-step latent similarity") it decays toward 0, and a serving
    policy can trade quality vs latency per request by watching it.
    Scalar by default; ``per_item`` keeps the batch axis ([B]) so each
    batched request gets its own trajectory (a shared-batch aggregate
    would let one fast-drifting request hide behind a stable one).
    Finite even for an all-zero state.
    """
    axes = (0, 2, 3, 4) if per_item else None  # [L, B, T, H, D] -> [B]
    num = (jnp.mean((new.k - old.k) ** 2, axis=axes)
           + jnp.mean((new.v - old.v) ** 2, axis=axes))
    den = jnp.mean(old.k ** 2, axis=axes) + jnp.mean(old.v ** 2, axis=axes)
    return jnp.sqrt(num / jnp.maximum(den, 1e-12))


def update_state_rows(state: KVState, k_new: jax.Array, v_new: jax.Array,
                      start: int) -> KVState:
    """Write fresh per-layer KV rows of one patch back into the state.

    k_new/v_new: [n_layers, B, Lp, Hkv, D]; rows [start, start+Lp) of the
    sequence axis (2) are replaced.
    """
    ins = lambda buf, new: lax.dynamic_update_slice_in_dim(
        buf, new.astype(buf.dtype), start, axis=2)
    return KVState(k=ins(state.k, k_new), v=ins(state.v, v_new))
