"""Ring Attention over a logical ring group (paper §2.2, Algorithm 1 RINGATTN).

Per-device view: the KV shard (possibly a Ulysses-gathered concatenation of
several chunks) rotates around the Ring group in P_r steps while each device
keeps its local Q and accumulates the online-softmax partial ``(O', l, m)``.

The KV transfer for step s+1 is issued *before* the attention compute of
step s (double buffering) through a one-sided ``repro.comm`` channel
(DESIGN.md §8): the ``put`` starts the collective-permute DMA, the
``fence`` is the receiver-side signal wait — the TPU equivalent of the
paper's stream-ordered one-sided pulls (Algorithm 1 RINGATTN lines 2-7:
pull next, compute current, wait).

Masking is exact under arbitrary chunk layouts: the caller supplies a
*position function* mapping the ring rank that owns the currently-held KV
to the global positions of its elements, so causal/sliding-window masks are
identical to the single-device computation no matter where a chunk
currently sits.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from ..comm import Stream, fence, ring_shift
from ..comm import profiler as _profiler
from ..comm import trace as _trace
from .collectives import GroupLayout
from .softmax import (MaskSpec, Partial, attend_partial,
                      attend_partial_blockwise, empty_partial, merge)

# maps the ring coordinate (traced int32) owning the chunk -> [Lk] positions
KPosFn = Callable[[jax.Array], jax.Array]


def ring_attention(
    q: jax.Array,  # [B, Lq, Hq, D] local query (stays put)
    k: jax.Array,  # [B, Lk, Hkv, D] local KV shard (rotates)
    v: jax.Array,
    layout: GroupLayout,
    *,
    q_pos: jax.Array | None,  # [Lq] global positions of q (None = no masking)
    k_pos_fn: KPosFn | None,
    scale: float | None = None,
    causal: bool = False,
    window: int | None = None,
    accum: Partial | None = None,
    unroll: bool = False,
    kv_block: int | None = None,
    backend: str = "xla",
    interpret: bool = True,
) -> Partial:
    """Run P_r ring steps; returns the merged partial (not finalized).

    ``kv_block`` caps the materialized score matrix per attend (see
    softmax.attend_partial_blockwise).

    ``backend="pallas"`` runs the fused path (DESIGN.md §8.1): each ring
    step is ONE ``kernels.ring_flash`` call that carries the (O', l, m)
    online-softmax state in VMEM *and* issues the next-step KV put from
    inside the kernel, the paper's Algorithm-2 overlap.  The pallas path
    is always step-unrolled (one kernel per step) and ignores
    ``kv_block`` (the kernel has its own VMEM blocking); ``interpret``
    selects the interpreter-mode lowering (the CPU CI path)."""
    if backend == "pallas":
        return _ring_attention_pallas(
            q, k, v, layout, q_pos=q_pos, k_pos_fn=k_pos_fn, scale=scale,
            causal=causal, window=window, accum=accum, interpret=interpret)
    def _attend(q_, k_, v_, mask):
        if kv_block is not None:
            return attend_partial_blockwise(q_, k_, v_, scale=scale,
                                            mask=mask, kv_block=kv_block)
        return attend_partial(q_, k_, v_, scale=scale, mask=mask)
    p_r = layout.p_ring
    b, lq, hq, d = q.shape
    acc = accum if accum is not None else empty_partial(b, lq, hq, d)
    masked = causal or window is not None

    def mask_for(owner_r):
        if not masked:
            return None
        return MaskSpec(
            causal=causal,
            window=window,
            q_pos=q_pos,
            k_pos=k_pos_fn(owner_r) if k_pos_fn is not None else None,
        )

    _, my_r = layout.my_coords()
    if p_r == 1:
        # pure-Ulysses plan: no ring rotation, but this local attend is
        # still the compute the torus hops are scheduled to hide — mark it
        # so per-stage traces stay complete for overlap accounting
        out = merge(acc, _attend(q, k, v, mask_for(my_r)))
        _profiler.mark_compute("local attend", layout.axes, (k, v),
                               tuple(out), stream="ring")
        return out

    stream = Stream("ring")

    def body(s, carry):
        kc, vc, acc = carry
        # issue next-step transfer first (double buffer), compute current
        nxt = ring_shift(layout, kc, vc, stream=stream,
                         overlaps="ring attend")
        owner = (my_r - s) % p_r  # ring rank whose shard I currently hold
        acc = merge(acc, _attend(q, kc, vc, mask_for(owner)))
        _profiler.mark_compute("ring attend", layout.axes, (kc, vc),
                               tuple(acc), stream=stream.name)
        return (*nxt.payload, acc)

    if unroll:
        # unrolling lets XLA schedule permutes across step boundaries at the
        # cost of HLO size; fori_loop keeps HLO O(1) in P_r.  The fence on
        # acc stops the scheduler from materializing every step's score
        # matrix at once (puts don't pass through the fence, so they still
        # overlap with compute).
        kc, vc = k, v
        for s in range(p_r - 1):
            # fence this step's attend inputs on the accumulator so only one
            # step's score matrix is live; the next put stays independent
            nxt = ring_shift(layout, kc, vc, stream=stream,
                             overlaps="ring attend")
            (kc_g, vc_g), accs = fence((kc, vc), tuple(acc))
            acc = Partial(*accs)
            owner = (my_r - s) % p_r
            acc = merge(acc, _attend(q, kc_g, vc_g, mask_for(owner)))
            _profiler.mark_compute("ring attend", layout.axes,
                                   (kc_g, vc_g), tuple(acc),
                                   stream=stream.name)
            kc, vc = nxt.payload
    else:
        kc, vc, acc = lax.fori_loop(0, p_r - 1, body, (k, v, acc))
    # last step: compute only, no further transfer (2(P-1)/P volume, §2.2)
    owner = (my_r - (p_r - 1)) % p_r
    out = merge(acc, _attend(q, kc, vc, mask_for(owner)))
    _profiler.mark_compute("ring attend", layout.axes, (kc, vc),
                           tuple(out), stream=stream.name)
    return out


# ---------------------------------------------------------------------------
# fused Pallas path (DESIGN.md §8.1)
# ---------------------------------------------------------------------------

def _ring_attention_pallas(
    q: jax.Array,  # [B, Lq, Hq, D]
    k: jax.Array,  # [B, Lk, Hkv, D]
    v: jax.Array,
    layout: GroupLayout,
    *,
    q_pos: jax.Array | None,
    k_pos_fn: KPosFn | None,
    scale: float | None,
    causal: bool,
    window: int | None,
    accum: Partial | None,
    interpret: bool,
) -> Partial:
    """P_r fused ring steps: kernel-carried (O', l, m) + in-kernel puts.

    The KV chunk circulates in *flattened padded* layout ([B·Hkv, Lk_pad,
    D], padding masked via k_pos = -1), so the kernel's forward buffers
    can be handed to the channel unmodified at every step.
    """
    from ..kernels.flash_mqkv import (DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q,
                                      flash_mqkv)
    from ..kernels.ops import _flatten_heads, _pad_to
    from ..kernels.ring_flash import ring_flash_step

    def _flatten_pad(x, block):  # [B, L, H, D] -> [B*H, L_pad, D]
        return _pad_to(_flatten_heads(x), 1, block)

    def _pad_pos(p, block, value):
        return _pad_to(p.astype(jnp.int32), 0, block, value=value)

    p_r = layout.p_ring
    b, lq, hq, d = q.shape
    lk, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    bq = min(DEFAULT_BLOCK_Q, max(8, lq))
    bk = min(DEFAULT_BLOCK_K, max(8, lk))
    _, my_r = layout.my_coords()

    qf = _flatten_pad(q, bq)
    qpp = _pad_pos(q_pos if q_pos is not None
                   else jnp.arange(lq, dtype=jnp.int32), bq, 0)
    kc, vc = _flatten_pad(k, bk), _flatten_pad(v, bk)

    def kpos_for(owner):
        base = (k_pos_fn(owner) if k_pos_fn is not None
                else jnp.arange(lk, dtype=jnp.int32))
        return _pad_pos(base, bk, -1)

    stream = Stream("ring", backend="pallas", interpret=interpret)
    state = None
    fut = None
    for s in range(p_r):
        if fut is not None:
            kc, vc = fut.wait()
        owner = (my_r - s) % p_r
        if s < p_r - 1:
            # fused step: the kernel issues the next-step put at its first
            # grid step and drains it after its last compute block
            ch = stream.channel(layout.axes, layout.ring_perm(1),
                                f"shift1.s{s}")
            stream.next_stage()
            (o, l, m), (kfwd, vfwd) = ring_flash_step(
                qf, kc, vc, qpp, kpos_for(owner), group=group, scale=scale,
                causal=causal, window=window, state=state, finalize=False,
                block_q=bq, block_k=bk, interpret=interpret)
            fut = ch.put_fused(kfwd, vfwd, overlaps="ring attend")
            _trace.mark_compute("ring attend", stream=stream.name)
        else:
            # last step: compute only (2(P-1)/P volume, §2.2)
            o, l, m = flash_mqkv(
                qf, kc, vc, qpp, kpos_for(owner), group=group, scale=scale,
                causal=causal, window=window, state=state, finalize=False,
                block_q=bq, block_k=bk, interpret=interpret)
        _profiler.mark_compute("ring attend", layout.axes, (kc, vc),
                               (o, l, m), stream=stream.name)
        state = (o, l, m)

    o, l, m = state
    part = Partial(
        o=o.reshape(b, hq, -1, d)[:, :, :lq].transpose(0, 2, 1, 3),
        l=l.reshape(b, hq, -1)[:, :, :lq],
        m=m.reshape(b, hq, -1)[:, :, :lq],
    )
    return part if accum is None else merge(accum, part)
