from . import checkpoint
from .data import SyntheticStream
from .optimizer import AdamWConfig, AdamWState, adamw_update, init_adamw
from .trainer import Trainer, batch_shardings, make_train_step

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "SyntheticStream",
    "Trainer",
    "adamw_update",
    "batch_shardings",
    "checkpoint",
    "init_adamw",
    "make_train_step",
]
