"""SLA-aware request scheduling for DiT serving (DESIGN.md §9).

Resolution-bucketed continuous batching: a bucketer groups requests by
latent length, an admission policy scores (bucket, batch-size) candidates
with the analytical comm model against per-request SLAs, a plan cache
selects and memoizes one ``plan_hybrid`` execution plan (and compiled
step) per bucket shape, and a drift policy turns the displaced pipeline's
``kv_drift`` signal into threshold-triggered resyncs.
"""
from .admission import AdmissionPolicy, Candidate, SchedConfig
from .bucketer import (
    Bucket,
    Bucketer,
    BucketStats,
    aged_priority,
    deadline_of,
    padded_rows,
)
from .drift import DriftPolicy
from .plan_cache import PlanCache, PlanChoice
from .scheduler import Admission, RequestScheduler

__all__ = [
    "Admission",
    "AdmissionPolicy",
    "Bucket",
    "Bucketer",
    "BucketStats",
    "Candidate",
    "DriftPolicy",
    "PlanCache",
    "PlanChoice",
    "RequestScheduler",
    "SchedConfig",
    "aged_priority",
    "deadline_of",
    "padded_rows",
]
