"""Serve a small DiT through the hybrid-parallel engine — the paper's
scenario (Figure 1) plus the beyond-paper hybrid axes (DESIGN.md §7):
mixed-resolution requests with SLA deadlines -> the request scheduler
(DESIGN.md §9: resolution buckets, deadline-scored admission, per-bucket
plan cache, drift-triggered resync) -> batched flow-matching sampling
with swift_torus SP composed with CFG parallelism and displaced patch
pipelining -> latents -> toy VAE decode.

Part two demonstrates the adaptive control loop (DESIGN.md §10) under a
bursty arrival pattern: a burst of tight-SLA small requests lands while
a long best-effort batch is mid-flight; the preemption policy parks the
running batch between sampler steps (its requests keep their accrued
age), the burst is served, the parked batch restarts, and the online
calibrator refits the comm model from the measured step times.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_dit.py
"""
import dataclasses
import os
import sys

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core import PipelineConfig, SPConfig, plan_hybrid
from repro.launch.mesh import make_hybrid_mesh
from repro.models import get_model
from repro.serving import (
    CalibrationConfig,
    ControlConfig,
    DiTRequest,
    DiTServer,
    DriftPolicy,
    PreemptionPolicy,
    SamplerConfig,
    toy_vae_decode,
)


def main():
    cfg = dataclasses.replace(get_reduced("flux-12b"), n_layers=2,
                              d_model=256, n_heads=8, n_kv_heads=8,
                              head_dim=32, d_ff=512, dtype="float32")
    bundle = get_model(cfg)
    params, axes = bundle.init(cfg, jax.random.PRNGKey(0), 1)

    # hybrid mesh over the 8 host devices: 2-way CFG x 2 pipeline stages x
    # 2-way swift_torus SP — the planner picks the same shape for a real
    # N x M cluster (cfg and pp consume the slow boundary first).
    h = plan_hybrid(4, 2, cfg.n_heads, cfg.n_kv_heads, cfg_parallel=True,
                    pp=2, n_layers=cfg.n_layers)
    print(f"hybrid plan: cfg={h.cfg} pp={h.pp} "
          f"P_u={h.sp.p_ulysses} P_r={h.sp.p_ring}  "
          f"({h.total_devices} devices)")
    mesh = make_hybrid_mesh(cfg=h.cfg, pipe=h.pp, data=1,
                            model=h.sp.sp_degree)
    sp = SPConfig(strategy="swift_torus", sp_axes=("model",),
                  batch_axes=("data",), cfg_axis="cfg", pp_axis="pipe")
    srv = DiTServer(params, cfg, mesh, sp,
                    sampler=SamplerConfig(
                        num_steps=4, guidance_scale=5.0, cfg_parallel=True,
                        pipeline=PipelineConfig(pp=2, warmup_steps=1)),
                    max_batch=2, param_axes=axes,
                    drift=DriftPolicy(threshold=0.1),
                    # the §10 control loop: step-level preemption, online
                    # comm-model refit, forecast-bounded deferral (the
                    # deferral horizon only binds dp-padded batches, so
                    # on this dp=1 mesh the forecaster just tracks rates)
                    control=ControlConfig(
                        preemption=PreemptionPolicy(min_remaining_steps=1),
                        calibration=CalibrationConfig(min_samples=4,
                                                      refit_every=2),
                        forecast=True))

    # a mixed-resolution queue with per-request SLAs: three "image" sizes;
    # the scheduler buckets by latent length, admits by deadline slack,
    # and caches one compiled step + plan per bucket shape (DESIGN.md §9)
    sizes = [64, 128, 256]
    # generous SLAs: on this CPU container the first batch per bucket pays
    # its jit trace inside the request latency
    slas = {64: 30.0, 128: 60.0, 256: 90.0}
    for i in range(6):
        n = sizes[i % len(sizes)]
        srv.submit(DiTRequest(rid=i, seq_len=n, sla=slas[n],
                              drift_threshold=0.1))
    results = srv.serve()
    for r in sorted(results, key=lambda r: r.rid):
        px = toy_vae_decode(r.latents[None])
        print(f"request {r.rid}: latents {tuple(r.latents.shape)} -> "
              f"pixels {tuple(px.shape)}  "
              f"latency {r.latency * 1e3:.1f} ms  sla_met={r.sla_met}  "
              f"resyncs={r.resyncs}  finite="
              f"{bool(jnp.all(jnp.isfinite(r.latents)))}")
    tot = srv.scheduler.totals()
    print(f"\nserved {len(results)} requests with swift_torus SP x "
          f"cfg_parallel x pp={h.pp} over {mesh.devices.size} devices; "
          f"{tot.batches} batches over {len(srv.plan_cache.plans)} bucket "
          f"shapes ({srv.plan_cache.traces} traces, "
          f"{srv.plan_cache.hits} step-cache hits)")

    # -- part two: a bursty arrival mid-batch (DESIGN.md §10) -------------
    # two long best-effort requests start a batch; after its first step a
    # burst of tight-SLA small requests lands via the on_step hook — the
    # preemption policy parks the long batch (remaining measured steps
    # exceed the burst's slack), serves the burst, then restarts it
    print("\n--- bursty arrivals: step-level preemption ---")
    srv.submit(DiTRequest(rid=100, seq_len=256))
    srv.submit(DiTRequest(rid=101, seq_len=256))
    burst_sent = []

    def burst(server, step):
        if not burst_sent:
            burst_sent.append(step)
            for j in range(2):
                server.submit(DiTRequest(rid=200 + j, seq_len=64, sla=0.15,
                                         drift_threshold=0.1))

    srv.on_step = burst
    bursty = srv.serve()
    srv.on_step = None
    for r in sorted(bursty, key=lambda r: r.rid):
        print(f"request {r.rid}: seq {r.latents.shape[0]}  "
              f"latency {r.latency * 1e3:.1f} ms  sla_met={r.sla_met}  "
              f"preemptions={r.preemptions}  "
              f"steps {[f'{t * 1e3:.0f}ms' for t in r.step_times]}")
    cal = srv.calibrator
    print(f"\ncontrol loop: {srv.preemptions} batch preemptions "
          f"({srv.scheduler.preempted} requests parked and requeued), "
          f"{cal.refits} comm-model refits, {cal.recalibrations} "
          f"recalibrations ({srv.plan_cache.invalidations} plan-score "
          f"invalidations; compiled steps kept: "
          f"{srv.plan_cache.traces} traces)")


if __name__ == "__main__":
    main()
