"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  See each module's docstring for
the figure it regenerates and the derivation caveats (this container is
CPU-only; multi-pod numbers come from the calibrated analytical model and
the dry-run roofline, not wall clocks).

Besides the CSV, every module run also lands in a ``BENCH_<module>.json``
trajectory record (``--out-dir``, default cwd): the module's rows (step
latencies — measured for device-local benches, model-predicted for
multi-pod sweeps) plus, for modules exposing ``records()``, structured
per-config records pairing each configuration with its comm-model
prediction breakdown.  These files are the calibration corpus the ROADMAP
"fit NetworkModel to BENCH_*.json" item consumes: the JSON keeps the full
(config -> prediction) mapping that the flat CSV derives away.

``--metrics out.jsonl`` additionally routes every parsed row through the
serving metrics sink (DESIGN.md §11): one ``bench.us`` gauge per row
(tagged module/name) and per-module ``bench.rows``/``bench.errors``
counters, schema-versioned like a serve trace — so bench trajectories
and serving telemetry are one stream format.  ``--only SUBSTR`` filters
modules by substring (CI's metrics-schema gate runs a single fast
module).
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys
import time


def parse_row(line: str) -> dict:
    """Inverse of common.row: 'name,us,derived' (derived may hold commas).
    Non-finite latencies (error rows) become null so the JSON stays valid."""
    name, us, derived = (line.split(",", 2) + ["", ""])[:3]
    try:
        us_val: float | None = float(us)
        if not math.isfinite(us_val):
            us_val = None
    except ValueError:
        us_val = None
    return {"name": name, "us": us_val, "derived": derived}


def write_bench_json(out_dir: pathlib.Path, module_name: str,
                     rows: list[str], records: list[dict] | None) -> pathlib.Path:
    """Write one BENCH_<module>.json trajectory record."""
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"BENCH_{module_name}.json"
    records = [dict(r) for r in records] if records else []
    for rec in records:
        # surface the comm model's predicted overlap efficiency
        # (DESIGN.md §12) as a first-class column, next to the latency it
        # modulates — consumers should not have to dig in the breakdown
        if "overlap_efficiency" not in rec:
            bd = rec.get("predicted_breakdown") or {}
            rec["overlap_efficiency"] = bd.get("overlap_efficiency")
    payload = {
        "schema": "bench.v1",
        "module": module_name,
        "generated_at": time.time(),
        "rows": [parse_row(r) for r in rows],
        "records": records,
    }
    path.write_text(json.dumps(payload, indent=1, sort_keys=True))
    return path


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", type=pathlib.Path, default=pathlib.Path("."),
                    help="directory for BENCH_*.json trajectory records")
    ap.add_argument("--no-json", action="store_true",
                    help="CSV to stdout only; write no BENCH_*.json")
    ap.add_argument("--only", default=None, metavar="SUBSTR",
                    help="run only modules whose name contains SUBSTR")
    ap.add_argument("--metrics", default=None, metavar="OUT.JSONL",
                    help="stream each row through the serving metrics "
                         "sink as schema-versioned JSONL (DESIGN.md §11)")
    ap.add_argument("--profile", default=None, metavar="TRACE.JSONL",
                    help="--metrics plus the span-level comm profiler "
                         "(DESIGN.md §12): device-executing modules also "
                         "stream per-device comm-leg/compute spans; render "
                         "with scripts/trace_report.py")
    args = ap.parse_args(argv)
    if args.profile is not None and args.metrics is not None:
        ap.error("--profile already streams metrics records; "
                 "give one output path, not both")

    import contextlib

    from repro.comm import CommProfiler, emit_leg_spans
    from repro.comm import profile as comm_profile
    from repro.serving.metrics import JsonlTracker, Tracker

    sink = args.profile if args.profile is not None else args.metrics
    tracker = JsonlTracker(sink) if sink is not None else Tracker()
    profiler = CommProfiler() if args.profile is not None else None

    from . import (
        ablation,
        comm_volume,
        config_sweep,
        e2e_latency,
        fleet_sweep,
        hier_a2a_sweep,
        hybrid_sweep,
        kernel_bench,
        layerwise,
        roofline_table,
        sched_sweep,
    )

    modules = {
        "comm_volume (Fig 3b / App D)": comm_volume,
        "e2e_latency (Fig 7)": e2e_latency,
        "config_sweep (Fig 8)": config_sweep,
        "layerwise (Fig 9)": layerwise,
        "ablation (Fig 10)": ablation,
        "kernel_bench (Fig 12)": kernel_bench,
        "roofline_table (assignment)": roofline_table,
        "hybrid_sweep (beyond-paper, DESIGN.md §7)": hybrid_sweep,
        "hier_a2a_sweep (beyond-paper, DESIGN.md §8.2)": hier_a2a_sweep,
        "sched_sweep (beyond-paper, DESIGN.md §9)": sched_sweep,
        "fleet_sweep (beyond-paper, DESIGN.md §13)": fleet_sweep,
    }
    if args.only is not None:
        modules = {t: m for t, m in modules.items() if args.only in t}
        if not modules:
            raise SystemExit(f"--only {args.only!r} matched no module")

    print("name,us_per_call,derived")
    ok = True
    for title, mod in modules.items():
        mod_name = mod.__name__.split(".")[-1]
        print(f"# --- {title} ---", file=sys.stderr)
        try:
            prof_ctx = (comm_profile(profiler) if profiler is not None
                        else contextlib.nullcontext())
            with prof_ctx:
                rows = list(mod.run())
            if profiler is not None:
                n_spans = emit_leg_spans(profiler, tracker)
                if n_spans:
                    print(f"# {mod_name}: {n_spans} profiler spans",
                          file=sys.stderr)
            for line in rows:
                print(line)
                parsed = parse_row(line)
                if parsed["us"] is not None:
                    tracker.log("bench.us", parsed["us"],
                                tags={"module": mod_name,
                                      "name": parsed["name"]})
            tracker.count("bench.rows", len(rows),
                          tags={"module": mod_name})
            if not args.no_json:
                recs = getattr(mod, "records", None)
                path = write_bench_json(args.out_dir, mod_name,
                                        rows, recs() if recs else None)
                print(f"# wrote {path}", file=sys.stderr)
        except Exception as e:  # keep the harness running, flag failure
            print(f"{title},NaN,ERROR:{type(e).__name__}:{e}")
            tracker.count("bench.errors", tags={"module": mod_name})
            ok = False
    tracker.close()
    if sink is not None:
        print(f"# wrote {sink}", file=sys.stderr)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
