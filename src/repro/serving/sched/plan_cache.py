"""Per-bucket-shape plan selection and compiled-step memoization
(DESIGN.md §9).

Two caches, both keyed by the bucket shape (padded batch rows, latent
length):

  * **plan cache** — ``plan_hybrid`` candidates scored with the analytical
    comm model (``core.comm_model.plan_step_latency``) for THAT shape's
    workload; the TAS/Torus placement inside each candidate's SP sub-mesh
    is the planner's own (§4.2 rules are untouched).  For pipelined plans
    the patch count is co-selected: more patches shrink the fill bubble
    but must divide the latent length.
  * **step cache** — whatever the engine compiles for a shape (a jitted
    step function or a warm/displaced pair) is memoized with hit/miss
    counters, so bucket switches never re-trace: one trace per bucket
    shape, observable via ``traces``.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

from ...core.comm_model import LayerWorkload, NetworkModel, plan_step_latency
from ...core.planner import HybridPlan, candidate_hybrid_plans
from ..metrics import Tracker


class PlanChoice(NamedTuple):
    """The selected execution plan for one bucket shape."""

    hplan: HybridPlan
    num_patches: int  # 0 = not pipelined
    pred: dict  # comm-model breakdown for the chosen (plan, patches)
    t_step: float  # predicted seconds per sampler step
    t_batch: float  # t_step * num_steps — the admission policy's latency


class PlanCache:
    def __init__(self, *, n_machines: int = 1, m_per_machine: int = 1,
                 heads: int, head_dim: int, n_layers: int,
                 kv_heads: int | None = None, num_steps: int = 20,
                 guided: bool = True, guidance_branches: int = 2,
                 dp: int = 1, net: NetworkModel | None = None,
                 candidates: list[HybridPlan] | None = None,
                 base_patches: int = 0,
                 patch_multipliers: tuple[int, ...] = (1, 2, 4),
                 comm_backend: str = "xla",
                 a2a_wire_dtype: str | None = None,
                 tracker: Tracker | None = None):
        """``candidates`` fixes the plan set (the engine passes the single
        plan its mesh can execute; the benchmark passes None to enumerate
        every feasible (cfg, pp) split).  ``base_patches`` > 0 enables
        patch-count co-selection even for pp = 1 plans (single-stage
        displaced pipelining).  ``comm_backend`` is the channel lowering
        the engine will execute with ("pallas" = kernel-fused, DESIGN.md
        §8.1); candidate plans are scored under it, so the fused path's
        lower per-step issue cost is what the selection sees.  When the
        enumeration runs here (``candidates is None``) it includes the
        hierarchical-a2a variants of every qualifying multi-machine
        factorisation (DESIGN.md §8.2), scored per leg, so the cache
        chooses flat vs hierarchical per bucket shape;
        ``a2a_wire_dtype`` additionally opts the enumeration into the
        fp8-wire variants.
        ``tracker`` is the metrics sink hit/miss/invalidation counters are
        published to (DESIGN.md §11); None = a private aggregate-only
        ``Tracker`` so the counter attributes keep working standalone."""
        self.net = net or NetworkModel()
        self.heads = heads
        self.head_dim = head_dim
        self.kv_heads = kv_heads
        self.n_layers = n_layers
        self.num_steps = num_steps
        self.guided = guided
        self.guidance_branches = guidance_branches
        self.dp = max(dp, 1)
        self.base_patches = base_patches
        self.patch_multipliers = patch_multipliers
        self.comm_backend = comm_backend
        if candidates is None:
            candidates = candidate_hybrid_plans(
                n_machines, m_per_machine, heads, kv_heads, n_layers=n_layers,
                cfg_degree=max(guidance_branches, 2),
                comm_backend=comm_backend,
                a2a_wire_dtype=a2a_wire_dtype)
        self.candidates = list(candidates)
        assert self.candidates, "plan cache needs at least one candidate"
        self.plans: dict[tuple[int, int], PlanChoice] = {}
        self._steps: dict[tuple[int, int], Any] = {}
        # all counters live in the tracker (DESIGN.md §11); the legacy
        # names (hits/misses/plan_hits/plan_misses/invalidations) remain
        # as thin reads below.  Plan-score counters are separate from the
        # compiled-step ones: a recalibration invalidates SCORES
        # (plan_misses grow again) but never compiled steps.
        self.tracker = tracker if tracker is not None else Tracker()

    # -- tracker-backed counters (legacy attribute surface) ---------------
    # emissions are tagged per bucket shape; the legacy attributes are the
    # totals over every shape (counter_total), so no public API moved
    @property
    def hits(self) -> int:
        return int(self.tracker.counter_total("plan_cache.step_hit"))

    @property
    def misses(self) -> int:
        return int(self.tracker.counter_total("plan_cache.step_miss"))

    @property
    def plan_hits(self) -> int:
        return int(self.tracker.counter_total("plan_cache.plan_hit"))

    @property
    def plan_misses(self) -> int:
        return int(self.tracker.counter_total("plan_cache.plan_miss"))

    @property
    def invalidations(self) -> int:
        return int(self.tracker.counter("plan_cache.invalidation"))

    # -- plan selection ---------------------------------------------------
    def _patch_options(self, hplan: HybridPlan, seq: int) -> list[int]:
        base = hplan.pp if hplan.pp > 1 else self.base_patches
        if base <= 0:
            return [0]
        opts = sorted({base * m for m in self.patch_multipliers
                       if base * m <= seq and seq % (base * m) == 0})
        return opts or [base]

    def select(self, batch_rows: int, seq: int) -> PlanChoice:
        """Best (plan, patch count) for a bucket shape, memoized.

        ``batch_rows`` is the padded global batch; the scored workload is
        the per-replica slice (batch_rows / dp) each plan actually runs.
        """
        key = (batch_rows, seq)
        cached = self.plans.get(key)
        if cached is not None:
            self.tracker.count("plan_cache.plan_hit",
                               tags={"rows": batch_rows, "seq": seq})
            return cached
        self.tracker.count("plan_cache.plan_miss",
                           tags={"rows": batch_rows, "seq": seq})
        wl = LayerWorkload(batch=max(batch_rows // self.dp, 1), seq=seq,
                           heads=self.heads, head_dim=self.head_dim)
        best: PlanChoice | None = None
        for h in self.candidates:
            for np_ in self._patch_options(h, seq):
                pred = plan_step_latency(
                    h, wl, self.net, n_layers=self.n_layers,
                    guided=self.guided,
                    guidance_branches=self.guidance_branches,
                    num_patches=np_ or None, num_steps=self.num_steps,
                    comm_backend=self.comm_backend)
                t = pred["t_step"]
                if best is None or t < best.t_step:
                    best = PlanChoice(h, np_, pred, t, t * self.num_steps)
        assert best is not None
        self.plans[key] = best
        # the selection itself is telemetry: after a recalibration the
        # re-scored per-shape prediction shows up as a new gauge sample
        self.tracker.log("plan_cache.t_step_pred_s", best.t_step,
                         tags={"rows": batch_rows, "seq": seq,
                               "patches": best.num_patches})
        return best

    def recalibrate(self, net: NetworkModel) -> None:
        """Swap in a refitted NetworkModel and invalidate every cached
        plan SCORE (DESIGN.md §10): the next ``select`` per bucket shape
        re-scores candidates under the new model.  Compiled steps are NOT
        touched — a latency re-estimate never costs a retrace; only the
        patch-count/plan choice and the admission policy's predicted
        latencies move."""
        self.net = net
        self.plans.clear()
        self.tracker.count("plan_cache.invalidation")

    # -- compiled-step memoization ---------------------------------------
    def step_fn(self, batch_rows: int, seq: int, build: Callable[[], Any],
                variant: Any = None):
        """Return the compiled step artifact for a shape, building (and
        counting a trace) only on first use.  ``variant`` distinguishes
        compile-relevant plan attributes beyond the shape (the engine
        passes the selected patch count): after a ``recalibrate`` changes
        a bucket's plan choice, the new variant compiles lazily while the
        old one stays cached."""
        key = (batch_rows, seq) if variant is None else (batch_rows, seq,
                                                         variant)
        tags = {"rows": batch_rows, "seq": seq}
        if key in self._steps:
            self.tracker.count("plan_cache.step_hit", tags=tags)
        else:
            self.tracker.count("plan_cache.step_miss", tags=tags)
            # the build (trace + compile) is a span: bucket switches show
            # up on the host timeline as plan_cache.trace blocks, making
            # compile stalls distinguishable from slow steps (§12)
            with self.tracker.span("plan_cache.trace", tags=tags):
                self._steps[key] = build()
        return self._steps[key]

    @property
    def traces(self) -> int:
        """Distinct compilations so far — the 'one trace per bucket shape'
        acceptance metric."""
        return self.misses
