"""Unified decoder-only language model covering the dense / moe / vlm /
hybrid / ssm families.

One ``lax.scan`` over stacked layer weights; the per-layer body dispatches
on family:

  sequence mixer:  attention (dense/moe/vlm)
                   attention ∥ SSD branch, mean-combined   (hymba)
                   RWKV6 time-mix                           (rwkv6)
  channel mixer :  MLP | MoE (+shared experts / dense residual) |
                   RWKV6 channel-mix

Decode mode threads per-layer caches through the scan:
  attention: (k_cache, v_cache) sharded over SP axes on the seq dim
  rwkv6    : (shift_tm, shift_cm, wkv state)
  hymba    : attention caches + SSD state
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import shard_map

from ..configs.base import ModelConfig
from . import ssm
from .blocks import (
    ParallelContext,
    ParamBuilder,
    Params,
    attention,
    init_attention,
    init_linear,
    init_mlp,
    init_norm,
    linear,
    mlp,
    norm,
    stack_layers,
)
from .moe import init_moe, moe_block, padded_n_experts

GLOBAL_WINDOW = 1 << 30  # "window" value meaning full/global attention


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key: jax.Array, cfg: ModelConfig, ep_degree: int) -> tuple[Params, Params]:
    b = ParamBuilder(key, dtype=jnp.dtype(cfg.dtype))
    if cfg.family == "ssm":  # rwkv6
        _init_rwkv_layer(b, cfg)
        return b.params, b.axes
    init_norm(b, "ln_attn", cfg.d_model, cfg.norm)
    init_attention(b, cfg)
    if cfg.family == "hybrid":
        _init_ssd_branch(b, cfg)
    init_norm(b, "ln_mlp", cfg.d_model, cfg.norm)
    if cfg.family == "moe":
        init_moe(b, cfg, n_pad_experts=padded_n_experts(cfg, ep_degree) - cfg.moe.n_experts)
        if cfg.moe.n_shared_experts:
            init_mlp(b, cfg, prefix="shared_mlp",
                     d_ff=cfg.moe.moe_d_ff * cfg.moe.n_shared_experts)
        if cfg.moe.dense_residual:
            init_mlp(b, cfg, prefix="dense_mlp", d_ff=cfg.d_ff)
    else:
        init_mlp(b, cfg)
    return b.params, b.axes


def _init_rwkv_layer(b: ParamBuilder, cfg: ModelConfig) -> None:
    d = cfg.d_model
    h = cfg.ssm.n_ssm_heads
    n = d // h
    init_norm(b, "ln_tm", d, cfg.norm)
    init_norm(b, "ln_cm", d, cfg.norm)
    for name in ("r", "k", "v", "g"):
        b.add(f"tm/mu_{name}", (d,), ("embed_norm",), init="zeros")
        init_linear(b, f"tm/w{name}", d, d, ("embed", "heads_flat"))
    b.add("tm/mu_w", (d,), ("embed_norm",), init="zeros")
    b.add("tm/w0", (d,), ("embed_norm",), init="zeros")
    lora = max(32, d // 32)
    init_linear(b, "tm/wlora_a", d, lora, ("embed", None))
    init_linear(b, "tm/wlora_b", lora, d, (None, "embed"), init="zeros")
    b.add("tm/u", (h, n), ("ssm_heads", None), init="zeros")
    b.add("tm/gn_scale", (d,), ("embed_norm",), init="ones")
    init_linear(b, "tm/wo", d, d, ("heads_flat", "embed"),
                scale=d ** -0.5 / (2 * cfg.n_layers) ** 0.5)
    # channel mix
    b.add("cm/mu_k", (d,), ("embed_norm",), init="zeros")
    b.add("cm/mu_r", (d,), ("embed_norm",), init="zeros")
    init_linear(b, "cm/wk", d, cfg.d_ff, ("embed", "mlp"))
    init_linear(b, "cm/wv", cfg.d_ff, d, ("mlp", "embed"),
                scale=cfg.d_ff ** -0.5 / (2 * cfg.n_layers) ** 0.5)
    init_linear(b, "cm/wr", d, d, ("embed", "embed_out"))


def _init_ssd_branch(b: ParamBuilder, cfg: ModelConfig) -> None:
    d = cfg.d_model
    h = cfg.ssm.n_ssm_heads
    p_ = (d * cfg.ssm.expand) // h
    n = cfg.ssm.state_size
    init_linear(b, "ssd/in_x", d, h * p_, ("embed", "heads_flat"))
    init_linear(b, "ssd/in_z", d, h * p_, ("embed", "heads_flat"))
    init_linear(b, "ssd/in_dt", d, h, ("embed", None))
    init_linear(b, "ssd/in_b", d, h * n, ("embed", None))
    init_linear(b, "ssd/in_c", d, h * n, ("embed", None))
    b.add("ssd/a_log", (h,), ("ssm_heads",), init="zeros")
    b.add("ssd/norm_scale", (h * p_,), ("embed_norm",), init="ones")
    init_linear(b, "ssd/out", h * p_, d, ("heads_flat", "embed"),
                scale=(h * p_) ** -0.5 / (2 * cfg.n_layers) ** 0.5)


def init_lm(cfg: ModelConfig, key: jax.Array, ep_degree: int = 1) -> tuple[Params, Params]:
    ke, kl, kf = jax.random.split(key, 3)
    params: Params = {}
    axes: Params = {}
    b = ParamBuilder(ke, dtype=jnp.dtype(cfg.dtype))
    if cfg.vocab:
        b.add("embed", (cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02)
        if not cfg.tie_embeddings:
            init_linear(b, "lm_head", cfg.d_model, cfg.vocab, ("embed", "vocab"))
    init_norm(b, "ln_f", cfg.d_model, cfg.norm)
    params.update(b.params)
    axes.update(b.axes)
    lp, la = stack_layers(partial(_init_layer, cfg=cfg, ep_degree=ep_degree),
                          cfg.n_layers, kl)
    params["layers"] = lp
    axes["layers"] = la
    return params, axes


# ---------------------------------------------------------------------------
# family-specific mixers
# ---------------------------------------------------------------------------

def _token_shift(x: jax.Array, ctx: ParallelContext, prev: jax.Array | None):
    """x_{t-1} with cross-device boundary handling (seq sharded over SP)."""
    if prev is not None:  # decode: prev token provided from cache
        return prev
    sp_axes = ctx.sp.sp_axes
    size = math.prod(ctx.mesh.shape[a] for a in sp_axes)

    def body(xl):
        last = xl[:, -1:]
        if size > 1:
            perm = [(i, i + 1) for i in range(size - 1)]
            recv = lax.ppermute(last, sp_axes, perm)
            rank = lax.axis_index(sp_axes)
            recv = jnp.where(rank > 0, recv, jnp.zeros_like(recv))
        else:
            recv = jnp.zeros_like(last)
        return jnp.concatenate([recv, xl[:, :-1]], axis=1)

    ba = ctx.sp.batch_axes
    fn = shard_map(
        body, mesh=ctx.mesh,
        in_specs=P(ba, sp_axes, None), out_specs=P(ba, sp_axes, None),
        check_vma=False,
    )
    return fn(x)


def _rwkv_time_mix(x, p, cfg, ctx: ParallelContext, cache):
    d = cfg.d_model
    h = cfg.ssm.n_ssm_heads
    n = d // h
    b_, l_, _ = x.shape
    prev = cache["shift_tm"] if ctx.decode else None
    xx = _token_shift(x, ctx, prev)
    mix = lambda mu: x + (xx - x) * mu
    r = linear(mix(p["mu_r"]), p["wr"]).reshape(b_, l_, h, n)
    k = linear(mix(p["mu_k"]), p["wk"]).reshape(b_, l_, h, n)
    v = linear(mix(p["mu_v"]), p["wv"]).reshape(b_, l_, h, n)
    g = jax.nn.silu(linear(mix(p["mu_g"]), p["wg"]))
    xw = mix(p["mu_w"])
    dd = jnp.einsum("bld,dr->blr", xw, p["wlora_a"]["w"].astype(x.dtype))
    dd = jnp.einsum("blr,rd->bld", jnp.tanh(dd), p["wlora_b"]["w"].astype(x.dtype))
    w = jnp.exp(-jnp.exp(p["w0"].astype(jnp.float32) + dd.astype(jnp.float32)))
    w = w.reshape(b_, l_, h, n)

    if ctx.decode:
        s = cache["wkv_state"]
        o, s_new = ssm.rwkv6_decode_step(
            r[:, 0], k[:, 0], v[:, 0], w[:, 0], p["u"], s)
        o = o[:, None]
        new_cache = {"shift_tm": x, "wkv_state": s_new}
    else:
        o = _distributed_scan_rwkv(r, k, v, w, p["u"], ctx)
        new_cache = None
    # per-head group norm
    o = o.reshape(b_, l_, h, n)
    mu = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    o = (o - mu) * jax.lax.rsqrt(var + 1e-5)
    o = o.reshape(b_, l_, d) * p["gn_scale"].astype(jnp.float32)
    o = o.astype(x.dtype) * g
    return linear(o, p["wo"]), new_cache


def _distributed_scan_rwkv(r, k, v, w, u, ctx: ParallelContext):
    sp_axes = ctx.sp.sp_axes
    size = math.prod(ctx.mesh.shape[a] for a in sp_axes)
    ba = ctx.sp.batch_axes

    def body(r, k, v, w):
        res = ssm.rwkv6_chunk_scan(r, k, v, w, u)
        s_in = ssm.distributed_state_in(res.a_dev, res.s_out, sp_axes, size)
        return ssm.rwkv6_apply_influence(res.out, res.infl, s_in)

    spec = P(ba, sp_axes, None, None)
    fn = shard_map(body, mesh=ctx.mesh, in_specs=(spec,) * 4,
                       out_specs=spec, check_vma=False)
    return fn(r, k, v, w)


# ---------------------------------------------------------------------------
# layer body + full forward
# ---------------------------------------------------------------------------

def _layer(x, lp, cfg, ctx: ParallelContext, positions, window, cache, cur_index):
    """One transformer layer.  Returns (x, aux_loss, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict[str, Any] = {}

    if cfg.family == "ssm":
        o, nc = _rwkv_time_mix(norm(x, lp["ln_tm"], cfg.norm), lp["tm"], cfg, ctx,
                               cache)
        if nc:
            new_cache.update(nc)
        x = x + o
        h_ = norm(x, lp["ln_cm"], cfg.norm)
        prev = cache["shift_cm"] if ctx.decode else None
        xx = _token_shift(h_, ctx, prev)
        if ctx.decode:
            new_cache["shift_cm"] = h_
        km = h_ + (xx - h_) * lp["cm"]["mu_k"]
        rm = h_ + (xx - h_) * lp["cm"]["mu_r"]
        kk = jnp.square(jax.nn.relu(linear(km, lp["cm"]["wk"])))
        x = x + jax.nn.sigmoid(linear(rm, lp["cm"]["wr"])) * linear(kk, lp["cm"]["wv"])
        return x, aux, new_cache

    h_ = norm(x, lp["ln_attn"], cfg.norm)
    kv_cache = (cache["k"], cache["v"]) if ctx.decode else None
    attn_out, upd_cache = attention(
        h_, lp["attn"], cfg, ctx, positions,
        window=window, kv_cache=kv_cache, cur_index=cur_index,
    )
    if ctx.decode and upd_cache is not None:
        new_cache["k"], new_cache["v"] = upd_cache

    if cfg.family == "hybrid":
        ssd_out, nc = _hymba_ssd(h_, lp["ssd"], cfg, ctx, cache)
        if nc:
            new_cache.update(nc)
        x = x + (attn_out + ssd_out) * 0.5
    else:
        x = x + attn_out

    h_ = norm(x, lp["ln_mlp"], cfg.norm)
    if cfg.family == "moe":
        y, aux = moe_block(h_, lp["moe"], cfg, ctx)
        if cfg.moe.n_shared_experts:
            y = y + mlp(h_, lp["shared_mlp"], cfg)
        if cfg.moe.dense_residual:
            y = y + mlp(h_, lp["dense_mlp"], cfg)
        x = x + y
        aux = aux * cfg.moe.router_aux_coef
    else:
        x = x + mlp(h_, lp["mlp"], cfg)
    return x, aux, new_cache


def _hymba_ssd(x, p, cfg, ctx, cache):
    """SSD branch wrapper returning (out, new_cache_or_None)."""
    h = cfg.ssm.n_ssm_heads
    d_in = cfg.d_model * cfg.ssm.expand
    p_ = d_in // h
    n = cfg.ssm.state_size
    b_, l_, _ = x.shape
    xs = linear(x, p["in_x"]).reshape(b_, l_, h, p_)
    z = jax.nn.silu(linear(x, p["in_z"]))
    dt = jax.nn.softplus(linear(x, p["in_dt"]))
    bm = linear(x, p["in_b"]).reshape(b_, l_, h, n)
    cm = linear(x, p["in_c"]).reshape(b_, l_, h, n)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    if ctx.decode:
        s = cache["ssd_state"]
        o, s_new = ssm.ssd_decode_step(xs[:, 0], dt[:, 0], bm[:, 0], cm[:, 0], a, s)
        o = o[:, None].astype(x.dtype)
        nc = {"ssd_state": s_new}
    else:
        sp_axes = ctx.sp.sp_axes
        size = math.prod(ctx.mesh.shape[ax] for ax in sp_axes)
        ba = ctx.sp.batch_axes

        def body(xs, dt, bm, cm):
            res = ssm.ssd_chunk_scan(xs, dt, bm, cm, a)
            s_in = ssm.distributed_state_in(res.a_dev, res.s_out, sp_axes, size)
            return ssm.ssd_apply_influence(res.out, res.infl, s_in)

        s4 = P(ba, sp_axes, None, None)
        s3 = P(ba, sp_axes, None)
        fn = shard_map(body, mesh=ctx.mesh, in_specs=(s4, s3, s4, s4),
                           out_specs=s4, check_vma=False)
        o = fn(xs, dt, bm, cm).astype(x.dtype)
        nc = None
    o = o.reshape(b_, l_, d_in)
    of = o.astype(jnp.float32)
    of = of * jax.lax.rsqrt(jnp.mean(of * of, axis=-1, keepdims=True) + 1e-6)
    o = (of * p["norm_scale"].astype(jnp.float32)).astype(x.dtype) * z
    return linear(o, p["out"]), nc


def _per_layer_windows(cfg: ModelConfig) -> jax.Array | None:
    """Hymba: layers {0, mid, last} global, rest sliding-window.  Other archs
    with cfg.window: uniform window.  None: fully global (no mask tensor)."""
    if cfg.family == "hybrid" and cfg.window:
        w = jnp.full((cfg.n_layers,), cfg.window, jnp.int32)
        glb = [0, cfg.n_layers // 2, cfg.n_layers - 1]
        return w.at[jnp.array(glb)].set(GLOBAL_WINDOW)
    if cfg.window:
        return jnp.full((cfg.n_layers,), cfg.window, jnp.int32)
    return None


def lm_forward(
    params: Params,
    cfg: ModelConfig,
    ctx: ParallelContext,
    *,
    tokens: jax.Array | None = None,  # [B, L] int32
    inputs_embeds: jax.Array | None = None,  # [B, L, d] (vlm stub frontend)
    positions: jax.Array | None = None,  # [B, L] or [3, B, L] (mrope)
    caches: Params | None = None,  # decode caches, stacked over layers
    cur_index: jax.Array | None = None,
    last_only: bool = False,  # prefill: logits for the final position only
) -> tuple[jax.Array, jax.Array, Params | None]:
    """Returns (logits [B, L, V] (or [B, 1, V] if last_only), aux, caches).

    ``last_only`` is the standard serving-engine optimization: a prefill
    only needs the next-token distribution, so the [B, L, V] logits
    tensor — the largest activation of the whole step — shrinks L×
    (beyond-paper, EXPERIMENTS.md §Perf)."""
    if inputs_embeds is not None:
        x = inputs_embeds
    else:
        x = params["embed"].astype(cfg.dtype)[tokens]
    b_, l_, _ = x.shape
    if positions is None:
        if ctx.decode:
            base = jnp.broadcast_to(cur_index, (b_, 1)).astype(jnp.int32)
        else:
            base = jnp.broadcast_to(jnp.arange(l_)[None], (b_, l_))
        positions = base
        if cfg.rope == "mrope":
            positions = jnp.broadcast_to(base[None], (3, b_, l_))

    windows = _per_layer_windows(cfg)

    def body(carry, xs):
        x, aux = carry
        lp = xs["params"]
        cache = xs.get("cache")
        window = xs.get("window")
        x, a, new_cache = _layer(x, lp, cfg, ctx, positions, window, cache, cur_index)
        return (x, aux + a), new_cache

    xs = {"params": params["layers"]}
    if caches is not None:
        xs["cache"] = caches
    if windows is not None:
        xs["window"] = windows
    # activation-checkpoint policy (ctx.remat) is a §Perf knob: default
    # recomputes the whole layer (incl. the SP attention schedule) in the
    # backward instead of saving ring-step internals.
    body = ctx.remat_wrap(body)
    # depth<=2 unrolls so dry-run cost probes see true per-layer cost
    # (XLA cost_analysis counts while-loop bodies once regardless of trips)
    (x, aux), new_caches = lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs,
                                    unroll=cfg.n_layers <= 2)

    if last_only:
        x = x[:, -1:]
    x = norm(x, params["ln_f"], cfg.norm)
    if cfg.vocab == 0:
        return x, aux, new_caches if caches is not None else None
    if cfg.tie_embeddings:
        logits = jnp.einsum("bld,vd->blv", x, params["embed"].astype(x.dtype))
    else:
        logits = linear(x, params["lm_head"])
    return logits, aux, new_caches if caches is not None else None


def init_lm_caches(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Params:
    """Decode caches stacked over layers (scan xs/ys structure)."""
    nl = cfg.n_layers
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    c: Params = {}
    if cfg.family == "ssm":
        h = cfg.ssm.n_ssm_heads
        n = cfg.d_model // h
        c["shift_tm"] = jnp.zeros((nl, batch, 1, cfg.d_model), dtype)
        c["shift_cm"] = jnp.zeros((nl, batch, 1, cfg.d_model), dtype)
        c["wkv_state"] = jnp.zeros((nl, batch, h, n, n), jnp.float32)
        return c
    c["k"] = jnp.zeros((nl, batch, max_len, hkv, hd), dtype)
    c["v"] = jnp.zeros((nl, batch, max_len, hkv, hd), dtype)
    if cfg.family == "hybrid":
        h = cfg.ssm.n_ssm_heads
        p_ = (cfg.d_model * cfg.ssm.expand) // h
        c["ssd_state"] = jnp.zeros((nl, batch, h, p_, cfg.ssm.state_size), jnp.float32)
    return c
