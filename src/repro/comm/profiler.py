"""Runtime span profiler for the comm runtime (DESIGN.md §12).

trace.py validates the *intended* schedule at compile time (the HLO
admits the overlap); this module measures what actually *executed*.  The
technique: while a ``profile(profiler)`` context is active during
tracing, every ``Channel.put``/``InFlight.wait`` (and the compute blocks
ring/torus attention mark) inserts ``jax.debug.callback`` ops whose
operands are cheap scalar slices of the leg's real tensors.  Each
callback therefore acquires a data dependency on the event it observes:

    issue   — depends on the put's INPUT tensors: fires once the operands
              are ready and the transfer could start.
    signal  — depends on the put's OUTPUT (the received buffer): fires
              when the DMA has delivered, i.e. the flag write.
    wait    — depends on the consumer-side ``wait(*deps)`` deps: fires
              when the receiver finished its independent compute and
              actually needs the buffer.

At runtime the callbacks fire host-side in executed-schedule order and
stamp ``time.perf_counter()``; ``lax.axis_index`` rides along so every
event knows its device coordinates, giving one timeline per device even
though the callbacks share a single host process (the CPU emulation
mesh).  Timestamps are *observations of the executed schedule*, not
in-graph barriers: the callbacks are unordered effects hanging off
values the schedule already produces, so instrumentation does not
serialize the overlap it measures (the residual host-callback cost is
why ``--profile`` is opt-in).

Exposure semantics per occurrence of a leg:

    exposed = max(0, t_signal - t_wait)

If the receiver hit its wait before the signal landed, the difference is
the stall the schedule failed to hide; if the signal beat the wait, the
leg was fully hidden.  ``emit_leg_spans`` drains paired events into
``kind="span"`` metrics records (``comm.leg`` / ``comm.compute`` /
``comm.exposed_wait``) that ``scripts/trace_report.py`` turns into
Perfetto tracks, the overlap-efficiency table, and per-term NetworkModel
residuals.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import functools
import itertools
import threading
import time
from typing import Any, Iterator, Sequence

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["CommProfiler", "LegEvent", "LegMeta", "active", "emit_leg_spans",
           "mark", "mark_compute", "profile"]


@dataclasses.dataclass(frozen=True)
class LegMeta:
    """Trace-time identity of one instrumented leg.  One put (or compute
    block) in the traced program mints one meta; a cached executable
    re-running (jit calls, fori_loop iterations) produces many runtime
    *occurrences* of the same leg, disambiguated when pairing."""

    leg: int
    kind: str  # "comm" | "compute"
    stream: str
    channel: str
    stage: int
    axes: tuple[str, ...]
    nbytes: int
    n_tensors: int
    backend: str
    intent: str  # ``overlaps`` label from the put ("" = not meant hidden)
    label: str = ""


@dataclasses.dataclass(frozen=True)
class LegEvent:
    """One runtime callback firing: leg + phase + device coords + time."""

    meta: LegMeta
    phase: str  # "issue" | "signal" | "wait" | "start" | "end"
    coords: tuple[int, ...]  # device index along meta.axes (-1 = unbound)
    t: float  # raw time.perf_counter()


class CommProfiler:
    """Thread-safe event sink the inserted callbacks append into.  The
    callbacks hold a reference to this instance, so recording works for
    the whole life of the compiled executable — the ``profile`` context
    only needs to be active while *tracing*."""

    def __init__(self):
        self.events: list[LegEvent] = []
        self._lock = threading.Lock()
        self._ids = itertools.count()

    def new_leg(self, **kw: Any) -> LegMeta:
        return LegMeta(leg=next(self._ids), **kw)

    def _record(self, meta: LegMeta, phase: str, coords, *_toks) -> None:
        # runs inside the XLA host-callback; must never raise
        t = time.perf_counter()
        try:
            cs = tuple(int(c) for c in coords)
        except Exception:
            cs = ()
        with self._lock:
            self.events.append(LegEvent(meta, phase, cs, t))

    def take(self) -> list[LegEvent]:
        """Atomically drain the recorded events."""
        with self._lock:
            evs, self.events = self.events, []
        return evs


_ACTIVE: contextvars.ContextVar[CommProfiler | None] = contextvars.ContextVar(
    "comm_profiler", default=None)


def active() -> CommProfiler | None:
    """The profiler instrumentation should insert callbacks into, if any."""
    return _ACTIVE.get()


@contextlib.contextmanager
def profile(profiler: CommProfiler) -> Iterator[CommProfiler]:
    """Enable instrumentation for any tracing done inside the context."""
    token = _ACTIVE.set(profiler)
    try:
        yield profiler
    finally:
        _ACTIVE.reset(token)


def _coords(axes: Sequence[str]) -> jax.Array:
    """Device coordinates along ``axes`` as one int32 vector; -1 where the
    axis is not bound (eager execution outside shard_map)."""
    out = []
    for a in axes:
        try:
            out.append(jnp.int32(lax.axis_index(a)))
        except Exception:
            out.append(jnp.int32(-1))
    return jnp.stack(out) if out else jnp.full((1,), -1, jnp.int32)


def mark(prof: CommProfiler, meta: LegMeta, phase: str,
         deps: Sequence[jax.Array]) -> None:
    """Insert one observation callback that fires when ``deps`` are ready.
    The callback operands are scalar slices, so the host copy is cheap and
    the graph gains no ordering constraint beyond dep-availability."""
    toks = [jnp.ravel(d)[0] for d in deps if getattr(d, "size", 0)]
    jax.debug.callback(functools.partial(prof._record, meta, phase),
                       _coords(meta.axes), *toks)


def nbytes_of(tensors: Sequence[jax.Array]) -> int:
    return sum(int(t.size) * t.dtype.itemsize for t in tensors)


def mark_compute(label: str, axes: Sequence[str],
                 start_deps: Sequence[jax.Array],
                 end_deps: Sequence[jax.Array], *, stream: str = "") -> None:
    """Bracket a compute block with start/end observations (no-op unless a
    profiler is active at trace time).  ``start`` fires when the block's
    inputs are ready — the earliest the compute *could* begin — and
    ``end`` when its outputs exist; the span is therefore an upper bound
    on the compute occupancy, which is the conservative side for overlap
    claims (a comm leg inside it genuinely had compute available)."""
    prof = active()
    if prof is None:
        return
    meta = prof.new_leg(kind="compute", stream=stream, channel=label,
                        stage=0, axes=tuple(axes),
                        nbytes=nbytes_of(end_deps),
                        n_tensors=len(end_deps), backend="", intent="",
                        label=label)
    mark(prof, meta, "start", start_deps)
    mark(prof, meta, "end", end_deps)


def _track(meta: LegMeta, coords: tuple[int, ...]) -> str:
    """Perfetto track id for one device: 'pod=0,model=3'."""
    if not coords or all(c < 0 for c in coords):
        return "dev"
    return ",".join(f"{a}={c}" for a, c in zip(meta.axes, coords))


def emit_leg_spans(profiler: CommProfiler, tracker: Any) -> int:
    """Drain the profiler and publish paired spans into ``tracker``
    (``span_event``, t_start relative to ``tracker.epoch``).  Returns the
    number of spans emitted.  Safe to call repeatedly (per batch)."""
    events = profiler.take()
    epoch = tracker.epoch

    def rel(t: float) -> float:
        # events recorded before the tracker existed clamp to its epoch
        return max(t - epoch, 0.0)

    groups: dict[tuple[int, tuple[int, ...]], list[LegEvent]] = {}
    for ev in events:
        groups.setdefault((ev.meta.leg, ev.coords), []).append(ev)
    n = 0
    for (leg, coords), evs in sorted(groups.items()):
        evs.sort(key=lambda e: e.t)
        meta = evs[0].meta
        track = _track(meta, coords)
        if meta.kind == "compute":
            occ, start = 0, None
            for ev in evs:
                if ev.phase == "start":
                    start = ev.t
                elif ev.phase == "end" and start is not None:
                    tracker.span_event(
                        "comm.compute", rel(start),
                        max(ev.t - start, 0.0),
                        tags={"label": meta.label, "stream": meta.stream,
                              "track": track, "leg": leg, "occ": occ})
                    occ, start = occ + 1, None
                    n += 1
            continue
        # comm leg: each "issue" starts a new occurrence
        occs: list[dict[str, float]] = []
        cur: dict[str, float] | None = None
        for ev in evs:
            if ev.phase == "issue":
                cur = {"issue": ev.t}
                occs.append(cur)
            elif cur is not None and ev.phase not in cur:
                cur[ev.phase] = ev.t
        for occ_i, o in enumerate(occs):
            if "signal" not in o:
                continue
            t0, t1 = o["issue"], o["signal"]
            tags: dict[str, Any] = {
                "stream": meta.stream, "channel": meta.channel,
                "stage": meta.stage, "axes": ",".join(meta.axes),
                "track": track, "leg": leg, "occ": occ_i,
                "nbytes": meta.nbytes, "tensors": meta.n_tensors,
                "backend": meta.backend, "intent": meta.intent}
            if "wait" in o:
                exposed = max(0.0, t1 - o["wait"])
                tags["exposed_s"] = exposed
                if exposed > 0:
                    tracker.span_event(
                        "comm.exposed_wait", rel(o["wait"]),
                        exposed, tags={"stream": meta.stream,
                                       "channel": meta.channel,
                                       "track": track, "leg": leg,
                                       "occ": occ_i})
                    n += 1
            tracker.span_event("comm.leg", rel(t0),
                               max(t1 - t0, 0.0), tags=tags)
            n += 1
    return n
