"""Request-scheduler subsystem (serving/sched, DESIGN.md §9): bucketer
invariants, SLA/starvation admission, plan-cache hit/miss behavior, drift
policy, and the comm-model scoring API — all host-side (no mesh needed)
except the ARServer aging test."""
import dataclasses

import jax.numpy as jnp
import pytest

from repro.core import SPConfig, candidate_hybrid_plans, plan_for_shape, plan_hybrid
from repro.core.comm_model import (
    LayerWorkload,
    NetworkModel,
    hybrid_step_latency,
    network_model_from_dict,
    plan_step_latency,
    sp_step_latency,
)
from repro.core.pipefusion import PipelineConfig
from repro.serving.sched import (
    DriftPolicy,
    PlanCache,
    RequestScheduler,
    SchedConfig,
    aged_priority,
    padded_rows,
)


@dataclasses.dataclass
class Req:
    rid: int
    seq_len: int
    submitted: float = 0.0
    sla: float | None = None
    drift_threshold: float | None = None


def make_cache(**kw):
    args = dict(n_machines=2, m_per_machine=4, heads=8, head_dim=64,
                n_layers=8, num_steps=4, dp=kw.pop("dp", 2))
    args.update(kw)
    return PlanCache(**args)


def make_sched(**kw):
    cfg = SchedConfig(max_batch=4, dp=2, starvation_age=10.0,
                      aging_rate=1.0, default_slack=100.0, defer_slack=1.0)
    cfg = dataclasses.replace(cfg, **kw)
    return RequestScheduler(make_cache(dp=cfg.dp), cfg)


# ---------------------------------------------------------------------------
# bucketer invariants
# ---------------------------------------------------------------------------

def test_batches_never_mix_buckets():
    s = make_sched()
    for i, n in enumerate([256, 512, 256, 1024, 512, 256, 1024, 256]):
        s.submit(Req(i, n), now=0.01 * i)
    seqs_seen = set()
    while s.pending:
        adm = s.next_batch(1.0, flush=True)
        assert len({r.seq_len for r in adm.requests}) == 1
        assert adm.requests[0].seq_len == adm.seq_len
        seqs_seen.add(adm.seq_len)
    assert seqs_seen == {256, 512, 1024}


def test_padding_accounting_matches_admissions():
    s = make_sched()
    for i in range(3):  # 3 requests, dp=2 -> one padded row somewhere
        s.submit(Req(i, 256), now=0.0)
    pads = 0
    while s.pending:
        adm = s.next_batch(0.0, flush=True)
        assert adm.pad_rows == padded_rows(len(adm.requests), 2)
        assert adm.batch_rows == len(adm.requests) + adm.pad_rows
        pads += adm.pad_rows
    tot = s.totals()
    assert tot.admitted == 3
    assert tot.padded_rows == pads == 1
    assert tot.padded_token_work == 256


def test_fifo_within_bucket():
    s = make_sched()
    for i in range(4):
        s.submit(Req(i, 256), now=float(i))
    adm = s.next_batch(10.0, flush=True)
    assert [r.rid for r in adm.requests] == [0, 1, 2, 3]


def test_requeue_reverses_admission_accounting():
    """A parked batch's bucket accounting (batches/admitted/pad work) is
    fully reversed on requeue and counted exactly once after eventual
    re-admission — including the dp pad rows the old code silently
    zeroed (ISSUE 9)."""
    s = make_sched()
    s.submit(Req(0, 256), now=0.0)
    adm = s.next_batch(0.0, flush=True)  # lone request: k=1 + 1 pad row
    assert adm.pad_rows == 1
    s.requeue(adm.requests, adm.pad_rows)
    tot = s.totals()
    assert (tot.batches, tot.admitted, tot.padded_rows) == (0, 0, 0)
    assert tot.padded_token_work == tot.real_token_work == 0
    again = s.next_batch(0.0, flush=True)
    assert [r.rid for r in again.requests] == [r.rid for r in adm.requests]
    tot = s.totals()
    assert (tot.batches, tot.admitted, tot.padded_rows) == (1, 1, 1)
    assert tot.padded_token_work == tot.real_token_work == 256


def test_requeue_rejects_mixed_bucket_batch():
    """Batches never mix buckets, so a multi-seq_len requeue means the
    caller broke the invariant — asserted, not silently mis-accounted."""
    s = make_sched()
    with pytest.raises(AssertionError, match="mixes buckets"):
        s.requeue([Req(0, 256), Req(1, 512)], pad_rows=1)
    with pytest.raises(AssertionError):
        s.requeue([], pad_rows=1)  # pad rows without a batch
    s.requeue([], pad_rows=0)  # empty no-op stays legal


def test_bucketer_drain_returns_global_fifo_with_age_intact():
    s = make_sched()
    for i, (n, at) in enumerate([(256, 0.3), (512, 0.1), (256, 0.2)]):
        s.submit(Req(i, n), now=at)
    out = s.drain()
    assert [r.rid for r in out] == [1, 2, 0]  # by submission time
    assert [r.submitted for r in out] == [0.1, 0.2, 0.3]  # untouched
    assert s.pending == 0 and s.drain() == []


# ---------------------------------------------------------------------------
# admission: SLA urgency, starvation bound, padded-batch deferral
# ---------------------------------------------------------------------------

def test_sla_urgency_beats_fifo_order():
    s = make_sched()
    # older best-effort bucket vs younger bucket with a tight deadline
    s.submit(Req(0, 1024), now=0.0)
    s.submit(Req(1, 1024), now=0.0)
    s.submit(Req(2, 256, sla=0.5), now=1.0)
    s.submit(Req(3, 256, sla=0.5), now=1.0)
    adm = s.next_batch(1.2, flush=True)
    assert adm.seq_len == 256  # urgent SLA wins despite younger age


def test_starvation_bound_overrides_urgency():
    s = make_sched(starvation_age=5.0)
    s.submit(Req(0, 1024), now=0.0)  # will become overdue
    s.submit(Req(1, 256, sla=0.5), now=6.0)  # urgent newcomer
    adm = s.next_batch(6.1, flush=True)
    assert adm.seq_len == 1024  # oldest bucket crossed the bound: must run
    adm = s.next_batch(6.2, flush=True)
    assert adm.seq_len == 256


def test_padded_batch_defers_until_flush_or_urgency():
    s = make_sched()
    s.submit(Req(0, 256), now=0.0)  # 1 request, dp=2 => padding needed
    assert s.next_batch(0.1, flush=False) is None  # worth waiting
    adm = s.next_batch(0.2, flush=True)  # no more arrivals: serve padded
    assert len(adm.requests) == 1 and adm.pad_rows == 1

    s2 = make_sched()
    s2.submit(Req(0, 256, sla=0.01), now=0.0)  # deadline already burning
    adm = s2.next_batch(0.1, flush=False)
    assert adm is not None and adm.pad_rows == 1  # urgency beats deferral


def test_overdue_padded_batch_admitted_without_flush():
    s = make_sched(starvation_age=2.0)
    s.submit(Req(0, 256), now=0.0)
    assert s.next_batch(0.5, flush=False) is None
    adm = s.next_batch(3.0, flush=False)  # past the bound: no more waiting
    assert adm is not None and len(adm.requests) == 1


def test_aged_priority_monotone():
    assert aged_priority(0.0, 10.0, 0.5) == pytest.approx(5.0)
    # a base-0 request overtakes base-4 after 8 units at rate 0.5
    assert aged_priority(0.0, 9.0, 0.5) > aged_priority(4.0, 0.0, 0.5)
    assert padded_rows(3, 2) == 1
    assert padded_rows(4, 2) == 0
    assert padded_rows(1, 1) == 0


# ---------------------------------------------------------------------------
# plan cache: per-shape selection + one trace per bucket shape
# ---------------------------------------------------------------------------

def test_plan_cache_selects_via_plan_hybrid_and_memoizes():
    pc = make_cache()
    c1 = pc.select(4, 256)
    c2 = pc.select(4, 256)
    assert c1 is c2 and len(pc.plans) == 1
    c3 = pc.select(4, 1024)
    assert len(pc.plans) == 2
    for c in (c1, c3):
        c.hplan.validate()
        assert c.hplan.total_devices == 8
        assert c.t_step > 0 and c.t_batch == pytest.approx(c.t_step * 4)
    # pipelined candidates must pick a patch count dividing the bucket
    if c3.hplan.pp > 1:
        assert c3.num_patches % c3.hplan.pp == 0
        assert 1024 % c3.num_patches == 0


def test_step_cache_one_trace_per_shape():
    pc = make_cache()
    calls = []

    def build_for(key):
        def build():
            calls.append(key)
            return key
        return build

    assert pc.step_fn(2, 256, build_for("a")) == "a"
    assert pc.step_fn(2, 256, build_for("a2")) == "a"  # hit: not rebuilt
    assert pc.step_fn(2, 512, build_for("b")) == "b"
    assert pc.traces == 2 and pc.hits == 1
    assert calls == ["a", "b"]


def test_fixed_candidate_cache_keeps_engine_plan():
    fixed = plan_hybrid(1, 8, 8, cfg_parallel=True, pp=2, n_layers=8)
    pc = PlanCache(heads=8, head_dim=64, n_layers=8, candidates=[fixed],
                   base_patches=2)
    for seq in (256, 1024):
        assert pc.select(2, seq).hplan is fixed


def test_plan_cache_enumeration_scores_hier_variants():
    """The cache's own enumeration must include the hierarchical-a2a
    twins of qualifying multi-machine factorisations (DESIGN.md §8.2),
    per-leg scored, with fp8 variants only on opt-in."""
    pc = make_cache(n_machines=2, m_per_machine=8, heads=16)
    assert any(h.hier_a2a for h in pc.candidates)
    assert not any(h.a2a_wire_dtype for h in pc.candidates)
    choice = pc.select(1, 256)
    assert "t_a2a_inter_step" in choice.pred  # per-leg, not single-blob
    assert "t_a2a" not in choice.pred
    fp8 = make_cache(n_machines=2, m_per_machine=8, heads=16,
                     a2a_wire_dtype="float8_e4m3fn")
    assert any(h.a2a_wire_dtype == "float8_e4m3fn" for h in fp8.candidates)


# ---------------------------------------------------------------------------
# planner per-shape entry + comm-model scoring API
# ---------------------------------------------------------------------------

def test_candidate_plans_cover_splits_and_validate():
    cands = candidate_hybrid_plans(2, 4, 8, n_layers=8)
    keys = {(h.cfg, h.pp) for h in cands}
    assert (1, 1) in keys and len(keys) > 1
    for h in cands:
        h.validate()
        assert h.total_devices == 8


def test_plan_for_shape_never_worse_than_sp_only():
    for seq in (256, 4096, 36_864):
        h, pred = plan_for_shape(2, 4, 24, seq=seq, head_dim=128,
                                 n_layers=48)
        sp_only = plan_hybrid(2, 4, 24, n_layers=48)
        wl = LayerWorkload(batch=1, seq=seq, heads=24, head_dim=128)
        base = plan_step_latency(sp_only, wl, n_layers=48)
        assert pred["t_step"] <= base["t_step"] + 1e-12


def test_plan_step_latency_dispatch_matches_direct_calls():
    wl = LayerWorkload(batch=1, seq=4096, heads=24, head_dim=128)
    sp_only = plan_hybrid(2, 4, 24, n_layers=48)
    assert plan_step_latency(sp_only, wl, n_layers=48)["t_step"] == (
        sp_step_latency(sp_only.sp, wl, n_layers=48, guided=True,
                        swift=True)["t_step"])
    hyb = plan_hybrid(2, 4, 24, cfg_parallel=True, pp=2, n_layers=48)
    assert plan_step_latency(hyb, wl, n_layers=48)["t_step"] == (
        hybrid_step_latency(hyb, wl, n_layers=48, guided=True)["t_step"])


def test_network_model_from_dict_ignores_report_keys():
    net = network_model_from_dict(
        {"inter_bw": 1.0e10, "mfu": 0.4, "fit": {"rms": 0.01}})
    assert net.inter_bw == 1.0e10 and net.mfu == 0.4
    assert net.intra_bw == NetworkModel().intra_bw


# ---------------------------------------------------------------------------
# drift policy
# ---------------------------------------------------------------------------

def test_drift_policy_threshold_triggers_resync():
    pipe = PipelineConfig(pp=2, warmup_steps=2)
    pol = DriftPolicy(threshold=0.1)
    assert pol.warm(pipe, 0, None, [None])  # warmup
    assert pol.warm(pipe, 1, None, [None])
    assert not pol.warm(pipe, 2, None, [None])  # fresh after warm step
    assert not pol.warm(pipe, 3, [0.05], [None])  # below bound
    assert pol.warm(pipe, 4, [0.2], [None])  # crossed: resync


def test_drift_policy_per_request_threshold_overrides_default():
    pipe = PipelineConfig(pp=2, warmup_steps=1)
    pol = DriftPolicy(threshold=0.5)
    # request 1 carries a tighter bound than the policy default
    assert pol.warm(pipe, 3, [0.1, 0.1], [None, 0.05])
    assert not pol.warm(pipe, 3, [0.1, 0.1], [None, 0.2])
    # no bound anywhere => never engaged; engine keeps the static schedule
    assert not DriftPolicy().engaged([None, None])
    assert DriftPolicy().engaged([None, 0.3])
    assert DriftPolicy(threshold=0.1).engaged([None, None])


def test_sampler_threshold_triggered_resync(mesh1):
    """sampler.sample with a DriftPolicy: a crossed threshold turns the
    NEXT step warm (drift is read post-step), replacing resync_every."""
    import jax

    from repro.configs import get_reduced
    from repro.models import ParallelContext, get_model
    from repro.serving import SamplerConfig, sample

    cfg = dataclasses.replace(get_reduced("flux-12b"), dtype="float32")
    bundle = get_model(cfg)
    params, _ = bundle.init(cfg, jax.random.PRNGKey(0), 1)
    # perturb: the zero-init output projection would otherwise keep the
    # latents (hence the KV drift) exactly zero
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(99), len(leaves))
    params = jax.tree.unflatten(treedef, [
        l + 0.05 * jax.random.normal(k, l.shape, l.dtype)
        for l, k in zip(leaves, keys)])
    ctx = ParallelContext(mesh1, SP, "prefill")
    cond = jnp.zeros((1, 256, cfg.d_model), jnp.float32)
    sc = SamplerConfig(num_steps=4,
                       pipeline=PipelineConfig(pp=1, num_patches=2,
                                               warmup_steps=1))

    def run(threshold):
        metrics = []
        sample(params, cfg, ctx, key=jax.random.PRNGKey(3), batch=1,
               seq_len=32, cond=cond, sc=sc, metrics=metrics,
               drift_policy=DriftPolicy(threshold=threshold))
        return metrics

    loose = run(1e9)  # never triggers: warmup only
    assert [m["warm"] for m in loose] == [True, False, False, False]
    assert loose[1]["kv_drift"] > 0.0  # displaced steps drift
    tight = run(0.0)  # any drift triggers the following step
    assert [m["warm"] for m in tight] == [True, False, True, False]
    assert tight[2]["kv_drift"] == 0.0  # the resync step is synchronous


# ---------------------------------------------------------------------------
# ARServer aging (shared starvation accounting)
# ---------------------------------------------------------------------------

SP = SPConfig(strategy="full", sp_axes=("model",), batch_axes=("data",))


@pytest.fixture(scope="module")
def ar_setup():
    import jax

    from repro.configs import get_reduced
    from repro.models import get_model

    cfg = dataclasses.replace(get_reduced("qwen2-1.5b"), dtype="float32")
    bundle = get_model(cfg)
    params, _ = bundle.init(cfg, jax.random.PRNGKey(0), 1)
    return cfg, params


def _drain_with_highpri_stream(srv, ticks: int) -> int | None:
    """Tick the server while a fresh high-priority request arrives every
    tick; return the tick at which rid 0 completed (None = starved)."""
    from repro.serving import ARRequest

    done_at = None
    for t in range(ticks):
        srv.submit(ARRequest(rid=100 + t, prompt=jnp.array([7], jnp.int32),
                             max_new_tokens=1, priority=1.0))
        srv.tick()
        if 0 in srv.results and done_at is None:
            done_at = t
    return done_at


def test_ar_server_aging_bounds_starvation(ar_setup, mesh1):
    from repro.serving import ARRequest, ARServer

    cfg, params = ar_setup
    srv = ARServer(params, cfg, mesh1, SP, batch_slots=1, max_len=16,
                   aging_rate=0.5)
    srv.submit(ARRequest(rid=0, prompt=jnp.array([3], jnp.int32),
                         max_new_tokens=1, priority=0.0))
    done_at = _drain_with_highpri_stream(srv, 12)
    # aged priority overtakes the fresh base-1.0 stream within
    # (1.0 - 0.0) / 0.5 = 2 ticks of queueing (plus service)
    assert done_at is not None and done_at <= 6, done_at


def test_ar_server_without_aging_starves(ar_setup, mesh1):
    """Contrast: aging_rate=0 reduces to raw priority order, and the
    low-priority request is bypassed indefinitely — the failure mode the
    satellite fix removes."""
    from repro.serving import ARRequest, ARServer

    cfg, params = ar_setup
    srv = ARServer(params, cfg, mesh1, SP, batch_slots=1, max_len=16,
                   aging_rate=0.0)
    srv.submit(ARRequest(rid=0, prompt=jnp.array([3], jnp.int32),
                         max_new_tokens=1, priority=0.0))
    assert _drain_with_highpri_stream(srv, 12) is None
