"""Pallas TPU kernels for the paper's compute hot-spot: FlashAttention over
multiple discontiguous Q/KV chunks with fused online-softmax merge
(Algorithm 2, Appendix B/C), plus the fused ring-step kernel that issues
its own KV forwarding DMA mid-kernel (DESIGN.md §8.1)."""
from .ops import (
    STATIC_ARGNAMES,
    flash_attention,
    flash_attention_segments,
    reset_trace_counts,
    trace_counts,
)
from .ref import flash_attention_ref
from .ring_flash import ring_flash_step
from .rwkv6_wkv import rwkv6_wkv

__all__ = ["STATIC_ARGNAMES", "flash_attention", "flash_attention_segments",
           "flash_attention_ref", "reset_trace_counts", "ring_flash_step",
           "rwkv6_wkv", "trace_counts"]
