"""Sampler step-loop instrumentation contract (serving/sampler.py):
the per-step wall clock ``t_step_s`` times the STEP, not the telemetry.

The clock stops the instant the step's outputs are ready; everything the
sink does with the sample afterwards — record construction, JSONL
serialisation, flushes — happens outside the timed region.  Pinned with
a deliberately slow tracker: if emission time leaked into ``t_step_s``,
the OnlineCalibrator would fit the sink's latency into the comm model
(PR 7 satellite fix)."""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.serving.metrics import RecordingTracker
from repro.serving.sampler import SamplerConfig, sample


class SlowTracker(RecordingTracker):
    """A sink that takes EMIT_S wall-clock per record — a stand-in for a
    JSONL sink on a slow disk or a fleet-shipping hook."""

    EMIT_S = 0.05

    def _emit(self, rec):
        time.sleep(self.EMIT_S)
        super()._emit(rec)


def _run(tracker, num_steps=4, metrics=None):
    cfg = get_reduced("flux-12b")
    sc = SamplerConfig(num_steps=num_steps)
    return sample(
        None, cfg, None, key=jax.random.PRNGKey(0), batch=1, seq_len=8,
        cond=None, sc=sc, metrics=metrics, tracker=tracker,
        # a near-instant step: any milliseconds observed are overhead
        step_fn=lambda x, cond, t: x - 0.01 * jnp.tanh(x))


def test_slow_tracker_does_not_inflate_step_clock():
    t = SlowTracker()
    metrics = []
    t0 = time.perf_counter()
    _run(t, num_steps=4, metrics=metrics)
    wall = time.perf_counter() - t0
    assert t.series("sampler.t_step_s").n == 4
    # every step emits >= 2 records through the slow sink (gauge + span),
    # so the loop really did pay the emission cost...
    assert wall >= 4 * 2 * SlowTracker.EMIT_S * 0.9
    # ...but none of it landed in the step clocks.  Step 0 additionally
    # pays one-time op compilation (which IS step work — the calibrator's
    # steady_t_step drops it the same way), so assert on the steady steps.
    for m in metrics[1:]:
        assert m["t_step_s"] < SlowTracker.EMIT_S, (
            f"step {m['step']} t_step_s {m['t_step_s']:.3f}s includes "
            "sink emission time")


def test_persistent_tracker_emits_step_spans():
    t = RecordingTracker()
    _run(t, num_steps=3)
    spans = [r for r in t.records if r.name == "sampler.step"]
    gauges = [r for r in t.records if r.name == "sampler.t_step_s"]
    assert [r.step for r in spans] == [0, 1, 2]
    # the span duration IS the step clock (one measurement, two views)
    for s, g in zip(spans, gauges):
        assert s.kind == "span" and s.value == g.value
        assert s.t_start is not None and s.t_start >= 0.0
    # spans are disjoint and ordered: step i ends before step i+1 starts
    for a, b in zip(spans, spans[1:]):
        assert a.t_start + a.value <= b.t_start + 1e-9


def test_aggregate_only_tracker_pays_no_step_sync():
    """An aggregate-only sink (not persistent) without a metrics list must
    leave the loop untimed — no per-step series appears at all."""
    from repro.serving.metrics import Tracker

    t = Tracker()
    _run(t, num_steps=2)
    assert t.series("sampler.t_step_s").n == 0


def test_metrics_list_alone_still_times():
    metrics = []
    _run(None, num_steps=2, metrics=metrics)
    assert len(metrics) == 2
    assert all(m["t_step_s"] > 0 for m in metrics)
