"""Span profiler end-to-end (DESIGN.md §12) on the 8-fake-device mesh:
a ``DiTServer`` built with ``profile=True`` streaming to a
``JsonlTracker`` serves a small queue, and the resulting span stream must
carry the whole §12 story — per-device comm legs with issue→signal
windows, compute blocks, host engine/plan-cache spans with nesting, and
a trace the report's ``--check`` gate accepts (comm overlapping compute,
Chrome JSON well-formed)."""
import dataclasses
import importlib.util
import pathlib
import sys

import jax
import pytest

from repro.configs import get_reduced
from repro.core import SPConfig
from repro.serving import (
    DiTRequest,
    DiTServer,
    JsonlTracker,
    SamplerConfig,
    read_jsonl,
)

ROOT = pathlib.Path(__file__).resolve().parent.parent.parent

_spec = importlib.util.spec_from_file_location(
    "trace_report", ROOT / "scripts" / "trace_report.py")
trace_report = importlib.util.module_from_spec(_spec)
sys.modules["trace_report"] = trace_report
_spec.loader.exec_module(trace_report)


@pytest.fixture(scope="module")
def profiled(tmp_path_factory, mesh8):
    cfg = dataclasses.replace(get_reduced("flux-12b"), dtype="float32")
    from repro.models import get_model

    bundle = get_model(cfg)
    params, axes = bundle.init(cfg, jax.random.PRNGKey(0),
                               mesh8.shape["model"])
    sp = SPConfig(strategy="swift_torus", sp_axes=("pod", "model"),
                  batch_axes=("data",))
    path = tmp_path_factory.mktemp("profile") / "trace.jsonl"
    tracker = JsonlTracker(path)
    srv = DiTServer(params, cfg, mesh8, sp,
                    sampler=SamplerConfig(num_steps=3),
                    param_axes=axes, tracker=tracker, profile=True)
    srv.submit(DiTRequest(rid=0, seq_len=64))
    srv.submit(DiTRequest(rid=1, seq_len=64))
    results = srv.serve()
    tracker.close()
    return srv, results, read_jsonl(path)  # validates every line


def _spans(records, name=None):
    return [r for r in records
            if r.kind == "span" and (name is None or r.name == name)]


def test_span_stream_schema_valid_and_complete(profiled):
    srv, results, records = profiled
    assert len(results) == 2
    legs = _spans(records, "comm.leg")
    comps = _spans(records, "comm.compute")
    steps = _spans(records, "engine.step")
    assert legs and comps and steps
    # 3 sampler steps measured per admitted batch
    assert len(steps) >= 3
    # per-device timelines: the SP sub-mesh is (pod=2, model=2) => 4
    # distinct device tracks carrying comm legs
    tracks = {r.tags["track"] for r in legs}
    assert len(tracks) == 4
    for r in legs:
        assert r.tags["nbytes"] > 0
        assert r.tags["backend"] == "xla"
        assert r.value >= 0 and r.t_start >= 0


def test_engine_step_spans_carry_model_predictions(profiled):
    _, _, records = profiled
    for r in _spans(records, "engine.step"):
        assert float(r.tags["pred_t_step_s"]) > 0
        assert float(r.tags["pred_compute_s"]) > 0
        assert r.step is not None


def test_plan_cache_trace_span_nests_under_host_timeline(profiled):
    srv, _, records = profiled
    traces = _spans(records, "plan_cache.trace")
    # one bucket shape => exactly one compile span
    assert len(traces) == srv.plan_cache.traces == 1
    (t,) = traces
    assert t.tags["seq"] == 64


def test_report_check_gate_passes(profiled, tmp_path):
    _, _, records = profiled
    spans = _spans(records)
    chrome = trace_report.chrome_trace(spans)
    assert trace_report.check_trace(spans, chrome) == []
    rows = trace_report.overlap_table(spans)
    assert rows
    # this mesh's plan is pure-Ulysses (P_r=1), so the comm legs are the
    # staged torus hops — all scheduled to hide behind attend compute
    torus = [r for r in rows if r["stream"] == "torus"]
    assert torus and all(r["intended_hidden"] for r in torus)
    res = trace_report.leg_residuals(spans, trace_report.NetworkModel(),
                                     frozenset({"pod"}))
    assert res
    step = trace_report.step_residuals(spans, trace_report.NetworkModel())
    assert step is not None and step["implied_mfu"] > 0
