"""Sharding-rule validity for every assigned arch WITHOUT compiling:
each param dim mapped to mesh axes must be divisible by their product,
for both serve and train rules, on both production mesh shapes.
"""
import math

import jax
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.models import get_model
from repro.models.sharding import _spec_of, rules_for

MESHES = {
    "pod": {"data": 16, "model": 16},
    "multipod": {"pod": 2, "data": 16, "model": 16},
}


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def _abstract_params(cfg, ep):
    bundle = get_model(cfg)
    captured = {}

    def f(key):
        params, axes = bundle.init(cfg, key, ep)
        captured["axes"] = axes
        return params

    sds = jax.eval_shape(f, jax.random.PRNGKey(0))
    return sds, captured["axes"]


@pytest.mark.parametrize("arch", ALL_ARCHS)
@pytest.mark.parametrize("mesh_name", ["pod", "multipod"])
@pytest.mark.parametrize("mode", ["serve", "train"])
def test_param_dims_divisible(arch, mesh_name, mode):
    cfg = get_config(arch)
    mesh = FakeMesh(MESHES[mesh_name])
    sds, axes = _abstract_params(cfg, mesh.shape["model"])
    rules = rules_for(cfg, mode)

    leaves_s = jax.tree.leaves(sds)
    leaves_a = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(leaves_s) == len(leaves_a)
    for s, logical in zip(leaves_s, leaves_a):
        spec = _spec_of(logical, rules, mesh)
        for dim, entry in zip(s.shape, spec):
            if entry is None:
                continue
            axes_ = (entry,) if isinstance(entry, str) else entry
            k = math.prod(mesh.shape[a] for a in axes_)
            assert dim % k == 0, (arch, mode, mesh_name, logical, s.shape, spec)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_axes_tree_mirrors_params(arch):
    """ParamBuilder guarantees the axes tree matches the params tree."""
    cfg = get_config(arch)
    sds, axes = _abstract_params(cfg, 16)
    s_paths = [p for p, _ in jax.tree_util.tree_leaves_with_path(sds)]
    a_paths = [p for p, _ in jax.tree_util.tree_leaves_with_path(
        axes, is_leaf=lambda x: isinstance(x, tuple))]
    assert s_paths == a_paths
    for (_, s), (_, a) in zip(
            jax.tree_util.tree_leaves_with_path(sds),
            jax.tree_util.tree_leaves_with_path(
                axes, is_leaf=lambda x: isinstance(x, tuple))):
        assert len(s.shape) == len(a), (s.shape, a)
