"""Flow-matching Euler sampler for DiT serving (paper Figure 1 pipeline).

One sampling step = one full DiT forward (velocity prediction) — this is
the unit the paper benchmarks ("latency of one sampling step").  The
sampler integrates x_t from t=1 (noise) to t=0 (data) with uniform Euler
steps; the toy linear VAE decode is the stubbed frontend inverse
(DESIGN.md §6).

Beyond the paper, the sampler composes two extra parallel axes with SP
(DESIGN.md §7):

  * **CFG parallelism** (``SamplerConfig.cfg_parallel``): with guidance
    enabled, the k guidance branches are stacked on the batch dim and —
    when the mesh carries ``SPConfig.cfg_axis`` — sharded across a k-way
    mesh axis, so each mesh slice runs one branch.  The branches recombine
    with a single psum-style weighted sum of the velocities
    (``v = Σ_i w_i·v_i``), the only cross-branch communication of the
    whole step.  The classic pair is k = 2 with weights ``(g, 1-g)``;
    ``cfg_weights`` generalises to negative prompts and multi-conditioning
    stacks (k > 2), with per-branch conditioning passed as a stacked
    ``[k, B, COND_TOKENS, d]`` tensor.
  * **Displaced patch pipelining** (``SamplerConfig.pipeline``): after
    ``warmup_steps`` synchronous steps, each step runs the PipeFusion
    forward (models/dit.py: ``dit_forward_displaced``) reusing
    one-step-stale KV for non-resident patches; the sampler threads the
    per-layer KVState across steps.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.pipefusion import KVState, PipelineConfig, init_kv_state, kv_drift
from ..models import ParallelContext
from ..models.dit import (
    COND_TOKENS,
    LATENT_CHANNELS,
    dit_forward,
    dit_forward_displaced,
)


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    num_steps: int = 20
    guidance_scale: float = 1.0  # >1 enables classifier-free guidance
    # Per-branch guidance weights for degree-k CFG (ROADMAP: k > 2 stacks).
    # None = classic 2-way (guidance_scale, 1 - guidance_scale).  With k > 2
    # (or a negative prompt at k = 2) every branch's conditioning must be
    # supplied explicitly as a stacked [k, B, COND_TOKENS, d] ``cond``
    # (zeros rows = unconditional branches).
    cfg_weights: tuple[float, ...] | None = None
    # hybrid parallelism (DESIGN.md §7); both compose with any SP strategy
    cfg_parallel: bool = False  # evaluate the CFG branches on the cfg axis
    pipeline: PipelineConfig | None = None  # patch-level pipelining

    @property
    def guided(self) -> bool:
        return self.guidance_scale != 1.0 or self.cfg_weights is not None

    @property
    def branch_weights(self) -> tuple[float, ...]:
        if self.cfg_weights is not None:
            return tuple(self.cfg_weights)
        return (self.guidance_scale, 1.0 - self.guidance_scale)

    @property
    def cfg_degree(self) -> int:
        return len(self.branch_weights)

    @property
    def pipelined(self) -> bool:
        return self.pipeline is not None and self.pipeline.enabled


def _cfg_recombine(v_all: jax.Array, batch: int,
                   weights: tuple[float, ...]) -> jax.Array:
    """The single cross-branch exchange: v = Σ_i w_i·v_i.

    Written as one weighted sum over the stacked branch dim (not the
    ``v_u + g (v_c - v_u)`` algebra) so with the branches sharded over the
    cfg axis it lowers to exactly one psum-sized collective of the
    velocity tensor, for any guidance degree k.
    """
    k = len(weights)
    v_br = v_all.reshape(k, batch, *v_all.shape[1:])
    w = jnp.asarray(weights, v_all.dtype).reshape(k, *([1] * (v_all.ndim)))
    return jnp.sum(w * v_br, axis=0)


def _branch_conds(cond: jax.Array, k: int) -> jax.Array:
    """Per-branch conditioning [k, B, C, d] from the user-facing ``cond``:
    stacked explicit branches, or the classic (cond, zeros) pair."""
    if cond.ndim == 4:
        assert cond.shape[0] == k, (
            f"stacked cond has {cond.shape[0]} branches, guidance degree {k}")
        return cond
    assert k == 2, (
        f"guidance degree {k} needs explicit stacked [k, B, C, d] cond")
    return jnp.stack([cond, jnp.zeros_like(cond)], axis=0)


def _stack_cfg_branches(x_t, cond, k: int):
    """[B,...] -> [kB,...]: branch i occupies rows [i·B, (i+1)·B)."""
    conds = _branch_conds(cond, k)
    return (jnp.concatenate([x_t] * k, axis=0),
            jnp.concatenate(list(conds), axis=0))


def _ctx_for(ctx: ParallelContext, sc: SamplerConfig) -> ParallelContext:
    """Drop the cfg mesh axis from the sharding specs unless this sampler
    config actually stacks the CFG pair — otherwise the un-doubled batch
    cannot be sharded over the 2-way cfg axis (shard_map divisibility)."""
    if ctx.sp.cfg_axis and not (sc.guided and sc.cfg_parallel):
        return dataclasses.replace(
            ctx, sp=dataclasses.replace(ctx.sp, cfg_axis=None))
    return ctx


def sample_step(params, cfg: ModelConfig, ctx: ParallelContext,
                x_t: jax.Array, cond: jax.Array, t: jax.Array,
                dt: jax.Array, sc: SamplerConfig) -> jax.Array:
    """One Euler step x_{t-dt} = x_t - dt * v(x_t, t)."""
    ctx = _ctx_for(ctx, sc)
    b = x_t.shape[0]
    tt = jnp.full((b,), t, jnp.float32)
    if sc.guided and sc.cfg_parallel:
        k = sc.cfg_degree
        lat_k, cond_k = _stack_cfg_branches(x_t, cond, k)
        v_all = dit_forward(params, cfg, ctx, latents=lat_k, cond=cond_k,
                            timesteps=jnp.concatenate([tt] * k))
        v = _cfg_recombine(v_all, b, sc.branch_weights)
        return x_t - dt * v.astype(x_t.dtype)
    if sc.guided and sc.cfg_weights is not None:
        # sequential general-degree guidance: one forward per branch,
        # recombined with the same weighted sum as the parallel path
        conds = _branch_conds(cond, sc.cfg_degree)
        v = None
        for w, c in zip(sc.branch_weights, conds):
            vb = dit_forward(params, cfg, ctx, latents=x_t, cond=c,
                             timesteps=tt)
            v = w * vb if v is None else v + w * vb
        return x_t - dt * v.astype(x_t.dtype)
    v = dit_forward(params, cfg, ctx, latents=x_t, cond=cond, timesteps=tt)
    if sc.guided:
        v_un = dit_forward(params, cfg, ctx, latents=x_t,
                           cond=jnp.zeros_like(cond), timesteps=tt)
        v = v_un + sc.guidance_scale * (v - v_un)
    return x_t - dt * v.astype(x_t.dtype)


# ---------------------------------------------------------------------------
# hybrid (cfg-parallel × patch-pipelined) stepping with threaded KV state
# ---------------------------------------------------------------------------

def hybrid_state_shape(cfg: ModelConfig, batch: int, seq_len: int,
                       sc: SamplerConfig) -> KVState:
    """Zero KVState matching what the hybrid steps thread (all k guidance
    branches included when cfg-parallel)."""
    b = sc.cfg_degree * batch if (sc.guided and sc.cfg_parallel) else batch
    return init_kv_state(cfg.n_layers, b, COND_TOKENS + seq_len,
                         cfg.n_kv_heads, cfg.resolved_head_dim,
                         jnp.dtype(cfg.dtype))


def hybrid_sample_step(params, cfg: ModelConfig, ctx: ParallelContext,
                       x_t: jax.Array, cond: jax.Array, t: jax.Array,
                       dt: jax.Array, sc: SamplerConfig, state: KVState,
                       *, warm: bool
                       ) -> tuple[jax.Array, KVState, dict[str, jax.Array]]:
    """One Euler step that also threads the displaced-pipeline KV state.

    ``warm`` (static): True runs the fully-synchronous forward — identical
    computation to ``sample_step``'s x-path — while capturing per-layer KV;
    False runs the PipeFusion displaced forward against ``state``.

    The third return is the per-step metrics dict: ``kv_drift`` is the
    batch-mean staleness measure ``PipelineConfig.resync_every`` bounds
    (core/pipefusion.kv_drift) and ``kv_drift_per_request`` its [B]
    per-request breakdown (guidance branches of one request folded
    together); both are 0 for warm steps.
    """
    assert sc.pipelined
    ctx = _ctx_for(ctx, sc)
    pipe = sc.pipeline
    b = x_t.shape[0]
    tt = jnp.full((b,), t, jnp.float32)
    if sc.guided and sc.cfg_parallel:
        lat_in, cond_in = _stack_cfg_branches(x_t, cond, sc.cfg_degree)
        tt_in = jnp.concatenate([tt] * sc.cfg_degree)
    elif sc.guided:
        raise NotImplementedError(
            "pipelined sampling with sequential CFG would need one KV "
            "state per branch; enable cfg_parallel (works on any mesh) "
            "instead")
    else:
        lat_in, cond_in, tt_in = x_t, cond, tt

    if warm:
        v_out, state = dit_forward(params, cfg, ctx, latents=lat_in,
                                   cond=cond_in, timesteps=tt_in,
                                   return_layer_kv=True)
        per_req = jnp.zeros((b,), jnp.float32)
    else:
        prev = state
        v_out, state = dit_forward_displaced(
            params, cfg, ctx, latents=lat_in, cond=cond_in, timesteps=tt_in,
            kv_state=state, num_patches=pipe.patches, pp=pipe.pp)
        per_req = kv_drift(prev, state, per_item=True).astype(jnp.float32)
        if sc.guided and sc.cfg_parallel:
            # branch rows of one request fold into that request's drift
            per_req = per_req.reshape(sc.cfg_degree, b).mean(axis=0)
    if sc.guided and sc.cfg_parallel:
        v = _cfg_recombine(v_out, b, sc.branch_weights)
    else:
        v = v_out
    metrics = {"kv_drift": per_req.mean(), "kv_drift_per_request": per_req}
    return x_t - dt * v.astype(x_t.dtype), state, metrics


def sample(params, cfg: ModelConfig, ctx: ParallelContext, *,
           key: jax.Array, batch: int, seq_len: int, cond: jax.Array,
           sc: SamplerConfig = SamplerConfig(),
           step_fn=None, metrics: list[dict] | None = None,
           drift_policy=None,
           drift_thresholds: list[float | None] | None = None,
           interrupt=None, tracker=None) -> jax.Array:
    """Full sampling loop; returns final latents [B, T, LATENT_CHANNELS].

    With ``sc.pipeline`` set, the loop threads the displaced-pipeline KV
    state: the first ``warmup_steps`` steps run synchronously, then
    displaced (PipeFusion) with a periodic synchronous re-sync every
    ``resync_every`` steps.  Passing a ``drift_policy`` (sched.DriftPolicy)
    replaces that static period with threshold-triggered resync: a step
    runs warm exactly when the previous step's per-request ``kv_drift``
    crossed the request's bound (``drift_thresholds``, one entry per batch
    row, None entries fall back to the policy default) — reading the drift
    on the host costs one device sync per step.  A custom ``step_fn``
    bypasses all of that.

    The loop is **step-granular** (DESIGN.md §10):

      * Passing a ``metrics`` list collects one per-step dict (``step``,
        ``warm``, ``kv_drift``, ``t_step_s``).  ``t_step_s`` is that
        step's own wall clock — the loop blocks on the step's outputs
        before stamping it, so resync (warm) steps and displaced steps
        are timed individually instead of aggregating into one number.
        This is what the online calibrator and the preemption policy
        consume; without ``metrics`` no per-step sync is paid.
      * ``interrupt``, called as ``interrupt(step_index)`` after every
        completed step, stops the loop early when it returns True and
        the current latents are returned as-is — the hook an embedding
        engine uses to park a batch between steps.
      * ``tracker`` (serving.metrics, DESIGN.md §11) publishes the same
        per-step series (``sampler.t_step_s``, ``sampler.kv_drift``) to
        a metrics sink.  A *persistent* sink (JSONL / recording) turns
        timing on by itself; an aggregate-only sink only collects what
        the ``metrics`` list already paid for.
    """
    x = jax.random.normal(key, (batch, seq_len, LATENT_CHANNELS), cfg.dtype)
    dt = 1.0 / sc.num_steps
    timed = metrics is not None or (tracker is not None
                                    and tracker.persistent)

    def stamp(i: int, outputs, extra_fn, t0: float) -> None:
        """Stop the step clock, THEN materialise extras and emit.

        ``t_step_s`` is captured the instant the step's outputs are ready:
        everything instrumentation-side — drift-float materialisation
        (``extra_fn`` is lazy), metrics appends, tracker/span emission —
        happens after the clock stops, so a slow sink cannot inflate the
        wall clocks the OnlineCalibrator fits (test_sampler.py pins this
        with a deliberately slow tracker)."""
        if not timed:
            return
        jax.block_until_ready(outputs)
        t_step = time.perf_counter() - t0
        extra = extra_fn() if callable(extra_fn) else extra_fn
        if metrics is not None:
            metrics.append({"step": i, "t_step_s": t_step, **extra})
        if tracker is not None:
            tracker.log("sampler.t_step_s", t_step, step=i,
                        tags={"warm": extra["warm"]}
                        if "warm" in extra else None)
            if "kv_drift" in extra:
                tracker.log("sampler.kv_drift", extra["kv_drift"], step=i)
            if tracker.persistent:
                tracker.span_event(
                    "sampler.step", t0 - tracker.epoch, t_step, step=i,
                    tags={"warm": extra["warm"]} if "warm" in extra
                    else None)

    if step_fn is not None:
        for i in range(sc.num_steps):
            t0 = time.perf_counter()
            x = step_fn(x, cond, 1.0 - i * dt)
            stamp(i, x, {}, t0)
            if interrupt is not None and interrupt(i):
                return x
        return x
    if not sc.pipelined:
        for i in range(sc.num_steps):
            t0 = time.perf_counter()
            x = sample_step(params, cfg, ctx, x, cond, 1.0 - i * dt, dt, sc)
            stamp(i, x, {}, t0)
            if interrupt is not None and interrupt(i):
                return x
        return x
    thresholds = drift_thresholds or [None] * batch
    use_drift = drift_policy is not None and drift_policy.engaged(thresholds)
    last_drift: list[float] | None = None
    state = hybrid_state_shape(cfg, batch, seq_len, sc)
    for i in range(sc.num_steps):
        if use_drift:
            warm = drift_policy.warm(sc.pipeline, i, last_drift, thresholds)
        else:
            warm = sc.pipeline.warm_step(i)
        t0 = time.perf_counter()
        x, state, m = hybrid_sample_step(params, cfg, ctx, x, cond,
                                         1.0 - i * dt, dt, sc, state,
                                         warm=warm)
        if timed:
            # stamp FIRST (clock stops at output-ready), then materialise
            # the drift floats lazily inside stamp — the per-step host
            # sync is still only paid when a drift bound or the metrics
            # list is configured (the PR-3 contract), and instrumentation
            # cost stays out of the timed region (satellite fix, PR 7)
            stamp(i, (x, state), lambda: {
                "warm": warm,
                "kv_drift": float(m["kv_drift"]),
                "kv_drift_per_request": [
                    float(d) for d in m["kv_drift_per_request"]],
            }, t0)
        if use_drift:
            per = m["kv_drift_per_request"]
            last_drift = [float(per[j]) for j in range(batch)]
        if interrupt is not None and interrupt(i):
            return x
    return x


def toy_vae_decode(latents: jax.Array, out_channels: int = 3,
                   patch: int = 2) -> jax.Array:
    """Stub VAE decoder: fixed linear map latent tokens -> pixel patches.
    [B, T, C] -> [B, T * patch**2, out_channels]."""
    b, t, c = latents.shape
    key = jax.random.PRNGKey(42)  # fixed decoder
    w = jax.random.normal(key, (c, patch * patch * out_channels), latents.dtype)
    px = jnp.einsum("btc,cp->btp", latents, w) / (c ** 0.5)
    return px.reshape(b, t * patch * patch, out_channels)
