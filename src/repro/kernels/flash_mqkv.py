"""Pallas TPU kernel: FlashAttention over multiple discontiguous Q/KV chunks
with a fused online-softmax merge — the TPU adaptation of the paper's
Algorithm 2 (Appendix B).

What the CUDA kernel does with warp-level mma + per-tensor binary search,
the TPU version does with MXU-aligned VMEM tiles and *position arrays*:
instead of launching one kernel per received chunk (kernel-launch overhead,
the problem Algorithm 2 solves), the caller concatenates any number of
discontiguous chunks and passes their **global positions**; padding slots
carry ``k_pos = -1`` and are masked in-kernel.  Exact causal/sliding-window
masks are computed from positions, so a chunk can sit anywhere in memory.

The Appendix-C merge is fused the same way as Algorithm 2 lines 11-15: the
kernel accepts carried-in ``(O', l, m)`` running state from previous calls
(earlier Ring/Torus steps), updates it across its KV blocks in VMEM
scratch, and divides by ``l`` only when ``finalize`` is set (FA2, eq. 3).

Grid: (batch·heads, Lq/block_q, Lk/block_k); the KV dimension is the
innermost "arbitrary" (sequential) axis, so the running (m, l, acc) state
lives in VMEM scratch across KV iterations.  GQA is handled by the k/v
index_map (kv head = q head // group) — no KV repetition in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import tpu_compiler_params

NEG_INF = float("-inf")
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _kernel(
    q_ref, k_ref, v_ref, qp_ref, kp_ref, oin_ref, lin_ref, min_ref,
    o_ref, l_ref, m_ref,
    acc_s, m_s, l_s,
    *, scale: float, causal: bool, window: int | None, finalize: bool,
    n_k: int, has_state: bool,
):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        if has_state:
            acc_s[...] = oin_ref[...].astype(jnp.float32)
            l_s[...] = lin_ref[...].astype(jnp.float32)[:, None]
            m_s[...] = min_ref[...].astype(jnp.float32)[:, None]
        else:
            acc_s[...] = jnp.zeros_like(acc_s)
            l_s[...] = jnp.zeros_like(l_s)
            m_s[...] = jnp.full_like(m_s, NEG_INF)

    q = q_ref[...].astype(jnp.float32)  # [bq, D]
    k = k_ref[...].astype(jnp.float32)  # [bk, D]
    v = v_ref[...].astype(jnp.float32)
    qp = qp_ref[...].astype(jnp.int32)[0]  # [bq]
    kp = kp_ref[...].astype(jnp.int32)[0]  # [bk]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [bq, bk]

    ok = (kp >= 0)[None, :]
    if causal:
        ok = ok & (qp[:, None] >= kp[None, :])
    if window is not None:
        ok = ok & (kp[None, :] > qp[:, None] - window)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_s[...]  # [bq, 1]
    l_prev = l_s[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    safe_m = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
    p = jnp.exp(s - safe_m)
    p = jnp.where(jnp.isneginf(s), 0.0, p)
    corr = jnp.where(jnp.isneginf(m_prev), 0.0, jnp.exp(m_prev - safe_m))
    l_s[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_s[...] = m_new
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_s[...] = acc_s[...] * corr + pv

    @pl.when(ki == n_k - 1)
    def _fin():
        acc = acc_s[...]
        l = l_s[...]
        if finalize:
            o_ref[...] = (acc / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)
        else:
            o_ref[...] = acc.astype(o_ref.dtype)
        l_ref[...] = l[:, 0].astype(l_ref.dtype)
        m_ref[...] = m_s[...][:, 0].astype(m_ref.dtype)


def flash_mqkv(
    q: jax.Array,  # [BH, Lq, D]
    k: jax.Array,  # [BHkv, Lk, D]
    v: jax.Array,
    q_pos: jax.Array,  # [Lq] int32
    k_pos: jax.Array,  # [Lk] int32, -1 = padding
    *,
    group: int = 1,  # GQA: q heads per kv head (BH = BHkv * group)
    scale: float | None = None,
    causal: bool = False,
    window: int | None = None,
    state: tuple[jax.Array, jax.Array, jax.Array] | None = None,
    finalize: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
):
    """Core pallas_call.  Lq % block_q == 0 and Lk % block_k == 0 required
    (ops.flash_attention pads).  Returns (o, l, m); o normalized iff
    ``finalize``."""
    bh, lq, d = q.shape
    bhkv, lk, _ = k.shape
    assert bh == bhkv * group, (bh, bhkv, group)
    assert lq % block_q == 0 and lk % block_k == 0, (lq, lk, block_q, block_k)
    if scale is None:
        scale = d ** -0.5
    n_q, n_k = lq // block_q, lk // block_k
    has_state = state is not None

    qp2 = q_pos.reshape(1, lq)
    kp2 = k_pos.reshape(1, lk)
    if state is None:
        # dummies (never read — has_state=False skips them); keep them tiny
        o_in = jnp.zeros((bh, block_q, d), jnp.float32)
        l_in = jnp.zeros((bh, block_q), jnp.float32)
        m_in = jnp.zeros((bh, block_q), jnp.float32)
        oin_spec = pl.BlockSpec((None, block_q, d), lambda h, qi, ki: (h, 0, 0))
        lin_spec = pl.BlockSpec((None, block_q), lambda h, qi, ki: (h, 0))
    else:
        o_in, l_in, m_in = state
        oin_spec = pl.BlockSpec((None, block_q, d), lambda h, qi, ki: (h, qi, 0))
        lin_spec = pl.BlockSpec((None, block_q), lambda h, qi, ki: (h, qi))

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        finalize=finalize, n_k=n_k, has_state=has_state,
    )
    out_shape = (
        jax.ShapeDtypeStruct((bh, lq, d), q.dtype if finalize else jnp.float32),
        jax.ShapeDtypeStruct((bh, lq), jnp.float32),
        jax.ShapeDtypeStruct((bh, lq), jnp.float32),
    )
    grid = (bh, n_q, n_k)
    o, l, m = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((None, block_k, d),
                         lambda h, qi, ki, g=group: (h // g, ki, 0)),
            pl.BlockSpec((None, block_k, d),
                         lambda h, qi, ki, g=group: (h // g, ki, 0)),
            pl.BlockSpec((1, block_q), lambda h, qi, ki: (0, qi)),
            pl.BlockSpec((1, block_k), lambda h, qi, ki: (0, ki)),
            oin_spec,
            lin_spec,
            lin_spec,
        ],
        out_specs=[
            pl.BlockSpec((None, block_q, d), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((None, block_q), lambda h, qi, ki: (h, qi)),
            pl.BlockSpec((None, block_q), lambda h, qi, ki: (h, qi)),
        ],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(pltpu,
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v, qp2, kp2, o_in, l_in, m_in)
    return o, l, m
