"""Render markdown tables for EXPERIMENTS.md from experiments/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
from collections import defaultdict

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k",
               "flux_3072", "flux_4096", "cogvideox_20s", "cogvideox_40s"]


def load(dir_: str):
    out = []
    for p in sorted(glob.glob(f"{dir_}/*.json")):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}µs"


def roofline_table(rows, mesh="pod", strategy=None):
    rows = [r for r in rows if r["mesh"] == mesh
            and (strategy is None or r["strategy"] == strategy)]
    key = lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                     if r["shape"] in SHAPE_ORDER else 99)
    lines = [
        "| arch | shape | strat | mem/dev | t_comp | t_mem | t_coll | bottleneck "
        "| useful | coll GiB/dev | inter-pod % |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=key):
        rf = r["roofline"]
        interpct = (100.0 * rf["collective_inter_pod"] / rf["collective_bytes"]
                    if rf["collective_bytes"] else 0.0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['strategy']} "
            f"| {r['memory']['total_bytes'] / 2**30:.2f}GiB "
            f"| {fmt_s(rf['t_compute'])} | {fmt_s(rf['t_memory'])} "
            f"| {fmt_s(rf['t_collective'])} | **{rf['bottleneck']}** "
            f"| {rf['useful_ratio']:.2f} "
            f"| {rf['collective_bytes'] / 2**30:.3f} | {interpct:.0f}% |")
    return "\n".join(lines)


def dryrun_table(rows):
    by = defaultdict(dict)
    for r in rows:
        by[(r["arch"], r["shape"], r["strategy"])][r["mesh"]] = r
    lines = ["| arch | shape | strat | pod(256) compile | mem/dev | "
             "multipod(512) compile | mem/dev |",
             "|---|---|---|---|---|---|---|"]
    key = lambda k: (k[0], SHAPE_ORDER.index(k[1]) if k[1] in SHAPE_ORDER else 99)
    for k in sorted(by, key=key):
        p = by[k].get("pod")
        m = by[k].get("multipod")
        f = lambda r: (f"{r['compile_s']}s | "
                       f"{r['memory']['total_bytes'] / 2**30:.2f}GiB"
                       if r else "— | —")
        lines.append(f"| {k[0]} | {k[1]} | {k[2]} | {f(p)} | {f(m)} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--table", choices=["roofline", "dryrun", "both"],
                    default="both")
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--strategy", default=None)
    args = ap.parse_args()
    rows = load(args.dir)
    if args.table in ("dryrun", "both"):
        print("### Dry-run matrix\n")
        print(dryrun_table(rows))
        print()
    if args.table in ("roofline", "both"):
        print(f"### Roofline ({args.mesh})\n")
        print(roofline_table(rows, mesh=args.mesh, strategy=args.strategy))


if __name__ == "__main__":
    main()
