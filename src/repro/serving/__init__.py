from .engine import ARRequest, ARServer, DiTRequest, DiTResult, DiTServer
from .sampler import (
    SamplerConfig,
    hybrid_sample_step,
    hybrid_state_shape,
    sample,
    sample_step,
    toy_vae_decode,
)
from .sched import (
    DriftPolicy,
    PlanCache,
    PlanChoice,
    RequestScheduler,
    SchedConfig,
)

__all__ = [
    "ARRequest",
    "ARServer",
    "DiTRequest",
    "DiTResult",
    "DiTServer",
    "DriftPolicy",
    "PlanCache",
    "PlanChoice",
    "RequestScheduler",
    "SamplerConfig",
    "SchedConfig",
    "hybrid_sample_step",
    "hybrid_state_shape",
    "sample",
    "sample_step",
    "toy_vae_decode",
]
