"""Shared helpers for the benchmark harness.

Wall-clock on this CPU container is meaningless for multi-pod TPU latency,
so each paper figure is regenerated from the calibrated analytical model
(core/comm_model.py, validated against the paper's own reported ratios in
tests/test_comm_model.py) plus *measured* single-device microbenchmarks
where the quantity is device-local (kernel parity, merge overhead).
Output contract: ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_call(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time of a jitted call, in microseconds."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.2f},{derived}"
