"""Backend parity: the same transfer programs through xla-Channels vs
pallas-Channels (interpret mode) must move identical bytes.

The Pallas backend's emulation branch (DESIGN.md §8.1) keeps the wire
move a ppermute and adds the semaphore-tracked landing kernel, so parity
is *bitwise* for pure transfers — any discrepancy is a delivery bug, not
numerics.  Tests parameterize over dtypes (fp32/bf16) and uneven shard
sizes (shapes far from any tile multiple).

Device-count note: this file runs in the outer suite (1 device under the
plain pytest invocation; 8 fake devices in CI).  Multi-hop routes only
exist with >= 8 devices, so those cases skip on single-device runs; the
always-on multidevice coverage lives in tests/multidevice/test_ring_pallas.py.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import comm
from repro.comm import pallas_backend
from repro.compat import shard_map
from repro.core.collectives import GroupLayout

N_DEV = jax.device_count()
needs8 = pytest.mark.skipif(N_DEV < 8, reason="needs 8 (fake) devices")

DTYPES = [jnp.float32, jnp.bfloat16]
UNEVEN_SHAPES = [(3, 5), (7, 3, 2), (1, 13)]  # per-shard, no tile alignment


def _mesh_sp():
    return jax.make_mesh((N_DEV,), ("sp",))


def _sharded(key, shape, dtype):
    """Global array whose leading dim shards over the full sp axis."""
    x = jax.random.normal(key, (N_DEV, *shape), jnp.float32)
    return x.astype(dtype)


def _run_program(mesh, fn, *xs):
    spec = P("sp")
    return jax.jit(shard_map(
        fn, mesh=mesh, in_specs=(spec,) * len(xs), out_specs=spec,
        check_vma=False))(*xs)


# ---------------------------------------------------------------------------
# landing kernel: the interpret-mode delivery path preserves values exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", UNEVEN_SHAPES)
def test_landing_copy_bitwise(dtype, shape):
    x = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
    y = jax.random.normal(jax.random.PRNGKey(1), shape).astype(dtype)
    ox, oy = pallas_backend.landing_copy((x, y))
    assert ox.dtype == dtype and oy.dtype == dtype
    np.testing.assert_array_equal(np.asarray(ox), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(oy), np.asarray(y))


# ---------------------------------------------------------------------------
# ring shift parity (any device count: the size-N_DEV rotation)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", UNEVEN_SHAPES)
def test_ring_shift_parity(dtype, shape):
    mesh = _mesh_sp()
    layout = GroupLayout(("sp",), 1, N_DEV, ulysses_outer=True)
    x = _sharded(jax.random.PRNGKey(2), shape, dtype)

    outs = {}
    for backend in ("xla", "pallas"):
        def body(xs, b=backend):
            return comm.ring_shift(layout, xs, backend=b,
                                   interpret=True).wait()
        outs[backend] = _run_program(mesh, body, x)
    np.testing.assert_array_equal(np.asarray(outs["xla"]),
                                  np.asarray(outs["pallas"]))


def test_ring_shift_pallas_records_semaphores():
    mesh = _mesh_sp()
    layout = GroupLayout(("sp",), 1, N_DEV, ulysses_outer=True)
    x = _sharded(jax.random.PRNGKey(3), (2, 3), jnp.float32)

    def body(xs):
        return comm.ring_shift(layout, xs, backend="pallas",
                               interpret=True).wait()

    with comm.record("shift") as tr:
        _run_program(mesh, body, x)
    assert len(tr.events) == 1 and tr.events[0].backend == "pallas"
    kinds = [e.kind for e in tr.sem_events]
    assert kinds == ["put", "signal", "wait"]
    assert comm.validate_semaphores(tr).ok


# ---------------------------------------------------------------------------
# distance-k torus hop + staged a2a parity (needs a real (P_u, P_r) torus)
# ---------------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("k", [1, 2, 3])
def test_torus_hop_parity(dtype, k):
    mesh = _mesh_sp()
    layout = GroupLayout(("sp",), 4, 2, ulysses_outer=True)
    x = _sharded(jax.random.PRNGKey(4), (3, 5), dtype)

    outs = {}
    for backend in ("xla", "pallas"):
        def body(xs, b=backend):
            return comm.torus_hop(layout, k, xs, backend=b,
                                  interpret=True).wait()
        outs[backend] = _run_program(mesh, body, x)
    np.testing.assert_array_equal(np.asarray(outs["xla"]),
                                  np.asarray(outs["pallas"]))


@needs8
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("feat", [5, 13])  # uneven non-split dims
def test_staged_a2a_parity(dtype, feat):
    mesh = _mesh_sp()
    layout = GroupLayout(("sp",), 4, 2, ulysses_outer=True)
    # split axis (per-shard axis 1) must divide by P_u = 4; others uneven
    x = _sharded(jax.random.PRNGKey(5), (4, feat), dtype)

    outs = {}
    for backend in ("xla", "pallas"):
        def body(xs, b=backend):
            return comm.staged_all_to_all(xs, layout, split_axis=1,
                                          backend=b, interpret=True)
        outs[backend] = _run_program(mesh, body, x)
    np.testing.assert_array_equal(np.asarray(outs["xla"]),
                                  np.asarray(outs["pallas"]))


@needs8
@pytest.mark.parametrize("dtype", DTYPES)
def test_staged_ungroup_parity(dtype):
    mesh = _mesh_sp()
    layout = GroupLayout(("sp",), 4, 2, ulysses_outer=True)
    x = _sharded(jax.random.PRNGKey(6), (8, 3), dtype)

    outs = {}
    for backend in ("xla", "pallas"):
        def body(xs, b=backend):
            stacked = comm.staged_all_to_all(xs, layout, split_axis=1,
                                             backend=b, interpret=True)
            return comm.staged_ungroup(stacked, layout, concat_axis=1,
                                       backend=b, interpret=True)
        outs[backend] = _run_program(mesh, body, x)
    # a2a followed by its inverse is the identity — on both backends
    np.testing.assert_array_equal(np.asarray(outs["xla"]),
                                  np.asarray(outs["pallas"]))
    np.testing.assert_array_equal(np.asarray(outs["pallas"]),
                                  np.asarray(x))


# ---------------------------------------------------------------------------
# semaphore pairing of randomly generated Stream programs (mini-hypothesis)
# ---------------------------------------------------------------------------

from hypothesis import given, settings  # noqa: E402  (shim via conftest)
from hypothesis import strategies as st  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 6), st.integers(0, 10**6), st.booleans())
def test_random_stream_program_semaphores_pair(n_stages, seed, defer_waits):
    """Any program of pallas-channel puts (waits in any order AFTER their
    put) records a valid semaphore pairing."""
    rng = random.Random(seed)
    layout = GroupLayout(("sp",), 1, N_DEV, ulysses_outer=True)
    mesh = _mesh_sp()

    def body(xs):
        stream = comm.Stream(f"rand{seed}", backend="pallas", interpret=True)
        futs, out = [], xs
        for _ in range(n_stages):
            futs.append(comm.ring_shift(
                layout, out, shift=rng.choice([1, N_DEV - 1] if N_DEV > 1
                                              else [1]),
                stream=stream))
            if not defer_waits:
                out = futs[-1].wait()
        if defer_waits:
            for f in futs:
                out = f.wait()
        return out

    with comm.record("rand") as tr:
        _run_program(mesh, body, _sharded(jax.random.PRNGKey(7), (2, 2),
                                          jnp.float32))
    assert len(tr.events) == n_stages
    rep = comm.validate_semaphores(tr)
    assert rep.ok, rep.summary()
    assert rep.puts == n_stages and rep.waits == n_stages


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10**6), st.sampled_from(["wait_first", "double_signal",
                                               "orphan_signal", "no_signal"]))
def test_malformed_semaphore_schedules_flagged(seed, defect):
    """Hand-built broken schedules must fail validation (the property the
    gate relies on: a buggy fused kernel wrapper cannot pass silently)."""
    from repro.comm.trace import ScheduleTrace, SemEvent

    tr = ScheduleTrace("broken")
    sem = f"chan.s0#{seed}"
    if defect == "wait_first":
        tr.sem_events = [SemEvent("wait", sem), SemEvent("put", sem),
                         SemEvent("signal", sem)]
    elif defect == "double_signal":
        tr.sem_events = [SemEvent("put", sem), SemEvent("signal", sem),
                         SemEvent("signal", sem), SemEvent("wait", sem)]
    elif defect == "orphan_signal":
        tr.sem_events = [SemEvent("signal", sem)]
    else:  # no_signal
        tr.sem_events = [SemEvent("put", sem), SemEvent("wait", sem)]
    assert not comm.validate_semaphores(tr).ok


def test_blocking_wait_flagged():
    """An overlap-intent put whose wait has no compute between is the
    schedule bug the fused kernel exists to avoid — must be flagged."""
    from repro.comm.trace import ScheduleTrace, SemEvent

    tr = ScheduleTrace("blocking")
    tr.sem_events = [
        SemEvent("put", "a", overlap=True), SemEvent("signal", "a"),
        SemEvent("wait", "a"), SemEvent("compute", ""),
    ]
    rep = comm.validate_semaphores(tr)
    assert not rep.ok and "blocking wait" in rep.failures[0]

    good = ScheduleTrace("overlapped")
    good.sem_events = [
        SemEvent("put", "a", overlap=True), SemEvent("signal", "a"),
        SemEvent("compute", ""), SemEvent("wait", "a"),
    ]
    assert comm.validate_semaphores(good).ok
