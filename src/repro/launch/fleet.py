"""Fleet-router launcher (DESIGN.md §13): N serving replicas — each one
mesh's PR-3/5 scheduler/control stack — behind global SLA-aware dispatch.

    PYTHONPATH=src python -m repro.launch.fleet --replicas 2 --policy warmth
    ... --policy sla --fail r0@0.35 --trace-dir /tmp/fleet
    ... --trace stream.json   (a benchmarks/sched_sweep.py --emit-trace file)

Entirely host-side on simulated time (no jax, no wall clock): the fleet
execution harness runs each admitted batch for its comm-model-predicted
duration, plus the one-time jit-trace stall the first time a replica
runs a bucket shape — the asymmetry the ``warmth`` policy exploits.
Router state is fed exclusively by folded per-replica ``metrics.v1``
tracker streams (the trace-shipping protocol); ``--trace-dir`` keeps the
per-replica JSONL traces and the router's folded trace on disk, each
independently valid under ``scripts/check_metrics_schema.py``.

``--fail RID@T`` / ``--drain RID@T`` injects a replica failure (queue
evacuated, router re-dispatch with age intact) or drain (serves out,
no new dispatch) at simulated second T; the replica revives
``--revive-after`` seconds later.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import random

from ..serving.fleet import (
    POLICIES,
    FailureEvent,
    FleetRequest,
    FleetRouter,
    Replica,
    run_fleet,
)
from ..serving.metrics import JsonlTracker


def default_stream(n: int = 120, seed: int = 7) -> list[FleetRequest]:
    """Seeded mixed-resolution stream: steady loose-SLA 1024 background
    with periodic tight-SLA 256 bursts (the sched_sweep bursty shape)."""
    rnd = random.Random(seed)
    reqs: list[FleetRequest] = []
    rid, t, next_burst = 0, 0.0, 0.02
    while rid < n:
        t += rnd.uniform(0.004, 0.012)
        if t >= next_burst:
            bt = next_burst
            for _ in range(4):
                reqs.append(FleetRequest(rid=rid, seq_len=256,
                                         arrival=round(bt, 6), sla=0.012))
                rid += 1
                bt += rnd.uniform(0.0001, 0.0004)
            next_burst += rnd.uniform(0.08, 0.12)
        reqs.append(FleetRequest(rid=rid, seq_len=1024,
                                 arrival=round(t, 6), sla=1.5))
        rid += 1
    reqs.sort(key=lambda r: (r.arrival, r.rid))
    return reqs


def load_stream(path: pathlib.Path) -> list[FleetRequest]:
    """A ``benchmarks/sched_sweep.py --emit-trace`` request trace."""
    payload = json.loads(path.read_text())
    return [FleetRequest(rid=d["rid"], seq_len=d["seq_len"],
                         arrival=d["arrival"], sla=d.get("sla"))
            for d in payload["requests"]]


def parse_event(spec: str, kind: str, revive_after: float) -> FailureEvent:
    rid, _, at = spec.partition("@")
    if not rid or not at:
        raise SystemExit(f"--{kind} wants RID@SECONDS, got {spec!r}")
    return FailureEvent(at=float(at), rid=rid, kind=kind,
                        revive_after=revive_after)


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--policy", choices=POLICIES, default="warmth")
    ap.add_argument("--requests", type=int, default=120,
                    help="length of the built-in seeded stream")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--trace", type=pathlib.Path, default=None,
                    help="replay a sched_sweep --emit-trace request file "
                         "instead of the built-in stream")
    ap.add_argument("--trace-dir", type=pathlib.Path, default=None,
                    help="write per-replica + router-folded metrics.v1 "
                         "JSONL traces here")
    ap.add_argument("--fail", default=None, metavar="RID@T",
                    help="fail a replica at simulated second T")
    ap.add_argument("--drain", default=None, metavar="RID@T",
                    help="drain a replica at simulated second T")
    ap.add_argument("--revive-after", type=float, default=0.25)
    args = ap.parse_args(argv)
    if args.fail and args.drain:
        ap.error("give --fail or --drain, not both")

    reqs = (load_stream(args.trace) if args.trace is not None
            else default_stream(args.requests, args.seed))
    failure = None
    if args.fail:
        failure = parse_event(args.fail, "fail", args.revive_after)
    elif args.drain:
        failure = parse_event(args.drain, "drain", args.revive_after)

    paths: list[pathlib.Path | None] = [None] * args.replicas
    router_trk = None
    if args.trace_dir is not None:
        args.trace_dir.mkdir(parents=True, exist_ok=True)
        paths = [args.trace_dir / f"replica-r{k}.jsonl"
                 for k in range(args.replicas)]
        router_trk = JsonlTracker(args.trace_dir / "router.jsonl")

    replicas = [Replica.sim(f"r{k}", paths[k])
                for k in range(args.replicas)]
    router = FleetRouter(replicas, policy=args.policy, tracker=router_trk)
    stats = run_fleet(reqs, router, failure=failure)
    for rep in replicas:
        if isinstance(rep.tracker, JsonlTracker):
            rep.tracker.close()
    if router_trk is not None:
        router_trk.close()

    print(f"fleet: {args.replicas} replicas, policy={args.policy}, "
          f"{len(reqs)} requests" + (f", {failure.kind}={failure.rid}"
                                     f"@{failure.at}" if failure else ""))
    for k in ("served", "batches", "sla_met", "sla_miss", "sla_met_frac",
              "makespan_s", "max_wait", "traces", "spills", "repartitions",
              "requeued"):
        v = stats[k]
        print(f"  {k:14} {v:.4f}" if isinstance(v, float) else
              f"  {k:14} {v}")
    if args.trace_dir is not None:
        print(f"  traces -> {args.trace_dir}/replica-r*.jsonl + router.jsonl")


if __name__ == "__main__":
    main()
