"""Production serving launcher: DiT sampling service or AR decode service.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.serve --arch flux-12b --reduced --requests 4
    ... --arch flux-12b --reduced --requests 6 --mixed --sla 30   (scheduler)
    ... --arch qwen2-1.5b --reduced --requests 4   (AR decode)

DiT requests go through the SLA-aware request scheduler (DESIGN.md §9):
``--mixed`` submits a mixed-resolution queue (seq, seq/2, 2*seq cycling)
so the resolution bucketer and per-bucket plan cache are exercised;
``--sla`` attaches a deadline to every request and the admission policy
scores buckets by deadline slack against the comm model's predicted
batch latency.

The adaptive control loop (DESIGN.md §10) is opt-in per feedback path:
``--preempt`` lets an SLA-critical bucket park the running batch between
sampler steps, ``--recalibrate`` refits the comm model from measured
step times in-flight, ``--forecast`` bounds padded-batch deferral with
the per-bucket arrival forecast.

``--metrics out.jsonl`` (DESIGN.md §11) attaches a ``JsonlTracker`` to
the engine: every plan-cache hit/miss, admission, per-step wall clock,
preemption, resync and recalibration streams to ``out.jsonl`` as
schema-versioned records, and an end-of-run aggregate table is printed.
A persistent sink opts the step loop into per-step timing even without
``--preempt``/``--recalibrate``.

``--profile trace.jsonl`` (DESIGN.md §12) is ``--metrics`` plus the
span-level comm-runtime profiler: per-device comm-leg and compute spans
from inside the jitted step, host-side engine/plan-cache/calibration
spans, all into the same JSONL stream.  Render it with
``scripts/trace_report.py trace.jsonl --chrome trace.json`` (Perfetto
timeline + overlap-efficiency table + comm-model residuals).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from ..configs import get_config, get_reduced
from ..core import SPConfig
from ..models import get_model
from ..serving import (
    ARRequest,
    ARServer,
    CalibrationConfig,
    ControlConfig,
    DiTRequest,
    DiTServer,
    JsonlTracker,
    PreemptionPolicy,
    SCHEMA_VERSION,
    SamplerConfig,
    Tracker,
)
from .mesh import make_host_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--strategy", default="swift_torus")
    ap.add_argument("--mesh", choices=["pod", "multipod", "host"], default="host")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--steps", type=int, default=4, help="sampling steps (DiT)")
    ap.add_argument("--mixed", action="store_true",
                    help="mixed-resolution queue (exercises the bucketer)")
    ap.add_argument("--sla", type=float, default=None,
                    help="deadline (s) attached to every DiT request")
    ap.add_argument("--preempt", action="store_true",
                    help="step-level preemption for SLA-critical buckets "
                         "(DESIGN.md §10)")
    ap.add_argument("--recalibrate", action="store_true",
                    help="refit the comm model from measured step times "
                         "in-flight (DESIGN.md §10)")
    ap.add_argument("--forecast", action="store_true",
                    help="bound padded-batch deferral with the arrival "
                         "forecaster (DESIGN.md §10; deferral applies to "
                         "dp-padded batches, so this needs --data > 1)")
    ap.add_argument("--metrics", default=None, metavar="OUT.JSONL",
                    help="stream schema-versioned metrics records to this "
                         "JSONL file and print an end-of-run aggregate "
                         "table (DESIGN.md §11)")
    ap.add_argument("--profile", default=None, metavar="TRACE.JSONL",
                    help="--metrics plus the span-level comm-runtime "
                         "profiler (DESIGN.md §12); render the trace with "
                         "scripts/trace_report.py.  DiT only.")
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()
    if args.profile is not None and args.metrics is not None:
        ap.error("--profile already streams metrics records; "
                 "give one output path, not both")

    if args.mesh == "host":
        mesh = make_host_mesh(model=args.model, data=args.data)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")
    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg, dtype="float32", sharding_overrides=())
    bundle = get_model(cfg)
    params, _ = bundle.init(cfg, jax.random.PRNGKey(0), mesh.shape["model"])

    sp_degree = mesh.shape["model"]
    sp = SPConfig(strategy=args.strategy if sp_degree > 1 else "full",
                  sp_axes=("model",), batch_axes=("data",))

    sink = args.profile if args.profile is not None else args.metrics
    tracker = JsonlTracker(sink) if sink is not None else Tracker()
    if args.profile is not None and cfg.family != "dit":
        ap.error("--profile instruments the DiT step loop; "
                 "use a dit --arch")
    if cfg.family == "dit":
        control = ControlConfig(
            preemption=PreemptionPolicy() if args.preempt else None,
            calibration=CalibrationConfig() if args.recalibrate else None,
            forecast=args.forecast)
        srv = DiTServer(params, cfg, mesh, sp,
                        sampler=SamplerConfig(num_steps=args.steps),
                        control=control, tracker=tracker,
                        profile=args.profile is not None)
        lens = ([args.seq, args.seq // 2, args.seq * 2] if args.mixed
                else [args.seq])
        for i in range(args.requests):
            srv.submit(DiTRequest(rid=i, seq_len=lens[i % len(lens)],
                                  sla=args.sla))
        for r in sorted(srv.serve(), key=lambda r: r.rid):
            print(f"request {r.rid}: latents {tuple(r.latents.shape)} "
                  f"latency {r.latency * 1e3:.1f} ms"
                  + ("" if r.sla_met else "  SLA MISSED"))
        tot = srv.scheduler.totals()
        print(f"scheduler: {tot.batches} batches over "
              f"{len(srv.plan_cache.plans)} bucket shapes "
              f"({srv.plan_cache.traces} traces, {srv.plan_cache.hits} "
              f"step-cache hits), {tot.padded_rows} padded rows, "
              f"max wait {tot.max_wait * 1e3:.1f} ms")
        if control.engaged:
            cal = srv.calibrator
            print(f"control: {srv.preemptions} preemptions "
                  f"({srv.scheduler.preempted} requests requeued)"
                  + (f", {cal.refits} refits / {cal.recalibrations} "
                     f"recalibrations ({srv.plan_cache.invalidations} "
                     f"plan-score invalidations)" if cal else ""))
    else:
        srv = ARServer(params, cfg, mesh, sp, batch_slots=4,
                       max_len=args.seq, tracker=tracker)
        for i in range(args.requests):
            srv.submit(ARRequest(rid=i,
                                 prompt=jnp.arange(1, 4 + i, dtype=jnp.int32),
                                 max_new_tokens=8))
        for rid, toks in sorted(srv.serve().items()):
            print(f"request {rid}: -> {toks}")
    if sink is not None:
        tracker.close()
        print(f"\nmetrics: wrote {tracker.path} (schema {SCHEMA_VERSION})")
        print(tracker.format_summary())
        if args.profile is not None:
            print(f"profile: render with scripts/trace_report.py "
                  f"{tracker.path} --chrome {tracker.path}.chrome.json")


if __name__ == "__main__":
    main()
