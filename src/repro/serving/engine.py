"""Serving engines.

DiTServer — the paper's scenario: requests ask for an image/video at a
given latent sequence length; compatible requests (same length) are
batched, the flow-matching sampler runs with the configured SP strategy,
and results stream back.  One jitted step per (batch, seq) bucket.

ARServer — autoregressive decode for the LM-family assigned archs:
slot-based continuous batching (fixed B decode slots; prefill on admit;
every engine tick advances all active slots one token through the
sequence-sharded KV cache).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core import SPConfig
from ..models import ParallelContext, get_model, param_shardings
from ..models.dit import COND_TOKENS
from .sampler import (
    SamplerConfig,
    hybrid_sample_step,
    hybrid_state_shape,
    sample_step,
)


# ---------------------------------------------------------------------------
# DiT serving (paper §5 workloads)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DiTRequest:
    rid: int
    seq_len: int  # latent tokens (resolution / duration proxy)
    cond: jax.Array | None = None  # [COND_TOKENS, d] text embedding (stub)
    submitted: float = 0.0


@dataclasses.dataclass
class DiTResult:
    rid: int
    latents: jax.Array
    latency: float
    sampling_steps: int
    # per-step KV staleness trajectory of the displaced pipeline (empty for
    # non-pipelined sampling); see core/pipefusion.kv_drift
    kv_drift: list[float] = dataclasses.field(default_factory=list)


class DiTServer:
    """Batched DiT sampling over the hybrid-parallel mesh (DESIGN.md §7).

    Beyond plain SP the server drives two optional extra axes:
      * ``sampler.cfg_parallel`` — the CFG pair is evaluated on the
        ``sp.cfg_axis`` halves of the mesh (one psum-style recombine per
        step).
      * ``sampler.pipeline`` — displaced patch pipelining: the server jits
        warm/displaced step variants per (batch, seq) bucket and threads
        the per-layer stale-KV state across the sampling loop.  When the
        mesh carries ``sp.pp_axis`` and ``param_axes`` is given, the
        stacked DiT block weights are sharded over the pipe axis, so each
        stage holds n_layers / pp blocks.
    """

    def __init__(self, params, cfg: ModelConfig, mesh, sp: SPConfig,
                 sampler: SamplerConfig = SamplerConfig(),
                 max_batch: int = 4, param_axes=None):
        self.params = params
        self.cfg = cfg
        self.ctx = ParallelContext(mesh, sp, "prefill")
        self.sampler = sampler
        self.max_batch = max_batch
        self.queue: deque[DiTRequest] = deque()
        # plain sampling caches one jitted step; pipelined sampling caches a
        # (warm, displaced) pair
        self._step_cache: dict[
            tuple[int, int], Callable | tuple[Callable, Callable]] = {}
        self._rng = jax.random.PRNGKey(0)
        if (sampler.pipelined and sp.pp_axis
                and sp.pp_axis in mesh.axis_names and param_axes is not None):
            # stage partitioning: each pipe rank holds its n_layers/pp blocks
            sh = param_shardings(param_axes, cfg, mesh, "serve",
                                 extra_rules={"layers": (sp.pp_axis,)})
            self.params = jax.device_put(params, sh)

    def submit(self, req: DiTRequest) -> None:
        req.submitted = time.time()
        self.queue.append(req)

    def _step_fn(self, batch: int, seq: int) -> Callable:
        key = (batch, seq)
        if key not in self._step_cache:
            dt = 1.0 / self.sampler.num_steps

            if self.sampler.pipelined:
                def warm(params, x, cond, t, state):
                    return hybrid_sample_step(params, self.cfg, self.ctx, x,
                                              cond, t, dt, self.sampler,
                                              state, warm=True)

                def displaced(params, x, cond, t, state):
                    return hybrid_sample_step(params, self.cfg, self.ctx, x,
                                              cond, t, dt, self.sampler,
                                              state, warm=False)

                # donate the threaded KV state (arg 4): the caller discards
                # the old state each step, so XLA may update it in place
                # instead of allocating a second full-size KV buffer
                self._step_cache[key] = (jax.jit(warm, donate_argnums=(4,)),
                                         jax.jit(displaced,
                                                 donate_argnums=(4,)))
            else:
                def f(params, x, cond, t):
                    return sample_step(params, self.cfg, self.ctx, x, cond, t,
                                       dt, self.sampler)

                self._step_cache[key] = jax.jit(f)
        return self._step_cache[key]

    def _next_batch(self) -> list[DiTRequest]:
        """Greedy same-length batching (SP requires uniform seq per batch)."""
        if not self.queue:
            return []
        head = self.queue[0]
        batch, rest = [], deque()
        while self.queue and len(batch) < self.max_batch:
            r = self.queue.popleft()
            (batch if r.seq_len == head.seq_len else rest).append(r)
        while rest:
            self.queue.appendleft(rest.pop())
        return batch

    def _dp_degree(self) -> int:
        import math
        ba = self.ctx.sp.batch_axes or ()
        return math.prod(self.ctx.mesh.shape[a] for a in ba)

    def run_once(self) -> list[DiTResult]:
        batch = self._next_batch()
        if not batch:
            return []
        # pad the batch up to a multiple of the data-parallel degree (SPMD
        # batch sharding requires divisibility); padded rows are dropped.
        dp = self._dp_degree()
        n_real = len(batch)
        b = -(-n_real // dp) * dp
        t = batch[0].seq_len
        d = self.cfg.d_model
        cond = jnp.stack([
            (batch[i].cond if i < n_real and batch[i].cond is not None
             else jnp.zeros((COND_TOKENS, d), self.cfg.dtype))
            for i in range(b)
        ])
        self._rng, sub = jax.random.split(self._rng)
        x = jax.random.normal(sub, (b, t, 64), self.cfg.dtype)
        fn = self._step_fn(b, t)
        dt = 1.0 / self.sampler.num_steps
        drift_vals = []
        if self.sampler.pipelined:
            warm_fn, displaced_fn = fn
            state = hybrid_state_shape(self.cfg, b, t, self.sampler)
            for i in range(self.sampler.num_steps):
                f = (warm_fn if self.sampler.pipeline.warm_step(i)
                     else displaced_fn)
                x, state, m = f(self.params, x, cond,
                                jnp.float32(1.0 - i * dt), state)
                # device [B] vector: no host sync inside the timed loop
                drift_vals.append(m["kv_drift_per_request"])
        else:
            for i in range(self.sampler.num_steps):
                x = fn(self.params, x, cond, jnp.float32(1.0 - i * dt))
        x.block_until_ready()
        now = time.time()
        # materialise after the timed region; row i is request i's own
        # trajectory (padded rows are never handed to a request)
        drifts = [[float(v[i]) for v in drift_vals] for i in range(n_real)]
        return [
            DiTResult(r.rid, x[i], now - r.submitted, self.sampler.num_steps,
                      kv_drift=drifts[i] if drift_vals else [])
            for i, r in enumerate(batch)
        ]

    def serve(self) -> list[DiTResult]:
        out = []
        while self.queue:
            out.extend(self.run_once())
        return out


# ---------------------------------------------------------------------------
# AR decode serving (assigned LM archs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ARRequest:
    rid: int
    prompt: jax.Array  # [L_prompt] int32
    max_new_tokens: int = 16


@dataclasses.dataclass
class Slot:
    req: ARRequest | None = None
    pos: int = 0  # next cache index to write
    generated: list[int] = dataclasses.field(default_factory=list)


class ARServer:
    """Fixed-slot continuous batching over a sequence-sharded KV cache.

    Prefill is implemented as teacher-forced decode of the prompt (one
    engine, one cache layout — adequate for the assigned decode shapes;
    a chunked-prefill path is a straightforward extension).
    """

    def __init__(self, params, cfg: ModelConfig, mesh, sp: SPConfig,
                 batch_slots: int = 4, max_len: int = 256,
                 cache_dtype=jnp.float32):
        self.params = params
        self.cfg = cfg
        self.ctx = ParallelContext(mesh, sp, "decode")
        self.bundle = get_model(cfg)
        self.slots = [Slot() for _ in range(batch_slots)]
        self.max_len = max_len
        self.caches = self.bundle.init_caches(cfg, batch_slots, max_len, cache_dtype)
        self.queue: deque[ARRequest] = deque()
        self.results: dict[int, list[int]] = {}

        def step(params, caches, tokens, cur_index):
            batch = {"tokens": tokens}
            logits, caches = self.bundle.step(params, batch, caches,
                                              cur_index, cfg, self.ctx)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

        self._step = jax.jit(step)

    def submit(self, req: ARRequest) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for s in self.slots:
            if s.req is None and self.queue:
                s.req = self.queue.popleft()
                s.pos = 0
                s.generated = []

    def tick(self) -> None:
        """Advance every active slot one position.

        All slots share one cur_index per tick in this reference engine;
        requests are aligned at admission (pos 0).  Slots therefore run in
        lockstep — the standard static-batching baseline."""
        self._admit()
        active = [s for s in self.slots if s.req is not None]
        if not active:
            return
        pos = active[0].pos
        tokens = []
        for s in self.slots:
            if s.req is None:
                tokens.append(0)
            elif s.pos < len(s.req.prompt):
                tokens.append(int(s.req.prompt[s.pos]))
            else:
                tokens.append(s.generated[-1] if s.generated else 0)
        tok = jnp.asarray(tokens, jnp.int32)[:, None]
        nxt, self.caches = self._step(self.params, self.caches, tok,
                                      jnp.int32(pos))
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            s.pos += 1
            if s.pos >= len(s.req.prompt):
                s.generated.append(int(nxt[i]))
            if (len(s.generated) >= s.req.max_new_tokens
                    or s.pos >= self.max_len - 1):
                self.results[s.req.rid] = list(s.generated)
                s.req = None

    def serve(self, max_ticks: int = 10_000) -> dict[int, list[int]]:
        t = 0
        while (self.queue or any(s.req for s in self.slots)) and t < max_ticks:
            self.tick()
            t += 1
        return self.results
