"""On-wire compression for the inter-machine a2a leg (DESIGN.md §8.2).

CoCoDiff-style bf16→fp8 wire compression: the slow leg of the
hierarchical all-to-all quantises each payload to ``float8_e4m3fn``
with a per-tensor absmax scale, ships (wire, scale) through the same
channel put, and dequantises on arrival — halving the inter-machine
bytes at the cost of one rounding per traversal.  The intra-machine
leg is never compressed (NVLink bandwidth makes the codec a pure loss
there), which is why the codec lives behind the ``wire_dtype`` knob of
the *hierarchical* programs only.

Error feedback (``ef_encode``): diffusion sampling sends the same
activation family every step, so quantisation error is not white — it
biases the trajectory.  The standard fix from gradient-compression
(1-bit Adam lineage) is to carry the residual: encode ``x + err`` and
keep ``err' = (x + err) - decode(encode(x + err))`` for the next step,
which turns the bias into a bounded moving residual.  The buffers are
per-call-site state the caller threads across steps (``zero_feedback``
builds the initial pytree).

Quantisation is a pure element-wise codec: it never changes routing, so
the hierarchical schedule's trace/validation story is identical with and
without compression.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["WIRE_DTYPES", "has_wire_dtype", "quantize", "dequantize",
           "ef_encode", "zero_feedback"]

# wire dtypes the codec knows how to produce; fp8 availability depends on
# the jax/ml_dtypes build, so resolve lazily and gate with has_wire_dtype.
WIRE_DTYPES = ("float8_e4m3fn", "float8_e5m2")


def _resolve(wire_dtype: str):
    if wire_dtype not in WIRE_DTYPES:
        raise ValueError(f"unknown wire dtype {wire_dtype!r}; "
                         f"known: {WIRE_DTYPES}")
    dt = getattr(jnp, wire_dtype, None)
    if dt is None:
        raise ValueError(
            f"wire dtype {wire_dtype!r} not available in this jax build")
    return dt


def has_wire_dtype(wire_dtype: str) -> bool:
    """True when this jax build can represent ``wire_dtype`` on the wire."""
    try:
        _resolve(wire_dtype)
        return True
    except ValueError:
        return False


def _amax_scale(x: jax.Array, dt) -> jax.Array:
    # absmax scaling to the wire format's finite range; the guard keeps
    # all-zero payloads (padding chunks) exactly representable.
    fmax = float(jnp.finfo(dt).max)
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    return jnp.maximum(amax / fmax, jnp.float32(1e-30))


def quantize(x: jax.Array, wire_dtype: str) -> tuple[jax.Array, jax.Array]:
    """Encode ``x`` for the wire: (payload in ``wire_dtype``, fp32 scale).

    The scale is a scalar rider tensor shipped through the same put (its
    bytes are noise next to the payload)."""
    dt = _resolve(wire_dtype)
    scale = _amax_scale(x, dt)
    wire = (x.astype(jnp.float32) / scale).astype(dt)
    return wire, scale


def dequantize(wire: jax.Array, scale: jax.Array,
               out_dtype: jnp.dtype) -> jax.Array:
    """Decode a wire payload back to the compute dtype."""
    return (wire.astype(jnp.float32) * scale).astype(out_dtype)


def ef_encode(x: jax.Array, err: jax.Array, wire_dtype: str
              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback encode: quantise ``x + err`` and return
    (wire, scale, err') with ``err'`` the residual the caller carries to
    the next step.  ``err`` is fp32 (residuals are below bf16 resolution
    by construction — that is what makes them worth keeping)."""
    dt = _resolve(wire_dtype)
    target = x.astype(jnp.float32) + err
    scale = _amax_scale(target, dt)
    wire = (target / scale).astype(dt)
    new_err = target - wire.astype(jnp.float32) * scale
    return wire, scale, new_err


def zero_feedback(x: jax.Array) -> jax.Array:
    """Initial (zero) error-feedback buffer for a payload like ``x``."""
    return jnp.zeros(x.shape, jnp.float32)
