"""repro.comm channels/streams on the 8-fake-device mesh: equivalence of
the Stream-based transfer programs against the raw lax collectives they
replaced, and trace-vs-compiled-HLO overlap validation (the ROADMAP
bubble-term check for the displaced pipeline)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import comm
from repro.compat import shard_map
from repro.configs import get_reduced
from repro.core import SPConfig, sp_attention
from repro.core.collectives import (
    GroupLayout,
    grouped_all_to_all,
    monolithic_all_to_all,
    ungroup_all_to_all,
)
from repro.core.pipefusion import PipelineConfig
from repro.launch.mesh import make_hybrid_mesh
from repro.models import ParallelContext, get_model
from repro.models.dit import COND_TOKENS, dit_forward_displaced
from repro.serving import SamplerConfig
from repro.serving.sampler import hybrid_state_shape

SP_AXES = ("pod", "model")


def _layout(p_u, p_r):
    return GroupLayout(SP_AXES, p_u, p_r, ulysses_outer=True)


def _smap(fn, mesh, spec):
    return shard_map(fn, mesh=mesh, in_specs=(spec,), out_specs=spec,
                     check_vma=False)


# ---------------------------------------------------------------------------
# equivalence vs raw lax collectives
# ---------------------------------------------------------------------------

def test_stream_ring_shift_matches_lax_ppermute(mesh8, rng):
    layout = _layout(2, 2)
    x = jax.random.normal(rng, (8, 16))
    spec = P(SP_AXES)
    via_comm = _smap(lambda xs: comm.ring_shift(layout, xs).wait(),
                     mesh8, spec)
    via_lax = _smap(
        lambda xs: lax.ppermute(xs, SP_AXES, perm=layout.ring_perm(1)),
        mesh8, spec)
    np.testing.assert_array_equal(np.asarray(via_comm(x)),
                                  np.asarray(via_lax(x)))


def test_staged_all_to_all_matches_monolithic(mesh8, rng):
    """Full-axis Ulysses group: the staged channel program must deliver
    exactly what the atomic lax.all_to_all delivers."""
    layout = _layout(4, 1)
    x = jax.random.normal(rng, (2, 32, 8, 4))
    spec = P(None, SP_AXES, None, None)

    def staged(xs):
        return comm.staged_all_to_all(xs, layout, split_axis=2)

    def monolithic(xs):
        return monolithic_all_to_all(xs, layout, split_axis=2)

    out_spec = P(None, None, SP_AXES, None, None)
    f1 = shard_map(staged, mesh=mesh8, in_specs=(spec,), out_specs=out_spec,
                   check_vma=False)
    f2 = shard_map(monolithic, mesh=mesh8, in_specs=(spec,),
                   out_specs=out_spec, check_vma=False)
    np.testing.assert_array_equal(np.asarray(f1(x)), np.asarray(f2(x)))


@pytest.mark.parametrize("p_u,p_r", [(2, 2), (4, 1)])
def test_grouped_ungroup_roundtrip(p_u, p_r, mesh8, rng):
    layout = _layout(p_u, p_r)
    x = jax.random.normal(rng, (2, 32, 8, 4))
    spec = P(None, SP_AXES, None, None)

    def roundtrip(xs):
        stacked = grouped_all_to_all(xs, layout, split_axis=2)
        return ungroup_all_to_all(stacked, layout, concat_axis=2)

    f = _smap(roundtrip, mesh8, spec)
    np.testing.assert_allclose(np.asarray(f(x)), np.asarray(x), rtol=0, atol=0)


def test_pipe_handoff_value_preserving_and_traced(rng):
    mesh = make_hybrid_mesh(cfg=1, pipe=2, data=2, model=2)
    x = jax.random.normal(rng, (4, 8, 16))

    def f(xs):
        return comm.pipe_handoff(xs, mesh, "pipe", batch_axes=("data",))

    with comm.record("pipe") as tr:
        lowered = jax.jit(f).lower(x)
    assert len(tr.events) == 1
    (e,) = tr.events
    assert e.axes == ("pipe",) and e.overlaps == "stage compute"
    # replicated over the pipe axis, the rotation is value-preserving
    np.testing.assert_array_equal(np.asarray(jax.jit(f)(x)), np.asarray(x))
    # ... but it is a *real* wire transfer in the compiled program
    report = comm.validate(tr, lowered.compile().as_text(), mesh,
                           require_overlap=False)
    assert report.hlo_permutes >= 1
    assert not any("no collective-permute" in f_ for f_ in report.failures)


# ---------------------------------------------------------------------------
# trace-vs-HLO overlap validation
# ---------------------------------------------------------------------------

def test_torus_schedule_validates_against_hlo(mesh8, rng):
    """Every put of the Torus schedule must appear as a collective-permute
    with the intended route, and each overlap intent must be admissible in
    the compiled program."""
    kq, kk, kv = jax.random.split(rng, 3)
    # 2 heads on the 4-way SP group => P_u = gcd(4, 2) = 2, P_r = 2: both
    # the torus hops AND the intra-ring rotations appear in the schedule
    q = jax.random.normal(kq, (2, 32, 2, 16))
    k = jax.random.normal(kk, (2, 32, 2, 16))
    v = jax.random.normal(kv, (2, 32, 2, 16))
    cfg = SPConfig(strategy="swift_torus", sp_axes=SP_AXES,
                   batch_axes=("data",))

    def fn(q, k, v):
        return sp_attention(q, k, v, mesh=mesh8, cfg=cfg)

    with comm.record("torus") as tr:
        lowered = jax.jit(fn).lower(q, k, v)
    assert tr.events, "no channel puts recorded for the torus schedule"
    assert any(e.stream == "torus" for e in tr.events)
    assert any(e.stream == "ring" for e in tr.events)
    report = comm.validate(tr, lowered.compile().as_text(), mesh8)
    assert report.ok, report.summary()
    assert report.overlapped, "no overlap intent validated"


def test_displaced_pipe_handoff_overlaps_stage_compute(rng):
    """The ROADMAP bubble-term validation: the displaced pipeline's stage
    hand-off must be an explicit collective-permute over the pipe axis
    that the compiled HLO can overlap with stage compute (patch p+1's
    transfer vs patch p's compute)."""
    mesh = make_hybrid_mesh(cfg=1, pipe=2, data=1, model=4)
    cfg = dataclasses.replace(get_reduced("flux-12b"), dtype="float32",
                              n_heads=4, n_kv_heads=4)
    bundle = get_model(cfg)
    params, _ = bundle.init(cfg, jax.random.PRNGKey(0), 1)
    sp = SPConfig(strategy="swift_torus", sp_axes=("model",),
                  batch_axes=("data",), pp_axis="pipe")
    ctx = ParallelContext(mesh, sp, "prefill")
    sc = SamplerConfig(num_steps=2,
                       pipeline=PipelineConfig(pp=2, warmup_steps=1))
    seq = 32
    lat = jax.random.normal(rng, (1, seq, 64), jnp.float32)
    cond = jax.random.normal(jax.random.PRNGKey(1),
                             (1, COND_TOKENS, cfg.d_model), jnp.float32)
    state = hybrid_state_shape(cfg, 1, seq, sc)
    tt = jnp.full((1,), 0.5, jnp.float32)

    def step(lat, cond, k, v):
        from repro.core.pipefusion import KVState
        return dit_forward_displaced(params, cfg, ctx, latents=lat,
                                     cond=cond, timesteps=tt,
                                     kv_state=KVState(k, v),
                                     num_patches=2, pp=2)

    with comm.record("displaced") as tr:
        lowered = jax.jit(step).lower(lat, cond, state.k, state.v)
    pipe_events = [e for e in tr.events if e.stream == "pipe"]
    # one hand-off per (patch, stage boundary): 2 patches x 1 boundary
    assert len(pipe_events) == 2, tr.events
    assert all(e.overlaps == "stage compute" for e in pipe_events)
    report = comm.validate(tr, lowered.compile().as_text(), mesh)
    assert report.ok, report.summary()
    assert any(ch.startswith("pipe.") for ch in report.overlapped), report


@pytest.mark.parametrize("k", [1, 2, 3])
def test_distance_k_torus_hop_validates_against_hlo(k, mesh8, rng):
    """Each distance-k hop of the decomposed all-to-all must compile to a
    collective-permute with exactly the intended distance-k route."""
    layout = _layout(4, 1)
    x = jax.random.normal(rng, (8, 16))
    spec = P(SP_AXES)

    def fn(xs):
        return comm.torus_hop(layout, k, xs).wait()

    with comm.record(f"hop{k}") as tr:
        lowered = jax.jit(_smap(fn, mesh8, spec)).lower(x)
    (e,) = tr.events
    assert e.channel == f"torus.hop{k}"
    assert e.perm == tuple(layout.ulysses_stage_perm(k))
    report = comm.validate(tr, lowered.compile().as_text(), mesh8,
                           require_overlap=False)
    assert report.ok, report.summary()
    assert report.hlo_permutes >= 1


@pytest.mark.parametrize("k", [1, 3])
def test_distance_k_torus_hop_validates_under_pallas(k, mesh8, rng):
    """Same distance-k routes through the Pallas channel backend
    (emulation branch, interpret mode): the wire move must still carry the
    intended pairs in HLO and the semaphore schedule must pair up."""
    layout = _layout(4, 1)
    x = jax.random.normal(rng, (8, 16))
    spec = P(SP_AXES)

    def fn(xs):
        return comm.torus_hop(layout, k, xs, backend="pallas",
                              interpret=True).wait()

    with comm.record(f"phop{k}") as tr:
        lowered = jax.jit(_smap(fn, mesh8, spec)).lower(x)
    assert all(e.backend == "pallas" for e in tr.events)
    assert tr.sem_events, "pallas put recorded no semaphore events"
    report = comm.validate(tr, lowered.compile().as_text(), mesh8,
                           require_overlap=False)
    assert report.ok, report.summary()
    sem = comm.validate_semaphores(tr)
    assert sem.ok, sem.summary()


def test_staged_a2a_validates_under_pallas(mesh8, rng):
    """The staged all-to-all Stream program under backend="pallas": every
    stage's route in HLO, a clean semaphore pairing, and value parity with
    the monolithic collective it replaces."""
    layout = _layout(4, 1)
    x = jax.random.normal(rng, (2, 32, 8, 4))
    spec = P(None, SP_AXES, None, None)
    out_spec = P(None, None, SP_AXES, None, None)

    def staged(xs):
        return comm.staged_all_to_all(xs, layout, split_axis=2,
                                      backend="pallas", interpret=True)

    f = shard_map(staged, mesh=mesh8, in_specs=(spec,), out_specs=out_spec,
                  check_vma=False)
    with comm.record("a2a_pallas") as tr:
        lowered = jax.jit(f).lower(x)
    # P_u - 1 = 3 wire stages (the diagonal chunk never leaves the device)
    assert len(tr.events) == 3
    assert all(e.backend == "pallas" for e in tr.events)
    report = comm.validate(tr, lowered.compile().as_text(), mesh8,
                           require_overlap=False)
    assert report.ok, report.summary()
    sem = comm.validate_semaphores(tr)
    assert sem.ok, sem.summary()
    ref = shard_map(lambda xs: monolithic_all_to_all(xs, layout, split_axis=2),
                    mesh=mesh8, in_specs=(spec,), out_specs=out_spec,
                    check_vma=False)
    np.testing.assert_allclose(np.asarray(jax.jit(f)(x)),
                               np.asarray(ref(x)), rtol=1e-6, atol=1e-6)


def test_staged_ungroup_validates_under_pallas(mesh8, rng):
    """The inverse program (a2a.inv) under the Pallas backend round-trips
    values and validates both its routes and its semaphore schedule."""
    layout = _layout(4, 1)
    x = jax.random.normal(rng, (2, 32, 8, 4))
    spec = P(None, SP_AXES, None, None)

    def roundtrip(xs):
        stacked = comm.staged_all_to_all(xs, layout, split_axis=2,
                                         backend="pallas", interpret=True)
        return comm.staged_ungroup(stacked, layout, concat_axis=2,
                                   backend="pallas", interpret=True)

    f = _smap(roundtrip, mesh8, spec)
    with comm.record("rt_pallas") as tr:
        lowered = jax.jit(f).lower(x)
    assert {e.stream for e in tr.events} == {"a2a", "a2a.inv"}
    report = comm.validate(tr, lowered.compile().as_text(), mesh8,
                           require_overlap=False)
    assert report.ok, report.summary()
    sem = comm.validate_semaphores(tr)
    assert sem.ok, sem.summary()
    np.testing.assert_allclose(np.asarray(f(x)), np.asarray(x),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# hierarchical two-level a2a (DESIGN.md §8.2)
# ---------------------------------------------------------------------------

def _hier_layout(p_u=4, p_r=1):
    """mesh8's SP group is (pod=2, model=2): N=2 machines, so the only
    hier-applicable factorisation is P_u=4 (m_u=2 members per machine)."""
    return GroupLayout(SP_AXES, p_u, p_r, ulysses_outer=True, u_groups=2)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_hier_a2a_bit_compatible_with_monolithic(backend, mesh8, rng):
    """Acceptance gate: on the 8-device CPU mesh the hierarchical a2a is
    bit-compatible (<= 1e-5 fp32; exact, being pure routing) with the
    monolithic collective under both channel backends."""
    hier, flat = _hier_layout(), _layout(4, 1)
    x = jax.random.normal(rng, (2, 32, 8, 4)).astype(jnp.float32)
    spec = P(None, SP_AXES, None, None)
    out_spec = P(None, None, SP_AXES, None, None)

    def hier_fn(xs):
        # dispatch happens inside monolithic_all_to_all on u_groups > 1
        return monolithic_all_to_all(xs, hier, split_axis=2,
                                     backend=backend, interpret=True)

    def flat_fn(xs):
        return monolithic_all_to_all(xs, flat, split_axis=2)

    f_h = shard_map(hier_fn, mesh=mesh8, in_specs=(spec,),
                    out_specs=out_spec, check_vma=False)
    f_f = shard_map(flat_fn, mesh=mesh8, in_specs=(spec,),
                    out_specs=out_spec, check_vma=False)
    np.testing.assert_allclose(np.asarray(jax.jit(f_h)(x)),
                               np.asarray(jax.jit(f_f)(x)),
                               rtol=0, atol=1e-5)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_hier_roundtrip_and_ungroup(backend, mesh8, rng):
    layout = _hier_layout()
    x = jax.random.normal(rng, (2, 32, 8, 4))
    spec = P(None, SP_AXES, None, None)

    def roundtrip(xs):
        stacked = monolithic_all_to_all(xs, layout, split_axis=2,
                                        backend=backend, interpret=True)
        return ungroup_all_to_all(stacked, layout, concat_axis=2,
                                  backend=backend, interpret=True)

    f = _smap(roundtrip, mesh8, spec)
    np.testing.assert_allclose(np.asarray(jax.jit(f)(x)), np.asarray(x),
                               rtol=0, atol=0)


def test_hier_a2a_fp8_wire_close_to_exact(mesh8, rng):
    """With fp8 on the inter-machine leg only, the result stays within
    e4m3 mantissa error of the exact exchange (intra leg untouched)."""
    pytest.importorskip("jax.numpy", reason="float8 availability")
    from repro.comm.compress import has_wire_dtype
    if not has_wire_dtype("float8_e4m3fn"):
        pytest.skip("jax build lacks float8")
    layout = _hier_layout()
    x = jax.random.normal(rng, (2, 32, 8, 4)).astype(jnp.float32)
    spec = P(None, SP_AXES, None, None)
    out_spec = P(None, None, SP_AXES, None, None)

    def fp8(xs):
        return monolithic_all_to_all(xs, layout, split_axis=2,
                                     wire_dtype="float8_e4m3fn")

    def exact(xs):
        return monolithic_all_to_all(xs, layout, split_axis=2)

    f8 = shard_map(fp8, mesh=mesh8, in_specs=(spec,), out_specs=out_spec,
                   check_vma=False)
    fx = shard_map(exact, mesh=mesh8, in_specs=(spec,), out_specs=out_spec,
                   check_vma=False)
    got, ref = np.asarray(jax.jit(f8)(x)), np.asarray(jax.jit(fx)(x))
    assert not np.array_equal(got, ref), "fp8 wire did not engage"
    np.testing.assert_allclose(got, ref, rtol=0.08, atol=0.08)


def test_hier_a2a_trace_declares_and_validates_inter_overlap(mesh8, rng):
    """The acceptance trace gate: both legs' hops appear as channel events
    with the intended routes; the inter hops carry an overlap declaration
    that validate() admits against the compiled HLO.  Two tensors go
    through the transform (as Q/K/V do in gather_qkv) — the exchanges are
    mutually independent, which is the compute the declaration names (a
    SINGLE standalone g=2 exchange has no peer and cannot overlap)."""
    layout = _hier_layout()
    kx, ky = jax.random.split(rng)
    x = jax.random.normal(kx, (2, 32, 8, 4))
    y = jax.random.normal(ky, (2, 32, 8, 4))
    spec = P(None, SP_AXES, None, None)
    out_spec = P(None, None, SP_AXES, None, None)

    def fn(xs, ys):
        return (monolithic_all_to_all(xs, layout, split_axis=2),
                monolithic_all_to_all(ys, layout, split_axis=2))

    f = shard_map(fn, mesh=mesh8, in_specs=(spec, spec),
                  out_specs=(out_spec, out_spec), check_vma=False)
    with comm.record("hier") as tr:
        lowered = jax.jit(f).lower(x, y)
    chans = [e.channel for e in tr.events]
    # per tensor: m_u - 1 = 1 fast-leg stage, g - 1 = 1 slow-leg stage
    assert chans == ["hier.a2a.intra1", "hier.a2a.inter1"] * 2, chans
    intra_e, inter_e = tr.events[:2]
    assert intra_e.perm == tuple(layout.ulysses_intra_stage_perm(1))
    assert inter_e.perm == tuple(layout.ulysses_inter_stage_perm(1))
    # the fast leg never crosses the machine boundary
    pod = mesh8.shape["model"]
    for s, d in intra_e.perm:
        assert s // pod == d // pod, intra_e.perm
    assert any(s // pod != d // pod for s, d in inter_e.perm)
    for e in tr.events:
        if e.channel.endswith("inter1"):
            assert e.overlaps, "inter hop must declare its overlap intent"
    report = comm.validate(tr, lowered.compile().as_text(), mesh8)
    assert report.ok, report.summary()
    assert any(ch.startswith("hier.a2a.inter") for ch in report.overlapped), (
        report.overlapped)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_hier_a2a_profiler_measures_inter_hops(backend, mesh8, rng):
    """PR-7 profiler agreement: the executed schedule records the inter
    hops as comm legs whose issue->signal windows exist and whose intent
    tag matches the trace declaration."""
    layout = _hier_layout()
    x = jax.random.normal(rng, (2, 32, 8, 4))
    spec = P(None, SP_AXES, None, None)
    out_spec = P(None, None, SP_AXES, None, None)

    def fn(xs):
        return monolithic_all_to_all(xs, layout, split_axis=2,
                                     backend=backend, interpret=True)

    f = shard_map(fn, mesh=mesh8, in_specs=(spec,), out_specs=out_spec,
                  check_vma=False)
    prof = comm.CommProfiler()
    with comm.profile(prof):
        out = jax.jit(f)(x)
    jax.block_until_ready(out)
    evs = prof.take()
    inter = [e for e in evs if e.meta.channel.startswith("hier.a2a.inter")]
    assert inter, [e.meta.channel for e in evs]
    assert {e.phase for e in inter} >= {"issue", "signal"}
    assert all(e.meta.intent for e in inter
               if e.meta.kind == "comm"), "inter legs lost their intent tag"


def test_hier_attention_matches_flat_end_to_end(mesh8, rng):
    """sp_attention with hier_a2a on vs off: identical O (<= 1e-5 fp32)
    — the full four-transform path through gather_qkv/scatter_o."""
    kq, kk, kv = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (2, 32, 4, 16))
    k = jax.random.normal(kk, (2, 32, 4, 16))
    v = jax.random.normal(kv, (2, 32, 4, 16))
    base = SPConfig(strategy="ulysses", sp_axes=SP_AXES,
                    batch_axes=("data",))
    hier = dataclasses.replace(base, hier_a2a=True)

    def run(cfg):
        return jax.jit(lambda *a: sp_attention(
            *a, mesh=mesh8, cfg=cfg))(q, k, v)

    np.testing.assert_allclose(np.asarray(run(hier)), np.asarray(run(base)),
                               rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# staged a2a <-> ungroup round-trip property (uneven heads, dtypes, layouts)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("outer", [True, False])
@pytest.mark.parametrize("p_u", [1, 2, 4])
def test_staged_roundtrip_property(p_u, outer, dtype, mesh8, rng):
    """staged_all_to_all ∘ staged_ungroup == identity for uneven head
    chunks (12 heads -> chunks of 3), both element dtypes (pure routing:
    exact even in bf16), every P_u, and both ulysses_outer layouts."""
    layout = GroupLayout(SP_AXES, p_u, 4 // p_u, ulysses_outer=outer)
    x = jax.random.normal(rng, (1, 32, 12, 2)).astype(dtype)
    spec = P(None, SP_AXES, None, None)

    def roundtrip(xs):
        stacked = comm.staged_all_to_all(xs, layout, split_axis=2)
        return comm.staged_ungroup(stacked, layout, concat_axis=2)

    f = _smap(roundtrip, mesh8, spec)
    np.testing.assert_array_equal(np.asarray(jax.jit(f)(x)), np.asarray(x))


@pytest.mark.parametrize("outer", [True, False])
@pytest.mark.parametrize("p_u", [2, 4])
def test_staged_chunk_order_matches_group_positions(p_u, outer, mesh8):
    """The stacked output's source-u ordering IS group_positions': encode
    each element's global sequence position into the input and check
    stacked[j] carries exactly the positions group_positions assigns to
    source j."""
    from repro.core.ulysses import group_positions

    layout = GroupLayout(SP_AXES, p_u, 4 // p_u, ulysses_outer=outer)
    ls = 8  # 32 global / 4 SP devices
    x = jnp.broadcast_to(jnp.arange(32, dtype=jnp.float32)[None, :, None,
                                                           None],
                         (1, 32, p_u, 1))
    spec = P(None, SP_AXES, None, None)

    def check(xs):
        stacked = comm.staged_all_to_all(xs, layout, split_axis=2)
        _, r = layout.my_coords()
        want = group_positions(layout, ls, r).reshape(p_u, ls)
        got = stacked[:, 0, :, 0, 0]  # [P_u, Ls] of encoded positions
        return jnp.max(jnp.abs(got - want)).reshape(1)

    f = shard_map(check, mesh=mesh8, in_specs=(spec,),
                  out_specs=P(SP_AXES), check_vma=False)
    assert np.asarray(jax.jit(f)(x)).max() == 0.0
