"""chatglm3-6b [dense] — RoPE 2d (half-dim rotary), GQA kv=2
[arXiv:2406.12793]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=65024,
    rope="rope2d",  # rotary on half the head dim, interleaved pairs
    qkv_bias=True,
    act="swiglu",
    norm="rmsnorm",
    sharding_overrides=(("mlp", ("data",)), ("vocab", ("data",))),
    citation="arXiv:2406.12793",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab=512,
        sharding_overrides=(),
    )
