"""Adaptive serving control loop (serving/sched/{control,forecast}.py,
DESIGN.md §10): arrival forecasting, the slack-aware deferral horizon,
and the preemption invariants (ISSUE 5) —

  (a) a preempted request never loses accrued starvation age,
  (b) the PR-3 hard starvation bound survives adversarial arrival
      streams with preemption enabled,
  (c) preemption never fires when the waiting side's remaining slack
      covers the running batch.

All host-side: the property tests drive the same scheduler objects and
step-granular simulation the engine and the replay harness use, on
simulated time (seeded mini-hypothesis, no wall clock)."""
import dataclasses
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving.sched import (
    ArrivalForecaster,
    Candidate,
    ControlConfig,
    PreemptionPolicy,
    RequestScheduler,
    SchedConfig,
)
from tests.test_sched import Req, make_cache


def make_sched(forecaster=None, **kw):
    cfg = SchedConfig(max_batch=4, dp=2, starvation_age=10.0,
                      aging_rate=1.0, default_slack=100.0, defer_slack=1.0)
    cfg = dataclasses.replace(cfg, **kw)
    return RequestScheduler(make_cache(dp=cfg.dp), cfg,
                            forecaster=forecaster)


def cand(min_slack: float, age: float = 0.0) -> Candidate:
    """A candidate carrying only what should_preempt reads."""
    return Candidate(bucket=None, k=1, batch_rows=2, pad_rows=1, plan=None,
                     min_slack=min_slack, age=age, score=min_slack)


# ---------------------------------------------------------------------------
# arrival forecaster
# ---------------------------------------------------------------------------

def test_forecaster_needs_two_arrivals():
    f = ArrivalForecaster()
    assert f.expected_fill_time(256, 1, now=0.0) is None
    f.observe(256, 0.0)
    assert f.expected_fill_time(256, 1, now=0.5) is None
    assert f.rate(256) == 0.0
    f.observe(256, 2.0)
    assert f.expected_fill_time(256, 1, now=2.0) is not None
    assert f.rate(256) == pytest.approx(0.5)


def test_forecaster_tracks_steady_rate():
    f = ArrivalForecaster(alpha=0.5)
    for i in range(20):
        f.observe(512, i * 0.1)
    assert f.rate(512) == pytest.approx(10.0, rel=0.01)
    # k more arrivals ≈ k·gap; the elapsed time since the last arrival is
    # credited against the first gap
    assert f.expected_fill_time(512, 3, now=1.9) == pytest.approx(
        0.3, abs=0.05)
    assert f.expected_fill_time(512, 3, now=1.95) == pytest.approx(
        0.25, abs=0.05)
    # a bucket never seen has no estimate
    assert f.expected_fill_time(1024, 1, now=2.0) is None


@given(st.integers(1, 6), st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_forecaster_fill_time_monotone_in_k(k, seed):
    rnd = random.Random(seed)
    f = ArrivalForecaster()
    t = 0.0
    for _ in range(rnd.randint(2, 30)):
        t += rnd.uniform(0.01, 1.0)
        f.observe(256, t)
    a = f.expected_fill_time(256, k, now=t)
    b = f.expected_fill_time(256, k + 1, now=t)
    assert a is not None and b is not None and 0.0 <= a <= b


# ---------------------------------------------------------------------------
# slack-aware deferral horizon (admission + forecaster)
# ---------------------------------------------------------------------------

def test_dried_up_bucket_served_padded_instead_of_stalling():
    """PR-3 defers a padded batch until flush whenever slack allows; with
    the forecaster, a bucket whose arrivals are too slow to fill the pad
    within the slack is served immediately (DESIGN.md §10)."""
    hist = [Req(0, 256), Req(1, 256)]
    fore = ArrivalForecaster()
    old, new = make_sched(), make_sched(forecaster=fore)
    for s in (old, new):
        for i, r in enumerate(hist):
            s.submit(dataclasses.replace(r), now=60.0 * i)  # 60 s gaps
        s.next_batch(120.0, flush=True)  # drain history (k=2, no pad)
        s.submit(Req(2, 256, sla=20.0), now=120.0)
    # the lone request needs 1 pad row; its ~59 s forecast fill time does
    # NOT fit the 20 s slack, so the forecaster admits it padded now
    assert old.next_batch(121.0, flush=False) is None  # PR-3: stalls
    adm = new.next_batch(121.0, flush=False)
    assert adm is not None and adm.pad_rows == 1 and len(adm.requests) == 1


def test_fast_bucket_still_defers_for_packing():
    """When arrivals ARE fast enough to fill the pad inside the slack the
    forecaster keeps deferring — same packing win as PR-3."""
    fore = ArrivalForecaster()
    s = make_sched(forecaster=fore)
    for i in range(4):  # 10 ms interarrival history
        s.submit(Req(i, 256), now=0.01 * i)
    s.next_batch(0.04, flush=True)
    s.submit(Req(4, 256), now=0.05)  # lone request, slack = default 100 s
    assert s.next_batch(0.051, flush=False) is None  # fill ≈ 10 ms: wait
    adm = s.next_batch(0.06, flush=True)
    assert adm is not None


def test_forecaster_evicts_idle_buckets():
    """With ``idle_age`` set, a bucket whose arrivals dried up is dropped
    on the next observe — the per-seq_len map stays bounded by the set of
    RECENTLY seen resolutions, not every resolution ever seen (ISSUE 9:
    ``buckets`` grew without bound)."""
    f = ArrivalForecaster(idle_age=1.0)
    f.observe(1024, 0.0)
    f.observe(256, 0.5)
    for i in range(6):
        f.observe(256, 0.6 + 0.1 * i)
    assert 1024 not in f.buckets  # idle > 1 s: evicted by a 256 observe
    assert set(f.buckets) == {256}
    # a returning bucket re-seeds from scratch (needs two arrivals again)
    f.observe(1024, 1.2)
    assert f.rate(1024) == 0.0


def test_forecaster_eviction_bounds_memory_under_resolution_churn():
    f = ArrivalForecaster(idle_age=0.5)
    for i in range(500):  # adversarial: every request a new resolution
        f.observe(256 + i, 0.1 * i)
    assert len(f.buckets) <= 6  # only buckets inside the idle window
    # the PR-5 default (no idle_age) keeps the old retain-forever shape
    g = ArrivalForecaster()
    for i in range(100):
        g.observe(256 + i, 0.1 * i)
    assert len(g.buckets) == 100


def test_forecaster_evict_idle_direct_call_counts_evictions():
    """Long-idle owners (the fleet tier) call ``evict_idle`` directly;
    eviction uses caller time only and is published as a counter."""
    from repro.serving.sched import RecordingTracker

    trk = RecordingTracker()
    f = ArrivalForecaster(idle_age=2.0, tracker=trk)
    f.observe(256, 0.0)
    f.observe(512, 1.0)
    assert f.evict_idle(1.5) == 0  # nothing idle yet
    assert f.evict_idle(2.5) == 1  # 256 idle 2.5 s > 2 s
    assert set(f.buckets) == {512}
    assert trk.counter("forecast.evictions", {"seq": 256}) == 1
    with pytest.raises(AssertionError):
        ArrivalForecaster(idle_age=0.0)


# ---------------------------------------------------------------------------
# (a) preemption preserves accrued age and FIFO position
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_requeue_preserves_age_and_order(seed, dp):
    rnd = random.Random(seed)
    s = make_sched(dp=dp)
    reqs = []
    t = 0.0
    for i in range(rnd.randint(2, 12)):
        t += rnd.uniform(0.0, 2.0)
        r = Req(i, rnd.choice([256, 512]))
        reqs.append(r)
        s.submit(r, now=t)
    now = t + rnd.uniform(0.0, 5.0)
    adm = s.next_batch(now, flush=True)
    submitted = {r.rid: r.submitted for r in adm.requests}
    ages_before = {r.rid: now - r.submitted for r in adm.requests}
    s.requeue(adm.requests)
    # accrued age intact: submitted stamps are untouched by the park
    later = now + 1.0
    adm2 = s.next_batch(later, flush=True)
    assert adm2.seq_len == adm.seq_len
    assert [r.rid for r in adm2.requests][:len(adm.requests)] == [
        r.rid for r in adm.requests]  # FIFO position restored (head)
    for r in adm2.requests:
        if r.rid in submitted:
            assert r.submitted == submitted[r.rid]
            assert later - r.submitted == pytest.approx(
                ages_before[r.rid] + 1.0)


# ---------------------------------------------------------------------------
# (c) the decision rule never fires when slack covers the running batch
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.integers(2, 30))
@settings(max_examples=50, deadline=None)
def test_no_preemption_when_slack_covers_running_batch(seed, remaining):
    rnd = random.Random(seed)
    pol = PreemptionPolicy(margin=rnd.choice([0.0, 0.01]))
    t_step = rnd.uniform(1e-4, 0.1)
    t_rem = remaining * t_step
    covered = [cand(t_rem + rnd.uniform(0.0, 10.0) + pol.margin)
               for _ in range(rnd.randint(1, 5))]
    assert pol.should_preempt(covered, remaining_steps=remaining,
                              t_step=t_step, running_age=0.0,
                              starvation_age=10.0) is None


def test_preemption_fires_only_for_salvageable_critical_candidates():
    pol = PreemptionPolicy(min_remaining_steps=2)
    kw = dict(remaining_steps=10, t_step=0.01, running_age=0.0,
              starvation_age=10.0)
    # doomed (negative slack): parking cannot save it
    assert pol.should_preempt([cand(-0.01)], **kw) is None
    # salvageable and doomed-by-waiting: fires, tightest slack wins
    got = pol.should_preempt([cand(0.05), cand(0.02)], **kw)
    assert got is not None and got.min_slack == 0.02
    # nearly-finished batches are never parked
    assert pol.should_preempt([cand(0.02)], remaining_steps=1, t_step=0.01,
                              running_age=0.0, starvation_age=10.0) is None
    # an overdue running batch is immune (carries the starvation bound)
    assert pol.should_preempt([cand(0.02)], remaining_steps=10, t_step=0.01,
                              running_age=10.0, starvation_age=10.0) is None


def test_same_bucket_candidate_only_useful_if_it_fits_the_restart():
    """Parking for the running batch's OWN bucket is futile unless the
    parked requests and the triggering ones fit one batch — the parked
    batch re-enters at the head, so otherwise the re-admission re-serves
    it and the trigger re-fires (park/restart thrash)."""
    from repro.serving.sched import Bucket

    c = Candidate(bucket=Bucket(256), k=1, batch_rows=1, pad_rows=0,
                  plan=None, min_slack=0.02, age=0.0, score=0.0)
    kw = dict(remaining_steps=10, t_step=0.01, running_age=0.0,
              starvation_age=10.0)
    pol = PreemptionPolicy(min_remaining_steps=2)
    # legacy callers without running-batch info keep the plain rule
    assert pol.should_preempt([c], **kw) is not None
    # same bucket, parked 4 + trigger 1 > max_batch 4: futile, skip
    assert pol.should_preempt([c], running_seq=256, running_k=4,
                              max_batch=4, **kw) is None
    # fits one batch with the parked requests: regrouping serves it
    assert pol.should_preempt([c], running_seq=256, running_k=3,
                              max_batch=4, **kw) is not None
    # a different bucket is unaffected by the futility rule
    assert pol.should_preempt([c], running_seq=512, running_k=4,
                              max_batch=4, **kw) is not None


def test_control_config_engaged():
    assert not ControlConfig().engaged
    assert ControlConfig(preemption=PreemptionPolicy()).engaged
    from repro.serving.sched import CalibrationConfig
    assert ControlConfig(calibration=CalibrationConfig()).engaged


# ---------------------------------------------------------------------------
# (b) hard starvation bound under adversarial streams with preemption
# ---------------------------------------------------------------------------

def test_requeue_reverses_admission_accounting():
    """A parked batch must not double-count in BucketStats: pop's
    accounting is reversed on requeue and re-applied on re-admission, so
    totals() reflects completed batches only."""
    s = make_sched(dp=2)
    s.submit(Req(0, 256), now=0.0)
    adm = s.next_batch(1.0, flush=True)  # k=1, 1 pad row
    assert adm.pad_rows == 1
    s.requeue(adm.requests, adm.pad_rows)
    t = s.totals()
    assert (t.admitted, t.batches, t.padded_rows, t.padded_token_work,
            t.real_token_work) == (0, 0, 0, 0, 0)
    s.next_batch(2.0, flush=True)  # re-admission re-accounts exactly once
    t = s.totals()
    assert t.admitted == 1 and t.batches == 1 and t.padded_rows == 1
    assert t.padded_token_work == t.real_token_work == 256
    assert t.max_wait >= 1.0  # the first admission's wait is kept


def test_sampler_interrupt_stops_between_steps():
    """sample(interrupt=...) — the step-granular park hook for callers
    that drive the sampler directly rather than through DiTServer."""
    import dataclasses as dc

    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.serving import SamplerConfig, sample

    cfg = dc.replace(get_reduced("flux-12b"), dtype="float32")
    calls = []

    def step_fn(x, cond, t):
        calls.append(float(t))
        return x + 1.0

    metrics = []
    import jax

    out = sample(None, cfg, None, key=jax.random.PRNGKey(0), batch=1,
                 seq_len=8, cond=jnp.zeros((1, 4, 8)),
                 sc=SamplerConfig(num_steps=5), step_fn=step_fn,
                 metrics=metrics, interrupt=lambda i: i == 1)
    assert len(calls) == 2  # stopped after step 1, before step 2
    assert len(metrics) == 2 and all(m["t_step_s"] > 0 for m in metrics)
    noise = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 64), cfg.dtype)
    # latents as of the parked step: two +1 steps applied, not five
    assert bool(jnp.allclose(out, noise + 2.0, atol=1e-6))


# ---------------------------------------------------------------------------
# engine integration: park + restart + online recalibration (1 device)
# ---------------------------------------------------------------------------

def test_engine_parks_restarts_and_recalibrates(mesh1):
    """A real (tiny) DiTServer with the full control loop: an urgent
    request injected mid-batch parks the running batch (accrued age
    kept, request completes later), per-step wall clocks are surfaced,
    and the online calibrator — fed CPU step times that are orders of
    magnitude off the analytical µs predictions — refits and invalidates
    the plan cache's scores."""
    import dataclasses as dc

    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.core import SPConfig as SP_
    from repro.models import get_model
    from repro.serving import (
        CalibrationConfig,
        DiTRequest,
        DiTServer,
        SamplerConfig,
    )

    cfg = dc.replace(get_reduced("flux-12b"), dtype="float32")
    bundle = get_model(cfg)
    params, _ = bundle.init(cfg, jax.random.PRNGKey(0), 1)
    sp = SP_(strategy="full", sp_axes=("model",), batch_axes=("data",))
    srv = DiTServer(
        params, cfg, mesh1, sp, sampler=SamplerConfig(num_steps=3),
        max_batch=4,
        sched=SchedConfig(max_batch=4, starvation_age=3600.0,
                          default_slack=1e9),
        control=ControlConfig(
            preemption=PreemptionPolicy(min_remaining_steps=1),
            calibration=CalibrationConfig(min_samples=2, refit_every=2),
            forecast=True))
    srv.submit(DiTRequest(rid=0, seq_len=32))
    srv.submit(DiTRequest(rid=1, seq_len=32))
    injected = []

    def inject(server, step):
        if not injected:
            injected.append(step)
            server.submit(DiTRequest(rid=2, seq_len=64, sla=0.5))

    srv.on_step = inject
    results = srv.serve()
    assert sorted(r.rid for r in results) == [0, 1, 2]
    by_rid = {r.rid: r for r in results}
    # the 32 batch was parked for the urgent 64 (first CPU step includes
    # its jit trace: far above the 0.5 s slack), then restarted clean
    assert srv.preemptions >= 1
    assert by_rid[0].preemptions >= 1 and by_rid[1].preemptions >= 1
    assert by_rid[2].preemptions == 0
    for r in results:
        assert len(r.step_times) == 3  # step-granular wall clocks
        assert all(t > 0.0 for t in r.step_times)
        assert bool(jnp.all(jnp.isfinite(r.latents)))
    # online recalibration: measured CPU seconds vs predicted µs is far
    # past any drift threshold — scores invalidated, steps not retraced
    assert srv.calibrator.refits >= 1
    assert srv.calibrator.recalibrations >= 1
    assert srv.plan_cache.invalidations == srv.calibrator.recalibrations
    assert srv.plan_cache.traces == len(srv.plan_cache._steps)
    # forecast engaged: serve() drove the non-flush deferral path and
    # the forecaster saw every bucket's arrivals
    assert srv.scheduler.forecaster is not None
    assert set(srv.scheduler.forecaster.buckets) == {32, 64}


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_starvation_bound_survives_preemption(seed):
    """Adversarial seeded streams (steady tight-SLA bursts trying to
    preempt everything) through the step-granular simulation: every
    request is served and no wait exceeds the PR-3 bound plus the
    batches already in flight (overdue batches are preemption-immune, so
    ages cannot grow unboundedly)."""
    from benchmarks.sched_sweep import (
        BucketedPolicy,
        SimRequest,
        STARVATION_AGE,
        simulate,
    )

    rnd = random.Random(seed)
    reqs, t, rid = [], 0.0, 0
    for _ in range(rnd.randint(20, 60)):
        t += rnd.uniform(0.0005, 0.02)
        if rnd.random() < 0.5:  # adversary: tight-SLA short request
            reqs.append(SimRequest(rid=rid, seq_len=256, arrival=round(t, 6),
                                   sla=rnd.uniform(0.005, 0.02)))
        else:  # victim tier: long best-effort / loose-SLA request
            reqs.append(SimRequest(
                rid=rid, seq_len=rnd.choice([512, 1024]),
                arrival=round(t, 6),
                sla=None if rnd.random() < 0.5 else rnd.uniform(0.5, 2.0)))
        rid += 1
    stats = simulate(BucketedPolicy(), [dataclasses.replace(r) for r in reqs],
                     preempt=PreemptionPolicy())
    assert stats["served"] == len(reqs)
    bound = STARVATION_AGE + 4 * stats["max_batch_s"]
    assert stats["max_wait"] <= bound, (stats["max_wait"], bound)
