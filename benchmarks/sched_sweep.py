"""Request-scheduler sweep (DESIGN.md §9/§10): resolution-bucketed
SLA-aware continuous batching vs the greedy same-length batcher, plus the
adaptive control loop's preemptive variant, on simulated
mixed-resolution queues.

The analytical part runs the policies through a **step-granular**
discrete-event simulation of one serving pipeline (per-replica cluster
N=2 machines x M=4 devices, dp=2 data-parallel replicas of the batch)
over the SAME deterministic arrival stream of 256/512/1024-latent
requests with SLAs:

  * **greedy** — the pre-scheduler ``DiTServer`` behavior: head-of-line
    same-length batching, immediate admission (fragment batches pay dp
    padding rows), one static plan (the sp-only swift_torus default) for
    every bucket.
  * **bucketed** — the ``serving.sched`` subsystem: per-bucket queues,
    deadline/aging-scored cross-bucket admission with padded batches
    deferred while slack allows, and a per-bucket ``plan_hybrid``
    selection (cfg/pp split + patch count) from the plan cache.
  * **preemptive** — bucketed plus the §10 control loop: between sampler
    steps a ``PreemptionPolicy`` may park the running batch (requests
    requeued with accrued age) for an SLA-critical bucket; optionally an
    ``ArrivalForecaster`` bounds padded-batch deferral.

The simulation is deterministic end-to-end — arrivals come from seeded
generators (``bursty_stream`` / ``diurnal_stream``; no wall clock
anywhere) or from a recorded trace (``--replay trace.json``, written by
``--emit-trace``).  Rows report predicted makespan, padded-token work,
worst queue wait, SLA-met fraction and preemptions per policy, plus the
per-bucket plan the cache selected.  ``--smoke`` asserts the PR-3
acceptance claims, the ISSUE-5 claim (the preemptive control loop
achieves a STRICTLY higher SLA-met fraction than the non-preemptive
scheduler on the seeded bursty stream), a replay round-trip, and drives
a real tiny ``DiTServer`` end-to-end on 8 simulated CPU devices.
``--metrics out.jsonl`` streams the preemptive simulation's ``sim.*``
trajectory through the serving metrics sink (DESIGN.md §11) — the same
schema-versioned JSONL format a real ``--metrics`` serve emits.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import pathlib
import random
import sys
from collections import deque
from typing import NamedTuple

from repro.core import plan_hybrid
from repro.core.comm_model import NetworkModel
from repro.serving.sched import (
    ArrivalForecaster,
    JsonlTracker,
    PreemptionPolicy,
    RequestScheduler,
    SchedConfig,
    PlanCache,
    Tracker,
    padded_rows,
)

from .common import row

# per-replica cluster the plans are scored on (paper testbed flavour)
N_MACHINES = 2
M_PER_MACHINE = 4
DP = 2  # data-parallel replicas the global batch must divide into
HEADS = 24
HEAD_DIM = 64
N_LAYERS = 42
NUM_STEPS = 20
MAX_BATCH = 4
STARVATION_AGE = 1.0
SEQS = (256, 512, 1024)
# SLA seconds per bucket: short sequences are the latency-critical tier
SLAS = {256: 0.15, 512: 0.4, 1024: 2.0}


@dataclasses.dataclass
class SimRequest:
    """Duck-typed stand-in for DiTRequest (no jax import needed)."""

    rid: int
    seq_len: int
    arrival: float
    sla: float | None = None
    submitted: float = 0.0
    drift_threshold: float | None = None


def request_stream(n: int = 30) -> list[SimRequest]:
    """Deterministic mixed-resolution arrival stream (no RNG: modular
    pattern), staggered so head-of-line batching fragments."""
    reqs, t = [], 0.0
    for i in range(n):
        seq = SEQS[(i * 7 + i // 3) % 3]
        t += 0.002 + 0.0013 * ((i * 5) % 3)
        reqs.append(SimRequest(rid=i, seq_len=seq, arrival=round(t, 5),
                               sla=SLAS[seq]))
    return reqs


# ---------------------------------------------------------------------------
# seeded load generators + trace replay (ISSUE 5; no wall clock anywhere)
# ---------------------------------------------------------------------------

# bursty scenario: latency-critical 256 tier vs throughput 1024 tier
BURST_SLA_256 = 0.012  # s — tighter than one 1024 batch (~30 ms), looser
BURST_SLA_1024 = 1.5   # than one 256 batch (~4 ms): preemption territory


def bursty_stream(n_bursts: int = 8, seed: int = 7) -> list[SimRequest]:
    """Steady loose-SLA 1024 background traffic with periodic bursts of
    tight-SLA 256 requests landing mid-batch — the workload where
    step-level preemption pays: a 256 burst that arrives while a ~30 ms
    1024 batch runs misses its ~12 ms SLA unless the batch is parked."""
    rnd = random.Random(seed)
    reqs: list[SimRequest] = []
    rid = 0
    t = 0.0
    next_burst = 0.02
    while len([r for r in reqs if r.seq_len == 256]) < n_bursts * 4:
        t += rnd.uniform(0.004, 0.012)
        if t >= next_burst:
            bt = next_burst
            for _ in range(4):  # dp-aligned burst: no padding to defer
                reqs.append(SimRequest(rid=rid, seq_len=256,
                                       arrival=round(bt, 6),
                                       sla=BURST_SLA_256))
                rid += 1
                bt += rnd.uniform(0.0001, 0.0004)
            next_burst += rnd.uniform(0.08, 0.12)
        reqs.append(SimRequest(rid=rid, seq_len=1024, arrival=round(t, 6),
                               sla=BURST_SLA_1024))
        rid += 1
    reqs.sort(key=lambda r: (r.arrival, r.rid))
    return reqs


def diurnal_stream(n: int = 80, seed: int = 11,
                   period: float = 0.4) -> list[SimRequest]:
    """Sinusoidally-modulated arrival rate (a compressed day): gaps are
    exponential with mean 1/λ(t), λ(t) = base·(1 + 0.85·sin(2πt/T)),
    mixed resolutions cycling — peak-hour pressure then troughs."""
    import math

    rnd = random.Random(seed)
    reqs, t = [], 0.0
    base_rate = 120.0  # arrivals/s at the mean
    for i in range(n):
        lam = base_rate * (1.0 + 0.85 * math.sin(2 * math.pi * t / period))
        t += rnd.expovariate(max(lam, 1e-3))
        seq = SEQS[(i * 5 + i // 4) % 3]
        reqs.append(SimRequest(rid=i, seq_len=seq, arrival=round(t, 6),
                               sla=SLAS[seq]))
    return reqs


SCENARIOS = {"bursty": bursty_stream, "diurnal": diurnal_stream}


def save_trace(reqs: list[SimRequest], path: pathlib.Path) -> None:
    path.write_text(json.dumps({
        "requests": [{"rid": r.rid, "seq_len": r.seq_len,
                      "arrival": r.arrival, "sla": r.sla} for r in reqs],
    }, indent=1))


def load_trace(path: pathlib.Path) -> list[SimRequest]:
    payload = json.loads(pathlib.Path(path).read_text())
    return [SimRequest(rid=d["rid"], seq_len=d["seq_len"],
                       arrival=d["arrival"], sla=d.get("sla"))
            for d in payload["requests"]]


def _plan_cache(static: bool) -> PlanCache:
    """Bucketed mode enumerates every feasible (cfg, pp) split and patch
    count; greedy mode pins the single sp-only plan with default patches
    — exactly what the pre-scheduler server ran."""
    kw = dict(heads=HEADS, head_dim=HEAD_DIM, n_layers=N_LAYERS,
              num_steps=NUM_STEPS, guided=True, dp=DP, net=NetworkModel())
    if static:
        sp_only = plan_hybrid(N_MACHINES, M_PER_MACHINE, HEADS,
                              n_layers=N_LAYERS)
        return PlanCache(candidates=[sp_only], patch_multipliers=(1,), **kw)
    return PlanCache(n_machines=N_MACHINES, m_per_machine=M_PER_MACHINE, **kw)


class _GreedyAdmission(NamedTuple):
    seq_len: int
    requests: list
    batch_rows: int
    pad_rows: int
    plan: object  # PlanChoice


class GreedyPolicy:
    """The old ``DiTServer._next_batch``: head-of-line same-length
    batching, admitted immediately — no deferral, no cross-bucket choice,
    one static plan."""

    def __init__(self):
        self.q: deque = deque()
        self.plan_cache = _plan_cache(static=True)

    def submit(self, req, now: float) -> None:
        req.submitted = now
        self.q.append(req)

    @property
    def pending(self) -> int:
        return len(self.q)

    def next(self, now: float, flush: bool) -> _GreedyAdmission | None:
        if not self.q:
            return None
        head = self.q[0]
        batch, rest = [], deque()
        while self.q and len(batch) < MAX_BATCH:
            r = self.q.popleft()
            (batch if r.seq_len == head.seq_len else rest).append(r)
        while rest:
            self.q.appendleft(rest.pop())
        pad = padded_rows(len(batch), DP)
        rows = len(batch) + pad
        return _GreedyAdmission(head.seq_len, batch, rows, pad,
                                self.plan_cache.select(rows, head.seq_len))


class BucketedPolicy:
    """The sched subsystem behind the same simulation interface.

    ``forecast=True`` attaches an ``ArrivalForecaster`` so padded-batch
    deferral runs under the §10 explicit horizon; the preemption hooks
    (``waiting_candidates`` / ``requeue``) are what the step-granular
    simulation drives when given a ``PreemptionPolicy``."""

    def __init__(self, forecast: bool = False):
        self.plan_cache = _plan_cache(static=False)
        self.cfg = SchedConfig(max_batch=MAX_BATCH, dp=DP,
                               starvation_age=STARVATION_AGE,
                               default_slack=10.0, defer_slack=0.02)
        self.sched = RequestScheduler(
            self.plan_cache, self.cfg,
            forecaster=ArrivalForecaster() if forecast else None)

    def submit(self, req, now: float) -> None:
        self.sched.submit(req, now)

    @property
    def pending(self) -> int:
        return self.sched.pending

    def next(self, now: float, flush: bool):
        return self.sched.next_batch(now, flush=flush)

    # -- control-loop hooks (sched/control.py) --------------------------
    def waiting_candidates(self, now: float):
        return self.sched.waiting_candidates(now)

    def requeue(self, reqs, pad_rows: int = 0) -> None:
        self.sched.requeue(reqs, pad_rows)

    @property
    def starvation_age(self) -> float:
        return self.cfg.starvation_age


def simulate(policy, reqs: list[SimRequest],
             preempt: PreemptionPolicy | None = None,
             tracker: Tracker | None = None) -> dict:
    """Step-granular discrete-event run of one serving pipeline: batches
    execute as NUM_STEPS sampler steps of their comm-model-predicted
    duration; arrivals land *between steps*, where (with ``preempt``
    set) the §10 preemption policy may park the running batch — exactly
    the engine's control point, on simulated time.

    ``tracker`` publishes the trajectory through the serving metrics
    sink (DESIGN.md §11): ``sim.*`` counters/gauges in the same
    schema-versioned stream format the real engine emits, so simulated
    and measured serving telemetry are directly comparable."""
    trk = tracker if tracker is not None else Tracker()
    i, t = 0, 0.0
    stats = {"pad_tokens": 0, "real_tokens": 0, "batches": 0,
             "max_wait": 0.0, "sla_miss": 0, "sla_met": 0, "sla_total": 0,
             "served": 0, "max_batch_s": 0.0, "preemptions": 0}

    def deliver(upto: float) -> None:
        nonlocal i
        while i < len(reqs) and reqs[i].arrival <= upto + 1e-9:
            policy.submit(reqs[i], reqs[i].arrival)
            i += 1

    while True:
        deliver(t)
        if not policy.pending:
            if i >= len(reqs):
                break
            t = reqs[i].arrival
            continue
        adm = policy.next(t, flush=i >= len(reqs))
        if adm is None:  # deferred for better packing; wait for arrivals
            t = reqs[i].arrival
            continue
        start = t
        dur = adm.plan.t_batch
        t_step = dur / NUM_STEPS
        parked = False
        for s in range(NUM_STEPS):
            t += t_step
            deliver(t)
            if preempt is not None and s < NUM_STEPS - 1:
                victim = preempt.should_preempt(
                    policy.waiting_candidates(t),
                    remaining_steps=NUM_STEPS - 1 - s, t_step=t_step,
                    running_age=t - min(r.submitted for r in adm.requests),
                    starvation_age=policy.starvation_age,
                    running_seq=adm.seq_len, running_k=len(adm.requests),
                    max_batch=MAX_BATCH)
                if victim is not None:
                    policy.requeue(adm.requests, adm.pad_rows)
                    stats["preemptions"] += 1
                    trk.count("sim.preemptions", tags={"seq": adm.seq_len})
                    parked = True
                    break
        if parked:
            continue
        for r in adm.requests:
            stats["max_wait"] = max(stats["max_wait"], start - r.submitted)
            if r.sla is not None:
                stats["sla_total"] += 1
                if t - r.submitted > r.sla:
                    stats["sla_miss"] += 1
                    trk.count("sim.sla_miss", tags={"seq": adm.seq_len})
                else:
                    stats["sla_met"] += 1
                    trk.count("sim.sla_met", tags={"seq": adm.seq_len})
        stats["pad_tokens"] += adm.pad_rows * adm.seq_len
        stats["real_tokens"] += len(adm.requests) * adm.seq_len
        stats["served"] += len(adm.requests)
        stats["batches"] += 1
        stats["max_batch_s"] = max(stats["max_batch_s"], dur)
        trk.count("sim.batches", tags={"seq": adm.seq_len})
        trk.count("sim.served", len(adm.requests), tags={"seq": adm.seq_len})
        if adm.pad_rows:
            trk.count("sim.pad_tokens", adm.pad_rows * adm.seq_len,
                      tags={"seq": adm.seq_len})
        trk.log("sim.batch_s", dur, step=stats["batches"],
                tags={"seq": adm.seq_len, "rows": adm.batch_rows})
    stats["makespan_s"] = t
    trk.log("sim.makespan_s", t)
    stats["sla_met_frac"] = (stats["sla_met"] / stats["sla_total"]
                             if stats["sla_total"] else 1.0)
    return stats


@functools.lru_cache(maxsize=1)
def _compare() -> tuple[dict, dict, BucketedPolicy]:
    """Both policies over the same stream — deterministic, so memoized
    (run(), records() and the smoke asserts all consume it)."""
    reqs = request_stream()
    greedy = simulate(GreedyPolicy(), [dataclasses.replace(r) for r in reqs])
    bucketed_policy = BucketedPolicy()
    bucketed = simulate(bucketed_policy,
                        [dataclasses.replace(r) for r in reqs])
    return greedy, bucketed, bucketed_policy


def compare_preemption(reqs: list[SimRequest],
                       forecast: bool = True) -> tuple[dict, dict]:
    """The ISSUE-5 comparison: the PR-3 non-preemptive scheduler vs the
    §10 control loop (preemption + forecaster) over the SAME stream."""
    plain = simulate(BucketedPolicy(),
                     [dataclasses.replace(r) for r in reqs])
    preemptive = simulate(BucketedPolicy(forecast=forecast),
                          [dataclasses.replace(r) for r in reqs],
                          preempt=PreemptionPolicy())
    return plain, preemptive


@functools.lru_cache(maxsize=1)
def _compare_bursty() -> tuple[dict, dict]:
    return compare_preemption(bursty_stream())


def _policy_row(scenario: str, name: str, s: dict) -> str:
    return row(
        f"sched_sweep/N{N_MACHINES}M{M_PER_MACHINE}/{scenario}/{name}",
        s["makespan_s"] * 1e6,
        f"padded_tokens={s['pad_tokens']},batches={s['batches']},"
        f"max_wait_s={s['max_wait']:.2f},sla_miss={s['sla_miss']},"
        f"sla_met_frac={s['sla_met_frac']:.3f},"
        f"preemptions={s['preemptions']}")


def run() -> list[str]:
    greedy, bucketed, policy = _compare()
    rows = []
    for name, s in (("greedy", greedy), ("bucketed", bucketed)):
        rows.append(row(
            f"sched_sweep/N{N_MACHINES}M{M_PER_MACHINE}/{name}/makespan",
            s["makespan_s"] * 1e6,
            f"padded_tokens={s['pad_tokens']},batches={s['batches']},"
            f"max_wait_s={s['max_wait']:.2f},sla_miss={s['sla_miss']}"))
    rows.append(row(
        f"sched_sweep/N{N_MACHINES}M{M_PER_MACHINE}/reduction",
        (greedy["makespan_s"] - bucketed["makespan_s"]) * 1e6,
        f"makespan_speedup={greedy['makespan_s'] / bucketed['makespan_s']:.2f}x,"
        f"pad_tokens={greedy['pad_tokens']}->{bucketed['pad_tokens']}"))
    for (rows_, seq), choice in sorted(policy.plan_cache.plans.items()):
        h = choice.hplan
        rows.append(row(
            f"sched_sweep/N{N_MACHINES}M{M_PER_MACHINE}/plan/seq{seq}/b{rows_}",
            choice.t_step * 1e6,
            f"cfg={h.cfg},pp={h.pp},Pu={h.sp.p_ulysses},Pr={h.sp.p_ring},"
            f"patches={choice.num_patches}"))
    plain, preemptive = _compare_bursty()
    rows.append(_policy_row("bursty", "non-preemptive", plain))
    rows.append(_policy_row("bursty", "preemptive", preemptive))
    return rows


def records() -> list[dict]:
    """Structured BENCH_sched_sweep.json records: both policies' queue
    metrics plus every per-bucket plan selection (fit-target field kept
    for symmetry with the other sweeps)."""
    greedy, bucketed, policy = _compare()
    out = [{
        "name": f"sched_sweep/N{N_MACHINES}M{M_PER_MACHINE}/{name}",
        "policy": name,
        "n_machines": N_MACHINES,
        "m_per_machine": M_PER_MACHINE,
        "dp": DP,
        "metrics": s,
        "measured_step_us": None,
    } for name, s in (("greedy", greedy), ("bucketed", bucketed))]
    for (rows_, seq), choice in sorted(policy.plan_cache.plans.items()):
        h = choice.hplan
        out.append({
            "name": (f"sched_sweep/N{N_MACHINES}M{M_PER_MACHINE}"
                     f"/plan/seq{seq}/b{rows_}"),
            # workload.batch is the per-replica slice the prediction was
            # scored on (rows // dp) — the contract calibrate_comm.py's
            # predict_us() relies on; batch_rows keeps the global size
            "workload": {"batch": max(rows_ // DP, 1), "seq": seq,
                         "heads": HEADS, "head_dim": HEAD_DIM,
                         "n_layers": N_LAYERS},
            "batch_rows": rows_,
            "dp": DP,
            "n_machines": N_MACHINES,
            "m_per_machine": M_PER_MACHINE,
            "plan": {"cfg": h.cfg, "pp": h.pp, "p_ulysses": h.sp.p_ulysses,
                     "p_ring": h.sp.p_ring,
                     "num_patches": choice.num_patches},
            "predicted_step_us": choice.t_step * 1e6,
            "predicted_breakdown": {k: v for k, v in choice.pred.items()
                                    if k != "t_step"},
            "measured_step_us": None,
        })
    plain, preemptive = _compare_bursty()
    for name, s in (("non-preemptive", plain), ("preemptive", preemptive)):
        out.append({
            "name": f"sched_sweep/N{N_MACHINES}M{M_PER_MACHINE}"
                    f"/bursty/{name}",
            "policy": name,
            "scenario": "bursty",
            "n_machines": N_MACHINES,
            "m_per_machine": M_PER_MACHINE,
            "dp": DP,
            "metrics": s,
            "measured_step_us": None,
        })
    return out


# ---------------------------------------------------------------------------
# --smoke: assert the acceptance claims + drive a real DiTServer
# ---------------------------------------------------------------------------

def _assert_analytic() -> list[str]:
    greedy, bucketed, policy = _compare()
    msgs = []
    assert bucketed["served"] == greedy["served"] > 0
    assert bucketed["pad_tokens"] < greedy["pad_tokens"], (
        bucketed["pad_tokens"], greedy["pad_tokens"])
    assert bucketed["makespan_s"] < greedy["makespan_s"], (
        bucketed["makespan_s"], greedy["makespan_s"])
    # starvation bound: an overdue bucket is served next, so no wait can
    # exceed the bound by more than the batches that were already ahead
    bound = STARVATION_AGE + len(SEQS) * bucketed["max_batch_s"]
    assert bucketed["max_wait"] <= bound, (bucketed["max_wait"], bound)
    # one plan per bucket shape, selected via plan_hybrid
    assert len(policy.plan_cache.plans) >= len(SEQS)
    msgs.append(f"analytic: pad {greedy['pad_tokens']} -> "
                f"{bucketed['pad_tokens']} tokens, makespan "
                f"{greedy['makespan_s']:.1f}s -> {bucketed['makespan_s']:.1f}s, "
                f"max_wait {bucketed['max_wait']:.1f}s <= bound {bound:.1f}s")
    return msgs


def _assert_preemption(tmpdir: pathlib.Path | None = None) -> list[str]:
    """ISSUE-5 acceptance: on the seeded bursty stream the preemptive
    control loop achieves a STRICTLY higher SLA-met fraction than the
    PR-3 non-preemptive scheduler, every request is still served, the
    starvation bound survives preemption, and a trace round-trips
    through --emit-trace/--replay bit-for-bit."""
    import tempfile

    plain, preemptive = _compare_bursty()
    assert preemptive["served"] == plain["served"] > 0, (
        preemptive["served"], plain["served"])
    assert preemptive["preemptions"] > 0, "bursty stream never preempted"
    assert plain["preemptions"] == 0
    assert preemptive["sla_met_frac"] > plain["sla_met_frac"], (
        preemptive["sla_met_frac"], plain["sla_met_frac"])
    # starvation bound with preemption: overdue batches are immune and
    # served first, so a wait can exceed the bound only by batches that
    # were already in flight (plus their restart)
    bound = STARVATION_AGE + (len(SEQS) + 1) * preemptive["max_batch_s"]
    assert preemptive["max_wait"] <= bound, (preemptive["max_wait"], bound)

    # replay round-trip: a saved trace drives an identical simulation
    reqs = bursty_stream()
    with tempfile.TemporaryDirectory(dir=tmpdir) as td:
        p = pathlib.Path(td) / "trace.json"
        save_trace(reqs, p)
        replayed = load_trace(p)
    assert [(r.rid, r.seq_len, r.arrival, r.sla) for r in replayed] == \
           [(r.rid, r.seq_len, r.arrival, r.sla) for r in reqs]
    plain2, preemptive2 = compare_preemption(replayed)
    assert (plain2, preemptive2) == (plain, preemptive), \
        "trace replay diverged from the generating run"
    return [f"preemption: bursty sla_met "
            f"{plain['sla_met_frac']:.3f} -> {preemptive['sla_met_frac']:.3f} "
            f"({preemptive['preemptions']} preemptions, "
            f"max_wait {preemptive['max_wait']:.2f}s <= {bound:.2f}s), "
            f"replay round-trip exact"]


def _smoke_engine() -> list[str]:
    """Mixed 256/512/1024 queue through a real (tiny) DiTServer on 8
    simulated CPU devices: scheduler path end-to-end, one jit trace per
    bucket shape."""
    import os

    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

    import dataclasses as dc

    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.core import PipelineConfig, SPConfig
    from repro.launch.mesh import make_hybrid_mesh
    from repro.models import get_model
    from repro.serving import DiTRequest, DiTServer, DriftPolicy, SamplerConfig

    assert len(jax.devices()) == 8, (
        "smoke needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
        f"before jax initializes (got {len(jax.devices())} devices)")
    cfg = dc.replace(get_reduced("flux-12b"), dtype="float32")
    bundle = get_model(cfg)
    params, axes = bundle.init(cfg, jax.random.PRNGKey(0), 1)
    mesh = make_hybrid_mesh(cfg=1, pipe=2, data=2, model=2)
    sp = SPConfig(strategy="swift_torus", sp_axes=("model",),
                  batch_axes=("data",), pp_axis="pipe")
    srv = DiTServer(params, cfg, mesh, sp,
                    sampler=SamplerConfig(
                        num_steps=3,
                        pipeline=PipelineConfig(pp=2, warmup_steps=1)),
                    max_batch=2, param_axes=axes,
                    drift=DriftPolicy(threshold=0.05))
    lens = [256, 512, 1024, 256, 512, 256]
    for i, n in enumerate(lens):
        srv.submit(DiTRequest(rid=i, seq_len=n, sla=SLAS[n],
                              drift_threshold=0.05 if i % 2 else None))
    results = srv.serve()
    assert sorted(r.rid for r in results) == list(range(len(lens)))
    by_rid = {r.rid: r for r in results}
    for i, n in enumerate(lens):
        r = by_rid[i]
        assert r.latents.shape == (n, 64), r.latents.shape
        assert bool(jnp.all(jnp.isfinite(r.latents)))
        assert len(r.kv_drift) == 3
    shapes = set(srv.plan_cache.plans)
    # one compiled trace per bucket shape, hits for every repeat
    assert srv.plan_cache.traces == len(shapes), (
        srv.plan_cache.traces, shapes)
    assert srv.plan_cache.hits == srv.scheduler.admissions - len(shapes)
    tot = srv.scheduler.totals()
    assert tot.admitted == len(lens)
    return [f"engine: served {len(results)} mixed requests over "
            f"{len(shapes)} bucket shapes, {srv.plan_cache.traces} traces, "
            f"{srv.plan_cache.hits} step-cache hits, "
            f"{tot.padded_rows} padded rows"]


def _replay_rows(reqs: list[SimRequest], label: str) -> list[str]:
    greedy = simulate(GreedyPolicy(), [dataclasses.replace(r) for r in reqs])
    plain, preemptive = compare_preemption(reqs)
    return [_policy_row(label, "greedy", greedy),
            _policy_row(label, "non-preemptive", plain),
            _policy_row(label, "preemptive", preemptive)]


def main(argv: list[str] | None = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="assert the acceptance claims + engine e2e")
    ap.add_argument("--replay", type=pathlib.Path, default=None,
                    help="re-run the policies over a recorded trace.json")
    ap.add_argument("--emit-trace", type=pathlib.Path, default=None,
                    help="write the --scenario stream as a trace.json")
    ap.add_argument("--scenario", choices=sorted(SCENARIOS),
                    default="bursty")
    ap.add_argument("--seed", type=int, default=None,
                    help="generator seed (default: the scenario's)")
    ap.add_argument("--metrics", type=pathlib.Path, default=None,
                    metavar="OUT.JSONL",
                    help="stream the --scenario preemptive simulation's "
                         "sim.* trajectory through the serving metrics "
                         "sink (DESIGN.md §11)")
    args = ap.parse_args(sys.argv[1:] if argv is None else argv)

    if args.metrics is not None:
        gen = SCENARIOS[args.scenario]
        reqs = gen(seed=args.seed) if args.seed is not None else gen()
        with JsonlTracker(args.metrics) as trk:
            stats = simulate(BucketedPolicy(forecast=True),
                             [dataclasses.replace(r) for r in reqs],
                             preempt=PreemptionPolicy(), tracker=trk)
        print(f"# wrote {args.metrics} "
              f"(sla_met_frac={stats['sla_met_frac']:.3f}, "
              f"{stats['preemptions']} preemptions)", file=sys.stderr)
        return

    if args.emit_trace is not None:
        gen = SCENARIOS[args.scenario]
        reqs = gen(seed=args.seed) if args.seed is not None else gen()
        save_trace(reqs, args.emit_trace)
        print(f"# wrote {len(reqs)} requests to {args.emit_trace}",
              file=sys.stderr)
        return

    if args.replay is not None:
        for line in _replay_rows(load_trace(args.replay),
                                 f"replay[{args.replay.stem}]"):
            print(line)
        return

    for line in run():
        print(line)
    if args.smoke:
        for m in _assert_analytic():
            print(f"# {m}", file=sys.stderr)
        for m in _assert_preemption():
            print(f"# {m}", file=sys.stderr)
        for m in _smoke_engine():
            print(f"# {m}", file=sys.stderr)
        print("# sched_sweep smoke OK", file=sys.stderr)


if __name__ == "__main__":
    main()
