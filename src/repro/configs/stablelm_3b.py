"""stablelm-3b [dense] — partial rotary (25%), LayerNorm
[hf:stabilityai/stablelm-2-1_6b]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab=50304,
    rope="rope",
    rope_pct=0.25,
    act="swiglu",
    norm="layernorm",
    sharding_overrides=(("vocab", ("data",)),),
    citation="hf:stabilityai/stablelm-2-1_6b",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512
    )
