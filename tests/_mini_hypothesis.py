"""Deterministic fallback for the tiny slice of `hypothesis` this suite uses.

The container image does not ship `hypothesis`; rather than skip the
property tests (they guard the planner/comm-model invariants the paper's
claims rest on), conftest.py installs this module as ``hypothesis`` when
the real package is absent.  It reimplements exactly the API surface the
tests touch:

    @given(st.integers(lo, hi), st.sampled_from(seq), st.booleans())
    @settings(max_examples=N, deadline=None)

Semantics: each ``given``-wrapped test runs ``max_examples`` times with
examples drawn from a PRNG seeded by the test's qualified name, so runs
are reproducible and independent of test order.  No shrinking, no
database — on failure the raw example values appear in the assertion
traceback.  See tests/README.md.
"""
from __future__ import annotations

import random


class SearchStrategy:
    """A sampleable value source (the only thing our tests need)."""

    def __init__(self, sample, name):
        self._sample = sample
        self.name = name

    def sample(self, rng: random.Random):
        return self._sample(rng)

    def __repr__(self):
        return self.name


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return SearchStrategy(
            lambda rng: rng.randint(min_value, max_value),
            f"integers({min_value}, {max_value})",
        )

    @staticmethod
    def sampled_from(elements):
        elems = list(elements)
        return SearchStrategy(lambda rng: rng.choice(elems), f"sampled_from({elems})")

    @staticmethod
    def booleans():
        return SearchStrategy(lambda rng: rng.random() < 0.5, "booleans()")


strategies = _Strategies()

_DEFAULT_MAX_EXAMPLES = 100


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Records max_examples on the function; works above or below @given."""

    def deco(fn):
        # @settings below @given: fn is the given-wrapper -> update its knob.
        # @settings above @given: fn is the raw test -> @given reads the attr.
        fn._mh_max_examples = max_examples
        return fn

    return deco


def given(*strats: SearchStrategy):
    def deco(fn):
        # NOTE: no functools.wraps — copying fn's signature would make
        # pytest treat the example parameters as fixture requests.
        def wrapper(*args, **kwargs):
            n = getattr(fn, "_mh_max_examples",
                        getattr(wrapper, "_mh_max_examples", _DEFAULT_MAX_EXAMPLES))
            rng = random.Random(f"mini-hypothesis:{fn.__module__}.{fn.__qualname__}")
            for i in range(n):
                example = tuple(s.sample(rng) for s in strats)
                try:
                    fn(*args, *example, **kwargs)
                except Exception as e:  # annotate which example failed
                    raise AssertionError(
                        f"falsifying example #{i}: "
                        f"{fn.__name__}{example!r}"
                    ) from e

        for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
            setattr(wrapper, attr, getattr(fn, attr))
        wrapper._mh_max_examples = getattr(fn, "_mh_max_examples", None) or \
            _DEFAULT_MAX_EXAMPLES
        return wrapper

    return deco
