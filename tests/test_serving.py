"""Serving engines: DiT sampling server + AR continuous batching."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import SPConfig
from repro.models import ParallelContext, get_model
from repro.serving import (
    ARRequest,
    ARServer,
    DiTRequest,
    DiTServer,
    SamplerConfig,
    sample,
    toy_vae_decode,
)

SP = SPConfig(strategy="full", sp_axes=("model",), batch_axes=("data",))


@pytest.fixture(scope="module")
def dit_setup():
    cfg = dataclasses.replace(get_reduced("flux-12b"), dtype="float32")
    bundle = get_model(cfg)
    params, _ = bundle.init(cfg, jax.random.PRNGKey(0), 1)
    return cfg, params


def test_dit_server_batches_same_length(dit_setup, mesh1):
    cfg, params = dit_setup
    srv = DiTServer(params, cfg, mesh1, SP,
                    sampler=SamplerConfig(num_steps=2), max_batch=4)
    for i in range(5):
        srv.submit(DiTRequest(rid=i, seq_len=32 if i < 3 else 64))
    results = srv.serve()
    assert sorted(r.rid for r in results) == [0, 1, 2, 3, 4]
    by_rid = {r.rid: r for r in results}
    assert by_rid[0].latents.shape == (32, 64)
    assert by_rid[4].latents.shape == (64, 64)
    for r in results:
        assert bool(jnp.all(jnp.isfinite(r.latents)))
        assert r.sampling_steps == 2


def test_sampler_deterministic_given_key(dit_setup, mesh1):
    cfg, params = dit_setup
    ctx = ParallelContext(mesh1, SP, "prefill")
    cond = jnp.zeros((1, 256, cfg.d_model), jnp.float32)
    a = sample(params, cfg, ctx, key=jax.random.PRNGKey(7), batch=1,
               seq_len=32, cond=cond, sc=SamplerConfig(num_steps=3))
    b = sample(params, cfg, ctx, key=jax.random.PRNGKey(7), batch=1,
               seq_len=32, cond=cond, sc=SamplerConfig(num_steps=3))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_toy_vae_decode_shapes():
    lat = jnp.zeros((2, 16, 64))
    px = toy_vae_decode(lat)
    assert px.shape == (2, 64, 3)


def test_ar_server_matches_manual_greedy(mesh1):
    cfg = dataclasses.replace(get_reduced("qwen2-1.5b"), dtype="float32")
    bundle = get_model(cfg)
    params, _ = bundle.init(cfg, jax.random.PRNGKey(0), 1)
    prompt = jnp.array([3, 7, 11], jnp.int32)

    srv = ARServer(params, cfg, mesh1, SP, batch_slots=2, max_len=32)
    srv.submit(ARRequest(rid=1, prompt=prompt, max_new_tokens=5))
    srv.submit(ARRequest(rid=2, prompt=prompt, max_new_tokens=5))
    results = srv.serve()
    assert set(results) == {1, 2}
    assert results[1] == results[2]  # identical prompts, greedy decode
    assert len(results[1]) == 5

    # manual greedy reference
    ctx = ParallelContext(mesh1, SP, "decode")
    caches = bundle.init_caches(cfg, 1, 32, jnp.float32)
    toks = list(map(int, prompt))
    out = []
    for t in range(8):
        cur = jnp.array([[toks[t] if t < len(toks) else out[-1]]], jnp.int32)
        logit, caches = bundle.step(params, {"tokens": cur}, caches,
                                    jnp.int32(t), cfg, ctx)
        if t >= len(toks) - 1:
            out.append(int(jnp.argmax(logit[0])))
    assert results[1] == out[:5]


def test_ar_server_queue_overflow_handled(mesh1):
    cfg = dataclasses.replace(get_reduced("qwen2-1.5b"), dtype="float32")
    bundle = get_model(cfg)
    params, _ = bundle.init(cfg, jax.random.PRNGKey(0), 1)
    srv = ARServer(params, cfg, mesh1, SP, batch_slots=2, max_len=16)
    for i in range(5):  # more requests than slots
        srv.submit(ARRequest(rid=i, prompt=jnp.array([i + 1], jnp.int32),
                             max_new_tokens=3))
    results = srv.serve()
    assert set(results) == set(range(5))
    assert all(len(v) == 3 for v in results.values())
