"""Runs the multi-device SP suite in ONE subprocess with 8 fake devices.

The outer pytest run keeps 1 device (assignment requirement); the inner
run sets XLA_FLAGS before jax initializes.  pyproject excludes
tests/multidevice from outer collection.

The inner suite is split by the ``slow`` marker: the default run skips
the heaviest e2e tests (they have a dedicated CI job — see the ``slow``
job in .github/workflows/ci.yml) so the tier-1 ``python -m pytest -x -q``
stays inside its time budget.  Set ``RUN_SLOW_TESTS=1`` to run the slow
set (``test_multidevice_slow_suite``) locally.
"""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)


def _run_inner(marker_expr: str) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    proc = subprocess.run(
        [sys.executable, "-m", "pytest",
         os.path.join(HERE, "multidevice"), "-q", "-p", "no:cacheprovider",
         "-m", marker_expr],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    if proc.returncode != 0:
        tail = "\n".join((proc.stdout + proc.stderr).splitlines()[-60:])
        pytest.fail(f"inner multidevice suite failed:\n{tail}")


@pytest.mark.timeout(1800)
def test_multidevice_suite():
    _run_inner("not slow")


@pytest.mark.timeout(1800)
@pytest.mark.skipif(os.environ.get("RUN_SLOW_TESTS") != "1",
                    reason="slow e2e set runs in the dedicated CI job "
                           "(RUN_SLOW_TESTS=1 to run locally)")
def test_multidevice_slow_suite():
    _run_inner("slow")
