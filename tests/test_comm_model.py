"""Appendix-D communication volume model: Lemma D.1 + paper claims."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import plan, usp_plan
from repro.core.comm_model import (
    LayerWorkload,
    NetworkModel,
    attention_layer_latency,
    swift_inter_volume,
    usp_inter_volume,
)

BLHD = 1.0e6


@given(st.sampled_from([2, 3, 4, 6, 8]), st.sampled_from([2, 4, 8]),
       st.integers(1, 96))
@settings(max_examples=300, deadline=None)
def test_lemma_d1_swift_never_more_inter_volume(n, m, heads):
    """V_USP >= V_SFU for the planner's own (P_u, P_r) when 2<=M<=P_u<=N —
    and empirically for every planner output with P_u != 2 (the paper's
    stated exception)."""
    sp = plan(n, m, heads)
    up = usp_plan(n, m, heads)
    v_s = swift_inter_volume(sp, BLHD)
    v_u = usp_inter_volume(up, BLHD)
    if sp.p_ulysses == 2:
        return  # paper: the single case where Ulysses may exceed Ring
    assert v_s <= v_u * (1 + 1e-9), (n, m, heads, sp, v_s, v_u)


def test_volume_formulas_match_paper_simple_cases():
    # P_u >= N: V_SFU = 4 (N-1)/N * BLHD / N          (eq. 6)
    p = plan(4, 2, 8)  # sp=8, heads=8 -> P_u=8 >= N=4
    assert math.isclose(swift_inter_volume(p, BLHD), 4 * 3 / 4 * BLHD / 4)
    # P_r >= N: V_USP = 2 (N-1) BLHD / N              (eq. 4)
    u = usp_plan(4, 2, 1)  # P_u=1, P_r=8 >= N
    assert math.isclose(usp_inter_volume(u, BLHD), 2 * 3 * BLHD / 4)


def test_single_machine_no_inter_volume():
    p = plan(1, 8, 24)
    assert swift_inter_volume(p, BLHD) == 0.0
    assert usp_inter_volume(usp_plan(1, 8, 24), BLHD) == 0.0


def test_ulysses_volume_decreases_with_machines():
    """SwiftFusion claim: inter-machine volume per GPU shrinks ~1/N."""
    vols = []
    for n in (2, 4, 8):
        p = plan(n, 8, 64)
        vols.append(swift_inter_volume(p, BLHD))
    assert vols[0] > vols[1] > vols[2]


def test_ring_volume_flat_with_machines():
    """Ring's volume does not shrink with more machines (paper Challenge 1)."""
    v = [usp_inter_volume(usp_plan(n, 8, 1), BLHD) for n in (2, 4, 8)]
    assert v[2] > v[1] > v[0] * 0.99  # grows toward 2*BLHD asymptote


@pytest.mark.parametrize("heads", [24, 48])
def test_latency_model_swift_beats_usp_multi_machine(heads):
    """End-to-end latency model reproduces the paper's Fig. 7 direction for
    the CogVideoX-like workload on >= 3 machines."""
    wl = LayerWorkload(batch=2, seq=48_000, heads=heads, head_dim=64)
    for n in (3, 4):
        sw = attention_layer_latency(plan(n, 8, heads), wl, swift=True,
                                     overlap_inter=True)
        us = attention_layer_latency(usp_plan(n, 8, heads), wl, swift=False,
                                     overlap_inter=False)
        assert sw["t_total"] < us["t_total"], (n, sw, us)


def test_torus_overlap_reduces_total():
    wl = LayerWorkload(batch=2, seq=96_000, heads=24, head_dim=64)
    p = plan(4, 8, 24)
    tas = attention_layer_latency(p, wl, swift=True, overlap_inter=False)
    sfu = attention_layer_latency(p, wl, swift=True, overlap_inter=True)
    assert sfu["t_total"] <= tas["t_total"]
    assert sfu["t_total"] < tas["t_total"] or tas["t_inter"] <= tas["t_compute"]
