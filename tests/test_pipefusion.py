"""Hybrid parallelism (DESIGN.md §7): displaced patch pipelining
(PipeFusion), CFG parallelism, and the (cfg, pp, P_u, P_r) planner.

Runs on 1 device (strategy="full"); the 8-fake-device composition with
swift_torus lives in tests/multidevice/test_hybrid.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, get_reduced
from repro.core import PipelineConfig, SPConfig, plan_hybrid
from repro.core.pipefusion import patch_slices, stage_layers
from repro.models import ParallelContext, get_model
from repro.models.dit import COND_TOKENS, dit_forward, dit_forward_displaced
from repro.serving import DiTRequest, DiTServer, SamplerConfig, sample

SP = SPConfig(strategy="full", sp_axes=("model",), batch_axes=("data",))
SEQ = 32


@pytest.fixture(scope="module")
def dit_setup():
    cfg = dataclasses.replace(get_reduced("flux-12b"), dtype="float32")
    bundle = get_model(cfg)
    params, _ = bundle.init(cfg, jax.random.PRNGKey(0), 1)
    # de-degenerate the adaLN-zero init (a freshly-initialised DiT is the
    # identity, which would make every displaced-vs-reference check vacuous)
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(jax.random.PRNGKey(99), len(leaves))
    leaves = [l + 0.05 * jax.random.normal(k, l.shape, l.dtype)
              for l, k in zip(leaves, keys)]
    return cfg, jax.tree.unflatten(treedef, leaves)


@pytest.fixture(scope="module")
def cond(dit_setup):
    cfg, _ = dit_setup
    return jax.random.normal(jax.random.PRNGKey(1), (1, COND_TOKENS, cfg.d_model),
                             jnp.float32)


# ---------------------------------------------------------------------------
# (a) warm steps bitwise; displaced steps within tolerance
# ---------------------------------------------------------------------------

def test_all_warm_pipeline_matches_reference_bitwise(dit_setup, cond, mesh1):
    cfg, params = dit_setup
    ctx = ParallelContext(mesh1, SP, "prefill")
    key = jax.random.PRNGKey(7)
    ref = sample(params, cfg, ctx, key=key, batch=1, seq_len=SEQ, cond=cond,
                 sc=SamplerConfig(num_steps=3))
    warm = sample(params, cfg, ctx, key=key, batch=1, seq_len=SEQ, cond=cond,
                  sc=SamplerConfig(num_steps=3,
                                   pipeline=PipelineConfig(pp=2, warmup_steps=3)))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(warm))


def test_displaced_steps_close_but_not_identical(dit_setup, cond, mesh1):
    cfg, params = dit_setup
    ctx = ParallelContext(mesh1, SP, "prefill")
    key = jax.random.PRNGKey(7)
    ref = sample(params, cfg, ctx, key=key, batch=1, seq_len=SEQ, cond=cond,
                 sc=SamplerConfig(num_steps=4))
    disp = sample(params, cfg, ctx, key=key, batch=1, seq_len=SEQ, cond=cond,
                  sc=SamplerConfig(num_steps=4,
                                   pipeline=PipelineConfig(pp=2, warmup_steps=1)))
    assert bool(jnp.all(jnp.isfinite(disp)))
    diff = float(jnp.max(jnp.abs(ref - disp)))
    scale = float(jnp.max(jnp.abs(ref)))
    assert diff < 0.05 * scale, (diff, scale)  # one-step-stale approximation
    assert diff > 0.0  # the displaced path genuinely ran


def test_displaced_forward_with_fresh_state_matches_reference(dit_setup, cond,
                                                              mesh1):
    """stale == fresh  =>  displaced forward == full forward (up to the
    partial-merge float association)."""
    cfg, params = dit_setup
    ctx = ParallelContext(mesh1, SP, "prefill")
    lat = jax.random.normal(jax.random.PRNGKey(3), (1, SEQ, 64), jnp.float32)
    tt = jnp.full((1,), 0.5, jnp.float32)
    v_ref, state = dit_forward(params, cfg, ctx, latents=lat, cond=cond,
                               timesteps=tt, return_layer_kv=True)
    for n_patches in (2, 4):
        v_disp, new_state = dit_forward_displaced(
            params, cfg, ctx, latents=lat, cond=cond, timesteps=tt,
            kv_state=state, num_patches=n_patches, pp=2)
        np.testing.assert_allclose(np.asarray(v_disp), np.asarray(v_ref),
                                   atol=5e-5, rtol=1e-4)
        # the state write-back covers every row: fresh == stale here
        np.testing.assert_allclose(np.asarray(new_state.k),
                                   np.asarray(state.k), atol=5e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# (b) cfg-parallel sampling == sequential CFG
# ---------------------------------------------------------------------------

def test_cfg_parallel_matches_sequential_cfg(dit_setup, cond, mesh1):
    cfg, params = dit_setup
    ctx = ParallelContext(mesh1, SP, "prefill")
    key = jax.random.PRNGKey(11)
    cond2 = jnp.tile(cond, (2, 1, 1))
    seq = sample(params, cfg, ctx, key=key, batch=2, seq_len=SEQ, cond=cond2,
                 sc=SamplerConfig(num_steps=3, guidance_scale=4.0))
    par = sample(params, cfg, ctx, key=key, batch=2, seq_len=SEQ, cond=cond2,
                 sc=SamplerConfig(num_steps=3, guidance_scale=4.0,
                                  cfg_parallel=True))
    np.testing.assert_allclose(np.asarray(seq), np.asarray(par),
                               atol=1e-4, rtol=1e-4)


def test_cfg_parallel_composes_with_pipeline(dit_setup, cond, mesh1):
    cfg, params = dit_setup
    ctx = ParallelContext(mesh1, SP, "prefill")
    key = jax.random.PRNGKey(13)
    ref = sample(params, cfg, ctx, key=key, batch=1, seq_len=SEQ, cond=cond,
                 sc=SamplerConfig(num_steps=4, guidance_scale=4.0))
    hyb = sample(params, cfg, ctx, key=key, batch=1, seq_len=SEQ, cond=cond,
                 sc=SamplerConfig(num_steps=4, guidance_scale=4.0,
                                  cfg_parallel=True,
                                  pipeline=PipelineConfig(pp=2, warmup_steps=1)))
    assert bool(jnp.all(jnp.isfinite(hyb)))
    diff = float(jnp.max(jnp.abs(ref - hyb)))
    assert diff < 0.05 * float(jnp.max(jnp.abs(ref))), diff


def test_cfg_weights_match_classic_pair(dit_setup, cond, mesh1):
    """cfg_weights=(g, 1-g) is the classic CFG pair, parallel or not."""
    cfg, params = dit_setup
    ctx = ParallelContext(mesh1, SP, "prefill")
    key = jax.random.PRNGKey(17)
    classic = sample(params, cfg, ctx, key=key, batch=2, seq_len=SEQ,
                     cond=jnp.tile(cond, (2, 1, 1)),
                     sc=SamplerConfig(num_steps=3, guidance_scale=4.0,
                                      cfg_parallel=True))
    weighted = sample(params, cfg, ctx, key=key, batch=2, seq_len=SEQ,
                      cond=jnp.tile(cond, (2, 1, 1)),
                      sc=SamplerConfig(num_steps=3,
                                       cfg_weights=(4.0, -3.0),
                                       cfg_parallel=True))
    np.testing.assert_allclose(np.asarray(classic), np.asarray(weighted),
                               atol=1e-5, rtol=1e-5)


def test_cfg_degree_3_weighted_recombine(dit_setup, mesh1):
    """k=3 guidance (two conditionings + uncond) == the hand-computed
    weighted sum of three separate forwards, in both the cfg-parallel and
    the sequential general-degree paths (ROADMAP k>2 item)."""
    from repro.models.dit import dit_forward
    from repro.serving.sampler import sample_step

    cfg, params = dit_setup
    ctx = ParallelContext(mesh1, SP, "prefill")
    weights = (3.0, 1.5, -3.5)  # sums to 1: Σ g_i cond_i + (1-Σ g_i) uncond
    c1 = jax.random.normal(jax.random.PRNGKey(21),
                           (1, COND_TOKENS, cfg.d_model), jnp.float32)
    c2 = jax.random.normal(jax.random.PRNGKey(22),
                           (1, COND_TOKENS, cfg.d_model), jnp.float32)
    conds = jnp.stack([c1, c2, jnp.zeros_like(c1)], axis=0)  # [3, B, C, d]
    x = jax.random.normal(jax.random.PRNGKey(23), (1, SEQ, 64), jnp.float32)
    tt = jnp.full((1,), 0.7, jnp.float32)
    # hand-computed reference
    v_ref = sum(
        w * dit_forward(params, cfg, ctx, latents=x, cond=c, timesteps=tt)
        for w, c in zip(weights, [c1, c2, jnp.zeros_like(c1)]))
    x_ref = x - 0.1 * v_ref
    for par in (True, False):
        sc = SamplerConfig(num_steps=10, cfg_weights=weights,
                           cfg_parallel=par)
        assert sc.cfg_degree == 3 and sc.guided
        x_new = sample_step(params, cfg, ctx, x, conds, jnp.float32(0.7),
                            jnp.float32(0.1), sc)
        np.testing.assert_allclose(np.asarray(x_new), np.asarray(x_ref),
                                   atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# (b2) staleness control: resync_every + the surfaced kv drift metric
# ---------------------------------------------------------------------------

def test_warm_step_schedule():
    p = PipelineConfig(pp=2, warmup_steps=2, resync_every=3)
    assert [p.warm_step(i) for i in range(9)] == [
        True, True, False, False, True, False, False, True, False]
    p0 = PipelineConfig(pp=2, warmup_steps=1)  # never re-sync (PipeFusion)
    assert [p0.warm_step(i) for i in range(4)] == [True, False, False, False]
    p1 = PipelineConfig(pp=2, warmup_steps=1, resync_every=1)
    assert all(p1.warm_step(i) for i in range(4))  # every step synchronous


def test_resync_every_step_matches_reference_bitwise(dit_setup, cond, mesh1):
    """resync_every=1 forces every step synchronous => identical to the
    plain sampler, staleness fully eliminated."""
    cfg, params = dit_setup
    ctx = ParallelContext(mesh1, SP, "prefill")
    key = jax.random.PRNGKey(7)
    ref = sample(params, cfg, ctx, key=key, batch=1, seq_len=SEQ, cond=cond,
                 sc=SamplerConfig(num_steps=4))
    resync = sample(params, cfg, ctx, key=key, batch=1, seq_len=SEQ,
                    cond=cond,
                    sc=SamplerConfig(num_steps=4,
                                     pipeline=PipelineConfig(
                                         pp=2, warmup_steps=1,
                                         resync_every=1)))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(resync))


def test_periodic_resync_tightens_displaced_error(dit_setup, cond, mesh1):
    cfg, params = dit_setup
    ctx = ParallelContext(mesh1, SP, "prefill")
    key = jax.random.PRNGKey(7)
    ref = sample(params, cfg, ctx, key=key, batch=1, seq_len=SEQ, cond=cond,
                 sc=SamplerConfig(num_steps=6))

    def err(resync):
        out = sample(params, cfg, ctx, key=key, batch=1, seq_len=SEQ,
                     cond=cond,
                     sc=SamplerConfig(num_steps=6,
                                      pipeline=PipelineConfig(
                                          pp=2, warmup_steps=1,
                                          resync_every=resync)))
        return float(jnp.max(jnp.abs(out - ref)))

    assert err(2) <= err(0) + 1e-7  # periodic re-sync never hurts


def test_kv_drift_per_item_isolates_batch_elements():
    """The per-request drift breakdown must not average one request's
    staleness into another's (the serving policy acts per request)."""
    from repro.core.pipefusion import KVState, kv_drift

    k = jnp.ones((2, 3, 4, 2, 2))  # [L, B=3, T, H, D]
    old = KVState(k=k, v=k)
    new_k = k.at[:, 1].add(1.0)  # only batch element 1 drifts
    new = KVState(k=new_k, v=k)
    per = kv_drift(old, new, per_item=True)
    assert per.shape == (3,)
    assert float(per[0]) == 0.0 and float(per[2]) == 0.0
    assert float(per[1]) > 0.0
    scalar = kv_drift(old, new)
    assert 0.0 < float(scalar) < float(per[1])


def test_sampler_surfaces_kv_drift(dit_setup, cond, mesh1):
    cfg, params = dit_setup
    ctx = ParallelContext(mesh1, SP, "prefill")
    metrics: list[dict] = []
    sample(params, cfg, ctx, key=jax.random.PRNGKey(5), batch=1, seq_len=SEQ,
           cond=cond,
           sc=SamplerConfig(num_steps=4,
                            pipeline=PipelineConfig(pp=2, warmup_steps=1,
                                                    resync_every=2)),
           metrics=metrics)
    assert [m["step"] for m in metrics] == [0, 1, 2, 3]
    assert [m["warm"] for m in metrics] == [True, False, True, False]
    assert all(m["kv_drift"] == 0.0 for m in metrics if m["warm"])
    displaced = [m["kv_drift"] for m in metrics if not m["warm"]]
    assert displaced and all(d > 0.0 for d in displaced)
    assert all(len(m["kv_drift_per_request"]) == 1 for m in metrics)


def test_pipelined_sequential_cfg_rejected(dit_setup, cond, mesh1):
    cfg, params = dit_setup
    ctx = ParallelContext(mesh1, SP, "prefill")
    with pytest.raises(NotImplementedError):
        sample(params, cfg, ctx, key=jax.random.PRNGKey(0), batch=1,
               seq_len=SEQ, cond=cond,
               sc=SamplerConfig(num_steps=2, guidance_scale=4.0,
                                pipeline=PipelineConfig(pp=2, warmup_steps=1)))


# ---------------------------------------------------------------------------
# engine drive
# ---------------------------------------------------------------------------

def test_dit_server_runs_hybrid_sampler(dit_setup, mesh1):
    cfg, params = dit_setup
    srv = DiTServer(params, cfg, mesh1, SP,
                    sampler=SamplerConfig(num_steps=3, guidance_scale=3.0,
                                          cfg_parallel=True,
                                          pipeline=PipelineConfig(
                                              pp=2, warmup_steps=1)),
                    max_batch=2)
    for i in range(3):
        srv.submit(DiTRequest(rid=i, seq_len=SEQ))
    results = srv.serve()
    assert sorted(r.rid for r in results) == [0, 1, 2]
    for r in results:
        assert r.latents.shape == (SEQ, 64)
        assert bool(jnp.all(jnp.isfinite(r.latents)))


# ---------------------------------------------------------------------------
# (c) hybrid planner over the seed model zoo
# ---------------------------------------------------------------------------

def test_plan_hybrid_valid_for_all_seed_configs():
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        if cfg.attention_free:
            continue
        for n, m in ((2, 8), (4, 8), (2, 16)):
            for cfg_par in (False, True):
                for pp in (1, 2):
                    h = plan_hybrid(n, m, cfg.n_heads, cfg.n_kv_heads,
                                    cfg_parallel=cfg_par, pp=pp)
                    h.validate()
                    assert h.total_devices == n * m, (arch, n, m)
                    heads = min(cfg.n_heads, cfg.n_kv_heads)
                    assert heads % h.sp.p_ulysses == 0, (arch, h)


def test_plan_hybrid_prefers_slow_boundary():
    h = plan_hybrid(4, 8, 24, cfg_parallel=True, pp=2)
    assert h.cfg_machines == 2 and h.pp_machines == 2  # machines consumed
    assert h.sp.n_machines == 1  # SP stays inside the machine
    h2 = plan_hybrid(1, 8, 24, cfg_parallel=True, pp=2)
    assert h2.cfg_machines == 1 and h2.pp_machines == 1  # chips consumed
    assert h2.sp.sp_degree == 2


def test_plan_hybrid_rejects_bad_factorisations():
    with pytest.raises(ValueError):
        plan_hybrid(1, 4, 8, cfg_parallel=True, pp=4)  # 8 > 4 devices
    with pytest.raises(ValueError):
        plan_hybrid(2, 8, 24, pp=3, n_layers=32)  # 3 does not divide 32


def test_hybrid_latency_model_wins_in_comm_bound_regime():
    """The analytical model predicts the hybrid plan beats SP-only at equal
    device count where per-layer inter-machine a2a is exposed (the
    medium-resolution serving bucket), and never wins by magic FLOPs
    (compute terms match)."""
    from repro.core import plan
    from repro.core.comm_model import (
        LayerWorkload, hybrid_step_latency, sp_step_latency)

    wl = LayerWorkload(batch=1, seq=4_096, heads=24, head_dim=128)
    sp_only = plan(4, 8, wl.heads)
    base = sp_step_latency(sp_only, wl, n_layers=96, guided=True)
    h = plan_hybrid(4, 8, wl.heads, cfg_parallel=True, pp=2, n_layers=96)
    hyb = hybrid_step_latency(h, wl, n_layers=96, guided=True)
    assert hyb["t_step"] < base["t_step"]
    assert hyb["inter_elems_step"] < base["inter_elems_step"]


def test_patch_and_stage_partitions():
    assert patch_slices(256, 64, 2) == [(0, 288), (288, 32)]
    assert stage_layers(96, 4) == [(0, 24), (24, 24), (48, 24), (72, 24)]
    with pytest.raises(AssertionError):
        patch_slices(256, 30, 4)  # 30 tokens don't split into 4 patches
    with pytest.raises(AssertionError):
        stage_layers(10, 4)
