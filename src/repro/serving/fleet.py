"""Multi-replica fleet tier (DESIGN.md §13): data-parallel serving above
the single-mesh engine.

One ``DiTServer`` on one mesh cannot carry heavy global traffic; the
fleet tier runs N independent replicas — each one mesh plus the full
PR-3/5 scheduler/control stack (bucketer, admission, plan cache,
forecaster) — behind a ``FleetRouter`` doing global SLA-aware dispatch.
This is the dp(fleet) × hybrid(replica) sweep shape xDiT demonstrates
with its dp_degree × pp_degree grids, lifted to a serving tier.

**Replica state machine** — ``active`` ⇄ ``draining`` / ``failed``:

    active ──drain()──▶ draining ──resume()──▶ active
    active ──fail()───▶ failed   ──resume()──▶ active

A draining replica accepts no new dispatch but serves out its queue; a
failed replica additionally evacuates its queued (never-admitted)
requests, which the router re-dispatches with submission age intact
(``RequestScheduler.submit(resubmit=True)``).  A batch already in flight
runs to completion in both cases — KV state is per-batch and disposable,
so drain/fail are queue-level events, not mid-step aborts.

**Trace-shipping protocol** — every replica publishes its serving
telemetry through its own ``metrics.v1`` tracker (a ``JsonlTracker`` in
production); the router periodically *ships* each stream — ``read_jsonl``
the file, fold the new records through ``TraceFold`` into the router's
own tracker under a ``{"replica": rid}`` tag namespace.  Counter records
carry cumulative totals, so the fold differences them per source series
and re-publishes increments through the tracker API: multi-replica folds
SUM (never clobber) and persistent router sinks see every record.

**The router reads only the folded view.**  Queue depth, plan-cache
warmth, drain/fail state and per-bucket arrival rates are all derived
from folded replica records (plus the router's own dispatch ledger for
the records not yet shipped) — never by reaching into a replica's
scheduler.  That keeps the tier honest about distribution: everything a
real cross-host router could know arrives over the same shipped streams
CI validates with ``scripts/check_metrics_schema.py``.

**Dispatch policies** (``FleetRouter.policy``):

  * ``round_robin``   — cycle over active replicas (the baseline).
  * ``least_loaded``  — minimum effective queue depth (folded depth gauge
    + unshipped dispatch ledger).
  * ``warmth``        — resolution-band affinity: each latent-length band
    has a home pool whose plan caches are already warm for its bucket
    shapes (first assignment prefers a replica whose folded stream shows
    a compiled step for the band), with least-queue spill when the home
    pool's depth exceeds the fleet minimum by ``spill_depth``.
  * ``sla``           — ``warmth`` plus elastic repartition: the replica
    pool is re-split between SP-heavy large-resolution and batch-heavy
    small-resolution service as the arrival mix shifts, driven by the
    per-bucket rates the replicas' own ``ArrivalForecaster``s publish
    (``forecast.mean_gap_s``, folded).

``run_fleet`` is the host-side discrete-event execution harness (no jax,
no wall clock): batches run for their comm-model-predicted duration plus
a one-time trace stall per new bucket shape per replica — the warmth
signal.  ``benchmarks/fleet_sweep.py`` sweeps it; ``repro.launch.fleet``
is the CLI.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Iterable, Sequence

from .metrics import (
    JsonlTracker,
    Record,
    RecordingTracker,
    Tracker,
    TraceFold,
    read_jsonl,
)
from .sched import (
    Admission,
    ArrivalForecaster,
    PlanCache,
    RequestScheduler,
    SchedConfig,
)

ACTIVE = "active"
DRAINING = "draining"
FAILED = "failed"
_STATE_CODE = {ACTIVE: 0, DRAINING: 1, FAILED: 2}
_CODE_STATE = {v: k for k, v in _STATE_CODE.items()}

POLICIES = ("round_robin", "least_loaded", "warmth", "sla")


@dataclasses.dataclass
class FleetRequest:
    """Duck-typed request for the fleet tier (same surface the scheduler
    sim uses: no jax import needed)."""

    rid: int
    seq_len: int
    arrival: float
    sla: float | None = None
    submitted: float = 0.0
    drift_threshold: float | None = None


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Router-side knobs (all in simulated/served seconds)."""

    ship_every: float = 0.05  # period of the trace-shipping fold
    # warmth/sla: spill off the home pool when its effective depth
    # exceeds the fleet minimum by this many requests
    spill_depth: int = 10
    repartition_every: float = 0.2  # min seconds between sla repartitions


class Replica:
    """One serving replica: a mesh's scheduler/control stack plus the
    drain/fail state machine, publishing its state exclusively through
    its tracker so the router can consume it over shipped traces.

    The replica publishes (beyond what the scheduler stack already
    emits): ``replica.state`` and ``replica.queue_depth`` gauges on every
    transition, and ``replica.served`` / ``replica.batches`` counters on
    batch completion."""

    def __init__(self, rid: str, scheduler: RequestScheduler):
        self.rid = rid
        self.scheduler = scheduler
        self.tracker = scheduler.tracker
        self.state = ACTIVE
        self._publish_state()
        self._publish_depth()

    @classmethod
    def sim(cls, rid: str, trace_path: str | pathlib.Path | None = None, *,
            n_machines: int = 2, m_per_machine: int = 4, heads: int = 24,
            head_dim: int = 64, n_layers: int = 42, num_steps: int = 20,
            dp: int = 2, max_batch: int = 4, starvation_age: float = 1.0,
            default_slack: float = 10.0, defer_slack: float = 0.02,
            forecast_idle_age: float | None = 2.0) -> "Replica":
        """A replica with the full PR-3/5 host-side stack on the paper
        testbed flavour (N machines × M devices, dp-way batch split) —
        what the fleet sim and the launch CLI construct.  ``trace_path``
        selects the production sink (``JsonlTracker``; this file is what
        the router ships); None keeps the trace in memory
        (``RecordingTracker``, the test sink)."""
        tracker: Tracker = (JsonlTracker(trace_path)
                            if trace_path is not None else RecordingTracker())
        cache = PlanCache(n_machines=n_machines, m_per_machine=m_per_machine,
                          heads=heads, head_dim=head_dim, n_layers=n_layers,
                          num_steps=num_steps, dp=dp, tracker=tracker)
        cfg = SchedConfig(max_batch=max_batch, dp=dp,
                          starvation_age=starvation_age,
                          default_slack=default_slack,
                          defer_slack=defer_slack)
        forecaster = ArrivalForecaster(idle_age=forecast_idle_age,
                                       tracker=tracker)
        sched = RequestScheduler(cache, cfg, forecaster=forecaster,
                                 tracker=tracker)
        return cls(rid, sched)

    # -- scheduler delegation ---------------------------------------------
    @property
    def plan_cache(self) -> PlanCache:
        return self.scheduler.plan_cache

    @property
    def pending(self) -> int:
        return self.scheduler.pending

    def submit(self, req, now: float, *, resubmit: bool = False) -> None:
        assert self.state == ACTIVE, (
            f"router dispatched to {self.state} replica {self.rid}")
        self.scheduler.submit(req, now, resubmit=resubmit)
        self._publish_depth()

    def next_batch(self, now: float, flush: bool = False) -> Admission | None:
        # a draining replica has no future arrivals by definition, so its
        # padded candidates must not defer waiting for them
        adm = self.scheduler.next_batch(
            now, flush=flush or self.state == DRAINING)
        if adm is not None:
            self._publish_depth()
        return adm

    def requeue(self, reqs: list, pad_rows: int = 0) -> None:
        self.scheduler.requeue(reqs, pad_rows)
        self._publish_depth()

    def complete(self, adm: Admission, now: float) -> None:
        """Account one finished batch (called by the execution harness
        when the batch's last step lands)."""
        tags = {"seq": adm.seq_len}
        self.tracker.count("replica.served", len(adm.requests), tags=tags)
        self.tracker.count("replica.batches", tags=tags)

    # -- state machine -----------------------------------------------------
    def drain(self, now: float) -> None:
        """Stop accepting dispatch; the queue serves out."""
        self.state = DRAINING
        self._publish_state()

    def fail(self, now: float) -> list:
        """Fail the replica: queued (never-admitted) requests are
        evacuated for router re-dispatch, age intact."""
        self.state = FAILED
        self._publish_state()
        evacuated = self.scheduler.drain()
        self._publish_depth()
        return evacuated

    def resume(self, now: float) -> None:
        self.state = ACTIVE
        self._publish_state()

    # -- publication -------------------------------------------------------
    def _publish_state(self) -> None:
        self.tracker.log("replica.state", float(_STATE_CODE[self.state]))

    def _publish_depth(self) -> None:
        self.tracker.log("replica.queue_depth", float(self.pending))


@dataclasses.dataclass(frozen=True)
class ReplicaView:
    """Router-side snapshot of one replica, derived exclusively from the
    folded trace plus the router's own unshipped-dispatch ledger."""

    rid: str
    state: str
    queue_depth: int  # last folded replica.queue_depth sample
    in_flight: int  # router dispatches since the last ship
    warm: frozenset  # seq bands with a compiled step (folded step_miss)
    submitted: float  # folded sched.submitted total

    @property
    def effective_depth(self) -> int:
        return self.queue_depth + self.in_flight


class FleetRouter:
    """Global SLA-aware dispatch over a replica pool, fed exclusively by
    folded per-replica tracker streams (see module docstring)."""

    def __init__(self, replicas: Sequence[Replica],
                 policy: str = "warmth",
                 cfg: FleetConfig = FleetConfig(),
                 tracker: Tracker | None = None):
        assert policy in POLICIES, f"policy {policy!r} not in {POLICIES}"
        assert replicas, "a fleet needs at least one replica"
        self.replicas = list(replicas)
        self.by_rid = {r.rid: r for r in self.replicas}
        assert len(self.by_rid) == len(self.replicas), "duplicate rids"
        self.policy = policy
        self.cfg = cfg
        # the router's own sink: the fold target for shipped traces and
        # the stream its own decisions (dispatch/spill/repartition) land
        # in.  A JsonlTracker here writes the folded multi-replica trace
        # CI's schema gate validates.
        self.tracker = tracker if tracker is not None else Tracker()
        self._folds = {r.rid: TraceFold(tags={"replica": r.rid})
                       for r in self.replicas}
        self._inflight = {r.rid: 0 for r in self.replicas}
        self._rr = 0
        # band (seq_len) -> home pool of rids; grown lazily under
        # warmth/sla, rewritten by sla's elastic repartition
        self._pools: dict[int, tuple[str, ...]] = {}
        self._last_repartition: float | None = None

    # -- tracker-backed counters (legacy attribute surface) ---------------
    @property
    def dispatched(self) -> int:
        return int(self.tracker.counter_total("router.dispatched"))

    @property
    def spills(self) -> int:
        return int(self.tracker.counter_total("router.spills"))

    @property
    def repartitions(self) -> int:
        return int(self.tracker.counter("router.repartitions"))

    @property
    def requeued(self) -> int:
        """Requests re-dispatched after a replica failure."""
        return int(self.tracker.counter_total("router.requeued"))

    # -- trace shipping ----------------------------------------------------
    def _read_records(self, rep: Replica) -> Iterable[Record]:
        t = rep.tracker
        if isinstance(t, JsonlTracker):
            t.flush()
            # partial_tail="drop": a replica killed mid-write still folds
            # up to its last complete record
            return read_jsonl(t.path, partial_tail="drop")
        if isinstance(t, RecordingTracker):
            return t.records
        raise TypeError(
            f"replica {rep.rid} tracker {type(t).__name__} retains no "
            f"record stream to ship (use JsonlTracker or RecordingTracker)")

    def ship(self, now: float) -> int:
        """One shipping round: fold every replica's new records into the
        router tracker (namespaced per replica), reset the unshipped
        ledger, and — under the ``sla`` policy — reconsider the pool
        partition.  Returns the number of records folded."""
        total = 0
        for rep in self.replicas:
            total += self._folds[rep.rid].fold(self._read_records(rep),
                                               self.tracker)
            self._inflight[rep.rid] = 0
        self.tracker.count("router.ships")
        if self.policy == "sla":
            self._maybe_repartition(now)
        return total

    # -- the folded view ---------------------------------------------------
    def view(self, rid: str) -> ReplicaView:
        t = self.tracker
        tags = {"replica": rid}
        st = t.series("replica.state", tags)
        state = _CODE_STATE[int(st.last)] if st.n else ACTIVE
        depth = t.series("replica.queue_depth", tags)
        warm = frozenset(
            tg["seq"] for tg, _ in t.counter_items("plan_cache.step_miss")
            if tg.get("replica") == rid and "seq" in tg)
        submitted = sum(v for tg, v in t.counter_items("sched.submitted")
                        if tg.get("replica") == rid)
        return ReplicaView(rid=rid, state=state,
                           queue_depth=int(depth.last) if depth.n else 0,
                           in_flight=self._inflight[rid], warm=warm,
                           submitted=submitted)

    def views(self) -> list[ReplicaView]:
        return [self.view(r.rid) for r in self.replicas]

    def band_rates(self) -> dict[int, float]:
        """Per-band global arrival rate (requests/s): the sum over
        replicas of each one's folded ``ArrivalForecaster`` estimate
        (1 / last EWMA mean gap) — each forecaster sees only its
        replica's share, so the fleet rate is the sum."""
        rates: dict[int, float] = {}
        for tg, st in self.tracker.series_items("forecast.mean_gap_s"):
            seq = tg.get("seq")
            if seq is None or st.n == 0 or st.last <= 0.0:
                continue
            rates[seq] = rates.get(seq, 0.0) + 1.0 / st.last
        return rates

    # -- dispatch ----------------------------------------------------------
    def dispatch(self, req, now: float, *, resubmit: bool = False) -> str:
        """Route one request to a replica; returns the chosen rid."""
        live = [v for v in self.views() if v.state == ACTIVE]
        if not live:
            raise RuntimeError("fleet has no active replica to dispatch to")
        rid = self._pick(req.seq_len, live)
        self._inflight[rid] += 1
        self.tracker.count("router.dispatched",
                           tags={"seq": req.seq_len, "replica": rid})
        self.by_rid[rid].submit(req, now, resubmit=resubmit)
        return rid

    def redispatch(self, reqs: Sequence, now: float) -> list[str]:
        """Re-route requests evacuated from a failed replica (submission
        age preserved; counted as ``router.requeued``)."""
        rids = []
        for req in reqs:
            self.tracker.count("router.requeued", tags={"seq": req.seq_len})
            rids.append(self.dispatch(req, now, resubmit=True))
        return rids

    def _pick(self, seq: int, live: list[ReplicaView]) -> str:
        if self.policy == "round_robin":
            order = [r.rid for r in self.replicas]
            live_rids = {v.rid for v in live}
            for _ in range(len(order)):
                rid = order[self._rr % len(order)]
                self._rr += 1
                if rid in live_rids:
                    return rid
        if self.policy == "least_loaded":
            return min(live, key=lambda v: (v.effective_depth, v.rid)).rid
        # warmth / sla: band affinity with least-queue spill
        pool = self._pool_for(seq, live)
        members = [v for v in live if v.rid in pool]
        floor = min(v.effective_depth for v in live)
        if not members:
            # home pool entirely down (failed/draining): spill to a warm
            # live replica if any, else the least loaded
            self.tracker.count("router.spills", tags={"seq": seq})
            warm = [v for v in live if seq in v.warm]
            pickfrom = warm or live
            return min(pickfrom,
                       key=lambda v: (v.effective_depth, v.rid)).rid
        home = min(members, key=lambda v: (v.effective_depth, v.rid))
        if home.effective_depth - floor >= self.cfg.spill_depth:
            target = min(live, key=lambda v: (v.effective_depth, v.rid))
            if target.rid != home.rid:
                self.tracker.count("router.spills", tags={"seq": seq})
                return target.rid
        return home.rid

    def _pool_for(self, seq: int, live: list[ReplicaView]) -> tuple[str, ...]:
        pool = self._pools.get(seq)
        if pool is None:
            # first sighting of a band: prefer a replica whose folded
            # trace already shows a compiled step for it (warm), else
            # balance homes across replicas
            warm = [v.rid for v in live if seq in v.warm]
            if warm:
                rid = sorted(warm)[0]
            else:
                counts = {v.rid: 0 for v in live}
                for p in self._pools.values():
                    for r in p:
                        if r in counts:
                            counts[r] += 1
                rid = min(counts, key=lambda r: (counts[r], r))
            pool = self._pools[seq] = (rid,)
            self.tracker.log("router.pool_size", 1.0, tags={"seq": seq})
        return pool

    # -- elastic repartition (sla policy) ----------------------------------
    def _maybe_repartition(self, now: float) -> None:
        c = self.cfg
        if (self._last_repartition is not None
                and now - self._last_repartition < c.repartition_every):
            return
        rates = self.band_rates()
        if not rates:
            return
        live_rids = sorted(r.rid for r in self.replicas
                           if self.view(r.rid).state == ACTIVE)
        if not live_rids:
            return
        self._last_repartition = now
        # token-rate load per band: an SP-heavy 1024 request is 4x the
        # work of a 256 one at equal arrival rates
        loads = {seq: rate * seq for seq, rate in rates.items()}
        total = sum(loads.values())
        if total <= 0.0:
            return
        bands = sorted(loads, key=lambda s: (-loads[s], s))
        n = len(live_rids)
        shares = {b: max(1, round(n * loads[b] / total)) for b in bands}
        while sum(shares.values()) > max(n, len(bands)):
            over = [b for b in bands if shares[b] > 1]
            if not over:
                break
            shares[max(over, key=lambda b: shares[b])] -= 1
        while sum(shares.values()) < n:
            shares[bands[0]] += 1
        # contiguous proportional slot -> replica map (pools may overlap
        # only when there are more bands than replicas)
        total_slots = sum(shares.values())
        pools: dict[int, tuple[str, ...]] = {}
        slot = 0
        for b in bands:
            members = tuple(dict.fromkeys(
                live_rids[(slot + j) * n // total_slots]
                for j in range(shares[b])))
            pools[b] = members
            slot += shares[b]
        new_pools = dict(self._pools)
        new_pools.update(pools)
        if new_pools != self._pools:
            self._pools = new_pools
            self.tracker.count("router.repartitions")
            for b, p in pools.items():
                self.tracker.log("router.pool_size", float(len(p)),
                                 tags={"seq": b})


# ---------------------------------------------------------------------------
# host-side discrete-event execution harness
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """One injected drain/fail: at time ``at`` replica ``rid`` drains
    (stops accepting dispatch, serves out) or fails (additionally
    evacuates its queue for router re-dispatch); it resumes
    ``revive_after`` seconds later (None = never)."""

    at: float
    rid: str
    kind: str = "fail"  # "fail" | "drain"
    revive_after: float | None = 0.25

    def __post_init__(self):
        assert self.kind in ("fail", "drain"), self.kind


def run_fleet(reqs: Sequence, router: FleetRouter, *,
              trace_cost_s: float = 0.04,
              failure: FailureEvent | None = None) -> dict:
    """Step the fleet through one arrival stream on simulated time (no
    wall clock, fully deterministic given the stream).

    Batches execute for their comm-model-predicted duration
    (``plan.t_batch``) plus a one-time ``trace_cost_s`` stall the first
    time a replica runs a given bucket shape — the jit trace the plan
    cache memoizes, and the asymmetry the warmth policy exploits.  Trace
    shipping happens every ``router.cfg.ship_every`` simulated seconds;
    a failure event forces an immediate ship (the failover signal IS a
    shipped trace, not a side channel).  Returns fleet-wide stats in the
    ``BENCH_fleet_sweep.json`` metrics shape."""
    eps = 1e-9
    reqs = sorted(reqs, key=lambda r: (r.arrival, r.rid))
    running: dict[str, tuple] = {}  # rid -> (adm, t_start, t_end)
    stats = {"pad_tokens": 0, "real_tokens": 0, "batches": 0,
             "max_wait": 0.0, "sla_miss": 0, "sla_met": 0, "sla_total": 0,
             "served": 0, "preemptions": 0}
    i = 0
    t = 0.0
    ship_every = router.cfg.ship_every
    next_ship = ship_every
    fail_t = failure.at if failure is not None else None
    revive_t: float | None = None

    def ship_due(now: float) -> None:
        nonlocal next_ship
        while next_ship <= now + eps:
            router.ship(next_ship)
            next_ship += ship_every

    def start_batches(now: float) -> None:
        flush = i >= len(reqs)
        for rep in router.replicas:
            if rep.rid in running or rep.state == FAILED or not rep.pending:
                continue
            adm = rep.next_batch(now, flush=flush)
            if adm is None:
                continue  # deferred for packing; retried at the next event
            dur = adm.plan.t_batch
            before = rep.plan_cache.traces
            rep.plan_cache.step_fn(adm.batch_rows, adm.seq_len,
                                   lambda: None,
                                   variant=adm.plan.num_patches)
            if rep.plan_cache.traces > before:
                dur += trace_cost_s  # first time this shape runs here
            running[rep.rid] = (adm, now, now + dur)

    def complete(rep: Replica, adm: Admission, start: float,
                 end: float) -> None:
        for r in adm.requests:
            stats["max_wait"] = max(stats["max_wait"], start - r.submitted)
            if r.sla is not None:
                stats["sla_total"] += 1
                if end - r.submitted > r.sla:
                    stats["sla_miss"] += 1
                else:
                    stats["sla_met"] += 1
        stats["pad_tokens"] += adm.pad_rows * adm.seq_len
        stats["real_tokens"] += len(adm.requests) * adm.seq_len
        stats["served"] += len(adm.requests)
        stats["batches"] += 1
        rep.complete(adm, end)

    while True:
        ship_due(t)
        start_batches(t)
        times = []
        if i < len(reqs):
            times.append(reqs[i].arrival)
        times.extend(end for (_, _, end) in running.values())
        if fail_t is not None:
            times.append(fail_t)
        if revive_t is not None:
            times.append(revive_t)
        if not times:
            break  # queues empty, nothing running, stream exhausted
        t = min(times)
        for rid in [rid for rid, (_, _, end) in running.items()
                    if end <= t + eps]:
            adm, start, end = running.pop(rid)
            complete(router.by_rid[rid], adm, start, end)
        if fail_t is not None and t + eps >= fail_t:
            rep = router.by_rid[failure.rid]
            if failure.kind == "drain":
                rep.drain(fail_t)
                router.ship(fail_t)
            else:
                evacuated = rep.fail(fail_t)
                router.ship(fail_t)  # failover signal = shipped trace
                router.redispatch(evacuated, fail_t)
            if failure.revive_after is not None:
                revive_t = fail_t + failure.revive_after
            fail_t = None
        if revive_t is not None and t + eps >= revive_t:
            router.by_rid[failure.rid].resume(revive_t)
            router.ship(revive_t)
            revive_t = None
        while i < len(reqs) and reqs[i].arrival <= t + eps:
            ship_due(reqs[i].arrival)
            router.dispatch(reqs[i], reqs[i].arrival)
            i += 1

    router.ship(t)  # final fold so the summary reads complete streams
    rt = router.tracker
    stats["makespan_s"] = t
    stats["sla_met_frac"] = (stats["sla_met"] / stats["sla_total"]
                             if stats["sla_total"] else 1.0)
    stats["spills"] = int(rt.counter_total("router.spills"))
    stats["repartitions"] = int(rt.counter("router.repartitions"))
    stats["requeued"] = int(rt.counter_total("router.requeued"))
    stats["traces"] = int(rt.counter_total("plan_cache.step_miss"))
    return stats
