"""Analytical communication-volume and latency model (paper Appendix D).

Reproduces the paper's inter-machine communication volume formulas for USP
and SwiftFusion, plus a simple two-level (intra/inter) alpha-beta latency
model used by the benchmark harness to regenerate the shape of the paper's
Figures 7/8/10 without multi-machine hardware.

All volumes are **elements per GPU** (multiply by bytes/elem for bytes), in
terms of B (batch), L (global sequence), H (heads), D (head dim), N
(machines), M (devices per machine), P_u, P_r (Ulysses/Ring degrees).

``plan_step_latency`` is the unified scoring entry point the request
scheduler's plan cache and admission policy consume (DESIGN.md §9);
``load_network_model`` loads the parameters ``scripts/calibrate_comm.py``
fits from recorded ``BENCH_*.json`` step measurements, replacing the
testbed-equivalent defaults with calibrated ones.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

from .planner import HybridPlan, SPPlan


def usp_inter_volume(plan: SPPlan, blhd: float) -> float:
    """Appendix D eq. (4)-(5): USP inter-machine elements per GPU."""
    n, p_r, p_u = plan.n_machines, plan.p_ring, plan.p_ulysses
    if n == 1:
        return 0.0
    if p_r >= n:
        # Ring spans machines; each of the N-1 inter-machine hops moves KV.
        return 2.0 * (n - 1) * blhd / n
    # Ring smaller than machine count: Ulysses also crosses machines with
    # degree N / P_r.
    g = n / p_r
    return (2.0 * (p_r - 1) * (n / p_r) + 4.0 * (g - 1) / g) * blhd / n


def swift_inter_volume(plan: SPPlan, blhd: float) -> float:
    """Appendix D eq. (6)-(7): SwiftFusion inter-machine elements per GPU."""
    n, p_u = plan.n_machines, plan.p_ulysses
    if n == 1:
        return 0.0
    if p_u >= n:
        return 4.0 * (n - 1) / n * blhd / n
    # Ulysses smaller than machine count: Ring also crosses machines with
    # degree N / P_u.
    g = n / p_u
    return (2.0 * (g - 1) + 4.0 * (p_u - 1) / p_u * g) * blhd / n


def hierarchical_applicable(plan: SPPlan) -> bool:
    """Whether the hierarchical two-level a2a (DESIGN.md §8.2) applies to
    this plan: the Ulysses groups must span machines (ulysses-outer
    placement, N > 1) with more than one member per machine (P_u > N) and
    an exact machine factorisation (N | P_u) so u-blocks are machine-
    contiguous.  Degenerate cases fall back to the flat path."""
    n, p_u = plan.n_machines, plan.p_ulysses
    return plan.ulysses_inter and n > 1 and p_u > n and p_u % n == 0


def a2a_leg_volumes(plan: SPPlan, blhd: float, *, swift: bool,
                    hierarchical: bool = False) -> dict[str, float]:
    """Per-leg element volumes of the four Ulysses all-to-alls (Q, K, V,
    O), split by the boundary each leg crosses — the decomposition that
    replaces the old single-blob a2a term.

    Derivation (per-machine NIC convention of Appendix D, cross-checked
    against eq. 4/6 in tests/test_comm_model.py): each device holds
    BLHD/(N·M) elements per tensor, so a machine's share per tensor is
    BLHD/N.  The a2a moves chunk j of P_u to ulysses-peer j; with
    m_u = P_u/N group members per machine (swift, P_u >= N):

      flat a2a:     (P_u - m_u)/P_u of every chunk crosses machines
                    -> inter = 4·(P_u - m_u)/P_u · BLHD/N (== eq. 6),
                    (m_u - 1)/P_u stays on NVLink
                    -> intra = 4·(m_u - 1)/P_u · BLHD/N.
      hierarchical: the intra leg exchanges FULL dest-machine bundles
                    (every chunk traverses NVLink once)
                    -> intra = 4·(m_u - 1)/m_u · BLHD/N,
                    the inter leg moves exactly the same remote chunks
                    as flat (aggregated m_u per message)
                    -> inter = 4·(P_u - m_u)/P_u · BLHD/N (unchanged).

    The hierarchical win is therefore NOT in volume (it pays ~m_u× more
    NVLink traffic) but in inter-message count — g - 1 paced hops instead
    of P_u - 1 — which is a latency term, priced per-leg in
    ``attention_layer_latency``.
    """
    n, m = plan.n_machines, plan.m_per_machine
    p_u, p_r = plan.p_ulysses, plan.p_ring
    if p_u == 1:
        return {"a2a_intra": 0.0, "a2a_inter": 0.0}
    if not swift:
        # USP: Ulysses stays inside the machine (eq. 5's a2a term covers
        # the P_r < N spill-over case where Ulysses crosses too).
        u_intra = min(p_u, m)
        intra = 4.0 * (u_intra - 1) / u_intra * blhd / n if m > 1 else 0.0
        inter = 0.0
        if n > 1 and p_r < n:
            g = n / p_r
            inter = 4.0 * (g - 1) / g * blhd / n
        return {"a2a_intra": intra, "a2a_inter": inter}
    if n == 1:
        return {"a2a_intra": 4.0 * (p_u - 1) / p_u * blhd,
                "a2a_inter": 0.0}
    if p_u < n:
        # eq. 7 regime: one group member per machine cluster — the a2a is
        # purely inter with degree g = N/P_u; hierarchy cannot apply.
        g = n / p_u
        return {"a2a_intra": 0.0,
                "a2a_inter": 4.0 * (p_u - 1) / p_u * g * blhd / n}
    # group members per machine; kept continuous so the inter share
    # reduces to eq. 6's 4*(N-1)/N*BLHD/N even when N does not divide P_u
    # (eq. 6's even-distribution idealisation — the hierarchical branch,
    # which needs exact machine blocks, is gated on divisibility anyway)
    m_u = p_u / n
    inter = 4.0 * (p_u - m_u) / p_u * blhd / n
    if hierarchical and hierarchical_applicable(plan):
        intra = 4.0 * (m_u - 1) / m_u * blhd / n
    else:
        intra = 4.0 * (m_u - 1) / p_u * blhd / n
    return {"a2a_intra": intra, "a2a_inter": inter}


def ring_leg_volumes(plan: SPPlan, blhd: float, *, swift: bool
                     ) -> dict[str, float]:
    """Per-leg element volumes of the Ring circulation (K and V), split by
    boundary.  Total receive volume per machine is 2·(P_r - 1)·BLHD/N
    (each of M devices receives P_r - 1 KV chunks of BLHD/(N·M) each, K
    and V both); the inter share is the paper's eq. 4/6/7 ring term and
    the intra share is the complement, floored at zero for the P_r < N
    regime where re-entry makes the inter term exceed the single-pass
    total."""
    n = plan.n_machines
    p_u, p_r = plan.p_ulysses, plan.p_ring
    if p_r == 1:
        return {"ring_intra": 0.0, "ring_inter": 0.0}
    total = 2.0 * (p_r - 1) * blhd / n
    if n == 1:
        return {"ring_intra": total, "ring_inter": 0.0}
    if swift:
        # ring crosses machines only when Ulysses is too small to cover
        # them (eq. 7's first term, g = N / P_u machine clusters)
        inter = 2.0 * (n / p_u - 1) * blhd / n if p_u < n else 0.0
    else:
        if p_r >= n:
            inter = 2.0 * (n - 1) * blhd / n  # eq. 4
        else:
            inter = 2.0 * (p_r - 1) * (n / p_r) * blhd / n  # eq. 5 term
    return {"ring_intra": max(total - inter, 0.0), "ring_inter": inter}


def intra_volume(plan: SPPlan, blhd: float, *, swift: bool,
                 hierarchical: bool = False) -> float:
    """Intra-machine elements per GPU (not in the paper's appendix; derived
    the same way): the a2a's intra-machine share plus the Ring's.

    Bug history: this used to be ``2·(min(P_r, M) - 1)·BLHD/N`` for swift
    — via a self-cancelling ``/ max(r_intra, 1) * r_intra`` factor — which
    is correct for P_r <= M (ring entirely inside the machine) but
    undercounts the P_r > M regime: there the ring spans g_r = P_r·N/SP
    machine segments and the intra share is the eq.-7 complement
    2·(P_r - g_r)·BLHD/N, not 2·(M - 1)·BLHD/N.  Both regimes (and the
    flat-a2a intra share this blob used to drop entirely) now come from
    the per-leg decomposition; tests/test_comm_model.py pins the
    derivation against the eq. 4/6 limits at P_r = M and N = 1.
    """
    if plan.m_per_machine == 1:
        return 0.0
    legs = a2a_leg_volumes(plan, blhd, swift=swift,
                           hierarchical=hierarchical)
    rlegs = ring_leg_volumes(plan, blhd, swift=swift)
    return legs["a2a_intra"] + rlegs["ring_intra"]


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    """Two-level network + compute model for latency estimates.

    Defaults approximate the paper's testbed-equivalent on TPU terms:
    intra = ICI, inter = DCN/inter-pod.
    """

    intra_bw: float = 4.9e11  # B/s aggregated intra-machine per device
    inter_bw: float = 5.0e10  # B/s inter-machine per device
    intra_lat: float = 1e-6  # s per hop
    inter_lat: float = 1e-5  # s per hop
    flops: float = 197e12  # peak bf16 FLOP/s per device
    mfu: float = 0.5  # assumed attention kernel efficiency
    bytes_per_elem: int = 2
    # Per-transfer-step issue gap when comm is scheduled BETWEEN ops (the
    # "xla" channel backend): each ring step / a2a stage pays one
    # dispatch+schedule window before its DMA can start.  The fused
    # kernel path ("pallas", DESIGN.md §8.1) issues the put from inside
    # the attention kernel and pays none of it.
    step_issue_overhead: float = 2e-6  # s per inter-op transfer step
    # Per-leg a2a terms (DESIGN.md §8.2).  The staged a2a's intra-machine
    # leg rides NVLink but with a different message shape than the ring
    # (full dest-machine bundles vs one KV chunk), so its achieved
    # bandwidth calibrates separately from intra_bw; inter_hop_lat is the
    # per-MESSAGE cost of an inter-machine a2a stage (NIC processing +
    # wire latency that does not pipeline across messages) — this is the
    # term the hierarchical path shrinks from P_u - 1 to N - 1 messages;
    # codec_bw is the on-device quantise+dequantise throughput of the
    # fp8 wire codec (comm/compress.py).
    a2a_intra_bw: float = 4.9e11  # B/s intra-machine a2a leg per device
    inter_hop_lat: float = 1e-5  # s per inter-machine a2a message
    codec_bw: float = 2.0e12  # B/s fp8 encode/decode throughput


@dataclasses.dataclass(frozen=True)
class LayerWorkload:
    batch: int
    seq: int  # global sequence length
    heads: int
    head_dim: int

    @property
    def blhd(self) -> float:
        return float(self.batch * self.seq * self.heads * self.head_dim)

    def attention_flops(self) -> float:
        # 2 matmuls (QK^T and PV), 2*L*L*D each per head, bidirectional DiT.
        return 4.0 * self.batch * self.heads * self.seq * self.seq * self.head_dim


def attention_layer_latency(
    plan: SPPlan,
    wl: LayerWorkload,
    net: NetworkModel = NetworkModel(),
    *,
    swift: bool,
    overlap_inter: bool = False,
    overlap_intra: bool = True,
    one_sided: bool = False,
    fused_comm: bool = False,
    hierarchical: bool = False,
    wire_dtype: str | None = None,
) -> dict[str, float]:
    """Estimate one distributed attention layer's latency components.

    ``overlap_inter`` models Torus Attention: the inter-machine all-to-all
    is hidden behind compute up to the compute time.  Ring's intra-machine
    transfers are overlappable by construction (``overlap_intra``).

    ``one_sided`` models §4.4: two-sided libraries pay a sender/receiver
    rendezvous *per transfer step* (P_r - 1 ring steps + the a2a stages,
    Fig. 4); the one-sided design pays exactly two barriers per layer
    (Algorithm 1 lines 16/36), independent of step count.

    ``fused_comm`` models the Pallas channel backend (DESIGN.md §8.1):
    when the attention kernel issues its own puts, the per-step inter-op
    issue gap (``net.step_issue_overhead`` per ring step / a2a stage)
    disappears — the kernel-fused analogue of the paper's in-kernel
    NVSHMEM puts.

    ``hierarchical`` scores the two-level a2a (DESIGN.md §8.2) when
    :func:`hierarchical_applicable` holds for the plan (no-op otherwise);
    ``wire_dtype`` prices fp8 compression of the inter-machine a2a leg
    (halved wire bytes, plus a codec term).  The returned dict carries
    every leg separately — ``t_a2a_intra``/``t_a2a_inter``/
    ``t_ring_intra``/``t_ring_inter``/``t_codec`` — with the legacy
    ``t_inter``/``t_intra`` as their sums, so no single-blob a2a term
    remains in the scoring.
    """
    hier = hierarchical and hierarchical_applicable(plan)
    a2a = a2a_leg_volumes(plan, wl.blhd, swift=swift, hierarchical=hier)
    ring = ring_leg_volumes(plan, wl.blhd, swift=swift)
    b = net.bytes_per_elem
    compressed = wire_dtype is not None and a2a["a2a_inter"] > 0.0
    wire_b = 1 if compressed else b  # fp8 wire formats are 1 byte/elem

    # a2a message counts per layer: the flat staged path paces P_u - 1
    # messages on the Ulysses boundary; the hierarchical path splits them
    # into m_u - 1 fast-leg + N - 1 slow-leg messages.
    p_u, n = plan.p_ulysses, plan.n_machines
    if hier:
        a2a_inter_msgs = n - 1
        a2a_intra_msgs = p_u // n - 1
    elif plan.ulysses_inter and n > 1:
        a2a_inter_msgs = max(p_u - 1, 0)
        a2a_intra_msgs = 0
    else:
        a2a_inter_msgs = 0
        a2a_intra_msgs = max(p_u - 1, 0)

    t_a2a_inter = (a2a["a2a_inter"] * wire_b / net.inter_bw
                   + a2a_inter_msgs * net.inter_hop_lat)
    t_a2a_intra = (a2a["a2a_intra"] * b / net.a2a_intra_bw
                   + a2a_intra_msgs * net.intra_lat)
    t_ring_inter = ring["ring_inter"] * b / net.inter_bw
    t_ring_intra = ring["ring_intra"] * b / net.intra_bw
    # encode on the sender + decode on the receiver, priced against the
    # uncompressed payload (the codec reads/writes the full-width tensor)
    t_codec = (2.0 * a2a["a2a_inter"] * b / net.codec_bw) if compressed else 0.0

    inter_v = a2a["a2a_inter"] + ring["ring_inter"]
    intra_v = a2a["a2a_intra"] + ring["ring_intra"]
    t_inter = (t_a2a_inter + t_ring_inter
               + (plan.n_machines > 1) * net.inter_lat)
    t_intra = (t_a2a_intra + t_ring_intra
               + (plan.m_per_machine > 1) * net.intra_lat)
    t_comp = wl.attention_flops() / plan.sp_degree / (net.flops * net.mfu)
    ring_steps = max(plan.p_ring - 1, 0)
    a2a_stages = a2a_inter_msgs + a2a_intra_msgs
    if one_sided:
        t_sync = 2 * (net.inter_lat if plan.n_machines > 1 else net.intra_lat)
    else:
        inter_steps = (a2a_inter_msgs
                       + ring_steps * (not plan.ulysses_inter))
        intra_steps = (a2a_intra_msgs
                       + ring_steps * plan.ulysses_inter)
        t_sync = (inter_steps * net.inter_lat * (plan.n_machines > 1)
                  + intra_steps * net.intra_lat * (plan.m_per_machine > 1))
    t_issue = (0.0 if fused_comm
               else (ring_steps + a2a_stages) * net.step_issue_overhead)
    exposed_intra = 0.0 if overlap_intra else t_intra
    exposed_inter = max(0.0, t_inter - t_comp) if overlap_inter else t_inter
    total = t_comp + exposed_inter + exposed_intra + t_sync + t_issue + t_codec
    hideable = t_inter + t_intra
    return {
        "t_compute": t_comp,
        "t_inter": t_inter,
        "t_intra": t_intra,
        "t_a2a_inter": t_a2a_inter,
        "t_a2a_intra": t_a2a_intra,
        "t_ring_inter": t_ring_inter,
        "t_ring_intra": t_ring_intra,
        "t_codec": t_codec,
        "t_sync": t_sync,
        "t_issue": t_issue,
        "t_total": total,
        "t_exposed_inter": exposed_inter,
        "t_exposed_intra": exposed_intra,
        # fraction of the layer's comm hidden behind compute (1.0 = fully
        # overlapped / nothing to hide) — the modelled counterpart of the
        # measured per-leg overlap efficiency (DESIGN.md §12)
        "overlap_efficiency": (1.0 - (exposed_inter + exposed_intra)
                               / hideable) if hideable > 0 else 1.0,
        "inter_elems": inter_v,
        "intra_elems": intra_v,
        "hierarchical": float(hier),
    }


# ---------------------------------------------------------------------------
# hybrid parallelism (DESIGN.md §7): CFG + patch pipeline composed with SP
# ---------------------------------------------------------------------------

LATENT_CHANNELS = 64  # mirrors models/dit.py (velocity tensor channel dim)


def cfg_recombine_volume(wl: LayerWorkload) -> float:
    """Elements each device exchanges for the CFG recombine, per sampler
    step: one velocity tensor (B·L·C with B the per-branch batch) — the
    weighted psum over k branches is a reduction, so the per-device volume
    is independent of the guidance degree.  This is the ONLY cross-branch
    traffic of cfg parallelism — it is per *step*, not per layer, which is
    why the planner spends the slow boundary on it first."""
    return float(wl.batch * wl.seq * LATENT_CHANNELS)


def pipefusion_boundary_volume(wl: LayerWorkload, pp: int) -> float:
    """Elements each pipeline stage hands to its successor per sampler
    step: every patch's activations cross each stage boundary once, so the
    per-device total is B·L·hidden (hidden ≈ H·D) per step — independent
    of both layer count and patch count.  Compare with SP, which moves
    O(B·L·H·D) *per layer*."""
    if pp <= 1:
        return 0.0
    return float(wl.batch * wl.seq * wl.heads * wl.head_dim)


# step-level dict keys carrying each comm leg (DESIGN.md §8.2): the
# scheduler and the bench records see the same decomposition the layer
# model scores with — no single-blob a2a term anywhere downstream either
PER_LEG_KEYS = ("t_a2a_inter", "t_a2a_intra", "t_ring_inter",
                "t_ring_intra", "t_codec")


def _per_leg_step(lat: dict[str, float], mult: float) -> dict[str, float]:
    out = {f"{k}_step": mult * lat[k] for k in PER_LEG_KEYS}
    out["hierarchical"] = lat["hierarchical"]
    return out


def sp_step_latency(
    plan: SPPlan,
    wl: LayerWorkload,
    net: NetworkModel = NetworkModel(),
    *,
    n_layers: int,
    guided: bool = True,
    guidance_branches: int = 2,
    swift: bool = True,
    comm_backend: str = "xla",
    hierarchical: bool = False,
    wire_dtype: str | None = None,
) -> dict[str, float]:
    """Predicted per-sampler-step latency of pure SP serving: ``n_layers``
    distributed attention layers (Torus overlap + one-sided sync), times
    the k guidance branches when classifier-free guidance runs them
    sequentially."""
    lat = attention_layer_latency(
        plan, wl, net, swift=swift, overlap_inter=True, one_sided=True,
        fused_comm=comm_backend == "pallas",
        hierarchical=hierarchical, wire_dtype=wire_dtype)
    branches = guidance_branches if guided else 1
    mult = branches * n_layers
    return {
        "t_step": mult * lat["t_total"],
        "t_layer": lat["t_total"],
        "t_compute_step": mult * lat["t_compute"],
        "t_issue_step": mult * lat["t_issue"],
        "overlap_efficiency": lat["overlap_efficiency"],
        "branches": float(branches),
        "inter_elems_step": mult * lat["inter_elems"],
        **_per_leg_step(lat, mult),
    }


def hybrid_step_latency(
    hplan: HybridPlan,
    wl: LayerWorkload,
    net: NetworkModel = NetworkModel(),
    *,
    n_layers: int,
    guided: bool = True,
    guidance_branches: int = 2,
    num_patches: int | None = None,
    num_steps: int = 20,
    overlap_pp: bool = True,
    comm_backend: str = "xla",
    hierarchical: bool = False,
    wire_dtype: str | None = None,
) -> dict[str, float]:
    """Predicted per-sampler-step latency of the (cfg, pp, P_u, P_r) plan.

    Model: each pipeline stage runs n_layers/pp SP-distributed attention
    layers over the full sequence's worth of patches (patch attention is
    Q_patch × KV_full, so per-stage flops equal n_layers/pp full layers);
    cfg = 2 removes the sequential-guidance doubling at the cost of one
    velocity-sized recombine per step; stage hand-offs stream one patch at
    a time and overlap with compute (the NVSHMEM-style async schedule —
    ``overlap_pp=False`` models a blocking hand-off).  The pipeline fill
    bubble is amortised across the sampler's ``num_steps`` (PipeFusion
    pipelines across diffusion steps).

    The SP sub-plan keeps the paper's TAS/Torus placement on the residual
    sub-mesh; when that sub-mesh has one machine the swift/USP distinction
    is moot for inter traffic and the Ulysses a2a is accounted as
    intra-machine (swift=False branch of ``intra_volume``).
    """
    np_ = num_patches or max(hplan.pp, 1)
    sub = hplan.sp
    lat = attention_layer_latency(
        sub, wl, net, swift=sub.n_machines > 1,
        overlap_inter=True, one_sided=True,
        fused_comm=comm_backend == "pallas",
        hierarchical=hierarchical, wire_dtype=wire_dtype)
    branches = guidance_branches if (guided and hplan.cfg == 1) else 1
    t_layers = branches * (n_layers / hplan.pp) * lat["t_total"]

    b = net.bytes_per_elem
    pp_bw = net.inter_bw if hplan.pp_inter else net.intra_bw
    t_pp = pipefusion_boundary_volume(wl, hplan.pp) * b / pp_bw
    exposed_pp = max(0.0, t_pp - t_layers) if overlap_pp else t_pp
    cfg_bw = net.inter_bw if hplan.cfg_inter else net.intra_bw
    t_cfg = 0.0
    if guided and hplan.cfg >= 2:
        t_cfg = (cfg_recombine_volume(wl) * b / cfg_bw
                 + (net.inter_lat if hplan.cfg_inter else net.intra_lat))
    t_bubble = t_layers * (hplan.pp - 1) / (np_ * num_steps)
    total = t_layers + exposed_pp + t_cfg + t_bubble
    layer_mult = branches * (n_layers / hplan.pp)
    hideable = layer_mult * (lat["t_inter"] + lat["t_intra"]) + t_pp
    exposed = (layer_mult * (lat["t_exposed_inter"]
                             + lat["t_exposed_intra"]) + exposed_pp)
    return {
        "t_step": total,
        "t_layers": t_layers,
        "t_pp": t_pp,
        "t_cfg": t_cfg,
        "t_bubble": t_bubble,
        "t_compute_step": layer_mult * lat["t_compute"],
        "t_issue_step": layer_mult * lat["t_issue"],
        "overlap_efficiency": (1.0 - exposed / hideable
                               if hideable > 0 else 1.0),
        "branches": float(branches),
        "inter_elems_step": (branches * (n_layers / hplan.pp)
                             * lat["inter_elems"]
                             + (pipefusion_boundary_volume(wl, hplan.pp)
                                if hplan.pp_inter else 0.0)
                             + (cfg_recombine_volume(wl)
                                if guided and hplan.cfg_inter else 0.0)),
        **_per_leg_step(lat, layer_mult),
    }


# ---------------------------------------------------------------------------
# scheduler scoring API (DESIGN.md §9) + calibration loading
# ---------------------------------------------------------------------------

def plan_step_latency(
    hplan: HybridPlan,
    wl: LayerWorkload,
    net: NetworkModel = NetworkModel(),
    *,
    n_layers: int,
    guided: bool = True,
    guidance_branches: int = 2,
    num_patches: int | None = None,
    num_steps: int = 20,
    comm_backend: str | None = None,
) -> dict[str, float]:
    """Predicted per-sampler-step latency of ANY hybrid plan — the single
    entry point the request scheduler scores candidate plans through.

    Dispatches to ``sp_step_latency`` for degenerate (cfg=1, pp=1) plans
    and ``hybrid_step_latency`` otherwise; both return a dict whose
    ``t_step`` is the admission policy's scoring quantity.

    ``comm_backend`` overrides the plan's own backend annotation (None =
    use ``hplan.comm_backend``); "pallas" scores the kernel-fused
    schedule, which drops the per-step issue overhead — this is how the
    planner and the scheduler's plan cache prefer the fused path when it
    wins.

    ``hplan.hier_a2a`` / ``hplan.a2a_wire_dtype`` select the hierarchical
    two-level a2a and its fp8 wire compression (DESIGN.md §8.2); both
    thread down to ``attention_layer_latency``'s per-leg terms so flat and
    hierarchical candidates for the same (P_u, P_r) score differently.
    """
    cb = comm_backend if comm_backend is not None else hplan.comm_backend
    hier = hplan.hier_a2a
    wire = hplan.a2a_wire_dtype
    if hplan.cfg == 1 and hplan.pp == 1:
        return sp_step_latency(
            hplan.sp, wl, net, n_layers=n_layers, guided=guided,
            guidance_branches=guidance_branches,
            swift=hplan.sp.ulysses_inter, comm_backend=cb,
            hierarchical=hier, wire_dtype=wire)
    return hybrid_step_latency(
        hplan, wl, net, n_layers=n_layers, guided=guided,
        guidance_branches=guidance_branches, num_patches=num_patches,
        num_steps=num_steps, comm_backend=cb,
        hierarchical=hier, wire_dtype=wire)


# NetworkModel fields the calibration fitter treats as free parameters
# (core/calibration.py, scripts/calibrate_comm.py, sched/control.py's
# OnlineCalibrator).  flops and bytes_per_elem are hardware constants;
# step_issue_overhead is calibrated on-TPU (ROADMAP Pallas item), not from
# step-latency records, which cannot separate it from the hop latencies.
# The per-leg a2a parameters (DESIGN.md §8.2) join the fit: sweeps that
# never exercise the hierarchical path leave them unidentifiable and the
# fitter's damping holds their ratios at 1.0.
FIT_PARAMS = ("intra_bw", "inter_bw", "intra_lat", "inter_lat", "mfu",
              "a2a_intra_bw", "inter_hop_lat", "codec_bw")


def fit_param_ratios(net: NetworkModel,
                     ref: NetworkModel | None = None) -> dict[str, float]:
    """Per-parameter ratio of ``net`` over ``ref`` (nominal by default) —
    the drift measure the online recalibration loop thresholds on and the
    quantity the calibration regression tests pin."""
    ref = ref if ref is not None else NetworkModel()
    return {k: getattr(net, k) / getattr(ref, k) for k in FIT_PARAMS}


def network_model_from_dict(d: dict) -> NetworkModel:
    """NetworkModel with any subset of fields overridden; non-field keys
    (e.g. the fit report ``calibrate_comm.py`` attaches) are ignored."""
    fields = {f.name for f in dataclasses.fields(NetworkModel)}
    return dataclasses.replace(
        NetworkModel(), **{k: v for k, v in d.items() if k in fields})


def load_network_model(path: str | pathlib.Path) -> NetworkModel:
    """Load a calibration JSON written by ``scripts/calibrate_comm.py``
    (the ``--calibration`` flag of the benchmark sweeps)."""
    return network_model_from_dict(
        json.loads(pathlib.Path(path).read_text()))
