"""SP strategy dispatch: full | ring | ulysses | usp | swift | swift_torus.

This is the public entry point models call for distributed attention.  It
owns the ``shard_map`` over the SP mesh axes; everything outside attention
remains plain GSPMD.

Strategies (P = SP degree, N = machines/pods, M = chips per pod):
  full        — no SP; single-device reference (debug / tiny meshes).
  ring        — Ring Attention over the whole SP group (P_u = 1).
  ulysses     — Ulysses Attention over the whole SP group (P_r = 1,
                monolithic all-to-all).  Requires P | gcd(Hq, Hkv).
  usp         — USP baseline [5]: Ulysses intra-machine, Ring inter.
  swift       — SwiftFusion TAS (§4.2): Ulysses *inter*-machine, Ring
                *intra*; monolithic all-to-alls (the paper's "TAS" ablation).
  swift_torus — TAS + Torus Attention (§4.3): chunked all-to-all overlapped
                with compute, one-sided-style ppermute stages (full SFU).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map

from . import planner
from .collectives import GroupLayout
from .ring import ring_attention
from .softmax import finalize, reference_attention, MaskSpec
from .torus import torus_attention
from .ulysses import gather_qkv, group_positions, scatter_o

STRATEGIES = ("full", "ring", "ulysses", "usp", "swift", "swift_torus")


@dataclasses.dataclass(frozen=True)
class SPConfig:
    """How attention is distributed on the mesh."""

    strategy: str = "swift_torus"
    sp_axes: tuple[str, ...] = ("model",)  # sequence-parallel mesh axes
    batch_axes: tuple[str, ...] | None = ("data",)  # batch (DP) mesh axes
    machine_axis: str = "pod"  # the slow-boundary axis (paper's N)
    replicate_kv: bool = False  # allow P_u up to gcd(SP, Hq) by replicating KV
    # Hybrid-parallel axes (DESIGN.md §7).  cfg_axis: the 2-way classifier-
    # free-guidance axis — the sampler stacks the cond/uncond branches on
    # the batch dim and this axis shards them, so attention (and, via GSPMD
    # propagation, the whole block) computes the two branches on disjoint
    # mesh halves.  pp_axis: the patch-pipeline stage axis — never touched
    # by attention itself (it partitions the *layer* dim of the weights);
    # named here so planners/engines can find it.
    cfg_axis: str | None = None
    pp_axis: str | None = None
    # Unrolled ring steps let XLA schedule each permute against the next
    # step's compute AND make HLO cost_analysis see every trip (lax loops
    # are counted once); fori_loop is available for very large P_r.
    unroll_ring: bool = True
    # Beyond-paper (§Perf): fuse all Pull-Q stage compute into one ring
    # circulation of the diagonal KV (Algorithm 1 re-circulates it P_u x).
    torus_fused_pull_q: bool = False
    # Beyond-paper (§Perf): cap the materialized score matrix per attend at
    # [B, H, Lq, attn_kv_block] (XLA-level flash blocking); None = off.
    attn_kv_block: int | None = None
    # Comm lowering (DESIGN.md §8.1): "xla" = ppermute + barrier, overlap
    # left to XLA's scheduler; "pallas" = in-kernel DMA + semaphores (the
    # fused ring_flash path).  kernel_interpret runs the Pallas branch in
    # interpreter mode — required on CPU (the CI path), off on real TPUs.
    comm_backend: str = "xla"
    kernel_interpret: bool = True
    # Hierarchical a2a (DESIGN.md §8.2): decompose every Ulysses
    # all-to-all into an intra-machine exchange plus staged inter-machine
    # hops whenever the Ulysses groups span machines (engages only when
    # the topology qualifies: ulysses-outer placement, N > 1, N | P_u,
    # P_u > N — otherwise the flat path runs unchanged).  a2a_wire_dtype
    # compresses the inter-machine leg ("float8_e4m3fn"/"float8_e5m2",
    # comm/compress.py); None keeps the wire exact, which is what makes
    # the hierarchical path bit-compatible with the flat one.
    hier_a2a: bool = False
    a2a_wire_dtype: str | None = None

    def __post_init__(self):
        assert self.strategy in STRATEGIES, self.strategy
        assert self.comm_backend in ("xla", "pallas"), self.comm_backend
        if self.a2a_wire_dtype is not None:
            from ..comm.compress import WIRE_DTYPES
            assert self.a2a_wire_dtype in WIRE_DTYPES, self.a2a_wire_dtype

    def effective_batch_axes(
        self, mesh: jax.sharding.Mesh | None = None
    ) -> tuple[str, ...] | None:
        """Batch mesh axes with the CFG axis prepended (when present).

        The CFG pair is stacked on the batch dim by the sampler, so for
        sharding purposes it is just the major batch axis.  When a mesh is
        given, axes it does not carry are dropped — the same SPConfig then
        works on meshes with and without a 'cfg' axis.
        """
        axes = ((self.cfg_axis,) if self.cfg_axis else ()) + tuple(
            self.batch_axes or ())
        if mesh is not None:
            axes = tuple(a for a in axes if a in mesh.axis_names)
        return axes or None


def resolve_layout(
    cfg: SPConfig, mesh: jax.sharding.Mesh, num_q_heads: int, num_kv_heads: int
) -> GroupLayout:
    """Instantiate the paper's (P_u × P_r) plan for this mesh + head count."""
    sp = math.prod(mesh.shape[a] for a in cfg.sp_axes)
    n = mesh.shape[cfg.machine_axis] if cfg.machine_axis in cfg.sp_axes else 1
    m = sp // n

    def u_groups(p_u: int, outer: bool) -> int:
        # Hierarchical decomposition applies when the Ulysses groups span
        # the machine boundary with > 1 member per machine: u-blocks are
        # then machine-contiguous (block size (P_u/N)·P_r = M) and the
        # two-level factorisation u = u_hi·m_u + u_lo is exact.
        if (cfg.hier_a2a and outer and n > 1 and p_u > n
                and p_u % n == 0):
            return n
        return 1

    if cfg.strategy == "ring":
        return GroupLayout(cfg.sp_axes, 1, sp, ulysses_outer=True)
    if cfg.strategy == "ulysses":
        heads = num_q_heads if cfg.replicate_kv else math.gcd(num_q_heads, num_kv_heads)
        if heads % sp != 0:
            raise ValueError(
                f"ulysses needs SP ({sp}) | heads ({heads}); use usp/swift instead"
            )
        return GroupLayout(cfg.sp_axes, sp, 1, ulysses_outer=True,
                           u_groups=u_groups(sp, True))
    swift = cfg.strategy in ("swift", "swift_torus")
    pl = planner.plan(
        n, m, num_q_heads, num_kv_heads, swift=swift, replicate_kv=cfg.replicate_kv
    )
    return GroupLayout(cfg.sp_axes, pl.p_ulysses, pl.p_ring, ulysses_outer=swift,
                       u_groups=u_groups(pl.p_ulysses, swift))


def _usp_like(q, k, v, layout: GroupLayout, *, scale, causal, window, unroll,
              kv_block=None, backend="xla", interpret=True, wire_dtype=None):
    """Shared body for usp/swift/ulysses/ring: monolithic Ulysses gather →
    Ring Attention → scatter.  The layout decides which boundary each
    technique crosses (that single bit is the paper's §4.2 contribution)."""
    ls = q.shape[1]
    g = gather_qkv(q, k, v, layout, backend=backend, interpret=interpret,
                   wire_dtype=wire_dtype)
    kpos_fn = lambda owner_r: group_positions(layout, ls, owner_r)
    part = ring_attention(
        g.q, g.k, g.v, layout,
        q_pos=g.q_pos, k_pos_fn=kpos_fn,
        scale=scale, causal=causal, window=window, unroll=unroll,
        kv_block=kv_block, backend=backend, interpret=interpret,
    )
    return scatter_o(finalize(part, dtype=q.dtype), layout,
                     backend=backend, interpret=interpret,
                     wire_dtype=wire_dtype)


def sp_attention(
    q: jax.Array,  # [B, L, Hq, D] global arrays (inside jit)
    k: jax.Array,  # [B, L, Hkv, D]
    v: jax.Array,
    *,
    mesh: jax.sharding.Mesh,
    cfg: SPConfig,
    scale: float | None = None,
    causal: bool = False,
    window: int | None = None,
) -> jax.Array:
    """Distributed attention over the mesh per the configured SP strategy.

    Sequence is sharded over ``cfg.sp_axes`` (flat-rank order), batch over
    ``cfg.batch_axes``; heads/head_dim replicated inside the SP group.
    """
    if cfg.strategy == "full" or math.prod(mesh.shape[a] for a in cfg.sp_axes) == 1:
        mask = MaskSpec(causal=causal, window=window)
        return reference_attention(q, k, v, scale=scale, mask=mask)

    layout = resolve_layout(cfg, mesh, q.shape[2], k.shape[2])
    if cfg.replicate_kv and layout.p_ulysses > 1:
        rep = layout.p_ulysses // math.gcd(layout.p_ulysses, k.shape[2])
        if rep > 1:
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)

    ba = cfg.effective_batch_axes(mesh)
    spec = P(ba, cfg.sp_axes, None, None)

    if cfg.strategy == "swift_torus":
        body = partial(
            torus_attention, layout=layout, scale=scale, causal=causal,
            window=window, unroll=cfg.unroll_ring,
            fused_pull_q=cfg.torus_fused_pull_q, kv_block=cfg.attn_kv_block,
            backend=cfg.comm_backend, interpret=cfg.kernel_interpret,
            wire_dtype=cfg.a2a_wire_dtype,
        )
    else:
        body = partial(
            _usp_like, layout=layout, scale=scale, causal=causal,
            window=window, unroll=cfg.unroll_ring, kv_block=cfg.attn_kv_block,
            backend=cfg.comm_backend, interpret=cfg.kernel_interpret,
            wire_dtype=cfg.a2a_wire_dtype,
        )

    fn = shard_map(
        lambda q, k, v: body(q, k, v),
        mesh=mesh,
        in_specs=(spec, spec, spec),
        out_specs=spec,
        check_vma=False,
    )
    return fn(q, k, v)
