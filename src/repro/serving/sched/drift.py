"""Drift-triggered displaced-pipeline resync (DESIGN.md §9; ROADMAP item).

``PipelineConfig.resync_every`` re-syncs on a fixed period regardless of
how stale the displaced KV actually is.  ``DriftPolicy`` instead consumes
the per-request ``kv_drift`` trajectory the sampler surfaces
(``DiTResult.kv_drift``) and schedules a fully-synchronous step exactly
when a request's staleness crosses ITS threshold — a quality-SLA knob
carried per request (``DiTRequest.drift_threshold``), falling back to the
policy-wide default.

The decision uses the PREVIOUS step's drift (the current step's drift is
only known after running it), so a threshold crossing at step i triggers
the resync at step i+1; warm steps reset drift to zero.  Reading the
drift on the host costs one device sync per step — the price of closing
the loop; engines keep the sync-free static schedule when no threshold is
configured.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from ...core.pipefusion import PipelineConfig
from ..metrics import Tracker


@dataclasses.dataclass(frozen=True)
class DriftPolicy:
    """Threshold rule for when a displaced step must be replaced by a
    warm (fully-synchronous) one."""

    threshold: float | None = None  # default kv-drift bound per request

    def engaged(self, thresholds: Sequence[float | None]) -> bool:
        """Whether any request carries a bound (policy-wide or its own) —
        if not, the engine keeps the static, sync-free schedule."""
        return self.threshold is not None or any(
            t is not None for t in thresholds)

    def warm(self, pipe: PipelineConfig, step: int,
             last_drift: Sequence[float] | None,
             thresholds: Sequence[float | None],
             tracker: Tracker | None = None) -> bool:
        """Decide step ``step`` given the previous step's per-request
        drift (None = previous step was warm or this is the first).

        With a ``tracker`` (DESIGN.md §11) the threshold crossing that
        forces a resync is published as a ``drift.trigger`` gauge (the
        offending request's drift value, tagged with its batch row and
        the bound it crossed) — the trace shows WHY a warm step was
        scheduled, not just that one happened."""
        if step < pipe.warmup_steps:
            return True
        if last_drift is None:
            return False
        for j, (d, t) in enumerate(zip(last_drift, thresholds)):
            bound = t if t is not None else self.threshold
            if bound is not None and d > bound:
                if tracker is not None:
                    tracker.log("drift.trigger", d, step=step,
                                tags={"row": j, "bound": bound})
                return True
        return False
