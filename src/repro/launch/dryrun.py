import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import (device count locks at first init); scoped to
#   this module only — tests and benchmarks see 1 device.

DOC = """Multi-pod dry-run (assignment deliverable (e)).

For every (architecture × input shape × mesh) combination this lowers and
compiles the real step function — train_step (optimizer included) for
train shapes, prefill/serve steps for inference shapes — against
ShapeDtypeStruct stand-ins (no allocation), prints memory_analysis() and
cost_analysis(), and records the roofline terms (deliverable (g)).

The 512 placeholder host devices above exist ONLY for this module; tests
and benchmarks see 1 device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
        --shape train_4k --mesh pod --strategy swift_torus
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
__doc__ = DOC

import argparse
import dataclasses
import json
import math
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import ALL_ARCHS, ASSIGNED_ARCHS, ModelConfig, SHAPES, get_config
from ..configs.shapes import DIT_SHAPES, InputShape
from ..core import SPConfig
from ..models import ParallelContext, get_model, param_shardings
from ..train.optimizer import AdamWConfig, init_adamw
from ..train.trainer import batch_shardings, make_train_step
from . import roofline as rl
from .mesh import make_production_mesh

LONG_CONTEXT_WINDOW = 4096


def sp_config_for(shape: InputShape, mesh: Mesh, strategy: str,
                  fused_pull_q: bool = False,
                  kv_block: int | None = None) -> SPConfig:
    """Map the assignment's input shapes onto the production mesh axes
    (DESIGN.md §4)."""
    multi_pod = "pod" in mesh.axis_names
    kw = dict(strategy=strategy, torus_fused_pull_q=fused_pull_q,
              attn_kv_block=kv_block)
    if shape.kind == "training":
        ba = ("pod", "data") if multi_pod else ("data",)
        return SPConfig(sp_axes=("model",), batch_axes=ba, **kw)
    if shape.kind == "prefill":
        if shape.global_batch == 1:  # DiT workloads: B=1, seq over data too
            sp = ("pod", "data", "model") if multi_pod else ("data", "model")
            return SPConfig(sp_axes=sp, batch_axes=None, **kw)
        sp = ("pod", "model") if multi_pod else ("model",)
        return SPConfig(sp_axes=sp, batch_axes=("data",), **kw)
    # decode
    if shape.global_batch == 1:  # long_500k: all devices shard the context
        sp = ("pod", "data", "model") if multi_pod else ("data", "model")
        return SPConfig(sp_axes=sp, batch_axes=None, **kw)
    sp = ("pod", "model") if multi_pod else ("model",)
    return SPConfig(sp_axes=sp, batch_axes=("data",), **kw)


def config_for(arch: str, shape: InputShape) -> ModelConfig:
    cfg = get_config(arch)
    if (shape.name == "long_500k" and not cfg.attention_free
            and cfg.window is None):
        # sub-quadratic requirement: sliding-window variant (DESIGN.md §5)
        cfg = dataclasses.replace(cfg, window=LONG_CONTEXT_WINDOW)
    return cfg


def abstract_init(cfg: ModelConfig, ep_degree: int):
    """Params as ShapeDtypeStructs (+ concrete logical axes) — no allocation."""
    bundle = get_model(cfg)
    captured = {}

    def f(key):
        params, axes = bundle.init(cfg, key, ep_degree)
        captured["axes"] = axes
        return params

    params_sds = jax.eval_shape(f, jax.random.PRNGKey(0))
    return params_sds, captured["axes"], bundle


def cache_shardings(caches_sds, mesh: Mesh, sp: SPConfig):
    ba, sa = sp.batch_axes, sp.sp_axes

    def spec(path, s):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in ("k", "v"):  # [layers, B, L, Hkv, D]
            return NamedSharding(mesh, P(None, ba, sa, None, None))
        # ssm states / shift buffers: replicate over SP, shard batch
        return NamedSharding(mesh, P(None, ba, *([None] * (len(s.shape) - 2))))

    return jax.tree_util.tree_map_with_path(spec, caches_sds)


def build_step(cfg: ModelConfig, shape: InputShape, mesh: Mesh, sp: SPConfig,
               remat: str = "full", last_only: bool = False,
               ep_token_gather: bool = False):
    """Construct the jitted step fn + abstract args for one config."""
    ep = mesh.shape.get("model", 1)
    params_sds, axes, bundle = abstract_init(cfg, ep)
    mode = "train" if shape.kind == "training" else "serve"
    p_sh = param_shardings(axes, cfg, mesh, mode)
    batch_sds = bundle.input_specs(cfg, shape, abstract=True)
    b_sh = batch_shardings(batch_sds, mesh, sp)

    if shape.kind == "training":
        # bf16 Adam moments for arctic-class models (see AdamWConfig)
        big = cfg.params_dense_estimate() > 1e11
        opt_cfg = AdamWConfig(moments_dtype="bfloat16" if big else "float32")
        opt_sds = jax.eval_shape(lambda p: init_adamw(p, opt_cfg), params_sds)
        opt_sh = type(opt_sds)(
            step=NamedSharding(mesh, P()),
            mu=p_sh, nu=p_sh,
        )
        step_fn = make_train_step(cfg, mesh, sp, opt_cfg, remat=remat)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_sh, opt_sh, b_sh),
            out_shardings=(p_sh, opt_sh, None),
            donate_argnums=(0, 1),
        )
        args = (params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        ctx = ParallelContext(mesh, sp, "prefill")
        lo = last_only and cfg.family not in ("audio", "dit")

        def prefill_step(params, batch):
            if lo:
                return bundle.apply(params, batch, cfg, ctx, last_only=True)
            return bundle.apply(params, batch, cfg, ctx)

        out_sh = NamedSharding(
            mesh, P(sp.batch_axes, None if lo else sp.sp_axes, None))
        jitted = jax.jit(prefill_step, in_shardings=(p_sh, b_sh),
                         out_shardings=out_sh)
        args = (params_sds, batch_sds)
    else:  # decode
        ctx = ParallelContext(mesh, sp, "decode",
                              ep_token_gather=ep_token_gather)
        caches_sds = jax.eval_shape(
            lambda: bundle.init_caches(cfg, shape.global_batch, shape.seq_len,
                                       jnp.bfloat16))
        c_sh = cache_shardings(caches_sds, mesh, sp)

        def serve_step(params, batch, caches, cur_index):
            return bundle.step(params, batch, caches, cur_index, cfg, ctx)

        jitted = jax.jit(
            serve_step,
            in_shardings=(p_sh, b_sh, c_sh, NamedSharding(mesh, P())),
            out_shardings=(None, c_sh),
        )
        args = (params_sds, batch_sds, caches_sds,
                jax.ShapeDtypeStruct((), jnp.int32))
    return jitted, args


def _depth_variant(cfg: ModelConfig, n: int) -> ModelConfig:
    kw = {"n_layers": n}
    if cfg.encoder_layers:
        kw["encoder_layers"] = n
    return dataclasses.replace(cfg, **kw)


def _compile_costs(cfg, shape, mesh, sp, pod_size, remat="full",
                   last_only=False, ep_token_gather=False):
    jitted, args = build_step(cfg, shape, mesh, sp, remat=remat,
                              last_only=last_only,
                              ep_token_gather=ep_token_gather)
    compiled = jitted.lower(*args).compile()
    cost = compiled.cost_analysis()
    coll = rl.parse_collectives(compiled.as_text(), pod_size)
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll.bytes_total), float(coll.bytes_inter_pod))


def lower_pair(arch: str, shape_name: str, mesh: Mesh, strategy: str,
               *, fused_pull_q: bool = False, remat: str = "full",
               last_only: bool = False, ep_token_gather: bool = False,
               kv_block: int | None = None):
    """Lower + compile one (arch, shape, mesh, strategy). Returns result dict.

    XLA's cost_analysis counts loop bodies ONCE, so the layer-scan cost is
    recovered by a two-point extrapolation over depth: compile n_layers ∈
    {1, 2} variants (inner loops are unrolled by construction) and take
    cost(L) = cost(1) + (cost(2) - cost(1))·(L - 1).  memory_analysis and
    the compile-success proof come from the FULL-depth compile.
    """
    shape = {**SHAPES, **DIT_SHAPES}[shape_name]
    cfg = config_for(arch, shape)
    sp = sp_config_for(shape, mesh, strategy, fused_pull_q, kv_block)
    chips = math.prod(mesh.shape.values())
    pod_size = chips // mesh.shape.get("pod", 1)

    opt_kw = dict(remat=remat, last_only=last_only,
                  ep_token_gather=ep_token_gather)
    jitted, args = build_step(cfg, shape, mesh, sp, **opt_kw)
    t0 = time.time()
    lowered = jitted.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    mem = compiled.memory_analysis()

    f1, b1, c1, i1 = _compile_costs(_depth_variant(cfg, 1), shape, mesh, sp,
                                    pod_size, **opt_kw)
    f2, b2, c2, i2 = _compile_costs(_depth_variant(cfg, 2), shape, mesh, sp,
                                    pod_size, **opt_kw)
    L = cfg.n_layers
    # slope clamped at 0: fusion differences between the depth probes can
    # make a term non-monotone by a few %; never extrapolate downward.
    ext = lambda v1, v2: v1 + max(0.0, v2 - v1) * (L - 1)
    cost = {"flops": ext(f1, f2), "bytes accessed": ext(b1, b2)}
    coll_total, coll_inter = ext(c1, c2), ext(i1, i2)

    if shape.kind == "training":
        # fwd+bwd ≈ 3x forward matmul flops
        mflops = 6.0 * cfg.params_active_estimate() * shape.seq_len * shape.global_batch
    elif shape.kind == "prefill":
        mflops = 2.0 * cfg.params_active_estimate() * shape.seq_len * shape.global_batch
    else:
        mflops = 2.0 * cfg.params_active_estimate() * 1 * shape.global_batch
    roof = rl.analyze_from_terms(
        flops=cost["flops"], byts=cost["bytes accessed"],
        coll_bytes=coll_total, coll_inter=coll_inter,
        chips=chips, model_flops=mflops,
    )

    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multipod" if "pod" in mesh.axis_names else "pod",
        "strategy": strategy,
        "fused_pull_q": fused_pull_q,
        "remat": remat,
        "last_only": last_only,
        "ep_token_gather": ep_token_gather,
        "kv_block": kv_block,
        "chips": chips,
        "step_kind": shape.kind,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
            "total_bytes": (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                            + mem.generated_code_size_in_bytes),
        },
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed")},
        "roofline": roof.as_dict(),
        "window_variant": config_for(arch, shape).window,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--strategy", default="swift_torus")
    ap.add_argument("--fused-pull-q", action="store_true")
    ap.add_argument("--remat", default="full", choices=["full", "dots", "none"])
    ap.add_argument("--last-only", action="store_true")
    ap.add_argument("--ep-token-gather", action="store_true")
    ap.add_argument("--kv-block", type=int, default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--dit", action="store_true", help="also run DiT workloads")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    pairs = []
    if args.all:
        for arch in ASSIGNED_ARCHS:
            for shape in SHAPES:
                pairs.append((arch, shape))
        if args.dit:
            for arch in ("flux-12b", "cogvideox-5b"):
                for shape in DIT_SHAPES:
                    pairs.append((arch, shape))
    else:
        pairs.append((args.arch, args.shape))

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_fail = 0
    for arch, shape in pairs:
        for mp in meshes:
            mesh = make_production_mesh(multi_pod=mp)
            tag = f"{arch}_{shape}_{'multipod' if mp else 'pod'}_{args.strategy}"
            if args.tag:
                tag += f"_{args.tag}"
            try:
                res = lower_pair(arch, shape, mesh, args.strategy,
                                 fused_pull_q=args.fused_pull_q,
                                 remat=args.remat, last_only=args.last_only,
                                 ep_token_gather=args.ep_token_gather,
                                 kv_block=args.kv_block)
                with open(f"{args.out}/{tag}.json", "w") as f:
                    json.dump(res, f, indent=1)
                r = res["roofline"]
                print(f"OK   {tag}: compile={res['compile_s']}s "
                      f"mem={res['memory']['total_bytes']/2**30:.2f}GiB "
                      f"t_comp={r['t_compute']:.2e} t_mem={r['t_memory']:.2e} "
                      f"t_coll={r['t_collective']:.2e} -> {r['bottleneck']}",
                      flush=True)
                n_ok += 1
            except Exception as e:
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
                n_fail += 1
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
