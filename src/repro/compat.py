"""Version compatibility shims for the jax APIs this repo uses.

The container pins jax 0.4.37, where ``shard_map`` still lives in
``jax.experimental.shard_map`` (with ``check_rep`` instead of
``check_vma``) and the Pallas-TPU compiler params class is named
``TPUCompilerParams``.  Newer jax promotes both.  Every call site imports
from here so the codebase runs on either side of the rename.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """jax.shard_map with the 0.4.x fallback (check_vma -> check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def make_mesh(shape, axis_names):
    """jax.make_mesh with explicit Auto axis types where supported.

    ``jax.sharding.AxisType`` (and make_mesh's ``axis_types``) only exist
    on newer jax; 0.4.x meshes are implicitly Auto.
    """
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axis_names)
    return jax.make_mesh(shape, axis_names,
                         axis_types=(AxisType.Auto,) * len(axis_names))


@jax.custom_jvp
def optimization_barrier(xs):
    """``lax.optimization_barrier`` that is differentiable on jax 0.4.x.

    0.4.37 has no JVP rule for the barrier primitive; training through the
    Torus/Ring schedules needs one.  The custom rule applies the barrier to
    the primals (the scheduling pin is a forward-pass concern) and passes
    tangents through untouched — identity, so reverse-mode transposition
    works too.
    """
    return jax.lax.optimization_barrier(xs)


@optimization_barrier.defjvp
def _optimization_barrier_jvp(primals, tangents):
    (xs,), (dxs,) = primals, tangents
    return jax.lax.optimization_barrier(xs), dxs


def tpu_compiler_params(pltpu, **kwargs):
    """pltpu.CompilerParams on new jax, pltpu.TPUCompilerParams on 0.4.x."""
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)
