"""AdamW with cosine schedule and global-norm clipping (pure JAX pytrees).

Optimizer state mirrors the param pytree, so it inherits the params'
shardings (ZeRO-style when train rules shard weight dims over 'data').
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any  # first moment, mirrors params
    nu: Any  # second moment, mirrors params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    # bf16 moments halve optimizer HBM — production practice for very large
    # MoEs (arctic-class) where f32 Adam state alone would exceed the pod
    moments_dtype: str = "float32"


def init_adamw(params, cfg: AdamWConfig | None = None) -> AdamWState:
    dt = jnp.dtype(cfg.moments_dtype) if cfg else jnp.float32
    z = lambda p: jnp.zeros_like(p, dtype=dt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(z, params),
        nu=jax.tree.map(z, params),
    )


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics).

    All per-tensor arithmetic happens inside one tree.map leaf function so
    XLA never materializes a whole-model f32 gradient copy — peak HBM stays
    params + moments + (bf16) grads + per-tensor temps.
    """
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, state.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moments_dtype)

    def upd(p, m, n, g):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        n_new = cfg.b2 * n.astype(jnp.float32) + (1 - cfg.b2) * g * g
        delta = (m_new / b1c) / (jnp.sqrt(n_new / b2c) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m_new.astype(mdt), n_new.astype(mdt))

    triples = jax.tree.map(upd, params, state.mu, state.nu, grads)
    is_triple = lambda x: isinstance(x, tuple) and len(x) == 3 and not isinstance(x[0], tuple)
    new_params = jax.tree.map(lambda t: t[0], triples, is_leaf=is_triple)
    mu = jax.tree.map(lambda t: t[1], triples, is_leaf=is_triple)
    nu = jax.tree.map(lambda t: t[2], triples, is_leaf=is_triple)
    return new_params, AdamWState(step=step, mu=mu, nu=nu), {
        "grad_norm": gnorm, "lr": lr,
    }
