"""Diffusion Transformer (the paper's own workload family).

Latent patches arrive pre-patchified (VAE + patchifier stubbed per
DESIGN.md §6) together with a text-conditioning token sequence; the model
concatenates [cond ; latents], runs adaLN-zero DiT blocks with the
configured SP attention strategy (bidirectional — DiTs are non-causal),
and projects the latent positions back to the latent channel dim,
predicting the flow-matching velocity.

This is the model the serving engine (serving/engine.py) samples with.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..comm import Stream, pipe_handoff
from ..configs.base import ModelConfig
from ..core.pipefusion import (
    KVState,
    drop_rows,
    patch_slices,
    stage_layers,
    update_state_rows,
)
from .blocks import (
    ParallelContext,
    ParamBuilder,
    Params,
    attention,
    init_attention,
    init_linear,
    init_mlp,
    init_norm,
    linear,
    mlp,
    norm,
    sinusoidal_embedding,
    stack_layers,
)

LATENT_CHANNELS = 64
COND_TOKENS = 256
TIME_EMB = 256


def _init_block(key, cfg: ModelConfig):
    b = ParamBuilder(key, dtype=jnp.dtype(cfg.dtype))
    init_norm(b, "ln_attn", cfg.d_model, cfg.norm)
    init_attention(b, cfg)
    init_norm(b, "ln_mlp", cfg.d_model, cfg.norm)
    init_mlp(b, cfg)
    # adaLN-zero: 6 modulation vectors from the time embedding; zero-init so
    # blocks start as identity (DiT paper).
    init_linear(b, "ada", cfg.d_model, 6 * cfg.d_model, ("embed", None),
                init="zeros")
    return b.params, b.axes


def init_dit(cfg: ModelConfig, key: jax.Array, ep_degree: int = 1):
    k1, k2 = jax.random.split(key)
    b = ParamBuilder(k1, dtype=jnp.dtype(cfg.dtype))
    init_linear(b, "proj_in", LATENT_CHANNELS, cfg.d_model, (None, "embed"))
    init_linear(b, "cond_proj", cfg.d_model, cfg.d_model, ("embed", "embed_out"))
    init_linear(b, "time_mlp1", TIME_EMB, cfg.d_model, (None, "embed"))
    init_linear(b, "time_mlp2", cfg.d_model, cfg.d_model, ("embed", "embed_out"))
    init_norm(b, "ln_f", cfg.d_model, cfg.norm)
    init_linear(b, "ada_f", cfg.d_model, 2 * cfg.d_model, ("embed", None),
                init="zeros")
    init_linear(b, "proj_out", cfg.d_model, LATENT_CHANNELS, ("embed", None),
                init="zeros")
    params, axes = b.params, b.axes
    lp, la = stack_layers(partial(_init_block, cfg=cfg), cfg.n_layers, k2)
    params["layers"], axes["layers"] = lp, la
    return params, axes


def _modulate(x, shift, scale):
    return x * (1.0 + scale[:, None]) + shift[:, None]


def _time_embedding(params: Params, timesteps: jax.Array, dtype) -> jax.Array:
    temb = sinusoidal_embedding(TIME_EMB, TIME_EMB)  # reuse table as freqs
    t_feat = jnp.concatenate(
        [jnp.sin(timesteps[:, None] * 1000.0 * temb[0, : TIME_EMB // 2]),
         jnp.cos(timesteps[:, None] * 1000.0 * temb[0, : TIME_EMB // 2])],
        axis=-1,
    ).astype(dtype)
    return linear(jax.nn.silu(linear(t_feat, params["time_mlp1"])),
                  params["time_mlp2"])  # [B, d]


def _final_projection(params: Params, cfg: ModelConfig, x: jax.Array,
                      t_emb: jax.Array) -> jax.Array:
    sh, sc = jnp.split(linear(t_emb, params["ada_f"]), 2, axis=-1)
    x = _modulate(norm(x, params["ln_f"], cfg.norm), sh, sc)
    return linear(x, params["proj_out"])


def dit_forward(
    params: Params,
    cfg: ModelConfig,
    ctx: ParallelContext,
    *,
    latents: jax.Array,  # [B, T, LATENT_CHANNELS]
    cond: jax.Array,  # [B, COND_TOKENS, d] (stub text encoder output)
    timesteps: jax.Array,  # [B] in [0, 1]
    return_layer_kv: bool = False,
):
    """Returns predicted velocity [B, T, LATENT_CHANNELS].

    With ``return_layer_kv`` also returns a KVState of every layer's
    full-sequence post-RoPE (K, V) — the warmup pass of displaced patch
    pipelining (DESIGN.md §7) uses this to seed the stale-activation
    caches.  The x-path computation is identical either way.
    """
    b_, t_, _ = latents.shape
    x_lat = linear(latents, params["proj_in"])
    x_cond = linear(cond, params["cond_proj"])
    x = jnp.concatenate([x_cond, x_lat], axis=1)
    l_ = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(l_)[None], (b_, l_))
    t_emb = _time_embedding(params, timesteps, x.dtype)

    def body(x, lp):
        mod = linear(t_emb, lp["ada"])  # [B, 6d]
        sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
        h = _modulate(norm(x, lp["ln_attn"], cfg.norm), sh1, sc1)
        if return_layer_kv:
            o, _, kv = attention(h, lp["attn"], cfg, ctx, positions,
                                 causal=False, return_kv=True)
        else:
            o, _ = attention(h, lp["attn"], cfg, ctx, positions, causal=False)
            kv = None
        x = x + g1[:, None] * o
        h = _modulate(norm(x, lp["ln_mlp"], cfg.norm), sh2, sc2)
        x = x + g2[:, None] * mlp(h, lp["mlp"], cfg)
        return x, kv

    body = ctx.remat_wrap(body)
    x, kv = lax.scan(body, x, params["layers"], unroll=cfg.n_layers <= 2)
    v = _final_projection(params, cfg, x, t_emb)[:, COND_TOKENS:]
    if return_layer_kv:
        return v, KVState(k=kv[0], v=kv[1])
    return v


def dit_forward_displaced(
    params: Params,
    cfg: ModelConfig,
    ctx: ParallelContext,
    *,
    latents: jax.Array,  # [B, T, LATENT_CHANNELS]
    cond: jax.Array,  # [B, COND_TOKENS, d]
    timesteps: jax.Array,  # [B]
    kv_state: KVState,  # per-layer stale KV from the previous sampler step
    num_patches: int,
    pp: int = 1,
) -> tuple[jax.Array, KVState]:
    """One displaced-pipeline DiT forward (PipeFusion async; DESIGN.md §7).

    The latent sequence is split into ``num_patches`` patches (patch 0 also
    owns the conditioning tokens); each patch runs the full block stack
    with fresh Q/KV for its own rows and one-step-stale KV (``kv_state``)
    for every other row.  Fresh per-layer KV is written back, giving the
    next step its stale state.  Returns (velocity, new KVState).

    The python patch loop realises the same dataflow the pp-stage pipeline
    executes across devices: stage s = layers ``stage_layers(L, pp)[s]``,
    micro-step (p, s) runs patch p's stage-s scan segment.  When the mesh
    carries a ``pp``-sized ``ctx.sp.pp_axis``, every stage boundary is an
    explicit one-sided hand-off over the pipe axis (``comm.pipe_handoff``,
    DESIGN.md §8) instead of a GSPMD-implicit transfer: the HLO then names
    one collective-permute per (patch, boundary) carrying the activation,
    independent of the neighbouring patches' compute — which is what lets
    stage s's compute on patch p overlap patch (p+1)'s transfer, and what
    ``comm.trace`` validates against ``comm_model.hybrid_step_latency``'s
    bubble/overlap assumptions.  Without the axis (single-device tests)
    the hand-off is skipped and the maths is unchanged.
    """
    b_, t_, _ = latents.shape
    stages = stage_layers(cfg.n_layers, pp)
    slices = patch_slices(COND_TOKENS, t_, num_patches)
    pp_axis = ctx.sp.pp_axis
    explicit_handoff = (pp > 1 and pp_axis is not None
                        and pp_axis in ctx.mesh.axis_names
                        and ctx.mesh.shape[pp_axis] == pp)
    stream = Stream("pipe")
    batch_axes = ctx.sp.effective_batch_axes(ctx.mesh)

    x_lat = linear(latents, params["proj_in"])
    x_cond = linear(cond, params["cond_proj"])
    x_full = jnp.concatenate([x_cond, x_lat], axis=1)
    total = x_full.shape[1]
    t_emb = _time_embedding(params, timesteps, x_full.dtype)

    new_state = kv_state
    vel_chunks = []
    for start, length in slices:
        xp = lax.dynamic_slice_in_dim(x_full, start, length, axis=1)
        pos = jnp.broadcast_to(jnp.arange(start, start + length)[None],
                               (b_, length))
        # stale KV for every NON-resident row, per layer: [L, B, T-len, ...]
        ek = drop_rows(kv_state.k, start, length, axis=2)
        ev = drop_rows(kv_state.v, start, length, axis=2)

        def body(x, xs):
            lp, ek_l, ev_l = xs
            mod = linear(t_emb, lp["ada"])
            sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
            h = _modulate(norm(x, lp["ln_attn"], cfg.norm), sh1, sc1)
            o, _, kv = attention(h, lp["attn"], cfg, ctx, pos, causal=False,
                                 extra_kv=(ek_l, ev_l), return_kv=True)
            x = x + g1[:, None] * o
            h = _modulate(norm(x, lp["ln_mlp"], cfg.norm), sh2, sc2)
            x = x + g2[:, None] * mlp(h, lp["mlp"], cfg)
            return x, kv

        # stage-segmented scan: stage s runs its n_layers/pp blocks, then
        # hands the activation to stage s+1 over the pipe axis
        kp_segs, vp_segs = [], []
        for s, (l0, cnt) in enumerate(stages):
            seg = jax.tree.map(lambda a: a[l0:l0 + cnt], params["layers"])
            xp, (kp_s, vp_s) = lax.scan(body, xp,
                                        (seg, ek[l0:l0 + cnt], ev[l0:l0 + cnt]),
                                        unroll=cnt <= 2)
            kp_segs.append(kp_s)
            vp_segs.append(vp_s)
            if explicit_handoff and s < pp - 1:
                xp = pipe_handoff(xp, ctx.mesh, pp_axis,
                                  batch_axes=batch_axes, stream=stream)
        kp = jnp.concatenate(kp_segs, axis=0)
        vp = jnp.concatenate(vp_segs, axis=0)
        new_state = update_state_rows(new_state, kp, vp, start)
        vp_out = _final_projection(params, cfg, xp, t_emb)
        if start == 0:  # patch 0 carries the conditioning tokens
            vp_out = vp_out[:, COND_TOKENS:]
        vel_chunks.append(vp_out)
    assert total == COND_TOKENS + t_
    return jnp.concatenate(vel_chunks, axis=1), new_state
