"""Distributed decode attention over a sequence-sharded KV cache.

Decode shapes (one new token against a long cached context) invert the SP
problem: Q is a single position, the KV cache is what is sharded.  Each SP
shard attends the replicated Q against its local cache slice, producing an
online-softmax partial ``(O', l, m)``; partials are combined with one tiny
``pmax``/``psum`` pair over the SP axes (the distributed form of the
Appendix-C merge — communication is O(B·H·D), independent of context
length).  The new token's KV is written into the shard that owns position
``cur_index``.

This is the flash-decoding analogue of the paper's schedule: all heavy
tensors stay put; only scalar-scale statistics cross the network.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import shard_map

from .collectives import flat_rank
from .softmax import MaskSpec, attend_partial
from .strategy import SPConfig


def _local_decode(
    q, k_cache, v_cache, new_k, new_v, cur_index, *, sp_axes, shard_len, scale, window
):
    """Per-device body: write new KV into my slice if I own the position,
    attend q against my slice, merge partials across the SP group."""
    my_rank = flat_rank(sp_axes)
    local_start = my_rank * shard_len
    owns = (cur_index >= local_start) & (cur_index < local_start + shard_len)
    idx = jnp.clip(cur_index - local_start, 0, shard_len - 1)

    def write(cache, new):
        updated = lax.dynamic_update_slice_in_dim(cache, new, idx, axis=1)
        return jnp.where(owns, updated, cache)

    k_cache = write(k_cache, new_k)
    v_cache = write(v_cache, new_v)

    pos = local_start + jnp.arange(shard_len)
    valid = pos <= cur_index
    if window is not None:
        valid &= pos > cur_index - window
    part = attend_partial(
        q, k_cache, v_cache, scale=scale, mask=MaskSpec(valid_k=valid)
    )
    # distributed Appendix-C merge: one pmax + two psums of [B, H, 1]-sized stats
    m_g = lax.pmax(part.m, sp_axes)
    safe = jnp.where(jnp.isneginf(part.m) & jnp.isneginf(m_g), 0.0, part.m - m_g)
    a = jnp.exp(safe)
    l_g = lax.psum(part.l * a, sp_axes)
    o_g = lax.psum(part.o * jnp.swapaxes(a, 1, 2)[..., None], sp_axes)
    l_sw = jnp.swapaxes(l_g, 1, 2)[..., None]  # [B, Lq, Hq, 1]
    o = o_g / jnp.where(l_sw == 0.0, 1.0, l_sw)
    return o.astype(q.dtype), k_cache, v_cache


def decode_attention(
    q: jax.Array,  # [B, 1, Hq, D] the new token's query
    k_cache: jax.Array,  # [B, L_max, Hkv, D] sharded over cfg.sp_axes on L
    v_cache: jax.Array,
    new_k: jax.Array,  # [B, 1, Hkv, D]
    new_v: jax.Array,
    cur_index: jax.Array,  # [] int32: position being decoded
    *,
    mesh: jax.sharding.Mesh,
    cfg: SPConfig,
    scale: float | None = None,
    window: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (attention output [B, 1, Hq, D], updated k_cache, v_cache)."""
    sp = math.prod(mesh.shape[a] for a in cfg.sp_axes)
    ba = cfg.batch_axes
    shard_len = k_cache.shape[1] // sp
    if scale is None:
        scale = q.shape[-1] ** -0.5

    qspec = P(ba, None, None, None)
    cspec = P(ba, cfg.sp_axes, None, None)
    body = partial(
        _local_decode,
        sp_axes=cfg.sp_axes,
        shard_len=shard_len,
        scale=scale,
        window=window,
    )
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(qspec, cspec, cspec, qspec, qspec, P()),
        out_specs=(qspec, cspec, cspec),
        check_vma=False,
    )
    return fn(q, k_cache, v_cache, new_k, new_v, cur_index)
