"""Hybrid-parallel sweep (beyond-paper; DESIGN.md §7): predicted per-step
serving latency of swift_torus SP alone vs + cfg parallelism vs + patch
pipelining, at EQUAL device count, from the analytical model.

Guided sampling (CFG) is on for every row — that is the serving scenario
the hybrid axes exist for.  All plans spend the same total FLOPs per step;
the hybrid plans win by (a) halving the sequential-guidance factor with
one velocity-sized recombine and (b) replacing per-layer inter-machine SP
collectives with one activation hand-off per stage boundary per step.

The win is regime-dependent and the sweep shows both sides honestly: at
the paper's longest sequences attention compute dominates and Torus hides
the inter-machine traffic anyway (hybrid ≈ SP-only, minus the pipeline
bubble); at medium resolutions — the latency-critical serving bucket —
per-layer comm exposure dominates SP-only and the hybrid plan, whose SP
sub-mesh never leaves the machine, wins by multiples.

Rows: ``hybrid_sweep/<wl>/N<n>/<plan>`` with us = predicted step latency
and derived = speedup over the SP-only plan (see EXPERIMENTS.md).
"""
from __future__ import annotations

from repro.core import plan, plan_hybrid
from repro.core.comm_model import (
    LayerWorkload,
    hybrid_step_latency,
    sp_step_latency,
)

from .common import row

# (workload, DiT depth): the paper's two geometries at several latent
# resolutions — seq scales ~ pixels, so 1024px ≈ 4k tokens for Flux.
WORKLOADS = {
    "flux_1024": (LayerWorkload(batch=1, seq=4_096, heads=24, head_dim=128), 96),
    "flux_2048": (LayerWorkload(batch=1, seq=16_384, heads=24, head_dim=128), 96),
    "flux_3072": (LayerWorkload(batch=1, seq=36_864, heads=24, head_dim=128), 96),
    "cogvideox_5s": (LayerWorkload(batch=1, seq=12_288, heads=24, head_dim=64), 42),
    "cogvideox_20s": (LayerWorkload(batch=1, seq=49_152, heads=24, head_dim=64), 42),
}
M_PER_MACHINE = 8  # paper testbed: 8 GPUs per machine


def _sweep():
    """Yield (name, workload-name, n, plan-dict, prediction-dict) points."""
    for wname, (wl, n_layers) in WORKLOADS.items():
        for n in (2, 4):
            sp_only = plan(n, M_PER_MACHINE, wl.heads)
            base = sp_step_latency(sp_only, wl, n_layers=n_layers,
                                   guided=True)
            yield (wname, n, wl, n_layers, "sp_only",
                   {"cfg": 1, "pp": 1, "p_ulysses": sp_only.p_ulysses,
                    "p_ring": sp_only.p_ring}, base, base)
            plans = {
                "cfg": dict(cfg_parallel=True, pp=1),
                "cfg_pp2": dict(cfg_parallel=True, pp=2),
            }
            for pname, kw in plans.items():
                h = plan_hybrid(n, M_PER_MACHINE, wl.heads,
                                n_layers=n_layers, **kw)
                pred = hybrid_step_latency(h, wl, n_layers=n_layers,
                                           guided=True)
                yield (wname, n, wl, n_layers, pname,
                       {"cfg": h.cfg, "pp": h.pp, "p_ulysses": h.sp.p_ulysses,
                        "p_ring": h.sp.p_ring}, pred, base)


def run() -> list[str]:
    rows = []
    for wname, n, wl, n_layers, pname, pl, pred, base in _sweep():
        if pname == "sp_only":
            rows.append(row(f"hybrid_sweep/{wname}/N{n}/sp_only",
                            pred["t_step"] * 1e6,
                            f"Pu={pl['p_ulysses']},Pr={pl['p_ring']}"))
        else:
            rows.append(row(
                f"hybrid_sweep/{wname}/N{n}/{pname}", pred["t_step"] * 1e6,
                f"cfg={pl['cfg']},pp={pl['pp']},Pu={pl['p_ulysses']},"
                f"Pr={pl['p_ring']},speedup={base['t_step'] / pred['t_step']:.2f}x"))
    return rows


def records() -> list[dict]:
    """Structured trajectory records for BENCH_hybrid_sweep.json: one entry
    per swept configuration, pairing the config with the comm-model
    prediction breakdown.  ``measured_step_us`` is null on this CPU
    container — the field exists so multi-machine runs can fill it in and
    the ROADMAP calibration item has a fit target."""
    out = []
    for wname, n, wl, n_layers, pname, pl, pred, _ in _sweep():
        out.append({
            "name": f"hybrid_sweep/{wname}/N{n}/{pname}",
            "workload": {"batch": wl.batch, "seq": wl.seq, "heads": wl.heads,
                         "head_dim": wl.head_dim, "n_layers": n_layers},
            "n_machines": n,
            "m_per_machine": M_PER_MACHINE,
            "plan": pl,
            "predicted_step_us": pred["t_step"] * 1e6,
            "predicted_breakdown": {k: v for k, v in pred.items()
                                    if k != "t_step"},
            "measured_step_us": None,
        })
    return out
