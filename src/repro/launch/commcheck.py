"""HLO overlap validation gate (CI step; DESIGN.md §8).

Traces the two comm-heaviest programs — swift_torus attention and the
displaced patch pipeline — on an 8-fake-device CPU mesh, records their
intended one-sided schedules (repro.comm.trace), compiles, and validates:

  * every channel put appears as a collective-permute with the intended
    route (device pairs), and
  * every declared overlap (torus hops vs attend compute, ring rotation
    vs attend, pipe hand-off vs stage compute) is admissible in the
    compiled program.

The gate then runs ONCE MORE with ``backend="pallas"`` (DESIGN.md §8.1,
interpret mode): the same swift_torus program through the Pallas channel
backend + fused ring kernel, validating (a) the emulation branch's wire
moves still carry the intended routes in HLO and (b) the recorded
semaphore schedule is a valid protocol pairing — every put signaled
exactly once, no wait-before-put, and no blocking wait before the last
compute block of a fused step.

Exit code 1 on any failure, so schedule regressions (a barrier that
serialises a put, a refactor that silently drops a transfer or fires a
semaphore twice) fail fast.

    python -m repro.launch.commcheck

``--profile trace.jsonl`` additionally EXECUTES the validated programs
under the span profiler (DESIGN.md §12) and streams per-device comm-leg
and compute spans to the given JSONL file — the measured counterpart of
the intended schedules this gate validates statically.  Render with
``scripts/trace_report.py``.
"""
from __future__ import annotations

import argparse
import os
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", default=None, metavar="TRACE.JSONL",
                    help="also execute the validated programs under the "
                         "span profiler and write the trace here")
    args = ap.parse_args()
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()

    import dataclasses

    import jax
    import jax.numpy as jnp

    from .. import comm
    from ..configs import get_reduced
    from ..core import SPConfig, sp_attention
    from ..core.pipefusion import KVState, PipelineConfig
    from ..models import ParallelContext, get_model
    from ..models.dit import COND_TOKENS, dit_forward_displaced
    from ..serving import SamplerConfig
    from ..serving.sampler import hybrid_state_shape
    from .mesh import make_hybrid_mesh

    assert len(jax.devices()) == 8, "commcheck needs 8 (fake) devices"
    reports = []

    # --- 1. swift_torus attention: torus hops + ring rotations ----------
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    sp = SPConfig(strategy="swift_torus", sp_axes=("pod", "model"),
                  batch_axes=("data",))
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (2, 32, 2, 16))  # 2 heads => P_u = P_r = 2
    k = jax.random.normal(kk, (2, 32, 2, 16))
    v = jax.random.normal(kv, (2, 32, 2, 16))
    with comm.record("swift_torus") as tr:
        lowered = jax.jit(
            lambda q, k, v: sp_attention(q, k, v, mesh=mesh, cfg=sp)
        ).lower(q, k, v)
    # an empty trace must never pass the gate: both the torus hops and the
    # intra-ring rotations are expected on this (P_u=2, P_r=2) plan
    for want in ("torus", "ring"):
        if not any(e.stream == want for e in tr.events):
            print(f"commcheck FAIL: no '{want}' channel puts recorded in the "
                  "swift_torus trace")
            return 1
    reports.append(comm.validate(tr, lowered.compile().as_text(), mesh))

    # --- 2. displaced patch pipeline: pipe-axis stage hand-off ----------
    hmesh = make_hybrid_mesh(cfg=1, pipe=2, data=1, model=4)
    cfg = dataclasses.replace(get_reduced("flux-12b"), dtype="float32",
                              n_heads=4, n_kv_heads=4)
    params, _ = get_model(cfg).init(cfg, jax.random.PRNGKey(1), 1)
    psp = SPConfig(strategy="swift_torus", sp_axes=("model",),
                   batch_axes=("data",), pp_axis="pipe")
    ctx = ParallelContext(hmesh, psp, "prefill")
    sc = SamplerConfig(num_steps=2,
                       pipeline=PipelineConfig(pp=2, warmup_steps=1))
    seq = 32
    lat = jax.random.normal(jax.random.PRNGKey(2), (1, seq, 64), jnp.float32)
    cond = jax.random.normal(jax.random.PRNGKey(3),
                             (1, COND_TOKENS, cfg.d_model), jnp.float32)
    state = hybrid_state_shape(cfg, 1, seq, sc)
    tt = jnp.full((1,), 0.5, jnp.float32)

    def step(lat, cond, sk, sv):
        return dit_forward_displaced(params, cfg, ctx, latents=lat, cond=cond,
                                     timesteps=tt, kv_state=KVState(sk, sv),
                                     num_patches=2, pp=2)

    with comm.record("displaced_pipe") as tr:
        lowered = jax.jit(step).lower(lat, cond, state.k, state.v)
    if not any(e.stream == "pipe" for e in tr.events):
        print("commcheck FAIL: no pipe hand-off recorded in the displaced "
              "pipeline trace")
        return 1
    reports.append(comm.validate(tr, lowered.compile().as_text(), hmesh))

    # --- 2b. hierarchical two-level a2a (DESIGN.md §8.2): ulysses over
    # both boundaries with u_groups = N — the fast leg must stay inside
    # the machine, the slow leg's hops must declare-and-admit overlap ----
    hier_cfg = SPConfig(strategy="ulysses", sp_axes=("pod", "model"),
                        batch_axes=("data",), hier_a2a=True)
    hq = jax.random.normal(kq, (2, 32, 4, 16))  # 4 heads => P_u = 4, N = 2
    hk = jax.random.normal(kk, (2, 32, 4, 16))
    hv = jax.random.normal(kv, (2, 32, 4, 16))
    with comm.record("hier_a2a") as tr:
        lowered = jax.jit(
            lambda q, k, v: sp_attention(q, k, v, mesh=mesh, cfg=hier_cfg)
        ).lower(hq, hk, hv)
    hier_events = [e for e in tr.events if e.stream.startswith("hier")]
    labels = {e.channel.rsplit(".", 1)[-1] for e in hier_events}
    if not {"intra1", "inter1"} <= labels:
        print("commcheck FAIL: hierarchical a2a recorded no intra+inter "
              f"legs (channels: {sorted(labels)})")
        return 1
    m_fast = mesh.shape["model"]
    for e in hier_events:
        if "intra" in e.channel and any(s // m_fast != d // m_fast
                                        for s, d in e.perm):
            print(f"commcheck FAIL: fast leg {e.channel} crosses the "
                  f"machine boundary: {e.perm}")
            return 1
    if not all(e.overlaps for e in hier_events if "inter" in e.channel):
        print("commcheck FAIL: a hier inter hop declares no overlap")
        return 1
    reports.append(comm.validate(tr, lowered.compile().as_text(), mesh))

    # same program through the Pallas channel backend (interpret mode):
    # routes still present in HLO, semaphore protocol clean
    hier_pl = dataclasses.replace(hier_cfg, comm_backend="pallas",
                                  kernel_interpret=True)
    with comm.record("hier_a2a_pallas") as tr:
        lowered = jax.jit(
            lambda q, k, v: sp_attention(q, k, v, mesh=mesh, cfg=hier_pl)
        ).lower(hq, hk, hv)
    if not any(e.backend == "pallas" and e.stream.startswith("hier")
               for e in tr.events):
        print("commcheck FAIL: no pallas-backend hier puts recorded")
        return 1
    reports.append(comm.validate(tr, lowered.compile().as_text(), mesh,
                                 require_overlap=False))
    hier_sem = comm.validate_semaphores(tr)
    if not hier_sem.ok:
        print(hier_sem.summary())
        return 1

    # --- 3. Pallas backend (DESIGN.md §8.1): same swift_torus program,
    # semaphore-tracked channels + fused ring kernel, interpret mode -----
    psp = dataclasses.replace(sp, comm_backend="pallas", kernel_interpret=True)
    with comm.record("swift_torus_pallas") as tr:
        lowered = jax.jit(
            lambda q, k, v: sp_attention(q, k, v, mesh=mesh, cfg=psp)
        ).lower(q, k, v)
    if not any(e.backend == "pallas" for e in tr.events):
        print("commcheck FAIL: no pallas-backend puts recorded in the "
              "swift_torus_pallas trace")
        return 1
    if not tr.sem_events:
        print("commcheck FAIL: pallas backend recorded no semaphore events")
        return 1
    # route presence still holds on the emulation branch (the wire move is
    # a ppermute with the same pairs); overlap of the fused puts is the
    # kernel's own schedule, validated at the semaphore level below, so
    # HLO-level overlap admission is not required here.
    reports.append(comm.validate(tr, lowered.compile().as_text(), mesh,
                                 require_overlap=False))
    sem_rep = comm.validate_semaphores(tr)
    print(sem_rep.summary())

    ok = sem_rep.ok
    for rep in reports:
        print(rep.summary())
        ok &= rep.ok

    # --- 4. optional measured-schedule trace (DESIGN.md §12) ------------
    if ok and args.profile is not None:
        from ..serving import JsonlTracker
        tracker = JsonlTracker(args.profile)
        prof = comm.CommProfiler()
        with comm.profile(prof):
            # fresh lambdas: the profiler's callbacks are baked in at
            # trace time, so the validated-but-unprofiled jits above are
            # not reusable here
            jax.block_until_ready(jax.jit(
                lambda q, k, v: sp_attention(q, k, v, mesh=mesh, cfg=sp)
            )(q, k, v))
            jax.block_until_ready(jax.jit(
                lambda q, k, v: sp_attention(q, k, v, mesh=mesh, cfg=psp)
            )(q, k, v))
        n = comm.emit_leg_spans(prof, tracker)
        tracker.close()
        print(f"profile: wrote {n} spans to {tracker.path} "
              "(render with scripts/trace_report.py)")
        if n == 0:
            print("commcheck FAIL: profiled run produced no spans")
            return 1
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
