"""rwkv6-1.6b [ssm] — Finch, data-dependent decay, attention-free
[arXiv:2404.05892].

No attention ⇒ the paper's SP-attention technique is inapplicable
(DESIGN.md §5); sequence sharding instead uses a distributed
chunked-state prefix scan (log₂P ppermute rounds) over the WKV6
recurrence.  Decode is O(1)-state.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=0,  # attention-free
    n_kv_heads=0,
    d_ff=7168,
    vocab=65536,
    rope="none",
    norm="layernorm",
    ssm=SSMConfig(state_size=64, n_ssm_heads=32),  # head_size 64 ⇒ 32 heads
    sharding_overrides=(("vocab", ("data",)),),
    citation="arXiv:2404.05892",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=128,
        d_ff=256,
        vocab=512,
        ssm=SSMConfig(state_size=16, n_ssm_heads=8),
    )
