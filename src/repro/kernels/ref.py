"""Pure-jnp oracle for the flash_mqkv kernel (paper Algorithm 2 semantics).

Same contract as kernels.ops.flash_attention: position-array masking
(k_pos = -1 marks padding), optional carried-in online-softmax state, and
optional finalization — the reference every kernel sweep asserts against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = float("-inf")


def flash_attention_ref(
    q: jax.Array,  # [BH, Lq, D]
    k: jax.Array,  # [BH, Lk, D]
    v: jax.Array,  # [BH, Lk, D]
    q_pos: jax.Array,  # [Lq] int32 global positions
    k_pos: jax.Array,  # [Lk] int32; -1 = padding (masked out)
    *,
    scale: float | None = None,
    causal: bool = False,
    window: int | None = None,
    state: tuple[jax.Array, jax.Array, jax.Array] | None = None,  # (o', l, m)
    finalize: bool = True,
):
    """Returns o [BH, Lq, D] if finalize else (o', l, m) FA2-style state."""
    bh, lq, d = q.shape
    lk = k.shape[1]
    if scale is None:
        scale = d ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    ok = (k_pos >= 0)[None, :]
    if causal:
        ok = ok & (q_pos[:, None] >= k_pos[None, :])
    if window is not None:
        ok = ok & (k_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(ok[None], s, NEG_INF)

    m_cur = jnp.max(s, axis=-1)  # [BH, Lq]
    if state is not None:
        o_in, l_in, m_in = state
        m_new = jnp.maximum(m_in, m_cur)
    else:
        o_in = jnp.zeros((bh, lq, d), jnp.float32)
        l_in = jnp.zeros((bh, lq), jnp.float32)
        m_in = jnp.full((bh, lq), NEG_INF, jnp.float32)
        m_new = m_cur
    safe_m = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
    p = jnp.exp(s - safe_m[..., None])
    p = jnp.where(jnp.isneginf(s), 0.0, p)
    corr = jnp.where(jnp.isneginf(m_in) & jnp.isneginf(m_new), 0.0,
                     jnp.exp(m_in - safe_m))
    corr = jnp.where(jnp.isneginf(m_in), 0.0, corr)
    l = l_in * corr + jnp.sum(p, axis=-1)
    o = o_in * corr[..., None] + jnp.einsum("bqk,bkd->bqd", p,
                                            v.astype(jnp.float32))
    if not finalize:
        return o, l, m_new
    return (o / jnp.where(l == 0.0, 1.0, l)[..., None]).astype(q.dtype)
