"""Pallas TPU kernel: chunked RWKV6 (Finch) WKV scan.

The SSM counterpart of flash_mqkv: grid (batch·heads, n_chunks) with the
chunk axis sequential ("arbitrary"), carrying the recurrent state
S [N, N] in VMEM scratch across chunks — the same carried-running-state
pattern Algorithm 2 uses for (m, l), applied to the linear recurrence

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Within a chunk the recurrence is evaluated in matmul form (GLA-style
cumulative-decay trick, MXU-friendly):

    o = ((r·D₋) (k/D)^T ⊙ tril) v + diag(r·u·k) v + (r·D₋) S_in

Decays are clipped to [EPS, 1] so the cumulative-product normalisation
stays bounded (decays ≤ 1 by construction in RWKV6).

Validated in interpret mode against models/ssm.rwkv6_chunk_scan and the
naive sequential recurrence (tests/test_kernels_rwkv.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import tpu_compiler_params

EPS = 1e-6
DEFAULT_CHUNK = 64


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scratch, *, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scratch[...] = jnp.zeros_like(s_scratch)

    r = r_ref[...].astype(jnp.float32)  # [c, N]
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    w = jnp.clip(w_ref[...].astype(jnp.float32), EPS, 1.0)
    u = u_ref[...].astype(jnp.float32)  # [1, N]

    logw = jnp.log(w)
    logD = jnp.cumsum(logw, axis=0)  # inclusive cumulative decay
    D = jnp.exp(logD)
    Dm1 = jnp.exp(logD - logw)  # exclusive (D_{t-1})
    c = r.shape[0]

    r_sc = r * Dm1  # r_t ⊙ D_{t-1}
    k_sc = k / D    # k_s / D_s
    att = jax.lax.dot_general(r_sc, k_sc, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [c, c]
    tri = jnp.tril(jnp.ones((c, c), jnp.float32), k=-1)
    att = att * tri
    diag = jnp.sum(r * u * k, axis=1, keepdims=True)  # r_t·(u ⊙ k_t)
    o = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o = o + diag * v
    # cross-chunk: contribution of the carried state
    s_in = s_scratch[...]
    o = o + jax.lax.dot_general(r_sc, s_in, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[...] = o.astype(o_ref.dtype)

    # state update: S = a_c ⊙ S_in + sum_s (a_c / D_s ⊙ k_s) ⊗ v_s
    a_c = D[-1]  # [N]
    k_tail = k_sc * a_c[None, :]
    s_new = a_c[:, None] * s_in + jax.lax.dot_general(
        k_tail, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_scratch[...] = s_new


def rwkv6_wkv(
    r: jax.Array,  # [BH, L, N]
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # decay in (0, 1]
    u: jax.Array,  # [BH, N] per-head bonus
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> jax.Array:
    """Returns o [BH, L, N] (f32)."""
    bh, l, n = r.shape
    c = min(chunk, l)
    assert l % c == 0, (l, c)
    n_chunks = l // c
    u2 = u.reshape(bh, 1, n)

    kernel = functools.partial(_kernel, n_chunks=n_chunks)
    spec = pl.BlockSpec((None, c, n), lambda h, ci: (h, ci, 0))
    uspec = pl.BlockSpec((None, 1, n), lambda h, ci: (h, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(bh, n_chunks),
        in_specs=[spec, spec, spec, spec, uspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bh, l, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        compiler_params=tpu_compiler_params(pltpu,
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, w, u2)
