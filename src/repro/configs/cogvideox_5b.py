"""cogvideox-5b [dit] — the paper's video-generation workload (§5.1)
[arXiv:2408.06072].

Per the paper's §5.1: 24 attention heads with head_dim 64 (attention width
1536 ≠ d_model — supported via explicit projections).  42 uniform adaLN
blocks at d=3072 ≈ 4.8B parameters.  3D-causal-VAE + patchify stubbed;
latent frame tokens arrive precomputed.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="cogvideox-5b",
    family="dit",
    n_layers=42,
    d_model=3072,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,  # attention width 1536, as in the paper's workload table
    d_ff=12288,
    vocab=0,
    rope="rope",
    causal=False,
    act="gelu",
    norm="layernorm",
    citation="CogVideoX [18]",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256
    )
