"""fp8 wire codec (comm/compress.py): roundtrip accuracy + error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.compress import (
    WIRE_DTYPES,
    dequantize,
    ef_encode,
    has_wire_dtype,
    quantize,
    zero_feedback,
)

pytestmark = pytest.mark.skipif(
    not has_wire_dtype("float8_e4m3fn"),
    reason="jax build lacks float8 dtypes")


@pytest.mark.parametrize("wire", WIRE_DTYPES)
def test_quantize_roundtrip_relative_error_bounded(wire):
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64), jnp.float32)
    w, scale = quantize(x, wire)
    assert w.dtype == getattr(jnp, wire)
    y = dequantize(w, scale, jnp.float32)
    # e4m3 has a 3-bit mantissa (~6% step), e5m2 2 bits (~12%)
    tol = 0.08 if wire == "float8_e4m3fn" else 0.15
    err = np.abs(np.asarray(y) - np.asarray(x))
    assert err.max() <= tol * np.abs(np.asarray(x)).max()


def test_quantize_scale_tracks_absmax():
    x = jnp.array([[1e-3, -2e-3], [5e-4, 1.5e-3]], jnp.float32)
    _, scale = quantize(x, "float8_e4m3fn")
    # absmax maps to the format's max representable: scale = absmax / fmax
    fmax = float(jnp.finfo(jnp.float8_e4m3fn).max)
    assert np.isclose(float(scale), 2e-3 / fmax, rtol=1e-6)


def test_unknown_wire_dtype_raises():
    with pytest.raises(ValueError):
        quantize(jnp.zeros((2,)), "int4")


def test_error_feedback_reduces_accumulated_drift():
    """Repeatedly quantising a running sum WITH error feedback keeps the
    accumulated error near one quantisation step; without it the bias
    compounds linearly (the §8.2 justification for threading err through
    the inter-machine stages)."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (256,), jnp.float32)

    def run(steps, with_ef):
        acc = jnp.zeros_like(x)
        err = zero_feedback(x)
        for _ in range(steps):
            if with_ef:
                w, s, err = ef_encode(x, err, "float8_e4m3fn")
            else:
                w, s = quantize(x, "float8_e4m3fn")
            acc = acc + dequantize(w, s, jnp.float32)
        return acc

    steps = 50
    target = np.asarray(x) * steps
    drift_ef = np.abs(np.asarray(run(steps, True)) - target).max()
    drift_raw = np.abs(np.asarray(run(steps, False)) - target).max()
    assert drift_ef < drift_raw / 5
    assert drift_ef < 0.5  # stays O(one step), not O(steps)


def test_ef_encode_error_state_is_residual():
    x = jax.random.normal(jax.random.PRNGKey(2), (32,), jnp.float32)
    err0 = zero_feedback(x)
    w, s, err1 = ef_encode(x, err0, "float8_e4m3fn")
    resid = np.asarray(x) - np.asarray(dequantize(w, s, jnp.float32))
    np.testing.assert_allclose(np.asarray(err1), resid, rtol=1e-6, atol=1e-7)
