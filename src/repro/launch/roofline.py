"""Roofline analysis from the compiled dry-run artifact (assignment §Roofline).

Three terms per (arch × shape × mesh), all derived WITHOUT hardware:

  compute    = HLO_FLOPs(per device)      / peak_FLOPs
  memory     = HLO_bytes(per device)      / HBM_bw
  collective = collective_bytes(per dev)  / link_bw

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes (JAX reports the
per-device partitioned module); collective bytes are NOT in cost_analysis,
so we parse the optimized HLO text and sum the output-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(all-reduce counted twice: reduce-scatter + all-gather equivalent).

Collectives are additionally classified intra- vs inter-pod by inspecting
``source_target_pairs`` / ``replica_groups`` against the pod boundary —
this is what lets EXPERIMENTS.md verify the paper's topology-aware claim
(SwiftFusion keeps the high-volume Ring traffic inside the pod).
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e-class hardware constants (assignment-provided)
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # B/s per chip
LINK_BW = 50e9  # B/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*\S+\s*=\s*(?:\([^)]*\)|(\w+)\[([\d,]*)\][^ ]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_TUPLE_RE = re.compile(
    r"^\s*\S+\s*=\s*\((.*?)\)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{((?:\{[\d,]+\},?)*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _crosses_pod(line: str, pod_size: int) -> bool:
    m = _PAIRS_RE.search(line)
    if m:
        for pair in re.findall(r"\{(\d+),(\d+)\}", "{" + m.group(1) + "}"):
            a, b = int(pair[0]), int(pair[1])
            if a // pod_size != b // pod_size:
                return True
        return False
    m = _GROUPS_RE.search(line)
    if m:
        for grp in re.findall(r"\{([\d,]+)\}", "{" + m.group(1) + "}"):
            ranks = [int(r) for r in grp.split(",")]
            if len({r // pod_size for r in ranks}) > 1:
                return True
        return False
    return False


@dataclasses.dataclass
class CollectiveStats:
    bytes_total: int = 0
    bytes_inter_pod: int = 0
    counts: dict = dataclasses.field(default_factory=dict)

    def add(self, kind: str, nbytes: int, inter: bool) -> None:
        self.bytes_total += nbytes
        if inter:
            self.bytes_inter_pod += nbytes
        key = kind + ("/inter" if inter else "/intra")
        self.counts[key] = self.counts.get(key, 0) + 1


def parse_collectives(hlo_text: str, pod_size: int = 1 << 30) -> CollectiveStats:
    """Sum per-device collective bytes from partitioned optimized HLO."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if "-start(" not in line and not re.search(
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\(",
            line,
        ):
            continue
        if "-done(" in line or "-done " in line:
            continue
        m = _COLLECTIVE_RE.match(line)
        kind = None
        nbytes = 0
        if m and m.group(1):
            kind = m.group(3)
            nbytes = _shape_bytes(m.group(1), m.group(2))
        else:
            mt = _TUPLE_RE.match(line)
            if mt:
                kind = mt.group(2)
                # tuple shapes (async start ops): count the largest element
                # (the payload buffer), not control scalars
                sizes = [_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(mt.group(1))]
                nbytes = max(sizes) if sizes else 0
        if not kind:
            continue
        if kind == "all-reduce":
            nbytes *= 2  # RS + AG equivalent wire traffic
        stats.add(kind, nbytes, _crosses_pod(line, pod_size))
    return stats


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    collective_inter_pod: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float  # 6·N·D (dense) or 6·N_active·D
    useful_ratio: float  # MODEL_FLOPS / (HLO_FLOPs × chips)

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze(cost: dict, hlo_text: str, *, chips: int, pod_size: int,
            model_flops: float) -> Roofline:
    coll = parse_collectives(hlo_text, pod_size)
    return analyze_from_terms(
        flops=float(cost.get("flops", 0.0)),
        byts=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=float(coll.bytes_total),
        coll_inter=float(coll.bytes_inter_pod),
        chips=chips,
        model_flops=model_flops,
    )


def analyze_from_terms(*, flops: float, byts: float, coll_bytes: float,
                       coll_inter: float, chips: int,
                       model_flops: float) -> Roofline:
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = coll_bytes / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    total_hlo_flops = flops * chips
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes=coll_bytes,
        collective_inter_pod=coll_inter,
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_x,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=(model_flops / total_hlo_flops) if total_hlo_flops else 0.0,
    )
