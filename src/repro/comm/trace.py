"""Schedule tracing and compiled-HLO overlap validation (DESIGN.md §8).

The one-sided channel layer (channel.py / stream.py) *intends* a specific
overlap schedule: every ``Channel.put`` is a transfer whose latency should
hide behind some independent compute.  On real NVSHMEM hardware that
intent is enforced at runtime by stream ordering; under XLA it is realised
by the latency-hiding scheduler, which the channel layer can only steer
(issue the permute early, fence the consumer).  This module closes the
loop: it records the intended schedule at trace time and then checks the
*compiled* HLO actually admits it.

Two validation levels, matching what the backend exposes:

  * async backends (TPU): ``collective-permute-start``/``-done`` pairs —
    overlap is validated directly by requiring compute instructions
    scheduled between start and done.
  * sync backends (CPU test mesh): a single ``collective-permute`` op —
    overlap is validated at the dependency level: there must exist compute
    instructions in the same computation that neither feed the permute nor
    consume its result, i.e. the program as compiled leaves the scheduler
    free to run them concurrently with the wire transfer.

Events are matched to HLO ops through ``source_target_pairs``: the channel
knows its (axes, perm) and the validator expands that to flat device-id
pairs for the concrete mesh — no reliance on op names or metadata.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import re
from typing import Iterator

__all__ = [
    "TransferEvent",
    "SemEvent",
    "ScheduleTrace",
    "record",
    "emit",
    "emit_sem",
    "mark_compute",
    "HloInstr",
    "parse_computations",
    "collective_permutes",
    "expected_pairs",
    "independent_compute",
    "validate",
    "validate_semaphores",
    "ValidationReport",
    "SemReport",
]


# ---------------------------------------------------------------------------
# schedule recording (trace-time side channel, active only under record())
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransferEvent:
    """One intended transfer: a ``Channel.put`` observed at trace time."""

    stream: str  # owning Stream (or "" for a bare channel)
    channel: str  # channel name, e.g. "torus.pullq1"
    stage: int  # stage index within the stream program
    axes: tuple[str, ...]  # mesh axes the permute runs over
    perm: tuple[tuple[int, int], ...]  # logical (src, dst) pairs on ``axes``
    shape: tuple[int, ...]  # per-device payload shape (first tensor)
    n_tensors: int  # tensors moved by this put (k and v travel together)
    overlaps: str  # label of the compute this transfer should hide behind
    backend: str = "xla"  # lowering that issued the put ("xla" | "pallas")


@dataclasses.dataclass(frozen=True)
class SemEvent:
    """One semaphore-protocol step of the Pallas lowering (DESIGN.md §8.1).

    The Pallas backend realises put/signal/wait with explicit semaphores
    (DMA completion + REGULAR flags) instead of XLA data dependencies, so
    the schedule becomes a sequence of discrete protocol steps that can be
    checked for well-formedness independently of HLO:

        put     — the async (remote) copy is issued (rdma.start())
        signal  — the completion semaphore fires (DMA done / remote flag)
        wait    — the consumer blocks on the semaphore
        compute — a compute block consumed between issue and wait (the
                  overlap the fused kernel provides; emitted by the kernel
                  wrappers, not by bare channels)
    """

    kind: str  # "put" | "signal" | "wait" | "compute"
    sem: str  # semaphore id ("" for compute markers)
    stream: str = ""
    channel: str = ""
    stage: int = 0
    overlap: bool = False  # put declared in-kernel overlap (fused path)


@dataclasses.dataclass
class ScheduleTrace:
    """The recorded intent of one traced program."""

    name: str
    events: list[TransferEvent] = dataclasses.field(default_factory=list)
    sem_events: list[SemEvent] = dataclasses.field(default_factory=list)

    def by_perm(self) -> dict[tuple, list[TransferEvent]]:
        """Group events by (axes, perm) — the key that maps to HLO pairs."""
        out: dict[tuple, list[TransferEvent]] = {}
        for e in self.events:
            out.setdefault((e.axes, e.perm), []).append(e)
        return out

    @property
    def overlap_events(self) -> list[TransferEvent]:
        return [e for e in self.events if e.overlaps]


_ACTIVE: contextvars.ContextVar[ScheduleTrace | None] = contextvars.ContextVar(
    "repro_comm_trace", default=None)


@contextlib.contextmanager
def record(name: str) -> Iterator[ScheduleTrace]:
    """Record every Channel.put issued while tracing under this context."""
    tr = ScheduleTrace(name)
    tok = _ACTIVE.set(tr)
    try:
        yield tr
    finally:
        _ACTIVE.reset(tok)


def emit(event: TransferEvent) -> None:
    """Called by Channel.put; no-op unless a trace is being recorded."""
    tr = _ACTIVE.get()
    if tr is not None:
        tr.events.append(event)


def emit_sem(event: SemEvent) -> None:
    """Called by the Pallas backend; no-op unless a trace is recording."""
    tr = _ACTIVE.get()
    if tr is not None:
        tr.sem_events.append(event)


def mark_compute(label: str = "", stream: str = "") -> None:
    """Record one compute block consumed between a fused put's issue and
    its wait — the overlap evidence `validate_semaphores` checks."""
    emit_sem(SemEvent(kind="compute", sem="", stream=stream, channel=label))


# ---------------------------------------------------------------------------
# semaphore-schedule validation (the Pallas-path analogue of the HLO gate)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SemReport:
    """Well-formedness verdict on a recorded semaphore schedule."""

    trace: str
    puts: int
    waits: int
    failures: list[str]

    @property
    def ok(self) -> bool:
        return not self.failures

    def summary(self) -> str:
        status = "OK" if self.ok else "FAIL"
        lines = [f"comm.trace[{self.trace}] sem {status}: "
                 f"{self.puts} puts, {self.waits} waits"]
        lines += [f"  FAIL: {f}" for f in self.failures]
        return "\n".join(lines)


def validate_semaphores(trace: ScheduleTrace) -> SemReport:
    """Check the recorded semaphore schedule is a valid protocol pairing.

    Rules (program order = recorded order, which is trace-time issue
    order, i.e. the order the SPMD program executes the protocol steps):

      * no wait-before-put: every ``wait`` on a semaphore must be preceded
        by the ``put`` that will satisfy it;
      * every put is signaled exactly once — a put with zero signals is a
        transfer whose completion nothing observes (a lost flag), one with
        two is a double-fire;
      * a ``signal`` with no preceding put on its semaphore is spurious;
      * no blocking wait: a put that declared in-kernel overlap
        (``overlap=True``, the fused ring kernel's puts) must have at
        least one compute block between its issue and its wait — a wait
        immediately after the put serialises the transfer, which is
        exactly the schedule bug the fused kernel exists to avoid.
    """
    failures: list[str] = []
    put_idx: dict[str, int] = {}
    overlap_puts: set[str] = set()
    signal_count: dict[str, int] = {}
    wait_idx: dict[str, int] = {}
    compute_idxs: list[int] = []
    for i, e in enumerate(trace.sem_events):
        if e.kind == "put":
            if e.sem in put_idx:
                failures.append(f"{e.sem}: put issued twice")
            put_idx[e.sem] = i
            if e.overlap:
                overlap_puts.add(e.sem)
            signal_count.setdefault(e.sem, 0)
        elif e.kind == "signal":
            if e.sem not in put_idx:
                failures.append(f"{e.sem}: signal with no preceding put")
            signal_count[e.sem] = signal_count.get(e.sem, 0) + 1
        elif e.kind == "wait":
            if e.sem not in put_idx:
                failures.append(f"{e.sem}: wait before put")
            elif e.sem not in wait_idx:
                wait_idx[e.sem] = i
        elif e.kind == "compute":
            compute_idxs.append(i)
    for sem, n in signal_count.items():
        if n != 1:
            failures.append(f"{sem}: signaled {n} times (want exactly 1)")
    for sem in overlap_puts:
        wi = wait_idx.get(sem)
        if wi is None:
            continue
        pi = put_idx[sem]
        if not any(pi < ci < wi for ci in compute_idxs):
            failures.append(
                f"{sem}: blocking wait — no compute block between the "
                "put and its wait")
    return SemReport(
        trace=trace.name,
        puts=len(put_idx),
        waits=len(wait_idx),
        failures=failures,
    )


# ---------------------------------------------------------------------------
# HLO parsing (text-level; the stable surface across jax versions)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HloInstr:
    name: str  # %foo.1
    op: str  # collective-permute | fusion | dot | ...
    operands: tuple[str, ...]  # operand instruction names
    computation: str  # enclosing computation name
    index: int  # position within the computation (schedule order)
    line: str  # raw text (for pair extraction etc.)


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*[^=]*?\s([\w\-]+)\(")
_OPERAND_RE = re.compile(r"(%[\w.\-]+)")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")
_PAIR_RE = re.compile(r"\{(\d+),(\d+)\}")


def parse_computations(hlo_text: str) -> dict[str, list[HloInstr]]:
    """Split HLO module text into computations -> instruction lists.

    Text-level parsing is deliberate: it works on ``compile().as_text()``
    from every backend and keeps this module free of XLA client APIs.
    """
    comps: dict[str, list[HloInstr]] = {}
    current: str | None = None
    for raw in hlo_text.splitlines():
        stripped = raw.strip()
        # computation header: '%name (params...) -> type {'.  Params may be
        # tuple-typed (while/fori bodies) and so contain nested parens — the
        # greedy '\(.*\)' spans them; instruction lines are excluded by the
        # '=' guard and by not ending in '{'.
        head = re.match(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*\(.*\)\s*->.*\{$",
                        stripped)
        if head and "=" not in stripped.split("(")[0]:
            current = head.group(1)
            comps[current] = []
            continue
        if stripped.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(raw)
        if not m:
            continue
        name, op = m.group(1), m.group(2)
        # operands: %refs on the line after the op's open paren, minus self
        after = raw[m.end():]
        # cut trailing attribute blobs that may contain %-free ids only
        operands = tuple(o for o in _OPERAND_RE.findall(after) if o != name)
        comps[current].append(
            HloInstr(name=name, op=op, operands=operands,
                     computation=current, index=len(comps[current]), line=raw))
    return comps


def _pairs_of(line: str) -> frozenset[tuple[int, int]] | None:
    m = _PAIRS_RE.search(line)
    if not m:
        return None
    return frozenset((int(a), int(b)) for a, b in _PAIR_RE.findall(m.group(1)))


def collective_permutes(hlo_text: str) -> list[HloInstr]:
    """All collective-permute(-start) instructions in the module."""
    out = []
    for instrs in parse_computations(hlo_text).values():
        for ins in instrs:
            if ins.op in ("collective-permute", "collective-permute-start"):
                out.append(ins)
    return out


def expected_pairs(mesh, axes: tuple[str, ...],
                   perm: tuple[tuple[int, int], ...]) -> frozenset[tuple[int, int]]:
    """Expand a logical perm over ``axes`` to flat device-id pairs.

    ``lax.ppermute`` flattens multi-axis ranks major-first in the given
    axes order; every assignment of the remaining mesh axes replicates the
    perm.  This mirrors exactly how XLA emits source_target_pairs.
    """
    import numpy as np

    ids = np.vectorize(lambda d: d.id)(mesh.devices)
    names = list(mesh.axis_names)
    sub_sizes = [mesh.shape[a] for a in axes]
    other = [a for a in names if a not in axes]
    other_sizes = [mesh.shape[a] for a in other]

    def coords(flat: int, sizes: list[int]) -> list[int]:
        out = []
        for s in reversed(sizes):
            out.append(flat % s)
            flat //= s
        return list(reversed(out))

    pairs = set()
    n_other = 1
    for s in other_sizes:
        n_other *= s
    for oflat in range(n_other):
        oc = dict(zip(other, coords(oflat, other_sizes)))
        for (src, dst) in perm:
            sc = dict(zip(axes, coords(src, sub_sizes)))
            dc = dict(zip(axes, coords(dst, sub_sizes)))
            s_idx = tuple((sc | oc)[a] for a in names)
            d_idx = tuple((dc | oc)[a] for a in names)
            pairs.add((int(ids[s_idx]), int(ids[d_idx])))
    return frozenset(pairs)


# ---------------------------------------------------------------------------
# overlap analysis
# ---------------------------------------------------------------------------

_COMPUTE_OPS = ("fusion", "dot", "convolution", "reduce", "exponential")


def _reach(instrs: list[HloInstr]) -> tuple[dict, dict]:
    """(ancestors, descendants) name->set maps within one computation."""
    by_name = {i.name: i for i in instrs}
    anc: dict[str, set[str]] = {}

    def ancestors(n: str) -> set[str]:
        if n in anc:
            return anc[n]
        anc[n] = set()  # cycle guard (HLO is a DAG; guard anyway)
        acc: set[str] = set()
        for o in by_name[n].operands if n in by_name else ():
            if o in by_name:
                acc.add(o)
                acc |= ancestors(o)
        anc[n] = acc
        return acc

    desc: dict[str, set[str]] = {i.name: set() for i in instrs}
    for i in instrs:
        for a in ancestors(i.name):
            desc[a].add(i.name)
    return anc, desc


def independent_compute(instrs: list[HloInstr], permute: HloInstr) -> list[HloInstr]:
    """Compute instructions with no dependence either way on ``permute`` —
    exactly the set a latency-hiding scheduler may run during the wire
    transfer."""
    anc, desc = _reach(instrs)
    excl = anc.get(permute.name, set()) | desc.get(permute.name, set())
    excl.add(permute.name)
    return [i for i in instrs
            if i.name not in excl and i.op in _COMPUTE_OPS]


def _between_start_done(instrs: list[HloInstr], start: HloInstr) -> list[HloInstr]:
    """Compute instructions scheduled between an async start and its done."""
    done_idx = None
    for i in instrs:
        if i.op == "collective-permute-done" and start.name in i.operands:
            done_idx = i.index
            break
    if done_idx is None:
        return []
    return [i for i in instrs
            if start.index < i.index < done_idx and i.op in _COMPUTE_OPS]


# ---------------------------------------------------------------------------
# validation entry point
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ValidationReport:
    trace: str
    hlo_permutes: int
    matched_groups: int
    overlapped: list[str]  # channel names whose overlap intent is satisfied
    failures: list[str]

    @property
    def ok(self) -> bool:
        return not self.failures

    def summary(self) -> str:
        status = "OK" if self.ok else "FAIL"
        lines = [f"comm.trace[{self.trace}] {status}: "
                 f"{self.hlo_permutes} collective-permutes, "
                 f"{self.matched_groups} schedule groups matched, "
                 f"{len(self.overlapped)} overlap intents validated"]
        lines += [f"  FAIL: {f}" for f in self.failures]
        return "\n".join(lines)


def validate(trace: ScheduleTrace, hlo_text: str, mesh,
             *, require_overlap: bool = True) -> ValidationReport:
    """Check the compiled HLO against the recorded schedule.

    For every (axes, perm) group of recorded puts there must be at least
    one collective-permute with the expanded device pairs (XLA may merge
    same-perm puts — k and v travel in one combined op — so counts are
    matched as >= 1 per group, not exactly).  For every put that declared
    an ``overlaps`` intent, the matching permute must admit overlap: async
    start/done with compute between them, or (sync backends) independent
    compute in the same computation.
    """
    comps = parse_computations(hlo_text)
    permutes = collective_permutes(hlo_text)
    failures: list[str] = []
    overlapped: list[str] = []
    groups = trace.by_perm()
    for (axes, perm), events in groups.items():
        want = expected_pairs(mesh, axes, perm)
        matches = [p for p in permutes if _pairs_of(p.line) == want]
        if not matches:
            failures.append(
                f"{events[0].channel}: no collective-permute with pairs "
                f"{sorted(want)} in compiled HLO")
            continue
        for e in events:
            if not e.overlaps:
                continue
            ok = False
            for p in matches:
                instrs = comps[p.computation]
                if p.op == "collective-permute-start":
                    ok = bool(_between_start_done(instrs, p))
                else:
                    ok = bool(independent_compute(instrs, p))
                if ok:
                    break
            if ok:
                if e.channel not in overlapped:
                    overlapped.append(e.channel)
            else:
                failures.append(
                    f"{e.channel} (stage {e.stage}): transfer cannot overlap "
                    f"'{e.overlaps}' — no independent compute in "
                    f"{matches[0].computation}")
    if require_overlap and trace.overlap_events and not overlapped:
        failures.append("no overlap intent could be validated")
    return ValidationReport(
        trace=trace.name,
        hlo_permutes=len(permutes),
        matched_groups=len(groups) - sum(
            1 for f in failures if "no collective-permute" in f),
        overlapped=overlapped,
        failures=failures,
    )
