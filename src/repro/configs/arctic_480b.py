"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base].

Dense-MoE hybrid: every layer has a dense FFN residual *in parallel* with
the routed top-2 MoE.  Expert weights are sharded expert-dim over 'model'
and hidden-dim over 'data' (sharding_overrides) so the 480B total fits
256 × 16 GiB chips.
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,  # dense-residual hidden size
    vocab=32000,
    rope="rope",
    act="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(
        n_experts=128,
        top_k=2,
        dense_residual=True,
        moe_d_ff=4864,
        capacity_factor=1.25,
    ),
    sharding_overrides=(
        ("experts", ("model",)),
        ("expert_mlp", ("data",)),
        ("mlp", ("data",)),
        ("vocab", ("data",)),
        ("heads_flat", ("data",)),
        ("kv_heads_flat", ("data",)),
    ),
    citation="hf:Snowflake/snowflake-arctic-base",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=128,
        vocab=512,
        moe=MoEConfig(n_experts=4, top_k=2, dense_residual=True, moe_d_ff=128),
        sharding_overrides=(),
    )
