"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer
[arXiv:2411.13676].

Each layer runs a GQA attention branch and an SSM (mamba-style selective
scan) branch in parallel on the same input, outputs mean-combined after
per-branch normalisation.  Layers {0, mid, last} use global attention, all
others sliding-window (Hymba §2.2).
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    rope="rope",
    act="swiglu",
    norm="rmsnorm",
    window=2048,  # SWA layers; global layers = {0, mid, last}
    ssm=SSMConfig(state_size=16, expand=1, n_ssm_heads=25),
    citation="arXiv:2411.13676",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab=512,
        window=16,
        ssm=SSMConfig(state_size=8, expand=1, n_ssm_heads=4),
    )
