"""Whisper-style encoder-decoder (audio family).

The mel-spectrogram + conv feature extractor is the stubbed modality
frontend (DESIGN.md §6): the model consumes precomputed frame embeddings
[B, encoder_seq, d_model].  Everything else — sinusoidal positions,
bidirectional encoder, causal decoder with cross-attention — is real.

Decode mode: self-attention KV is cached (sharded over SP axes); the
encoder memory is passed in and cross-attention recomputes its K/V per
step (memory is small: 1.5k frames).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from .blocks import (
    ParallelContext,
    ParamBuilder,
    Params,
    attention,
    init_attention,
    init_mlp,
    init_norm,
    linear,
    mlp,
    norm,
    sinusoidal_embedding,
    stack_layers,
)


def _init_enc_layer(key, cfg: ModelConfig):
    b = ParamBuilder(key, dtype=jnp.dtype(cfg.dtype))
    init_norm(b, "ln_attn", cfg.d_model, cfg.norm)
    init_attention(b, cfg)
    init_norm(b, "ln_mlp", cfg.d_model, cfg.norm)
    init_mlp(b, cfg)
    return b.params, b.axes


def _init_dec_layer(key, cfg: ModelConfig):
    b = ParamBuilder(key, dtype=jnp.dtype(cfg.dtype))
    init_norm(b, "ln_self", cfg.d_model, cfg.norm)
    init_attention(b, cfg, prefix="self_attn")
    init_norm(b, "ln_cross", cfg.d_model, cfg.norm)
    init_attention(b, cfg, prefix="cross_attn")
    init_norm(b, "ln_mlp", cfg.d_model, cfg.norm)
    init_mlp(b, cfg)
    return b.params, b.axes


def init_whisper(cfg: ModelConfig, key: jax.Array, ep_degree: int = 1):
    k1, k2, k3 = jax.random.split(key, 3)
    b = ParamBuilder(k1, dtype=jnp.dtype(cfg.dtype))
    b.add("embed", (cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02)
    init_norm(b, "ln_enc_f", cfg.d_model, cfg.norm)
    init_norm(b, "ln_dec_f", cfg.d_model, cfg.norm)
    params, axes = b.params, b.axes
    ep, ea = stack_layers(partial(_init_enc_layer, cfg=cfg), cfg.encoder_layers, k2)
    dp, da = stack_layers(partial(_init_dec_layer, cfg=cfg), cfg.n_layers, k3)
    params["enc_layers"], axes["enc_layers"] = ep, ea
    params["dec_layers"], axes["dec_layers"] = dp, da
    return params, axes


def encode(params: Params, frames: jax.Array, cfg: ModelConfig,
           ctx: ParallelContext) -> jax.Array:
    """frames [B, T_enc, d] (stub frontend output) -> memory [B, T_enc, d]."""
    t = frames.shape[1]
    x = frames + sinusoidal_embedding(t, cfg.d_model).astype(frames.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(t)[None], frames.shape[:2])
    enc_ctx = ctx if not ctx.decode else ParallelContext(ctx.mesh, ctx.sp, "prefill")

    def body(x, lp):
        h = norm(x, lp["ln_attn"], cfg.norm)
        o, _ = attention(h, lp["attn"], cfg, enc_ctx, positions, causal=False)
        x = x + o
        x = x + mlp(norm(x, lp["ln_mlp"], cfg.norm), lp["mlp"], cfg)
        return x, None

    body = enc_ctx.remat_wrap(body) if ctx.mode == "train" else body
    x, _ = lax.scan(body, x, params["enc_layers"],
                    unroll=cfg.encoder_layers <= 2)
    return norm(x, params["ln_enc_f"], cfg.norm)


def decode_forward(
    params: Params,
    cfg: ModelConfig,
    ctx: ParallelContext,
    *,
    tokens: jax.Array,  # [B, L]
    memory: jax.Array,  # [B, T_enc, d] encoder output
    caches: Params | None = None,
    cur_index: jax.Array | None = None,
) -> tuple[jax.Array, Params | None]:
    b_, l_ = tokens.shape
    x = params["embed"].astype(cfg.dtype)[tokens]
    if ctx.decode:
        positions = jnp.broadcast_to(cur_index, (b_, 1)).astype(jnp.int32)
        pos_emb = sinusoidal_embedding(4 * 65536, cfg.d_model)[cur_index][None, None]
    else:
        positions = jnp.broadcast_to(jnp.arange(l_)[None], (b_, l_))
        pos_emb = sinusoidal_embedding(l_, cfg.d_model)[None]
    x = x + pos_emb.astype(x.dtype)

    def body(carry, xs):
        x = carry
        lp = xs["params"]
        cache = xs.get("cache")
        h = norm(x, lp["ln_self"], cfg.norm)
        kv_cache = (cache["k"], cache["v"]) if ctx.decode else None
        o, upd = attention(h, lp["self_attn"], cfg, ctx, positions,
                           kv_cache=kv_cache, cur_index=cur_index, causal=True)
        x = x + o
        h = norm(x, lp["ln_cross"], cfg.norm)
        o, _ = attention(h, lp["cross_attn"], cfg, ctx, positions, xkv=memory,
                         causal=False)
        x = x + o
        x = x + mlp(norm(x, lp["ln_mlp"], cfg.norm), lp["mlp"], cfg)
        new_cache = {"k": upd[0], "v": upd[1]} if ctx.decode else {}
        return x, new_cache

    xs = {"params": params["dec_layers"]}
    if caches is not None:
        xs["cache"] = caches
    body = ctx.remat_wrap(body)
    x, new_caches = lax.scan(body, x, xs, unroll=cfg.n_layers <= 2)
    x = norm(x, params["ln_dec_f"], cfg.norm)
    logits = jnp.einsum("bld,vd->blv", x, params["embed"].astype(x.dtype))
    return logits, new_caches if caches is not None else None


def init_whisper_caches(cfg: ModelConfig, batch: int, max_len: int,
                        dtype=jnp.bfloat16) -> Params:
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, hkv, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, hkv, hd), dtype),
    }
