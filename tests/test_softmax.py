"""Property tests for the online-softmax merge algebra (paper Appendix C)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import MaskSpec, Partial, attend_partial, empty_partial, finalize, merge
from repro.core.softmax import attend_chunked, reference_attention

jax.config.update("jax_platform_name", "cpu")


def _rand_partial(seed, b=1, lq=4, hq=2, d=8):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    return Partial(
        o=jax.random.normal(k1, (b, lq, hq, d)),
        l=jax.nn.softplus(jax.random.normal(k2, (b, hq, lq))),
        m=jax.random.normal(k3, (b, hq, lq)) * 3.0,
    )


@given(st.integers(0, 10_000), st.integers(0, 10_000), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_merge_associative(s1, s2, s3):
    a, b, c = _rand_partial(s1), _rand_partial(s2), _rand_partial(s3)
    left = merge(merge(a, b), c)
    right = merge(a, merge(b, c))
    np.testing.assert_allclose(finalize(left), finalize(right), rtol=1e-5, atol=1e-5)


@given(st.integers(0, 10_000), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_merge_commutative(s1, s2):
    a, b = _rand_partial(s1), _rand_partial(s2)
    np.testing.assert_allclose(
        finalize(merge(a, b)), finalize(merge(b, a)), rtol=1e-5, atol=1e-5
    )


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_merge_identity(seed):
    a = _rand_partial(seed)
    e = empty_partial(*a.o.shape)
    out = merge(a, e)
    np.testing.assert_allclose(finalize(out), finalize(a), rtol=1e-6)
    out = merge(e, a)
    np.testing.assert_allclose(finalize(out), finalize(a), rtol=1e-6)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("window", [None, 10])
@pytest.mark.parametrize("n_chunks", [1, 2, 4])
def test_chunked_equals_full(causal, window, n_chunks):
    key = jax.random.PRNGKey(0)
    b, l, hq, hkv, d = 2, 32, 4, 2, 16
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, l, hq, d))
    k = jax.random.normal(kk, (b, l, hkv, d))
    v = jax.random.normal(kv, (b, l, hkv, d))
    ref = reference_attention(q, k, v, mask=MaskSpec(causal=causal, window=window))
    cs = l // n_chunks
    chunks = [(k[:, i * cs:(i + 1) * cs], v[:, i * cs:(i + 1) * cs], i * cs)
              for i in range(n_chunks)]
    out = finalize(attend_chunked(q, chunks, causal=causal, window=window))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_chunk_order_invariance():
    key = jax.random.PRNGKey(1)
    b, l, h, d = 1, 24, 2, 8
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, l, h, d))
    k = jax.random.normal(kk, (b, l, h, d))
    v = jax.random.normal(kv, (b, l, h, d))
    cs = 8
    chunks = [(k[:, i:i + cs], v[:, i:i + cs], i) for i in range(0, l, cs)]
    a = finalize(attend_chunked(q, chunks, causal=True))
    bb = finalize(attend_chunked(q, chunks[::-1], causal=True))
    np.testing.assert_allclose(a, bb, rtol=1e-5, atol=1e-5)


def test_fully_masked_rows_are_zero():
    """First token with window/causal edge: rows with zero valid keys."""
    q = jnp.ones((1, 4, 1, 8))
    k = jnp.ones((1, 4, 1, 8))
    v = jnp.ones((1, 4, 1, 8))
    # k chunk strictly in the future of all q
    p = attend_partial(q, k, v, mask=MaskSpec(causal=True, q_offset=0, k_offset=100))
    out = finalize(p)
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_allclose(out, 0.0)
