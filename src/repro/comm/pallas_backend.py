"""Pallas lowering of the one-sided channel verbs (DESIGN.md §8.1).

The XLA backend (channel.py) leaves the put's overlap to the latency-hiding
scheduler; this backend issues the transfer *itself*, the way the paper's
NVSHMEM kernels do, with explicit semaphores:

    put     -> ``pltpu.make_async_remote_copy(...).start()``: the RDMA is
               started from inside a Pallas kernel, on the DMA engines,
               while the kernel's compute continues.
    signal  -> the copy's recv semaphore (``pltpu.SemaphoreType.DMA``):
               signalled by hardware when the payload has landed — the
               NVSHMEM signal flag, with no flag tensor materialised.
    wait    -> ``dma.wait()`` (``pltpu.semaphore_wait`` on the recv
               semaphore): the receiver-side spin-wait, executed as late
               as the schedule allows.

Two lowering branches, selected by ``interpret`` / the runtime platform:

  * **TPU** (``interpret=False`` on a TPU backend): a kernel performs the
    remote copy proper.  The destination rank comes from the channel's
    perm table indexed by ``lax.axis_index`` — a *distance*, exactly like
    the XLA route.  Only single-axis channels lower this way (the RDMA
    ``device_id`` is a coordinate along one mesh axis); multi-axis routes
    fall back to the emulation branch.
  * **interpret / CPU CI** (the tested path): inter-device wire movement
    is not expressible inside an interpret-mode kernel, so the wire move
    stays a ``lax.ppermute`` (same HLO pairs, so `trace.validate` keeps
    working unchanged) and a *landing kernel* executes the put/signal/wait
    protocol on the received buffer: an in-kernel async copy
    (``pltpu.make_async_copy`` + DMA semaphore) delivers the payload into
    the receive buffer.  Everything downstream of the channel — the fused
    ring kernel, the semaphore schedule, trace validation — runs for real.

Every protocol step is recorded as a ``trace.SemEvent`` so commcheck can
validate the schedule's well-formedness (pairing, no wait-before-put, no
blocking wait) next to the HLO-level overlap checks.
"""
from __future__ import annotations

import itertools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import profiler as _profiler
from . import trace as _trace

__all__ = ["BACKENDS", "deliver", "fused_transfer_events", "new_sem",
           "landing_copy"]

BACKENDS = ("xla", "pallas")

_sem_counter = itertools.count()


def new_sem(channel_name: str, stage: int) -> str:
    """Mint a unique semaphore id for one put (trace bookkeeping only —
    the runtime semaphore is a kernel scratch, not addressed by name)."""
    return f"{channel_name}.s{stage}#{next(_sem_counter)}"


def _landing_kernel(*refs):
    """Deliver ``n`` received buffers through in-kernel async copies.

    refs = (in_0..in_{n-1}, out_0..out_{n-1}, sem_0..sem_{n-1}).  All
    copies are started before any is waited — the multi-tensor put (K and
    V ride one route) stays a single protocol step.
    """
    n = len(refs) // 3
    ins, outs, sems = refs[:n], refs[n:2 * n], refs[2 * n:]
    dmas = [pltpu.make_async_copy(i, o, s)
            for i, o, s in zip(ins, outs, sems)]
    for dma in dmas:
        dma.start()
    for dma in dmas:
        dma.wait()


def landing_copy(tensors: Sequence[jax.Array]) -> tuple[jax.Array, ...]:
    """Run the landing kernel over ``tensors`` (interpret mode).

    One ``pallas_call`` delivers all tensors of a put: the buffers stay in
    ANY/HBM space (no VMEM staging of arbitrarily-shaped payloads) and one
    DMA semaphore per tensor tracks completion.
    """
    tensors = tuple(tensors)
    n = len(tensors)
    out = pl.pallas_call(
        _landing_kernel,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * n,
        out_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * n,
        out_shape=[jax.ShapeDtypeStruct(t.shape, t.dtype) for t in tensors],
        scratch_shapes=[pltpu.SemaphoreType.DMA] * n,
        interpret=True,
    )(*tensors)
    return tuple(out)


def _perm_table(perm: Sequence[tuple[int, int]], size: int) -> jax.Array:
    tbl = [0] * size
    for s, d in perm:
        tbl[s] = d
    return jnp.asarray(tbl, jnp.int32)


def _remote_put_kernel(dst_ref, *refs):
    """TPU branch: remote-copy every tensor to ``dst`` (scalar prefetch).

    refs = (in_0.., out_0.., send_sem_0.., recv_sem_0..).  The out refs
    are this device's *receive* buffers — written by the neighbour's
    symmetric copy, exactly NVSHMEM's symmetric-heap contract.
    """
    n = len(refs) // 4
    ins, outs = refs[:n], refs[n:2 * n]
    send, recv = refs[2 * n:3 * n], refs[3 * n:]
    dmas = [
        pltpu.make_async_remote_copy(
            src_ref=i, dst_ref=o, send_sem=s, recv_sem=r,
            device_id=(dst_ref[0],),
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        for i, o, s, r in zip(ins, outs, send, recv)
    ]
    for dma in dmas:
        dma.start()
    for dma in dmas:
        dma.wait()


def _tpu_remote_put(tensors: tuple[jax.Array, ...], axis: str,
                    perm: Sequence[tuple[int, int]],
                    size: int) -> tuple[jax.Array, ...]:
    """In-kernel one-sided put along a single mesh axis (TPU only).

    Untestable on the CPU CI (no RDMA in interpret mode); exercised on
    hardware via ``backend="pallas", interpret=False``.
    """
    n = len(tensors)
    dst = _perm_table(perm, size)[lax.axis_index(axis)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * n,
        out_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * n,
        scratch_shapes=([pltpu.SemaphoreType.DMA] * (2 * n)),
    )
    from ..compat import tpu_compiler_params

    out = pl.pallas_call(
        _remote_put_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(t.shape, t.dtype) for t in tensors],
        compiler_params=tpu_compiler_params(
            pltpu, has_side_effects=True, collective_id=0),
    )(dst[None], *tensors)
    return tuple(out)


def deliver(
    tensors: Sequence[jax.Array],
    axes: tuple[str, ...],
    perm: Sequence[tuple[int, int]],
    *,
    interpret: bool = True,
    profile_src=None,
) -> tuple[jax.Array, ...]:
    """Move ``tensors`` one hop along the channel route, Pallas-lowered.

    The caller (Channel.put) owns the trace events; this function owns the
    lowering branch choice.  ``profile_src`` (the owning Channel, when a
    runtime profiler is active) brackets the landing kernel's DMA
    semaphore wait as its own span — the protocol cost on top of the
    wire move (DESIGN.md §12).
    """
    tensors = tuple(tensors)
    on_tpu = jax.default_backend() == "tpu"
    if on_tpu and not interpret and len(axes) == 1:
        size = max(max(s, d) for s, d in perm) + 1
        return _tpu_remote_put(tensors, axes[0], perm, size)
    # emulation branch: ppermute carries the bytes (keeping the HLO route
    # validatable), the landing kernel executes the semaphore protocol
    moved = tuple(lax.ppermute(t, axes, perm=list(perm)) for t in tensors)
    prof = _profiler.active()
    meta = None
    if prof is not None and profile_src is not None:
        meta = prof.new_leg(
            kind="comm", stream=profile_src.stream,
            channel=f"{profile_src.name}.semwait", stage=profile_src.stage,
            axes=tuple(axes), nbytes=_profiler.nbytes_of(tensors),
            n_tensors=len(tensors), backend="pallas", intent="sem")
        _profiler.mark(prof, meta, "issue", moved)
    out = landing_copy(moved)
    if meta is not None:
        _profiler.mark(prof, meta, "signal", out)
    return out


def fused_transfer_events(
    channel,
    shape: tuple[int, ...],
    n_tensors: int,
    *,
    overlaps: str,
) -> str:
    """Record the schedule of an *in-kernel* fused put (ring_flash.py):
    the kernel issues the copy at its first grid step and waits only after
    its last compute block, so the event sequence is put → signal at
    completion; the matching SemEvent('wait') is emitted by InFlight.wait
    and the kernel wrapper contributes the 'compute' markers in between.
    Returns the minted semaphore id.
    """
    sem = new_sem(channel.name, channel.stage)
    _trace.emit(_trace.TransferEvent(
        stream=channel.stream, channel=channel.name, stage=channel.stage,
        axes=tuple(channel.axes), perm=tuple(channel.perm),
        shape=tuple(shape), n_tensors=n_tensors,
        overlaps=overlaps, backend="pallas"))
    _trace.emit_sem(_trace.SemEvent(
        kind="put", sem=sem, stream=channel.stream, channel=channel.name,
        stage=channel.stage, overlap=True))
    return sem
